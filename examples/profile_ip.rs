//! Perf-pass instrumentation driver (EXPERIMENTS.md §Perf): phase
//! breakdown of every preset on the n=6000 SPM archetype.
//!
//! ```bash
//! cargo run --release --example profile_ip
//! ```

use mtkahypar::coordinator::context::{Context, Preset};
use mtkahypar::coordinator::partitioner;
use mtkahypar::generators;
use std::time::Instant;

fn main() {
    let hg = generators::spm_hypergraph(6000, 6000, 7, 7);
    println!(
        "driver: n={} m={} pins={} (SPM archetype)",
        hg.num_nodes(),
        hg.num_nets(),
        hg.num_pins()
    );
    for preset in [Preset::Default, Preset::DefaultFlows, Preset::Quality, Preset::Deterministic]
    {
        let ctx = Context::new(preset, 8, 0.03).with_seed(1).with_threads(1);
        let s = Instant::now();
        let phg = partitioner::partition(&hg, &ctx);
        println!(
            "{:<18} total {:>6.2}s km1={}",
            preset.name(),
            s.elapsed().as_secs_f64(),
            phg.km1()
        );
        for (n, t) in ctx.timer.snapshot() {
            if t > 0.05 {
                println!("    {n:<24} {t:.2}s");
            }
        }
        assert!(phg.is_balanced());
    }
}
