//! SAT formula partitioning (paper §12's PRIMAL/DUAL/LITERAL benchmark
//! families): encode a random community-structured CNF in all three
//! hypergraph representations and compare the presets on each.
//!
//! ```bash
//! cargo run --release --example sat_partitioning
//! ```

use mtkahypar::coordinator::context::{Context, Preset};
use mtkahypar::coordinator::partitioner;
use mtkahypar::generators::{sat_hypergraph, SatRepresentation};
use std::time::Instant;

fn main() {
    let reps = [
        ("PRIMAL", SatRepresentation::Primal),
        ("DUAL", SatRepresentation::Dual),
        ("LITERAL", SatRepresentation::Literal),
    ];
    let presets = [Preset::Speed, Preset::Default, Preset::DefaultFlows, Preset::Deterministic];
    for (name, rep) in reps {
        let hg = sat_hypergraph(1500, 6000, rep, 3);
        println!(
            "\n### {name}: n={} m={} pins={}",
            hg.num_nodes(),
            hg.num_nets(),
            hg.num_pins()
        );
        println!("| preset | km1 | cut | imbalance | time [s] |");
        println!("|---|---|---|---|---|");
        for preset in presets {
            let ctx = Context::new(preset, 8, 0.03).with_seed(11).with_threads(4);
            let start = Instant::now();
            let phg = partitioner::partition(&hg, &ctx);
            println!(
                "| {} | {} | {} | {:.4} | {:.2} |",
                preset.name(),
                phg.km1(),
                phg.cut(),
                phg.imbalance(),
                start.elapsed().as_secs_f64()
            );
            assert!(phg.is_balanced(), "{name}/{preset:?}");
        }
    }
    println!("\nDUAL instances (clauses as nodes) have larger nets — exactly the regime");
    println!("where the connectivity metric and FM gain caching differ most from graphs.");
}
