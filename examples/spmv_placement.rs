//! Sparse-matrix placement: the paper's motivating application — minimize
//! the communication volume of a parallel SpMV by partitioning the
//! column-net hypergraph of a sparse matrix (connectivity metric =
//! communication volume, §1/§2).
//!
//! ```bash
//! cargo run --release --example spmv_placement
//! ```

use mtkahypar::coordinator::context::{Context, Preset};
use mtkahypar::coordinator::partitioner;
use mtkahypar::generators;
use std::time::Instant;

fn main() {
    // rows = nets over their nonzero columns (banded + long-range fills)
    let hg = generators::spm_hypergraph(6000, 6000, 7, 7);
    println!(
        "sparse matrix model: {} cols (nodes), {} rows (nets), {} nnz (pins)",
        hg.num_nodes(),
        hg.num_nets(),
        hg.num_pins()
    );
    println!("\n| k | comm. volume (km1) | imbalance | time [s] |");
    println!("|---|---|---|---|");
    for k in [2usize, 4, 8, 16] {
        let ctx = Context::new(Preset::Default, k, 0.03).with_seed(1).with_threads(4);
        let start = Instant::now();
        let phg = partitioner::partition(&hg, &ctx);
        println!(
            "| {k} | {} | {:.4} | {:.2} |",
            phg.km1(),
            phg.imbalance(),
            start.elapsed().as_secs_f64()
        );
        assert!(phg.is_balanced());
    }
    println!("\ncommunication volume grows sublinearly in k on banded matrices — the");
    println!("hypergraph model (km1) counts each boundary row once per extra block.");
}
