//! Quickstart: partition a synthetic hypergraph with the default
//! configuration and print the result report.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use mtkahypar::coordinator::report::PartitionReport;
use mtkahypar::prelude::*;
use std::time::Instant;

fn main() {
    // a hypergraph with 8 planted blocks — the partitioner should
    // recover a cut close to the planted one
    let hg = generators::planted_hypergraph(
        &PlantedParams { n: 4000, m: 7000, blocks: 8, ..Default::default() },
        42,
    );
    println!(
        "instance: n={} m={} pins={}",
        hg.num_nodes(),
        hg.num_nets(),
        hg.num_pins()
    );

    let ctx = Context::new(Preset::Default, 8, 0.03).with_seed(42).with_threads(4);
    let start = Instant::now();
    let partition = partitioner::partition(&hg, &ctx);
    let secs = start.elapsed().as_secs_f64();

    let report = PartitionReport::from_partition(
        "Mt-KaHyPar-D",
        &partition,
        secs,
        ctx.timer.snapshot(),
    );
    report.print();
    assert!(partition.is_balanced());
    partition.verify_consistency().expect("internal consistency");
    println!("\nOK — balanced {}-way partition with km1 = {}", partition.k(), partition.km1());
}
