//! End-to-end driver proving that all layers compose (EXPERIMENTS.md
//! records this run): generate a realistic workload, run every framework
//! configuration plus the baselines, exercise the AOT L1/L2 path (gain
//! oracle + spectral portfolio member) against the Rust implementation,
//! and report the paper's headline metric (connectivity) per solver.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use mtkahypar::benchkit::{baselines, suites};
use mtkahypar::coordinator::context::{Context, Preset};
use mtkahypar::coordinator::partitioner;
use mtkahypar::generators::{self, PlantedParams};
use mtkahypar::metrics;
use mtkahypar::runtime;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    println!("=== Mt-KaHyPar-rs end-to-end driver ===\n");

    // ---- layer check: AOT artifacts (L1 Pallas kernel + L2 model) ----
    match runtime::global() {
        Some(rt) => {
            // the AOT gain oracle must agree with the Rust gain definition
            let hg = generators::planted_hypergraph(
                &PlantedParams { n: 100, m: 120, blocks: 2, ..Default::default() },
                3,
            );
            let parts: Vec<u32> = (0..100).map(|u| (u % 2) as u32).collect();
            let nodes: Vec<u32> = (0..100).collect();
            let nets: Vec<u32> = hg.nets().take(128).collect();
            let (benefit, _pen) =
                runtime::gain_tile_for(rt, &hg, &parts, &nodes, &nets, 2).expect("oracle");
            let phg =
                mtkahypar::partition::PartitionedHypergraph::new(Arc::new(hg.clone()), 2);
            phg.assign_all(&parts, 1);
            let mut checked = 0;
            for (i, &u) in nodes.iter().enumerate() {
                let mut b = 0f32;
                for &e in hg.incident_nets(u) {
                    if nets.contains(&e) && phg.pin_count(e, parts[u as usize]) == 1 {
                        b += hg.net_weight(e) as f32;
                    }
                }
                assert_eq!(b, benefit[i]);
                checked += 1;
            }
            println!("[L1/L2] AOT gain-tile oracle == Rust gains on {checked} nodes ✓");
        }
        None => println!("[L1/L2] artifacts missing — run `make artifacts` first (continuing)"),
    }

    // ---- real small workload: SPM + SAT + planted suite, k = 8 ----
    let instances = suites::suite_mhg();
    let k = 8;
    println!("\n[L3] partitioning {} instances with every configuration, k={k}\n", instances.len());
    println!("| solver | geo-mean km1 | worst imbalance | geo-mean time [s] |");
    println!("|---|---|---|---|");

    type Runner = Box<dyn Fn(&Arc<mtkahypar::hypergraph::Hypergraph>) -> (i64, f64)>;
    let mk_ctx = move |preset: Preset, spectral: bool| -> Context {
        let mut ctx = Context::new(preset, k, 0.03).with_seed(7).with_threads(4);
        ctx.contraction_limit_factor = 24;
        ctx.ip_min_repetitions = 2;
        ctx.ip_max_repetitions = 4;
        ctx.fm_max_rounds = 4;
        ctx.use_spectral_ip = spectral;
        ctx
    };
    let solvers: Vec<(&str, Runner)> = vec![
        ("Mt-KaHyPar-S", boxed(move |hg| run(hg, mk_ctx(Preset::Speed, false)))),
        ("Mt-KaHyPar-D", boxed(move |hg| run(hg, mk_ctx(Preset::Default, false)))),
        ("Mt-KaHyPar-D (+spectral IP)", boxed(move |hg| run(hg, mk_ctx(Preset::Default, true)))),
        ("Mt-KaHyPar-D-F", boxed(move |hg| run(hg, mk_ctx(Preset::DefaultFlows, false)))),
        ("Mt-KaHyPar-Q", boxed(move |hg| run(hg, mk_ctx(Preset::Quality, false)))),
        ("Mt-KaHyPar-Q-F", boxed(move |hg| run(hg, mk_ctx(Preset::QualityFlows, false)))),
        ("Mt-KaHyPar-SDet", boxed(move |hg| run(hg, mk_ctx(Preset::Deterministic, false)))),
        (
            "PaToH-like (baseline)",
            boxed(move |hg| run_with(hg, mk_ctx(Preset::Default, false), baselines::patoh_like)),
        ),
        (
            "Zoltan-like (baseline)",
            boxed(move |hg| run_with(hg, mk_ctx(Preset::Default, false), baselines::zoltan_like)),
        ),
        (
            "BiPart-like (baseline)",
            boxed(move |hg| run_with(hg, mk_ctx(Preset::Default, false), baselines::bipart_like)),
        ),
    ];

    for (name, runner) in &solvers {
        let mut km1s = Vec::new();
        let mut worst_imb = f64::MIN;
        let start = Instant::now();
        for inst in &instances {
            let (km1, imb) = runner(&inst.hg);
            km1s.push(km1 as f64 + 1.0);
            worst_imb = worst_imb.max(imb);
        }
        let secs = start.elapsed().as_secs_f64() / instances.len() as f64;
        println!(
            "| {name} | {:.0} | {worst_imb:.4} | {secs:.2} |",
            mtkahypar::util::stats::geometric_mean(&km1s)
        );
    }

    // ---- determinism witness ----
    let hg = &instances[0].hg;
    let p1 = partitioner::partition_arc(hg.clone(), &mk_ctx(Preset::Deterministic, false)).parts();
    let p2 = {
        let ctx = mk_ctx(Preset::Deterministic, false).with_threads(1);
        partitioner::partition_arc(hg.clone(), &ctx).parts()
    };
    println!("\n[det] SDet partitions bit-identical across thread counts: {}", p1 == p2);

    println!("\nend_to_end OK");
}

fn boxed(
    f: impl Fn(&Arc<mtkahypar::hypergraph::Hypergraph>) -> (i64, f64) + 'static,
) -> Box<dyn Fn(&Arc<mtkahypar::hypergraph::Hypergraph>) -> (i64, f64)> {
    Box::new(f)
}

fn run(hg: &Arc<mtkahypar::hypergraph::Hypergraph>, ctx: Context) -> (i64, f64) {
    let phg = partitioner::partition_arc(hg.clone(), &ctx);
    assert!(phg.is_balanced(), "balance violated: {}", phg.imbalance());
    let parts = phg.parts();
    assert_eq!(phg.km1(), metrics::km1(hg, &parts, ctx.k), "objective verified from scratch");
    (phg.km1(), phg.imbalance())
}

fn run_with(
    hg: &Arc<mtkahypar::hypergraph::Hypergraph>,
    ctx: Context,
    f: impl Fn(
        &Arc<mtkahypar::hypergraph::Hypergraph>,
        &Context,
    ) -> mtkahypar::partition::PartitionedHypergraph,
) -> (i64, f64) {
    let phg = f(hg, &ctx);
    (phg.km1(), phg.imbalance())
}
