//! End-to-end integration tests over the public API: every preset on
//! every instance archetype, balance guarantees, objective verification
//! from scratch, determinism, IO round trips, and the CLI-visible paths.

use mtkahypar::benchkit::baselines;
use mtkahypar::coordinator::context::{Context, Preset};
use mtkahypar::coordinator::partitioner;
use mtkahypar::generators::{self, PlantedParams, SatRepresentation};
use mtkahypar::graph::partitioner::partition_graph_arc;
use mtkahypar::hypergraph::Hypergraph;
use mtkahypar::metrics::{self, Objective};
use mtkahypar::{io, BlockId};
use std::sync::Arc;

/// Objective for the whole suite, selected by the CI matrix: the
/// `MTKH_TEST_OBJECTIVE` env var ("km1" | "cut" | "soed", default km1)
/// reruns every end-to-end test under that objective.
fn test_objective() -> Objective {
    match std::env::var("MTKH_TEST_OBJECTIVE").ok().as_deref() {
        Some("cut") => Objective::Cut,
        Some("soed") => Objective::Soed,
        _ => Objective::Km1,
    }
}

fn test_ctx(preset: Preset, k: usize, seed: u64) -> Context {
    let mut ctx = Context::new(preset, k, 0.03)
        .with_threads(2)
        .with_seed(seed)
        .with_objective(test_objective());
    ctx.contraction_limit_factor = 24;
    ctx.ip_min_repetitions = 2;
    ctx.ip_max_repetitions = 3;
    ctx.fm_max_rounds = 3;
    ctx
}

fn check(hg: &Hypergraph, preset: Preset, k: usize, seed: u64) -> i64 {
    let ctx = test_ctx(preset, k, seed);
    let obj = ctx.objective;
    let phg = partitioner::partition(hg, &ctx);
    assert!(phg.is_balanced(), "{preset:?} k={k}: imbalance {}", phg.imbalance());
    phg.verify_consistency().unwrap_or_else(|e| panic!("{preset:?}: {e}"));
    let parts = phg.parts();
    assert_eq!(phg.km1(), metrics::km1(hg, &parts, k), "{preset:?}: km1 verified");
    assert_eq!(
        phg.objective_value(obj),
        metrics::objective_hg(obj, hg, &parts, k),
        "{preset:?}: configured objective verified"
    );
    assert!(
        metrics::block_weights_hg(hg, &parts, k).iter().all(|&w| w > 0),
        "{preset:?}: no empty blocks"
    );
    phg.objective_value(obj)
}

#[test]
fn all_presets_on_all_archetypes() {
    let instances: Vec<(&str, Hypergraph)> = vec![
        (
            "planted",
            generators::planted_hypergraph(
                &PlantedParams { n: 350, m: 650, blocks: 4, ..Default::default() },
                1,
            ),
        ),
        ("spm", generators::spm_hypergraph(350, 350, 5, 2)),
        ("sat_dual", generators::sat_hypergraph(150, 550, SatRepresentation::Dual, 3)),
        ("vlsi", generators::vlsi_hypergraph(400, 600, 4)),
    ];
    for (name, hg) in &instances {
        for preset in Preset::all() {
            let val = check(hg, preset, 4, 5);
            println!("{name} {preset:?}: {} = {val}", test_objective().name());
        }
    }
}

#[test]
fn k_sweep_balance_always_holds() {
    let hg = generators::planted_hypergraph(
        &PlantedParams { n: 700, m: 1200, blocks: 8, ..Default::default() },
        9,
    );
    for k in [2, 3, 5, 8, 16] {
        check(&hg, Preset::Default, k, 11);
    }
}

#[test]
fn planted_partitions_recovered() {
    // near-perfectly separable instance: the planted cut must be found
    // (low km1 compared to the number of cross nets)
    let p = PlantedParams { n: 500, m: 1000, blocks: 4, p_intra: 0.97, ..Default::default() };
    let hg = generators::planted_hypergraph(&p, 21);
    let val = check(&hg, Preset::Default, 4, 3);
    // ~3% of 1000 nets cross blocks; each contributes ≥1 to km1/cut and
    // ≥2 to soed. allow 2× slack for imperfect recovery
    let bound = if test_objective() == Objective::Soed { 160 } else { 80 };
    assert!(val < bound, "planted structure should be recovered: {val}");
}

#[test]
fn deterministic_is_bit_identical_everywhere() {
    let hg = generators::spm_hypergraph(400, 400, 5, 13);
    let runs: Vec<(i64, Vec<BlockId>)> = [1usize, 2, 4]
        .iter()
        .map(|&t| {
            let mut ctx = test_ctx(Preset::Deterministic, 4, 17);
            ctx.threads = t;
            let phg = partitioner::partition(&hg, &ctx);
            (phg.km1(), phg.parts())
        })
        .collect();
    assert_eq!(runs[0], runs[1]);
    assert_eq!(runs[1], runs[2]);
    // and across repeated runs
    let again = partitioner::partition(&hg, &test_ctx(Preset::Deterministic, 4, 17)).parts();
    assert_eq!(runs[0].1, again);
}

#[test]
fn deterministic_nlevel_is_bit_identical_across_threads() {
    // the full Deterministic pipeline on the *n-level* driver: dynamic
    // deterministic coarsening, seeded det-FM batch refinement and the
    // deterministic finest-level stack — same seed, three thread counts,
    // bit-identical Π and km1 (the det-multilevel twin of the test above)
    let hg = generators::planted_hypergraph(
        &PlantedParams { n: 450, m: 800, blocks: 4, ..Default::default() },
        23,
    );
    let runs: Vec<(i64, Vec<BlockId>)> = [1usize, 2, 4]
        .iter()
        .map(|&t| {
            let mut ctx = test_ctx(Preset::Deterministic, 4, 23);
            ctx.threads = t;
            ctx.nlevel = true;
            ctx.nlevel_batch_size = 64;
            let phg = partitioner::partition(&hg, &ctx);
            assert!(phg.is_balanced(), "t={t}: imbalance {}", phg.imbalance());
            phg.verify_consistency().unwrap();
            (phg.km1(), phg.parts())
        })
        .collect();
    assert_eq!(runs[0], runs[1], "t=1 vs t=2");
    assert_eq!(runs[1], runs[2], "t=2 vs t=4");
}

#[test]
fn nondeterministic_seeds_vary_but_quality_stable() {
    let hg = generators::planted_hypergraph(
        &PlantedParams { n: 400, m: 700, blocks: 4, ..Default::default() },
        31,
    );
    let km1s: Vec<i64> =
        (0..3).map(|seed| check(&hg, Preset::Default, 4, seed)).collect();
    let max = *km1s.iter().max().unwrap() as f64;
    let min = *km1s.iter().min().unwrap() as f64;
    assert!(max <= 2.0 * min + 16.0, "seed variance too large: {km1s:?}");
}

#[test]
fn graph_pipeline_and_io_roundtrip() {
    let g = Arc::new(generators::mesh_graph(20, 20));
    let ctx = test_ctx(Preset::Default, 4, 7);
    let pg = partition_graph_arc(g.clone(), &ctx);
    assert!(pg.is_balanced());
    assert_eq!(pg.cut(), metrics::graph_cut(&g, &pg.parts()));

    // partition file round trip
    let dir = std::env::temp_dir().join("mtk_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let pfile = dir.join("mesh.part");
    io::write_partition(&pg.parts(), &pfile).unwrap();
    assert_eq!(io::read_partition(&pfile).unwrap(), pg.parts());
}

#[test]
fn graph_and_two_pin_hypergraph_view_agree() {
    // a partitioned graph and the same assignment on the graph's 2-pin
    // hypergraph view must be metrically indistinguishable: identical
    // km1/cut/soed, both balanced, and km1 == cut == the weight of the
    // cut edges (the two-pin collapse the graph fast path relies on)
    let g = Arc::new(generators::mesh_graph(18, 18));
    let ctx = test_ctx(Preset::Default, 3, 13);
    let pg = partition_graph_arc(g.clone(), &ctx);
    pg.verify_consistency().unwrap();
    let hg = Arc::new(g.to_hypergraph());
    let mut phg = mtkahypar::partition::PartitionedHypergraph::new(hg, 3);
    phg.set_uniform_max_weight(0.03);
    phg.assign_all(&pg.parts(), 2);
    phg.verify_consistency().unwrap();
    assert_eq!(pg.km1(), phg.km1(), "km1 agrees across representations");
    assert_eq!(pg.cut(), phg.cut(), "cut agrees across representations");
    assert_eq!(
        pg.objective_value(Objective::Soed),
        phg.objective_value(Objective::Soed),
        "soed agrees (and equals 2·cut on graphs)"
    );
    assert_eq!(pg.objective_value(Objective::Soed), 2 * pg.cut());
    assert!(pg.is_balanced() && phg.is_balanced());
    assert_eq!(pg.km1(), metrics::graph_cut(&g, &pg.parts()));
}

#[test]
fn hmetis_file_to_partition_pipeline() {
    // write an instance, read it back, partition it — the CLI data path
    let hg = generators::vlsi_hypergraph(300, 450, 3);
    let dir = std::env::temp_dir().join("mtk_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let f = dir.join("circuit.hgr");
    io::write_hmetis(&hg, &f).unwrap();
    let rd = Arc::new(io::read_hmetis(&f).unwrap());
    assert_eq!(rd.num_pins(), hg.num_pins());
    let phg = partitioner::partition_arc(rd, &test_ctx(Preset::Default, 2, 1));
    assert!(phg.is_balanced());
}

#[test]
fn baselines_quality_ordering() {
    // the paper's core claim, reproduced end-to-end: Mt-KaHyPar-D-F ≥ D ≥
    // Zoltan-like in quality (aggregate over seeds)
    let mut df = 0i64;
    let mut d = 0i64;
    let mut z = 0i64;
    let obj = test_objective();
    for seed in 0..3u64 {
        let hg = Arc::new(generators::planted_hypergraph(
            &PlantedParams { n: 450, m: 850, blocks: 4, p_intra: 0.88, ..Default::default() },
            seed,
        ));
        let ctx = test_ctx(Preset::Default, 4, seed);
        d += partitioner::partition_arc(hg.clone(), &ctx).objective_value(obj);
        let ctx_f = test_ctx(Preset::DefaultFlows, 4, seed);
        df += partitioner::partition_arc(hg.clone(), &ctx_f).objective_value(obj);
        z += baselines::zoltan_like(&hg, &ctx).objective_value(obj);
    }
    assert!(d <= z, "D ({d}) must beat the LP-only class ({z})");
    assert!(df <= d + 8, "flows must not lose quality: {df} vs {d}");
}

#[test]
fn cut_and_soed_run_end_to_end_through_all_drivers() {
    // the objective portfolio on every driver, independent of the CI env
    // matrix: multilevel, V-cycle, n-level and the baseline class must
    // all accept Objective::Cut / Objective::Soed and keep the
    // incremental objective value exact against the from-scratch metric
    let hg = Arc::new(generators::planted_hypergraph(
        &PlantedParams { n: 350, m: 600, blocks: 3, ..Default::default() },
        41,
    ));
    for obj in [Objective::Cut, Objective::Soed] {
        // multilevel driver
        let ctx = test_ctx(Preset::Default, 3, 7).with_objective(obj);
        let phg = partitioner::partition_arc(hg.clone(), &ctx);
        assert!(phg.is_balanced(), "{obj:?} multilevel: imbalance {}", phg.imbalance());
        assert_eq!(
            phg.objective_value(obj),
            metrics::objective_hg(obj, &hg, &phg.parts(), 3),
            "{obj:?} multilevel"
        );
        // V-cycle driver on top of the multilevel result
        let before = phg.objective_value(obj);
        let improved = mtkahypar::refinement::vcycle(phg, &ctx, 1);
        assert!(
            improved.objective_value(obj) <= before,
            "{obj:?} vcycle worsened: {} > {before}",
            improved.objective_value(obj)
        );
        assert!(improved.is_balanced(), "{obj:?} vcycle");
        improved.verify_consistency().unwrap_or_else(|e| panic!("{obj:?} vcycle: {e}"));
        // n-level driver
        let mut nctx = test_ctx(Preset::Default, 3, 7).with_objective(obj);
        nctx.nlevel = true;
        nctx.nlevel_batch_size = 64;
        let nphg = partitioner::partition_arc(hg.clone(), &nctx);
        assert!(nphg.is_balanced(), "{obj:?} n-level");
        assert_eq!(
            nphg.objective_value(obj),
            metrics::objective_hg(obj, &hg, &nphg.parts(), 3),
            "{obj:?} n-level"
        );
        // baseline driver class
        let b = baselines::zoltan_like(&hg, &ctx);
        assert_eq!(
            b.objective_value(obj),
            metrics::objective_hg(obj, &hg, &b.parts(), 3),
            "{obj:?} baseline"
        );
    }
}

#[test]
fn sparse_state_runs_every_driver_for_every_objective() {
    use mtkahypar::partition::KStateChoice;
    // The forced SparseKState end-to-end, mirroring
    // `cut_and_soed_run_end_to_end_through_all_drivers`: multilevel,
    // V-cycle, n-level and the baseline class under km1/cut/soed must
    // keep the incremental objective exact against the from-scratch
    // metric, stay balanced and verify. Quality must land in the dense
    // twin's band — bit-identical results are not guaranteed (the dense
    // scan enumerates blocks in ascending order, the sparse state in Λ
    // entry order with a total-order tie-break, so equal-gain moves may
    // resolve differently), but the values computed along the way are
    // the same, which the state/gain-table property tests pin exactly.
    let hg = Arc::new(generators::planted_hypergraph(
        &PlantedParams { n: 350, m: 600, blocks: 3, ..Default::default() },
        43,
    ));
    for obj in [Objective::Km1, Objective::Cut, Objective::Soed] {
        // multilevel driver, dense vs sparse
        let dctx = test_ctx(Preset::Default, 3, 7)
            .with_objective(obj)
            .with_kstate(KStateChoice::Dense);
        let sctx = test_ctx(Preset::Default, 3, 7)
            .with_objective(obj)
            .with_kstate(KStateChoice::Sparse);
        let dphg = partitioner::partition_arc(hg.clone(), &dctx);
        let sphg = partitioner::partition_arc(hg.clone(), &sctx);
        sphg.verify_consistency().unwrap_or_else(|e| panic!("{obj:?} sparse multilevel: {e}"));
        assert!(sphg.is_balanced(), "{obj:?} sparse multilevel");
        assert_eq!(
            sphg.objective_value(obj),
            metrics::objective_hg(obj, &hg, &sphg.parts(), 3),
            "{obj:?} sparse multilevel: incremental vs from-scratch"
        );
        let (dv, sv) = (dphg.objective_value(obj) as f64, sphg.objective_value(obj) as f64);
        assert!(
            sv <= dv * 1.5 + 8.0 && dv <= sv * 1.5 + 8.0,
            "{obj:?}: dense {dv} vs sparse {sv} quality diverged"
        );
        // V-cycle driver on top of the sparse result
        let before = sphg.objective_value(obj);
        let improved = mtkahypar::refinement::vcycle(sphg, &sctx, 1);
        assert!(
            improved.objective_value(obj) <= before,
            "{obj:?} sparse vcycle worsened: {} > {before}",
            improved.objective_value(obj)
        );
        improved.verify_consistency().unwrap_or_else(|e| panic!("{obj:?} sparse vcycle: {e}"));
        // n-level driver
        let mut nctx = test_ctx(Preset::Default, 3, 7)
            .with_objective(obj)
            .with_kstate(KStateChoice::Sparse);
        nctx.nlevel = true;
        nctx.nlevel_batch_size = 64;
        let nphg = partitioner::partition_arc(hg.clone(), &nctx);
        assert!(nphg.is_balanced(), "{obj:?} sparse n-level");
        nphg.verify_consistency().unwrap_or_else(|e| panic!("{obj:?} sparse n-level: {e}"));
        assert_eq!(
            nphg.objective_value(obj),
            metrics::objective_hg(obj, &hg, &nphg.parts(), 3),
            "{obj:?} sparse n-level: incremental vs from-scratch"
        );
        // baseline driver class
        let b = baselines::zoltan_like(&hg, &sctx);
        assert_eq!(
            b.objective_value(obj),
            metrics::objective_hg(obj, &hg, &b.parts(), 3),
            "{obj:?} sparse baseline"
        );
    }
}

#[test]
fn large_k_sparse_state_end_to_end() {
    // k = 128 sits above SPARSE_K_THRESHOLD, so `Auto` resolves to the
    // sparse state on its own — the regime the k-adaptive layer exists
    // for (the CI matrix additionally reruns the whole suite with
    // MTKH_KSTATE=sparse to force it at small k). ε is widened to 0.1:
    // at ~16 nodes per block the default 3 % leaves no integral slack.
    let hg = Arc::new(generators::planted_hypergraph(
        &PlantedParams { n: 2000, m: 3500, blocks: 16, ..Default::default() },
        51,
    ));
    let mut ctx = Context::new(Preset::Default, 128, 0.1)
        .with_threads(2)
        .with_seed(3)
        .with_objective(test_objective());
    ctx.contraction_limit_factor = 8;
    ctx.ip_min_repetitions = 2;
    ctx.ip_max_repetitions = 3;
    ctx.fm_max_rounds = 2;
    let obj = ctx.objective;
    let phg = partitioner::partition_arc(hg.clone(), &ctx);
    assert!(phg.is_balanced(), "k=128: imbalance {}", phg.imbalance());
    phg.verify_consistency().unwrap();
    assert_eq!(
        phg.objective_value(obj),
        metrics::objective_hg(obj, &hg, &phg.parts(), 128),
        "k=128: incremental vs from-scratch"
    );
    assert!(
        metrics::block_weights_hg(&hg, &phg.parts(), 128).iter().all(|&w| w > 0),
        "k=128: no empty blocks"
    );
}

#[test]
fn deterministic_sparse_state_is_bit_identical_across_threads() {
    use mtkahypar::partition::KStateChoice;
    // Satellite of the large-k layer: the Deterministic preset with the
    // sparse state forced on must stay bit-identical at 1/2/4 threads on
    // both the multilevel and the n-level driver. This exercises the
    // non-canonical Λ enumeration order under deterministic refinement —
    // every selection over it must go through the total-order tie-break.
    let hg = generators::spm_hypergraph(350, 350, 5, 29);
    let run = |t: usize, nlevel: bool| {
        let mut ctx =
            test_ctx(Preset::Deterministic, 4, 29).with_kstate(KStateChoice::Sparse);
        ctx.threads = t;
        ctx.nlevel = nlevel;
        ctx.nlevel_batch_size = 64;
        let phg = partitioner::partition(&hg, &ctx);
        assert!(phg.is_balanced(), "nlevel={nlevel} t={t}: imbalance {}", phg.imbalance());
        phg.verify_consistency().unwrap();
        (phg.km1(), phg.parts())
    };
    for nlevel in [false, true] {
        let r1 = run(1, nlevel);
        let r2 = run(2, nlevel);
        let r4 = run(4, nlevel);
        assert_eq!(r1, r2, "nlevel={nlevel}: t=1 vs t=2");
        assert_eq!(r2, r4, "nlevel={nlevel}: t=2 vs t=4");
    }
}

#[test]
fn runtime_oracle_agrees_when_artifacts_present() {
    let Some(rt) = mtkahypar::runtime::global() else {
        eprintln!("artifacts not built; skipping");
        return;
    };
    let hg = generators::planted_hypergraph(
        &PlantedParams { n: 120, m: 128, blocks: 3, ..Default::default() },
        5,
    );
    let parts: Vec<BlockId> = (0..hg.num_nodes()).map(|u| (u % 3) as BlockId).collect();
    let nodes: Vec<u32> = (0..hg.num_nodes() as u32).collect();
    let nets: Vec<u32> = hg.nets().take(128).collect();
    let (benefit, penalty) =
        mtkahypar::runtime::gain_tile_for(rt, &hg, &parts, &nodes, &nets, 3).unwrap();
    let phg = mtkahypar::partition::PartitionedHypergraph::new(Arc::new(hg.clone()), 3);
    phg.assign_all(&parts, 1);
    for (i, &u) in nodes.iter().enumerate() {
        let mut b = 0f32;
        let mut p = [0f32; 3];
        for &e in hg.incident_nets(u) {
            if !nets.contains(&e) {
                continue;
            }
            let w = hg.net_weight(e) as f32;
            if phg.pin_count(e, parts[u as usize]) == 1 {
                b += w;
            }
            for (t, pt) in p.iter_mut().enumerate() {
                if phg.pin_count(e, t as BlockId) == 0 {
                    *pt += w;
                }
            }
        }
        assert_eq!(benefit[i], b);
        for t in 0..3 {
            assert_eq!(penalty[i * mtkahypar::runtime::K + t], p[t]);
        }
    }
}
