//! Property-based tests (seeded mini-framework, DESIGN.md §6): random
//! instances → structural and algorithmic invariants. Each property runs
//! over many seeds; failures print the reproducing seed.

use mtkahypar::coordinator::context::{Context, Preset};
use mtkahypar::generators::{self, PlantedParams};
use mtkahypar::hypergraph::dynamic::DynamicHypergraph;
use mtkahypar::hypergraph::{contraction, Hypergraph, HypergraphOps};
use mtkahypar::metrics;
use mtkahypar::partition::{
    gain_recalculation::{recalculate_gains, replay_gains_reference},
    GainTable, Move, PartitionPool, PartitionedHypergraph,
};
use mtkahypar::util::Rng;
use mtkahypar::{BlockId, NodeId};
use std::sync::Arc;

const SEEDS: u64 = 24;

fn random_hypergraph(seed: u64) -> Hypergraph {
    let mut rng = Rng::new(seed ^ 0xfeed);
    let n = 20 + rng.next_below(120);
    let m = 20 + rng.next_below(200);
    let mut nets = Vec::new();
    for _ in 0..m {
        let sz = 2 + rng.next_below(6);
        let pins: Vec<NodeId> =
            rng.sample_indices(n, sz).into_iter().map(|x| x as NodeId).collect();
        if pins.len() >= 2 {
            nets.push(pins);
        }
    }
    let weights: Vec<i64> = (0..n).map(|_| 1 + rng.next_below(3) as i64).collect();
    let net_w: Vec<i64> = (0..nets.len()).map(|_| 1 + rng.next_below(4) as i64).collect();
    Hypergraph::from_nets(n, &nets, Some(weights), Some(net_w))
}

fn random_parts(rng: &mut Rng, n: usize, k: usize) -> Vec<BlockId> {
    (0..n).map(|_| rng.next_below(k) as BlockId).collect()
}

#[test]
fn prop_contraction_preserves_weight_and_shrinks() {
    for seed in 0..SEEDS {
        let hg = random_hypergraph(seed);
        let mut rng = Rng::new(seed);
        let n = hg.num_nodes();
        // random idempotent clustering
        let mut rep: Vec<NodeId> = (0..n as NodeId).collect();
        for u in 0..n {
            let target = rng.next_below(n);
            if rep[target] == target as NodeId {
                rep[u] = target as NodeId;
            }
        }
        // full path compression (assignments form acyclic chains)
        for u in 0..n {
            let mut r = u;
            while rep[r] as usize != r {
                r = rep[r] as usize;
            }
            rep[u] = r as NodeId;
        }
        let c = contraction::contract(&hg, &rep, 2);
        assert_eq!(c.coarse.total_weight(), hg.total_weight(), "seed {seed}");
        assert!(c.coarse.num_nodes() <= n, "seed {seed}");
        c.coarse.validate().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        // pins of the coarse hypergraph never exceed the original
        assert!(c.coarse.num_pins() <= hg.num_pins(), "seed {seed}");
    }
}

#[test]
fn prop_partition_structure_consistent_under_random_moves() {
    for seed in 0..SEEDS {
        let hg = Arc::new(random_hypergraph(seed));
        let mut rng = Rng::new(seed ^ 1);
        let k = 2 + rng.next_below(5);
        let phg = PartitionedHypergraph::new(hg.clone(), k);
        phg.assign_all(&random_parts(&mut rng, hg.num_nodes(), k), 1);
        let mut km1 = phg.km1();
        for _ in 0..100 {
            let u = rng.next_below(hg.num_nodes()) as NodeId;
            let t = rng.next_below(k) as BlockId;
            if t != phg.block_of(u) {
                let expected = phg.gain(u, t);
                let out = phg.move_unchecked(u, t, None);
                assert_eq!(out.attributed_gain, expected, "seed {seed}");
                km1 -= out.attributed_gain;
            }
        }
        assert_eq!(phg.km1(), km1, "seed {seed}");
        phg.verify_consistency().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
    }
}

#[test]
fn prop_gain_table_exact_after_quiescence() {
    for seed in 0..SEEDS {
        let hg = Arc::new(random_hypergraph(seed));
        let mut rng = Rng::new(seed ^ 2);
        let k = 2 + rng.next_below(4);
        let phg = PartitionedHypergraph::new(hg.clone(), k);
        phg.assign_all(&random_parts(&mut rng, hg.num_nodes(), k), 1);
        let gt = GainTable::new(hg.num_nodes(), k);
        gt.initialize(&phg, 1);
        // each node moved at most once (FM round discipline)
        let mut moved = vec![false; hg.num_nodes()];
        for u in rng.sample_indices(hg.num_nodes(), hg.num_nodes() / 3) {
            let t = rng.next_below(k) as BlockId;
            if t != phg.block_of(u as NodeId) {
                phg.move_unchecked(u as NodeId, t, Some(&gt));
                moved[u] = true;
            }
        }
        gt.verify_against(&phg, &|u| moved[u as usize])
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
    }
}

#[test]
fn prop_gain_recalculation_equals_sequential_replay() {
    for seed in 0..SEEDS {
        let hg = Arc::new(random_hypergraph(seed));
        let mut rng = Rng::new(seed ^ 3);
        let k = 2 + rng.next_below(4);
        let parts = random_parts(&mut rng, hg.num_nodes(), k);
        let mut moves = Vec::new();
        for u in rng.sample_indices(hg.num_nodes(), hg.num_nodes() / 2) {
            let from = parts[u];
            let to = ((from as usize + 1 + rng.next_below(k - 1)) % k) as BlockId;
            moves.push(Move { node: u as NodeId, from, to });
        }
        let pre = PartitionedHypergraph::new(hg.clone(), k);
        pre.assign_all(&parts, 1);
        let expected = replay_gains_reference(&pre, &moves);
        let got = recalculate_gains(&pre, &moves, 2);
        assert_eq!(got, expected, "seed {seed}");
    }
}

#[test]
fn prop_refinement_never_worsens_or_unbalances() {
    for seed in 0..SEEDS / 2 {
        let p = PlantedParams { n: 200, m: 380, blocks: 3, ..Default::default() };
        let hg = Arc::new(generators::planted_hypergraph(&p, seed));
        let mut rng = Rng::new(seed ^ 4);
        let k = 3;
        let n = hg.num_nodes();
        let mut parts: Vec<BlockId> = (0..n).map(|u| (u * k / n) as BlockId).collect();
        for _ in 0..n / 8 {
            parts[rng.next_below(n)] = rng.next_below(k) as BlockId;
        }
        let mut phg = PartitionedHypergraph::new(hg.clone(), k);
        phg.set_uniform_max_weight(0.3);
        phg.assign_all(&parts, 1);
        let before = phg.km1();
        let mut ctx = Context::new(Preset::DefaultFlows, k, 0.3).with_threads(2).with_seed(seed);
        ctx.fm_max_rounds = 3;
        mtkahypar::refinement::lp::lp_refine(&phg, &ctx);
        mtkahypar::refinement::fm::fm_refine(&phg, &ctx);
        mtkahypar::refinement::flow::flow_refine(&phg, &ctx);
        assert!(phg.km1() <= before, "seed {seed}: {} > {before}", phg.km1());
        assert!(phg.is_balanced(), "seed {seed}");
        phg.verify_consistency().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
    }
}

#[test]
fn prop_flows_respect_non_uniform_weight_limits() {
    // explicit per-block limits on weighted nodes (the set_max_weights
    // path): flow refinement derives its region bounds from the actual
    // limits and must hand back a partition satisfying every one of them
    for seed in 0..SEEDS / 2 {
        let hg = Arc::new(random_hypergraph(seed ^ 0x0f10));
        let n = hg.num_nodes();
        let mut rng = Rng::new(seed ^ 21);
        let k = 2 + rng.next_below(3);
        let parts = random_parts(&mut rng, n, k);
        // non-uniform limits: each block's current weight plus a distinct
        // slack, so the start is feasible and the limits all differ
        let mut limits = vec![0i64; k];
        for (u, &b) in parts.iter().enumerate() {
            limits[b as usize] += hg.node_weight(u as NodeId);
        }
        for (b, l) in limits.iter_mut().enumerate() {
            *l += 1 + (3 * b as i64 + seed as i64) % 7;
        }
        let mut phg = PartitionedHypergraph::new(hg.clone(), k);
        phg.set_max_weights(limits.clone());
        phg.assign_all(&parts, 1);
        assert!(phg.is_balanced(), "seed {seed}: start must be feasible");
        let before = phg.km1();
        let ctx = Context::new(Preset::DefaultFlows, k, 0.1).with_threads(2).with_seed(seed);
        let g = mtkahypar::refinement::flow::flow_refine(&phg, &ctx);
        assert!(g >= 0, "seed {seed}");
        assert_eq!(phg.km1(), before - g, "seed {seed}: attributed accounting");
        for b in 0..k as BlockId {
            assert!(
                phg.block_weight(b) <= limits[b as usize],
                "seed {seed}: block {b} exceeds its explicit limit"
            );
        }
        phg.verify_consistency().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
    }
}

#[test]
fn prop_maxflow_equals_mincut_random_dags() {
    use mtkahypar::refinement::flow::maxflow::FlowNetwork;
    for seed in 0..SEEDS {
        let mut rng = Rng::new(seed ^ 5);
        let n = 6 + rng.next_below(20);
        let mut net = FlowNetwork::new(n);
        for u in 0..n {
            for v in 0..n {
                if u != v && rng.coin(0.25) {
                    net.add_edge(u as u32, v as u32, 1 + rng.next_below(9) as i64);
                }
            }
        }
        let mut source = vec![false; n];
        let mut sink = vec![false; n];
        source[0] = true;
        sink[n - 1] = true;
        let f = net.max_preflow(&source, &sink);
        // weight of the source-side cut must equal the flow value
        let side = net.source_side(&source, &sink);
        if side[n - 1] {
            // sink reachable => infeasible cut; flow must have hit
            // capacity of NO cut — this cannot happen for max preflow
            panic!("seed {seed}: sink on source side");
        }
        let mut cut = 0i64;
        for u in 0..n {
            if side[u] {
                for e in &net.edges[u] {
                    if !side[e.to as usize] && e.cap > 0 {
                        cut += e.cap;
                    }
                }
            }
        }
        assert_eq!(cut, f, "seed {seed}: max-flow min-cut duality");
    }
}

#[test]
fn prop_projection_preserves_objective() {
    // projecting a coarse partition to the finer level never changes km1
    for seed in 0..SEEDS / 2 {
        let hg = Arc::new(random_hypergraph(seed));
        let mut rng = Rng::new(seed ^ 6);
        let n = hg.num_nodes();
        let mut rep: Vec<NodeId> = (0..n as NodeId).collect();
        for u in 0..n {
            let t = rng.next_below(n);
            if rep[t] == t as NodeId {
                rep[u] = t as NodeId;
            }
        }
        for u in 0..n {
            let mut r = u;
            while rep[r] as usize != r {
                r = rep[r] as usize;
            }
            rep[u] = r as NodeId;
        }
        let c = contraction::contract(&hg, &rep, 1);
        let k = 3;
        let coarse_parts: Vec<BlockId> =
            (0..c.coarse.num_nodes()).map(|u| (u % k) as BlockId).collect();
        let fine_parts: Vec<BlockId> =
            (0..n).map(|u| coarse_parts[c.fine_to_coarse[u] as usize]).collect();
        assert_eq!(
            metrics::km1(&c.coarse, &coarse_parts, k),
            metrics::km1(&hg, &fine_parts, k),
            "seed {seed}: projection must preserve the objective"
        );
    }
}

#[test]
fn prop_deterministic_coarsening_thread_invariant() {
    for seed in 0..SEEDS / 3 {
        let hg = random_hypergraph(seed);
        let mk = |threads| {
            let mut ctx =
                Context::new(Preset::Deterministic, 2, 0.03).with_threads(threads).with_seed(seed);
            ctx.det_sub_rounds = 8;
            mtkahypar::coarsening::deterministic::cluster(
                &hg,
                &ctx,
                None,
                hg.total_weight() / 4,
                4,
            )
        };
        assert_eq!(mk(1), mk(4), "seed {seed}");
    }
}

#[test]
fn prop_pooled_rebind_matches_fresh_construction_on_real_hierarchies() {
    // After every in-place rebind of the pooled partition state, pin
    // counts, connectivity sets and block weights must be identical to a
    // freshly constructed PartitionedHypergraph on the projected
    // assignment, and verify_consistency must hold.
    for seed in 0..SEEDS / 2 {
        let hg = Arc::new(random_hypergraph(seed));
        let k = 2 + (seed % 3) as usize;
        let mut ctx = Context::new(Preset::Default, k, 0.5).with_threads(2).with_seed(seed);
        ctx.contraction_limit_factor = 4;
        let hierarchy = mtkahypar::coarsening::coarsen(hg.clone(), &ctx, None);
        let mut rng = Rng::new(seed ^ 7);
        let coarsest = hierarchy.coarsest();
        let mut parts = random_parts(&mut rng, coarsest.num_nodes(), k);

        let mut pool = PartitionPool::new(k);
        pool.reserve(&*hg);
        let mut phg = pool.bind(coarsest, &parts, 0.5, 2);
        phg.verify_consistency().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        for i in (0..hierarchy.levels.len()).rev() {
            let finer = if i == 0 {
                hg.clone()
            } else {
                hierarchy.levels[i - 1].coarse.clone()
            };
            phg = pool.rebind_level(
                phg,
                finer.clone(),
                &hierarchy.levels[i].fine_to_coarse,
                Some(&hierarchy.levels[i].net_map),
                0.5,
                2,
            );
            phg.verify_consistency().unwrap_or_else(|e| panic!("seed {seed} level {i}: {e}"));
            // reference: legacy constructor on the separately projected parts
            parts = mtkahypar::coarsening::project_partition(&hierarchy.levels[i], &parts);
            let mut fresh = PartitionedHypergraph::new(finer.clone(), k);
            fresh.set_uniform_max_weight(0.5);
            fresh.assign_all(&parts, 1);
            assert_eq!(phg.parts(), fresh.parts(), "seed {seed} level {i}: assignment");
            for b in 0..k as BlockId {
                assert_eq!(
                    phg.block_weight(b),
                    fresh.block_weight(b),
                    "seed {seed} level {i}: block weight {b}"
                );
            }
            for e in finer.nets() {
                assert_eq!(
                    phg.connectivity(e),
                    fresh.connectivity(e),
                    "seed {seed} level {i}: connectivity of net {e}"
                );
                for b in 0..k as BlockId {
                    assert_eq!(
                        phg.pin_count(e, b),
                        fresh.pin_count(e, b),
                        "seed {seed} level {i}: pin count ({e},{b})"
                    );
                }
            }
        }
        assert_eq!(
            pool.structural_allocs(),
            1,
            "seed {seed}: a reserved pool allocates exactly once"
        );
    }
}

#[test]
fn prop_pooled_uncoarsening_performs_zero_per_level_allocations() {
    // Drive the real pipeline API across a multi-level hierarchy and
    // assert the alloc counters: one structural partition allocation and
    // one gain-table allocation for the entire sequence (mirror of the
    // gain-table reuse test, extended to the §6.1 state).
    use mtkahypar::refinement::RefinementPipeline;
    let p = PlantedParams { n: 400, m: 700, blocks: 2, ..Default::default() };
    let hg = Arc::new(generators::planted_hypergraph(&p, 3));
    let mut ctx = Context::new(Preset::Default, 2, 0.3).with_threads(2).with_seed(3);
    ctx.contraction_limit_factor = 24;
    ctx.fm_max_rounds = 2;
    let hierarchy = mtkahypar::coarsening::coarsen(hg.clone(), &ctx, None);
    assert!(!hierarchy.levels.is_empty(), "instance must coarsen");
    let coarsest = hierarchy.coarsest();
    let parts: Vec<BlockId> =
        (0..coarsest.num_nodes()).map(|u| (u % 2) as BlockId).collect();
    let mut pipeline = RefinementPipeline::new_for(&ctx, &hg);
    let phg = pipeline.bind(coarsest, &parts, &ctx);
    pipeline.refine(&phg, &ctx);
    let phg = pipeline.uncoarsen(&hierarchy.levels, &hg, phg, &ctx);
    phg.verify_consistency().unwrap();
    assert!(phg.is_balanced(), "imbalance {}", phg.imbalance());
    assert_eq!(
        pipeline.partition_pool().structural_allocs(),
        1,
        "uncoarsening must not allocate partition storage per level"
    );
    assert_eq!(pipeline.partition_pool().rebinds(), hierarchy.levels.len());
    assert_eq!(
        pipeline.partition_pool().value_rebuilds(),
        1,
        "only the initial bind may rebuild Φ/Λ from scratch — every \
         uncoarsening level must take the net_map delta-repair path"
    );
    assert_eq!(
        pipeline.partition_pool().delta_repairs(),
        hierarchy.levels.len(),
        "every projection must be a counted per-net delta repair"
    );
    assert_eq!(pipeline.workspace().gain_table_allocs(), 1);
}

#[test]
fn prop_refiner_gains_equal_objective_delta_for_every_objective() {
    // Objective-portfolio contract: for each configured objective the
    // attributed gain every refiner returns must equal the from-scratch
    // metric delta — no refiner may improve km1 while claiming cut.
    use mtkahypar::metrics::Objective;
    for obj in [Objective::Km1, Objective::Cut, Objective::Soed] {
        for seed in 0..SEEDS / 3 {
            let hg = Arc::new(random_hypergraph(seed ^ 0x0b1e));
            let mut rng = Rng::new(seed ^ 8);
            let k = 2 + rng.next_below(4);
            let mut phg = PartitionedHypergraph::new(hg.clone(), k);
            phg.set_uniform_max_weight(0.5);
            phg.assign_all(&random_parts(&mut rng, hg.num_nodes(), k), 1);
            let mut ctx = Context::new(Preset::DefaultFlows, k, 0.5)
                .with_threads(2)
                .with_seed(seed)
                .with_objective(obj);
            ctx.fm_max_rounds = 2;

            let before = phg.objective_value(obj);
            let g = mtkahypar::refinement::lp::lp_refine(&phg, &ctx);
            assert_eq!(phg.objective_value(obj), before - g, "{obj:?} seed {seed}: LP");

            let before = phg.objective_value(obj);
            let stats = mtkahypar::refinement::fm::fm_refine(&phg, &ctx);
            assert_eq!(
                phg.objective_value(obj),
                before - stats.improvement,
                "{obj:?} seed {seed}: FM"
            );

            let before = phg.objective_value(obj);
            let g = mtkahypar::refinement::flow::flow_refine(&phg, &ctx);
            assert_eq!(phg.objective_value(obj), before - g, "{obj:?} seed {seed}: flows");

            // the incremental value agrees with the metrics module
            assert_eq!(
                phg.objective_value(obj),
                metrics::objective_hg(obj, &hg, &phg.parts(), k),
                "{obj:?} seed {seed}: incremental vs from-scratch"
            );
            phg.verify_consistency().unwrap_or_else(|e| panic!("{obj:?} seed {seed}: {e}"));
        }
    }
}

#[test]
fn prop_deterministic_refiners_account_exactly_for_every_objective() {
    use mtkahypar::metrics::Objective;
    for obj in [Objective::Km1, Objective::Cut, Objective::Soed] {
        for seed in 0..SEEDS / 3 {
            let hg = Arc::new(random_hypergraph(seed ^ 0xde7));
            let mut rng = Rng::new(seed ^ 9);
            let k = 2 + rng.next_below(3);
            let mut phg = PartitionedHypergraph::new(hg.clone(), k);
            phg.set_uniform_max_weight(0.5);
            phg.assign_all(&random_parts(&mut rng, hg.num_nodes(), k), 1);
            let mut ctx = Context::new(Preset::Deterministic, k, 0.5)
                .with_threads(2)
                .with_seed(seed)
                .with_objective(obj);
            ctx.fm_max_rounds = 2;

            let before = phg.objective_value(obj);
            let g = mtkahypar::refinement::lp::lp_refine_deterministic(&phg, &ctx);
            assert_eq!(phg.objective_value(obj), before - g, "{obj:?} seed {seed}: det-LP");

            let before = phg.objective_value(obj);
            let stats = mtkahypar::refinement::fm::deterministic::fm_refine_deterministic(
                &phg, &ctx,
            );
            assert_eq!(
                phg.objective_value(obj),
                before - stats.improvement,
                "{obj:?} seed {seed}: det-FM"
            );
            phg.verify_consistency().unwrap_or_else(|e| panic!("{obj:?} seed {seed}: {e}"));
        }
    }
}

#[test]
fn prop_deterministic_vcycle_thread_invariant() {
    // Deterministic preset end-to-end including V-cycles: partition +
    // vcycle must produce bit-identical assignments at 1, 2 and 4
    // threads (PR-5 leftover; §11 determinism guarantee).
    for seed in 0..SEEDS / 6 {
        let p = PlantedParams { n: 300, m: 550, blocks: 3, ..Default::default() };
        let hg = Arc::new(generators::planted_hypergraph(&p, seed));
        let run = |threads: usize| {
            let mut ctx = Context::new(Preset::Deterministic, 3, 0.1)
                .with_threads(threads)
                .with_seed(seed);
            ctx.contraction_limit_factor = 24;
            ctx.ip_min_repetitions = 1;
            ctx.ip_max_repetitions = 2;
            ctx.fm_max_rounds = 2;
            let phg =
                mtkahypar::coordinator::partitioner::partition_arc(hg.clone(), &ctx);
            let improved = mtkahypar::refinement::vcycle(phg, &ctx, 2);
            (improved.km1(), improved.parts())
        };
        let r1 = run(1);
        assert_eq!(r1, run(2), "seed {seed}: 1 vs 2 threads");
        assert_eq!(r1, run(4), "seed {seed}: 1 vs 4 threads");
    }
}

#[test]
fn prop_dynamic_uncontractions_match_snapshots() {
    // Dynamic-vs-snapshot equivalence (paper §9): after every
    // uncontract_batch, the dynamic structure's pins / incident nets /
    // node weights — and the incrementally repaired Π/Φ/Λ/km1 — must be
    // identical to a freshly contracted static snapshot at the same
    // prefix of the contraction sequence.
    use std::collections::HashMap;
    for seed in 0..SEEDS / 3 {
        let hg = Arc::new(random_hypergraph(seed ^ 0xd15c));
        let n = hg.num_nodes();
        let mut rng = Rng::new(seed ^ 0x44);
        let k = 2 + (seed % 3) as usize;

        // random single-contraction sequence down to ~n/4 active nodes
        let mut dynhg = DynamicHypergraph::from_hypergraph(&hg);
        let mut mementos = Vec::new();
        while dynhg.num_active_nodes() > (n / 4).max(2) {
            let actives: Vec<NodeId> = dynhg.active_nodes().collect();
            let v = actives[rng.next_below(actives.len())];
            let u = actives[rng.next_below(actives.len())];
            if u != v {
                mementos.push(dynhg.contract(v, u));
            }
        }
        dynhg.validate().unwrap_or_else(|e| panic!("seed {seed}: {e}"));

        // pooled partition over the dynamic coarsest state
        let coarse_parts: Vec<BlockId> =
            (0..n).map(|_| rng.next_below(k) as BlockId).collect();
        let mut pool = PartitionPool::new(k);
        pool.reserve(&*hg);
        let mut dyn_arc = Arc::new(dynhg);
        let mut phg = pool.bind(dyn_arc.clone(), &coarse_parts, 0.5, 2);

        let mut applied = mementos.len();
        while applied > 0 {
            let start = applied.saturating_sub(1 + rng.next_below(8));
            let batch = &mementos[start..applied];
            applied = start;

            // the n-level batch boundary: park → in-place revert →
            // unpark (values preserved) → incremental Π/Φ repair
            pool.park(phg);
            Arc::get_mut(&mut dyn_arc)
                .expect("sole owner between batches")
                .uncontract_batch(batch);
            phg = pool.unpark(dyn_arc.clone(), 0.5);
            phg.apply_uncontractions(batch);

            // interleave a little "refinement": random moves of active
            // nodes, so Π(v) ← Π(u) inherits refined blocks
            let actives: Vec<NodeId> = dyn_arc.active_nodes().collect();
            for _ in 0..4 {
                let u = actives[rng.next_below(actives.len())];
                let t = rng.next_below(k) as BlockId;
                if t != phg.block_of(u) {
                    phg.move_unchecked(u, t, None);
                }
            }

            dyn_arc.validate().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            // Φ/Λ/weights consistent with Π over the *dynamic* structure
            phg.verify_consistency().unwrap_or_else(|e| panic!("seed {seed}: {e}"));

            // ---- static snapshot at the same prefix ----
            let mut rep: Vec<NodeId> = (0..n as NodeId).collect();
            for m in &mementos[..applied] {
                rep[m.v as usize] = m.u;
            }
            for u in 0..n {
                let mut r = rep[u] as usize;
                while rep[r] as usize != r {
                    r = rep[r] as usize;
                }
                rep[u] = r as NodeId;
            }
            let c = contraction::contract(&hg, &rep, 2);

            // node identity & weights: every active slot is a root whose
            // cluster weight matches the snapshot's coarse node
            let mut active_count = 0usize;
            for u in dyn_arc.active_nodes() {
                active_count += 1;
                assert_eq!(rep[u as usize], u, "seed {seed}: active slots are roots");
                assert_eq!(
                    HypergraphOps::node_weight(&*dyn_arc, u),
                    c.coarse.node_weight(c.fine_to_coarse[u as usize]),
                    "seed {seed}: weight of root {u}"
                );
            }
            assert_eq!(active_count, c.coarse.num_nodes(), "seed {seed}");

            // pin-list equivalence: weighted multiset of (mapped, sorted)
            // pin sets. The snapshot merges identical nets and drops
            // single-pin nets; aggregating dynamic net weights by pin set
            // must therefore coincide exactly.
            let mut dyn_nets: HashMap<Vec<NodeId>, i64> = HashMap::new();
            for e in HypergraphOps::nets(&*dyn_arc) {
                let pins = HypergraphOps::pins(&*dyn_arc, e);
                if pins.len() < 2 {
                    continue;
                }
                let mut key: Vec<NodeId> =
                    pins.iter().map(|&p| c.fine_to_coarse[p as usize]).collect();
                key.sort_unstable();
                *dyn_nets.entry(key).or_insert(0) += HypergraphOps::net_weight(&*dyn_arc, e);
            }
            let mut snap_nets: HashMap<Vec<NodeId>, i64> = HashMap::new();
            for e in c.coarse.nets() {
                let mut key: Vec<NodeId> = c.coarse.pins(e).to_vec();
                key.sort_unstable();
                *snap_nets.entry(key).or_insert(0) += c.coarse.net_weight(e);
            }
            assert_eq!(dyn_nets, snap_nets, "seed {seed}: pin-list multisets differ");

            // partition equivalence: projecting Π onto the snapshot and
            // rebuilding from scratch must reproduce km1 and block weights
            let mut snap_parts: Vec<BlockId> = vec![0; c.coarse.num_nodes()];
            for u in dyn_arc.active_nodes() {
                snap_parts[c.fine_to_coarse[u as usize] as usize] = phg.block_of(u);
            }
            let mut fresh =
                PartitionedHypergraph::new(Arc::new(c.coarse), k);
            fresh.set_uniform_max_weight(0.5);
            fresh.assign_all(&snap_parts, 1);
            assert_eq!(phg.km1(), fresh.km1(), "seed {seed}: km1 after repair");
            for b in 0..k as BlockId {
                assert_eq!(
                    phg.block_weight(b),
                    fresh.block_weight(b),
                    "seed {seed}: block weight {b}"
                );
            }
        }
        assert_eq!(pool.structural_allocs(), 1, "seed {seed}");
        assert_eq!(pool.value_rebuilds(), 1, "seed {seed}: only the bind rebuilds");
    }
}
