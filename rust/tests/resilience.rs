//! Resilience tests: malformed-instance corpus, configuration
//! validation, deadline/degradation behavior of every driver, and (with
//! `--features failpoints`) panic-injection recovery at each failpoint
//! site.

use mtkahypar::coordinator::context::{Context, Preset};
use mtkahypar::coordinator::partitioner;
use mtkahypar::generators::{planted_hypergraph, PlantedParams};
use mtkahypar::io;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn small_ctx(preset: Preset, k: usize, threads: usize, seed: u64) -> Context {
    let mut c = Context::new(preset, k, 0.03).with_threads(threads).with_seed(seed);
    c.contraction_limit_factor = 24;
    c.ip_min_repetitions = 1;
    c.ip_max_repetitions = 2;
    c.fm_max_rounds = 2;
    c.nlevel_batch_size = 64;
    c
}

fn small_instance(seed: u64) -> Arc<mtkahypar::hypergraph::Hypergraph> {
    Arc::new(planted_hypergraph(
        &PlantedParams { n: 400, m: 700, blocks: 4, p_intra: 0.85, ..Default::default() },
        seed,
    ))
}

// ---------------------------------------------------------------------
// Malformed-instance corpus: every case must return Err, never panic.
// ---------------------------------------------------------------------

fn corpus_file(name: &str, contents: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("mtkahypar_resilience_test");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join(name);
    std::fs::write(&p, contents).unwrap();
    p
}

#[test]
fn hmetis_rejects_malformed_instances() {
    let cases: &[(&str, &str)] = &[
        // a pin id of 0 used to wrap the 1-based conversion on u64
        ("zero_pin.hgr", "2 4\n1 2\n0 3\n"),
        ("oob_pin.hgr", "2 4\n1 2\n3 5\n"),
        ("oob_pin_large.hgr", "1 4\n1 999999999\n"),
        ("truncated_nets.hgr", "3 4\n1 2\n2 3\n"),
        ("empty_net.hgr", "2 4 1\n3 1 2\n7\n"),
        ("zero_net_weight.hgr", "2 4 1\n0 1 2\n1 3 4\n"),
        ("negative_net_weight.hgr", "2 4 1\n-2 1 2\n1 3 4\n"),
        ("zero_node_weight.hgr", "1 2 10\n1 2\n1\n0\n"),
        ("negative_node_weight.hgr", "1 2 10\n1 2\n1\n-5\n"),
        ("truncated_node_weights.hgr", "1 2 10\n1 2\n1\n"),
        ("bad_fmt.hgr", "1 2 7\n1 2\n"),
        ("junk_tokens.hgr", "2 4\n1 banana\n3 4\n"),
        ("junk_header.hgr", "two four\n1 2\n"),
        ("short_header.hgr", "3\n1 2\n"),
        ("zero_nodes.hgr", "1 0\n1\n"),
        ("trailing_data.hgr", "1 4\n1 2\n3 4\n"),
        ("empty.hgr", ""),
        ("comments_only.hgr", "% nothing here\n% still nothing\n"),
    ];
    for (name, contents) in cases {
        let p = corpus_file(name, contents);
        let r = io::read_hmetis(&p);
        assert!(r.is_err(), "{name} must be rejected, got {:?}", r.map(|h| h.num_nodes()));
    }
}

#[test]
fn metis_rejects_malformed_instances() {
    let cases: &[(&str, &str)] = &[
        ("zero_neighbor.graph", "2 1\n0\n1\n"),
        ("oob_neighbor.graph", "2 1\n2\n3\n"),
        ("truncated.graph", "3 2\n2\n1\n"),
        ("bad_fmt.graph", "2 1 5\n2\n1\n"),
        ("junk.graph", "2 1\nx\n1\n"),
        ("zero_node_weight.graph", "2 1 10\n0 2\n1 1\n"),
        ("zero_edge_weight.graph", "2 1 1\n2 0\n1 0\n"),
        ("short_header.graph", "2\n"),
        ("empty.graph", ""),
    ];
    for (name, contents) in cases {
        let p = corpus_file(name, contents);
        let r = io::read_metis(&p);
        assert!(r.is_err(), "{name} must be rejected, got {:?}", r.map(|g| g.num_nodes()));
    }
}

#[test]
fn hmetis_still_accepts_wellformed_instances() {
    // the hardening must not reject valid files
    let p = corpus_file("ok.hgr", "% comment\n3 4 11\n2 1 2\n1 2 3\n3 3 4 1\n1\n2\n1\n1\n");
    let hg = io::read_hmetis(&p).unwrap();
    assert_eq!(hg.num_nodes(), 4);
    assert_eq!(hg.num_nets(), 3);
    assert_eq!(hg.net_weight(0), 2);
    assert_eq!(hg.node_weight(1), 2);
    hg.validate().unwrap();
}

// ---------------------------------------------------------------------
// Configuration validation
// ---------------------------------------------------------------------

#[test]
fn context_validation_rejects_bad_configs() {
    assert!(Context::try_new(Preset::Default, 1, 0.03).is_err(), "k=1");
    assert!(Context::try_new(Preset::Default, 0, 0.03).is_err(), "k=0");
    assert!(Context::try_new(Preset::Default, 4, -0.1).is_err(), "negative epsilon");
    assert!(Context::try_new(Preset::Default, 4, f64::NAN).is_err(), "NaN epsilon");
    assert!(Context::try_new(Preset::Default, 4, 0.03).is_ok());

    let ctx = Context::new(Preset::Default, 64, 0.03);
    assert!(ctx.validate_for_instance(32).is_err(), "k > n");
    assert!(ctx.validate_for_instance(64).is_ok());

    let mut z = Context::new(Preset::Default, 4, 0.03);
    z.time_limit = Some(Duration::ZERO);
    assert!(z.validate().is_err(), "zero time limit");
    let ok = Context::new(Preset::Default, 4, 0.03).with_time_limit(Duration::from_secs(1));
    assert!(ok.validate().is_ok());
}

#[test]
fn try_partition_arc_rejects_oversized_k() {
    let hg = small_instance(1);
    let ctx = small_ctx(Preset::Default, hg.num_nodes() + 1, 1, 1);
    assert!(partitioner::try_partition_arc(hg.clone(), &ctx).is_err());
    let ctx = small_ctx(Preset::Default, 4, 1, 1);
    let phg = partitioner::try_partition_arc(hg, &ctx).unwrap();
    assert!(phg.is_balanced());
}

// ---------------------------------------------------------------------
// Deadlines: every driver must return a balanced, consistent partition
// even with an already-expired budget.
// ---------------------------------------------------------------------

/// An expired-on-arrival budget (set directly: `validate()` rejects a
/// zero limit from user configuration, but the runtime must survive it).
fn expired_ctx(preset: Preset, k: usize, threads: usize, seed: u64) -> Context {
    let mut c = small_ctx(preset, k, threads, seed);
    c.time_limit = Some(Duration::ZERO);
    c
}

#[test]
fn multilevel_meets_expired_deadline() {
    let hg = small_instance(3);
    for preset in [Preset::Default, Preset::DefaultFlows, Preset::Speed, Preset::Deterministic] {
        let ctx = expired_ctx(preset, 4, 2, 3);
        let (phg, report) = partitioner::partition_arc_with_report(hg.clone(), &ctx);
        assert!(phg.is_balanced(), "{preset:?}: imbalance {}", phg.imbalance());
        phg.validate().unwrap();
        assert!(report.expired, "{preset:?}: zero budget must read as expired");
        assert!(report.degraded(), "{preset:?}: zero budget must degrade");
    }
}

#[test]
fn nlevel_meets_expired_deadline() {
    let hg = small_instance(5);
    for preset in [Preset::Quality, Preset::QualityFlows] {
        let ctx = expired_ctx(preset, 4, 2, 5);
        let phg = partitioner::partition_arc(hg.clone(), &ctx);
        assert!(phg.is_balanced(), "{preset:?}: imbalance {}", phg.imbalance());
        phg.validate().unwrap();
    }
}

#[test]
fn nlevel_tight_but_nonzero_deadline_still_balanced() {
    // a budget that expires mid-run (not on arrival) exercises the
    // degradation ladder rather than the floor
    let hg = small_instance(7);
    let mut ctx = small_ctx(Preset::Quality, 4, 2, 7);
    ctx.time_limit = Some(Duration::from_millis(5));
    let phg = partitioner::partition_arc(hg, &ctx);
    assert!(phg.is_balanced(), "imbalance {}", phg.imbalance());
    phg.validate().unwrap();
}

#[test]
fn vcycle_meets_expired_deadline() {
    let hg = small_instance(9);
    let ctx = small_ctx(Preset::Default, 4, 2, 9);
    let phg = partitioner::partition_arc(hg, &ctx);
    let before = phg.parts();
    let mut vctx = small_ctx(Preset::Default, 4, 2, 9);
    vctx.time_limit = Some(Duration::ZERO);
    let improved = mtkahypar::refinement::vcycle(phg, &vctx, 3);
    assert!(improved.is_balanced());
    improved.validate().unwrap();
    // an expired-on-arrival budget means zero cycles ran: the input
    // partition comes back untouched
    assert_eq!(improved.parts(), before);
}

#[test]
fn baselines_meet_expired_deadline() {
    let hg = small_instance(11);
    for (name, phg) in [
        ("patoh", mtkahypar::benchkit::baselines::patoh_like(&hg, &expired_ctx(Preset::Default, 4, 1, 11))),
        ("zoltan", mtkahypar::benchkit::baselines::zoltan_like(&hg, &expired_ctx(Preset::Default, 4, 2, 11))),
        ("bipart", mtkahypar::benchkit::baselines::bipart_like(&hg, &expired_ctx(Preset::Default, 4, 2, 11))),
    ] {
        assert!(phg.is_balanced(), "{name}: imbalance {}", phg.imbalance());
        phg.validate().unwrap();
    }
}

#[test]
fn degradation_report_is_clean_without_deadline() {
    let hg = small_instance(13);
    let ctx = small_ctx(Preset::Default, 4, 2, 13);
    let (phg, report) = partitioner::partition_arc_with_report(hg, &ctx);
    assert!(phg.is_balanced());
    assert!(!report.degraded(), "no deadline, no faults: {}", report.summary());
    assert!(!report.expired);
    assert_eq!(report.panics_recovered, 0);
}

// ---------------------------------------------------------------------
// Bit-identity: an armed-but-never-binding deadline must not change the
// result (the checkpoints only read the clock, they never act early).
// ---------------------------------------------------------------------

#[test]
fn generous_deadline_is_bit_identical() {
    // single-threaded for the async presets (their multi-threaded runs
    // are racy run-to-run, so only t=1 admits an exact comparison);
    // the Deterministic preset is compared at 2 threads
    let hg = small_instance(17);
    for (preset, threads) in
        [(Preset::Default, 1), (Preset::Quality, 1), (Preset::Deterministic, 2)]
    {
        let base =
            partitioner::partition_arc(hg.clone(), &small_ctx(preset, 4, threads, 17)).parts();
        let mut ctx = small_ctx(preset, 4, threads, 17);
        ctx.time_limit = Some(Duration::from_secs(3600));
        let limited = partitioner::partition_arc(hg.clone(), &ctx).parts();
        assert_eq!(base, limited, "{preset:?}: unused deadline changed the result");
    }
}

#[test]
fn deterministic_preset_with_deadline_is_thread_invariant() {
    // the Deterministic preset must stay bit-identical across thread
    // counts even with a (generous, never-firing) deadline armed
    let hg = small_instance(19);
    let run = |threads: usize| {
        let mut c = small_ctx(Preset::Deterministic, 4, threads, 19);
        c.time_limit = Some(Duration::from_secs(3600));
        partitioner::partition_arc(hg.clone(), &c).parts()
    };
    let p1 = run(1);
    assert_eq!(p1, run(2));
    assert_eq!(p1, run(4));
}

// ---------------------------------------------------------------------
// Failpoint injection: panics at every site must be isolated, the
// partition repaired, and the run completed. Feature-gated; the sites
// compile to no-ops otherwise.
// ---------------------------------------------------------------------

#[cfg(feature = "failpoints")]
mod failpoint_recovery {
    use super::*;
    use mtkahypar::util::failpoints::{self, Action};
    use std::sync::Mutex;

    /// The failpoint registry is process-global: serialize these tests
    /// and always clear the registry afterwards. The panic hook is
    /// silenced for the duration so injected panics don't spam stderr.
    static FP_LOCK: Mutex<()> = Mutex::new(());

    fn with_failpoint<R>(site: &str, action: Action, times: usize, f: impl FnOnce() -> R) -> R {
        let _guard = FP_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let prev_hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        failpoints::configure(site, action, times);
        let result = f();
        failpoints::clear();
        std::panic::set_hook(prev_hook);
        result
    }

    #[test]
    fn fm_worker_panic_is_recovered() {
        let hg = small_instance(23);
        let ctx = small_ctx(Preset::Default, 4, 2, 23);
        let (phg, report) = with_failpoint(failpoints::GAIN_TABLE_UPDATE, Action::Panic, 1, || {
            partitioner::partition_arc_with_report(hg.clone(), &ctx)
        });
        assert!(phg.is_balanced(), "imbalance {}", phg.imbalance());
        phg.validate().unwrap();
        assert!(report.panics_recovered >= 1, "{}", report.summary());
    }

    #[test]
    fn flow_worker_panic_is_recovered() {
        let hg = small_instance(29);
        let ctx = small_ctx(Preset::DefaultFlows, 4, 2, 29);
        let (phg, report) = with_failpoint(failpoints::FLOW_WAVE_TAIL, Action::Panic, 1, || {
            partitioner::partition_arc_with_report(hg.clone(), &ctx)
        });
        assert!(phg.is_balanced(), "imbalance {}", phg.imbalance());
        phg.validate().unwrap();
        assert!(report.panics_recovered >= 1, "{}", report.summary());
    }

    #[test]
    fn batch_refinement_panic_is_recovered() {
        let hg = small_instance(31);
        let ctx = small_ctx(Preset::Quality, 4, 2, 31);
        let (phg, report) = with_failpoint(failpoints::BATCH_UNCONTRACTION, Action::Panic, 1, || {
            partitioner::partition_arc_with_report(hg.clone(), &ctx)
        });
        assert!(phg.is_balanced(), "imbalance {}", phg.imbalance());
        phg.validate().unwrap();
        assert!(report.panics_recovered >= 1, "{}", report.summary());
    }

    #[test]
    fn ip_candidate_panic_is_recovered() {
        let hg = small_instance(37);
        let ctx = small_ctx(Preset::Default, 4, 2, 37);
        let (phg, report) = with_failpoint(failpoints::IP_CANDIDATE, Action::Panic, 1, || {
            partitioner::partition_arc_with_report(hg.clone(), &ctx)
        });
        assert!(phg.is_balanced(), "imbalance {}", phg.imbalance());
        phg.validate().unwrap();
        assert!(report.panics_recovered >= 1, "{}", report.summary());
    }

    #[test]
    fn repeated_panics_at_every_site_still_complete() {
        // several injections per site, flows + n-level in one run
        let hg = small_instance(41);
        let ctx = small_ctx(Preset::QualityFlows, 4, 2, 41);
        let _guard = FP_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let prev_hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        failpoints::configure(failpoints::GAIN_TABLE_UPDATE, Action::Panic, 2);
        failpoints::configure(failpoints::FLOW_WAVE_TAIL, Action::Panic, 2);
        failpoints::configure(failpoints::BATCH_UNCONTRACTION, Action::Panic, 2);
        failpoints::configure(failpoints::IP_CANDIDATE, Action::Panic, 2);
        let (phg, report) = partitioner::partition_arc_with_report(hg, &ctx);
        failpoints::clear();
        std::panic::set_hook(prev_hook);
        assert!(phg.is_balanced(), "imbalance {}", phg.imbalance());
        phg.validate().unwrap();
        assert!(report.panics_recovered >= 1, "{}", report.summary());
    }

    #[test]
    fn repartition_apply_panic_is_recovered() {
        use mtkahypar::coordinator::report::DegradationReport;
        use mtkahypar::repartition::{Change, ChangeBatch, RepartitionConfig, Repartitioner};
        let hg = small_instance(53);
        let ctx = small_ctx(Preset::Default, 4, 2, 53);
        let mut rep = Repartitioner::new(hg, ctx, RepartitionConfig::default());
        let mut batch = ChangeBatch::new();
        batch.push(Change::InsertNode { weight: 1 });
        batch.push(Change::RemoveNode { node: 7 });
        let ms = with_failpoint(failpoints::REPARTITION_APPLY, Action::Panic, 1, || {
            rep.apply(&batch)
        })
        .expect("apply must absorb the injected panic");
        assert!(ms.balanced, "imbalance {}", ms.imbalance);
        rep.partition().verify_consistency().unwrap();
        rep.hypergraph().validate().unwrap();
        let report = DegradationReport::from_token(&rep.context().cancel, None);
        assert!(report.panics_recovered >= 1, "{}", report.summary());
        // the service keeps serving after the recovered request
        let ms2 = rep.apply(&ChangeBatch::new()).unwrap();
        assert!(ms2.balanced);
    }

    #[test]
    fn forced_expiry_failpoint_degrades_gracefully() {
        // Expire mid-run via the IP-candidate site: everything after
        // initial partitioning runs at the RebalanceOnly floor
        let hg = small_instance(43);
        let mut ctx = small_ctx(Preset::Default, 4, 2, 43);
        ctx.time_limit = Some(Duration::from_secs(3600));
        let (phg, report) = with_failpoint(failpoints::IP_CANDIDATE, Action::Expire, 1, || {
            partitioner::partition_arc_with_report(hg.clone(), &ctx)
        });
        assert!(phg.is_balanced(), "imbalance {}", phg.imbalance());
        phg.validate().unwrap();
        assert!(report.expired, "{}", report.summary());
        assert!(report.degraded(), "{}", report.summary());
    }

    #[test]
    fn delay_failpoint_burns_the_budget() {
        // a slow worker under a short deadline: the run must still finish
        // balanced, shedding whatever the spent budget demands
        let hg = small_instance(47);
        let mut ctx = small_ctx(Preset::Default, 4, 2, 47);
        ctx.time_limit = Some(Duration::from_millis(30));
        let (phg, _report) =
            with_failpoint(failpoints::IP_CANDIDATE, Action::Delay(Duration::from_millis(40)), 1, || {
                partitioner::partition_arc_with_report(hg.clone(), &ctx)
            });
        assert!(phg.is_balanced(), "imbalance {}", phg.imbalance());
        phg.validate().unwrap();
    }
}
