//! Degenerate and boundary inputs: the cases a production partitioner
//! must survive (empty structures, k=1, k close to n, disconnected
//! inputs, giant nets, tight ε, skewed weights).

use mtkahypar::coordinator::context::{Context, Preset};
use mtkahypar::coordinator::partitioner;
use mtkahypar::generators;
use mtkahypar::hypergraph::Hypergraph;
use mtkahypar::partition::PartitionedHypergraph;
use mtkahypar::refinement::rebalance;
use mtkahypar::BlockId;
use std::sync::Arc;

fn ctx(k: usize) -> Context {
    let mut c = Context::new(Preset::Default, k, 0.03).with_threads(2).with_seed(1);
    c.contraction_limit_factor = 16;
    c.ip_min_repetitions = 1;
    c.ip_max_repetitions = 2;
    c.fm_max_rounds = 2;
    c
}

#[test]
fn netless_hypergraph() {
    let hg = Hypergraph::from_nets(50, &[], None, None);
    let phg = partitioner::partition(&hg, &ctx(4));
    assert!(phg.is_balanced());
    assert_eq!(phg.km1(), 0);
}

#[test]
fn single_net_spanning_everything() {
    let hg = Hypergraph::from_nets(20, &[(0..20u32).collect()], None, None);
    let phg = partitioner::partition(&hg, &ctx(4));
    assert!(phg.is_balanced());
    // one net over 4 blocks: km1 = λ−1 = 3 at best balance
    assert_eq!(phg.km1(), 3);
}

#[test]
fn k_equals_one() {
    let hg = generators::random_kuniform(30, 50, 3, 1);
    let phg = partitioner::partition(&hg, &ctx(1));
    assert_eq!(phg.km1(), 0);
    assert!(phg.parts().iter().all(|&b| b == 0));
}

#[test]
fn k_close_to_n() {
    let hg = generators::random_kuniform(24, 40, 3, 2);
    let phg = partitioner::partition(&hg, &ctx(12));
    assert!(phg.is_balanced(), "imbalance {}", phg.imbalance());
    phg.verify_consistency().unwrap();
}

#[test]
fn disconnected_components() {
    // two components with no net between them
    let mut nets: Vec<Vec<u32>> = Vec::new();
    for i in 0..20u32 {
        nets.push(vec![i, (i + 1) % 25]);
        nets.push(vec![25 + i, 25 + (i + 1) % 25]);
    }
    let hg = Hypergraph::from_nets(50, &nets, None, None);
    let phg = partitioner::partition(&hg, &ctx(2));
    assert!(phg.is_balanced());
    // optimal: split along the components, cutting nothing
    assert!(phg.km1() <= 2, "components should separate: km1 {}", phg.km1());
}

#[test]
fn duplicate_free_requirement_documented() {
    // pins within one net must be distinct (documented API contract);
    // the generators and IO readers uphold it
    let hg = generators::vlsi_hypergraph(200, 300, 1);
    for e in hg.nets() {
        let mut pins = hg.pins(e).to_vec();
        pins.sort_unstable();
        pins.dedup();
        assert_eq!(pins.len(), hg.net_size(e));
    }
}

#[test]
fn skewed_node_weights() {
    // one node carries half the total weight: must sit alone-ish
    let mut weights = vec![1i64; 40];
    weights[0] = 40;
    let nets: Vec<Vec<u32>> = (0..39u32).map(|i| vec![i, i + 1]).collect();
    let hg = Hypergraph::from_nets(40, &nets, Some(weights), None);
    let mut c = ctx(2);
    c.epsilon = 0.1;
    let phg = partitioner::partition(&hg, &c);
    // feasibility is possible (40 vs 39+eps slack) and must be found
    assert!(phg.is_balanced(), "imbalance {}", phg.imbalance());
}

#[test]
fn tight_epsilon_with_rebalance_fallback() {
    let hg = Arc::new(generators::random_kuniform(64, 120, 3, 5));
    // adversarial start: everything in block 0
    let mut phg = PartitionedHypergraph::new(hg, 2);
    phg.set_uniform_max_weight(0.01);
    phg.assign_all(&vec![0 as BlockId; 64], 1);
    assert!(!phg.is_balanced());
    rebalance(&phg, &ctx(2));
    assert!(phg.is_balanced(), "rebalancer must repair: {}", phg.imbalance());
    phg.verify_consistency().unwrap();
}

#[test]
fn weighted_nets_drive_the_objective() {
    // a heavy net must stay uncut in favor of many light ones
    let nets = vec![vec![0u32, 1, 2, 3], vec![0, 4], vec![1, 5], vec![2, 6], vec![3, 7]];
    let net_w = vec![100i64, 1, 1, 1, 1];
    let hg = Hypergraph::from_nets(8, &nets, None, Some(net_w));
    let mut c = ctx(2);
    c.epsilon = 0.34; // allow 4/8 + slack
    let phg = partitioner::partition(&hg, &c);
    assert_eq!(
        phg.pin_count(0, phg.block_of(0)),
        4,
        "heavy net must be internal: km1 {}",
        phg.km1()
    );
}

#[test]
fn single_node() {
    let hg = Hypergraph::from_nets(1, &[], None, None);
    let phg = partitioner::partition(&hg, &ctx(1));
    assert_eq!(phg.parts(), vec![0]);
}

#[test]
fn all_presets_survive_degenerate_inputs() {
    let tiny = Hypergraph::from_nets(6, &[vec![0, 1], vec![2, 3], vec![4, 5]], None, None);
    for preset in Preset::all() {
        let mut c = Context::new(preset, 2, 0.5).with_threads(2).with_seed(2);
        c.contraction_limit_factor = 16;
        c.ip_min_repetitions = 1;
        c.ip_max_repetitions = 1;
        let phg = partitioner::partition(&tiny, &c);
        assert!(phg.is_balanced(), "{preset:?}");
        phg.verify_consistency().unwrap();
    }
}

// ---------------------------------------------------------------------
// Online-mutation edge cases through the warm-start repartitioner: the
// degenerate change batches a serving deployment will eventually see.
// ---------------------------------------------------------------------

mod repartition_edges {
    use super::*;
    use mtkahypar::hypergraph::HypergraphOps;
    use mtkahypar::repartition::{Change, ChangeBatch, RepartitionConfig, Repartitioner};

    /// A 2-regular chain with one triangle net at the head.
    fn chain_instance(n: usize) -> Arc<Hypergraph> {
        let mut nets: Vec<Vec<u32>> = vec![vec![0, 1, 2]];
        for i in 0..(n as u32 - 1) {
            nets.push(vec![i, i + 1]);
        }
        Arc::new(Hypergraph::from_nets(n, &nets, None, None))
    }

    fn rep_ctx(k: usize, eps: f64) -> Context {
        let mut c = ctx(k);
        c.epsilon = eps;
        c
    }

    #[test]
    fn weight_update_flipping_balance_is_repaired() {
        let hg = chain_instance(12);
        let mut rep =
            Repartitioner::new(hg, rep_ctx(2, 0.1), RepartitionConfig::default());
        assert!(rep.partition().is_balanced());
        // one node jumps from weight 1 to 5: its block overflows the
        // (recomputed) L_max and apply must migrate nodes out
        let heavy = 0u32;
        let mut batch = ChangeBatch::new();
        batch.push(Change::UpdateWeight { node: heavy, weight: 5 });
        let ms = rep.apply(&batch).unwrap();
        assert!(ms.balanced, "imbalance {} after weight flip", ms.imbalance);
        rep.partition().verify_consistency().unwrap();
        assert_eq!(HypergraphOps::node_weight(rep.hypergraph(), heavy), 5);
    }

    #[test]
    fn removing_nodes_until_a_net_empties() {
        let hg = chain_instance(14);
        let mut rep =
            Repartitioner::new(hg, rep_ctx(2, 0.2), RepartitionConfig::default());
        // the triangle net {0,1,2} loses all three pins in one batch
        let mut batch = ChangeBatch::new();
        for u in [0u32, 1, 2] {
            batch.push(Change::RemoveNode { node: u });
        }
        let ms = rep.apply(&batch).unwrap();
        assert!(ms.balanced);
        rep.hypergraph().validate().unwrap();
        rep.partition().verify_consistency().unwrap();
        assert!(HypergraphOps::pins(rep.hypergraph(), 0).is_empty(), "net 0 emptied");
        // the emptied net is still removable (its slot is not yet free)
        let mut cleanup = ChangeBatch::new();
        cleanup.push(Change::RemoveNet { net: 0 });
        rep.apply(&cleanup).unwrap();
        rep.hypergraph().validate().unwrap();
    }

    #[test]
    fn single_pin_net_insert_is_objective_neutral() {
        let hg = chain_instance(12);
        // rebalance-only, no V-cycles: the partition must not move, so
        // the λ=1 net's zero contribution is observable exactly
        let cfg = RepartitionConfig {
            rebalance_only: true,
            vcycles: 0,
            ..RepartitionConfig::default()
        };
        let mut rep = Repartitioner::new(hg, rep_ctx(2, 0.2), cfg);
        let before = rep.partition().km1();
        let soed_before = rep.partition().soed();
        let mut batch = ChangeBatch::new();
        batch.push(Change::InsertNet { pins: vec![5], weight: 3 });
        let ms = rep.apply(&batch).unwrap();
        assert!(ms.balanced);
        assert_eq!(ms.objective, before, "single-pin net must contribute 0");
        assert_eq!(rep.partition().km1(), before);
        assert_eq!(rep.partition().soed(), soed_before);
        rep.partition().verify_consistency().unwrap();
    }

    #[test]
    fn failed_batch_keeps_the_service_alive() {
        let hg = chain_instance(12);
        let mut rep =
            Repartitioner::new(hg, rep_ctx(2, 0.2), RepartitionConfig::default());
        let mut bad = ChangeBatch::new();
        bad.push(Change::InsertNode { weight: 2 });
        bad.push(Change::UpdateWeight { node: 999, weight: 1 }); // invalid
        assert!(rep.apply(&bad).is_err());
        // the applied prefix (the insert) is in, the state is consistent,
        // and the next batch serves normally
        rep.hypergraph().validate().unwrap();
        rep.partition().verify_consistency().unwrap();
        let mut ok = ChangeBatch::new();
        ok.push(Change::InsertNode { weight: 1 });
        let ms = rep.apply(&ok).unwrap();
        assert!(ms.balanced);
        assert_eq!(ms.placements.len(), 1);
    }
}
