//! Behavioral tests: paper-stated properties of individual components
//! that the unit tests don't already pin down — coarsening limits and
//! guards, batch-size effects in the n-level scheme, portfolio balance
//! guarantees, flow-scheduler convergence, ε′ monotonicity, objective
//! cross-checks between all metric implementations.

use mtkahypar::coarsening;
use mtkahypar::coordinator::context::{Context, Preset};
use mtkahypar::coordinator::partitioner;
use mtkahypar::generators::{self, PlantedParams};
use mtkahypar::initial::{adaptive_epsilon, portfolio};
use mtkahypar::metrics;
use mtkahypar::partition::PartitionedHypergraph;
use mtkahypar::util::Rng;
use mtkahypar::BlockId;
use std::sync::Arc;

fn ctx(k: usize, seed: u64) -> Context {
    let mut c = Context::new(Preset::Default, k, 0.03).with_threads(2).with_seed(seed);
    c.contraction_limit_factor = 24;
    c.ip_min_repetitions = 1;
    c.ip_max_repetitions = 2;
    c.fm_max_rounds = 2;
    c
}

// ---------------------------------------------------------- coarsening

#[test]
fn coarsening_stops_at_contraction_limit() {
    let hg = Arc::new(generators::planted_hypergraph(
        &PlantedParams { n: 3000, m: 5500, blocks: 4, ..Default::default() },
        1,
    ));
    let c = ctx(4, 1);
    let h = coarsening::coarsen(hg, &c, None);
    let coarsest = h.coarsest();
    // must reach the limit but not undershoot it catastrophically (the
    // paper's 2.5× shrink guard bounds each pass)
    assert!(coarsest.num_nodes() >= c.contraction_limit() / 4);
    assert!(coarsest.num_nodes() <= 3000);
}

#[test]
fn coarsening_pass_shrink_guard() {
    // a hypergraph with no 2-pin structure to exploit: single giant net.
    // cluster weight limit blocks most joins → coarsening must terminate
    // (1% shrink guard) instead of looping forever
    let hg = Arc::new(generators::random_kuniform(600, 5, 4, 2));
    let mut c = ctx(2, 2);
    c.contraction_limit_factor = 8;
    let h = coarsening::coarsen(hg, &c, None);
    assert!(h.levels.len() < 60, "guards must bound the level count");
}

#[test]
fn hierarchy_level_sizes_strictly_decrease() {
    let hg = Arc::new(generators::spm_hypergraph(1500, 1500, 5, 3));
    let h = coarsening::coarsen(hg.clone(), &ctx(4, 3), None);
    let mut prev = hg.num_nodes();
    for level in &h.levels {
        assert!(level.coarse.num_nodes() < prev);
        prev = level.coarse.num_nodes();
    }
}

// ---------------------------------------------------------- initial

#[test]
fn adaptive_epsilon_monotone_in_subweight() {
    // lighter subhypergraphs get a looser ε′ (Equation 1)
    let e_light = adaptive_epsilon(8000, 1500, 8, 2, 0.03);
    let e_heavy = adaptive_epsilon(8000, 2500, 8, 2, 0.03);
    assert!(e_light > e_heavy);
}

#[test]
fn portfolio_best_is_never_worse_than_each_polished_member() {
    let hg = Arc::new(generators::planted_hypergraph(
        &PlantedParams { n: 160, m: 320, blocks: 2, ..Default::default() },
        5,
    ));
    let half = (hg.total_weight() as f64 * 0.53) as i64;
    let c = ctx(2, 5);
    let best = portfolio::best_bipartition(&hg, half, half, &c, 9);
    // the winner must at least match a freshly polished random run
    let parts = portfolio::run_technique(portfolio::Technique::Random, &hg, half, half, 9);
    let rand_km1 = metrics::km1(&hg, &parts, 2);
    assert!(best.km1 <= rand_km1);
    assert!(best.imbalance <= 0.0, "portfolio result must be feasible");
}

#[test]
fn greedy_growing_respects_target_weight() {
    let hg = Arc::new(generators::vlsi_hypergraph(300, 500, 7));
    let max0 = hg.total_weight() / 2;
    for tech in portfolio::Technique::all() {
        let parts = portfolio::run_technique(tech, &hg, max0, max0, 3);
        let w0: i64 = (0..300).filter(|&u| parts[u] == 0).count() as i64;
        assert!(w0 <= max0, "{tech:?}: block 0 overfull ({w0} > {max0})");
    }
}

// ---------------------------------------------------------- refinement

#[test]
fn flow_scheduler_terminates_on_optimal_partitions() {
    // planted perfect partition: flows must converge without changes
    let p = PlantedParams { n: 240, m: 420, blocks: 4, p_intra: 1.0, ..Default::default() };
    let hg = Arc::new(generators::planted_hypergraph(&p, 11));
    let n = hg.num_nodes();
    let parts: Vec<BlockId> = (0..n).map(|u| (u * 4 / n) as BlockId).collect();
    let mut phg = PartitionedHypergraph::new(hg, 4);
    phg.set_uniform_max_weight(0.1);
    phg.assign_all(&parts, 1);
    let before = phg.km1();
    let mut c = ctx(4, 11);
    c.use_flows = true;
    let g = mtkahypar::refinement::flow::flow_refine(&phg, &c);
    assert_eq!(phg.km1(), before - g);
    assert!(g >= 0);
}

#[test]
fn fm_single_round_bounded_by_max_rounds() {
    let hg = Arc::new(generators::planted_hypergraph(
        &PlantedParams { n: 260, m: 500, blocks: 2, ..Default::default() },
        13,
    ));
    let n = hg.num_nodes();
    let mut rng = Rng::new(13);
    let mut parts: Vec<BlockId> = (0..n).map(|u| (u * 2 / n) as BlockId).collect();
    for _ in 0..40 {
        parts[rng.next_below(n)] = rng.next_below(2) as BlockId;
    }
    let mut phg = PartitionedHypergraph::new(hg, 2);
    phg.set_uniform_max_weight(0.3);
    phg.assign_all(&parts, 1);
    let mut c = ctx(2, 13);
    c.fm_max_rounds = 1;
    let stats = mtkahypar::refinement::fm::fm_refine(&phg, &c);
    assert!(stats.rounds <= 1);
}

#[test]
fn lp_localized_touches_only_the_region() {
    // nodes far from the seed set must keep their block when they have
    // no improving move reachable through the expansion frontier
    let p = PlantedParams { n: 300, m: 550, blocks: 2, p_intra: 1.0, ..Default::default() };
    let hg = Arc::new(generators::planted_hypergraph(&p, 17));
    let n = hg.num_nodes();
    let parts: Vec<BlockId> = (0..n).map(|u| (u * 2 / n) as BlockId).collect();
    let mut phg = PartitionedHypergraph::new(hg, 2);
    phg.set_uniform_max_weight(0.2);
    phg.assign_all(&parts, 1);
    let seeds: Vec<u32> = (0..10).collect();
    mtkahypar::refinement::lp::lp_refine_localized(&phg, &ctx(2, 17), &seeds);
    assert_eq!(phg.parts(), parts, "perfect partition: nothing may move");
}

// ---------------------------------------------------------- n-level

#[test]
fn nlevel_batch_size_extremes_work() {
    let hg = Arc::new(generators::planted_hypergraph(
        &PlantedParams { n: 220, m: 420, blocks: 2, ..Default::default() },
        19,
    ));
    for b_max in [1usize, 8, 10_000] {
        let mut c = ctx(2, 19);
        c.nlevel = true;
        c.nlevel_batch_size = b_max;
        let phg = partitioner::partition_arc(hg.clone(), &c);
        assert!(phg.is_balanced(), "b_max={b_max}");
        phg.verify_consistency().unwrap();
    }
}

// ---------------------------------------------------------- metrics

#[test]
fn metric_implementations_agree() {
    let hg = Arc::new(generators::sat_hypergraph(
        120,
        480,
        generators::SatRepresentation::Primal,
        23,
    ));
    let mut rng = Rng::new(23);
    let k = 4;
    let parts: Vec<BlockId> = (0..hg.num_nodes()).map(|_| rng.next_below(k) as BlockId).collect();
    let phg = PartitionedHypergraph::new(hg.clone(), k);
    phg.assign_all(&parts, 2);
    assert_eq!(phg.km1(), metrics::km1(&hg, &parts, k));
    assert_eq!(phg.cut(), metrics::cut(&hg, &parts));
    assert_eq!(phg.soed(), metrics::soed(&hg, &parts, k));
    let bw = metrics::block_weights_hg(&hg, &parts, k);
    let imb = metrics::imbalance(hg.total_weight(), k, &bw);
    assert!((phg.imbalance() - imb).abs() < 1e-9);
}

#[test]
fn graph_and_hypergraph_cut_agree_on_2pin_nets() {
    let g = generators::mesh_graph(12, 12);
    let hg = g.to_hypergraph();
    let mut rng = Rng::new(29);
    let parts: Vec<BlockId> = (0..g.num_nodes()).map(|_| rng.next_below(3) as BlockId).collect();
    assert_eq!(metrics::graph_cut(&g, &parts), metrics::cut(&hg, &parts));
    // for 2-pin nets km1 == cut
    assert_eq!(metrics::km1(&hg, &parts, 3), metrics::cut(&hg, &parts));
}

// ---------------------------------------------------------- pipelines

#[test]
fn flows_only_preset_combination() {
    // flows without FM (custom config): must still be sound
    let hg = generators::planted_hypergraph(
        &PlantedParams { n: 260, m: 500, blocks: 2, ..Default::default() },
        31,
    );
    let mut c = ctx(2, 31);
    c.use_fm = false;
    c.use_flows = true;
    let phg = partitioner::partition(&hg, &c);
    assert!(phg.is_balanced());
    phg.verify_consistency().unwrap();
}

#[test]
fn vcycle_composes_with_every_preset() {
    let hg = generators::planted_hypergraph(
        &PlantedParams { n: 240, m: 450, blocks: 2, ..Default::default() },
        37,
    );
    for preset in [Preset::Speed, Preset::Default] {
        let mut c = ctx(2, 37);
        c.use_fm = preset == Preset::Default;
        let phg = partitioner::partition(&hg, &c);
        let before = phg.km1();
        let improved = mtkahypar::refinement::vcycle(phg, &c, 1);
        assert!(improved.km1() <= before, "{preset:?}");
        assert!(improved.is_balanced());
    }
}

#[test]
fn seeds_change_nondeterministic_results() {
    // sanity that seeding actually reaches the RNG everywhere
    let hg = generators::planted_hypergraph(
        &PlantedParams { n: 300, m: 560, blocks: 4, ..Default::default() },
        41,
    );
    let p1 = partitioner::partition(&hg, &ctx(4, 1)).parts();
    let p2 = partitioner::partition(&hg, &ctx(4, 2)).parts();
    assert_ne!(p1, p2, "different seeds should explore different solutions");
}
