//! Scenario-suite harness: every synthetic instance archetype the
//! generator module produces, driven through every preset, every
//! objective and both Φ/Λ layouts, with balance, consistency and
//! objective-sanity assertions on each run.
//!
//! Instances are deliberately tiny — the point is coverage of the
//! configuration cross-product (the CI matrix re-runs the suite at
//! `MTKH_TEST_THREADS=4` and `MTKH_KSTATE=sparse`), not throughput.

use mtkahypar::coordinator::context::{Context, Preset};
use mtkahypar::coordinator::partitioner;
use mtkahypar::generators::{self, PlantedParams, SatRepresentation};
use mtkahypar::graph::partitioner::partition_graph_arc;
use mtkahypar::hypergraph::Hypergraph;
use mtkahypar::metrics::{self, Objective};
use mtkahypar::partition::KStateChoice;
use std::sync::Arc;

fn test_threads() -> usize {
    std::env::var("MTKH_TEST_THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(2)
}

fn scenario_ctx(preset: Preset, k: usize, obj: Objective, kstate: KStateChoice) -> Context {
    let mut c = Context::new(preset, k, 0.1)
        .with_threads(test_threads())
        .with_seed(7)
        .with_objective(obj)
        .with_kstate(kstate);
    c.contraction_limit_factor = 16;
    c.ip_min_repetitions = 1;
    c.ip_max_repetitions = 2;
    c.fm_max_rounds = 2;
    c
}

/// Every hypergraph archetype the generator module produces, kept small.
fn hypergraph_scenarios() -> Vec<(&'static str, Arc<Hypergraph>)> {
    vec![
        (
            "planted",
            Arc::new(generators::planted_hypergraph(
                &PlantedParams { n: 220, m: 380, blocks: 4, ..Default::default() },
                1,
            )),
        ),
        ("spm", Arc::new(generators::spm_hypergraph(180, 180, 4, 2))),
        (
            "sat_primal",
            Arc::new(generators::sat_hypergraph(80, 240, SatRepresentation::Primal, 3)),
        ),
        ("sat_dual", Arc::new(generators::sat_hypergraph(80, 240, SatRepresentation::Dual, 4))),
        (
            "sat_literal",
            Arc::new(generators::sat_hypergraph(80, 240, SatRepresentation::Literal, 5)),
        ),
        ("vlsi", Arc::new(generators::vlsi_hypergraph(200, 320, 6))),
        ("kuniform", Arc::new(generators::random_kuniform(180, 300, 3, 8))),
    ]
}

/// One scenario run: partition and assert every invariant the harness
/// checks — balance, internal consistency, the configured objective
/// matching a from-scratch evaluation, and the km1/cut/soed identities
/// (`soed = km1 + cut`, `cut ≤ km1 ≤ soed`).
fn check(name: &str, hg: &Arc<Hypergraph>, preset: Preset, obj: Objective, kstate: KStateChoice) {
    let k = 4;
    let ctx = scenario_ctx(preset, k, obj, kstate);
    let phg = partitioner::partition_arc(hg.clone(), &ctx);
    let tag = format!("{name} {preset:?} {obj:?} {kstate:?}");
    assert!(phg.is_balanced(), "{tag}: imbalance {}", phg.imbalance());
    phg.verify_consistency().unwrap_or_else(|e| panic!("{tag}: {e}"));
    let parts = phg.parts();
    assert_eq!(phg.km1(), metrics::km1(hg, &parts, k), "{tag}: km1 from scratch");
    assert_eq!(
        phg.objective_value(obj),
        metrics::objective_hg(obj, hg, &parts, k),
        "{tag}: configured objective from scratch"
    );
    assert_eq!(phg.soed(), phg.km1() + phg.cut(), "{tag}: soed identity");
    assert!(phg.cut() <= phg.km1(), "{tag}: cut ≤ km1");
    assert!(phg.km1() <= phg.soed(), "{tag}: km1 ≤ soed");
    assert!(
        metrics::block_weights_hg(hg, &parts, k).iter().all(|&w| w > 0),
        "{tag}: no empty blocks"
    );
}

fn run_preset(preset: Preset) {
    for (name, hg) in &hypergraph_scenarios() {
        for obj in [Objective::Km1, Objective::Cut, Objective::Soed] {
            for kstate in [KStateChoice::Dense, KStateChoice::Sparse] {
                check(name, hg, preset, obj, kstate);
            }
        }
    }
}

#[test]
fn scenarios_speed() {
    run_preset(Preset::Speed);
}

#[test]
fn scenarios_default() {
    run_preset(Preset::Default);
}

#[test]
fn scenarios_default_flows() {
    run_preset(Preset::DefaultFlows);
}

#[test]
fn scenarios_quality() {
    run_preset(Preset::Quality);
}

#[test]
fn scenarios_quality_flows() {
    run_preset(Preset::QualityFlows);
}

#[test]
fn scenarios_deterministic() {
    run_preset(Preset::Deterministic);
}

/// The plain-graph archetypes through the graph fast path: every preset
/// on an R-MAT power-law graph and a structured mesh (on plain graphs
/// km1 = cut, so the objective loop collapses to the default).
#[test]
fn scenarios_plain_graphs() {
    let graphs = vec![
        ("rmat", Arc::new(generators::rmat_graph(8, 6, 9))),
        ("mesh", Arc::new(generators::mesh_graph(14, 14))),
    ];
    for (name, g) in &graphs {
        for preset in Preset::all() {
            for kstate in [KStateChoice::Dense, KStateChoice::Sparse] {
                let ctx = scenario_ctx(preset, 4, Objective::Km1, kstate);
                let pg = partition_graph_arc(g.clone(), &ctx);
                let tag = format!("{name} {preset:?} {kstate:?}");
                assert!(pg.is_balanced(), "{tag}: imbalance {}", pg.imbalance());
                pg.verify_consistency().unwrap_or_else(|e| panic!("{tag}: {e}"));
                assert_eq!(pg.km1(), pg.cut(), "{tag}: km1 = cut on plain graphs");
            }
        }
    }
}
