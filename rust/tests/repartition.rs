//! Property tests for the warm-start repartitioning service: after
//! random change-batch sequences the incrementally maintained partition
//! matches a freshly built one, warm-start quality beats the
//! rebalance-only baseline, migration volume respects the configured
//! bound, the Deterministic preset stays thread-invariant through
//! `apply`, and the steady-state serving path performs zero pool
//! structural allocations after the first session bind.
//!
//! The suite runs under both Φ/Λ layouts: CI repeats it with
//! `MTKH_KSTATE=sparse` (the env override wins over the per-test
//! `KStateChoice`), and the explicit dense/sparse loop below covers both
//! in a plain run.

use mtkahypar::coordinator::context::{Context, Preset};
use mtkahypar::generators::{planted_hypergraph, PlantedParams};
use mtkahypar::hypergraph::{Hypergraph, HypergraphOps};
use mtkahypar::partition::{KStateChoice, PartitionedHypergraph};
use mtkahypar::repartition::{
    Change, ChangeBatch, RepartitionConfig, RepartitionSession, Repartitioner,
};
use mtkahypar::util::Rng;
use mtkahypar::{coordinator::partitioner, metrics, BlockId, EdgeId, NodeId};
use std::sync::Arc;

fn test_threads() -> usize {
    std::env::var("MTKH_TEST_THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(2)
}

fn small_ctx(preset: Preset, k: usize, seed: u64) -> Context {
    let mut c = Context::new(preset, k, 0.1).with_threads(test_threads()).with_seed(seed);
    c.contraction_limit_factor = 24;
    c.ip_min_repetitions = 1;
    c.ip_max_repetitions = 2;
    c.fm_max_rounds = 2;
    c
}

fn small_instance(seed: u64) -> Arc<Hypergraph> {
    Arc::new(planted_hypergraph(
        &PlantedParams { n: 300, m: 520, blocks: 4, ..Default::default() },
        seed,
    ))
}

/// Generate a random batch that is valid against the *current* dynamic
/// structure (removals target live ids, net pins target active nodes).
fn random_batch(rep: &Repartitioner, rng: &mut Rng, size: usize) -> ChangeBatch {
    let hg = rep.hypergraph();
    let mut active: Vec<NodeId> = hg.active_nodes().collect();
    let mut live_nets: Vec<EdgeId> =
        hg.nets().filter(|&e| !HypergraphOps::pins(hg, e).is_empty()).collect();
    let mut batch = ChangeBatch::new();
    for _ in 0..size {
        match rng.next_below(5) {
            0 => {
                batch.push(Change::InsertNode { weight: 1 + rng.next_below(3) as i64 });
            }
            1 if active.len() > 16 => {
                let i = rng.next_below(active.len());
                batch.push(Change::RemoveNode { node: active.swap_remove(i) });
            }
            2 if active.len() >= 4 => {
                let pins: Vec<NodeId> = rng
                    .sample_indices(active.len(), 2 + rng.next_below(3))
                    .into_iter()
                    .map(|i| active[i])
                    .collect();
                batch.push(Change::InsertNet { pins, weight: 1 + rng.next_below(2) as i64 });
            }
            3 if !live_nets.is_empty() => {
                let i = rng.next_below(live_nets.len());
                batch.push(Change::RemoveNet { net: live_nets.swap_remove(i) });
            }
            _ => {
                let u = active[rng.next_below(active.len())];
                batch.push(Change::UpdateWeight { node: u, weight: 1 + rng.next_below(4) as i64 });
            }
        }
    }
    batch
}

/// The partition the service maintains incrementally must agree with one
/// built from scratch on the mutated structure: same consistency
/// invariants (Π/Φ/Λ/block weights, via `verify_consistency`) and the
/// same objective values as the frozen snapshot evaluated externally.
#[test]
fn matches_fresh_partition_after_random_batches() {
    for (kstate, seed) in [(KStateChoice::Dense, 71u64), (KStateChoice::Sparse, 73)] {
        let ctx = small_ctx(Preset::Default, 4, seed).with_kstate(kstate);
        let mut rep =
            Repartitioner::new(small_instance(seed), ctx, RepartitionConfig::default());
        let mut rng = Rng::new(seed ^ 0xfeed);
        for round in 0..4 {
            let batch = random_batch(&rep, &mut rng, 12);
            let ms = rep.apply(&batch).unwrap_or_else(|e| panic!("round {round}: {e}"));
            assert!(ms.balanced, "round {round}: imbalance {}", ms.imbalance);
            rep.hypergraph().validate().unwrap_or_else(|e| panic!("round {round}: {e}"));
            rep.partition()
                .verify_consistency()
                .unwrap_or_else(|e| panic!("round {round}: {e}"));

            // freeze the active structure and re-evaluate the objective
            // from scratch on the static snapshot: single-pin and empty
            // nets drop out, contributing 0 to every objective, so the
            // values must agree exactly
            let snap = rep.hypergraph().freeze();
            let parts_dyn = rep.partition().parts();
            let parts_snap: Vec<BlockId> =
                snap.to_dynamic.iter().map(|&u| parts_dyn[u as usize]).collect();
            assert_eq!(
                rep.partition().km1(),
                metrics::km1(&snap.hg, &parts_snap, 4),
                "round {round}: km1 must match a from-scratch evaluation"
            );
            let mut fresh = PartitionedHypergraph::new(Arc::new(snap.hg), 4);
            fresh.set_uniform_max_weight(0.1);
            fresh.assign_all(&parts_snap, test_threads());
            fresh.verify_consistency().unwrap();
            assert_eq!(rep.partition().km1(), fresh.km1(), "round {round}");
            assert_eq!(rep.partition().cut(), fresh.cut(), "round {round}");
            assert_eq!(rep.partition().soed(), fresh.soed(), "round {round}");
            for b in 0..4 {
                assert_eq!(
                    rep.partition().block_weight(b),
                    fresh.block_weight(b),
                    "round {round}: block {b} weight"
                );
            }
        }
    }
}

/// Warm-start repair (localized refinement + V-cycle) must end at least
/// as good as the rebalance-only floor on the same mapped partition.
#[test]
fn warm_start_beats_rebalance_only_baseline() {
    let hg = small_instance(77);
    let cold = partitioner::partition_arc(hg.clone(), &small_ctx(Preset::Default, 4, 77));
    let parts = cold.parts();
    drop(cold);

    let run = |rebalance_only: bool| {
        let cfg = RepartitionConfig { rebalance_only, ..RepartitionConfig::default() };
        let ctx = small_ctx(Preset::Default, 4, 77);
        let mut rep = Repartitioner::new_with_parts(hg.clone(), &parts, ctx, cfg);
        let mut rng = Rng::new(0xbead);
        for _ in 0..3 {
            let batch = random_batch(&rep, &mut rng, 10);
            rep.apply(&batch).unwrap();
        }
        (rep.partition().km1(), rep.partition().is_balanced())
    };
    let (warm, warm_balanced) = run(false);
    let (base, base_balanced) = run(true);
    assert!(warm_balanced && base_balanced);
    assert!(warm <= base, "warm start km1 {warm} must not lose to rebalance-only {base}");
}

/// The migrated weight reported per batch respects the configured bound
/// and equals the recomputed sum over the reported moves.
#[test]
fn migration_volume_respects_bound() {
    let hg = small_instance(81);
    let cfg = RepartitionConfig {
        max_migration_fraction: Some(0.2),
        ..RepartitionConfig::default()
    };
    let ctx = small_ctx(Preset::Default, 4, 81);
    let mut rep = Repartitioner::new(hg, ctx, cfg);
    let mut rng = Rng::new(0xcafe);
    for round in 0..4 {
        let batch = random_batch(&rep, &mut rng, 10);
        let ms = rep.apply(&batch).unwrap();
        let limit = ms.migration_limit.expect("bound configured");
        let recomputed: i64 = ms
            .moves
            .iter()
            .map(|&(u, _, _)| HypergraphOps::node_weight(rep.hypergraph(), u))
            .sum();
        assert_eq!(ms.migrated_weight, recomputed, "round {round}: reported volume");
        for &(u, from, to) in &ms.moves {
            assert_ne!(from, to);
            assert_eq!(rep.partition().block_of(u), to, "round {round}: move applied");
        }
        if ms.balanced {
            assert!(
                ms.bound_satisfied(),
                "round {round}: migrated {} over limit {limit}",
                ms.migrated_weight
            );
        }
    }
}

/// Under the Deterministic preset, `apply` is bit-identical for any
/// thread count: same instance, same starting assignment, same batches
/// → same partition at 1, 2 and 4 threads.
#[test]
fn deterministic_apply_is_thread_invariant() {
    let hg = small_instance(83);
    let cold =
        partitioner::partition_arc(hg.clone(), &small_ctx(Preset::Deterministic, 4, 83).with_threads(1));
    let parts = cold.parts();
    drop(cold);

    let run = |threads: usize| {
        let ctx = small_ctx(Preset::Deterministic, 4, 83).with_threads(threads);
        let mut rep = Repartitioner::new_with_parts(
            hg.clone(),
            &parts,
            ctx,
            RepartitionConfig::default(),
        );
        // the batch stream itself is fixed up front (same seed, and the
        // generator only reads structure, which evolves identically)
        let mut rng = Rng::new(0xdead);
        let mut out = Vec::new();
        for _ in 0..3 {
            let batch = random_batch(&rep, &mut rng, 8);
            rep.apply(&batch).unwrap();
            out.push(rep.partition().parts());
        }
        out
    };
    let p1 = run(1);
    assert_eq!(p1, run(2), "threads=2 diverged");
    assert_eq!(p1, run(4), "threads=4 diverged");
}

/// The acceptance criterion of the serving path: after the first session
/// bind, slot-reusing churn batches keep the pool at exactly one
/// structural allocation — park, mutate, unpark, refine and the warm
/// V-cycle all run inside the originally bound buffers.
#[test]
fn steady_state_apply_makes_zero_structural_allocations() {
    for (kstate, seed) in [(KStateChoice::Dense, 87u64), (KStateChoice::Sparse, 89)] {
        let ctx = small_ctx(Preset::Default, 4, seed).with_kstate(kstate);
        let mut rep =
            Repartitioner::new(small_instance(seed), ctx, RepartitionConfig::default());
        assert_eq!(rep.partition_pool().structural_allocs(), 1, "session bind");
        let mut rng = Rng::new(seed ^ 0xace);
        for round in 0..5 {
            // churn that stays within the slot free-lists: every insert
            // is preceded by a removal of at least equal capacity
            let hg = rep.hypergraph();
            let active: Vec<NodeId> = hg.active_nodes().collect();
            let victim_net = hg
                .nets()
                .max_by_key(|&e| HypergraphOps::pins(hg, e).len())
                .expect("instance has nets");
            let victim_size = HypergraphOps::pins(hg, victim_net).len();
            assert!(victim_size >= 3, "churn net too small to re-insert below capacity");
            let victim_node = active[rng.next_below(active.len())];
            let mut batch = ChangeBatch::new();
            batch.push(Change::RemoveNet { net: victim_net });
            batch.push(Change::RemoveNode { node: victim_node });
            batch.push(Change::InsertNode { weight: 1 });
            // pins must exclude the node removed above — it is inactive
            // by the time the net insert applies
            let pins: Vec<NodeId> = rng
                .sample_indices(active.len(), victim_size)
                .into_iter()
                .map(|i| active[i])
                .filter(|&u| u != victim_node)
                .take(victim_size - 1)
                .collect();
            batch.push(Change::InsertNet { pins, weight: 1 });
            let ms = rep.apply(&batch).unwrap_or_else(|e| panic!("round {round}: {e}"));
            assert!(ms.balanced, "round {round}");
            assert_eq!(
                rep.partition_pool().structural_allocs(),
                1,
                "round {round} ({kstate:?}): the warm path must not allocate"
            );
        }
        rep.partition().verify_consistency().unwrap();
    }
}

/// A batch whose insertions outgrow the parked buffers takes the pool's
/// growth path: exactly one counted reallocation, consistent state, and
/// subsequent batches are warm again at the new capacity.
#[test]
fn growth_past_reservation_reallocates_cleanly() {
    let ctx = small_ctx(Preset::Default, 4, 91);
    let mut rep = Repartitioner::new(small_instance(91), ctx, RepartitionConfig::default());
    assert_eq!(rep.partition_pool().structural_allocs(), 1);
    let mut batch = ChangeBatch::new();
    for _ in 0..64 {
        batch.push(Change::InsertNode { weight: 1 });
    }
    // a net wider than anything in the instance forces the state layout
    // past its reservation as well
    let wide: Vec<NodeId> = (0..40).collect();
    batch.push(Change::InsertNet { pins: wide, weight: 1 });
    let ms = rep.apply(&batch).unwrap();
    assert_eq!(ms.placements.len(), 64);
    assert_eq!(
        rep.partition_pool().structural_allocs(),
        2,
        "growth must be one clean counted reallocation"
    );
    rep.hypergraph().validate().unwrap();
    rep.partition().verify_consistency().unwrap();
    // the service is warm again at the grown capacity
    let mut churn = ChangeBatch::new();
    churn.push(Change::RemoveNode { node: ms.placements[0].0 });
    churn.push(Change::InsertNode { weight: 1 });
    rep.apply(&churn).unwrap();
    assert_eq!(rep.partition_pool().structural_allocs(), 2, "no further growth");
}

/// Pool headroom reserved at construction absorbs insertions beyond the
/// instance without any growth reallocation.
#[test]
fn reserved_headroom_absorbs_insertions() {
    let cfg = RepartitionConfig {
        headroom_nodes: 96,
        headroom_nets: 16,
        headroom_net_size: 8,
        ..RepartitionConfig::default()
    };
    let ctx = small_ctx(Preset::Default, 4, 93);
    let mut rep = Repartitioner::new(small_instance(93), ctx, cfg);
    assert_eq!(rep.partition_pool().structural_allocs(), 1);
    let mut batch = ChangeBatch::new();
    for _ in 0..64 {
        batch.push(Change::InsertNode { weight: 1 });
    }
    batch.push(Change::InsertNet { pins: (0..8).collect(), weight: 1 });
    let ms = rep.apply(&batch).unwrap();
    assert!(ms.balanced);
    assert_eq!(
        rep.partition_pool().structural_allocs(),
        1,
        "headroom must keep the growth batch on the warm path"
    );
    rep.partition().verify_consistency().unwrap();
}

/// Session mode: a previously served instance is recognized by its
/// structural hash and warm-starts from the cached partition; quality
/// carries over without a second multilevel run.
#[test]
fn session_cache_round_trip_across_instances() {
    let a = small_instance(95);
    let b = small_instance(96);
    let mut session = RepartitionSession::new(
        small_ctx(Preset::Default, 4, 95),
        RepartitionConfig::default(),
    );
    session.bind(a.clone());
    let km1_a = session.repartitioner().unwrap().partition().km1();
    session.bind(b);
    assert_eq!(session.cache_misses(), 2, "two distinct instances");
    session.bind(a);
    assert_eq!(session.cache_hits(), 1, "instance A recognized");
    assert_eq!(session.cache_misses(), 2);
    let rep = session.repartitioner().unwrap();
    assert_eq!(rep.partition().km1(), km1_a, "cached assignment restored verbatim");
    assert!(rep.partition().is_balanced());
    // and the restored binding keeps serving
    let mut batch = ChangeBatch::new();
    batch.push(Change::InsertNode { weight: 1 });
    let ms = session.apply(&batch).unwrap();
    assert!(ms.balanced);
}
