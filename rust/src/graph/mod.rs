//! Plain-graph data structures (paper §10).
//!
//! A graph is a hypergraph whose nets all have exactly two pins, but the
//! hypergraph representation wastes memory and cache: GP tools use *one*
//! adjacency array. This module provides that optimized representation
//! plus its parallel contraction algorithm; [`crate::partition::graph_partition`]
//! provides the matching partition data structure with on-the-fly gains.

pub mod contraction;
pub mod partitioner;

use crate::{EdgeWeight, NodeId, NodeWeight};

/// An undirected weighted graph stored as directed CSR (each undirected
/// edge appears in both endpoint lists, as the paper's data structure).
#[derive(Clone, Debug, Default)]
pub struct Graph {
    pub(crate) offsets: Vec<u64>,
    pub(crate) targets: Vec<NodeId>,
    pub(crate) edge_weight: Vec<EdgeWeight>,
    pub(crate) node_weight: Vec<NodeWeight>,
    pub(crate) total_weight: NodeWeight,
}

impl Graph {
    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.node_weight.len()
    }

    /// Number of *directed* edges (2× the undirected count).
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.targets.len()
    }

    #[inline]
    pub fn degree(&self, u: NodeId) -> usize {
        (self.offsets[u as usize + 1] - self.offsets[u as usize]) as usize
    }

    #[inline]
    pub fn node_weight(&self, u: NodeId) -> NodeWeight {
        self.node_weight[u as usize]
    }

    #[inline]
    pub fn total_weight(&self) -> NodeWeight {
        self.total_weight
    }

    /// Weighted degree (volume) of `u` — Σ ω(u,v).
    pub fn weighted_degree(&self, u: NodeId) -> EdgeWeight {
        let (s, e) = (self.offsets[u as usize] as usize, self.offsets[u as usize + 1] as usize);
        self.edge_weight[s..e].iter().sum()
    }

    /// Total edge volume Σ_u weighted_degree(u) (= 2 · Σ_{uv} ω(uv)).
    pub fn total_volume(&self) -> EdgeWeight {
        self.edge_weight.iter().sum()
    }

    /// Iterate `(neighbor, weight)` pairs of `u`.
    #[inline]
    pub fn neighbors(&self, u: NodeId) -> impl Iterator<Item = (NodeId, EdgeWeight)> + '_ {
        let s = self.offsets[u as usize] as usize;
        let e = self.offsets[u as usize + 1] as usize;
        self.targets[s..e].iter().copied().zip(self.edge_weight[s..e].iter().copied())
    }

    pub fn nodes(&self) -> impl Iterator<Item = NodeId> {
        0..self.num_nodes() as NodeId
    }

    /// Build from per-node adjacency lists `(target, weight)`.
    /// The lists must already be symmetric.
    pub fn from_adjacency(
        adj: &[Vec<(NodeId, EdgeWeight)>],
        node_weight: Option<Vec<NodeWeight>>,
    ) -> Self {
        let n = adj.len();
        let node_weight = node_weight.unwrap_or_else(|| vec![1; n]);
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0u64);
        let mut targets = Vec::new();
        let mut edge_weight = Vec::new();
        for list in adj {
            for &(v, w) in list {
                debug_assert!((v as usize) < n);
                targets.push(v);
                edge_weight.push(w);
            }
            offsets.push(targets.len() as u64);
        }
        let total_weight = node_weight.iter().sum();
        Graph { offsets, targets, edge_weight, node_weight, total_weight }
    }

    /// Build from an undirected edge list (symmetrized here).
    pub fn from_edges(
        n: usize,
        edges: &[(NodeId, NodeId, EdgeWeight)],
        node_weight: Option<Vec<NodeWeight>>,
    ) -> Self {
        let mut adj: Vec<Vec<(NodeId, EdgeWeight)>> = vec![Vec::new(); n];
        for &(u, v, w) in edges {
            if u == v {
                continue; // self-loops contribute nothing to cuts
            }
            adj[u as usize].push((v, w));
            adj[v as usize].push((u, w));
        }
        Self::from_adjacency(&adj, node_weight)
    }

    /// Convert to the hypergraph representation (each undirected edge one
    /// 2-pin net) — the baseline the §10 optimizations are measured against.
    pub fn to_hypergraph(&self) -> crate::hypergraph::Hypergraph {
        let mut nets = Vec::with_capacity(self.num_edges() / 2);
        let mut weights = Vec::with_capacity(self.num_edges() / 2);
        for u in self.nodes() {
            for (v, w) in self.neighbors(u) {
                if u < v {
                    nets.push(vec![u, v]);
                    weights.push(w);
                }
            }
        }
        crate::hypergraph::Hypergraph::from_nets(
            self.num_nodes(),
            &nets,
            Some(self.node_weight.clone()),
            Some(weights),
        )
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.offsets.len() != self.num_nodes() + 1 {
            return Err("offsets length".into());
        }
        if *self.offsets.last().unwrap() as usize != self.targets.len() {
            return Err("offset tail".into());
        }
        for u in self.nodes() {
            for (v, w) in self.neighbors(u) {
                if v as usize >= self.num_nodes() {
                    return Err(format!("edge target {v} out of range"));
                }
                if !self.neighbors(v).any(|(t, tw)| t == u && tw == w) {
                    return Err(format!("asymmetric edge ({u},{v})"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn path4() -> Graph {
        Graph::from_edges(4, &[(0, 1, 1), (1, 2, 1), (2, 3, 1)], None)
    }

    #[test]
    fn basic() {
        let g = path4();
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.num_edges(), 6);
        assert_eq!(g.degree(1), 2);
        assert_eq!(g.weighted_degree(1), 2);
        assert_eq!(g.total_volume(), 6);
        g.validate().unwrap();
    }

    #[test]
    fn to_hypergraph_roundtrip_counts() {
        let g = path4();
        let hg = g.to_hypergraph();
        assert_eq!(hg.num_nodes(), 4);
        assert_eq!(hg.num_nets(), 3);
        assert_eq!(hg.num_pins(), 6);
        hg.validate().unwrap();
    }

    #[test]
    fn self_loops_dropped() {
        let g = Graph::from_edges(2, &[(0, 0, 5), (0, 1, 1)], None);
        assert_eq!(g.num_edges(), 2);
    }
}
