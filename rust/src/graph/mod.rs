//! Plain-graph data structures (paper §10).
//!
//! A graph is a hypergraph whose nets all have exactly two pins, but the
//! hypergraph representation wastes memory and cache: GP tools use *one*
//! adjacency array. This module provides that optimized representation
//! plus its parallel contraction algorithm. [`Graph`] implements
//! [`HypergraphOps`] with each undirected edge as an implicit two-pin net
//! (`net_size() == 2` is a compile-time-specializable fact), so the whole
//! generic partition/refinement stack runs on it directly — paired with
//! [`crate::partition::state::TwoPinState`], which derives Φ and
//! Λ(e) ∈ {1, 2} from the two endpoint blocks instead of allocating
//! pin-count arrays and connectivity bitsets.

pub mod contraction;
pub mod partitioner;

use crate::hypergraph::HypergraphOps;
use crate::partition::state::TwoPinState;
use crate::{EdgeId, EdgeWeight, NodeId, NodeWeight};

/// An undirected weighted graph stored as directed CSR (each undirected
/// edge appears in both endpoint lists, as the paper's data structure),
/// plus the undirected-net view: every directed slot knows its undirected
/// edge id, and each undirected edge stores its canonical pin pair.
#[derive(Clone, Debug, Default)]
pub struct Graph {
    pub(crate) offsets: Vec<u64>,
    pub(crate) targets: Vec<NodeId>,
    pub(crate) edge_weight: Vec<EdgeWeight>,
    pub(crate) node_weight: Vec<NodeWeight>,
    pub(crate) total_weight: NodeWeight,
    /// undirected edge id of each directed CSR slot (aligned with
    /// `targets`) — a node's incident-net list is a slice of this
    pub(crate) uedge: Vec<EdgeId>,
    /// canonical `(min, max)` endpoint pair per undirected edge, two
    /// entries each — the pin list of the implicit two-pin net
    pub(crate) upins: Vec<NodeId>,
    /// weight per undirected edge
    pub(crate) uweight: Vec<EdgeWeight>,
}

impl Graph {
    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.node_weight.len()
    }

    /// Number of *directed* edges (2× the undirected count).
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.targets.len()
    }

    #[inline]
    pub fn degree(&self, u: NodeId) -> usize {
        (self.offsets[u as usize + 1] - self.offsets[u as usize]) as usize
    }

    #[inline]
    pub fn node_weight(&self, u: NodeId) -> NodeWeight {
        self.node_weight[u as usize]
    }

    #[inline]
    pub fn total_weight(&self) -> NodeWeight {
        self.total_weight
    }

    /// Weighted degree (volume) of `u` — Σ ω(u,v).
    pub fn weighted_degree(&self, u: NodeId) -> EdgeWeight {
        let (s, e) = (self.offsets[u as usize] as usize, self.offsets[u as usize + 1] as usize);
        self.edge_weight[s..e].iter().sum()
    }

    /// Total edge volume Σ_u weighted_degree(u) (= 2 · Σ_{uv} ω(uv)).
    pub fn total_volume(&self) -> EdgeWeight {
        self.edge_weight.iter().sum()
    }

    /// Iterate `(neighbor, weight)` pairs of `u`.
    #[inline]
    pub fn neighbors(&self, u: NodeId) -> impl Iterator<Item = (NodeId, EdgeWeight)> + '_ {
        let s = self.offsets[u as usize] as usize;
        let e = self.offsets[u as usize + 1] as usize;
        self.targets[s..e].iter().copied().zip(self.edge_weight[s..e].iter().copied())
    }

    pub fn nodes(&self) -> impl Iterator<Item = NodeId> {
        0..self.num_nodes() as NodeId
    }

    /// Build from per-node adjacency lists `(target, weight)`.
    /// The lists must already be symmetric.
    pub fn from_adjacency(
        adj: &[Vec<(NodeId, EdgeWeight)>],
        node_weight: Option<Vec<NodeWeight>>,
    ) -> Self {
        let n = adj.len();
        let node_weight = node_weight.unwrap_or_else(|| vec![1; n]);
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0u64);
        let mut targets = Vec::new();
        let mut edge_weight = Vec::new();
        for list in adj {
            for &(v, w) in list {
                debug_assert!((v as usize) < n);
                targets.push(v);
                edge_weight.push(w);
            }
            offsets.push(targets.len() as u64);
        }
        let total_weight = node_weight.iter().sum();
        let (uedge, upins, uweight) =
            Self::build_undirected(n, &offsets, &targets, &edge_weight);
        Graph { offsets, targets, edge_weight, node_weight, total_weight, uedge, upins, uweight }
    }

    /// Pair the two directed slots of each undirected edge under one id —
    /// the implicit two-pin-net view. Directed slots are keyed by their
    /// canonical `(min, max, weight)` triple and sorted by slot within
    /// each group; since the smaller endpoint's CSR slots all precede the
    /// larger's, the i-th forward slot pairs with the i-th reverse slot.
    /// Parallel edges of equal weight pair arbitrarily among themselves,
    /// which is fine: each still gets its own undirected id, and both
    /// slots of an id always belong to *opposite* endpoints (the
    /// invariant the two-pin partition state's packed endpoint words
    /// rely on).
    fn build_undirected(
        n: usize,
        offsets: &[u64],
        targets: &[NodeId],
        edge_weight: &[EdgeWeight],
    ) -> (Vec<EdgeId>, Vec<NodeId>, Vec<EdgeWeight>) {
        let mut keyed: Vec<(NodeId, NodeId, EdgeWeight, u32)> =
            Vec::with_capacity(targets.len());
        for u in 0..n {
            for slot in offsets[u] as usize..offsets[u + 1] as usize {
                let v = targets[slot];
                debug_assert_ne!(u as NodeId, v, "self-loops must be dropped upstream");
                keyed.push((
                    (u as NodeId).min(v),
                    (u as NodeId).max(v),
                    edge_weight[slot],
                    slot as u32,
                ));
            }
        }
        debug_assert!(keyed.len() % 2 == 0, "adjacency must be symmetric");
        keyed.sort_unstable();
        let num_u = keyed.len() / 2;
        let mut uedge = vec![0 as EdgeId; targets.len()];
        let mut upins = vec![0 as NodeId; 2 * num_u];
        let mut uweight = vec![0 as EdgeWeight; num_u];
        let mut id = 0usize;
        let mut i = 0usize;
        while i < keyed.len() {
            let (x, y, w, _) = keyed[i];
            let mut j = i;
            while j < keyed.len() && (keyed[j].0, keyed[j].1, keyed[j].2) == (x, y, w) {
                j += 1;
            }
            let c = (j - i) / 2;
            debug_assert!((j - i) % 2 == 0, "unpaired directed edge — asymmetric adjacency");
            for t in 0..c {
                // keyed[i..i+c] are x's slots, keyed[i+c..j] are y's
                // (x < y ⇒ x's CSR slots come first in slot order)
                let sx = keyed[i + t].3 as usize;
                let sy = keyed[i + c + t].3 as usize;
                debug_assert!(targets[sx] == y && targets[sy] == x);
                uedge[sx] = id as EdgeId;
                uedge[sy] = id as EdgeId;
                upins[2 * id] = x;
                upins[2 * id + 1] = y;
                uweight[id] = w;
                id += 1;
            }
            i = j;
        }
        (uedge, upins, uweight)
    }

    /// Number of undirected edges (= implicit two-pin nets).
    #[inline]
    pub fn num_undirected_edges(&self) -> usize {
        self.uweight.len()
    }

    /// Build from an undirected edge list (symmetrized here).
    pub fn from_edges(
        n: usize,
        edges: &[(NodeId, NodeId, EdgeWeight)],
        node_weight: Option<Vec<NodeWeight>>,
    ) -> Self {
        let mut adj: Vec<Vec<(NodeId, EdgeWeight)>> = vec![Vec::new(); n];
        for &(u, v, w) in edges {
            if u == v {
                continue; // self-loops contribute nothing to cuts
            }
            adj[u as usize].push((v, w));
            adj[v as usize].push((u, w));
        }
        Self::from_adjacency(&adj, node_weight)
    }

    /// Convert to the hypergraph representation (each undirected edge one
    /// 2-pin net) — the baseline the §10 optimizations are measured against.
    pub fn to_hypergraph(&self) -> crate::hypergraph::Hypergraph {
        let mut nets = Vec::with_capacity(self.num_edges() / 2);
        let mut weights = Vec::with_capacity(self.num_edges() / 2);
        for u in self.nodes() {
            for (v, w) in self.neighbors(u) {
                if u < v {
                    nets.push(vec![u, v]);
                    weights.push(w);
                }
            }
        }
        crate::hypergraph::Hypergraph::from_nets(
            self.num_nodes(),
            &nets,
            Some(self.node_weight.clone()),
            Some(weights),
        )
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.offsets.len() != self.num_nodes() + 1 {
            return Err("offsets length".into());
        }
        if *self.offsets.last().unwrap() as usize != self.targets.len() {
            return Err("offset tail".into());
        }
        for u in self.nodes() {
            for (v, w) in self.neighbors(u) {
                if v as usize >= self.num_nodes() {
                    return Err(format!("edge target {v} out of range"));
                }
                if !self.neighbors(v).any(|(t, tw)| t == u && tw == w) {
                    return Err(format!("asymmetric edge ({u},{v})"));
                }
            }
        }
        if self.uedge.len() != self.targets.len() || self.upins.len() != 2 * self.uweight.len() {
            return Err("undirected view sizes".into());
        }
        for (slot, &e) in self.uedge.iter().enumerate() {
            let (x, y) = (self.upins[2 * e as usize], self.upins[2 * e as usize + 1]);
            let v = self.targets[slot];
            if x >= y {
                return Err(format!("undirected edge {e} pins not canonical"));
            }
            if v != x && v != y {
                return Err(format!("slot {slot} maps to undirected edge {e} missing its target"));
            }
        }
        Ok(())
    }
}

/// The two-pin-net view: each undirected edge is a net of exactly two
/// pins, a node's incident nets are the undirected ids of its adjacency
/// slice, and the partition state is [`TwoPinState`] — no pin-count or
/// connectivity-set allocations anywhere on this path (paper §10).
impl HypergraphOps for Graph {
    type State = TwoPinState;

    #[inline]
    fn num_nodes(&self) -> usize {
        Graph::num_nodes(self)
    }
    #[inline]
    fn num_nets(&self) -> usize {
        self.uweight.len()
    }
    #[inline]
    fn num_pins(&self) -> usize {
        self.upins.len()
    }
    #[inline]
    fn pins(&self, e: EdgeId) -> &[NodeId] {
        &self.upins[2 * e as usize..2 * e as usize + 2]
    }
    #[inline]
    fn incident_nets(&self, u: NodeId) -> &[EdgeId] {
        &self.uedge[self.offsets[u as usize] as usize..self.offsets[u as usize + 1] as usize]
    }
    #[inline]
    fn node_weight(&self, u: NodeId) -> NodeWeight {
        Graph::node_weight(self, u)
    }
    #[inline]
    fn net_weight(&self, e: EdgeId) -> EdgeWeight {
        self.uweight[e as usize]
    }
    #[inline]
    fn total_weight(&self) -> NodeWeight {
        Graph::total_weight(self)
    }
    #[inline]
    fn max_net_size(&self) -> usize {
        2
    }
    #[inline]
    fn net_size(&self, _e: EdgeId) -> usize {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn path4() -> Graph {
        Graph::from_edges(4, &[(0, 1, 1), (1, 2, 1), (2, 3, 1)], None)
    }

    #[test]
    fn basic() {
        let g = path4();
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.num_edges(), 6);
        assert_eq!(g.degree(1), 2);
        assert_eq!(g.weighted_degree(1), 2);
        assert_eq!(g.total_volume(), 6);
        g.validate().unwrap();
    }

    #[test]
    fn to_hypergraph_roundtrip_counts() {
        let g = path4();
        let hg = g.to_hypergraph();
        assert_eq!(hg.num_nodes(), 4);
        assert_eq!(hg.num_nets(), 3);
        assert_eq!(hg.num_pins(), 6);
        hg.validate().unwrap();
    }

    #[test]
    fn self_loops_dropped() {
        let g = Graph::from_edges(2, &[(0, 0, 5), (0, 1, 1)], None);
        assert_eq!(g.num_edges(), 2);
    }

    #[test]
    fn uedge_ids_pair_up() {
        let edges: Vec<(NodeId, NodeId, i64)> =
            (0..6).map(|u| (u, (u + 1) % 6, 1)).collect();
        let g = Graph::from_edges(6, &edges, None);
        assert_eq!(HypergraphOps::num_nets(&g), 6);
        let mut count = vec![0usize; 6];
        for &e in &g.uedge {
            count[e as usize] += 1;
        }
        assert!(count.iter().all(|&c| c == 2), "every undirected id appears twice");
        g.validate().unwrap();
    }

    #[test]
    fn two_pin_net_view_matches_hypergraph() {
        let g = path4();
        let hg = g.to_hypergraph();
        assert_eq!(HypergraphOps::num_nets(&g), hg.num_nets());
        assert_eq!(HypergraphOps::num_pins(&g), hg.num_pins());
        for u in g.nodes() {
            assert_eq!(HypergraphOps::degree(&g, u), g.degree(u));
        }
        // per-net pin sets agree up to net id permutation
        let mut g_nets: Vec<(Vec<NodeId>, i64)> = (0..HypergraphOps::num_nets(&g))
            .map(|e| (HypergraphOps::pins(&g, e as u32).to_vec(), HypergraphOps::net_weight(&g, e as u32)))
            .collect();
        let mut h_nets: Vec<(Vec<NodeId>, i64)> = (0..hg.num_nets())
            .map(|e| (hg.pins(e as u32).to_vec(), hg.net_weight(e as u32)))
            .collect();
        g_nets.sort();
        h_nets.sort();
        assert_eq!(g_nets, h_nets);
    }

    #[test]
    fn parallel_edges_get_distinct_net_ids() {
        // from_adjacency with a doubled edge: both survive as separate nets
        let adj = vec![vec![(1, 2), (1, 3)], vec![(0, 2), (0, 3)]];
        let g = Graph::from_adjacency(&adj, None);
        assert_eq!(HypergraphOps::num_nets(&g), 2);
        assert_eq!(g.uweight.iter().sum::<i64>(), 5);
        g.validate().unwrap();
    }
}
