//! Parallel graph contraction (paper §10.1).
//!
//! Remap cluster ids via prefix sum, aggregate weights/degrees with atomic
//! fetch-add, copy incident edges per cluster, sort each cluster's list,
//! merge parallel edges (aggregating weights) and drop self-loops, then
//! rebuild the CSR via a prefix sum.

use super::Graph;
use crate::parallel::{par_for_auto, parallel_prefix_sum, SharedSlice};
use crate::{EdgeWeight, NodeId, NodeWeight};
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};

pub struct GraphContraction {
    pub coarse: Graph,
    pub fine_to_coarse: Vec<NodeId>,
}

/// Contract the clustering `rep` (idempotent representative array).
pub fn contract(g: &Graph, rep: &[NodeId], threads: usize) -> GraphContraction {
    let n = g.num_nodes();
    assert_eq!(rep.len(), n);

    // remap representatives to consecutive coarse ids
    let mut is_rep = vec![0u64; n];
    par_for_auto(n, threads, {
        let is_rep = SharedSlice::new(&mut is_rep);
        move |u| {
            if rep[u] as usize == u {
                unsafe { is_rep.write(u, 1) };
            }
        }
    });
    let coarse_n = parallel_prefix_sum(&mut is_rep, threads) as usize;
    let coarse_id = is_rep;
    let mut fine_to_coarse = vec![0 as NodeId; n];
    par_for_auto(n, threads, {
        let f2c = SharedSlice::new(&mut fine_to_coarse);
        let coarse_id = &coarse_id;
        move |u| unsafe { f2c.write(u, coarse_id[rep[u] as usize] as NodeId) }
    });

    // aggregate weights and (upper-bound) degrees
    let weights: Vec<AtomicI64> = (0..coarse_n).map(|_| AtomicI64::new(0)).collect();
    let degrees: Vec<AtomicU64> = (0..coarse_n).map(|_| AtomicU64::new(0)).collect();
    par_for_auto(n, threads, |u| {
        let c = fine_to_coarse[u] as usize;
        weights[c].fetch_add(g.node_weight(u as NodeId), Ordering::Relaxed);
        degrees[c].fetch_add(g.degree(u as NodeId) as u64, Ordering::Relaxed);
    });

    // copy incident edges of each cluster into a contiguous staging range
    let mut stage_offsets: Vec<u64> = degrees.iter().map(|d| d.load(Ordering::Relaxed)).collect();
    stage_offsets.push(0);
    let total: u64 = parallel_prefix_sum(&mut stage_offsets, threads);
    let cursors: Vec<AtomicU64> =
        stage_offsets.iter().take(coarse_n).map(|&o| AtomicU64::new(o)).collect();
    let mut staging: Vec<(NodeId, EdgeWeight)> = vec![(0, 0); total as usize];
    {
        let staging_s = SharedSlice::new(&mut staging);
        par_for_auto(n, threads, |u| {
            let c = fine_to_coarse[u] as usize;
            for (v, w) in g.neighbors(u as NodeId) {
                let slot = cursors[c].fetch_add(1, Ordering::Relaxed) as usize;
                // SAFETY: each slot claimed exactly once via fetch_add.
                unsafe { staging_s.write(slot, (fine_to_coarse[v as usize], w)) };
            }
        });
    }

    // per-cluster: sort, drop self-loops, merge parallel edges
    let mut merged: Vec<Vec<(NodeId, EdgeWeight)>> = vec![Vec::new(); coarse_n];
    {
        let merged_s = SharedSlice::new(&mut merged);
        let stage_offsets = &stage_offsets;
        let staging = &staging;
        par_for_auto(coarse_n, threads, move |c| {
            let s = stage_offsets[c] as usize;
            let e = if c + 1 < stage_offsets.len() { stage_offsets[c + 1] as usize } else { s };
            let mut list: Vec<(NodeId, EdgeWeight)> = staging[s..e].to_vec();
            list.sort_unstable_by_key(|&(v, _)| v);
            let mut out: Vec<(NodeId, EdgeWeight)> = Vec::with_capacity(list.len());
            for (v, w) in list {
                if v as usize == c {
                    continue; // self-loop
                }
                if let Some(last) = out.last_mut() {
                    if last.0 == v {
                        last.1 += w;
                        continue;
                    }
                }
                out.push((v, w));
            }
            unsafe { merged_s.write(c, out) };
        });
    }

    let coarse_weights: Vec<NodeWeight> = weights.into_iter().map(|w| w.into_inner()).collect();
    let coarse = Graph::from_adjacency(&merged, Some(coarse_weights));
    debug_assert!(coarse.validate().is_ok());
    GraphContraction { coarse, fine_to_coarse }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contract_triangle_pair() {
        // two triangles joined by one edge
        let g = Graph::from_edges(
            6,
            &[(0, 1, 1), (1, 2, 1), (0, 2, 1), (3, 4, 1), (4, 5, 1), (3, 5, 1), (2, 3, 1)],
            None,
        );
        let rep = vec![0, 0, 0, 3, 3, 3];
        let c = contract(&g, &rep, 2);
        assert_eq!(c.coarse.num_nodes(), 2);
        // only the bridging edge survives, weight 1, both directions
        assert_eq!(c.coarse.num_edges(), 2);
        assert_eq!(c.coarse.neighbors(0).next().unwrap().1, 1);
        assert_eq!(c.coarse.node_weight(0), 3);
        assert_eq!(c.coarse.total_weight(), 6);
    }

    #[test]
    fn parallel_edges_merge() {
        let g = Graph::from_edges(4, &[(0, 2, 1), (1, 2, 2), (0, 3, 3), (1, 3, 4)], None);
        let rep = vec![0, 0, 2, 3];
        let c = contract(&g, &rep, 1);
        assert_eq!(c.coarse.num_nodes(), 3);
        let w02 = c.coarse.neighbors(0).find(|&(v, _)| v == 1).map(|(_, w)| w);
        let w03 = c.coarse.neighbors(0).find(|&(v, _)| v == 2).map(|(_, w)| w);
        assert_eq!(w02, Some(3)); // 1+2
        assert_eq!(w03, Some(7)); // 3+4
        c.coarse.validate().unwrap();
    }

    #[test]
    fn identity_preserves() {
        let g = Graph::from_edges(5, &[(0, 1, 1), (1, 2, 1), (2, 3, 1), (3, 4, 1)], None);
        let rep: Vec<NodeId> = (0..5).collect();
        let c = contract(&g, &rep, 4);
        assert_eq!(c.coarse.num_nodes(), 5);
        assert_eq!(c.coarse.num_edges(), 8);
    }
}
