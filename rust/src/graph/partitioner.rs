//! Graph partitioning pipeline on the optimized plain-graph data
//! structures (paper §10): drop-in replacements for coarsening, label
//! propagation and FM refinement that exploit the single adjacency array
//! and on-the-fly edge-cut gains. Initial partitioning converts the
//! (small) coarsest graph to its hypergraph view and reuses the portfolio
//! (paper: "initial partitioning uses all algorithms within multilevel
//! recursive bipartitioning").

use super::{contraction as gcontract, Graph};
use crate::coordinator::context::Context;
use crate::datastructures::{AddressablePQ, RatingMap};
use crate::initial;
use crate::parallel::parallel_chunks;
use crate::partition::PartitionedGraph;
use crate::util::rng::hash2;
use crate::util::Rng;
use crate::{BlockId, Gain, NodeId, NodeWeight};
use std::sync::atomic::{AtomicI64, AtomicU32, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};

/// Multilevel graph partitioning (the §10 pipeline).
pub fn partition_graph(g: &Graph, ctx: &Context) -> PartitionedGraph {
    partition_graph_arc(Arc::new(g.clone()), ctx)
}

pub fn partition_graph_arc(g: Arc<Graph>, ctx: &Context) -> PartitionedGraph {
    let timer = ctx.timer.clone();
    // standalone driver: arm the deadline for this run (no-op when unset)
    ctx.cancel.arm(ctx.time_limit);
    // ---- preprocessing: Louvain runs directly on the graph ----
    let communities = if ctx.use_community_detection {
        Some(timer.time("preprocessing", || {
            crate::preprocessing::louvain(
                &g,
                &crate::preprocessing::LouvainConfig {
                    threads: ctx.threads,
                    seed: ctx.seed,
                    max_rounds: ctx.louvain_max_rounds,
                    deterministic: ctx.deterministic,
                    ..Default::default()
                },
            )
        }))
    } else {
        None
    };

    // ---- coarsening on the graph data structure ----
    struct GLevel {
        coarse: Arc<Graph>,
        fine_to_coarse: Vec<NodeId>,
    }
    let limit = ctx.contraction_limit().max(2 * ctx.k);
    let cmax = ctx.max_cluster_weight(g.total_weight());
    let mut levels: Vec<GLevel> = Vec::new();
    let mut current = g.clone();
    let mut comms = communities;
    timer.time("coarsening", || {
        while current.num_nodes() > limit {
            // cancellation checkpoint at the pass boundary, as in the
            // hypergraph coarsener: a shorter hierarchy stays usable
            if ctx.cancel.is_expired() {
                ctx.cancel.note_early_stop();
                break;
            }
            let n_before = current.num_nodes();
            let rep = cluster_graph(&current, ctx, comms.as_deref(), cmax, limit);
            let c = gcontract::contract(&current, &rep, ctx.threads);
            if n_before - c.coarse.num_nodes() <= (ctx.min_shrink * n_before as f64) as usize {
                break;
            }
            if let Some(cm) = &comms {
                let mut coarse = vec![0u32; c.coarse.num_nodes()];
                for u in 0..n_before {
                    coarse[c.fine_to_coarse[u] as usize] = cm[u];
                }
                comms = Some(coarse);
            }
            let coarse = Arc::new(c.coarse);
            levels.push(GLevel { coarse: coarse.clone(), fine_to_coarse: c.fine_to_coarse });
            current = coarse;
        }
    });

    // ---- initial partitioning via the hypergraph portfolio ----
    let mut parts: Vec<BlockId> = timer.time("initial_partitioning", || {
        let coarsest_hg = Arc::new(current.to_hypergraph());
        initial::initial_partition(coarsest_hg, ctx)
    });

    // ---- uncoarsening with graph-specialized refinement ----
    let refine = |g: Arc<Graph>, parts: &[BlockId]| -> PartitionedGraph {
        let mut pg = PartitionedGraph::new(g, ctx.k);
        pg.set_uniform_max_weight(ctx.epsilon);
        pg.assign_all(parts, ctx.threads);
        timer.time("label_propagation", || lp_refine_graph(&pg, ctx));
        // the graph specialization has no synchronous FM sibling yet, so
        // `ctx.deterministic` keeps the pre-det-FM behavior (LP only)
        // instead of silently running the asynchronous FM
        if ctx.use_fm && !ctx.deterministic {
            timer.time("fm", || fm_refine_graph(&pg, ctx));
        }
        pg
    };
    for i in (0..levels.len()).rev() {
        let pg = refine(levels[i].coarse.clone(), &parts);
        let refined = pg.parts();
        parts = levels[i].fine_to_coarse.iter().map(|&c| refined[c as usize]).collect();
    }
    refine(g, &parts)
}

// ---------------------------------------------------------------- coarsen

const G_UNCLUSTERED: u8 = 0;
const G_CLUSTERED: u8 = 2;

/// Heavy-edge clustering on the plain-graph structure (one adjacency
/// array ⇒ the cache-friendly path of Fig. 15). Protocol as in §4.1 but
/// with edge-weight ratings.
pub fn cluster_graph(
    g: &Graph,
    ctx: &Context,
    communities: Option<&[u32]>,
    cmax: NodeWeight,
    floor: usize,
) -> Vec<NodeId> {
    let n = g.num_nodes();
    let state: Vec<AtomicU8> = (0..n).map(|_| AtomicU8::new(G_UNCLUSTERED)).collect();
    let rep: Vec<AtomicU32> = (0..n as u32).map(AtomicU32::new).collect();
    let weight: Vec<AtomicI64> =
        (0..n).map(|u| AtomicI64::new(g.node_weight(u as NodeId))).collect();
    let remaining = AtomicI64::new(n as i64);
    let min_remaining = floor.max((n as f64 / ctx.shrink_limit) as usize) as i64;

    let mut order: Vec<u32> = (0..n as u32).collect();
    Rng::new(hash2(ctx.seed, n as u64 ^ 0x6a)).shuffle(&mut order);

    parallel_chunks(n, ctx.threads, |_, s, e| {
        let mut map = RatingMap::new(4096);
        for &u in &order[s..e] {
            if remaining.load(Ordering::Acquire) <= min_remaining {
                break;
            }
            if state[u as usize].load(Ordering::Acquire) != G_UNCLUSTERED {
                continue;
            }
            // rating over neighbor clusters
            map.clear();
            let cu = communities.map(|c| c[u as usize]);
            for (v, w) in g.neighbors(u) {
                if v == u {
                    continue;
                }
                if let Some(cu) = cu {
                    if communities.unwrap()[v as usize] != cu {
                        continue;
                    }
                }
                if map.should_grow() {
                    map.grow();
                }
                map.add(rep[v as usize].load(Ordering::Relaxed) as u64, w as f64);
            }
            let wu = g.node_weight(u);
            let mut best: Option<(f64, u64, u32)> = None;
            for (root, rating, _) in map.iter() {
                if root == u as u64 || weight[root as usize].load(Ordering::Relaxed) + wu > cmax {
                    continue;
                }
                let tb = hash2(ctx.seed ^ u as u64, root);
                if best.map_or(true, |(br, bt, _)| {
                    rating > br + 1e-12 || ((rating - br).abs() <= 1e-12 && tb > bt)
                }) {
                    best = Some((rating, tb, root as u32));
                }
            }
            let Some((_, _, v)) = best else { continue };
            // simplified join: lock u via CAS, then adopt v's root if v is
            // stable; cycles resolved by retrying on the (rare) conflict
            if state[u as usize]
                .compare_exchange(G_UNCLUSTERED, G_CLUSTERED, Ordering::AcqRel, Ordering::Acquire)
                .is_err()
            {
                continue;
            }
            let root = rep[v as usize].load(Ordering::Acquire);
            if weight[root as usize].fetch_add(wu, Ordering::AcqRel) + wu > cmax {
                weight[root as usize].fetch_sub(wu, Ordering::AcqRel);
                state[u as usize].store(G_UNCLUSTERED, Ordering::Release);
                continue;
            }
            rep[u as usize].store(root, Ordering::Release);
            state[root as usize].store(G_CLUSTERED, Ordering::Release);
            remaining.fetch_sub(1, Ordering::AcqRel);
        }
    });

    // flatten chains (a root may have joined elsewhere before freezing)
    let mut out: Vec<NodeId> = rep.iter().map(|r| r.load(Ordering::Relaxed)).collect();
    for u in 0..n {
        let mut r = out[u] as usize;
        let mut hops = 0;
        while out[r] as usize != r && hops < n {
            r = out[r] as usize;
            hops += 1;
        }
        out[u] = r as NodeId;
    }
    out
}

// ------------------------------------------------------------------- LP

/// Label propagation on the graph partition (on-the-fly gains, §10.2).
pub fn lp_refine_graph(pg: &PartitionedGraph, ctx: &Context) -> Gain {
    let n = pg.graph().num_nodes();
    let mut total: Gain = 0;
    for round in 0..ctx.lp_rounds {
        // cancellation checkpoint: finish only whole rounds
        if ctx.cancel.is_expired() {
            ctx.cancel.note_early_stop();
            break;
        }
        pg.reset_edge_sync();
        let mut order: Vec<u32> = (0..n as u32).collect();
        Rng::new(hash2(ctx.seed, 0x61 ^ round as u64)).shuffle(&mut order);
        let gained = AtomicI64::new(0);
        parallel_chunks(n, ctx.threads, |_, s, e| {
            for &u in &order[s..e] {
                if !pg.is_border(u) {
                    continue;
                }
                if let Some((g, t)) = pg.max_gain_move(u) {
                    if g > 0 {
                        if let Some(attr) = pg.try_move(u, t) {
                            gained.fetch_add(attr, Ordering::Relaxed);
                        }
                    }
                }
            }
        });
        let delta = gained.load(Ordering::Relaxed);
        total += delta;
        if delta <= 0 {
            break;
        }
    }
    total
}

// ------------------------------------------------------------------- FM

/// Boundary FM on the graph partition: per round each node moves at most
/// once; moves apply directly to the global partition with CAS-attributed
/// gains, and the round's move sequence is reverted to its best prefix.
pub fn fm_refine_graph(pg: &PartitionedGraph, ctx: &Context) -> Gain {
    let n = pg.graph().num_nodes();
    let mut total: Gain = 0;
    for round in 0..ctx.fm_max_rounds {
        // cancellation checkpoint: finish only whole rounds
        if ctx.cancel.is_expired() {
            ctx.cancel.note_early_stop();
            break;
        }
        pg.reset_edge_sync();
        let mut boundary: Vec<NodeId> = (0..n as NodeId).filter(|&u| pg.is_border(u)).collect();
        if boundary.is_empty() {
            break;
        }
        Rng::new(hash2(ctx.seed ^ 0x6f, round as u64)).shuffle(&mut boundary);
        let moved: Vec<AtomicU8> = (0..n).map(|_| AtomicU8::new(0)).collect();
        let seq: Mutex<Vec<(NodeId, BlockId, Gain)>> = Mutex::new(Vec::new());

        parallel_chunks(boundary.len(), ctx.threads, |_, s, e| {
            let mut pq = AddressablePQ::new();
            let mut local: Vec<(NodeId, BlockId, Gain)> = Vec::new();
            for &u in &boundary[s..e] {
                if moved[u as usize].swap(1, Ordering::AcqRel) == 0 {
                    if let Some((g, _)) = pg.max_gain_move(u) {
                        pq.insert(u, g);
                    } else {
                        moved[u as usize].store(0, Ordering::Release);
                    }
                }
            }
            let mut stop = crate::refinement::fm::AdaptiveStoppingRule::new(1.0, n);
            while let Some((u, g)) = pq.pop_max() {
                let Some((g2, t)) = pg.max_gain_move(u) else { continue };
                if g2 < g {
                    pq.insert(u, g2);
                    continue;
                }
                let from = pg.block_of(u);
                let Some(attr) = pg.try_move(u, t) else { continue };
                local.push((u, from, attr));
                stop.push(attr);
                if attr > 0 {
                    stop.improvement_found();
                }
                // expand to neighbors
                for (v, _) in pg.graph().neighbors(u) {
                    if pq.contains(v) {
                        if let Some((gv, _)) = pg.max_gain_move(v) {
                            pq.adjust(v, gv);
                        }
                    } else if moved[v as usize].swap(1, Ordering::AcqRel) == 0 {
                        if let Some((gv, _)) = pg.max_gain_move(v) {
                            pq.insert(v, gv);
                        } else {
                            moved[v as usize].store(0, Ordering::Release);
                        }
                    }
                }
                if stop.should_stop() {
                    break;
                }
            }
            seq.lock().unwrap().extend(local);
        });

        // best prefix by attributed gains (exact in the sequential case;
        // see DESIGN.md for the concurrent approximation note)
        let seq = seq.into_inner().unwrap();
        let gains: Vec<Gain> = seq.iter().map(|&(_, _, g)| g).collect();
        let (len, prefix_gain) = crate::partition::best_prefix(&gains);
        for &(u, from, _) in seq[len..].iter().rev() {
            pg.move_unchecked(u, from);
        }
        total += prefix_gain;
        if prefix_gain <= 0 {
            break;
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::{Context, Preset};
    use crate::generators::{mesh_graph, rmat_graph};
    use crate::metrics;

    fn ctx(k: usize, threads: usize, seed: u64) -> Context {
        let mut c = Context::new(Preset::Default, k, 0.03).with_threads(threads).with_seed(seed);
        c.contraction_limit_factor = 24;
        c.ip_min_repetitions = 2;
        c.ip_max_repetitions = 3;
        c.fm_max_rounds = 3;
        c
    }

    #[test]
    fn graph_pipeline_on_mesh() {
        let g = mesh_graph(24, 24);
        let pg = partition_graph(&g, &ctx(4, 2, 3));
        assert!(pg.is_balanced(), "imbalance {}", pg.imbalance());
        pg.verify_consistency().unwrap();
        // a 24×24 mesh split in 4 should cut far less than all edges
        let cut = pg.cut();
        assert!(cut < g.num_edges() as i64 / 4, "cut {cut}");
        // sanity vs from-scratch metric
        assert_eq!(cut, metrics::graph_cut(&g, &pg.parts()));
    }

    #[test]
    fn graph_pipeline_on_powerlaw() {
        let g = rmat_graph(9, 8, 5);
        let pg = partition_graph(&g, &ctx(2, 2, 5));
        assert!(pg.is_balanced());
        pg.verify_consistency().unwrap();
    }

    #[test]
    fn graph_clustering_respects_weight_limit() {
        let g = mesh_graph(16, 16);
        let rep = cluster_graph(&g, &ctx(2, 4, 1), None, 4, 8);
        let mut w = std::collections::HashMap::new();
        for u in 0..g.num_nodes() {
            assert_eq!(rep[rep[u] as usize], rep[u], "idempotent");
            *w.entry(rep[u]).or_insert(0i64) += 1;
        }
        assert!(w.values().all(|&c| c <= 4));
    }

    #[test]
    fn graph_fm_improves_bad_partition() {
        let g = Arc::new(mesh_graph(16, 16));
        let n = g.num_nodes();
        // stripes: terrible cut for k=2
        let parts: Vec<BlockId> = (0..n).map(|u| ((u / 16) % 2) as BlockId).collect();
        let mut pg = PartitionedGraph::new(g, 2);
        pg.set_uniform_max_weight(0.05);
        pg.assign_all(&parts, 1);
        let before = pg.cut();
        // single-threaded: attributed-gain accounting is exact only
        // sequentially (the concurrent prefix revert uses apply-time
        // gains — see the module docs / DESIGN.md)
        let c = ctx(2, 1, 9);
        let g1 = lp_refine_graph(&pg, &c);
        let g2 = fm_refine_graph(&pg, &c);
        assert!(g1 + g2 > 0, "lp {g1} fm {g2}");
        assert_eq!(pg.cut(), before - g1 - g2, "attributed accounting");
        assert!(pg.is_balanced());
    }
}
