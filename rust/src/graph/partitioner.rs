//! Graph partitioning driver on the optimized plain-graph data
//! structures (paper §10): graph-native coarsening (heavy-edge clustering
//! on the single adjacency array, or the synchronous §11 clustering under
//! `ctx.deterministic`), initial partitioning through the hypergraph
//! portfolio on the (small) coarsest level's two-pin view, and
//! uncoarsening on the *shared* pooled
//! [`RefinementPipeline`](crate::refinement::RefinementPipeline) — the
//! same `rebalance → LP → (det-)FM → rebalance` stack the hypergraph
//! drivers run, instantiated over `PartitionedGraph`'s
//! [`TwoPinState`](crate::partition::TwoPinState) (on-the-fly two-pin
//! gains, no gain table, no pin-count/connectivity-set allocations). One
//! finest-level-sized partition allocation is rebound across all levels,
//! with the PR-7 degradation ladder, cancellation checkpoints and panic
//! isolation applying unchanged.

use super::{contraction as gcontract, Graph};
use crate::coordinator::context::Context;
use crate::datastructures::RatingMap;
use crate::initial;
use crate::parallel::parallel_chunks;
use crate::partition::PartitionedGraph;
use crate::refinement::RefinementPipeline;
use crate::util::rng::hash2;
use crate::util::Rng;
use crate::{BlockId, NodeId, NodeWeight};
use std::sync::atomic::{AtomicI64, AtomicU32, AtomicU8, Ordering};
use std::sync::Arc;

/// Multilevel graph partitioning (the §10 pipeline). Takes the graph by
/// `Arc` so binding the finest level costs a reference count, not a CSR
/// deep copy (the former `partition_graph(&g)` wrapper cloned the whole
/// adjacency structure per call).
pub fn partition_graph_arc(g: Arc<Graph>, ctx: &Context) -> PartitionedGraph {
    let timer = ctx.timer.clone();
    // standalone driver: arm the deadline for this run (no-op when unset)
    ctx.cancel.arm(ctx.time_limit);
    // ---- preprocessing: Louvain runs directly on the graph ----
    let communities = if ctx.use_community_detection {
        Some(timer.time("preprocessing", || {
            crate::preprocessing::louvain(
                &g,
                &crate::preprocessing::LouvainConfig {
                    threads: ctx.threads,
                    seed: ctx.seed,
                    max_rounds: ctx.louvain_max_rounds,
                    deterministic: ctx.deterministic,
                    ..Default::default()
                },
            )
        }))
    } else {
        None
    };

    // ---- coarsening on the graph data structure ----
    struct GLevel {
        coarse: Arc<Graph>,
        fine_to_coarse: Vec<NodeId>,
    }
    let limit = ctx.contraction_limit().max(2 * ctx.k);
    let cmax = ctx.max_cluster_weight(g.total_weight());
    let mut levels: Vec<GLevel> = Vec::new();
    let mut current = g.clone();
    let mut comms = communities;
    timer.time("coarsening", || {
        while current.num_nodes() > limit {
            // cancellation checkpoint at the pass boundary, as in the
            // hypergraph coarsener: a shorter hierarchy stays usable
            if ctx.cancel.is_expired() {
                ctx.cancel.note_early_stop();
                break;
            }
            let n_before = current.num_nodes();
            // the deterministic preset reuses the synchronous §11
            // clustering, which is generic over HypergraphOps and therefore
            // runs on the two-pin net view directly; graph contraction is
            // thread-count invariant given the clustering
            let rep = if ctx.deterministic {
                crate::coarsening::deterministic::cluster(
                    &*current,
                    ctx,
                    comms.as_deref(),
                    cmax,
                    limit,
                )
            } else {
                cluster_graph(&current, ctx, comms.as_deref(), cmax, limit)
            };
            let c = gcontract::contract(&current, &rep, ctx.threads);
            if n_before - c.coarse.num_nodes() <= (ctx.min_shrink * n_before as f64) as usize {
                break;
            }
            if let Some(cm) = &comms {
                let mut coarse = vec![0u32; c.coarse.num_nodes()];
                for u in 0..n_before {
                    coarse[c.fine_to_coarse[u] as usize] = cm[u];
                }
                comms = Some(coarse);
            }
            let coarse = Arc::new(c.coarse);
            levels.push(GLevel { coarse: coarse.clone(), fine_to_coarse: c.fine_to_coarse });
            current = coarse;
        }
    });

    // ---- initial partitioning via the hypergraph portfolio ----
    let parts: Vec<BlockId> = timer.time("initial_partitioning", || {
        let coarsest_hg = Arc::new(current.to_hypergraph());
        initial::initial_partition(coarsest_hg, ctx)
    });

    // ---- uncoarsening on the shared pooled pipeline ----
    // One finest-level-sized Workspace<TwoPinState> (endpoint-pair words
    // instead of Φ/Λ, empty gain table); each level rebinds the same
    // memory and runs the full refiner stack with the degradation ladder
    // and panic isolation of the hypergraph drivers.
    let mut pipe = RefinementPipeline::new_for_graph(ctx, &g);
    let coarsest: Arc<Graph> =
        levels.last().map(|l| l.coarse.clone()).unwrap_or_else(|| g.clone());
    let mut pg = pipe.bind(coarsest, &parts, ctx);
    pipe.refine_at_distance(&pg, ctx, levels.len());
    for i in (0..levels.len()).rev() {
        let finer = if i == 0 { g.clone() } else { levels[i - 1].coarse.clone() };
        pg = pipe.project_to_level(pg, finer, &levels[i].fine_to_coarse, None, ctx);
        // after projecting over levels[i] the partition lives at distance
        // i from the finest level (the uncoarsen() convention)
        pipe.refine_at_distance(&pg, ctx, i);
    }
    pg
}

// ---------------------------------------------------------------- coarsen

const G_UNCLUSTERED: u8 = 0;
const G_CLUSTERED: u8 = 2;

/// Heavy-edge clustering on the plain-graph structure (one adjacency
/// array ⇒ the cache-friendly path of Fig. 15). Protocol as in §4.1 but
/// with edge-weight ratings.
pub fn cluster_graph(
    g: &Graph,
    ctx: &Context,
    communities: Option<&[u32]>,
    cmax: NodeWeight,
    floor: usize,
) -> Vec<NodeId> {
    let n = g.num_nodes();
    let state: Vec<AtomicU8> = (0..n).map(|_| AtomicU8::new(G_UNCLUSTERED)).collect();
    let rep: Vec<AtomicU32> = (0..n as u32).map(AtomicU32::new).collect();
    let weight: Vec<AtomicI64> =
        (0..n).map(|u| AtomicI64::new(g.node_weight(u as NodeId))).collect();
    let remaining = AtomicI64::new(n as i64);
    let min_remaining = floor.max((n as f64 / ctx.shrink_limit) as usize) as i64;

    let mut order: Vec<u32> = (0..n as u32).collect();
    Rng::new(hash2(ctx.seed, n as u64 ^ 0x6a)).shuffle(&mut order);

    parallel_chunks(n, ctx.threads, |_, s, e| {
        let mut map = RatingMap::new(4096);
        for &u in &order[s..e] {
            if remaining.load(Ordering::Acquire) <= min_remaining {
                break;
            }
            if state[u as usize].load(Ordering::Acquire) != G_UNCLUSTERED {
                continue;
            }
            // rating over neighbor clusters
            map.clear();
            let cu = communities.map(|c| c[u as usize]);
            for (v, w) in g.neighbors(u) {
                if v == u {
                    continue;
                }
                if let Some(cu) = cu {
                    if communities.unwrap()[v as usize] != cu {
                        continue;
                    }
                }
                if map.should_grow() {
                    map.grow();
                }
                map.add(rep[v as usize].load(Ordering::Relaxed) as u64, w as f64);
            }
            let wu = g.node_weight(u);
            let mut best: Option<(f64, u64, u32)> = None;
            for (root, rating, _) in map.iter() {
                if root == u as u64 || weight[root as usize].load(Ordering::Relaxed) + wu > cmax {
                    continue;
                }
                let tb = hash2(ctx.seed ^ u as u64, root);
                if best.map_or(true, |(br, bt, _)| {
                    rating > br + 1e-12 || ((rating - br).abs() <= 1e-12 && tb > bt)
                }) {
                    best = Some((rating, tb, root as u32));
                }
            }
            let Some((_, _, v)) = best else { continue };
            // simplified join: lock u via CAS, then adopt v's root if v is
            // stable; cycles resolved by retrying on the (rare) conflict
            if state[u as usize]
                .compare_exchange(G_UNCLUSTERED, G_CLUSTERED, Ordering::AcqRel, Ordering::Acquire)
                .is_err()
            {
                continue;
            }
            let root = rep[v as usize].load(Ordering::Acquire);
            if weight[root as usize].fetch_add(wu, Ordering::AcqRel) + wu > cmax {
                weight[root as usize].fetch_sub(wu, Ordering::AcqRel);
                state[u as usize].store(G_UNCLUSTERED, Ordering::Release);
                continue;
            }
            rep[u as usize].store(root, Ordering::Release);
            state[root as usize].store(G_CLUSTERED, Ordering::Release);
            remaining.fetch_sub(1, Ordering::AcqRel);
        }
    });

    // flatten chains (a root may have joined elsewhere before freezing)
    let mut out: Vec<NodeId> = rep.iter().map(|r| r.load(Ordering::Relaxed)).collect();
    for u in 0..n {
        let mut r = out[u] as usize;
        let mut hops = 0;
        while out[r] as usize != r && hops < n {
            r = out[r] as usize;
            hops += 1;
        }
        out[u] = r as NodeId;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::{Context, Preset};
    use crate::generators::{mesh_graph, rmat_graph};
    use crate::metrics;

    /// Thread count for the graph-driver tests, overridable via
    /// `MTKH_TEST_THREADS` (CI runs this suite at 4 threads too).
    fn test_threads(default: usize) -> usize {
        std::env::var("MTKH_TEST_THREADS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
            .max(1)
    }

    fn ctx(k: usize, threads: usize, seed: u64) -> Context {
        let mut c = Context::new(Preset::Default, k, 0.03)
            .with_threads(test_threads(threads))
            .with_seed(seed);
        c.contraction_limit_factor = 24;
        c.ip_min_repetitions = 2;
        c.ip_max_repetitions = 3;
        c.fm_max_rounds = 3;
        c
    }

    #[test]
    fn graph_pipeline_on_mesh() {
        let g = Arc::new(mesh_graph(24, 24));
        let pg = partition_graph_arc(g.clone(), &ctx(4, 2, 3));
        assert!(pg.is_balanced(), "imbalance {}", pg.imbalance());
        pg.verify_consistency().unwrap();
        // a 24×24 mesh split in 4 should cut far less than all edges
        let cut = pg.cut();
        assert!(cut < g.num_edges() as i64 / 4, "cut {cut}");
        // sanity vs from-scratch metric
        assert_eq!(cut, metrics::graph_cut(&g, &pg.parts()));
    }

    #[test]
    fn graph_pipeline_on_powerlaw() {
        let g = Arc::new(rmat_graph(9, 8, 5));
        let pg = partition_graph_arc(g, &ctx(2, 2, 5));
        assert!(pg.is_balanced());
        pg.verify_consistency().unwrap();
    }

    #[test]
    fn graph_clustering_respects_weight_limit() {
        let g = mesh_graph(16, 16);
        let rep = cluster_graph(&g, &ctx(2, 4, 1), None, 4, 8);
        let mut w = std::collections::HashMap::new();
        for u in 0..g.num_nodes() {
            assert_eq!(rep[rep[u] as usize], rep[u], "idempotent");
            *w.entry(rep[u]).or_insert(0i64) += 1;
        }
        assert!(w.values().all(|&c| c <= 4));
    }

    #[test]
    fn pipeline_improves_bad_partition_and_accounts_exactly() {
        let g = Arc::new(mesh_graph(16, 16));
        let n = g.num_nodes();
        // stripes: terrible cut for k=2 (but perfectly balanced)
        let parts: Vec<BlockId> = (0..n).map(|u| ((u / 16) % 2) as BlockId).collect();
        let c = ctx(2, 2, 9);
        let mut pipe = RefinementPipeline::new_for_graph(&c, &g);
        let pg = pipe.bind(g.clone(), &parts, &c);
        let before = pg.km1();
        let gain = pipe.refine(&pg, &c);
        assert!(gain > 0, "LP+FM must improve the stripes");
        // exact accounting even at 2 threads: the endpoint-pair CAS words
        // attribute every concurrent two-pin gain exactly (telescoping)
        assert_eq!(pg.km1(), before - gain, "attributed accounting");
        assert!(pg.is_balanced());
        pg.verify_consistency().unwrap();
        assert_eq!(pg.km1(), metrics::graph_cut(&g, &pg.parts()));
    }

    #[test]
    fn graph_uncoarsening_reuses_one_partition_allocation() {
        // the pooled-lifecycle invariant on the graph instantiation: one
        // structural allocation across bind + project_to_level
        let g = Arc::new(mesh_graph(16, 16));
        let c = ctx(2, 2, 7);
        let rep = cluster_graph(&g, &c, None, 8, 32);
        let lvl = gcontract::contract(&g, &rep, 2);
        let coarse = Arc::new(lvl.coarse);
        let parts: Vec<BlockId> =
            (0..coarse.num_nodes()).map(|u| (u % 2) as BlockId).collect();
        let mut pipe = RefinementPipeline::new_for_graph(&c, &g);
        let mut pg = pipe.bind(coarse, &parts, &c);
        pipe.refine_at_distance(&pg, &c, 1);
        pg = pipe.project_to_level(pg, g.clone(), &lvl.fine_to_coarse, None, &c);
        pipe.refine_at_distance(&pg, &c, 0);
        assert_eq!(pipe.partition_pool().structural_allocs(), 1);
        assert_eq!(pipe.partition_pool().rebinds(), 1);
        assert!(pg.is_balanced());
        pg.verify_consistency().unwrap();
    }

    #[test]
    fn graph_pipeline_uses_no_gain_table() {
        // USE_GAIN_TABLE = false for the two-pin state: the workspace
        // table has zero rows and FM runs on on-the-fly adjacency gains
        let g = Arc::new(mesh_graph(8, 8));
        let c = ctx(2, 1, 1);
        let pipe = RefinementPipeline::new_for_graph(&c, &g);
        assert_eq!(pipe.workspace().gain_table().node_capacity(), 0);
    }

    #[test]
    fn deterministic_graph_driver_thread_invariant() {
        // the Deterministic preset on the graph driver: bit-identical
        // results at 1/2/4 threads (det clustering + det-LP + det-FM)
        let g = Arc::new(mesh_graph(20, 20));
        let run = |threads: usize| {
            let mut c = Context::new(Preset::Deterministic, 3, 0.03)
                .with_threads(threads)
                .with_seed(11);
            c.contraction_limit_factor = 24;
            c.ip_min_repetitions = 2;
            c.ip_max_repetitions = 3;
            c.fm_max_rounds = 3;
            assert!(c.use_fm, "the Deterministic preset must run det-FM");
            let pg = partition_graph_arc(g.clone(), &c);
            pg.verify_consistency().unwrap();
            assert!(pg.is_balanced());
            (pg.km1(), pg.parts())
        };
        let r1 = run(1);
        let r2 = run(2);
        let r4 = run(4);
        assert_eq!(r1, r2, "t=1 vs t=2");
        assert_eq!(r2, r4, "t=2 vs t=4");
    }
}
