//! L3 ↔ L1/L2 bridge: loads the AOT artifacts (`artifacts/*.hlo.txt`,
//! produced once by `make artifacts`) through the PJRT CPU client and
//! exposes them to the coordinator:
//!
//! * [`Runtime::gain_tiles`] — the dense gain-tile oracle (L1 Pallas
//!   kernel): pin counts Φ, benefit and penalty terms for a packed
//!   incidence tile,
//! * [`Runtime::spectral`] / [`spectral_bipartition`] — the L2 spectral
//!   bipartitioner used as an additional initial-partitioning portfolio
//!   member.
//!
//! Python is never on this path: the artifacts are plain HLO text and
//! execution goes through `PjRtClient::cpu()`.
//!
//! The PJRT client comes from the external `xla` crate, which is not
//! available in the offline registry this build targets. The whole
//! execution path is therefore gated behind the `xla-runtime` feature;
//! without it [`global`] reports the runtime as unavailable and every
//! caller falls back to the pure-Rust implementations (the portfolio
//! simply skips the spectral member, tests skip the oracle checks).

use crate::hypergraph::Hypergraph;
use crate::util::error::Result;
use crate::{BlockId, NodeId, NodeWeight};
use std::path::PathBuf;
use std::sync::OnceLock;

#[cfg(feature = "xla-runtime")]
use crate::util::error::Context as _;
#[cfg(feature = "xla-runtime")]
use std::sync::Mutex;

/// Tile shape of the gain oracle (must match python/compile/kernels).
pub const TN: usize = 128;
pub const TV: usize = 128;
pub const K: usize = 16;
/// Spectral problem size (padded).
pub const SPECTRAL_N: usize = 256;

/// A loaded PJRT runtime with the compiled executables.
pub struct Runtime {
    // PjRt handles are not Sync; serialize access through a mutex.
    #[cfg(feature = "xla-runtime")]
    inner: Mutex<Inner>,
}

#[cfg(feature = "xla-runtime")]
struct Inner {
    _client: xla::PjRtClient,
    gain_exe: xla::PjRtLoadedExecutable,
    spectral_exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "xla-runtime")]
unsafe impl Send for Inner {}

static RUNTIME: OnceLock<Option<Runtime>> = OnceLock::new();

/// Locate the artifacts directory: `$MTKAHYPAR_ARTIFACTS` or `artifacts/`
/// relative to the workspace root / current directory.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("MTKAHYPAR_ARTIFACTS") {
        return PathBuf::from(p);
    }
    for candidate in ["artifacts", "../artifacts", "../../artifacts"] {
        let p = PathBuf::from(candidate);
        if p.join("gain_tiles.hlo.txt").exists() {
            return p;
        }
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Global runtime, initialized lazily; `None` when the artifacts are not
/// built or the crate was compiled without the `xla-runtime` feature.
pub fn global() -> Option<&'static Runtime> {
    RUNTIME
        .get_or_init(|| match Runtime::load(&artifacts_dir()) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("[runtime] AOT artifacts unavailable: {e}");
                None
            }
        })
        .as_ref()
}

impl Runtime {
    /// Load and compile both artifacts from `dir`.
    #[cfg(feature = "xla-runtime")]
    pub fn load(dir: &std::path::Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let load = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(name);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path utf8")?,
            )
            .with_context(|| format!("parse {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).with_context(|| format!("compile {name}"))
        };
        let gain_exe = load("gain_tiles.hlo.txt")?;
        let spectral_exe = load("spectral.hlo.txt")?;
        Ok(Runtime { inner: Mutex::new(Inner { _client: client, gain_exe, spectral_exe }) })
    }

    /// Without the `xla-runtime` feature no artifacts can be loaded.
    #[cfg(not(feature = "xla-runtime"))]
    pub fn load(_dir: &std::path::Path) -> Result<Self> {
        Err(crate::util::error::Error::msg(
            "compiled without the `xla-runtime` feature (offline build)",
        ))
    }

    /// Execute the gain-tile kernel: `a` is row-major `TN×TV` 0/1
    /// incidence, `w` the `TN` net weights, `x` the row-major `TV×K`
    /// one-hot assignment. Returns `(phi[TN·K], benefit[TV], penalty[TV·K])`.
    #[cfg(feature = "xla-runtime")]
    pub fn gain_tiles(
        &self,
        a: &[f32],
        w: &[f32],
        x: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        assert_eq!(a.len(), TN * TV);
        assert_eq!(w.len(), TN);
        assert_eq!(x.len(), TV * K);
        let inner = self.inner.lock().unwrap();
        let la = xla::Literal::vec1(a).reshape(&[TN as i64, TV as i64])?;
        let lw = xla::Literal::vec1(w);
        let lx = xla::Literal::vec1(x).reshape(&[TV as i64, K as i64])?;
        let result =
            inner.gain_exe.execute::<xla::Literal>(&[la, lw, lx])?[0][0].to_literal_sync()?;
        let (phi, benefit, penalty) = result.to_tuple3()?;
        Ok((phi.to_vec::<f32>()?, benefit.to_vec::<f32>()?, penalty.to_vec::<f32>()?))
    }

    /// Stub without the `xla-runtime` feature: unreachable in practice
    /// because [`global`] never hands out a `Runtime`, but keeps the call
    /// sites compiling.
    #[cfg(not(feature = "xla-runtime"))]
    pub fn gain_tiles(
        &self,
        a: &[f32],
        w: &[f32],
        x: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        assert_eq!(a.len(), TN * TV);
        assert_eq!(w.len(), TN);
        assert_eq!(x.len(), TV * K);
        Err(crate::util::error::Error::msg("xla-runtime feature disabled"))
    }

    /// Execute the spectral power iteration on a dense padded adjacency.
    #[cfg(feature = "xla-runtime")]
    pub fn spectral(&self, adj: &[f32], deg: &[f32]) -> Result<Vec<f32>> {
        assert_eq!(adj.len(), SPECTRAL_N * SPECTRAL_N);
        assert_eq!(deg.len(), SPECTRAL_N);
        let inner = self.inner.lock().unwrap();
        let la = xla::Literal::vec1(adj).reshape(&[SPECTRAL_N as i64, SPECTRAL_N as i64])?;
        let ld = xla::Literal::vec1(deg);
        let result =
            inner.spectral_exe.execute::<xla::Literal>(&[la, ld])?[0][0].to_literal_sync()?;
        let fiedler = result.to_tuple1()?;
        Ok(fiedler.to_vec::<f32>()?)
    }

    /// Stub without the `xla-runtime` feature (see [`Runtime::gain_tiles`]).
    #[cfg(not(feature = "xla-runtime"))]
    pub fn spectral(&self, adj: &[f32], deg: &[f32]) -> Result<Vec<f32>> {
        assert_eq!(adj.len(), SPECTRAL_N * SPECTRAL_N);
        assert_eq!(deg.len(), SPECTRAL_N);
        Err(crate::util::error::Error::msg("xla-runtime feature disabled"))
    }
}

/// Pack a hypergraph neighborhood into a dense gain tile and evaluate it
/// through the AOT kernel. `nodes` (≤ TV) and their incident `nets`
/// (≤ TN; larger neighborhoods are tiled by the caller) — returns
/// per-node benefit and per-(node, block) penalty, matching
/// `PartitionedHypergraph::gain` restricted to the tile's nets.
pub fn gain_tile_for(
    rt: &Runtime,
    hg: &Hypergraph,
    parts: &[BlockId],
    nodes: &[NodeId],
    nets: &[crate::EdgeId],
    k: usize,
) -> Result<(Vec<f32>, Vec<f32>)> {
    assert!(nodes.len() <= TV && nets.len() <= TN && k <= K);
    let mut a = vec![0f32; TN * TV];
    let mut w = vec![0f32; TN];
    let mut x = vec![0f32; TV * K];
    let mut node_slot = vec![usize::MAX; hg.num_nodes()];
    for (i, &u) in nodes.iter().enumerate() {
        node_slot[u as usize] = i;
        x[i * K + parts[u as usize] as usize] = 1.0;
    }
    for (j, &e) in nets.iter().enumerate() {
        w[j] = hg.net_weight(e) as f32;
        for &p in hg.pins(e) {
            let s = node_slot[p as usize];
            if s != usize::MAX {
                a[j * TV + s] = 1.0;
            }
        }
    }
    // park padding rows on the scratch block K−1 so Φ of real blocks is
    // unaffected (callers use k ≤ K−1 real blocks)
    for i in nodes.len()..TV {
        x[i * K + (K - 1)] = 1.0;
    }
    let (_phi, benefit, penalty) = rt.gain_tiles(&a, &w, &x)?;
    Ok((benefit, penalty))
}

/// Spectral bipartitioning portfolio member (paper §5 extension): bucket
/// to ≤ `SPECTRAL_N` nodes, build the dense clique-expansion adjacency,
/// run the AOT power iteration, and threshold the Fiedler vector under
/// the balance constraint. Returns `None` when the runtime is missing or
/// the constraint cannot be met.
pub fn spectral_bipartition(
    hg: &Hypergraph,
    max0: NodeWeight,
    max1: NodeWeight,
) -> Option<Vec<BlockId>> {
    let rt = global()?;
    let n = hg.num_nodes();
    if n < 4 {
        return None;
    }
    let buckets = n.min(SPECTRAL_N);
    let bucket_of = |u: usize| u * buckets / n;
    let mut adj = vec![0f32; SPECTRAL_N * SPECTRAL_N];
    for e in hg.nets() {
        let pins = hg.pins(e);
        if pins.len() < 2 || pins.len() > 64 {
            continue; // clique expansion of huge nets adds noise only
        }
        let wq = hg.net_weight(e) as f32 / (pins.len() - 1) as f32;
        for i in 0..pins.len() {
            for j in i + 1..pins.len() {
                let (a, b) = (bucket_of(pins[i] as usize), bucket_of(pins[j] as usize));
                if a != b {
                    adj[a * SPECTRAL_N + b] += wq;
                    adj[b * SPECTRAL_N + a] += wq;
                }
            }
        }
    }
    let deg: Vec<f32> = (0..SPECTRAL_N)
        .map(|i| adj[i * SPECTRAL_N..(i + 1) * SPECTRAL_N].iter().sum())
        .collect();
    let fiedler = rt.spectral(&adj, &deg).ok()?;

    // sweep the sorted Fiedler values to a balanced threshold
    let mut order: Vec<usize> = (0..buckets).collect();
    order.sort_by(|&a, &b| fiedler[a].partial_cmp(&fiedler[b]).unwrap());
    let mut bucket_weight = vec![0i64; buckets];
    for u in 0..n {
        bucket_weight[bucket_of(u)] += hg.node_weight(u as NodeId);
    }
    let total: i64 = hg.total_weight();
    let mut w0 = 0i64;
    let mut side0 = vec![false; buckets];
    for &b in &order {
        if w0 + bucket_weight[b] <= max0 {
            side0[b] = true;
            w0 += bucket_weight[b];
        }
        if total - w0 <= max1 && w0 * 2 >= total {
            break;
        }
    }
    if total - w0 > max1 {
        return None;
    }
    Some((0..n).map(|u| u32::from(!side0[bucket_of(u)])).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime_or_skip() -> Option<&'static Runtime> {
        let rt = global();
        if rt.is_none() {
            eprintln!("skipping runtime test: artifacts not built (run `make artifacts`)");
        }
        rt
    }

    #[test]
    fn gain_tiles_match_rust_gains() {
        let Some(rt) = runtime_or_skip() else { return };
        let hg = crate::generators::planted_hypergraph(
            &crate::generators::PlantedParams { n: 100, m: 120, blocks: 2, ..Default::default() },
            3,
        );
        let parts: Vec<BlockId> = (0..100).map(|u| (u % 2) as BlockId).collect();
        let phg =
            crate::partition::PartitionedHypergraph::new(std::sync::Arc::new(hg.clone()), 2);
        phg.assign_all(&parts, 1);
        // one tile over the first 100 nodes and nets fully inside them
        let nodes: Vec<NodeId> = (0..100u32).collect();
        let mut nets: Vec<crate::EdgeId> = Vec::new();
        let mut in_tile = crate::util::Bitset::new(hg.num_nets());
        for e in hg.nets() {
            if nets.len() < TN {
                nets.push(e);
                in_tile.set(e as usize);
            }
        }
        let (benefit, penalty) =
            gain_tile_for(rt, &hg, &parts, &nodes, &nets, 2).expect("oracle run");
        for (i, &u) in nodes.iter().enumerate() {
            let mut b = 0f32;
            let mut p = [0f32; 2];
            for &e in hg.incident_nets(u) {
                if !in_tile.get(e as usize) {
                    continue;
                }
                let w = hg.net_weight(e) as f32;
                if phg.pin_count(e, parts[u as usize]) == 1 {
                    b += w;
                }
                for (t, pt) in p.iter_mut().enumerate() {
                    if phg.pin_count(e, t as BlockId) == 0 {
                        *pt += w;
                    }
                }
            }
            assert_eq!(benefit[i], b, "benefit of node {u}");
            assert_eq!(penalty[i * K], p[0], "penalty({u},0)");
            assert_eq!(penalty[i * K + 1], p[1], "penalty({u},1)");
        }
    }

    #[test]
    fn spectral_bipartition_splits_planted() {
        if runtime_or_skip().is_none() {
            return;
        }
        let hg = crate::generators::planted_hypergraph(
            &crate::generators::PlantedParams {
                n: 300,
                m: 600,
                blocks: 2,
                p_intra: 0.95,
                ..Default::default()
            },
            5,
        );
        let max = (hg.total_weight() as f64 * 0.6) as i64;
        let parts = spectral_bipartition(&hg, max, max).expect("spectral result");
        let km1 = crate::metrics::km1(&hg, &parts, 2);
        assert!(
            km1 < hg.num_nets() as i64 / 3,
            "spectral quality: {km1} of {} nets",
            hg.num_nets()
        );
    }
}
