//! # mtkahypar — Scalable High-Quality Hypergraph Partitioning
//!
//! A shared-memory multilevel (hyper)graph partitioning framework
//! reproducing *"Scalable High-Quality Hypergraph Partitioning"*
//! (Gottesbüren, Heuer, Maas, Sanders, Schlag — 2023), built as the L3
//! (coordinator) layer of a Rust + JAX + Pallas three-layer stack.
//!
//! ## Architecture
//!
//! * **L3 (this crate)** — the full partitioning framework: parallel
//!   clustering-based coarsening guided by community detection, initial
//!   partitioning via work-stealing recursive bipartitioning over a
//!   portfolio of techniques, and three refinement algorithms (label
//!   propagation, parallel localized FM, parallel flow-based refinement),
//!   plus the n-level scheme, a deterministic mode (synchronous LP *and*
//!   FM, bit-identical for any thread count), and plain-graph
//!   data-structure specializations.
//! * **L2/L1 (build-time Python, `python/compile`)** — a spectral
//!   bipartitioner and a dense gain-tile Pallas kernel, AOT-lowered to HLO
//!   text and executed from [`runtime`] through the PJRT CPU client.
//!
//! `rust/ARCHITECTURE.md` is the contributor-facing map: the module
//! layout, the pooled-memory lifecycle (bind / rebind / park / unpark)
//! and the determinism guarantees, with pointers into the module docs
//! that carry the per-section paper-adaptation notes.
//!
//! ## Quickstart
//!
//! ```no_run
//! use mtkahypar::prelude::*;
//!
//! let hg = generators::planted_hypergraph(&PlantedParams::default(), 42);
//! let ctx = Context::new(Preset::Default, /*k=*/ 8, /*eps=*/ 0.03).with_seed(42);
//! let partition = partitioner::partition(&hg, &ctx);
//! println!("km1 = {}", partition.km1());
//! ```

pub mod benchkit;
pub mod coarsening;
pub mod coordinator;
pub mod datastructures;
pub mod generators;
pub mod graph;
pub mod hypergraph;
pub mod initial;
pub mod io;
pub mod metrics;
pub mod nlevel;
pub mod parallel;
pub mod partition;
pub mod preprocessing;
pub mod refinement;
pub mod repartition;
pub mod runtime;
pub mod util;

/// Node identifier (index into the node arrays of a hypergraph).
pub type NodeId = u32;
/// Hyperedge (net) identifier.
pub type EdgeId = u32;
/// Block identifier of a k-way partition.
pub type BlockId = u32;
/// Node weight `c(v)`.
pub type NodeWeight = i64;
/// Net weight `ω(e)`.
pub type EdgeWeight = i64;
/// Gain value (change in the objective; may be negative).
pub type Gain = i64;

/// Sentinel for "no block assigned".
pub const INVALID_BLOCK: BlockId = BlockId::MAX;
/// Sentinel node id.
pub const INVALID_NODE: NodeId = NodeId::MAX;
/// Sentinel edge id.
pub const INVALID_EDGE: EdgeId = EdgeId::MAX;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::coordinator::context::{Context, Preset};
    pub use crate::coordinator::partitioner;
    pub use crate::generators::{self, PlantedParams};
    pub use crate::graph::Graph;
    pub use crate::hypergraph::Hypergraph;
    pub use crate::metrics::Objective;
    pub use crate::partition::PartitionedHypergraph;
    pub use crate::repartition::{
        Change, ChangeBatch, MoveSet, RepartitionConfig, RepartitionSession, Repartitioner,
    };
    pub use crate::{BlockId, EdgeId, Gain, NodeId};
}
