//! Objective functions and from-scratch metric computation (paper §2).

use crate::graph::Graph;
use crate::hypergraph::Hypergraph;
use crate::BlockId;

/// Partitioning objective.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    /// cut-net metric f_c (edge cut for plain graphs)
    Cut,
    /// connectivity metric f_{λ−1}
    Km1,
    /// sum of external degrees f_s = f_{λ−1} + f_c
    Soed,
}

impl Objective {
    /// Short display name used by the coordinator report and the CLI.
    pub fn name(&self) -> &'static str {
        match self {
            Objective::Cut => "cut",
            Objective::Km1 => "km1",
            Objective::Soed => "soed",
        }
    }
}

/// Connectivity metric computed from scratch.
pub fn km1(hg: &Hypergraph, parts: &[BlockId], k: usize) -> i64 {
    let mut total = 0;
    let mut seen = vec![u32::MAX; k];
    for e in hg.nets() {
        let mut lambda = 0i64;
        for &p in hg.pins(e) {
            let b = parts[p as usize] as usize;
            if seen[b] != e {
                seen[b] = e;
                lambda += 1;
            }
        }
        total += (lambda - 1).max(0) * hg.net_weight(e);
    }
    total
}

/// Cut-net metric computed from scratch.
pub fn cut(hg: &Hypergraph, parts: &[BlockId]) -> i64 {
    let mut total = 0;
    for e in hg.nets() {
        let pins = hg.pins(e);
        if pins.is_empty() {
            continue;
        }
        let b0 = parts[pins[0] as usize];
        if pins.iter().any(|&p| parts[p as usize] != b0) {
            total += hg.net_weight(e);
        }
    }
    total
}

/// Sum of external degrees.
pub fn soed(hg: &Hypergraph, parts: &[BlockId], k: usize) -> i64 {
    km1(hg, parts, k) + cut(hg, parts)
}

/// Edge cut of a plain graph.
pub fn graph_cut(g: &Graph, parts: &[BlockId]) -> i64 {
    let mut total = 0;
    for u in g.nodes() {
        for (v, w) in g.neighbors(u) {
            if u < v && parts[u as usize] != parts[v as usize] {
                total += w;
            }
        }
    }
    total
}

/// Imbalance ε(Π) = max_b c(V_b)/⌈c(V)/k⌉ − 1.
///
/// Matches `PartitionedHypergraph::imbalance`: the ⌈c(V)/k⌉ reference is
/// the same one the `L_max` block-weight limits use, so `imbalance ≤ ε`
/// and the per-block limit check agree on totals not divisible by k.
pub fn imbalance(
    total_weight: i64,
    k: usize,
    block_weights: &[i64],
) -> f64 {
    let per = crate::partition::PartitionedHypergraph::reference_block_weight(total_weight, k);
    block_weights.iter().map(|&w| w as f64 / per - 1.0).fold(-1.0, f64::max)
}

/// Block weights of a partition over a hypergraph.
pub fn block_weights_hg(hg: &Hypergraph, parts: &[BlockId], k: usize) -> Vec<i64> {
    let mut bw = vec![0i64; k];
    for u in hg.nodes() {
        bw[parts[u as usize] as usize] += hg.node_weight(u);
    }
    bw
}

/// Block weights of a partition over a graph.
pub fn block_weights_graph(g: &Graph, parts: &[BlockId], k: usize) -> Vec<i64> {
    let mut bw = vec![0i64; k];
    for u in g.nodes() {
        bw[parts[u as usize] as usize] += g.node_weight(u);
    }
    bw
}

/// Objective value dispatcher.
pub fn objective_hg(obj: Objective, hg: &Hypergraph, parts: &[BlockId], k: usize) -> i64 {
    match obj {
        Objective::Cut => cut(hg, parts),
        Objective::Km1 => km1(hg, parts, k),
        Objective::Soed => soed(hg, parts, k),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Hypergraph {
        Hypergraph::from_nets(
            7,
            &[vec![0, 2], vec![0, 1, 3, 4], vec![3, 4, 6], vec![2, 5, 6]],
            None,
            None,
        )
    }

    #[test]
    fn matches_partition_structure() {
        let hg = std::sync::Arc::new(tiny());
        let parts: Vec<BlockId> = vec![0, 0, 0, 1, 1, 1, 1];
        let phg = crate::partition::PartitionedHypergraph::new(hg.clone(), 2);
        phg.assign_all(&parts, 1);
        assert_eq!(km1(&hg, &parts, 2), phg.km1());
        assert_eq!(cut(&hg, &parts), phg.cut());
        assert_eq!(soed(&hg, &parts, 2), phg.soed());
    }

    #[test]
    fn graph_cut_matches() {
        let g = Graph::from_edges(4, &[(0, 1, 2), (1, 2, 3), (2, 3, 4)], None);
        assert_eq!(graph_cut(&g, &[0, 0, 1, 1]), 3);
        assert_eq!(graph_cut(&g, &[0, 1, 0, 1]), 9);
    }

    #[test]
    fn imbalance_uniform() {
        assert!((imbalance(8, 2, &[4, 4])).abs() < 1e-9);
        assert!((imbalance(8, 2, &[6, 2]) - 0.5).abs() < 1e-9);
    }
}
