//! The hypergraph data structures (paper §2, §4.2, §9).
//!
//! Two representations share one read interface ([`HypergraphOps`]):
//!
//! * [`Hypergraph`] — the **static** CSR structure: the pin-lists of nets
//!   and the incident nets of nodes in two adjacency arrays, plus node/net
//!   weights. Multilevel coarsening produces new `Hypergraph` values via
//!   [`contraction::contract`]; recursive bipartitioning extracts induced
//!   subhypergraphs via [`subhypergraph::extract_block`].
//! * [`dynamic::DynamicHypergraph`] — the **dynamic** structure of the
//!   n-level scheme (paper §9): single-node contractions mutate the shared
//!   pin-lists in place (active-size markers) and record a [`dynamic::Memento`]
//!   on a stack; batch uncontractions revert the stack suffix at
//!   O(Σ|I(batch)|) cost instead of re-materializing a snapshot.
//!
//! The partition layer ([`crate::partition::PartitionedHypergraph`]) and
//! the localized refiners are generic over [`HypergraphOps`], so the same
//! move operation, gain machinery and LP/FM searches run unchanged on
//! either representation.

pub mod bipartite;
pub mod contraction;
pub mod dynamic;
pub mod subhypergraph;

use crate::{EdgeId, EdgeWeight, NodeId, NodeWeight};

/// Read-side interface shared by the static [`Hypergraph`] and the
/// n-level [`dynamic::DynamicHypergraph`].
///
/// The dynamic structure keeps one slot per *input* node for its whole
/// lifetime; contracted (inactive) slots report an empty incident-net
/// list, degree 0 and `is_active_node == false`, and never appear in any
/// pin list — so generic code that walks pins only ever reaches active
/// nodes, and code that iterates `nodes()` must either tolerate isolated
/// nodes (LP/FM/rebalance do: a node without nets is never a border node)
/// or skip inactive slots explicitly (weight accumulation does).
pub trait HypergraphOps: Send + Sync + Sized {
    /// The partition state this representation pairs with: the packed
    /// Φ/Λ machinery for hypergraphs, the derived two-pin state for plain
    /// graphs (see [`crate::partition::state`]).
    type State: crate::partition::state::StateOps<Self>;

    /// Number of node slots `n` (for the dynamic structure: input nodes,
    /// including inactive ones — all node-indexed state is sized by this).
    fn num_nodes(&self) -> usize;
    /// Number of nets `m`.
    fn num_nets(&self) -> usize;
    /// Number of (active) pins `p`.
    fn num_pins(&self) -> usize;
    /// Pins of net `e` (the active prefix for the dynamic structure).
    fn pins(&self, e: EdgeId) -> &[NodeId];
    /// Incident nets `I(u)` (empty for inactive dynamic slots).
    fn incident_nets(&self, u: NodeId) -> &[EdgeId];
    /// Node weight `c(u)` — for the dynamic structure the *current
    /// cluster* weight of an active representative.
    fn node_weight(&self, u: NodeId) -> NodeWeight;
    /// Net weight `ω(e)`.
    fn net_weight(&self, e: EdgeId) -> EdgeWeight;
    /// Total node weight `c(V)` (invariant under contraction).
    fn total_weight(&self) -> NodeWeight;
    /// Upper bound on `|e|` over the structure's lifetime (sizes packed
    /// pin-count storage; the dynamic structure reports the input bound).
    fn max_net_size(&self) -> usize;

    /// Net size `|e|`.
    #[inline]
    fn net_size(&self, e: EdgeId) -> usize {
        self.pins(e).len()
    }

    /// Upper bound on `|e|` over the structure's *lifetime* (sizes the
    /// sparse Φ/Λ slot arena so per-net regions survive n-level pin
    /// growth). Equals `net_size` for static structures; the dynamic
    /// structure reports the full slot-range size of the net.
    #[inline]
    fn net_pin_capacity(&self, e: EdgeId) -> usize {
        self.net_size(e)
    }

    /// Node degree `d(u) = |I(u)|`.
    #[inline]
    fn degree(&self, u: NodeId) -> usize {
        self.incident_nets(u).len()
    }

    /// Iterator over all node slots (including inactive dynamic slots).
    #[inline]
    fn nodes(&self) -> std::ops::Range<NodeId> {
        0..self.num_nodes() as NodeId
    }

    /// Iterator over all net ids.
    #[inline]
    fn nets(&self) -> std::ops::Range<EdgeId> {
        0..self.num_nets() as EdgeId
    }

    /// Is `u` a live node (always true for the static structure)?
    #[inline]
    fn is_active_node(&self, _u: NodeId) -> bool {
        true
    }

    /// Number of live nodes (`num_nodes` for the static structure).
    #[inline]
    fn num_active_nodes(&self) -> usize {
        self.num_nodes()
    }
}

impl HypergraphOps for Hypergraph {
    type State = crate::partition::state::HgState;

    #[inline]
    fn num_nodes(&self) -> usize {
        Hypergraph::num_nodes(self)
    }
    #[inline]
    fn num_nets(&self) -> usize {
        Hypergraph::num_nets(self)
    }
    #[inline]
    fn num_pins(&self) -> usize {
        Hypergraph::num_pins(self)
    }
    #[inline]
    fn pins(&self, e: EdgeId) -> &[NodeId] {
        Hypergraph::pins(self, e)
    }
    #[inline]
    fn incident_nets(&self, u: NodeId) -> &[EdgeId] {
        Hypergraph::incident_nets(self, u)
    }
    #[inline]
    fn node_weight(&self, u: NodeId) -> NodeWeight {
        Hypergraph::node_weight(self, u)
    }
    #[inline]
    fn net_weight(&self, e: EdgeId) -> EdgeWeight {
        Hypergraph::net_weight(self, e)
    }
    #[inline]
    fn total_weight(&self) -> NodeWeight {
        Hypergraph::total_weight(self)
    }
    #[inline]
    fn max_net_size(&self) -> usize {
        Hypergraph::max_net_size(self)
    }
    #[inline]
    fn net_size(&self, e: EdgeId) -> usize {
        Hypergraph::net_size(self, e)
    }
    #[inline]
    fn degree(&self, u: NodeId) -> usize {
        Hypergraph::degree(self, u)
    }
}

/// A weighted hypergraph `H = (V, E, c, ω)` in CSR form.
#[derive(Clone, Debug, Default)]
pub struct Hypergraph {
    /// net e's pins are `pins[net_offsets[e]..net_offsets[e+1]]`
    pub(crate) net_offsets: Vec<u64>,
    pub(crate) pins: Vec<NodeId>,
    /// node u's incident nets are `incident_nets[node_offsets[u]..node_offsets[u+1]]`
    pub(crate) node_offsets: Vec<u64>,
    pub(crate) incident_nets: Vec<EdgeId>,
    pub(crate) node_weight: Vec<NodeWeight>,
    pub(crate) net_weight: Vec<EdgeWeight>,
    pub(crate) total_weight: NodeWeight,
}

impl Hypergraph {
    /// Number of nodes `n`.
    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.node_weight.len()
    }

    /// Number of nets `m`.
    #[inline]
    pub fn num_nets(&self) -> usize {
        self.net_weight.len()
    }

    /// Number of pins `p`.
    #[inline]
    pub fn num_pins(&self) -> usize {
        self.pins.len()
    }

    /// Pins of net `e`.
    #[inline]
    pub fn pins(&self, e: EdgeId) -> &[NodeId] {
        &self.pins[self.net_offsets[e as usize] as usize..self.net_offsets[e as usize + 1] as usize]
    }

    /// Incident nets `I(u)` of node `u`.
    #[inline]
    pub fn incident_nets(&self, u: NodeId) -> &[EdgeId] {
        &self.incident_nets
            [self.node_offsets[u as usize] as usize..self.node_offsets[u as usize + 1] as usize]
    }

    /// Net size `|e|`.
    #[inline]
    pub fn net_size(&self, e: EdgeId) -> usize {
        (self.net_offsets[e as usize + 1] - self.net_offsets[e as usize]) as usize
    }

    /// Node degree `d(u) = |I(u)|`.
    #[inline]
    pub fn degree(&self, u: NodeId) -> usize {
        (self.node_offsets[u as usize + 1] - self.node_offsets[u as usize]) as usize
    }

    #[inline]
    pub fn node_weight(&self, u: NodeId) -> NodeWeight {
        self.node_weight[u as usize]
    }

    #[inline]
    pub fn net_weight(&self, e: EdgeId) -> EdgeWeight {
        self.net_weight[e as usize]
    }

    /// Total node weight `c(V)`.
    #[inline]
    pub fn total_weight(&self) -> NodeWeight {
        self.total_weight
    }

    /// Iterator over all node ids.
    pub fn nodes(&self) -> impl Iterator<Item = NodeId> {
        0..self.num_nodes() as NodeId
    }

    /// Iterator over all net ids.
    pub fn nets(&self) -> impl Iterator<Item = EdgeId> {
        0..self.num_nets() as EdgeId
    }

    /// Maximum net size (0 for netless hypergraphs).
    pub fn max_net_size(&self) -> usize {
        (0..self.num_nets() as EdgeId).map(|e| self.net_size(e)).max().unwrap_or(0)
    }

    /// Build from explicit pin lists and weights.
    ///
    /// Nets with fewer than one pin are kept as given (callers sanitize);
    /// pins must be valid node ids `< num_nodes`.
    pub fn from_nets(
        num_nodes: usize,
        nets: &[Vec<NodeId>],
        node_weight: Option<Vec<NodeWeight>>,
        net_weight: Option<Vec<EdgeWeight>>,
    ) -> Self {
        let node_weight = node_weight.unwrap_or_else(|| vec![1; num_nodes]);
        assert_eq!(node_weight.len(), num_nodes);
        let net_weight = net_weight.unwrap_or_else(|| vec![1; nets.len()]);
        assert_eq!(net_weight.len(), nets.len());

        let mut net_offsets = Vec::with_capacity(nets.len() + 1);
        net_offsets.push(0u64);
        let mut pins = Vec::with_capacity(nets.iter().map(Vec::len).sum());
        for net in nets {
            for &p in net {
                debug_assert!((p as usize) < num_nodes, "pin out of range");
                pins.push(p);
            }
            net_offsets.push(pins.len() as u64);
        }

        let (node_offsets, incident_nets) = build_incidence(num_nodes, &net_offsets, &pins);
        let total_weight = node_weight.iter().sum();
        Hypergraph {
            net_offsets,
            pins,
            node_offsets,
            incident_nets,
            node_weight,
            net_weight,
            total_weight,
        }
    }

    /// Cheap structural sanity check (used by tests and debug assertions).
    pub fn validate(&self) -> Result<(), String> {
        if self.net_offsets.len() != self.num_nets() + 1 {
            return Err("net_offsets length".into());
        }
        if self.node_offsets.len() != self.num_nodes() + 1 {
            return Err("node_offsets length".into());
        }
        if *self.net_offsets.last().unwrap() as usize != self.pins.len() {
            return Err("net_offsets tail".into());
        }
        if *self.node_offsets.last().unwrap() as usize != self.incident_nets.len() {
            return Err("node_offsets tail".into());
        }
        if self.pins.len() != self.incident_nets.len() {
            return Err("pin count mismatch between the two CSRs".into());
        }
        for e in self.nets() {
            for &p in self.pins(e) {
                if p as usize >= self.num_nodes() {
                    return Err(format!("net {e} has out-of-range pin {p}"));
                }
                if !self.incident_nets(p).contains(&e) {
                    return Err(format!("incidence mismatch: node {p} misses net {e}"));
                }
            }
        }
        if self.total_weight != self.node_weight.iter().sum::<NodeWeight>() {
            return Err("total weight".into());
        }
        Ok(())
    }
}

/// Build the node→nets CSR from the nets→pins CSR (counting sort).
pub(crate) fn build_incidence(
    num_nodes: usize,
    net_offsets: &[u64],
    pins: &[NodeId],
) -> (Vec<u64>, Vec<EdgeId>) {
    let mut node_offsets = vec![0u64; num_nodes + 1];
    for &p in pins {
        node_offsets[p as usize + 1] += 1;
    }
    for i in 0..num_nodes {
        node_offsets[i + 1] += node_offsets[i];
    }
    let mut cursor = node_offsets.clone();
    let mut incident_nets = vec![0 as EdgeId; pins.len()];
    for e in 0..net_offsets.len() - 1 {
        for i in net_offsets[e] as usize..net_offsets[e + 1] as usize {
            let u = pins[i] as usize;
            incident_nets[cursor[u] as usize] = e as EdgeId;
            cursor[u] += 1;
        }
    }
    (node_offsets, incident_nets)
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn tiny() -> Hypergraph {
        // 7 nodes, 4 nets — the classic KaHyPar example topology
        Hypergraph::from_nets(
            7,
            &[vec![0, 2], vec![0, 1, 3, 4], vec![3, 4, 6], vec![2, 5, 6]],
            None,
            None,
        )
    }

    #[test]
    fn basic_accessors() {
        let hg = tiny();
        assert_eq!(hg.num_nodes(), 7);
        assert_eq!(hg.num_nets(), 4);
        assert_eq!(hg.num_pins(), 12);
        assert_eq!(hg.pins(1), &[0, 1, 3, 4]);
        assert_eq!(hg.net_size(1), 4);
        assert_eq!(hg.degree(0), 2);
        assert_eq!(hg.incident_nets(6), &[2, 3]);
        assert_eq!(hg.total_weight(), 7);
        assert_eq!(hg.max_net_size(), 4);
        hg.validate().unwrap();
    }

    #[test]
    fn weighted_build() {
        let hg = Hypergraph::from_nets(
            3,
            &[vec![0, 1], vec![1, 2]],
            Some(vec![5, 1, 2]),
            Some(vec![10, 20]),
        );
        assert_eq!(hg.total_weight(), 8);
        assert_eq!(hg.net_weight(1), 20);
        assert_eq!(hg.node_weight(0), 5);
        hg.validate().unwrap();
    }

    #[test]
    fn incidence_symmetry() {
        let hg = tiny();
        for u in hg.nodes() {
            for &e in hg.incident_nets(u) {
                assert!(hg.pins(e).contains(&u));
            }
        }
    }
}
