//! Induced subhypergraph extraction for recursive bipartitioning
//! (paper §2 and §5: after a bipartition `{V₁,V₂}`, extract `H[V₁]` and
//! `H[V₂]` and recurse on both in parallel).

use super::{build_incidence, Hypergraph};
use crate::partition::PartitionedHypergraph;
use crate::{BlockId, EdgeId, NodeId};

/// A subhypergraph plus the mapping back to the parent's node ids.
pub struct Subhypergraph {
    pub hg: Hypergraph,
    /// `sub_to_parent[u_sub] = u_parent`
    pub sub_to_parent: Vec<NodeId>,
}

/// Extract the subhypergraph induced by the nodes of `block`.
///
/// Nets are intersected with the block; intersections of size ≤ 1 are
/// dropped (they cannot become cut nets in the recursion).
pub fn extract_block(phg: &PartitionedHypergraph, block: BlockId) -> Subhypergraph {
    let hg = phg.hypergraph();
    let n = hg.num_nodes();
    let mut parent_to_sub = vec![crate::INVALID_NODE; n];
    let mut sub_to_parent = Vec::new();
    for u in hg.nodes() {
        if phg.block_of(u) == block {
            parent_to_sub[u as usize] = sub_to_parent.len() as NodeId;
            sub_to_parent.push(u);
        }
    }

    let mut net_offsets = vec![0u64];
    let mut pins: Vec<NodeId> = Vec::new();
    let mut net_weight = Vec::new();
    for e in hg.nets() {
        // only nets with at least 2 pins in the block survive
        if phg.pin_count(e, block) < 2 {
            continue;
        }
        let before = pins.len();
        for &p in hg.pins(e) {
            let s = parent_to_sub[p as usize];
            if s != crate::INVALID_NODE {
                pins.push(s);
            }
        }
        debug_assert!(pins.len() - before >= 2);
        net_offsets.push(pins.len() as u64);
        net_weight.push(hg.net_weight(e));
    }

    let node_weight: Vec<_> = sub_to_parent.iter().map(|&u| hg.node_weight(u)).collect();
    let total_weight = node_weight.iter().sum();
    let (node_offsets, incident_nets) =
        build_incidence(sub_to_parent.len(), &net_offsets, &pins);
    let sub = Hypergraph {
        net_offsets,
        pins,
        node_offsets,
        incident_nets,
        node_weight,
        net_weight,
        total_weight,
    };
    debug_assert!(sub.validate().is_ok());
    Subhypergraph { hg: sub, sub_to_parent }
}

/// Extract the subhypergraph induced by an explicit node set (used by flow
/// refinement's region construction, §8.2). Returns the subhypergraph,
/// the mapping, and for each surviving net its parent net id.
pub fn extract_node_set(hg: &Hypergraph, nodes: &[NodeId]) -> (Subhypergraph, Vec<EdgeId>) {
    let mut parent_to_sub = vec![crate::INVALID_NODE; hg.num_nodes()];
    for (i, &u) in nodes.iter().enumerate() {
        parent_to_sub[u as usize] = i as NodeId;
    }
    let mut seen = crate::util::Bitset::new(hg.num_nets());
    let mut net_offsets = vec![0u64];
    let mut pins: Vec<NodeId> = Vec::new();
    let mut net_weight = Vec::new();
    let mut parent_net = Vec::new();
    for &u in nodes {
        for &e in hg.incident_nets(u) {
            if seen.test_and_set(e as usize) {
                continue;
            }
            let before = pins.len();
            for &p in hg.pins(e) {
                let s = parent_to_sub[p as usize];
                if s != crate::INVALID_NODE {
                    pins.push(s);
                }
            }
            if pins.len() - before < 2 {
                pins.truncate(before);
                continue;
            }
            net_offsets.push(pins.len() as u64);
            net_weight.push(hg.net_weight(e));
            parent_net.push(e);
        }
    }
    let node_weight: Vec<_> = nodes.iter().map(|&u| hg.node_weight(u)).collect();
    let total_weight = node_weight.iter().sum();
    let (node_offsets, incident_nets) = build_incidence(nodes.len(), &net_offsets, &pins);
    let sub = Hypergraph {
        net_offsets,
        pins,
        node_offsets,
        incident_nets,
        node_weight,
        net_weight,
        total_weight,
    };
    (Subhypergraph { hg: sub, sub_to_parent: nodes.to_vec() }, parent_net)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extract_node_set_basic() {
        let hg = Hypergraph::from_nets(
            6,
            &[vec![0, 1, 2], vec![2, 3], vec![3, 4, 5], vec![0, 5]],
            None,
            None,
        );
        let (sub, parents) = extract_node_set(&hg, &[1, 2, 3]);
        // surviving nets: {0,1,2}∩ = {1,2}, {2,3}∩ = {2,3}; others ≤1 pin
        assert_eq!(sub.hg.num_nodes(), 3);
        assert_eq!(sub.hg.num_nets(), 2);
        assert_eq!(parents.len(), 2);
        assert!(parents.contains(&0) && parents.contains(&1));
        assert_eq!(sub.sub_to_parent, vec![1, 2, 3]);
        sub.hg.validate().unwrap();
    }
}
