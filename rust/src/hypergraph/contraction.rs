//! Parallel hypergraph contraction (paper §4.2).
//!
//! Contracts a clustering `rep: V → V` (each node points at its cluster
//! representative; representatives point at themselves). Steps, all
//! parallelizable and implemented with the primitives in [`crate::parallel`]:
//!
//! 1. remap representative ids to a consecutive coarse range (prefix sum),
//! 2. aggregate coarse node weights (atomic fetch-add),
//! 3. rewrite each net's pin list to coarse ids, deduplicate, drop
//!    single-pin nets,
//! 4. remove *identical nets* with the parallelized INRSRT scheme of
//!    Aykanat et al.: fingerprint `f(e) = Σ (v+1)²`, group nets by
//!    (fingerprint, size) via sorting, pairwise-compare within groups,
//!    aggregate weights at one representative,
//! 5. rebuild both CSRs via prefix sums.

use super::{build_incidence, Hypergraph};
use crate::parallel::{self, par_for_auto, parallel_prefix_sum, SharedSlice};
use crate::{EdgeId, EdgeWeight, NodeId, NodeWeight};
use std::sync::atomic::{AtomicI64, Ordering};

/// Result of a contraction: the coarse hypergraph plus the mapping from
/// fine node id to coarse node id (needed to project partitions back).
pub struct Contraction {
    pub coarse: Hypergraph,
    pub fine_to_coarse: Vec<NodeId>,
    /// Fine net id → coarse net id. `EdgeId::MAX` marks nets dropped
    /// during contraction (all pins in one cluster — uniform under any
    /// projected partition); an INRSRT duplicate maps to its surviving
    /// representative. Lets [`crate::partition::PartitionPool::rebind_level`]
    /// repair Φ/Λ per net across the level instead of rebuilding them.
    pub net_map: Vec<EdgeId>,
}

/// Net fingerprint — identical nets necessarily agree on it.
#[inline]
pub fn fingerprint(pins: &[NodeId]) -> u64 {
    pins.iter().map(|&v| {
        let x = v as u64 + 1;
        x.wrapping_mul(x)
    })
    .fold(0u64, |a, b| a.wrapping_add(b))
}

/// Contract the clustering `rep` (must satisfy `rep[rep[u]] == rep[u]`).
pub fn contract(hg: &Hypergraph, rep: &[NodeId], threads: usize) -> Contraction {
    let n = hg.num_nodes();
    assert_eq!(rep.len(), n);

    // ---- 1. remap representatives to consecutive coarse ids ----
    let mut is_rep = vec![0u64; n];
    par_for_auto(n, threads, {
        let is_rep = SharedSlice::new(&mut is_rep);
        move |u| {
            debug_assert_eq!(rep[rep[u] as usize], rep[u], "rep must be idempotent");
            if rep[u] as usize == u {
                // SAFETY: one writer per index
                unsafe { is_rep.write(u, 1) };
            }
        }
    });
    let coarse_n = parallel_prefix_sum(&mut is_rep, threads) as usize;
    let coarse_id = is_rep; // after scan: coarse_id[u] = id if u is rep

    let mut fine_to_coarse = vec![0 as NodeId; n];
    par_for_auto(n, threads, {
        let f2c = SharedSlice::new(&mut fine_to_coarse);
        let coarse_id = &coarse_id;
        move |u| unsafe { f2c.write(u, coarse_id[rep[u] as usize] as NodeId) }
    });

    // ---- 2. coarse node weights ----
    let weights: Vec<AtomicI64> = (0..coarse_n).map(|_| AtomicI64::new(0)).collect();
    par_for_auto(n, threads, |u| {
        weights[fine_to_coarse[u] as usize]
            .fetch_add(hg.node_weight(u as NodeId), Ordering::Relaxed);
    });
    let coarse_weights: Vec<NodeWeight> =
        weights.into_iter().map(|w| w.into_inner()).collect();

    // ---- 3. rewrite pin lists to coarse ids; dedup; drop |e| <= 1 ----
    let m = hg.num_nets();
    let mut coarse_nets: Vec<Option<Vec<NodeId>>> = vec![None; m];
    par_for_auto(m, threads, {
        let slots = SharedSlice::new(&mut coarse_nets);
        let f2c = &fine_to_coarse;
        move |e| {
            let mut list: Vec<NodeId> =
                hg.pins(e as crate::EdgeId).iter().map(|&p| f2c[p as usize]).collect();
            list.sort_unstable();
            list.dedup();
            if list.len() > 1 {
                unsafe { slots.write(e, Some(list)) };
            }
        }
    });

    // ---- 4. identical net removal (INRSRT) ----
    // entries: (fingerprint, size, original net id)
    let mut entries: Vec<(u64, u32, u32)> = coarse_nets
        .iter()
        .enumerate()
        .filter_map(|(e, net)| {
            net.as_ref().map(|list| (fingerprint(list), list.len() as u32, e as u32))
        })
        .collect();
    parallel::par_sort_by_key(&mut entries, threads, |&(f, s, e)| (f, s, e));

    // Within each (fingerprint, size) group compare pairwise; keep one
    // representative and add up the weights of its duplicates.
    let mut keep: Vec<(u32, EdgeWeight)> = Vec::with_capacity(entries.len());
    let mut dups: Vec<(u32, u32)> = Vec::new(); // (duplicate, representative)
    let mut g = 0usize;
    while g < entries.len() {
        let mut h = g + 1;
        while h < entries.len() && entries[h].0 == entries[g].0 && entries[h].1 == entries[g].1 {
            h += 1;
        }
        if h - g == 1 {
            let e = entries[g].2;
            keep.push((e, hg.net_weight(e)));
        } else {
            // small group: pairwise identity detection
            let mut consumed = vec![false; h - g];
            for i in g..h {
                if consumed[i - g] {
                    continue;
                }
                let ei = entries[i].2;
                let mut w = hg.net_weight(ei);
                let pi = coarse_nets[ei as usize].as_ref().unwrap();
                for j in i + 1..h {
                    if consumed[j - g] {
                        continue;
                    }
                    let ej = entries[j].2;
                    if coarse_nets[ej as usize].as_ref().unwrap() == pi {
                        consumed[j - g] = true;
                        w += hg.net_weight(ej);
                        dups.push((ej, ei));
                    }
                }
                keep.push((ei, w));
            }
        }
        g = h;
    }
    // Deterministic output order: sort surviving nets by original id.
    parallel::par_sort_by_key(&mut keep, threads, |&(e, _)| e);

    // Fine→coarse net mapping for the cross-level Φ/Λ delta repair.
    let mut net_map = vec![EdgeId::MAX; m];
    for (new_id, &(e, _)) in keep.iter().enumerate() {
        net_map[e as usize] = new_id as EdgeId;
    }
    for &(dup, rep_e) in &dups {
        net_map[dup as usize] = net_map[rep_e as usize];
    }

    // ---- 5. build coarse CSRs ----
    let mut net_offsets = Vec::with_capacity(keep.len() + 1);
    net_offsets.push(0u64);
    let mut pins: Vec<NodeId> = Vec::new();
    let mut net_weight: Vec<EdgeWeight> = Vec::with_capacity(keep.len());
    for &(e, w) in &keep {
        let list = coarse_nets[e as usize].as_ref().unwrap();
        pins.extend_from_slice(list);
        net_offsets.push(pins.len() as u64);
        net_weight.push(w);
    }
    let (node_offsets, incident_nets) = build_incidence(coarse_n, &net_offsets, &pins);

    let coarse = Hypergraph {
        net_offsets,
        pins,
        node_offsets,
        incident_nets,
        node_weight: coarse_weights,
        net_weight,
        total_weight: hg.total_weight(),
    };
    debug_assert!(coarse.validate().is_ok());
    Contraction { coarse, fine_to_coarse, net_map }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Hypergraph {
        Hypergraph::from_nets(
            7,
            &[vec![0, 2], vec![0, 1, 3, 4], vec![3, 4, 6], vec![2, 5, 6]],
            None,
            None,
        )
    }

    #[test]
    fn identity_clustering_keeps_structure() {
        let hg = tiny();
        let rep: Vec<NodeId> = (0..7).collect();
        let c = contract(&hg, &rep, 2);
        assert_eq!(c.coarse.num_nodes(), 7);
        assert_eq!(c.coarse.num_nets(), 4);
        assert_eq!(c.coarse.num_pins(), 12);
        assert_eq!(c.coarse.total_weight(), 7);
    }

    #[test]
    fn merges_and_drops_single_pin_nets() {
        let hg = tiny();
        // cluster {0,1,3,4} -> rep 0; {2}; {5}; {6}
        let rep = vec![0, 0, 2, 0, 0, 5, 6];
        let c = contract(&hg, &rep, 2);
        // net {0,1,3,4} collapses to single pin -> dropped
        // net {0,2}, {3,4,6}->{0,6}, {2,5,6} survive
        assert_eq!(c.coarse.num_nodes(), 4);
        assert_eq!(c.coarse.num_nets(), 3);
        assert_eq!(c.coarse.total_weight(), 7);
        let cw: Vec<NodeWeight> =
            (0..4).map(|u| c.coarse.node_weight(u as NodeId)).collect();
        assert_eq!(cw.iter().sum::<NodeWeight>(), 7);
        assert!(cw.contains(&4)); // merged cluster weight
        c.coarse.validate().unwrap();
    }

    #[test]
    fn identical_nets_aggregate_weight() {
        // two nets become identical after contraction
        let hg = Hypergraph::from_nets(
            4,
            &[vec![0, 2], vec![1, 2], vec![0, 3], vec![1, 3]],
            None,
            Some(vec![1, 2, 3, 4]),
        );
        // merge 0 and 1 -> nets {01,2} appear twice (w 1+2), {01,3} twice (w 3+4)
        let rep = vec![0, 0, 2, 3];
        let c = contract(&hg, &rep, 1);
        assert_eq!(c.coarse.num_nets(), 2);
        let mut ws: Vec<EdgeWeight> =
            (0..2).map(|e| c.coarse.net_weight(e as crate::EdgeId)).collect();
        ws.sort_unstable();
        assert_eq!(ws, vec![3, 7]);
    }

    #[test]
    fn fingerprint_order_invariant() {
        assert_eq!(fingerprint(&[1, 5, 9]), fingerprint(&[9, 1, 5]));
        assert_ne!(fingerprint(&[1, 5, 9]), fingerprint(&[1, 5, 8]));
    }

    #[test]
    fn net_map_tracks_survivors_drops_and_duplicates() {
        let hg = tiny();
        // cluster {0,1,3,4} -> rep 0; {2}; {5}; {6}
        let rep = vec![0, 0, 2, 0, 0, 5, 6];
        let c = contract(&hg, &rep, 2);
        // net 1 = {0,1,3,4} collapses to a single cluster -> dropped
        assert_eq!(c.net_map[1], crate::EdgeId::MAX);
        // survivors map to consecutive coarse ids in original order
        assert_eq!(c.net_map[0], 0);
        assert_eq!(c.net_map[2], 1);
        assert_eq!(c.net_map[3], 2);
        // every non-MAX entry names a net with the matching coarse pins
        for (e, &ce) in c.net_map.iter().enumerate() {
            if ce == crate::EdgeId::MAX {
                continue;
            }
            let mut projected: Vec<NodeId> = hg
                .pins(e as crate::EdgeId)
                .iter()
                .map(|&p| c.fine_to_coarse[p as usize])
                .collect();
            projected.sort_unstable();
            projected.dedup();
            assert_eq!(c.coarse.pins(ce), &projected[..], "net {e}");
        }

        // duplicates point at their surviving representative
        let hg2 = Hypergraph::from_nets(
            4,
            &[vec![0, 2], vec![1, 2], vec![0, 3], vec![1, 3]],
            None,
            Some(vec![1, 2, 3, 4]),
        );
        let c2 = contract(&hg2, &vec![0, 0, 2, 3], 1);
        assert_eq!(c2.coarse.num_nets(), 2);
        assert_eq!(c2.net_map[0], c2.net_map[1], "identical nets share a coarse id");
        assert_eq!(c2.net_map[2], c2.net_map[3]);
        assert_ne!(c2.net_map[0], c2.net_map[2]);
        assert!(c2.net_map.iter().all(|&e| e != crate::EdgeId::MAX));
    }

    #[test]
    fn mapping_is_consistent() {
        let hg = tiny();
        let rep = vec![0, 0, 2, 3, 3, 5, 5];
        let c = contract(&hg, &rep, 4);
        for u in 0..7usize {
            assert_eq!(
                c.fine_to_coarse[u],
                c.fine_to_coarse[rep[u] as usize],
                "cluster members map together"
            );
        }
        let mut ids: Vec<NodeId> = c.fine_to_coarse.clone();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), c.coarse.num_nodes());
    }
}
