//! The dynamic hypergraph of the n-level scheme (paper §9, "The Dynamic
//! Hypergraph Data Structure"; see also arXiv:2104.08107 §4).
//!
//! The n-level algorithm contracts **one node at a time** and uncontracts
//! in **batches** during uncoarsening. Materializing a static snapshot per
//! batch costs O(n + m) each time; this structure instead mutates the two
//! incidence structures *in place* at O(Σ_{e ∈ I(v)} |e|) per contraction
//! and O(batch events) per batch uncontraction:
//!
//! * **Pin lists** are shared arrays with *active-size markers*: every net
//!   keeps its input-level capacity, and `active_pins[e]` marks the live
//!   prefix. `contract(v, u)` visits each net of `v`: if `u` is already a
//!   pin, `v`'s pin is swapped into the inactive suffix and the active
//!   size shrinks (a *removed* pin); otherwise `v`'s slot is overwritten
//!   with `u` (a *replaced* pin). Because uncontractions revert in LIFO
//!   order, the inactive suffix behaves like a stack: the exact slot/swap
//!   of every mutation is recorded as a `PinEvent` so the inverse
//!   restores the precise permutation, keeping all recorded slots of
//!   earlier events valid.
//! * **Incident-net lists** are per-node vectors. `contract(v, u)` appends
//!   `v`'s non-shared nets to `u`'s list and freezes `v`'s own list as the
//!   record of `I(v)` at contraction time; the uncontraction truncates
//!   `u`'s list back to its recorded prefix length — an in-place prefix
//!   restore, no copying.
//!
//! ## Memento lifecycle
//!
//! `contract(v, u)` returns a [`Memento`] referencing the contraction's
//! slice of the shared event stack. The n-level driver owns the memento
//! sequence; [`DynamicHypergraph::uncontract_batch`] reverts a suffix of
//! it (in reverse order) and leaves the events *above the stack cursor*
//! intact, so the partition layer can afterwards replay the batch against
//! Π/Φ/Λ: `PartitionedHypergraph::apply_uncontractions` assigns
//! Π(v) ← Π(u) and increments Φ(e, Π(u)) for exactly the nets whose pin
//! was *removed* (replaced pins swap `u → v` within the same block, which
//! leaves Φ unchanged). Block weights are invariant under uncontraction
//! (the cluster weight splits within one block), so the whole repair is
//! O(Σ|I(batch)|) — no `rebuild_from_parts`, no snapshot contraction.
//!
//! [`DynamicHypergraph::freeze`] renders the current coarse state as a
//! static [`Hypergraph`] (plus the coarse-id → slot mapping) so initial
//! partitioning keeps running on the static snapshot it expects.
//!
//! ## Online mutation
//!
//! Beyond the contraction/uncontraction cycle, the structure supports
//! *permanent* finest-level edits for incremental repartitioning
//! ([`crate::repartition`]): [`DynamicHypergraph::insert_node`],
//! [`DynamicHypergraph::remove_node`], [`DynamicHypergraph::insert_net`],
//! [`DynamicHypergraph::remove_net`] and
//! [`DynamicHypergraph::update_weight`]. These reuse the same in-place
//! active-prefix pin machinery as contraction but are not recorded as
//! events — they are irreversible, so they are only legal while no
//! contraction is outstanding (`event_cursor == 0`); each call clears the
//! stale event stack. Removed node and net slots go onto free lists and
//! are reused by later insertions, so bounded churn reaches a zero-growth
//! steady state (observable via [`DynamicHypergraph::structural_grows`]).

use super::{Hypergraph, HypergraphOps};
use crate::parallel::{par_for_auto, SharedSlice};
use crate::util::fxhash::FxHashMap;
use crate::{EdgeId, EdgeWeight, NodeId, NodeWeight};
use std::sync::atomic::{AtomicUsize, Ordering};

/// One pin-list mutation of a contraction, recorded for exact inversion.
#[derive(Clone, Copy, Debug)]
struct PinEvent {
    net: EdgeId,
    /// absolute slot in the shared pin array that was mutated
    slot: usize,
    /// true: `v` swapped into the inactive suffix (shared net);
    /// false: `v`'s slot overwritten with `u` (v-only net)
    removed: bool,
}

/// Record of one `contract(v, u)`: the pair plus the contraction's slice
/// of the event stack and the prefix length of `u`'s incident-net list.
#[derive(Clone, Copy, Debug)]
pub struct Memento {
    /// contracted node (inactive while the memento is applied)
    pub v: NodeId,
    /// representative `v` was merged into
    pub u: NodeId,
    events_start: usize,
    events_end: usize,
    u_incident_len: usize,
}

/// Static snapshot of the current coarse state (see
/// [`DynamicHypergraph::freeze`]).
pub struct FrozenSnapshot {
    /// the coarse hypergraph with consecutively renumbered nodes
    pub hg: Hypergraph,
    /// `to_dynamic[c]` = dynamic slot of coarse node `c`
    pub to_dynamic: Vec<NodeId>,
}

/// The dynamic hypergraph (paper §9): in-place single-node contractions
/// with a memento stack, reverted by in-place batch uncontractions.
pub struct DynamicHypergraph {
    /// net e's pin capacity is `net_offsets[e]..net_offsets[e+1]`
    net_offsets: Vec<u64>,
    /// shared pin array, mutated in place
    pins: Vec<NodeId>,
    /// live prefix length of each net's pin slice
    active_pins: Vec<u32>,
    net_weight: Vec<EdgeWeight>,
    /// per-slot incident nets: exact `I(u)` for active `u`, the frozen
    /// contraction-time `I(v)` for inactive `v`
    incident: Vec<Vec<EdgeId>>,
    /// current cluster weight for active slots, frozen for inactive ones
    node_weight: Vec<NodeWeight>,
    active: Vec<bool>,
    num_active: usize,
    num_active_pins: usize,
    total_weight: NodeWeight,
    /// input-level bound on |e| (sizes packed pin-count storage)
    max_net_capacity: usize,
    /// shared event stack; `event_cursor` is the live top (events above it
    /// belong to just-reverted mementos and stay readable until the next
    /// contraction)
    events: Vec<PinEvent>,
    event_cursor: usize,
    structural_grows: usize,
    /// node slots vacated by [`Self::remove_node`], reused by
    /// [`Self::insert_node`]
    free_nodes: Vec<NodeId>,
    /// net slots vacated by [`Self::remove_net`], reused by
    /// [`Self::insert_net`] when the slot capacity fits
    free_nets: Vec<EdgeId>,
}

impl DynamicHypergraph {
    /// Build the dynamic structure from a static hypergraph (every node
    /// active, every net at full size — the `Hypergraph →
    /// DynamicHypergraph` conversion of the n-level driver).
    pub fn from_hypergraph(hg: &Hypergraph) -> Self {
        let n = hg.num_nodes();
        let m = hg.num_nets();
        let incident: Vec<Vec<EdgeId>> =
            (0..n as NodeId).map(|u| hg.incident_nets(u).to_vec()).collect();
        DynamicHypergraph {
            net_offsets: hg.net_offsets.clone(),
            pins: hg.pins.clone(),
            active_pins: (0..m as EdgeId).map(|e| hg.net_size(e) as u32).collect(),
            net_weight: hg.net_weight.clone(),
            incident,
            node_weight: hg.node_weight.clone(),
            active: vec![true; n],
            num_active: n,
            num_active_pins: hg.num_pins(),
            total_weight: hg.total_weight(),
            max_net_capacity: hg.max_net_size(),
            events: Vec::new(),
            event_cursor: 0,
            structural_grows: 0,
            free_nodes: Vec::new(),
            free_nets: Vec::new(),
        }
    }

    /// Iterator over the active (live) node slots.
    pub fn active_nodes(&self) -> impl Iterator<Item = NodeId> + '_ {
        (0..self.active.len() as NodeId).filter(move |&u| self.active[u as usize])
    }

    /// How often the event stack or an incident-net list had to grow its
    /// allocation. Constant across `uncontract_batch` calls (the
    /// uncoarsening path performs zero structural allocations) and across
    /// re-contractions that fit the previously grown capacity.
    pub fn structural_grows(&self) -> usize {
        self.structural_grows
    }

    /// Pre-size the event stack. This is a head start, not an upper
    /// bound: replaced-pin events are re-recorded when a later
    /// contraction absorbs a net's current holder, so the total event
    /// count is Σ|I(v)| at contraction time and can exceed the input pin
    /// count — growth beyond the reservation is geometric and counted by
    /// [`Self::structural_grows`]. The n-level driver reserves one event
    /// per input pin, which covers typical hierarchies' first doubling.
    pub fn reserve_events(&mut self, extra: usize) {
        self.events.reserve(extra);
    }

    #[inline]
    fn push_event(&mut self, ev: PinEvent) {
        if self.events.len() == self.events.capacity() {
            self.structural_grows += 1;
        }
        self.events.push(ev);
    }

    /// Contract `v` onto `u` (both active, `v != u`): merge `v`'s pins and
    /// incident nets into `u` in place and record the memento. Cost
    /// O(Σ_{e ∈ I(v)} |e|) — each net of `v` is scanned once to locate
    /// `v`'s pin slot and detect whether `u` shares the net.
    pub fn contract(&mut self, v: NodeId, u: NodeId) -> Memento {
        assert_ne!(v, u, "cannot contract a node onto itself");
        debug_assert!(self.active[v as usize], "contracted node must be active");
        debug_assert!(self.active[u as usize], "representative must be active");
        // drop events of previously reverted mementos before recording
        self.events.truncate(self.event_cursor);
        let events_start = self.events.len();
        let u_incident_len = self.incident[u as usize].len();
        // take v's list to split the borrow; it is put back untouched as
        // the frozen I(v) record the uncontraction replays
        let v_nets = std::mem::take(&mut self.incident[v as usize]);
        for &e in &v_nets {
            let off = self.net_offsets[e as usize] as usize;
            let a = self.active_pins[e as usize] as usize;
            let mut v_slot = usize::MAX;
            let mut u_present = false;
            for (i, &p) in self.pins[off..off + a].iter().enumerate() {
                if p == v {
                    v_slot = off + i;
                    if u_present {
                        break;
                    }
                } else if p == u {
                    u_present = true;
                    if v_slot != usize::MAX {
                        break;
                    }
                }
            }
            debug_assert_ne!(v_slot, usize::MAX, "net {e} must contain pin {v}");
            if u_present {
                // shared net: swap v's pin into the inactive suffix
                self.pins.swap(v_slot, off + a - 1);
                self.active_pins[e as usize] = (a - 1) as u32;
                self.num_active_pins -= 1;
                self.push_event(PinEvent { net: e, slot: v_slot, removed: true });
            } else {
                // v-only net: the pin slot and the net pass to u
                self.pins[v_slot] = u;
                self.push_event(PinEvent { net: e, slot: v_slot, removed: false });
                let list = &mut self.incident[u as usize];
                if list.len() == list.capacity() {
                    self.structural_grows += 1;
                }
                list.push(e);
            }
        }
        self.incident[v as usize] = v_nets;
        self.node_weight[u as usize] += self.node_weight[v as usize];
        self.active[v as usize] = false;
        self.num_active -= 1;
        self.event_cursor = self.events.len();
        Memento { v, u, events_start, events_end: self.events.len(), u_incident_len }
    }

    /// Revert a suffix of the contraction sequence **in place**. `batch`
    /// must be the most recent still-applied mementos in their original
    /// contraction order; they are reverted back-to-front (LIFO). Cost
    /// O(batch events); performs zero allocations.
    ///
    /// The batch's events stay readable above the stack cursor afterwards
    /// so [`Self::reactivated_nets`] can drive the partition layer's
    /// incremental Φ/Λ repair.
    pub fn uncontract_batch(&mut self, batch: &[Memento]) {
        for m in batch.iter().rev() {
            debug_assert_eq!(
                self.event_cursor, m.events_end,
                "mementos must be reverted in LIFO order"
            );
            debug_assert!(!self.active[m.v as usize]);
            debug_assert!(self.active[m.u as usize]);
            for ev in self.events[m.events_start..m.events_end].iter().rev() {
                let off = self.net_offsets[ev.net as usize] as usize;
                if ev.removed {
                    // inverse of: swap(slot, off+a-1); active -= 1
                    let a = self.active_pins[ev.net as usize] as usize;
                    self.active_pins[ev.net as usize] = (a + 1) as u32;
                    self.pins.swap(ev.slot, off + a);
                    self.num_active_pins += 1;
                    debug_assert_eq!(self.pins[ev.slot], m.v);
                } else {
                    debug_assert_eq!(self.pins[ev.slot], m.u);
                    self.pins[ev.slot] = m.v;
                }
            }
            self.incident[m.u as usize].truncate(m.u_incident_len);
            self.node_weight[m.u as usize] -= self.node_weight[m.v as usize];
            self.active[m.v as usize] = true;
            self.num_active += 1;
            self.event_cursor = m.events_start;
        }
    }

    /// Parallel variant of [`Self::uncontract_batch`]: reverts the same
    /// suffix with the pin-list repairs of *distinct nets* running
    /// concurrently.
    ///
    /// The sequential replay reverts all events in global LIFO order; an
    /// event only touches its own net's pin region and active-size marker,
    /// so events of distinct nets commute and per-net reverse order is
    /// equivalent. Three phases:
    ///
    /// 1. group the batch's events by net (sequential, O(batch events)),
    /// 2. revert each net's event list back-to-front — net groups are
    ///    disjoint, so they run in parallel without synchronization,
    /// 3. per-memento O(1) bookkeeping (incident-list truncation, weights,
    ///    activation) sequentially in LIFO order.
    ///
    /// The batch boundary stays O(Σ|I(batch)|) total work; the result is
    /// bit-identical to `uncontract_batch` regardless of thread count.
    pub fn uncontract_batch_parallel(&mut self, batch: &[Memento], threads: usize) {
        if threads <= 1 || batch.len() <= 1 {
            self.uncontract_batch(batch);
            return;
        }
        let start = batch[0].events_start;
        debug_assert_eq!(
            self.event_cursor,
            batch[batch.len() - 1].events_end,
            "mementos must be the applied suffix"
        );

        // Phase 1: group events by net, keeping per-net stack order. The
        // tuple records everything phase 2 needs: the mutated slot, the
        // event kind and the contracted/representative pair.
        let mut groups: FxHashMap<EdgeId, Vec<(usize, bool, NodeId, NodeId)>> =
            FxHashMap::default();
        for m in batch {
            for ev in &self.events[m.events_start..m.events_end] {
                groups.entry(ev.net).or_default().push((ev.slot, ev.removed, m.v, m.u));
            }
        }
        let groups: Vec<(EdgeId, Vec<(usize, bool, NodeId, NodeId)>)> =
            groups.into_iter().collect();

        // Phase 2: disjoint per-net reverts in parallel.
        let restored = {
            let pins = SharedSlice::new(&mut self.pins);
            let active_pins = SharedSlice::new(&mut self.active_pins);
            let net_offsets = &self.net_offsets;
            let restored = AtomicUsize::new(0);
            par_for_auto(groups.len(), threads, |gi| {
                let (e, evs) = &groups[gi];
                let e = *e as usize;
                let off = net_offsets[e] as usize;
                let mut local = 0usize;
                for &(slot, removed, v, u) in evs.iter().rev() {
                    // SAFETY: this thread exclusively owns net e's pin
                    // region and active-size marker (groups are disjoint).
                    unsafe {
                        if removed {
                            // inverse of: swap(slot, off+a-1); active -= 1
                            let a = *active_pins.read(e) as usize;
                            active_pins.write(e, (a + 1) as u32);
                            let tail = *pins.read(off + a);
                            pins.write(off + a, *pins.read(slot));
                            pins.write(slot, tail);
                            debug_assert_eq!(*pins.read(slot), v);
                            local += 1;
                        } else {
                            debug_assert_eq!(*pins.read(slot), u);
                            pins.write(slot, v);
                        }
                    }
                }
                restored.fetch_add(local, Ordering::Relaxed);
            });
            restored.into_inner()
        };
        self.num_active_pins += restored;

        // Phase 3: O(1) bookkeeping per memento, LIFO like the sequential
        // path (repeated representatives truncate to shrinking prefixes).
        for m in batch.iter().rev() {
            debug_assert!(!self.active[m.v as usize]);
            self.incident[m.u as usize].truncate(m.u_incident_len);
            self.node_weight[m.u as usize] -= self.node_weight[m.v as usize];
            self.active[m.v as usize] = true;
            self.num_active += 1;
        }
        self.event_cursor = start;
    }

    /// The nets whose pin list regained `m.v` when `m` was uncontracted
    /// (*removed*-pin events): exactly the nets whose pin count Φ(e, Π(u))
    /// must be incremented by the partition repair. Valid after
    /// [`Self::uncontract_batch`] until the next contraction.
    pub fn reactivated_nets<'a>(&'a self, m: &Memento) -> impl Iterator<Item = EdgeId> + 'a {
        self.events[m.events_start..m.events_end]
            .iter()
            .filter(|ev| ev.removed)
            .map(|ev| ev.net)
    }

    /// Online mutations are permanent finest-level edits; they cannot
    /// coexist with applied contractions (no memento could revert across
    /// them). Errors unless every contraction has been uncontracted.
    fn check_mutable(&mut self) -> Result<(), String> {
        if self.event_cursor != 0 {
            return Err("online mutation with applied contractions outstanding".into());
        }
        // drop events of reverted mementos: their recorded slots become
        // stale the moment the structure is edited
        self.events.clear();
        Ok(())
    }

    /// Set the weight of an active node, returning the previous weight.
    pub fn update_weight(&mut self, u: NodeId, w: NodeWeight) -> Result<NodeWeight, String> {
        self.check_mutable()?;
        if (u as usize) >= self.active.len() || !self.active[u as usize] {
            return Err(format!("update_weight: node {u} is not active"));
        }
        if w <= 0 {
            return Err(format!("update_weight: weight must be positive, got {w}"));
        }
        let old = self.node_weight[u as usize];
        self.node_weight[u as usize] = w;
        self.total_weight += w - old;
        Ok(old)
    }

    /// Insert a new node of weight `w`, returning its id. Reuses a slot
    /// vacated by [`Self::remove_node`] when one is free (no allocation);
    /// otherwise appends a slot (counted by [`Self::structural_grows`]).
    pub fn insert_node(&mut self, w: NodeWeight) -> Result<NodeId, String> {
        self.check_mutable()?;
        if w <= 0 {
            return Err(format!("insert_node: weight must be positive, got {w}"));
        }
        let u = match self.free_nodes.pop() {
            Some(u) => {
                debug_assert!(!self.active[u as usize]);
                debug_assert!(self.incident[u as usize].is_empty());
                self.active[u as usize] = true;
                self.node_weight[u as usize] = w;
                u
            }
            None => {
                let u = self.active.len() as NodeId;
                self.active.push(true);
                self.node_weight.push(w);
                self.incident.push(Vec::new());
                self.structural_grows += 1;
                u
            }
        };
        self.num_active += 1;
        self.total_weight += w;
        Ok(u)
    }

    /// Remove an active node: its pin is swapped out of every incident
    /// net's live prefix (nets may legitimately shrink to one or zero
    /// pins) and the slot goes onto the free list for reuse. Cost
    /// O(Σ_{e ∈ I(u)} |e|); allocates nothing.
    pub fn remove_node(&mut self, u: NodeId) -> Result<(), String> {
        self.check_mutable()?;
        if (u as usize) >= self.active.len() || !self.active[u as usize] {
            return Err(format!("remove_node: node {u} is not active"));
        }
        let mut nets = std::mem::take(&mut self.incident[u as usize]);
        for &e in &nets {
            let off = self.net_offsets[e as usize] as usize;
            let a = self.active_pins[e as usize] as usize;
            let slot = self.pins[off..off + a]
                .iter()
                .position(|&p| p == u)
                .expect("incidence invariant: net must contain the pin");
            self.pins.swap(off + slot, off + a - 1);
            self.active_pins[e as usize] = (a - 1) as u32;
            self.num_active_pins -= 1;
        }
        nets.clear();
        self.incident[u as usize] = nets; // capacity retained for reuse
        self.active[u as usize] = false;
        self.num_active -= 1;
        self.total_weight -= self.node_weight[u as usize];
        self.free_nodes.push(u);
        Ok(())
    }

    /// Insert a net over `pins` (distinct active nodes; single-pin nets
    /// are allowed and simply never cut) with weight `w`, returning its
    /// id. Reuses a slot vacated by [`Self::remove_net`] whose pin
    /// capacity fits; otherwise appends to the shared pin array (counted
    /// by [`Self::structural_grows`]).
    pub fn insert_net(&mut self, new_pins: &[NodeId], w: EdgeWeight) -> Result<EdgeId, String> {
        self.check_mutable()?;
        if new_pins.is_empty() {
            return Err("insert_net: a net needs at least one pin".into());
        }
        if w <= 0 {
            return Err(format!("insert_net: weight must be positive, got {w}"));
        }
        for (i, &p) in new_pins.iter().enumerate() {
            if (p as usize) >= self.active.len() || !self.active[p as usize] {
                return Err(format!("insert_net: pin {p} is not an active node"));
            }
            if new_pins[..i].contains(&p) {
                return Err(format!("insert_net: duplicate pin {p}"));
            }
        }
        let reuse = self
            .free_nets
            .iter()
            .position(|&e| self.net_pin_capacity(e) >= new_pins.len());
        let e = match reuse {
            Some(i) => {
                let e = self.free_nets.swap_remove(i);
                let off = self.net_offsets[e as usize] as usize;
                self.pins[off..off + new_pins.len()].copy_from_slice(new_pins);
                self.active_pins[e as usize] = new_pins.len() as u32;
                self.net_weight[e as usize] = w;
                e
            }
            None => {
                let e = self.net_weight.len() as EdgeId;
                self.pins.extend_from_slice(new_pins);
                self.net_offsets.push(self.pins.len() as u64);
                self.active_pins.push(new_pins.len() as u32);
                self.net_weight.push(w);
                self.structural_grows += 1;
                e
            }
        };
        for &p in new_pins {
            let list = &mut self.incident[p as usize];
            if list.len() == list.capacity() {
                self.structural_grows += 1;
            }
            list.push(e);
        }
        self.num_active_pins += new_pins.len();
        self.max_net_capacity = self.max_net_capacity.max(new_pins.len());
        Ok(e)
    }

    /// Remove a net: it is deleted from every pin's incident list and the
    /// slot goes onto the free list for reuse by [`Self::insert_net`].
    /// Removing a net that earlier node removals already emptied is fine.
    /// Cost O(Σ_{p ∈ e} |I(p)|); allocates nothing.
    pub fn remove_net(&mut self, e: EdgeId) -> Result<(), String> {
        self.check_mutable()?;
        if (e as usize) >= self.net_weight.len() {
            return Err(format!("remove_net: net {e} does not exist"));
        }
        if self.free_nets.contains(&e) {
            return Err(format!("remove_net: net {e} was already removed"));
        }
        let off = self.net_offsets[e as usize] as usize;
        let a = self.active_pins[e as usize] as usize;
        for i in off..off + a {
            let p = self.pins[i] as usize;
            let pos = self.incident[p]
                .iter()
                .position(|&f| f == e)
                .expect("incidence invariant: pin must list the net");
            self.incident[p].swap_remove(pos);
        }
        self.num_active_pins -= a;
        self.active_pins[e as usize] = 0;
        self.free_nets.push(e);
        Ok(())
    }

    /// Render the current coarse state as a static [`Hypergraph`] with
    /// consecutive node ids (nets shrunk to ≤ 1 pin are dropped; identical
    /// nets are kept separate — the km1/cut metrics are unaffected). Used
    /// once, for initial partitioning on the coarsest state.
    pub fn freeze(&self) -> FrozenSnapshot {
        let n = self.active.len();
        let mut to_dynamic: Vec<NodeId> = Vec::with_capacity(self.num_active);
        let mut to_coarse: Vec<NodeId> = vec![crate::INVALID_NODE; n];
        for u in 0..n {
            if self.active[u] {
                to_coarse[u] = to_dynamic.len() as NodeId;
                to_dynamic.push(u as NodeId);
            }
        }
        let mut nets: Vec<Vec<NodeId>> = Vec::new();
        let mut net_w: Vec<EdgeWeight> = Vec::new();
        for e in 0..self.net_weight.len() as EdgeId {
            let pins = HypergraphOps::pins(self, e);
            if pins.len() < 2 {
                continue;
            }
            nets.push(pins.iter().map(|&p| to_coarse[p as usize]).collect());
            net_w.push(self.net_weight[e as usize]);
        }
        let node_w: Vec<NodeWeight> =
            to_dynamic.iter().map(|&u| self.node_weight[u as usize]).collect();
        let hg = Hypergraph::from_nets(to_dynamic.len(), &nets, Some(node_w), Some(net_w));
        FrozenSnapshot { hg, to_dynamic }
    }

    /// Structural sanity check over the active state (tests and debug
    /// assertions): incidence symmetry, distinct active pins, weight
    /// conservation and counter consistency.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.active.len();
        let mut active_weight: NodeWeight = 0;
        let mut seen_pins = 0usize;
        for u in 0..n as NodeId {
            if !self.active[u as usize] {
                continue;
            }
            active_weight += self.node_weight[u as usize];
            for &e in &self.incident[u as usize] {
                if !HypergraphOps::pins(self, e).contains(&u) {
                    return Err(format!("incidence mismatch: net {e} misses pin {u}"));
                }
            }
        }
        if active_weight != self.total_weight {
            return Err(format!(
                "active weight {active_weight} != total {}",
                self.total_weight
            ));
        }
        for e in 0..self.net_weight.len() as EdgeId {
            let pins = HypergraphOps::pins(self, e);
            seen_pins += pins.len();
            let mut sorted: Vec<NodeId> = pins.to_vec();
            sorted.sort_unstable();
            for w in sorted.windows(2) {
                if w[0] == w[1] {
                    return Err(format!("net {e} has duplicate active pin {}", w[0]));
                }
            }
            for &p in pins {
                if !self.active[p as usize] {
                    return Err(format!("net {e} has inactive pin {p}"));
                }
                if !self.incident[p as usize].contains(&e) {
                    return Err(format!("pin {p} of net {e} misses the net in I({p})"));
                }
            }
            let cap = (self.net_offsets[e as usize + 1] - self.net_offsets[e as usize]) as usize;
            if pins.len() > cap {
                return Err(format!("net {e} exceeds its pin capacity"));
            }
        }
        if seen_pins != self.num_active_pins {
            return Err(format!(
                "pin counter {} != recount {seen_pins}",
                self.num_active_pins
            ));
        }
        if self.active.iter().filter(|&&a| a).count() != self.num_active {
            return Err("active-node counter mismatch".into());
        }
        Ok(())
    }
}

impl HypergraphOps for DynamicHypergraph {
    type State = crate::partition::state::HgState;

    #[inline]
    fn num_nodes(&self) -> usize {
        self.active.len()
    }

    #[inline]
    fn num_nets(&self) -> usize {
        self.net_weight.len()
    }

    #[inline]
    fn num_pins(&self) -> usize {
        self.num_active_pins
    }

    #[inline]
    fn pins(&self, e: EdgeId) -> &[NodeId] {
        let off = self.net_offsets[e as usize] as usize;
        &self.pins[off..off + self.active_pins[e as usize] as usize]
    }

    #[inline]
    fn incident_nets(&self, u: NodeId) -> &[EdgeId] {
        if self.active[u as usize] {
            &self.incident[u as usize]
        } else {
            &[]
        }
    }

    #[inline]
    fn node_weight(&self, u: NodeId) -> NodeWeight {
        self.node_weight[u as usize]
    }

    #[inline]
    fn net_weight(&self, e: EdgeId) -> EdgeWeight {
        self.net_weight[e as usize]
    }

    #[inline]
    fn total_weight(&self) -> NodeWeight {
        self.total_weight
    }

    #[inline]
    fn max_net_size(&self) -> usize {
        self.max_net_capacity
    }

    #[inline]
    fn net_pin_capacity(&self, e: EdgeId) -> usize {
        // full slot-range size: pins regained by uncontraction must fit
        // the sparse state's per-net region for the structure's lifetime
        (self.net_offsets[e as usize + 1] - self.net_offsets[e as usize]) as usize
    }

    #[inline]
    fn is_active_node(&self, u: NodeId) -> bool {
        self.active[u as usize]
    }

    #[inline]
    fn num_active_nodes(&self) -> usize {
        self.num_active
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Hypergraph {
        // 7 nodes, 4 nets — the classic KaHyPar example topology
        Hypergraph::from_nets(
            7,
            &[vec![0, 2], vec![0, 1, 3, 4], vec![3, 4, 6], vec![2, 5, 6]],
            None,
            None,
        )
    }

    fn pin_set(d: &DynamicHypergraph, e: EdgeId) -> Vec<NodeId> {
        let mut p: Vec<NodeId> = HypergraphOps::pins(d, e).to_vec();
        p.sort_unstable();
        p
    }

    #[test]
    fn conversion_preserves_structure() {
        let hg = tiny();
        let d = DynamicHypergraph::from_hypergraph(&hg);
        assert_eq!(HypergraphOps::num_nodes(&d), 7);
        assert_eq!(HypergraphOps::num_nets(&d), 4);
        assert_eq!(HypergraphOps::num_pins(&d), 12);
        assert_eq!(d.num_active_nodes(), 7);
        assert_eq!(pin_set(&d, 1), vec![0, 1, 3, 4]);
        assert_eq!(HypergraphOps::total_weight(&d), 7);
        d.validate().unwrap();
    }

    #[test]
    fn contract_shared_and_exclusive_nets() {
        let hg = tiny();
        let mut d = DynamicHypergraph::from_hypergraph(&hg);
        // contract 4 onto 3: net1 {0,1,3,4} and net2 {3,4,6} are shared
        // (pin 4 removed), node 4 has no exclusive nets
        let m = d.contract(4, 3);
        assert_eq!(pin_set(&d, 1), vec![0, 1, 3]);
        assert_eq!(pin_set(&d, 2), vec![3, 6]);
        assert_eq!(HypergraphOps::node_weight(&d, 3), 2);
        assert!(!d.is_active_node(4));
        assert_eq!(d.num_active_nodes(), 6);
        assert_eq!(d.reactivated_nets(&m).count(), 2);
        d.validate().unwrap();

        // contract 3 onto 0: net1 shared (remove 3); net2 {3,6} exclusive
        // to 3 → pin replaced by 0
        let m2 = d.contract(3, 0);
        assert_eq!(pin_set(&d, 1), vec![0, 1]);
        assert_eq!(pin_set(&d, 2), vec![0, 6]);
        assert_eq!(HypergraphOps::node_weight(&d, 0), 3);
        assert_eq!(d.reactivated_nets(&m2).count(), 1);
        d.validate().unwrap();

        // revert both; the structure must be bit-equivalent to the input
        d.uncontract_batch(&[m, m2]);
        assert_eq!(d.num_active_nodes(), 7);
        for e in 0..4 {
            assert_eq!(pin_set(&d, e), {
                let mut p = hg.pins(e).to_vec();
                p.sort_unstable();
                p
            });
        }
        for u in 0..7 {
            assert_eq!(HypergraphOps::node_weight(&d, u), 1);
            let mut a: Vec<EdgeId> = HypergraphOps::incident_nets(&d, u).to_vec();
            a.sort_unstable();
            let mut b: Vec<EdgeId> = hg.incident_nets(u).to_vec();
            b.sort_unstable();
            assert_eq!(a, b);
        }
        d.validate().unwrap();
    }

    #[test]
    fn chained_contractions_revert_in_batches() {
        let hg = tiny();
        let mut d = DynamicHypergraph::from_hypergraph(&hg);
        let seq =
            vec![d.contract(1, 0), d.contract(4, 3), d.contract(3, 0), d.contract(6, 5)];
        assert_eq!(d.num_active_nodes(), 3);
        assert_eq!(HypergraphOps::node_weight(&d, 0), 4);
        d.validate().unwrap();
        // batch 1: revert the last two
        d.uncontract_batch(&seq[2..]);
        assert_eq!(d.num_active_nodes(), 5);
        assert_eq!(HypergraphOps::node_weight(&d, 0), 2);
        assert_eq!(HypergraphOps::node_weight(&d, 3), 2);
        d.validate().unwrap();
        // batch 2: back to the input
        d.uncontract_batch(&seq[..2]);
        assert_eq!(d.num_active_nodes(), 7);
        assert_eq!(HypergraphOps::num_pins(&d), 12);
        d.validate().unwrap();
    }

    #[test]
    fn uncontraction_allocates_nothing() {
        let hg = tiny();
        let mut d = DynamicHypergraph::from_hypergraph(&hg);
        d.reserve_events(16);
        let seq = vec![d.contract(1, 0), d.contract(4, 3), d.contract(3, 0)];
        let grows = d.structural_grows();
        d.uncontract_batch(&seq);
        assert_eq!(d.structural_grows(), grows, "uncontract must not allocate");
        // re-contracting the same sequence fits the retained capacity
        let mut d2_seq = Vec::new();
        for m in &seq {
            d2_seq.push(d.contract(m.v, m.u));
        }
        assert_eq!(d.structural_grows(), grows, "re-contraction reuses capacity");
        d.uncontract_batch(&d2_seq);
        d.validate().unwrap();
    }

    #[test]
    fn parallel_uncontract_matches_sequential() {
        // a larger random-ish instance so batches span many nets
        let mut nets = Vec::new();
        for i in 0..40u32 {
            let a = (i * 7) % 60;
            let b = (i * 13 + 3) % 60;
            let c = (i * 29 + 11) % 60;
            let d = (i * 31 + 17) % 60;
            let mut net = vec![a, b, c, d];
            net.sort_unstable();
            net.dedup();
            if net.len() >= 2 {
                nets.push(net);
            }
        }
        let hg = Hypergraph::from_nets(60, &nets, None, None);
        let contract_pairs: Vec<(NodeId, NodeId)> =
            (0..30).map(|i| (30 + i as NodeId, i as NodeId)).collect();

        let run = |parallel: usize| {
            let mut d = DynamicHypergraph::from_hypergraph(&hg);
            let seq: Vec<Memento> =
                contract_pairs.iter().map(|&(v, u)| d.contract(v, u)).collect();
            // revert in two batches
            if parallel > 1 {
                d.uncontract_batch_parallel(&seq[15..], parallel);
                d.uncontract_batch_parallel(&seq[..15], parallel);
            } else {
                d.uncontract_batch(&seq[15..]);
                d.uncontract_batch(&seq[..15]);
            }
            d.validate().unwrap();
            d
        };

        let a = run(1);
        let b = run(4);
        assert_eq!(a.num_active_nodes(), 60);
        assert_eq!(b.num_active_nodes(), 60);
        assert_eq!(a.pins, b.pins, "pin arrays must be bit-identical");
        assert_eq!(a.active_pins, b.active_pins);
        assert_eq!(a.num_active_pins, b.num_active_pins);
        for u in 0..60 {
            assert_eq!(a.incident[u], b.incident[u]);
            assert_eq!(a.node_weight[u], b.node_weight[u]);
        }
        // both match the original input
        for e in 0..HypergraphOps::num_nets(&a) as EdgeId {
            assert_eq!(pin_set(&a, e), {
                let mut p = hg.pins(e).to_vec();
                p.sort_unstable();
                p
            });
        }
    }

    #[test]
    fn freeze_matches_active_state() {
        let hg = tiny();
        let mut d = DynamicHypergraph::from_hypergraph(&hg);
        d.contract(1, 0);
        d.contract(4, 3);
        let snap = d.freeze();
        snap.hg.validate().unwrap();
        assert_eq!(snap.hg.num_nodes(), 5);
        assert_eq!(snap.hg.total_weight(), 7);
        assert_eq!(snap.to_dynamic.len(), 5);
        // every coarse node maps to an active slot with the same weight
        for (c, &u) in snap.to_dynamic.iter().enumerate() {
            assert!(d.is_active_node(u));
            assert_eq!(snap.hg.node_weight(c as NodeId), HypergraphOps::node_weight(&d, u));
        }
        // no single-pin nets survive the freeze
        for e in snap.hg.nets() {
            assert!(snap.hg.net_size(e) >= 2);
        }
    }

    #[test]
    fn online_mutations_keep_validate_green() {
        let hg = tiny();
        let mut d = DynamicHypergraph::from_hypergraph(&hg);

        let old = d.update_weight(5, 3).unwrap();
        assert_eq!(old, 1);
        assert_eq!(HypergraphOps::total_weight(&d), 9);
        d.validate().unwrap();

        let u = d.insert_node(2).unwrap();
        assert_eq!(u, 7);
        assert_eq!(d.num_active_nodes(), 8);
        assert_eq!(HypergraphOps::total_weight(&d), 11);
        d.validate().unwrap();

        let e = d.insert_net(&[u, 0, 5], 2).unwrap();
        assert_eq!(pin_set(&d, e), vec![0, 5, u]);
        assert!(HypergraphOps::incident_nets(&d, u).contains(&e));
        d.validate().unwrap();

        d.remove_net(e).unwrap();
        assert!(HypergraphOps::pins(&d, e).is_empty());
        assert!(!HypergraphOps::incident_nets(&d, 0).contains(&e));
        d.validate().unwrap();

        d.remove_node(u).unwrap();
        assert_eq!(d.num_active_nodes(), 7);
        assert_eq!(HypergraphOps::total_weight(&d), 9);
        d.validate().unwrap();
    }

    #[test]
    fn slot_reuse_reaches_zero_growth_steady_state() {
        let hg = tiny();
        let mut d = DynamicHypergraph::from_hypergraph(&hg);
        // first round grows: fresh node slot, fresh net slot
        let u = d.insert_node(1).unwrap();
        let e = d.insert_net(&[u, 0], 1).unwrap();
        d.remove_net(e).unwrap();
        d.remove_node(u).unwrap();
        let grows = d.structural_grows();
        // bounded churn afterwards reuses the vacated slots
        for _ in 0..5 {
            let u2 = d.insert_node(1).unwrap();
            assert_eq!(u2, u, "node slot must be reused");
            let e2 = d.insert_net(&[u2, 0], 1).unwrap();
            assert_eq!(e2, e, "net slot must be reused");
            d.remove_net(e2).unwrap();
            d.remove_node(u2).unwrap();
            d.validate().unwrap();
        }
        assert_eq!(d.structural_grows(), grows, "steady-state churn must not allocate");
    }

    #[test]
    fn removing_a_node_can_empty_a_net() {
        let hg = tiny();
        let mut d = DynamicHypergraph::from_hypergraph(&hg);
        // net0 = {0, 2}: removing both pins empties it
        d.remove_node(0).unwrap();
        assert_eq!(pin_set(&d, 0), vec![2]);
        d.validate().unwrap();
        d.remove_node(2).unwrap();
        assert!(HypergraphOps::pins(&d, 0).is_empty());
        d.validate().unwrap();
        // the emptied net contributes nothing and can still be removed
        d.remove_net(0).unwrap();
        d.validate().unwrap();
    }

    #[test]
    fn mutation_error_paths_leave_state_intact() {
        let hg = tiny();
        let mut d = DynamicHypergraph::from_hypergraph(&hg);
        assert!(d.update_weight(0, 0).is_err());
        assert!(d.update_weight(99, 1).is_err());
        assert!(d.insert_node(-1).is_err());
        assert!(d.insert_net(&[], 1).is_err());
        assert!(d.insert_net(&[0, 0], 1).is_err(), "duplicate pins");
        assert!(d.insert_net(&[0, 99], 1).is_err(), "inactive pin");
        assert!(d.insert_net(&[0, 1], 0).is_err(), "non-positive weight");
        assert!(d.remove_net(99).is_err());
        d.remove_node(6).unwrap();
        assert!(d.remove_node(6).is_err(), "double removal");
        assert!(d.insert_net(&[0, 6], 1).is_err(), "removed node as pin");
        d.validate().unwrap();

        // single-pin nets are allowed
        let e = d.insert_net(&[3], 1).unwrap();
        assert_eq!(pin_set(&d, e), vec![3]);
        d.validate().unwrap();
        d.remove_net(e).unwrap();
        assert!(d.remove_net(e).is_err(), "double removal of a net");
        d.validate().unwrap();
    }

    #[test]
    fn mutations_require_no_outstanding_contractions() {
        let hg = tiny();
        let mut d = DynamicHypergraph::from_hypergraph(&hg);
        let m = d.contract(4, 3);
        assert!(d.insert_node(1).is_err());
        assert!(d.remove_node(0).is_err());
        assert!(d.update_weight(0, 2).is_err());
        d.uncontract_batch(&[m]);
        // fully reverted: mutations become legal again
        let u = d.insert_node(1).unwrap();
        d.validate().unwrap();
        // and contraction still works after a mutation
        let m2 = d.contract(u, 0);
        d.validate().unwrap();
        d.uncontract_batch(&[m2]);
        d.validate().unwrap();
    }

    #[test]
    fn net_shrinks_to_single_pin_and_back() {
        // net0 {0,2}: contracting 2 onto 0 shrinks it to {0}
        let hg = tiny();
        let mut d = DynamicHypergraph::from_hypergraph(&hg);
        let m = d.contract(2, 0);
        assert_eq!(pin_set(&d, 0), vec![0]);
        // net3 {2,5,6} was exclusive to 2 → {0,5,6}
        assert_eq!(pin_set(&d, 3), vec![0, 5, 6]);
        d.validate().unwrap();
        d.uncontract_batch(&[m]);
        assert_eq!(pin_set(&d, 0), vec![0, 2]);
        assert_eq!(pin_set(&d, 3), vec![2, 5, 6]);
        d.validate().unwrap();
    }
}
