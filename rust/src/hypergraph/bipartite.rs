//! Bipartite graph ("star expansion") representation of a hypergraph
//! (paper §2 and §4.3): one vertex per node, one vertex per net, an edge
//! `{u, e}` for every pin. Community detection for community-aware
//! coarsening runs on this graph with the edge-weight model of
//! Heuer & Schlag: `w(u, e) = ω(e) · d(u) / |e|` — emphasizing
//! low-degree structure — here in its unit-weight instantiation
//! `w(u,e) = ω(e)/|e|` plus degree scaling handled by the Louvain volume.

use super::Hypergraph;
use crate::graph::Graph;

/// Build the weighted bipartite representation `G*(H)`.
///
/// Node ids: `0..n` are hypergraph nodes, `n..n+m` are net vertices.
/// Edge weights follow ω(e)/|e| (scaled ×|e| to stay integral would lose
/// the model, so `Graph` stores f64-scaled integer weights via a fixed
/// 2⁸ multiplier).
pub fn bipartite_graph(hg: &Hypergraph) -> Graph {
    const SCALE: i64 = 256;
    let n = hg.num_nodes();
    let m = hg.num_nets();
    let mut adj: Vec<Vec<(crate::NodeId, i64)>> = vec![Vec::new(); n + m];
    for e in hg.nets() {
        let sz = hg.net_size(e).max(1) as i64;
        let w = (hg.net_weight(e) * SCALE / sz).max(1);
        let ev = (n + e as usize) as crate::NodeId;
        for &p in hg.pins(e) {
            adj[p as usize].push((ev, w));
            adj[ev as usize].push((p, w));
        }
    }
    Graph::from_adjacency(&adj, None)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn star_expansion_shape() {
        let hg = Hypergraph::from_nets(4, &[vec![0, 1], vec![1, 2, 3]], None, None);
        let g = bipartite_graph(&hg);
        assert_eq!(g.num_nodes(), 4 + 2);
        assert_eq!(g.num_edges(), 2 * (2 + 3)); // directed edge count
        // node 1 connects to both net-vertices 4 and 5
        let nbrs: Vec<_> = g.neighbors(1).map(|(v, _)| v).collect();
        assert!(nbrs.contains(&4) && nbrs.contains(&5));
    }

    #[test]
    fn small_nets_weigh_more() {
        let hg = Hypergraph::from_nets(5, &[vec![0, 1], vec![0, 1, 2, 3, 4]], None, None);
        let g = bipartite_graph(&hg);
        let w_small = g.neighbors(0).find(|&(v, _)| v == 5).unwrap().1;
        let w_large = g.neighbors(0).find(|&(v, _)| v == 6).unwrap().1;
        assert!(w_small > w_large);
    }
}
