//! The initial partitioning phase (paper §5): parallel recursive
//! bipartitioning with work stealing, the adaptive imbalance ratio ε′
//! (Equation 1), and the portfolio of flat bipartitioners.

pub mod portfolio;

use crate::coordinator::context::Context;
use crate::hypergraph::{subhypergraph::extract_node_set, Hypergraph};
use crate::parallel::TaskPool;
use crate::{BlockId, NodeId, NodeWeight};
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

/// Adaptive imbalance ratio for bipartitioning a subhypergraph that will
/// be divided into `k'` final blocks (Equation 1, paper §5):
/// `ε' = ((1+ε)·(c(V)/k)·(k'/c(V')))^(1/⌈log₂ k'⌉) − 1`.
pub fn adaptive_epsilon(
    total_weight: NodeWeight,
    sub_weight: NodeWeight,
    k: usize,
    k_sub: usize,
    eps: f64,
) -> f64 {
    if k_sub <= 1 {
        return eps;
    }
    let levels = (k_sub as f64).log2().ceil().max(1.0);
    let base =
        (1.0 + eps) * (total_weight as f64 / k as f64) * (k_sub as f64 / sub_weight.max(1) as f64);
    (base.powf(1.0 / levels) - 1.0).max(0.0)
}

/// Compute an initial k-way partition of `hg` via parallel recursive
/// bipartitioning over a work-stealing task pool (paper §5).
pub fn initial_partition(hg: Arc<Hypergraph>, ctx: &Context) -> Vec<BlockId> {
    let n = hg.num_nodes();
    let result: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
    let total_weight = hg.total_weight();
    {
        let result = &result;
        let mut ctx2 = ctx.clone();
        ctx2.ip_original_k = ctx.k;
        let all_nodes: Vec<NodeId> = (0..n as NodeId).collect();
        TaskPool::run(ctx.threads, move |pool| {
            recurse(pool, hg, all_nodes, ctx2, total_weight, 0, result);
        });
    }
    result.iter().map(|b| b.load(Ordering::Relaxed)).collect()
}

/// One recursion step: bipartition the node set, then recurse on both
/// sides as independent pool tasks (dynamic load balancing, §5).
fn recurse<'s>(
    pool: &TaskPool<'s>,
    hg: Arc<Hypergraph>,
    nodes: Vec<NodeId>,
    ctx: Context,
    total_weight: NodeWeight,
    block_offset: u32,
    result: &'s [AtomicU32],
) {
    let k_sub = ctx.k;
    if k_sub <= 1 || nodes.len() <= 1 {
        for &u in &nodes {
            result[u as usize].store(block_offset, Ordering::Relaxed);
        }
        return;
    }
    // extract the induced subhypergraph of this recursion branch
    let (sub, _) = extract_node_set(&hg, &nodes);
    let sub_hg = Arc::new(sub.hg);
    let sub_to_parent = sub.sub_to_parent;

    // ε′-adapted side weight limits (Equation 1)
    let k0 = (k_sub + 1) / 2; // ⌈k'/2⌉ final blocks on side 0
    let k1 = k_sub / 2;
    let eps_prime =
        adaptive_epsilon(total_weight, sub_hg.total_weight(), ctx.k_original(), k_sub, ctx.epsilon);
    let per_final_block = sub_hg.total_weight() as f64 / k_sub as f64;
    let max0 = ((1.0 + eps_prime) * per_final_block * k0 as f64).floor() as NodeWeight;
    let max1 = ((1.0 + eps_prime) * per_final_block * k1 as f64).floor() as NodeWeight;

    let seed = crate::util::rng::hash2(ctx.seed ^ 0x1b17, block_offset as u64 ^ nodes.len() as u64);
    let bi = portfolio::best_bipartition(&sub_hg, max0, max1, &ctx, seed);

    let side0: Vec<NodeId> = (0..sub_hg.num_nodes())
        .filter(|&u| bi.parts[u] == 0)
        .map(|u| sub_to_parent[u])
        .collect();
    let side1: Vec<NodeId> = (0..sub_hg.num_nodes())
        .filter(|&u| bi.parts[u] == 1)
        .map(|u| sub_to_parent[u])
        .collect();

    // recurse in parallel (work stealing balances uneven sides)
    let mut ctx0 = ctx.clone();
    ctx0.k = k0;
    let mut ctx1 = ctx;
    ctx1.k = k1;
    let hg0 = hg.clone();
    pool.spawn(move |p| recurse(p, hg0, side0, ctx0, total_weight, block_offset, result));
    pool.spawn(move |p| {
        recurse(p, hg, side1, ctx1, total_weight, block_offset + k0 as u32, result)
    });
}

// The recursion halves ctx.k; the ε′ formula needs the *original* k.
// Stored once here to avoid threading another parameter everywhere.
impl Context {
    fn k_original(&self) -> usize {
        // contraction_limit_factor never changes during recursion, and
        // contraction_limit() = factor · original k at the top level; the
        // recursion overwrites `k` only. We conservatively reconstruct the
        // original k from the stored field set by the coordinator.
        self.ip_original_k.max(self.k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::{Context, Preset};
    use crate::generators::{planted_hypergraph, PlantedParams};
    use crate::metrics;

    fn ctx(k: usize, threads: usize) -> Context {
        let mut c = Context::new(Preset::Default, k, 0.03).with_threads(threads).with_seed(42);
        c.ip_original_k = k;
        c.ip_min_repetitions = 2;
        c.ip_max_repetitions = 4;
        c
    }

    #[test]
    fn adaptive_epsilon_tightens_with_depth() {
        // ε' for the first bipartition of a k=8 run is smaller than ε
        // would naively allow at the leaves
        let e_top = adaptive_epsilon(8000, 8000, 8, 8, 0.03);
        let e_leaf = adaptive_epsilon(8000, 2000, 8, 2, 0.03);
        assert!(e_top > 0.0 && e_top < 0.03);
        assert!(e_leaf >= e_top, "leaves get looser ε': {e_leaf} vs {e_top}");
    }

    #[test]
    fn produces_balanced_kway_partitions() {
        for k in [2usize, 4, 7] {
            for threads in [1, 4] {
                let hg = Arc::new(planted_hypergraph(
                    &PlantedParams { n: 280, m: 500, blocks: k, ..Default::default() },
                    13,
                ));
                let parts = initial_partition(hg.clone(), &ctx(k, threads));
                assert_eq!(parts.len(), 280);
                let bw = metrics::block_weights_hg(&hg, &parts, k);
                assert!(bw.iter().all(|&w| w > 0), "k={k} t={threads}: empty block {bw:?}");
                let imb = metrics::imbalance(hg.total_weight(), k, &bw);
                assert!(imb <= 0.03 + 1e-9, "k={k} t={threads}: imbalance {imb} {bw:?}");
            }
        }
    }

    #[test]
    fn recovers_planted_structure_reasonably() {
        let k = 4;
        let hg = Arc::new(planted_hypergraph(
            &PlantedParams { n: 400, m: 900, blocks: k, p_intra: 0.95, ..Default::default() },
            3,
        ));
        let parts = initial_partition(hg.clone(), &ctx(k, 2));
        let km1 = metrics::km1(&hg, &parts, k);
        // a random balanced 4-way partition cuts ~everything; planted
        // structure should keep most nets internal
        let total_nets = hg.num_nets() as i64;
        assert!(
            km1 < total_nets / 2,
            "IP quality: km1 {km1} on {total_nets} nets"
        );
    }
}
