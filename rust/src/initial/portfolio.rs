//! The portfolio of flat bipartitioning techniques (paper §5).
//!
//! Nine algorithms as in KaHyPar: random assignment, BFS growing, six
//! greedy-hypergraph-growing variants (three selection policies × two
//! gain functions), and label-propagation initial partitioning. Each is
//! run at least 5 and at most 20 times; after 5 runs an algorithm is
//! retired when `µ − 2σ` of its results exceeds the incumbent (the 95%
//! rule). Every bipartition is polished with sequential 2-way FM.

use crate::coordinator::context::Context;
use crate::datastructures::AddressablePQ;
use crate::hypergraph::Hypergraph;
use crate::partition::PartitionedHypergraph;
use crate::util::stats::RunningStats;
use crate::util::Rng;
use crate::{BlockId, Gain, NodeId, NodeWeight};
use std::collections::VecDeque;
use std::sync::Arc;

/// Identifiers of the nine portfolio members.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Technique {
    Random,
    Bfs,
    GreedyGlobalKm1,
    GreedyGlobalCut,
    GreedyRoundRobinKm1,
    GreedyRoundRobinCut,
    GreedySequentialKm1,
    GreedySequentialCut,
    LabelPropagation,
}

impl Technique {
    pub fn all() -> [Technique; 9] {
        [
            Technique::Random,
            Technique::Bfs,
            Technique::GreedyGlobalKm1,
            Technique::GreedyGlobalCut,
            Technique::GreedyRoundRobinKm1,
            Technique::GreedyRoundRobinCut,
            Technique::GreedySequentialKm1,
            Technique::GreedySequentialCut,
            Technique::LabelPropagation,
        ]
    }
}

/// Result of a portfolio run.
pub struct Bipartition {
    pub parts: Vec<BlockId>,
    pub km1: i64,
    /// value of the *configured* objective (`ctx.objective`); equals
    /// `km1` when partitioning under `Objective::Km1`
    pub objective: i64,
    pub imbalance: f64,
}

/// Bipartition `hg` with side weight limits `max0`/`max1` using the full
/// adaptive portfolio; returns the best result found.
pub fn best_bipartition(
    hg: &Arc<Hypergraph>,
    max0: NodeWeight,
    max1: NodeWeight,
    ctx: &Context,
    seed: u64,
) -> Bipartition {
    let mut best: Option<Bipartition> = None;
    let mut rng = Rng::new(seed);
    // AOT spectral bipartitioner (L2 artifact) as the extra member
    if ctx.use_spectral_ip {
        if let Some(parts) = crate::runtime::spectral_bipartition(hg, max0, max1) {
            let refined = polish(hg, parts, max0, max1, ctx, seed ^ 0x57ec);
            best = Some(refined);
        }
    }
    'techniques: for tech in Technique::all() {
        let mut stats = RunningStats::default();
        for rep in 0..ctx.ip_max_repetitions {
            // cancellation checkpoint, honored only once some candidate
            // exists — the portfolio must always produce a bipartition,
            // deadline or not
            if best.is_some() && ctx.cancel.is_expired() {
                ctx.cancel.note_early_stop();
                break 'techniques;
            }
            // 95%-rule retirement after the minimum repetitions
            if rep >= ctx.ip_min_repetitions {
                if let Some(b) = &best {
                    if stats.mean() - 2.0 * stats.stddev() > b.objective as f64 {
                        break;
                    }
                }
            }
            let run_seed = rng.next_u64();
            // candidate isolation: a failing technique run is dropped and
            // the portfolio carries on with the other candidates
            let candidate = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                crate::util::failpoints::fire(
                    crate::util::failpoints::IP_CANDIDATE,
                    &ctx.cancel,
                );
                let parts = run_technique(tech, hg, max0, max1, run_seed);
                // polish with sequential 2-way FM (paper §5)
                polish(hg, parts, max0, max1, ctx, run_seed)
            }));
            let refined = match candidate {
                Ok(r) => r,
                Err(_) => {
                    ctx.cancel.note_panic_recovered();
                    continue;
                }
            };
            stats.push(refined.objective as f64);
            let better = match &best {
                None => true,
                Some(b) => {
                    // prefer feasible, then configured objective, then balance
                    let bf = b.imbalance <= 0.0;
                    let rf = refined.imbalance <= 0.0;
                    (rf && !bf)
                        || (rf == bf
                            && (refined.objective < b.objective
                                || (refined.objective == b.objective
                                    && refined.imbalance < b.imbalance)))
                }
            };
            if better {
                best = Some(refined);
            }
        }
    }
    best.expect("portfolio always produces a bipartition")
}

/// Run one flat technique; result may be unbalanced (polish/FM fixes it
/// or the portfolio selection penalizes it).
pub fn run_technique(
    tech: Technique,
    hg: &Hypergraph,
    max0: NodeWeight,
    max1: NodeWeight,
    seed: u64,
) -> Vec<BlockId> {
    match tech {
        Technique::Random => random_assignment(hg, max0, seed),
        Technique::Bfs => bfs_growing(hg, max0, max1, seed),
        Technique::GreedyGlobalKm1 => greedy_growing(hg, max0, max1, seed, Policy::Global, true),
        Technique::GreedyGlobalCut => greedy_growing(hg, max0, max1, seed, Policy::Global, false),
        Technique::GreedyRoundRobinKm1 => {
            greedy_growing(hg, max0, max1, seed, Policy::RoundRobin, true)
        }
        Technique::GreedyRoundRobinCut => {
            greedy_growing(hg, max0, max1, seed, Policy::RoundRobin, false)
        }
        Technique::GreedySequentialKm1 => {
            greedy_growing(hg, max0, max1, seed, Policy::Sequential, true)
        }
        Technique::GreedySequentialCut => {
            greedy_growing(hg, max0, max1, seed, Policy::Sequential, false)
        }
        Technique::LabelPropagation => lp_ip(hg, max0, max1, seed),
    }
}

fn polish(
    hg: &Arc<Hypergraph>,
    parts: Vec<BlockId>,
    max0: NodeWeight,
    max1: NodeWeight,
    ctx: &Context,
    seed: u64,
) -> Bipartition {
    let mut phg = PartitionedHypergraph::new(hg.clone(), 2);
    phg.set_max_weights(vec![max0, max1]);
    phg.assign_all(&parts, 1);
    let mut fm_ctx = ctx.clone();
    fm_ctx.threads = 1;
    fm_ctx.seed = seed;
    fm_ctx.fm_max_rounds = 1;
    crate::refinement::fm::fm_refine(&phg, &fm_ctx);
    let km1 = phg.km1();
    let objective = phg.objective_value(ctx.objective);
    // imbalance relative to the *given* limits (≤ 0 means feasible)
    let over0 = phg.block_weight(0) - max0;
    let over1 = phg.block_weight(1) - max1;
    Bipartition {
        parts: phg.parts(),
        km1,
        objective,
        imbalance: over0.max(over1) as f64 / hg.total_weight() as f64,
    }
}

/// Random assignment: shuffle nodes, fill block 0 to ~half weight.
fn random_assignment(hg: &Hypergraph, max0: NodeWeight, seed: u64) -> Vec<BlockId> {
    let n = hg.num_nodes();
    let mut order: Vec<u32> = (0..n as u32).collect();
    Rng::new(seed).shuffle(&mut order);
    let target0 = (hg.total_weight() / 2).min(max0);
    let mut parts = vec![1 as BlockId; n];
    let mut w0 = 0;
    for &u in &order {
        if w0 + hg.node_weight(u) <= target0 {
            parts[u as usize] = 0;
            w0 += hg.node_weight(u);
        }
    }
    parts
}

/// BFS growing: grow block 0 from a random seed until half weight.
fn bfs_growing(hg: &Hypergraph, max0: NodeWeight, _max1: NodeWeight, seed: u64) -> Vec<BlockId> {
    let n = hg.num_nodes();
    let mut rng = Rng::new(seed);
    let start = rng.next_below(n.max(1)) as NodeId;
    let target0 = (hg.total_weight() / 2).min(max0);
    let mut parts = vec![1 as BlockId; n];
    let mut visited = vec![false; n];
    let mut q = VecDeque::new();
    visited[start as usize] = true;
    q.push_back(start);
    let mut w0 = 0;
    while w0 < target0 {
        let Some(u) = q.pop_front() else {
            // disconnected: jump to a fresh node
            match (0..n).find(|&v| !visited[v]) {
                Some(v) => {
                    visited[v] = true;
                    q.push_back(v as NodeId);
                    continue;
                }
                None => break,
            }
        };
        if w0 + hg.node_weight(u) > target0 {
            continue;
        }
        parts[u as usize] = 0;
        w0 += hg.node_weight(u);
        for &e in hg.incident_nets(u) {
            for &v in hg.pins(e) {
                if !visited[v as usize] {
                    visited[v as usize] = true;
                    q.push_back(v);
                }
            }
        }
    }
    parts
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum Policy {
    /// always take the global max-gain node
    Global,
    /// alternate between taking max-gain and BFS-order nodes
    RoundRobin,
    /// take nodes in discovery order (cheapest)
    Sequential,
}

/// Greedy hypergraph growing (paper §5 / KaHyPar's GHG family): grow
/// block 0 from a seed, selecting boundary nodes by gain.
fn greedy_growing(
    hg: &Hypergraph,
    max0: NodeWeight,
    _max1: NodeWeight,
    seed: u64,
    policy: Policy,
    km1_gain: bool,
) -> Vec<BlockId> {
    let n = hg.num_nodes();
    let mut rng = Rng::new(seed);
    let start = rng.next_below(n.max(1)) as NodeId;
    let target0 = (hg.total_weight() / 2).min(max0);
    let mut parts = vec![1 as BlockId; n];
    let mut in_queue = vec![false; n];
    let mut pq = AddressablePQ::new();
    let mut fifo: VecDeque<NodeId> = VecDeque::new();
    // pins already in block 0 per net (for gain evaluation)
    let mut pins0: Vec<u32> = vec![0; hg.num_nets()];

    let gain_of = |u: NodeId, pins0: &[u32], hg: &Hypergraph| -> Gain {
        let mut g = 0;
        for &e in hg.incident_nets(u) {
            let sz = hg.net_size(e) as u32;
            let p0 = pins0[e as usize];
            if km1_gain {
                // km1: moving u into block 0 uncuts e when u is the last
                // remaining block-1 pin; cuts it when it is the first
                if p0 + 1 == sz {
                    g += hg.net_weight(e);
                } else if p0 == 0 {
                    g -= hg.net_weight(e);
                }
            } else {
                // max-net (cut-style): prefer nets with many pins inside
                g += (p0 as i64 * hg.net_weight(e)) / sz as i64;
            }
        }
        g
    };

    let enqueue = |u: NodeId,
                       pq: &mut AddressablePQ,
                       fifo: &mut VecDeque<NodeId>,
                       in_queue: &mut [bool],
                       pins0: &[u32]| {
        if !in_queue[u as usize] {
            in_queue[u as usize] = true;
            pq.insert(u, gain_of(u, pins0, hg));
            fifo.push_back(u);
        }
    };
    enqueue(start, &mut pq, &mut fifo, &mut in_queue, &pins0);

    let mut w0 = 0;
    let mut step = 0usize;
    while w0 < target0 {
        let next = match policy {
            Policy::Global => pq.pop_max().map(|(u, _)| u),
            Policy::RoundRobin => {
                step += 1;
                if step % 2 == 0 {
                    pq.pop_max().map(|(u, _)| u)
                } else {
                    fifo.pop_front()
                }
            }
            Policy::Sequential => fifo.pop_front(),
        };
        let Some(u) = next else {
            // disconnected: restart from an unvisited node
            match (0..n).find(|&v| parts[v] == 1 && !in_queue[v]) {
                Some(v) => {
                    enqueue(v as NodeId, &mut pq, &mut fifo, &mut in_queue, &pins0);
                    continue;
                }
                None => break,
            }
        };
        if parts[u as usize] == 0 {
            continue; // already assigned via the other queue
        }
        if w0 + hg.node_weight(u) > target0 {
            continue;
        }
        parts[u as usize] = 0;
        w0 += hg.node_weight(u);
        for &e in hg.incident_nets(u) {
            pins0[e as usize] += 1;
            for &v in hg.pins(e) {
                if parts[v as usize] == 1 {
                    if in_queue[v as usize] {
                        pq.adjust(v, gain_of(v, &pins0, hg));
                    } else {
                        enqueue(v, &mut pq, &mut fifo, &mut in_queue, &pins0);
                    }
                }
            }
        }
    }
    parts
}

/// Label propagation initial partitioning: two random seeds, then LP
/// rounds where unassigned nodes adopt the majority side of their nets.
fn lp_ip(hg: &Hypergraph, max0: NodeWeight, max1: NodeWeight, seed: u64) -> Vec<BlockId> {
    let n = hg.num_nodes();
    let mut rng = Rng::new(seed);
    let mut parts = vec![crate::INVALID_BLOCK; n];
    let s0 = rng.next_below(n.max(1));
    let mut s1 = rng.next_below(n.max(1));
    if n > 1 {
        while s1 == s0 {
            s1 = rng.next_below(n);
        }
    }
    parts[s0] = 0;
    parts[s1] = 1;
    let mut weights = [hg.node_weight(s0 as NodeId), hg.node_weight(s1 as NodeId)];
    let caps = [max0, max1];
    for _ in 0..5 {
        let mut changed = false;
        for u in 0..n {
            if parts[u] != crate::INVALID_BLOCK {
                continue;
            }
            let mut score = [0i64, 0i64];
            for &e in hg.incident_nets(u as NodeId) {
                for &v in hg.pins(e) {
                    let pv = parts[v as usize];
                    if pv == 0 || pv == 1 {
                        score[pv as usize] += hg.net_weight(e);
                    }
                }
            }
            if score[0] == 0 && score[1] == 0 {
                continue;
            }
            let b = usize::from(!(score[0] >= score[1]));
            let b = if weights[b] + hg.node_weight(u as NodeId) <= caps[b] { b } else { 1 - b };
            parts[u] = b as BlockId;
            weights[b] += hg.node_weight(u as NodeId);
            changed = true;
        }
        if !changed {
            break;
        }
    }
    // unassigned leftovers go to the lighter side
    for p in parts.iter_mut() {
        if *p == crate::INVALID_BLOCK {
            let b = usize::from(weights[0] > weights[1]);
            *p = b as BlockId;
        }
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::{Context, Preset};
    use crate::generators::{planted_hypergraph, PlantedParams};

    fn ctx() -> Context {
        Context::new(Preset::Default, 2, 0.03).with_seed(3)
    }

    #[test]
    fn all_techniques_produce_two_sides() {
        let hg = Arc::new(planted_hypergraph(
            &PlantedParams { n: 120, m: 240, blocks: 2, ..Default::default() },
            1,
        ));
        let half = (hg.total_weight() as f64 * 0.55) as NodeWeight;
        for tech in Technique::all() {
            let parts = run_technique(tech, &hg, half, half, 7);
            assert_eq!(parts.len(), 120, "{tech:?}");
            assert!(parts.iter().all(|&b| b <= 1), "{tech:?}");
            let c0 = parts.iter().filter(|&&b| b == 0).count();
            assert!(c0 > 0 && c0 < 120, "{tech:?} degenerate: {c0}");
        }
    }

    #[test]
    fn portfolio_beats_pure_random() {
        let hg = Arc::new(planted_hypergraph(
            &PlantedParams { n: 150, m: 350, blocks: 2, p_intra: 0.95, ..Default::default() },
            5,
        ));
        let half = (hg.total_weight() as f64 * 0.52) as NodeWeight;
        let best = best_bipartition(&hg, half, half, &ctx(), 11);
        // random alone (unpolished)
        let rand = run_technique(Technique::Random, &hg, half, half, 11);
        let rand_km1 = crate::metrics::km1(&hg, &rand, 2);
        assert!(best.km1 < rand_km1, "portfolio {} vs random {rand_km1}", best.km1);
        assert!(best.imbalance <= 0.0, "feasible result expected");
    }

    #[test]
    fn respects_weight_caps() {
        let hg = Arc::new(planted_hypergraph(
            &PlantedParams { n: 100, m: 200, blocks: 2, ..Default::default() },
            9,
        ));
        let max0 = hg.total_weight() * 6 / 10;
        let max1 = hg.total_weight() * 6 / 10;
        let b = best_bipartition(&hg, max0, max1, &ctx(), 3);
        let w0: i64 = (0..100).filter(|&u| b.parts[u] == 0).map(|_| 1).sum();
        assert!(w0 <= max0);
        assert!(hg.total_weight() - w0 <= max1);
    }
}
