//! Result summaries printed by the CLI, examples and benches.

use crate::metrics::Objective;
use crate::partition::PartitionedHypergraph;

/// Final partitioning statistics.
#[derive(Clone, Debug)]
pub struct PartitionReport {
    pub algorithm: String,
    pub k: usize,
    /// the objective the run was configured to optimize
    pub objective: Objective,
    /// value of `objective` on the final partition
    pub objective_value: i64,
    pub km1: i64,
    pub cut: i64,
    pub soed: i64,
    pub imbalance: f64,
    pub balanced: bool,
    pub seconds: f64,
    /// (phase name, seconds)
    pub phases: Vec<(&'static str, f64)>,
}

impl PartitionReport {
    pub fn from_partition(
        algorithm: &str,
        phg: &PartitionedHypergraph,
        objective: Objective,
        seconds: f64,
        phases: Vec<(&'static str, f64)>,
    ) -> Self {
        PartitionReport {
            algorithm: algorithm.to_string(),
            k: phg.k(),
            objective,
            objective_value: phg.objective_value(objective),
            km1: phg.km1(),
            cut: phg.cut(),
            soed: phg.soed(),
            imbalance: phg.imbalance(),
            balanced: phg.is_balanced(),
            seconds,
            phases,
        }
    }

    pub fn print(&self) {
        println!("================= {} =================", self.algorithm);
        println!("  k          = {}", self.k);
        println!("  objective  = {} = {}", self.objective.name(), self.objective_value);
        // all three metrics stay informational regardless of the objective
        println!("  km1 (λ−1)  = {}", self.km1);
        println!("  cut        = {}", self.cut);
        println!("  soed       = {}", self.soed);
        println!("  imbalance  = {:.4} ({})", self.imbalance, if self.balanced { "balanced" } else { "IMBALANCED" });
        println!("  time       = {:.3}s", self.seconds);
        if !self.phases.is_empty() {
            println!("  phases:");
            let total: f64 = self.phases.iter().map(|(_, s)| s).sum();
            for (name, secs) in &self.phases {
                println!(
                    "    {name:<22} {secs:>8.3}s  ({:>5.1}%)",
                    100.0 * secs / total.max(1e-12)
                );
            }
        }
    }
}
