//! Result summaries printed by the CLI, examples and benches.

use crate::hypergraph::HypergraphOps;
use crate::metrics::Objective;
use crate::partition::PartitionedHypergraph;
use crate::util::cancel::{CancelToken, DegradationLevel};
use std::sync::atomic::Ordering;
use std::time::Duration;

/// Final partitioning statistics.
#[derive(Clone, Debug)]
pub struct PartitionReport {
    pub algorithm: String,
    pub k: usize,
    /// the objective the run was configured to optimize
    pub objective: Objective,
    /// value of `objective` on the final partition
    pub objective_value: i64,
    pub km1: i64,
    pub cut: i64,
    pub soed: i64,
    pub imbalance: f64,
    pub balanced: bool,
    pub seconds: f64,
    /// (phase name, seconds)
    pub phases: Vec<(&'static str, f64)>,
}

/// What the resilient runtime did to meet a deadline (or recover from an
/// isolated panic) during one partitioning run. Snapshot of the
/// [`CancelToken`] counters; with no time limit set and no injected
/// faults every field is zero/`Full` and `degraded()` is `false`.
#[derive(Clone, Debug, Default)]
pub struct DegradationReport {
    /// the configured wall-clock budget, if any
    pub time_limit: Option<Duration>,
    /// whether the deadline fired (or was force-expired) during the run
    pub expired: bool,
    /// deepest degradation level the run reached
    pub max_level: DegradationLevel,
    /// flow refiner invocations shed by the ladder
    pub flows_shed: usize,
    /// FM invocations capped to a single round
    pub fm_capped: usize,
    /// FM invocations shed entirely
    pub fm_shed: usize,
    /// LP invocations shed (RebalanceOnly floor)
    pub lp_shed: usize,
    /// loops (coarsening passes, V-cycles, flow waves, batch refinement,
    /// IP repetitions) that stopped early at a cancellation checkpoint
    pub early_stops: usize,
    /// isolated panics recovered by revalidate + repair
    pub panics_recovered: usize,
}

impl DegradationReport {
    /// Snapshot the token's counters after a run.
    pub fn from_token(cancel: &CancelToken, time_limit: Option<Duration>) -> Self {
        DegradationReport {
            time_limit,
            expired: cancel.is_expired(),
            max_level: cancel.max_level(),
            flows_shed: cancel.flows_shed.load(Ordering::Relaxed),
            fm_capped: cancel.fm_capped.load(Ordering::Relaxed),
            fm_shed: cancel.fm_shed.load(Ordering::Relaxed),
            lp_shed: cancel.lp_shed.load(Ordering::Relaxed),
            early_stops: cancel.early_stops.load(Ordering::Relaxed),
            panics_recovered: cancel.panics_recovered.load(Ordering::Relaxed),
        }
    }

    /// `true` if the run shed any work, stopped any loop early or
    /// recovered from a panic — i.e. the result may differ from an
    /// unconstrained run.
    pub fn degraded(&self) -> bool {
        self.max_level > DegradationLevel::Full
            || self.flows_shed + self.fm_capped + self.fm_shed + self.lp_shed > 0
            || self.early_stops > 0
            || self.panics_recovered > 0
    }

    /// One-line summary (stderr-friendly; the CLI prints this when a run
    /// actually degraded).
    pub fn summary(&self) -> String {
        format!(
            "degradation: level={} expired={} shed(flows/fm/lp)={}/{}/{} \
             fm_capped={} early_stops={} panics_recovered={}",
            self.max_level.name(),
            self.expired,
            self.flows_shed,
            self.fm_shed,
            self.lp_shed,
            self.fm_capped,
            self.early_stops,
            self.panics_recovered,
        )
    }
}

impl PartitionReport {
    /// Works for both representations: on a [`PartitionedGraph`]
    /// (`H = Graph`) km1 and cut coincide with the edge cut and
    /// soed is exactly `2 * cut` (every cut edge has Λ = 2).
    ///
    /// [`PartitionedGraph`]: crate::partition::PartitionedGraph
    pub fn from_partition<H: HypergraphOps>(
        algorithm: &str,
        phg: &PartitionedHypergraph<H>,
        objective: Objective,
        seconds: f64,
        phases: Vec<(&'static str, f64)>,
    ) -> Self {
        PartitionReport {
            algorithm: algorithm.to_string(),
            k: phg.k(),
            objective,
            objective_value: phg.objective_value(objective),
            km1: phg.km1(),
            cut: phg.cut(),
            soed: phg.soed(),
            imbalance: phg.imbalance(),
            balanced: phg.is_balanced(),
            seconds,
            phases,
        }
    }

    pub fn print(&self) {
        println!("================= {} =================", self.algorithm);
        println!("  k          = {}", self.k);
        println!("  objective  = {} = {}", self.objective.name(), self.objective_value);
        // all three metrics stay informational regardless of the objective
        println!("  km1 (λ−1)  = {}", self.km1);
        println!("  cut        = {}", self.cut);
        println!("  soed       = {}", self.soed);
        println!("  imbalance  = {:.4} ({})", self.imbalance, if self.balanced { "balanced" } else { "IMBALANCED" });
        println!("  time       = {:.3}s", self.seconds);
        if !self.phases.is_empty() {
            println!("  phases:");
            let total: f64 = self.phases.iter().map(|(_, s)| s).sum();
            for (name, secs) in &self.phases {
                println!(
                    "    {name:<22} {secs:>8.3}s  ({:>5.1}%)",
                    100.0 * secs / total.max(1e-12)
                );
            }
        }
    }
}
