//! Configuration presets and the partitioning context (paper §12.1).
//!
//! The framework configurations evaluated in the paper:
//!
//! | Preset | Paper name | Components |
//! |---|---|---|
//! | `Speed` | Mt-KaHyPar-S | multilevel, LP only |
//! | `Default` | Mt-KaHyPar-D | multilevel, LP + FM |
//! | `DefaultFlows` | Mt-KaHyPar-D-F | multilevel, LP + FM + flows |
//! | `Quality` | Mt-KaHyPar-Q | n-level, localized LP + FM |
//! | `QualityFlows` | Mt-KaHyPar-Q-F | n-level, + flows |
//! | `Deterministic` | Mt-KaHyPar-SDet | deterministic multilevel, sync LP + sync FM |
//!
//! The paper's SDet is LP-only; our `Deterministic` preset additionally
//! runs the synchronous deterministic FM
//! ([`crate::refinement::fm::deterministic`]) — same §11 discipline,
//! same thread-count invariance, better quality than LP alone.

use crate::metrics::Objective;
use crate::partition::KStateChoice;
use crate::util::error::Result;
use crate::util::{CancelToken, PhaseTimer};
use std::sync::Arc;
use std::time::Duration;

/// Named configuration presets.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Preset {
    Speed,
    Default,
    DefaultFlows,
    Quality,
    QualityFlows,
    Deterministic,
}

impl Preset {
    pub fn name(&self) -> &'static str {
        match self {
            Preset::Speed => "Mt-KaHyPar-S",
            Preset::Default => "Mt-KaHyPar-D",
            Preset::DefaultFlows => "Mt-KaHyPar-D-F",
            Preset::Quality => "Mt-KaHyPar-Q",
            Preset::QualityFlows => "Mt-KaHyPar-Q-F",
            Preset::Deterministic => "Mt-KaHyPar-SDet",
        }
    }

    pub fn all() -> [Preset; 6] {
        [
            Preset::Speed,
            Preset::Default,
            Preset::DefaultFlows,
            Preset::Quality,
            Preset::QualityFlows,
            Preset::Deterministic,
        ]
    }
}

/// All knobs of the framework. Constructed via [`Context::new`] from a
/// preset; every field can be overridden afterwards.
#[derive(Clone)]
pub struct Context {
    pub preset: Preset,
    /// number of blocks
    pub k: usize,
    /// imbalance ratio ε
    pub epsilon: f64,
    pub seed: u64,
    pub threads: usize,
    pub objective: Objective,
    /// partition-state / gain-table layout (`--kstate`): `Auto` (the
    /// default) picks the dense packed Φ/Λ arrays for small k and the
    /// sparse (block → count) mini-table layout above
    /// [`crate::partition::SPARSE_K_THRESHOLD`]; `MTKH_KSTATE` overrides
    pub kstate: KStateChoice,

    // ---- coarsening (paper §4) ----
    /// coarsening stops at `contraction_limit_factor · k` nodes
    /// (the paper's "160k" contraction limit)
    pub contraction_limit_factor: usize,
    /// abort a pass if it shrinks the node count by less than this factor
    pub min_shrink: f64,
    /// do not let one pass shrink below `n / shrink_limit`
    pub shrink_limit: f64,
    /// community-aware coarsening (§4.3)
    pub use_community_detection: bool,
    /// Louvain rounds for community detection
    pub louvain_max_rounds: usize,

    // ---- initial partitioning (paper §5) ----
    pub ip_min_repetitions: usize,
    pub ip_max_repetitions: usize,
    /// the original (top-level) k — recursion overwrites `k`, Equation 1
    /// needs the root value
    pub ip_original_k: usize,
    /// enable the AOT spectral bipartitioner (L2 artifact) when available
    pub use_spectral_ip: bool,

    // ---- refinement (papers §6–8) ----
    pub lp_rounds: usize,
    pub use_fm: bool,
    pub fm_max_rounds: usize,
    pub fm_seeds_per_poll: usize,
    /// adaptive stopping rule window (Osipov–Sanders)
    pub fm_adaptive_alpha: f64,
    pub use_flows: bool,
    /// flow region scaling factor α (§8.2)
    pub flow_alpha: f64,
    /// max BFS distance from cut δ (§8.2)
    pub flow_distance: usize,
    /// scheduler parallelism factor τ (§8.1)
    pub flow_tau: f64,
    /// stop a flow round when relative improvement < this (§8.1)
    pub flow_min_relative_improvement: f64,
    /// run flows only on this many finest uncoarsening levels (§8.1 cost
    /// model: coarse-level flow problems rarely pay for themselves);
    /// clamped to ≥ 1 so the finest level always gets flows
    pub flow_finest_levels: usize,

    // ---- n-level (paper §9) ----
    pub nlevel: bool,
    pub nlevel_batch_size: usize,

    // ---- determinism (paper §11) ----
    pub deterministic: bool,
    pub det_sub_rounds: usize,

    // ---- resilience ----
    /// wall-clock budget for one driver run; `None` (the default) keeps
    /// the whole resilience layer inert and results bit-identical
    pub time_limit: Option<Duration>,
    /// shared cancellation token, armed with `time_limit` at driver entry
    /// and polled at every component checkpoint
    pub cancel: Arc<CancelToken>,

    /// per-phase wall-clock accounting (Fig. 11)
    pub timer: Arc<PhaseTimer>,
}

impl Context {
    pub fn new(preset: Preset, k: usize, epsilon: f64) -> Self {
        let mut ctx = Context {
            preset,
            k,
            epsilon,
            seed: 0,
            threads: 1,
            objective: Objective::Km1,
            kstate: KStateChoice::Auto,
            contraction_limit_factor: 160,
            min_shrink: 0.01,
            shrink_limit: 2.5,
            use_community_detection: true,
            louvain_max_rounds: 5,
            // paper defaults are 5/20 with 10+ cores running the
            // portfolio concurrently; scaled to this 1-vCPU testbed
            // (see EXPERIMENTS.md §Perf — quality impact measured there)
            ip_min_repetitions: 3,
            ip_max_repetitions: 8,
            ip_original_k: k,
            use_spectral_ip: false,
            lp_rounds: 5,
            use_fm: true,
            fm_max_rounds: 10,
            fm_seeds_per_poll: 25,
            fm_adaptive_alpha: 1.0,
            use_flows: false,
            flow_alpha: 16.0,
            flow_distance: 2,
            flow_tau: 1.0,
            flow_min_relative_improvement: 0.001,
            flow_finest_levels: 2,
            nlevel: false,
            nlevel_batch_size: 1000,
            deterministic: false,
            det_sub_rounds: 16,
            time_limit: None,
            cancel: Arc::new(CancelToken::new()),
            timer: Arc::new(PhaseTimer::new()),
        };
        match preset {
            Preset::Speed => {
                ctx.use_fm = false;
            }
            Preset::Default => {}
            Preset::DefaultFlows => {
                ctx.use_flows = true;
            }
            Preset::Quality => {
                ctx.nlevel = true;
            }
            Preset::QualityFlows => {
                ctx.nlevel = true;
                ctx.use_flows = true;
            }
            Preset::Deterministic => {
                // the paper's SDet drops FM entirely; we keep `use_fm` on
                // and substitute the synchronous deterministic FM, so the
                // preset's refiner stack is det-LP → det-FM (§11)
                ctx.deterministic = true;
            }
        }
        ctx
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    pub fn with_objective(mut self, obj: Objective) -> Self {
        self.objective = obj;
        self
    }

    /// Force the dense or sparse partition-state layout (`--kstate`).
    pub fn with_kstate(mut self, kstate: KStateChoice) -> Self {
        self.kstate = kstate;
        self
    }

    /// Set a wall-clock budget for each driver run. The budget clock
    /// starts when a driver is entered, not here.
    pub fn with_time_limit(mut self, limit: Duration) -> Self {
        self.time_limit = Some(limit);
        self
    }

    /// Fallible constructor: [`Context::new`] plus [`Context::validate`].
    pub fn try_new(preset: Preset, k: usize, epsilon: f64) -> Result<Self> {
        let ctx = Context::new(preset, k, epsilon);
        ctx.validate()?;
        Ok(ctx)
    }

    /// Check the configuration invariants every driver assumes, as a
    /// structured error instead of a panic deep inside the pipeline.
    pub fn validate(&self) -> Result<()> {
        if self.k < 2 {
            crate::bail!("k must be at least 2, got {}", self.k);
        }
        if !self.epsilon.is_finite() || self.epsilon < 0.0 {
            crate::bail!("epsilon must be finite and non-negative, got {}", self.epsilon);
        }
        if self.threads < 1 {
            crate::bail!("thread count must be at least 1, got {}", self.threads);
        }
        if let Some(limit) = self.time_limit {
            if limit.is_zero() {
                crate::bail!("time limit must be positive");
            }
        }
        Ok(())
    }

    /// Instance-level validation at partition entry: the configuration
    /// must be sane *and* admit a partition of this instance.
    pub fn validate_for_instance(&self, num_nodes: usize) -> Result<()> {
        self.validate()?;
        if self.k > num_nodes {
            crate::bail!("k = {} exceeds the instance's {} nodes", self.k, num_nodes);
        }
        Ok(())
    }

    /// Coarsening stops at this many nodes (`160·k`, paper §4.1).
    pub fn contraction_limit(&self) -> usize {
        self.contraction_limit_factor * self.k
    }

    /// Maximum cluster weight `c_max = c(V) / (160·k)` (paper §4.1).
    pub fn max_cluster_weight(&self, total_weight: i64) -> i64 {
        (total_weight / self.contraction_limit() as i64).max(1)
    }

    /// `L_max = (1+ε)⌈c(V)/k⌉`.
    pub fn max_block_weight(&self, total_weight: i64) -> i64 {
        crate::partition::PartitionedHypergraph::max_weight_for(total_weight, self.k, self.epsilon)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_configure_components() {
        let d = Context::new(Preset::Default, 8, 0.03);
        assert!(d.use_fm && !d.use_flows && !d.nlevel && !d.deterministic);
        let df = Context::new(Preset::DefaultFlows, 8, 0.03);
        assert!(df.use_fm && df.use_flows);
        assert!(df.flow_finest_levels >= 1, "flows must reach the finest level");
        let q = Context::new(Preset::Quality, 8, 0.03);
        assert!(q.nlevel && !q.use_flows);
        let qf = Context::new(Preset::QualityFlows, 8, 0.03);
        assert!(qf.nlevel && qf.use_flows);
        let det = Context::new(Preset::Deterministic, 8, 0.03);
        assert!(det.deterministic && det.use_fm, "SDet runs the deterministic FM");
        let s = Context::new(Preset::Speed, 8, 0.03);
        assert!(!s.use_fm);
    }

    #[test]
    fn validation_catches_bad_configs() {
        assert!(Context::try_new(Preset::Default, 1, 0.03).is_err(), "k < 2");
        assert!(Context::try_new(Preset::Default, 4, -0.5).is_err(), "negative epsilon");
        assert!(Context::try_new(Preset::Default, 4, f64::NAN).is_err(), "NaN epsilon");
        let ok = Context::try_new(Preset::Default, 4, 0.03).unwrap();
        assert!(ok.validate_for_instance(3).is_err(), "k > num_nodes");
        assert!(ok.validate_for_instance(4).is_ok());
        assert!(ok.clone().with_time_limit(Duration::ZERO).validate().is_err());
        assert!(ok.with_time_limit(Duration::from_millis(50)).validate().is_ok());
    }

    #[test]
    fn derived_limits() {
        let ctx = Context::new(Preset::Default, 64, 0.03);
        assert_eq!(ctx.contraction_limit(), 10_240); // paper: 160·64
        assert_eq!(ctx.max_cluster_weight(1_024_000), 100);
        assert_eq!(ctx.max_block_weight(64_000), (1000.0f64 * 1.03).floor() as i64);
    }
}
