//! The coordinator layer: configuration presets, the multilevel pipeline
//! driver (Algorithm 3.1), and reporting.

pub mod context;
pub mod partitioner;
pub mod report;

pub use context::{Context, Preset};
pub use report::DegradationReport;
