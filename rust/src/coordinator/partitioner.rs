//! The multilevel pipeline driver (paper Algorithm 3.1).
//!
//! preprocess (community detection) → coarsen → initial partition →
//! uncoarsen with LP / FM / flow refinement per level. Dispatches to the
//! n-level scheme (paper §9) for the Quality presets.

use crate::coarsening::{self, Hierarchy};
use crate::coordinator::context::Context;
use crate::coordinator::report::DegradationReport;
use crate::hypergraph::Hypergraph;
use crate::initial;
use crate::partition::PartitionedHypergraph;
use crate::preprocessing::{detect_communities, LouvainConfig};
use crate::refinement::RefinementPipeline;
use crate::util::error::Result;
use crate::BlockId;
use std::sync::Arc;

/// Partition `hg` into `ctx.k` blocks. Clones the hypergraph into an
/// `Arc`; use [`partition_arc`] to avoid the copy.
pub fn partition(hg: &Hypergraph, ctx: &Context) -> PartitionedHypergraph {
    partition_arc(Arc::new(hg.clone()), ctx)
}

/// [`partition_arc`] with the configuration validated against the
/// instance first (k ≥ 2, k ≤ n, sane ε/threads/time limit) — the entry
/// point for untrusted configurations such as the CLI's.
pub fn try_partition_arc(hg: Arc<Hypergraph>, ctx: &Context) -> Result<PartitionedHypergraph> {
    ctx.validate_for_instance(hg.num_nodes())?;
    Ok(partition_arc(hg, ctx))
}

/// [`partition_arc`] plus a [`DegradationReport`] describing what the
/// resilient runtime shed or repaired to meet `ctx.time_limit`. With no
/// time limit and no injected faults the report is all-zero and the
/// partition is bit-identical to `partition_arc`'s.
pub fn partition_arc_with_report(
    hg: Arc<Hypergraph>,
    ctx: &Context,
) -> (PartitionedHypergraph, DegradationReport) {
    let phg = partition_arc(hg, ctx);
    let report = DegradationReport::from_token(&ctx.cancel, ctx.time_limit);
    (phg, report)
}

/// Full pipeline on a shared hypergraph.
pub fn partition_arc(hg: Arc<Hypergraph>, ctx: &Context) -> PartitionedHypergraph {
    // arm the shared deadline for this run (no-op when `time_limit` is
    // unset: the token never reads the clock and every checkpoint stays
    // inert, preserving bit-identical results)
    ctx.cancel.arm(ctx.time_limit);
    if ctx.nlevel {
        return crate::nlevel::partition(hg, ctx);
    }
    let timer = ctx.timer.clone();

    // ---- preprocessing: community detection (§4.3) ----
    let communities = if ctx.use_community_detection {
        Some(timer.time("preprocessing", || {
            detect_communities(
                &hg,
                &LouvainConfig {
                    threads: ctx.threads,
                    seed: ctx.seed,
                    max_rounds: ctx.louvain_max_rounds,
                    deterministic: ctx.deterministic,
                    ..Default::default()
                },
            )
        }))
    } else {
        None
    };

    // ---- coarsening (§4) ----
    let hierarchy: Hierarchy =
        timer.time("coarsening", || coarsening::coarsen(hg.clone(), ctx, communities.as_deref()));

    // ---- initial partitioning (§5) ----
    let coarsest = hierarchy.coarsest();
    let parts: Vec<BlockId> =
        timer.time("initial_partitioning", || initial::initial_partition(coarsest, ctx));

    // ---- uncoarsening + refinement (§6–8) ----
    // One pipeline for the whole uncoarsening sequence: the gain table,
    // FM ownership bits, per-thread search scratch *and* the partition
    // structure itself (Π atomics, pin counts, connectivity sets, net
    // locks via the workspace PartitionPool) are allocated once, sized
    // for the finest level, and rebound/repaired in place per level —
    // `project_to_level` writes the projected assignment through the
    // contraction mapping directly into the pooled Π array, so the loop
    // performs zero per-level structural allocations (see the
    // `perf_hotpath` "level build" and "gain table per level" entries).
    // level-aware refinement: the coarsest level sits `levels.len()`
    // projections away from the finest, so level-gated refiners (flows,
    // §8.1 cost model) can skip it unless the hierarchy is shallow
    let mut pipeline = RefinementPipeline::new_for(ctx, &hg);
    let phg = pipeline.bind(hierarchy.coarsest(), &parts, ctx);
    pipeline.refine_at_distance(&phg, ctx, hierarchy.levels.len());
    pipeline.uncoarsen(&hierarchy.levels, &hg, phg, ctx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::Preset;
    use crate::generators::{planted_hypergraph, spm_hypergraph, PlantedParams};

    pub(crate) fn small_ctx(preset: Preset, k: usize, threads: usize, seed: u64) -> Context {
        let mut ctx = Context::new(preset, k, 0.03).with_threads(threads).with_seed(seed);
        ctx.contraction_limit_factor = 24;
        ctx.ip_min_repetitions = 2;
        ctx.ip_max_repetitions = 4;
        ctx.fm_max_rounds = 4;
        ctx
    }

    #[test]
    fn end_to_end_default_preset() {
        let hg = planted_hypergraph(
            &PlantedParams { n: 600, m: 1100, blocks: 4, ..Default::default() },
            21,
        );
        let phg = partition(&hg, &small_ctx(Preset::Default, 4, 2, 21));
        assert!(phg.is_balanced(), "imbalance {}", phg.imbalance());
        phg.verify_consistency().unwrap();
        // planted structure: most nets should be uncut
        assert!(
            phg.km1() < hg.num_nets() as i64 / 2,
            "quality: km1 {} of {} nets",
            phg.km1(),
            hg.num_nets()
        );
    }

    #[test]
    fn end_to_end_all_multilevel_presets() {
        let hg = spm_hypergraph(300, 300, 4, 3);
        for preset in [Preset::Speed, Preset::Default, Preset::DefaultFlows, Preset::Deterministic]
        {
            let phg = partition(&hg, &small_ctx(preset, 4, 2, 5));
            assert!(phg.is_balanced(), "{preset:?} imbalance {}", phg.imbalance());
            phg.verify_consistency().unwrap();
        }
    }

    #[test]
    fn quality_ordering_roughly_holds() {
        // D should be at least as good as Speed (LP only) on average
        let mut km1_speed = 0i64;
        let mut km1_default = 0i64;
        for seed in 0..3u64 {
            let hg = planted_hypergraph(
                &PlantedParams { n: 500, m: 900, blocks: 4, p_intra: 0.85, ..Default::default() },
                seed,
            );
            km1_speed += partition(&hg, &small_ctx(Preset::Speed, 4, 2, seed)).km1();
            km1_default += partition(&hg, &small_ctx(Preset::Default, 4, 2, seed)).km1();
        }
        assert!(
            km1_default <= km1_speed,
            "FM must help: D {km1_default} vs S {km1_speed}"
        );
    }

    #[test]
    fn deterministic_preset_reproducible_across_threads() {
        let hg = planted_hypergraph(
            &PlantedParams { n: 400, m: 800, blocks: 2, ..Default::default() },
            7,
        );
        let p1 = partition(&hg, &small_ctx(Preset::Deterministic, 2, 1, 7)).parts();
        let p2 = partition(&hg, &small_ctx(Preset::Deterministic, 2, 4, 7)).parts();
        assert_eq!(p1, p2, "SDet must be bit-identical across thread counts");
    }
}
