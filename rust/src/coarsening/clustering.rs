//! Parallel heavy-edge clustering with the on-the-fly conflict-resolution
//! join protocol (paper §4.1, Algorithm 4.1).
//!
//! Each node evaluates the heavy-edge rating `r(u,C) = Σ ω(e)/(|e|−1)`
//! over the clusters of its net-neighbors in a thread-local fixed-capacity
//! rating table — *without locking any node* — and then executes the
//! cluster-join operation: a CAS-based protocol with three node states
//! (Unclustered / Joining / Clustered), busy-wait resolution of path
//! conflicts and smallest-ID breaking of cyclic conflicts.

use crate::coordinator::context::Context;
use crate::datastructures::RatingMap;
use crate::hypergraph::HypergraphOps;
use crate::parallel::parallel_chunks;
use crate::util::rng::hash2;
use crate::util::Rng;
use crate::{NodeId, NodeWeight};
use std::sync::atomic::{AtomicI64, AtomicU32, AtomicU64, AtomicU8, Ordering};

const UNCLUSTERED: u8 = 0;
const JOINING: u8 = 1;
const CLUSTERED: u8 = 2;
const NO_TARGET: u32 = u32::MAX;

/// Reusable buffers of a clustering pass: the four input-slot-sized
/// vectors of the join protocol (node states, representatives, desired
/// targets, cluster weights), the shuffled visit order and the flattened
/// output. One n-level run performs O(log n) rating passes over the same
/// slot space, and a multilevel hierarchy runs one pass per level —
/// pooling the buffers in the driver's workspace means a pass *resets*
/// O(n) values instead of allocating (and faulting in) six fresh vectors
/// each time (the ROADMAP "pool JoinState + shuffle order" leftover).
#[derive(Default)]
pub struct ClusterScratch {
    state: Vec<AtomicU8>,
    rep: Vec<AtomicU32>,
    target: Vec<AtomicU32>,
    cluster_weight: Vec<AtomicI64>,
    order: Vec<u32>,
    rep_out: Vec<NodeId>,
}

impl ClusterScratch {
    /// Grow to `hg`'s slot count and reset the live prefix for a fresh
    /// pass (atomics are reset in place; capacity never shrinks, so a
    /// multilevel hierarchy reuses the finest level's allocation).
    fn prepare<H: HypergraphOps>(&mut self, hg: &H) {
        let n = hg.num_nodes();
        while self.state.len() < n {
            self.state.push(AtomicU8::new(UNCLUSTERED));
            self.rep.push(AtomicU32::new(0));
            self.target.push(AtomicU32::new(NO_TARGET));
            self.cluster_weight.push(AtomicI64::new(0));
        }
        for u in 0..n {
            // inactive slots of a dynamic hypergraph enter as CLUSTERED:
            // they are skipped as movers and (having no pins) can never
            // be rated as targets
            let s = if hg.is_active_node(u as NodeId) { UNCLUSTERED } else { CLUSTERED };
            self.state[u].store(s, Ordering::Relaxed);
            self.rep[u].store(u as u32, Ordering::Relaxed);
            self.target[u].store(NO_TARGET, Ordering::Relaxed);
            self.cluster_weight[u].store(hg.node_weight(u as NodeId), Ordering::Relaxed);
        }
    }
}

/// Shared state of one clustering pass, borrowing the pooled buffers.
struct JoinState<'a, H: HypergraphOps> {
    state: &'a [AtomicU8],
    rep: &'a [AtomicU32],
    /// desired target of each Joining node (cycle detection, §4.1)
    target: &'a [AtomicU32],
    cluster_weight: &'a [AtomicI64],
    /// #live nodes remaining after the joins performed so far
    remaining: AtomicU64,
    hg: &'a H,
    cmax: NodeWeight,
}

impl<H: HypergraphOps> JoinState<'_, H> {
    #[inline]
    fn state_of(&self, u: NodeId) -> u8 {
        self.state[u as usize].load(Ordering::Acquire)
    }

    #[inline]
    fn rep_of(&self, u: NodeId) -> NodeId {
        self.rep[u as usize].load(Ordering::Acquire)
    }

    /// Algorithm 4.1: add `u` to the cluster represented by `v`.
    /// Returns true if `u` ended up clustered (to anything).
    fn join(&self, u: NodeId, v: NodeId) -> bool {
        let ui = u as usize;
        if self.state[ui]
            .compare_exchange(UNCLUSTERED, JOINING, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            return false; // another thread owns u
        }
        // weight reservation on the (racily read) root of v's cluster
        let root = self.rep_of(v) as usize;
        let w = self.hg.node_weight(u);
        if self.cluster_weight[root].fetch_add(w, Ordering::AcqRel) + w > self.cmax {
            self.cluster_weight[root].fetch_sub(w, Ordering::AcqRel);
            self.state[ui].store(UNCLUSTERED, Ordering::Release);
            return false;
        }
        self.target[ui].store(v, Ordering::Release);

        let vi = v as usize;
        if self.state_of(v) == CLUSTERED
            || self.state[vi]
                .compare_exchange(UNCLUSTERED, JOINING, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
        {
            // exclusive ownership of rep[u]; v frozen (Joining by us or
            // already Clustered): safe to adopt rep[v]
            self.rep[ui].store(self.rep_of(v), Ordering::Release);
            self.finish(u, v);
            return true;
        }
        // v is itself Joining under another thread: busy-wait (path
        // conflict) and watch for cycles
        loop {
            match self.state_of(v) {
                JOINING => {
                    if let Some(min_id) = self.detect_cycle(u) {
                        if min_id == u {
                            // smallest node in the cycle breaks it
                            self.rep[ui].store(self.rep_of(v), Ordering::Release);
                            self.finish(u, v);
                            return true;
                        }
                    }
                    std::hint::spin_loop();
                }
                _ => {
                    // v resolved: adopt its (now final) representative
                    if self.state_of(u) == JOINING {
                        self.rep[ui].store(self.rep_of(v), Ordering::Release);
                    }
                    self.finish(u, v);
                    return true;
                }
            }
        }
    }

    /// Follow the desired-target chain from `u`; if it loops back to `u`
    /// through Joining nodes, return the smallest node id on the cycle.
    fn detect_cycle(&self, u: NodeId) -> Option<NodeId> {
        let mut cur = u;
        let mut min_id = u;
        for _ in 0..self.state.len() {
            let t = self.target[cur as usize].load(Ordering::Acquire);
            if t == NO_TARGET || self.state_of(cur) != JOINING {
                return None;
            }
            cur = t;
            if cur == u {
                return Some(min_id);
            }
            min_id = min_id.min(cur);
        }
        None
    }

    /// Mark `u` and `v` clustered (final line of Algorithm 4.1).
    fn finish(&self, u: NodeId, v: NodeId) {
        self.state[u as usize].store(CLUSTERED, Ordering::Release);
        self.state[v as usize].store(CLUSTERED, Ordering::Release);
        self.target[u as usize].store(NO_TARGET, Ordering::Release);
        if self.rep_of(u) != u {
            // u actually merged into another cluster
            self.remaining.fetch_sub(1, Ordering::AcqRel);
        }
    }
}

/// Heavy-edge rating pass: returns an idempotent representative array.
///
/// Convenience wrapper allocating throwaway scratch — drivers that run
/// many passes go through [`cluster_with_scratch`].
pub fn cluster<H: HypergraphOps>(
    hg: &H,
    ctx: &Context,
    communities: Option<&[u32]>,
    cmax: NodeWeight,
    floor: usize,
) -> Vec<NodeId> {
    let mut scratch = ClusterScratch::default();
    cluster_with_scratch(hg, ctx, communities, cmax, floor, &mut scratch).to_vec()
}

/// Heavy-edge rating pass on pooled [`ClusterScratch`] buffers; returns
/// the idempotent representative array, borrowed from the scratch (valid
/// until the next pass on the same scratch).
///
/// `floor` bounds how far a single pass may shrink (the paper's
/// `c(V)/2.5` safeguard handled as a node-count floor = `limit`).
/// Generic over the representation: the n-level driver runs it directly
/// on the evolving [`crate::hypergraph::dynamic::DynamicHypergraph`]
/// (inactive slots stay singletons; shrink accounting uses live nodes).
pub fn cluster_with_scratch<'s, H: HypergraphOps>(
    hg: &H,
    ctx: &Context,
    communities: Option<&[u32]>,
    cmax: NodeWeight,
    floor: usize,
    scratch: &'s mut ClusterScratch,
) -> &'s [NodeId] {
    let n = hg.num_nodes();
    scratch.prepare(hg);
    let ClusterScratch { state, rep, target, cluster_weight, order, rep_out } = scratch;
    let js = JoinState {
        state: &state[..n],
        rep: &rep[..n],
        target: &target[..n],
        cluster_weight: &cluster_weight[..n],
        remaining: AtomicU64::new(hg.num_active_nodes() as u64),
        hg,
        cmax,
    };
    let min_remaining =
        (floor.max((hg.num_active_nodes() as f64 / ctx.shrink_limit) as usize)) as u64;

    // random node order, deterministic in the seed
    order.clear();
    order.extend(0..n as u32);
    Rng::new(hash2(ctx.seed, n as u64)).shuffle(order);
    let order = &*order;

    parallel_chunks(n, ctx.threads, |_, s, e| {
        let mut map = RatingMap::with_default_capacity();
        for &u in &order[s..e] {
            if js.remaining.load(Ordering::Acquire) <= min_remaining {
                break; // don't overshoot the shrink limit
            }
            if js.state_of(u) != UNCLUSTERED {
                continue;
            }
            if let Some(v) = best_target(hg, u, &js, communities, &mut map, ctx.seed) {
                js.join(u, v);
            }
        }
    });

    // flatten: rep[rep[u]] may lag one level behind on cycle breaks
    rep_out.clear();
    rep_out.extend(js.rep.iter().map(|r| r.load(Ordering::Relaxed)));
    for u in 0..n {
        let mut r = rep_out[u] as usize;
        let mut hops = 0;
        while rep_out[r] as usize != r && hops < n {
            r = rep_out[r] as usize;
            hops += 1;
        }
        rep_out[u] = r as NodeId;
    }
    rep_out
}

/// Evaluate the heavy-edge rating for `u` over the representatives of its
/// net-neighbors (paper §4.1), respecting community and weight limits.
fn best_target<H: HypergraphOps>(
    hg: &H,
    u: NodeId,
    js: &JoinState<H>,
    communities: Option<&[u32]>,
    map: &mut RatingMap,
    seed: u64,
) -> Option<NodeId> {
    map.clear();
    let cu = communities.map(|c| c[u as usize]);
    for &e in hg.incident_nets(u) {
        let size = hg.net_size(e);
        if size < 2 {
            continue;
        }
        let r = hg.net_weight(e) as f64 / (size as f64 - 1.0);
        for &p in hg.pins(e) {
            if p == u {
                continue;
            }
            if let Some(cu) = cu {
                if communities.unwrap()[p as usize] != cu {
                    continue;
                }
            }
            if map.should_grow() {
                map.grow();
            }
            // aggregate at the pin's current representative (racy read —
            // conflicts are rare and benign, paper §4.1)
            map.add(js.rep_of(p) as u64, r);
        }
    }
    let w_u = hg.node_weight(u);
    let mut best: Option<(f64, u64, NodeId)> = None; // (rating, tiebreak, node)
    for (root, rating, _) in map.iter() {
        if root == u as u64 {
            continue; // own (singleton) cluster
        }
        if js.cluster_weight[root as usize].load(Ordering::Relaxed) + w_u > js.cmax {
            continue;
        }
        // ties broken uniformly at random via a per-(u,root) hash
        let tb = hash2(seed ^ u as u64, root);
        let better = match best {
            None => true,
            Some((br, bt, _)) => rating > br + 1e-12 || ((rating - br).abs() <= 1e-12 && tb > bt),
        };
        if better {
            // join at the cluster's representative node
            best = Some((rating, tb, root as NodeId));
        }
    }
    best.map(|(_, _, v)| v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::{Context, Preset};
    use crate::generators::{planted_hypergraph, PlantedParams};

    fn ctx() -> Context {
        Context::new(Preset::Default, 2, 0.03).with_threads(4).with_seed(1)
    }

    fn check_idempotent(rep: &[NodeId]) {
        for &r in rep {
            assert_eq!(rep[r as usize], r, "rep must be idempotent");
        }
    }

    #[test]
    fn produces_valid_clustering() {
        let hg = planted_hypergraph(&PlantedParams::default(), 2);
        let cmax = hg.total_weight() / 32;
        let rep = cluster(&hg, &ctx(), None, cmax, 10);
        check_idempotent(&rep);
        // some contraction happened
        let clusters: std::collections::HashSet<_> = rep.iter().collect();
        assert!(clusters.len() < hg.num_nodes());
    }

    #[test]
    fn cluster_weight_limit_respected() {
        let hg = planted_hypergraph(&PlantedParams::default(), 3);
        let cmax = 3; // tiny limit: clusters of at most 3 unit-weight nodes
        let rep = cluster(&hg, &ctx(), None, cmax, 2);
        check_idempotent(&rep);
        let mut w = std::collections::HashMap::new();
        for u in 0..hg.num_nodes() {
            *w.entry(rep[u]).or_insert(0i64) += hg.node_weight(u as NodeId);
        }
        for (&root, &cw) in &w {
            assert!(cw <= cmax, "cluster {root} weight {cw} > {cmax}");
        }
    }

    #[test]
    fn community_restriction_respected() {
        let hg = planted_hypergraph(&PlantedParams::default(), 4);
        let comms: Vec<u32> = (0..hg.num_nodes()).map(|u| (u % 3) as u32).collect();
        let rep = cluster(&hg, &ctx(), Some(&comms), hg.total_weight(), 2);
        check_idempotent(&rep);
        for u in 0..hg.num_nodes() {
            assert_eq!(comms[u], comms[rep[u] as usize], "cross-community merge");
        }
    }

    #[test]
    fn concurrent_protocol_is_safe_many_seeds() {
        // stress the join protocol: dense small hypergraph, many threads
        for seed in 0..5 {
            let hg = crate::generators::random_kuniform(60, 120, 3, seed);
            let mut c = ctx();
            c.seed = seed;
            let rep = cluster(&hg, &c, None, hg.total_weight() / 4, 2);
            check_idempotent(&rep);
        }
    }

    #[test]
    fn scratch_reuse_matches_fresh_scratch() {
        // pooled buffers must behave exactly like throwaway ones, even
        // when reused across passes over hypergraphs of different sizes
        // (the prepare() reset restores the between-passes invariant);
        // single-threaded so the join protocol itself is deterministic
        let mut scratch = ClusterScratch::default();
        let mut c = ctx();
        c.threads = 1;
        for seed in 0..4u64 {
            let hg = planted_hypergraph(
                &PlantedParams { n: 120 + 40 * seed as usize, ..Default::default() },
                seed,
            );
            c.seed = seed;
            let cmax = hg.total_weight() / 16;
            let fresh = cluster(&hg, &c, None, cmax, 8);
            let pooled =
                cluster_with_scratch(&hg, &c, None, cmax, 8, &mut scratch).to_vec();
            assert_eq!(fresh, pooled, "seed {seed}");
            check_idempotent(&pooled);
        }
    }

    #[test]
    fn respects_floor() {
        let hg = planted_hypergraph(&PlantedParams::default(), 8);
        let floor = hg.num_nodes() / 2;
        let rep = cluster(&hg, &ctx(), None, hg.total_weight(), floor);
        let clusters: std::collections::HashSet<_> = rep.iter().collect();
        assert!(
            clusters.len() + 8 >= floor,
            "should stop near the floor: {} < {floor}",
            clusters.len()
        );
    }
}
