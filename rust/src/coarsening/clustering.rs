//! Parallel heavy-edge clustering with the on-the-fly conflict-resolution
//! join protocol (paper §4.1, Algorithm 4.1).
//!
//! Each node evaluates the heavy-edge rating `r(u,C) = Σ ω(e)/(|e|−1)`
//! over the clusters of its net-neighbors in a thread-local fixed-capacity
//! rating table — *without locking any node* — and then executes the
//! cluster-join operation: a CAS-based protocol with three node states
//! (Unclustered / Joining / Clustered), busy-wait resolution of path
//! conflicts and smallest-ID breaking of cyclic conflicts.

use crate::coordinator::context::Context;
use crate::datastructures::RatingMap;
use crate::hypergraph::HypergraphOps;
use crate::parallel::parallel_chunks;
use crate::util::rng::hash2;
use crate::util::Rng;
use crate::{NodeId, NodeWeight};
use std::sync::atomic::{AtomicI64, AtomicU32, AtomicU64, AtomicU8, Ordering};

const UNCLUSTERED: u8 = 0;
const JOINING: u8 = 1;
const CLUSTERED: u8 = 2;
const NO_TARGET: u32 = u32::MAX;

/// Shared state of one clustering pass.
struct JoinState<'a, H: HypergraphOps> {
    state: Vec<AtomicU8>,
    rep: Vec<AtomicU32>,
    /// desired target of each Joining node (cycle detection, §4.1)
    target: Vec<AtomicU32>,
    cluster_weight: Vec<AtomicI64>,
    /// #live nodes remaining after the joins performed so far
    remaining: AtomicU64,
    hg: &'a H,
    cmax: NodeWeight,
}

impl<'a, H: HypergraphOps> JoinState<'a, H> {
    fn new(hg: &'a H, cmax: NodeWeight) -> Self {
        let n = hg.num_nodes();
        JoinState {
            // inactive slots of a dynamic hypergraph enter as CLUSTERED:
            // they are skipped as movers and (having no pins) can never be
            // rated as targets
            state: (0..n as NodeId)
                .map(|u| {
                    AtomicU8::new(if hg.is_active_node(u) { UNCLUSTERED } else { CLUSTERED })
                })
                .collect(),
            rep: (0..n as u32).map(AtomicU32::new).collect(),
            target: (0..n).map(|_| AtomicU32::new(NO_TARGET)).collect(),
            cluster_weight: (0..n).map(|u| AtomicI64::new(hg.node_weight(u as NodeId))).collect(),
            remaining: AtomicU64::new(hg.num_active_nodes() as u64),
            hg,
            cmax,
        }
    }

    #[inline]
    fn state_of(&self, u: NodeId) -> u8 {
        self.state[u as usize].load(Ordering::Acquire)
    }

    #[inline]
    fn rep_of(&self, u: NodeId) -> NodeId {
        self.rep[u as usize].load(Ordering::Acquire)
    }

    /// Algorithm 4.1: add `u` to the cluster represented by `v`.
    /// Returns true if `u` ended up clustered (to anything).
    fn join(&self, u: NodeId, v: NodeId) -> bool {
        let ui = u as usize;
        if self.state[ui]
            .compare_exchange(UNCLUSTERED, JOINING, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            return false; // another thread owns u
        }
        // weight reservation on the (racily read) root of v's cluster
        let root = self.rep_of(v) as usize;
        let w = self.hg.node_weight(u);
        if self.cluster_weight[root].fetch_add(w, Ordering::AcqRel) + w > self.cmax {
            self.cluster_weight[root].fetch_sub(w, Ordering::AcqRel);
            self.state[ui].store(UNCLUSTERED, Ordering::Release);
            return false;
        }
        self.target[ui].store(v, Ordering::Release);

        let vi = v as usize;
        if self.state_of(v) == CLUSTERED
            || self.state[vi]
                .compare_exchange(UNCLUSTERED, JOINING, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
        {
            // exclusive ownership of rep[u]; v frozen (Joining by us or
            // already Clustered): safe to adopt rep[v]
            self.rep[ui].store(self.rep_of(v), Ordering::Release);
            self.finish(u, v);
            return true;
        }
        // v is itself Joining under another thread: busy-wait (path
        // conflict) and watch for cycles
        loop {
            match self.state_of(v) {
                JOINING => {
                    if let Some(min_id) = self.detect_cycle(u) {
                        if min_id == u {
                            // smallest node in the cycle breaks it
                            self.rep[ui].store(self.rep_of(v), Ordering::Release);
                            self.finish(u, v);
                            return true;
                        }
                    }
                    std::hint::spin_loop();
                }
                _ => {
                    // v resolved: adopt its (now final) representative
                    if self.state_of(u) == JOINING {
                        self.rep[ui].store(self.rep_of(v), Ordering::Release);
                    }
                    self.finish(u, v);
                    return true;
                }
            }
        }
    }

    /// Follow the desired-target chain from `u`; if it loops back to `u`
    /// through Joining nodes, return the smallest node id on the cycle.
    fn detect_cycle(&self, u: NodeId) -> Option<NodeId> {
        let mut cur = u;
        let mut min_id = u;
        for _ in 0..self.state.len() {
            let t = self.target[cur as usize].load(Ordering::Acquire);
            if t == NO_TARGET || self.state_of(cur) != JOINING {
                return None;
            }
            cur = t;
            if cur == u {
                return Some(min_id);
            }
            min_id = min_id.min(cur);
        }
        None
    }

    /// Mark `u` and `v` clustered (final line of Algorithm 4.1).
    fn finish(&self, u: NodeId, v: NodeId) {
        self.state[u as usize].store(CLUSTERED, Ordering::Release);
        self.state[v as usize].store(CLUSTERED, Ordering::Release);
        self.target[u as usize].store(NO_TARGET, Ordering::Release);
        if self.rep_of(u) != u {
            // u actually merged into another cluster
            self.remaining.fetch_sub(1, Ordering::AcqRel);
        }
    }
}

/// Heavy-edge rating pass: returns an idempotent representative array.
///
/// `floor` bounds how far a single pass may shrink (the paper's
/// `c(V)/2.5` safeguard handled as a node-count floor = `limit`).
/// Generic over the representation: the n-level driver runs it directly
/// on the evolving [`crate::hypergraph::dynamic::DynamicHypergraph`]
/// (inactive slots stay singletons; shrink accounting uses live nodes).
pub fn cluster<H: HypergraphOps>(
    hg: &H,
    ctx: &Context,
    communities: Option<&[u32]>,
    cmax: NodeWeight,
    floor: usize,
) -> Vec<NodeId> {
    let n = hg.num_nodes();
    let js = JoinState::new(hg, cmax);
    let min_remaining =
        (floor.max((hg.num_active_nodes() as f64 / ctx.shrink_limit) as usize)) as u64;

    // random node order, deterministic in the seed
    let mut order: Vec<u32> = (0..n as u32).collect();
    Rng::new(hash2(ctx.seed, n as u64)).shuffle(&mut order);

    parallel_chunks(n, ctx.threads, |_, s, e| {
        let mut map = RatingMap::with_default_capacity();
        for &u in &order[s..e] {
            if js.remaining.load(Ordering::Acquire) <= min_remaining {
                break; // don't overshoot the shrink limit
            }
            if js.state_of(u) != UNCLUSTERED {
                continue;
            }
            if let Some(v) = best_target(hg, u, &js, communities, &mut map, ctx.seed) {
                js.join(u, v);
            }
        }
    });

    // flatten: rep[rep[u]] may lag one level behind on cycle breaks
    let mut rep: Vec<NodeId> =
        js.rep.iter().map(|r| r.load(Ordering::Relaxed)).collect();
    for u in 0..n {
        let mut r = rep[u] as usize;
        let mut hops = 0;
        while rep[r] as usize != r && hops < n {
            r = rep[r] as usize;
            hops += 1;
        }
        rep[u] = r as NodeId;
    }
    rep
}

/// Evaluate the heavy-edge rating for `u` over the representatives of its
/// net-neighbors (paper §4.1), respecting community and weight limits.
fn best_target<H: HypergraphOps>(
    hg: &H,
    u: NodeId,
    js: &JoinState<H>,
    communities: Option<&[u32]>,
    map: &mut RatingMap,
    seed: u64,
) -> Option<NodeId> {
    map.clear();
    let cu = communities.map(|c| c[u as usize]);
    for &e in hg.incident_nets(u) {
        let size = hg.net_size(e);
        if size < 2 {
            continue;
        }
        let r = hg.net_weight(e) as f64 / (size as f64 - 1.0);
        for &p in hg.pins(e) {
            if p == u {
                continue;
            }
            if let Some(cu) = cu {
                if communities.unwrap()[p as usize] != cu {
                    continue;
                }
            }
            if map.should_grow() {
                map.grow();
            }
            // aggregate at the pin's current representative (racy read —
            // conflicts are rare and benign, paper §4.1)
            map.add(js.rep_of(p) as u64, r);
        }
    }
    let w_u = hg.node_weight(u);
    let mut best: Option<(f64, u64, NodeId)> = None; // (rating, tiebreak, node)
    for (root, rating, _) in map.iter() {
        if root == u as u64 {
            continue; // own (singleton) cluster
        }
        if js.cluster_weight[root as usize].load(Ordering::Relaxed) + w_u > js.cmax {
            continue;
        }
        // ties broken uniformly at random via a per-(u,root) hash
        let tb = hash2(seed ^ u as u64, root);
        let better = match best {
            None => true,
            Some((br, bt, _)) => rating > br + 1e-12 || ((rating - br).abs() <= 1e-12 && tb > bt),
        };
        if better {
            // join at the cluster's representative node
            best = Some((rating, tb, root as NodeId));
        }
    }
    best.map(|(_, _, v)| v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::{Context, Preset};
    use crate::generators::{planted_hypergraph, PlantedParams};

    fn ctx() -> Context {
        Context::new(Preset::Default, 2, 0.03).with_threads(4).with_seed(1)
    }

    fn check_idempotent(rep: &[NodeId]) {
        for &r in rep {
            assert_eq!(rep[r as usize], r, "rep must be idempotent");
        }
    }

    #[test]
    fn produces_valid_clustering() {
        let hg = planted_hypergraph(&PlantedParams::default(), 2);
        let cmax = hg.total_weight() / 32;
        let rep = cluster(&hg, &ctx(), None, cmax, 10);
        check_idempotent(&rep);
        // some contraction happened
        let clusters: std::collections::HashSet<_> = rep.iter().collect();
        assert!(clusters.len() < hg.num_nodes());
    }

    #[test]
    fn cluster_weight_limit_respected() {
        let hg = planted_hypergraph(&PlantedParams::default(), 3);
        let cmax = 3; // tiny limit: clusters of at most 3 unit-weight nodes
        let rep = cluster(&hg, &ctx(), None, cmax, 2);
        check_idempotent(&rep);
        let mut w = std::collections::HashMap::new();
        for u in 0..hg.num_nodes() {
            *w.entry(rep[u]).or_insert(0i64) += hg.node_weight(u as NodeId);
        }
        for (&root, &cw) in &w {
            assert!(cw <= cmax, "cluster {root} weight {cw} > {cmax}");
        }
    }

    #[test]
    fn community_restriction_respected() {
        let hg = planted_hypergraph(&PlantedParams::default(), 4);
        let comms: Vec<u32> = (0..hg.num_nodes()).map(|u| (u % 3) as u32).collect();
        let rep = cluster(&hg, &ctx(), Some(&comms), hg.total_weight(), 2);
        check_idempotent(&rep);
        for u in 0..hg.num_nodes() {
            assert_eq!(comms[u], comms[rep[u] as usize], "cross-community merge");
        }
    }

    #[test]
    fn concurrent_protocol_is_safe_many_seeds() {
        // stress the join protocol: dense small hypergraph, many threads
        for seed in 0..5 {
            let hg = crate::generators::random_kuniform(60, 120, 3, seed);
            let mut c = ctx();
            c.seed = seed;
            let rep = cluster(&hg, &c, None, hg.total_weight() / 4, 2);
            check_idempotent(&rep);
        }
    }

    #[test]
    fn respects_floor() {
        let hg = planted_hypergraph(&PlantedParams::default(), 8);
        let floor = hg.num_nodes() / 2;
        let rep = cluster(&hg, &ctx(), None, hg.total_weight(), floor);
        let clusters: std::collections::HashSet<_> = rep.iter().collect();
        assert!(
            clusters.len() + 8 >= floor,
            "should stop near the floor: {} < {floor}",
            clusters.len()
        );
    }
}
