//! Deterministic clustering for coarsening (paper §11).
//!
//! Synchronous local moving in sub-rounds: each unclustered node first
//! determines its desired target cluster by the heavy-edge rating, then
//! moves are grouped by target cluster, sorted by ascending node weight
//! (node id as tie-breaker), and the longest prefix that respects the
//! cluster weight limit c_max is applied. The approve-all shortcut skips
//! the group-by stage for clusters whose aggregate incoming weight fits.
//!
//! Generic over [`HypergraphOps`]: the multilevel driver runs it on the
//! static hypergraph per level, and the deterministic n-level path runs
//! it directly on the evolving
//! [`DynamicHypergraph`](crate::hypergraph::dynamic::DynamicHypergraph)
//! (inactive slots stay singleton fixed points and are never rated as
//! targets — their pins left the shared pin lists at contraction time).

use crate::coordinator::context::Context;
use crate::datastructures::RatingMap;
use crate::hypergraph::HypergraphOps;
use crate::parallel::{par_sort_by_key, parallel_chunks};
use crate::util::rng::hash2;
use crate::{NodeId, NodeWeight};
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::Mutex;

/// Deterministic clustering pass; returns an idempotent representative
/// array that is bit-identical for any thread count.
pub fn cluster<H: HypergraphOps>(
    hg: &H,
    ctx: &Context,
    communities: Option<&[u32]>,
    cmax: NodeWeight,
    floor: usize,
) -> Vec<NodeId> {
    let n = hg.num_nodes();
    let sub_rounds = ctx.det_sub_rounds.max(1) as u64;
    let mut rep: Vec<NodeId> = (0..n as NodeId).collect();
    // weight of each cluster, indexed by representative id
    let cluster_weight: Vec<AtomicI64> =
        (0..n).map(|u| AtomicI64::new(hg.node_weight(u as NodeId))).collect();
    // #clusters so far (sequentially maintained between sub-rounds);
    // inactive dynamic slots are not clusters and never become members
    let mut num_clusters = hg.num_active_nodes();
    let min_clusters = floor.max((hg.num_active_nodes() as f64 / ctx.shrink_limit) as usize);
    // roots that received members: frozen (cannot move anymore)
    let mut locked = vec![false; n];

    'outer: for s in 0..sub_rounds {
        // members of this sub-round: unclustered (singleton) live nodes
        let members: Vec<NodeId> = (0..n as NodeId)
            .filter(|&u| {
                hg.is_active_node(u)
                    && rep[u as usize] == u
                    && !locked[u as usize]
                    && hash2(ctx.seed ^ 0xde7e_55, u as u64) % sub_rounds == s
            })
            .collect();
        if members.is_empty() {
            continue;
        }
        // ---- phase 1: desired targets against the frozen state ----
        let desired = Mutex::new(Vec::<(NodeId, NodeId)>::new()); // (node, target root)
        parallel_chunks(members.len(), ctx.threads, |_, lo, hi| {
            let mut map = RatingMap::with_default_capacity();
            let mut local = Vec::new();
            for &u in &members[lo..hi] {
                if let Some(t) = best_target_frozen(
                    hg,
                    u,
                    &rep,
                    &cluster_weight,
                    communities,
                    &mut map,
                    cmax,
                    ctx.seed,
                ) {
                    local.push((u, t));
                }
            }
            desired.lock().unwrap().extend(local);
        });
        let mut desired = desired.into_inner().unwrap();
        // moving nodes cannot simultaneously be targets (freeze rule):
        // a proposal onto a node that itself proposes a move is dropped
        let proposes: crate::util::fxhash::FxHashSet<NodeId> =
            desired.iter().map(|&(u, _)| u).collect();
        desired.retain(|&(_, t)| !proposes.contains(&t));

        // ---- phase 2: group by target, sort, prefix-accept ----
        // sort by (target, node weight, node id) — deterministic order
        par_sort_by_key(&mut desired, ctx.threads, |&(u, t)| {
            (t, hg.node_weight(u), u)
        });
        let mut i = 0;
        while i < desired.len() {
            let t = desired[i].1;
            let mut j = i;
            while j < desired.len() && desired[j].1 == t {
                j += 1;
            }
            // approve-all shortcut: total incoming weight fits
            let incoming: NodeWeight =
                desired[i..j].iter().map(|&(u, _)| hg.node_weight(u)).sum();
            let base = cluster_weight[t as usize].load(Ordering::Relaxed);
            let accept_until = if base + incoming <= cmax {
                j
            } else {
                // longest prefix by ascending weight
                let mut acc = base;
                let mut end = i;
                while end < j {
                    let w = hg.node_weight(desired[end].0);
                    if acc + w > cmax {
                        break;
                    }
                    acc += w;
                    end += 1;
                }
                end
            };
            for &(u, t) in &desired[i..accept_until] {
                rep[u as usize] = t;
                locked[t as usize] = true;
                cluster_weight[t as usize]
                    .fetch_add(hg.node_weight(u), Ordering::Relaxed);
                num_clusters -= 1;
                if num_clusters <= min_clusters {
                    break 'outer;
                }
            }
            i = j;
        }
    }
    debug_assert!(rep.iter().all(|&r| rep[r as usize] == r));
    rep
}

/// Heavy-edge rating against the frozen `rep` state.
#[allow(clippy::too_many_arguments)]
fn best_target_frozen<H: HypergraphOps>(
    hg: &H,
    u: NodeId,
    rep: &[NodeId],
    cluster_weight: &[AtomicI64],
    communities: Option<&[u32]>,
    map: &mut RatingMap,
    cmax: NodeWeight,
    seed: u64,
) -> Option<NodeId> {
    map.clear();
    let cu = communities.map(|c| c[u as usize]);
    for &e in hg.incident_nets(u) {
        let size = hg.net_size(e);
        if size < 2 {
            continue;
        }
        let r = hg.net_weight(e) as f64 / (size as f64 - 1.0);
        for &p in hg.pins(e) {
            if p == u {
                continue;
            }
            if let Some(cu) = cu {
                if communities.unwrap()[p as usize] != cu {
                    continue;
                }
            }
            if map.should_grow() {
                map.grow();
            }
            map.add(rep[p as usize] as u64, r);
        }
    }
    let w_u = hg.node_weight(u);
    let mut best: Option<(f64, u64, NodeId)> = None;
    for (root, rating, _) in map.iter() {
        if root == u as u64 {
            continue;
        }
        if cluster_weight[root as usize].load(Ordering::Relaxed) + w_u > cmax {
            continue;
        }
        let tb = hash2(seed ^ u as u64, root);
        let better = match best {
            None => true,
            Some((br, bt, _)) => rating > br + 1e-12 || ((rating - br).abs() <= 1e-12 && tb > bt),
        };
        if better {
            best = Some((rating, tb, root as NodeId));
        }
    }
    best.map(|(_, _, v)| v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::{Context, Preset};
    use crate::generators::{planted_hypergraph, PlantedParams};

    fn ctx(threads: usize) -> Context {
        Context::new(Preset::Deterministic, 2, 0.03).with_threads(threads).with_seed(5)
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let hg = planted_hypergraph(&PlantedParams::default(), 17);
        let cmax = hg.total_weight() / 16;
        let r1 = cluster(&hg, &ctx(1), None, cmax, 8);
        let r4 = cluster(&hg, &ctx(4), None, cmax, 8);
        assert_eq!(r1, r4, "bit-identical clustering for t=1 and t=4");
    }

    #[test]
    fn weight_limit_and_idempotence() {
        let hg = planted_hypergraph(&PlantedParams::default(), 23);
        let cmax = 4;
        let rep = cluster(&hg, &ctx(2), None, cmax, 2);
        let mut w = std::collections::HashMap::new();
        for u in 0..hg.num_nodes() {
            assert_eq!(rep[rep[u] as usize], rep[u]);
            *w.entry(rep[u]).or_insert(0i64) += 1;
        }
        assert!(w.values().all(|&c| c <= cmax));
    }

    #[test]
    fn communities_respected() {
        let hg = planted_hypergraph(&PlantedParams::default(), 31);
        let comms: Vec<u32> = (0..hg.num_nodes()).map(|u| (u % 4) as u32).collect();
        let rep = cluster(&hg, &ctx(2), Some(&comms), hg.total_weight(), 2);
        for u in 0..hg.num_nodes() {
            assert_eq!(comms[u], comms[rep[u] as usize]);
        }
    }

    #[test]
    fn dynamic_structure_active_slots_only() {
        // the deterministic n-level path rates the evolving dynamic
        // structure directly: inactive slots must stay singleton fixed
        // points and the result must stay bit-identical across threads
        use crate::hypergraph::dynamic::DynamicHypergraph;
        let hg = planted_hypergraph(&PlantedParams::default(), 41);
        let mut d = DynamicHypergraph::from_hypergraph(&hg);
        let ms = vec![d.contract(1, 0), d.contract(3, 2), d.contract(5, 4)];
        let cmax = hg.total_weight() / 16;
        let r1 = cluster(&d, &ctx(1), None, cmax, 8);
        let r4 = cluster(&d, &ctx(4), None, cmax, 8);
        assert_eq!(r1, r4, "bit-identical on the dynamic structure");
        for m in &ms {
            assert_eq!(r1[m.v as usize], m.v, "inactive slots stay fixed points");
        }
        for (u, &r) in r1.iter().enumerate() {
            if d.is_active_node(u as NodeId) {
                assert!(d.is_active_node(r), "representatives must be active");
            }
        }
    }

    #[test]
    fn actually_contracts() {
        let hg = planted_hypergraph(&PlantedParams::default(), 37);
        let rep = cluster(&hg, &ctx(2), None, hg.total_weight() / 8, 8);
        let roots: std::collections::HashSet<_> = rep.iter().collect();
        assert!(roots.len() * 3 < hg.num_nodes() * 2, "shrunk by ≥ 1/3");
    }
}
