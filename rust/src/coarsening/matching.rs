//! Matching-based coarsening — the baseline scheme of classic multilevel
//! partitioners (hMetis/PaToH heavy-edge matching; paper §4's related
//! work). Used by the internal "PaToH-like" comparison baseline: pairs of
//! nodes are matched greedily by the heavy-edge rating, so each pass at
//! most halves the node count. Clustering-based coarsening (the paper's
//! approach) shrinks skewed-degree instances much faster — this module
//! exists to reproduce that contrast.

use crate::datastructures::RatingMap;
use crate::hypergraph::Hypergraph;
use crate::util::Rng;
use crate::{NodeId, NodeWeight};

/// Sequential greedy heavy-edge matching; returns an idempotent
/// representative array (pairs share the smaller id as representative).
pub fn match_nodes(hg: &Hypergraph, cmax: NodeWeight, seed: u64) -> Vec<NodeId> {
    let n = hg.num_nodes();
    let mut rep: Vec<NodeId> = (0..n as NodeId).collect();
    let mut matched = vec![false; n];
    let mut order: Vec<u32> = (0..n as u32).collect();
    Rng::new(seed).shuffle(&mut order);
    let mut map = RatingMap::with_default_capacity();

    for &u in &order {
        if matched[u as usize] {
            continue;
        }
        map.clear();
        for &e in hg.incident_nets(u) {
            let size = hg.net_size(e);
            if size < 2 {
                continue;
            }
            let r = hg.net_weight(e) as f64 / (size as f64 - 1.0);
            for &p in hg.pins(e) {
                if p != u && !matched[p as usize] {
                    if map.should_grow() {
                        map.grow();
                    }
                    map.add(p as u64, r);
                }
            }
        }
        let wu = hg.node_weight(u);
        let mut best: Option<(f64, NodeId)> = None;
        for (v, rating, _) in map.iter() {
            let v = v as NodeId;
            if hg.node_weight(v) + wu > cmax {
                continue;
            }
            if best.map_or(true, |(br, _)| rating > br) {
                best = Some((rating, v));
            }
        }
        if let Some((_, v)) = best {
            let (lo, hi) = if u < v { (u, v) } else { (v, u) };
            rep[hi as usize] = lo;
            matched[u as usize] = true;
            matched[v as usize] = true;
        }
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators::{planted_hypergraph, PlantedParams};

    #[test]
    fn matching_pairs_only() {
        let hg = planted_hypergraph(&PlantedParams::default(), 3);
        let rep = match_nodes(&hg, 2, 1);
        let mut sizes = std::collections::HashMap::new();
        for u in 0..hg.num_nodes() {
            assert_eq!(rep[rep[u] as usize], rep[u]);
            *sizes.entry(rep[u]).or_insert(0usize) += 1;
        }
        assert!(sizes.values().all(|&s| s <= 2), "matching = clusters of ≤ 2");
        // a decent fraction got matched
        let singletons = sizes.values().filter(|&&s| s == 1).count();
        assert!(singletons * 2 < hg.num_nodes(), "most nodes matched");
    }

    #[test]
    fn halving_at_best() {
        let hg = planted_hypergraph(&PlantedParams::default(), 9);
        let rep = match_nodes(&hg, i64::MAX, 2);
        let roots: std::collections::HashSet<_> = rep.iter().collect();
        assert!(roots.len() * 2 >= hg.num_nodes(), "shrink factor ≤ 2");
    }
}
