//! The coarsening phase (paper §4): repeatedly compute a clustering of
//! highly-connected nodes and contract it, until the hypergraph reaches
//! the contraction limit (160·k nodes).

pub mod clustering;
pub mod deterministic;
pub mod matching;

use crate::coordinator::context::Context;
use crate::hypergraph::{contraction, Hypergraph};
use crate::{EdgeId, NodeId};
use std::sync::Arc;

/// One level of the multilevel hierarchy.
pub struct Level {
    /// the coarser hypergraph produced at this level
    pub coarse: Arc<Hypergraph>,
    /// node mapping from the finer hypergraph into `coarse`
    pub fine_to_coarse: Vec<NodeId>,
    /// net mapping from the finer hypergraph into `coarse`
    /// (`EdgeId::MAX` for nets dropped by the contraction) — drives the
    /// Φ/Λ delta repair during uncoarsening instead of full rebuilds
    pub net_map: Vec<EdgeId>,
}

/// The full coarsening hierarchy: `input` followed by `levels` of
/// successively coarser hypergraphs.
pub struct Hierarchy {
    pub input: Arc<Hypergraph>,
    pub levels: Vec<Level>,
}

impl Hierarchy {
    /// The coarsest hypergraph (the input if no contraction happened).
    pub fn coarsest(&self) -> Arc<Hypergraph> {
        self.levels.last().map(|l| l.coarse.clone()).unwrap_or_else(|| self.input.clone())
    }
}

/// Multilevel clustering coarsening (Algorithm 3.1's loop, paper §4.1):
/// stops at the contraction limit, when a pass shrinks by < `min_shrink`,
/// or when the clustering would overshoot the `shrink_limit` (handled
/// inside the clustering by capping the number of joins).
pub fn coarsen(
    hg: Arc<Hypergraph>,
    ctx: &Context,
    communities: Option<&[u32]>,
) -> Hierarchy {
    let limit = ctx.contraction_limit().max(2 * ctx.k);
    let cmax = ctx.max_cluster_weight(hg.total_weight());
    let mut levels: Vec<Level> = Vec::new();
    let mut current = hg.clone();
    let mut comms: Option<Vec<u32>> = communities.map(|c| c.to_vec());
    // one set of rating-pass buffers for the whole hierarchy (coarser
    // levels reuse the input level's allocation)
    let mut scratch = clustering::ClusterScratch::default();

    while current.num_nodes() > limit {
        // cancellation checkpoint at the pass boundary: a shorter
        // hierarchy is fully usable — IP just runs on a larger coarsest
        // level and uncoarsening visits fewer levels
        if ctx.cancel.is_expired() {
            ctx.cancel.note_early_stop();
            break;
        }
        let n_before = current.num_nodes();
        let det_rep: Vec<NodeId>;
        let rep: &[NodeId] = if ctx.deterministic {
            det_rep = deterministic::cluster(&*current, ctx, comms.as_deref(), cmax, limit);
            &det_rep
        } else {
            clustering::cluster_with_scratch(
                &*current,
                ctx,
                comms.as_deref(),
                cmax,
                limit,
                &mut scratch,
            )
        };
        let c = contraction::contract(&current, rep, ctx.threads);
        let n_after = c.coarse.num_nodes();
        // stop if the pass did not shrink the hypergraph by more than 1%
        if (n_before - n_after) as f64 <= ctx.min_shrink * n_before as f64 {
            break;
        }
        // project communities onto the coarse hypergraph
        if let Some(cm) = &comms {
            let mut coarse_comms = vec![0u32; n_after];
            for u in 0..n_before {
                coarse_comms[c.fine_to_coarse[u] as usize] = cm[u];
            }
            comms = Some(coarse_comms);
        }
        let coarse = Arc::new(c.coarse);
        levels.push(Level {
            coarse: coarse.clone(),
            fine_to_coarse: c.fine_to_coarse,
            net_map: c.net_map,
        });
        current = coarse;
    }
    Hierarchy { input: hg, levels }
}

/// Project a partition of the coarser level back to the finer level
/// (uncoarsening step of Algorithm 3.1).
pub fn project_partition(level: &Level, coarse_parts: &[crate::BlockId]) -> Vec<crate::BlockId> {
    level.fine_to_coarse.iter().map(|&c| coarse_parts[c as usize]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::{Context, Preset};
    use crate::generators::{planted_hypergraph, PlantedParams};

    fn ctx(k: usize) -> Context {
        let mut c = Context::new(Preset::Default, k, 0.03).with_threads(2).with_seed(7);
        c.contraction_limit_factor = 16; // small instances in tests
        c
    }

    #[test]
    fn hierarchy_shrinks_to_limit() {
        let hg = Arc::new(planted_hypergraph(&PlantedParams::default(), 3));
        let ctx = ctx(4);
        let h = coarsen(hg.clone(), &ctx, None);
        assert!(!h.levels.is_empty());
        let coarsest = h.coarsest();
        assert!(coarsest.num_nodes() < hg.num_nodes());
        // weights conserved across every level
        for l in &h.levels {
            assert_eq!(l.coarse.total_weight(), hg.total_weight());
            l.coarse.validate().unwrap();
        }
        // monotone shrinking
        let mut prev = hg.num_nodes();
        for l in &h.levels {
            assert!(l.coarse.num_nodes() < prev);
            prev = l.coarse.num_nodes();
        }
    }

    #[test]
    fn respects_community_restriction() {
        let hg = Arc::new(planted_hypergraph(&PlantedParams::default(), 5));
        let ctx = ctx(2);
        // two communities: node parity
        let comms: Vec<u32> = (0..hg.num_nodes()).map(|u| (u % 2) as u32).collect();
        let h = coarsen(hg.clone(), &ctx, Some(&comms));
        if let Some(first) = h.levels.first() {
            // nodes merged into one coarse node must share the community
            let mut coarse_comm: Vec<Option<u32>> = vec![None; first.coarse.num_nodes()];
            for u in 0..hg.num_nodes() {
                let c = first.fine_to_coarse[u] as usize;
                match coarse_comm[c] {
                    None => coarse_comm[c] = Some(comms[u]),
                    Some(cc) => assert_eq!(cc, comms[u], "community violated"),
                }
            }
        }
    }

    #[test]
    fn projection_roundtrip() {
        let hg = Arc::new(planted_hypergraph(&PlantedParams::default(), 11));
        let ctx = ctx(2);
        let h = coarsen(hg.clone(), &ctx, None);
        if let Some(level) = h.levels.last() {
            let k_parts: Vec<crate::BlockId> =
                (0..level.coarse.num_nodes()).map(|u| (u % 2) as crate::BlockId).collect();
            let fine = project_partition(level, &k_parts);
            let fine_n = if h.levels.len() >= 2 {
                h.levels[h.levels.len() - 2].coarse.num_nodes()
            } else {
                hg.num_nodes()
            };
            assert_eq!(fine.len(), fine_n);
            for (u, &b) in fine.iter().enumerate() {
                assert_eq!(b, k_parts[level.fine_to_coarse[u] as usize]);
            }
        }
    }
}
