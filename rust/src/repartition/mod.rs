//! Warm-start repartitioning on the dynamic hypergraph.
//!
//! The paper motivates partitioning as the backbone of distributed data
//! placement, where the hypergraph evolves under traffic and nobody
//! should pay full multilevel cost per request. This module is that
//! story's serving layer: it keeps one partition *bound* to a
//! [`DynamicHypergraph`], accepts [`ChangeBatch`]es of online mutations
//! (insert/remove nodes and nets, weight updates), maps the previous
//! assignment Π onto the mutated structure, and repairs quality with
//! localized refinement plus a bounded-migration V-cycle from the cached
//! partition (the established warm-start scheme, arXiv:2010.10272 §4.3)
//! — all through the pooled [`RefinementPipeline`], so a stream of
//! batches runs on **one** warm arena: after the first session bind the
//! partition pool performs zero structural allocations as long as churn
//! stays within the slot free-lists and the reserved headroom (asserted
//! by the pool counters in the tests and `perf_hotpath`).
//!
//! ## One `apply` call
//!
//! 1. **Park** the bound partition (its buffers return to the pool) and
//!    mutate the sole-owner dynamic structure in place — the same
//!    boundary discipline as the n-level batch loop.
//! 2. **Unpark** onto the mutated structure. If the mutations outgrew
//!    the parked buffers (insertions past the reservation), the pool's
//!    growth path ([`crate::partition::PartitionPool::unpark_with_parts`])
//!    reallocates *cleanly* (counted) instead of corrupting state.
//! 3. **Map Π**: surviving nodes keep their block; new nodes are seeded
//!    into the lightest block and immediately improved by a gain-greedy
//!    relocation under the run's objective.
//! 4. **Localized refinement** around every touched node (LP + FM, or
//!    the synchronous deterministic FM under the `Deterministic` preset)
//!    with the PR-7 panic isolation: an unwinding worker is recovered,
//!    the partition revalidated/rebuilt and rebalanced, and the request
//!    still completes.
//! 5. **Warm V-cycle** (optional, `RepartitionConfig::vcycles`): freeze
//!    the active structure, V-cycle from the current assignment with the
//!    blocks as coarsening communities, and carry the improvement back —
//!    every rebind stays inside the pooled buffers.
//! 6. **Migration bound**: nodes whose block changed are reverted
//!    (cheapest-first) until the migrated weight respects
//!    `RepartitionConfig::max_migration_fraction`; the returned
//!    [`MoveSet`] reports migration volume alongside quality.
//!
//! [`RepartitionSession`] adds the long-lived batch mode: partitions are
//! cached keyed by a structural instance hash, so re-binding a
//! previously seen instance skips the cold multilevel run entirely. The
//! CLI exposes the stream mode as `--repartition changes.txt`.

use crate::coarsening;
use crate::coordinator::context::Context;
use crate::coordinator::partitioner;
use crate::hypergraph::dynamic::DynamicHypergraph;
use crate::hypergraph::{Hypergraph, HypergraphOps};
use crate::partition::objective::with_policy;
use crate::partition::{PartitionPool, PartitionedHypergraph};
use crate::refinement::{rebalance, RefinementPipeline};
use crate::util::error::{Context as ErrCtx, Result as IoResult};
use crate::util::failpoints;
use crate::util::fxhash::FxHashMap;
use crate::{BlockId, EdgeId, EdgeWeight, NodeId, NodeWeight};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::Arc;

/// One online mutation of the finest-level hypergraph.
#[derive(Clone, Debug)]
pub enum Change {
    /// add a node of the given weight (its id is reported as a placement)
    InsertNode { weight: NodeWeight },
    /// remove an active node (its pins leave every incident net)
    RemoveNode { node: NodeId },
    /// add a net over existing active nodes
    InsertNet { pins: Vec<NodeId>, weight: EdgeWeight },
    /// remove a net
    RemoveNet { net: EdgeId },
    /// set a node's weight
    UpdateWeight { node: NodeId, weight: NodeWeight },
}

/// An ordered batch of changes applied atomically by
/// [`Repartitioner::apply`] (one park/unpark cycle, one refinement pass).
#[derive(Clone, Debug, Default)]
pub struct ChangeBatch {
    pub changes: Vec<Change>,
}

impl ChangeBatch {
    pub fn new() -> Self {
        ChangeBatch { changes: Vec::new() }
    }

    pub fn push(&mut self, c: Change) -> &mut Self {
        self.changes.push(c);
        self
    }

    pub fn len(&self) -> usize {
        self.changes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.changes.is_empty()
    }
}

/// The outcome of one [`Repartitioner::apply`]: which nodes moved, how
/// much weight migrated, and the quality of the repaired partition.
#[derive(Clone, Debug)]
pub struct MoveSet {
    /// surviving nodes whose block changed: `(node, from, to)`
    pub moves: Vec<(NodeId, BlockId, BlockId)>,
    /// nodes inserted by this batch and their assigned block
    pub placements: Vec<(NodeId, BlockId)>,
    /// total weight of the `moves` (placements are not migration — a new
    /// node has to be placed somewhere)
    pub migrated_weight: NodeWeight,
    /// the configured absolute migration bound, if any
    pub migration_limit: Option<NodeWeight>,
    /// objective value of the repaired partition (per `ctx.objective`)
    pub objective: i64,
    pub imbalance: f64,
    pub balanced: bool,
}

impl MoveSet {
    /// Does the migration volume respect the configured bound?
    pub fn bound_satisfied(&self) -> bool {
        self.migration_limit.map_or(true, |l| self.migrated_weight <= l)
    }

    /// One-line summary for stream-mode logging.
    pub fn summary(&self) -> String {
        format!(
            "moved {} nodes (weight {}{}) placed {} objective {} imbalance {:.4}{}",
            self.moves.len(),
            self.migrated_weight,
            self.migration_limit.map_or(String::new(), |l| format!("/{l}")),
            self.placements.len(),
            self.objective,
            self.imbalance,
            if self.balanced { "" } else { " IMBALANCED" },
        )
    }
}

/// Knobs of the warm-start service.
#[derive(Clone, Debug)]
pub struct RepartitionConfig {
    /// cap migrated weight per `apply` at this fraction of the total
    /// node weight (`None`: unbounded)
    pub max_migration_fraction: Option<f64>,
    /// warm V-cycles per `apply` (0 disables the multilevel repair)
    pub vcycles: usize,
    /// baseline mode: skip all quality repair, only restore balance —
    /// the floor the warm start is measured against in the tests
    pub rebalance_only: bool,
    /// extra node slots reserved in the pool beyond the bound instance,
    /// so insertions past the free-list stay within the first allocation
    pub headroom_nodes: usize,
    /// extra net slots reserved in the pool
    pub headroom_nets: usize,
    /// largest net the reservation must accommodate (0: the instance's)
    pub headroom_net_size: usize,
}

impl Default for RepartitionConfig {
    fn default() -> Self {
        RepartitionConfig {
            max_migration_fraction: None,
            vcycles: 1,
            rebalance_only: false,
            headroom_nodes: 0,
            headroom_nets: 0,
            headroom_net_size: 0,
        }
    }
}

/// The warm-start repartitioner: one dynamic hypergraph, one cached
/// partition, one pooled refinement arena, many [`Self::apply`] calls.
pub struct Repartitioner {
    ctx: Context,
    cfg: RepartitionConfig,
    pipeline: RefinementPipeline,
    dynhg: Arc<DynamicHypergraph>,
    phg: Option<PartitionedHypergraph<DynamicHypergraph>>,
    // ---- reused per-apply scratch ----
    /// pre-batch assignment (migration accounting)
    prev_parts: Vec<BlockId>,
    /// assignment handed to the rebuild on the mutated structure
    next_parts: Vec<BlockId>,
    /// nodes whose neighborhood a batch touched (refinement seeds)
    touched: Vec<NodeId>,
    /// per-block weights for the greedy placement seed
    bw: Vec<NodeWeight>,
}

impl Repartitioner {
    /// Cold start: run full multilevel partitioning once, then bind the
    /// result to the dynamic structure for incremental serving.
    pub fn new(hg: Arc<Hypergraph>, ctx: Context, cfg: RepartitionConfig) -> Self {
        let phg = partitioner::partition_arc(hg.clone(), &ctx);
        let parts = phg.parts();
        drop(phg);
        Self::new_with_parts(hg, &parts, ctx, cfg)
    }

    /// Warm start from an existing assignment (session cache hits): the
    /// multilevel run is skipped entirely.
    pub fn new_with_parts(
        hg: Arc<Hypergraph>,
        parts: &[BlockId],
        ctx: Context,
        cfg: RepartitionConfig,
    ) -> Self {
        assert_eq!(parts.len(), hg.num_nodes(), "assignment must cover the instance");
        let dynhg = Arc::new(DynamicHypergraph::from_hypergraph(&hg));
        let mut pipeline = RefinementPipeline::new_for(&ctx, &hg);
        pipeline.workspace_mut().reserve_partition(&*dynhg);
        if cfg.headroom_nodes > 0 || cfg.headroom_nets > 0 || cfg.headroom_net_size > 0 {
            // sparse pin budget: every headroom net may need min(|e|, k)
            // slots, bounded by the reserved max net size
            let slot = cfg.headroom_net_size.max(hg.max_net_size()).min(ctx.k);
            pipeline.reserve_headroom(
                cfg.headroom_nodes,
                cfg.headroom_nets,
                cfg.headroom_net_size,
                cfg.headroom_nets * slot,
            );
        }
        pipeline
            .workspace_mut()
            .ensure_node_capacity(hg.num_nodes() + cfg.headroom_nodes);
        // the first (and ideally only) structural allocation of the session
        let phg = pipeline.bind(dynhg.clone(), parts, &ctx);
        Repartitioner {
            ctx,
            cfg,
            pipeline,
            dynhg,
            phg: Some(phg),
            prev_parts: Vec::new(),
            next_parts: Vec::new(),
            touched: Vec::new(),
            bw: Vec::new(),
        }
    }

    /// The bound partition (valid between `apply` calls).
    pub fn partition(&self) -> &PartitionedHypergraph<DynamicHypergraph> {
        self.phg.as_ref().expect("no partition bound (apply in progress?)")
    }

    /// The mutated dynamic structure.
    pub fn hypergraph(&self) -> &DynamicHypergraph {
        &self.dynhg
    }

    /// The pooled partition state (allocation counters for the tests).
    pub fn partition_pool(&self) -> &PartitionPool {
        self.pipeline.partition_pool()
    }

    pub fn context(&self) -> &Context {
        &self.ctx
    }

    /// Apply one change batch: mutate, remap Π, refine, bound migration.
    /// On a bad change the batch stops at the offending mutation, the
    /// partition is still restored to a consistent state on whatever was
    /// applied, and the error is returned.
    pub fn apply(&mut self, batch: &ChangeBatch) -> Result<MoveSet, String> {
        let phg = self
            .phg
            .take()
            .ok_or_else(|| "no partition bound (previous apply failed hard)".to_string())?;
        // each request runs under its own deadline arming, like a driver
        self.ctx.cancel.arm(self.ctx.time_limit);
        let n_before = HypergraphOps::num_nodes(phg.hypergraph());
        self.prev_parts.clear();
        self.prev_parts.extend(phg.parts());
        self.touched.clear();

        // ---- park + mutate (n-level batch-boundary discipline) ----
        self.pipeline.park(phg);
        let mut new_nodes: Vec<NodeId> = Vec::new();
        let mut batch_err: Option<String> = None;
        match Arc::get_mut(&mut self.dynhg) {
            None => {
                batch_err =
                    Some("dynamic hypergraph is shared; drop outside references first".into())
            }
            Some(hg_mut) => {
                for change in &batch.changes {
                    let r = apply_change(hg_mut, change, &mut new_nodes, &mut self.touched);
                    if let Err(e) = r {
                        batch_err = Some(e);
                        break;
                    }
                }
            }
        }

        // ---- unpark onto the mutated structure ----
        let n_now = HypergraphOps::num_nodes(&*self.dynhg);
        debug_assert!(n_now >= n_before, "node slots never shrink");
        self.next_parts.clear();
        self.next_parts.extend_from_slice(&self.prev_parts);
        self.next_parts.resize(n_now, 0);

        // greedy placement seed: new nodes go to the lightest block
        // (deterministic: ties toward the lower block id)
        let k = self.ctx.k;
        self.bw.clear();
        self.bw.resize(k, 0);
        for u in self.dynhg.active_nodes() {
            if !new_nodes.contains(&u) {
                self.bw[self.next_parts[u as usize] as usize] +=
                    HypergraphOps::node_weight(&*self.dynhg, u);
            }
        }
        let mut placements: Vec<(NodeId, BlockId)> = Vec::with_capacity(new_nodes.len());
        for &u in &new_nodes {
            let b = (0..k).min_by_key(|&b| (self.bw[b], b)).unwrap() as BlockId;
            self.bw[b as usize] += HypergraphOps::node_weight(&*self.dynhg, u);
            self.next_parts[u as usize] = b;
            placements.push((u, b));
        }

        let phg = if self.pipeline.parked_fits(&*self.dynhg) {
            // warm path: the parked buffers host the mutated structure,
            // the values are rebuilt in place — zero structural allocation
            let phg = self.pipeline.unpark(self.dynhg.clone(), &self.ctx);
            phg.assign_all(&self.next_parts, self.ctx.threads);
            phg
        } else {
            // growth path: mutations outgrew the buffers (or the state
            // layout); reallocate cleanly, counted by the pool
            self.pipeline.unpark_with_parts(self.dynhg.clone(), &self.next_parts, &self.ctx)
        };

        if let Some(e) = batch_err {
            // the structure holds whatever prefix of the batch applied;
            // the partition above is consistent with it — report and bail
            self.phg = Some(phg);
            return Err(e);
        }

        // gain-greedy improvement of the placement seeds
        with_policy!(self.ctx.objective, P => {
            for p in placements.iter_mut() {
                if let Some((gain, to)) = phg.max_gain_move_p::<P>(p.0) {
                    if gain > 0 && phg.try_move_p::<P>(p.0, to, None).is_some() {
                        p.1 = to;
                    }
                }
            }
        });

        // ---- localized refinement around the touched neighborhood ----
        self.touched.sort_unstable();
        self.touched.dedup();
        let dynhg = &self.dynhg;
        self.touched.retain(|&u| dynhg.is_active_node(u));
        self.pipeline.workspace_mut().ensure_node_capacity(n_now);
        let refined = {
            let pipeline = &mut self.pipeline;
            let ctx = &self.ctx;
            let cfg = &self.cfg;
            let touched = &self.touched;
            catch_unwind(AssertUnwindSafe(|| {
                failpoints::fire(failpoints::REPARTITION_APPLY, &ctx.cancel);
                if !cfg.rebalance_only && !touched.is_empty() {
                    if ctx.deterministic {
                        // thread-count invariance: the synchronous
                        // deterministic FM doubles as the localized LP
                        pipeline.fm_with_seeds(&phg, ctx, Some(touched));
                    } else {
                        pipeline.lp_localized(&phg, ctx, touched);
                        if ctx.use_fm {
                            pipeline.fm_with_seeds(&phg, ctx, Some(touched));
                        }
                    }
                }
            }))
        };
        let worker_panicked = self.pipeline.workspace_mut().take_worker_panic();
        if refined.is_err() || worker_panicked {
            // panic isolation (PR-7 ladder): recover, revalidate, rebalance
            self.ctx.cancel.note_panic_recovered();
            let ws = self.pipeline.workspace_mut();
            ws.reset_owner(ws.owner.len());
            if phg.validate().is_err() {
                phg.rebuild_from_parts(self.ctx.threads);
            }
        }
        if !phg.is_balanced() {
            rebalance::rebalance(&phg, &self.ctx);
        }

        // ---- warm V-cycle on the frozen active structure ----
        let phg = if self.cfg.vcycles > 0
            && !self.cfg.rebalance_only
            && !self.ctx.cancel.is_expired()
            && self.dynhg.num_active_nodes() >= 2 * k
        {
            self.warm_vcycle(phg)
        } else {
            phg
        };

        // ---- migration accounting + bound ----
        let total_weight = HypergraphOps::total_weight(&*self.dynhg);
        let migration_limit = self.cfg.max_migration_fraction.map(|f| {
            ((f * total_weight as f64).ceil() as NodeWeight).max(0)
        });
        new_nodes.sort_unstable();
        let is_new = |u: NodeId| new_nodes.binary_search(&u).is_ok();
        let mut migrated: Vec<(NodeId, BlockId, BlockId)> = Vec::new();
        let mut migrated_weight: NodeWeight = 0;
        for u in self.dynhg.active_nodes() {
            if (u as usize) < n_before && !is_new(u) {
                let from = self.prev_parts[u as usize];
                let to = phg.block_of(u);
                if from != to {
                    migrated.push((u, from, to));
                    migrated_weight += HypergraphOps::node_weight(&*self.dynhg, u);
                }
            }
        }
        if let Some(limit) = migration_limit {
            if migrated_weight > limit {
                migrated_weight =
                    enforce_migration_bound(&phg, &self.ctx, &mut migrated, migrated_weight, limit);
            }
        }
        // reverts may have unbalanced blocks the migrations were fixing
        if !phg.is_balanced() {
            rebalance::rebalance(&phg, &self.ctx);
            // a forced rebalance can re-migrate: re-account (bound may be
            // exceeded; the MoveSet reports it instead of hiding it)
            migrated.clear();
            migrated_weight = 0;
            for u in self.dynhg.active_nodes() {
                if (u as usize) < n_before && !is_new(u) {
                    let from = self.prev_parts[u as usize];
                    let to = phg.block_of(u);
                    if from != to {
                        migrated.push((u, from, to));
                        migrated_weight += HypergraphOps::node_weight(&*self.dynhg, u);
                    }
                }
            }
        }
        for p in placements.iter_mut() {
            p.1 = phg.block_of(p.0);
        }

        let result = MoveSet {
            moves: migrated,
            placements,
            migrated_weight,
            migration_limit,
            objective: phg.objective_value(self.ctx.objective),
            imbalance: phg.imbalance(),
            balanced: phg.is_balanced(),
        };
        self.phg = Some(phg);
        Ok(result)
    }

    /// V-cycle the current assignment on a frozen snapshot of the active
    /// structure (blocks as coarsening communities, arXiv:2010.10272
    /// §4.3), then carry the improved assignment back onto the dynamic
    /// binding. Every rebind reuses the pooled buffers: the snapshot is
    /// no larger than the dynamic structure, so the pool's fit check
    /// keeps the whole cycle allocation-free.
    fn warm_vcycle(
        &mut self,
        phg: PartitionedHypergraph<DynamicHypergraph>,
    ) -> PartitionedHypergraph<DynamicHypergraph> {
        let mut parts_dyn = phg.parts();
        let snap = self.dynhg.freeze();
        let snap_hg = Arc::new(snap.hg);
        let mut parts_s: Vec<BlockId> =
            snap.to_dynamic.iter().map(|&u| parts_dyn[u as usize]).collect();
        self.pipeline.park(phg);
        let mut cur = self.pipeline.unpark_with_parts(snap_hg.clone(), &parts_s, &self.ctx);
        for _ in 0..self.cfg.vcycles {
            if self.ctx.cancel.is_expired() {
                self.ctx.cancel.note_early_stop();
                break;
            }
            let before = cur.objective_value(self.ctx.objective);
            let hierarchy = coarsening::coarsen(snap_hg.clone(), &self.ctx, Some(&parts_s));
            let mut coarse_parts: Vec<BlockId> = parts_s.clone();
            for level in &hierarchy.levels {
                let mut next = vec![0 as BlockId; level.coarse.num_nodes()];
                for (u, &c) in level.fine_to_coarse.iter().enumerate() {
                    next[c as usize] = coarse_parts[u];
                }
                coarse_parts = next;
            }
            cur = self.pipeline.rebind_with_parts(
                cur,
                hierarchy.coarsest(),
                &coarse_parts,
                &self.ctx,
            );
            self.pipeline.refine_at_distance(&cur, &self.ctx, hierarchy.levels.len());
            cur = self.pipeline.uncoarsen(&hierarchy.levels, &snap_hg, cur, &self.ctx);
            if cur.objective_value(self.ctx.objective) < before && cur.is_balanced() {
                parts_s = cur.parts();
            } else {
                // rejected: delta-restore the best accepted assignment
                cur.apply_parts_delta(&parts_s, self.ctx.threads);
                break;
            }
        }
        for (c, &u) in snap.to_dynamic.iter().enumerate() {
            parts_dyn[u as usize] = parts_s[c];
        }
        self.pipeline.park(cur);
        self.pipeline.unpark_with_parts(self.dynhg.clone(), &parts_dyn, &self.ctx)
    }
}

/// Apply one change, recording new node ids and the touched neighborhood
/// (refinement seeds: every node whose gain structure the change shifts).
fn apply_change(
    hg: &mut DynamicHypergraph,
    change: &Change,
    new_nodes: &mut Vec<NodeId>,
    touched: &mut Vec<NodeId>,
) -> Result<(), String> {
    match change {
        Change::InsertNode { weight } => {
            let u = hg.insert_node(*weight)?;
            new_nodes.push(u);
            touched.push(u);
        }
        Change::RemoveNode { node } => {
            for &e in HypergraphOps::incident_nets(hg, *node) {
                for &p in HypergraphOps::pins(hg, e) {
                    if p != *node {
                        touched.push(p);
                    }
                }
            }
            hg.remove_node(*node)?;
        }
        Change::InsertNet { pins, weight } => {
            hg.insert_net(pins, *weight)?;
            touched.extend_from_slice(pins);
        }
        Change::RemoveNet { net } => {
            if (*net as usize) < HypergraphOps::num_nets(hg) {
                touched.extend_from_slice(HypergraphOps::pins(hg, *net));
            }
            hg.remove_net(*net)?;
        }
        Change::UpdateWeight { node, weight } => {
            hg.update_weight(*node, *weight)?;
            touched.push(*node);
        }
    }
    Ok(())
}

/// Revert migrations cheapest-first until the bound holds. Deterministic:
/// candidates are ordered by (revert gain desc, node id), reverts run
/// sequentially through balance-checked moves. Returns the remaining
/// migrated weight (the bound can stay violated when reverts would
/// overload blocks; the caller reports `bound_satisfied` accordingly).
fn enforce_migration_bound(
    phg: &PartitionedHypergraph<DynamicHypergraph>,
    ctx: &Context,
    migrated: &mut Vec<(NodeId, BlockId, BlockId)>,
    mut migrated_weight: NodeWeight,
    limit: NodeWeight,
) -> NodeWeight {
    with_policy!(ctx.objective, P => {
        let mut order: Vec<(i64, NodeId, BlockId)> =
            migrated.iter().map(|&(u, from, _)| (phg.gain_p::<P>(u, from), u, from)).collect();
        // revert the cheapest migrations first: highest revert gain means
        // the move bought the least quality for its migration cost
        order.sort_unstable_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        let mut reverted: Vec<NodeId> = Vec::new();
        for &(_, u, from) in &order {
            if migrated_weight <= limit {
                break;
            }
            if phg.try_move_p::<P>(u, from, None).is_some() {
                migrated_weight -= HypergraphOps::node_weight(phg.hypergraph(), u);
                reverted.push(u);
            }
        }
        // reverted is in revert order, not sorted — linear containment is
        // fine for the typically-small revert set
        migrated.retain(|&(u, _, _)| !reverted.contains(&u));
    });
    migrated_weight
}

// ---------------------------------------------------------------------
// Long-lived session: cached partitions keyed by instance hash
// ---------------------------------------------------------------------

/// Structural hash of the *active* state of a hypergraph: node ids and
/// weights, plus per-net weight and an order-independent pin digest (pin
/// order inside a net is not canonical on the dynamic structure). Two
/// instances hash equal iff they expose the same active nodes/nets in
/// the same id space — exactly when a cached assignment is reusable.
pub fn instance_hash<H: HypergraphOps>(hg: &H) -> u64 {
    #[inline]
    fn splitmix(mut x: u64) -> u64 {
        x = x.wrapping_add(0x9e3779b97f4a7c15);
        x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
        x ^ (x >> 31)
    }
    let mut h: u64 = 0xcbf29ce484222325;
    let mut mix = |v: u64| {
        h = (h ^ splitmix(v)).wrapping_mul(0x100000001b3);
    };
    for u in 0..hg.num_nodes() as NodeId {
        if hg.is_active_node(u) {
            mix(u as u64);
            mix(hg.node_weight(u) as u64);
        }
    }
    for e in hg.nets() {
        let pins = hg.pins(e);
        if pins.is_empty() {
            continue; // removed / emptied slots are structurally absent
        }
        let mut digest: u64 = 0;
        for &p in pins {
            digest ^= splitmix(p as u64);
        }
        mix(e as u64);
        mix(digest);
        mix(hg.net_weight(e) as u64);
    }
    h
}

/// Long-lived serving mode: bind instances, stream change batches, and
/// cache partitions keyed by [`instance_hash`] so a previously seen
/// instance warm-starts without a multilevel run.
pub struct RepartitionSession {
    ctx: Context,
    cfg: RepartitionConfig,
    rep: Option<Repartitioner>,
    cache: FxHashMap<u64, Vec<BlockId>>,
    hits: usize,
    misses: usize,
}

impl RepartitionSession {
    pub fn new(ctx: Context, cfg: RepartitionConfig) -> Self {
        RepartitionSession { ctx, cfg, rep: None, cache: FxHashMap::default(), hits: 0, misses: 0 }
    }

    /// Bind an instance: a cache hit restores the stored assignment (no
    /// multilevel run), a miss pays the cold start once and caches it.
    pub fn bind(&mut self, hg: Arc<Hypergraph>) -> &mut Repartitioner {
        self.stash_current();
        let key = instance_hash(&*hg);
        let rep = match self.cache.get(&key) {
            Some(parts) if parts.len() == hg.num_nodes() => {
                self.hits += 1;
                Repartitioner::new_with_parts(hg, parts, self.ctx.clone(), self.cfg.clone())
            }
            _ => {
                self.misses += 1;
                let rep = Repartitioner::new(hg, self.ctx.clone(), self.cfg.clone());
                self.cache.insert(key, rep.partition().parts());
                rep
            }
        };
        self.rep = Some(rep);
        self.rep.as_mut().unwrap()
    }

    /// Apply a batch through the bound repartitioner and re-cache the
    /// post-batch assignment under the mutated instance's hash.
    pub fn apply(&mut self, batch: &ChangeBatch) -> Result<MoveSet, String> {
        let rep = self.rep.as_mut().ok_or_else(|| "no instance bound".to_string())?;
        let result = rep.apply(batch)?;
        let key = instance_hash(rep.hypergraph());
        self.cache.insert(key, rep.partition().parts());
        Ok(result)
    }

    /// Cache the currently bound partition under its current hash (also
    /// runs automatically when `bind` replaces the instance).
    pub fn stash_current(&mut self) {
        if let Some(rep) = &self.rep {
            let key = instance_hash(rep.hypergraph());
            self.cache.insert(key, rep.partition().parts());
        }
    }

    pub fn repartitioner(&self) -> Option<&Repartitioner> {
        self.rep.as_ref()
    }

    pub fn cache_hits(&self) -> usize {
        self.hits
    }

    pub fn cache_misses(&self) -> usize {
        self.misses
    }
}

// ---------------------------------------------------------------------
// Change-stream parsing (the CLI's `--repartition changes.txt`)
// ---------------------------------------------------------------------

/// Parse a change stream. Line format (`%`/`#` start comments):
///
/// ```text
/// insert-node <weight>
/// remove-node <node>
/// insert-net <weight> <pin> <pin> ...
/// remove-net <net>
/// update-weight <node> <weight>
/// apply
/// ```
///
/// `apply` closes the current batch; a trailing batch without `apply` is
/// flushed at end of file.
pub fn parse_changes(path: &Path) -> IoResult<Vec<ChangeBatch>> {
    fn num<'a>(
        tok: &mut impl Iterator<Item = &'a str>,
        lineno: usize,
        op: &str,
        what: &str,
    ) -> IoResult<i64> {
        tok.next()
            .ok_or_else(|| {
                crate::util::error::Error::msg(format!(
                    "line {lineno}: '{op}' is missing its {what}"
                ))
            })?
            .parse::<i64>()
            .with_context(|| format!("line {lineno}: bad {what}"))
    }
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("cannot read change stream {}", path.display()))?;
    let mut batches: Vec<ChangeBatch> = Vec::new();
    let mut current = ChangeBatch::new();
    for (i, raw) in text.lines().enumerate() {
        let lineno = i + 1;
        let line = raw.split(['%', '#']).next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut tok = line.split_whitespace();
        let op = tok.next().unwrap();
        match op {
            "insert-node" => {
                current.push(Change::InsertNode { weight: num(&mut tok, lineno, op, "weight")? });
            }
            "remove-node" => {
                current.push(Change::RemoveNode {
                    node: num(&mut tok, lineno, op, "node id")? as NodeId,
                });
            }
            "insert-net" => {
                let weight = num(&mut tok, lineno, op, "weight")?;
                let mut pins: Vec<NodeId> = Vec::new();
                for t in tok.by_ref() {
                    pins.push(
                        t.parse::<NodeId>()
                            .with_context(|| format!("line {lineno}: bad pin '{t}'"))?,
                    );
                }
                current.push(Change::InsertNet { pins, weight });
            }
            "remove-net" => {
                current.push(Change::RemoveNet {
                    net: num(&mut tok, lineno, op, "net id")? as EdgeId,
                });
            }
            "update-weight" => {
                let node = num(&mut tok, lineno, op, "node id")? as NodeId;
                let weight = num(&mut tok, lineno, op, "weight")?;
                current.push(Change::UpdateWeight { node, weight });
            }
            "apply" => {
                batches.push(std::mem::take(&mut current));
            }
            other => {
                crate::bail!("line {lineno}: unknown change op '{other}'");
            }
        }
        if tok.next().is_some() && op != "insert-net" {
            crate::bail!("line {lineno}: trailing tokens after '{op}'");
        }
    }
    if !current.is_empty() {
        batches.push(current);
    }
    Ok(batches)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::Preset;
    use crate::generators::{planted_hypergraph, PlantedParams};

    fn small_ctx(k: usize) -> Context {
        let mut c = Context::new(Preset::Default, k, 0.1).with_threads(2).with_seed(5);
        c.contraction_limit_factor = 24;
        c.ip_min_repetitions = 1;
        c.ip_max_repetitions = 2;
        c.fm_max_rounds = 2;
        c
    }

    fn small_instance(seed: u64) -> Arc<Hypergraph> {
        Arc::new(planted_hypergraph(
            &PlantedParams { n: 300, m: 500, blocks: 4, ..Default::default() },
            seed,
        ))
    }

    #[test]
    fn apply_smoke_insert_remove_update() {
        let hg = small_instance(11);
        let mut rep = Repartitioner::new(hg, small_ctx(4), RepartitionConfig::default());
        let mut batch = ChangeBatch::new();
        batch.push(Change::InsertNode { weight: 2 });
        batch.push(Change::UpdateWeight { node: 3, weight: 4 });
        batch.push(Change::RemoveNode { node: 17 });
        batch.push(Change::InsertNet { pins: vec![1, 2, 5], weight: 1 });
        let ms = rep.apply(&batch).unwrap();
        assert_eq!(ms.placements.len(), 1);
        assert!(ms.balanced, "imbalance {}", ms.imbalance);
        rep.hypergraph().validate().unwrap();
        rep.partition().verify_consistency().unwrap();
        // the new node got a real block
        let (u, b) = ms.placements[0];
        assert_eq!(rep.partition().block_of(u), b);
    }

    #[test]
    fn apply_error_keeps_state_consistent() {
        let hg = small_instance(13);
        let mut rep = Repartitioner::new(hg, small_ctx(4), RepartitionConfig::default());
        let before_nodes = rep.hypergraph().num_active_nodes();
        let mut batch = ChangeBatch::new();
        batch.push(Change::RemoveNode { node: 5 });
        batch.push(Change::RemoveNode { node: 5 }); // double removal: error
        batch.push(Change::InsertNode { weight: 1 }); // never reached
        let err = rep.apply(&batch).unwrap_err();
        assert!(err.contains("not active"), "{err}");
        // the applied prefix stands, the partition is consistent on it
        assert_eq!(rep.hypergraph().num_active_nodes(), before_nodes - 1);
        rep.hypergraph().validate().unwrap();
        rep.partition().verify_consistency().unwrap();
        // and the next batch runs normally
        let ms = rep.apply(&ChangeBatch::new()).unwrap();
        assert!(ms.moves.is_empty() || ms.balanced);
    }

    #[test]
    fn session_caches_by_instance_hash() {
        let hg = small_instance(17);
        let mut session =
            RepartitionSession::new(small_ctx(4), RepartitionConfig::default());
        session.bind(hg.clone());
        assert_eq!(session.cache_misses(), 1);
        let obj = session.repartitioner().unwrap().partition().km1();
        // re-binding the identical instance is a hit, not a second run
        session.bind(hg);
        assert_eq!(session.cache_hits(), 1);
        assert_eq!(session.cache_misses(), 1);
        assert_eq!(session.repartitioner().unwrap().partition().km1(), obj);
    }

    #[test]
    fn instance_hash_tracks_structure_not_pin_order() {
        let hg = small_instance(19);
        let d1 = DynamicHypergraph::from_hypergraph(&hg);
        let mut d2 = DynamicHypergraph::from_hypergraph(&hg);
        assert_eq!(instance_hash(&d1), instance_hash(&*hg));
        // a contract/uncontract round-trip permutes pins within nets but
        // restores the same structure
        let m = d2.contract(1, 0);
        let h_contracted = instance_hash(&d2);
        d2.uncontract_batch(&[m]);
        assert_eq!(instance_hash(&d1), instance_hash(&d2));
        assert_ne!(instance_hash(&d1), h_contracted);
        // mutations change the hash
        let mut d3 = DynamicHypergraph::from_hypergraph(&hg);
        d3.update_weight(0, 5).unwrap();
        assert_ne!(instance_hash(&d1), instance_hash(&d3));
    }

    #[test]
    fn parse_changes_roundtrip() {
        let dir = std::env::temp_dir();
        let path = dir.join("mtkh_test_changes.txt");
        std::fs::write(
            &path,
            "% a comment\ninsert-node 2\ninsert-net 1 0 4 9 % inline\napply\n\
             remove-net 3\nupdate-weight 7 5\napply\nremove-node 1\n",
        )
        .unwrap();
        let batches = parse_changes(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(batches.len(), 3, "trailing batch flushed at EOF");
        assert_eq!(batches[0].len(), 2);
        assert!(matches!(batches[0].changes[0], Change::InsertNode { weight: 2 }));
        assert!(
            matches!(&batches[0].changes[1], Change::InsertNet { pins, weight: 1 } if pins == &[0, 4, 9])
        );
        assert_eq!(batches[1].len(), 2);
        assert_eq!(batches[2].len(), 1);
    }

    #[test]
    fn parse_changes_rejects_garbage() {
        let dir = std::env::temp_dir();
        let path = dir.join("mtkh_test_changes_bad.txt");
        std::fs::write(&path, "frobnicate 3\n").unwrap();
        assert!(parse_changes(&path).is_err());
        std::fs::write(&path, "insert-node\n").unwrap();
        assert!(parse_changes(&path).is_err(), "missing weight");
        std::fs::write(&path, "remove-node 3 4\n").unwrap();
        assert!(parse_changes(&path).is_err(), "trailing tokens");
        std::fs::remove_file(&path).ok();
    }
}
