//! Internal baseline partitioners: the three algorithm classes the
//! paper's 25-solver comparison reduces to, built on the same substrates
//! so differences isolate the *algorithmic* gap:
//!
//! * **PaToH-like** — sequential multilevel with matching-based
//!   coarsening and a single LP+weak-FM pass (fast sequential class:
//!   PaToH-D/-Q, Metis),
//! * **Zoltan-like** — parallel multilevel with LP-only refinement and no
//!   community-aware coarsening (distributed/fast-parallel class:
//!   Zoltan, ParMetis, KaMinPar for graphs),
//! * **BiPart-like** — deterministic multilevel with synchronous LP, a
//!   non-adaptive 1-repetition portfolio and coarse sub-rounds (the
//!   deterministic class: BiPart).

use crate::coarsening::matching;
use crate::coordinator::context::{Context, Preset};
use crate::coordinator::partitioner;
use crate::hypergraph::{contraction, Hypergraph};
use crate::initial;
use crate::partition::PartitionedHypergraph;
use crate::refinement::lp;
use crate::BlockId;
use std::sync::Arc;

/// Sequential PaToH-like multilevel partitioner.
pub fn patoh_like(hg: &Arc<Hypergraph>, ctx_in: &Context) -> PartitionedHypergraph {
    let mut ctx = ctx_in.clone();
    ctx.threads = 1;
    ctx.use_community_detection = false;
    ctx.use_flows = false;
    ctx.fm_max_rounds = 2;
    ctx.ip_min_repetitions = 1;
    ctx.ip_max_repetitions = 3;
    // standalone driver: arm the deadline for this run
    ctx.cancel.arm(ctx.time_limit);

    // matching-based coarsening hierarchy
    let limit = ctx.contraction_limit().max(2 * ctx.k);
    let cmax = ctx.max_cluster_weight(hg.total_weight());
    let mut levels: Vec<crate::coarsening::Level> = Vec::new();
    let mut current = hg.clone();
    while current.num_nodes() > limit {
        // cancellation checkpoint (same pass-boundary discipline as the
        // main coarsener: a shorter hierarchy is fully usable)
        if ctx.cancel.is_expired() {
            ctx.cancel.note_early_stop();
            break;
        }
        let n_before = current.num_nodes();
        let rep = matching::match_nodes(&current, cmax, ctx.seed ^ levels.len() as u64);
        let c = contraction::contract(&current, &rep, 1);
        if n_before - c.coarse.num_nodes() <= n_before / 100 {
            break;
        }
        let coarse = Arc::new(c.coarse);
        levels.push(crate::coarsening::Level {
            coarse: coarse.clone(),
            fine_to_coarse: c.fine_to_coarse,
            net_map: c.net_map,
        });
        current = coarse;
    }
    let parts = initial::initial_partition(current.clone(), &ctx);
    // uncoarsen on the pooled workspace partition (zero per-level
    // structural allocations, same as the main multilevel driver); the
    // coarsest refine carries its level distance for level-gated refiners
    let mut pipeline = crate::refinement::RefinementPipeline::new_for(&ctx, hg);
    let phg = pipeline.bind(current, &parts, &ctx);
    pipeline.refine_at_distance(&phg, &ctx, levels.len());
    pipeline.uncoarsen(&levels, hg, phg, &ctx)
}

/// Parallel LP-only multilevel (Zoltan / KaMinPar class).
pub fn zoltan_like(hg: &Arc<Hypergraph>, ctx_in: &Context) -> PartitionedHypergraph {
    let mut ctx = ctx_in.clone();
    ctx.use_fm = false;
    ctx.use_flows = false;
    ctx.use_community_detection = false;
    ctx.ip_min_repetitions = 1;
    ctx.ip_max_repetitions = 3;
    partitioner::partition_arc(hg.clone(), &ctx)
}

/// Deterministic BiPart-like partitioner: synchronous LP, no portfolio
/// adaptivity, coarse sub-rounds, no community detection.
pub fn bipart_like(hg: &Arc<Hypergraph>, ctx_in: &Context) -> PartitionedHypergraph {
    let mut ctx = Context::new(Preset::Deterministic, ctx_in.k, ctx_in.epsilon)
        .with_threads(ctx_in.threads)
        .with_seed(ctx_in.seed);
    ctx.use_community_detection = false;
    // BiPart has no FM at all — pin the baseline to synchronous LP even
    // though our Deterministic preset now runs det-FM as well
    ctx.use_fm = false;
    ctx.det_sub_rounds = 2; // coarser synchronization = weaker decisions
    ctx.lp_rounds = 2;
    ctx.ip_min_repetitions = 1;
    ctx.ip_max_repetitions = 1;
    ctx.contraction_limit_factor = ctx_in.contraction_limit_factor;
    // the fresh Context must still honor the caller's wall-clock budget
    ctx.time_limit = ctx_in.time_limit;
    partitioner::partition_arc(hg.clone(), &ctx)
}

/// Flat (non-multilevel) LP partitioning — the control showing why the
/// multilevel scheme matters (paper §12's "faster methods omitting the
/// multilevel scheme are inferior").
pub fn flat_lp(hg: &Arc<Hypergraph>, ctx_in: &Context) -> PartitionedHypergraph {
    let ctx = ctx_in.clone();
    // random balanced start, LP only
    let n = hg.num_nodes();
    let mut rng = crate::util::Rng::new(ctx.seed);
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    let mut parts: Vec<BlockId> = vec![0; n];
    for (i, &u) in order.iter().enumerate() {
        parts[u as usize] = (i % ctx.k) as BlockId;
    }
    let mut phg = PartitionedHypergraph::new(hg.clone(), ctx.k);
    phg.set_uniform_max_weight(ctx.epsilon);
    phg.assign_all(&parts, ctx.threads);
    lp::lp_refine(&phg, &ctx);
    phg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators::{planted_hypergraph, PlantedParams};

    fn ctx(k: usize) -> Context {
        let mut c = Context::new(Preset::Default, k, 0.03).with_threads(2).with_seed(1);
        c.contraction_limit_factor = 24;
        c.ip_min_repetitions = 1;
        c.ip_max_repetitions = 2;
        c.fm_max_rounds = 2;
        c
    }

    #[test]
    fn baselines_produce_feasible_partitions() {
        let hg = Arc::new(planted_hypergraph(
            &PlantedParams { n: 500, m: 900, blocks: 4, ..Default::default() },
            3,
        ));
        for (name, phg) in [
            ("patoh", patoh_like(&hg, &ctx(4))),
            ("zoltan", zoltan_like(&hg, &ctx(4))),
            ("bipart", bipart_like(&hg, &ctx(4))),
        ] {
            assert!(phg.is_balanced(), "{name} imbalance {}", phg.imbalance());
            phg.verify_consistency().unwrap();
        }
    }

    #[test]
    fn multilevel_beats_flat_lp() {
        let hg = Arc::new(planted_hypergraph(
            &PlantedParams { n: 600, m: 1100, blocks: 4, p_intra: 0.9, ..Default::default() },
            7,
        ));
        let ml = partitioner::partition_arc(hg.clone(), &ctx(4)).km1();
        let flat = flat_lp(&hg, &ctx(4)).km1();
        assert!(ml < flat, "multilevel {ml} vs flat {flat}");
    }

    #[test]
    fn quality_hierarchy_mt_vs_baselines() {
        // Mt-KaHyPar-D ≥ Zoltan-like in quality (the paper's headline)
        let mut d_total = 0i64;
        let mut z_total = 0i64;
        for seed in 0..3u64 {
            let hg = Arc::new(planted_hypergraph(
                &PlantedParams { n: 500, m: 900, blocks: 4, p_intra: 0.88, ..Default::default() },
                seed,
            ));
            let mut c = ctx(4);
            c.seed = seed;
            d_total += partitioner::partition_arc(hg.clone(), &c).km1();
            z_total += zoltan_like(&hg, &c).km1();
        }
        assert!(d_total <= z_total, "D {d_total} vs Zoltan-like {z_total}");
    }
}
