//! Evaluation toolkit (paper §12): benchmark suites, performance
//! profiles, effectiveness tests, aggregation and the internal baseline
//! partitioners the comparison figures are regenerated against.

pub mod baselines;
pub mod profiles;
pub mod suites;

use crate::coordinator::context::Context;
use crate::coordinator::partitioner;
use crate::hypergraph::Hypergraph;
use crate::metrics;
use crate::BlockId;
use std::sync::Arc;
use std::time::Instant;

/// One measured run of an algorithm on an instance.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub algorithm: String,
    pub instance: String,
    pub k: usize,
    pub quality: i64,
    pub imbalance: f64,
    pub feasible: bool,
    pub seconds: f64,
}

/// Run a hypergraph config once and measure it.
pub fn run_hg(
    name: &str,
    hg: &Arc<Hypergraph>,
    instance: &str,
    ctx: &Context,
) -> RunResult {
    let start = Instant::now();
    let phg = partitioner::partition_arc(hg.clone(), ctx);
    let seconds = start.elapsed().as_secs_f64();
    RunResult {
        algorithm: name.to_string(),
        instance: instance.to_string(),
        k: ctx.k,
        // quality under the run's *configured* objective (km1 by default)
        quality: phg.objective_value(ctx.objective),
        imbalance: phg.imbalance(),
        feasible: phg.is_balanced(),
        seconds,
    }
}

/// Arithmetic-mean quality and geometric-mean time per (algorithm,
/// instance) over seeds — the paper's per-instance aggregation.
pub fn aggregate_seeds(results: &[RunResult]) -> Vec<RunResult> {
    use std::collections::BTreeMap;
    let mut groups: BTreeMap<(String, String, usize), Vec<&RunResult>> = BTreeMap::new();
    for r in results {
        groups.entry((r.algorithm.clone(), r.instance.clone(), r.k)).or_default().push(r);
    }
    groups
        .into_iter()
        .map(|((algorithm, instance, k), rs)| RunResult {
            algorithm,
            instance,
            k,
            quality: (rs.iter().map(|r| r.quality as f64).sum::<f64>() / rs.len() as f64)
                .round() as i64,
            imbalance: rs.iter().map(|r| r.imbalance).fold(f64::MIN, f64::max),
            feasible: rs.iter().all(|r| r.feasible),
            seconds: crate::util::stats::geometric_mean(
                &rs.iter().map(|r| r.seconds).collect::<Vec<_>>(),
            ),
            })
        .collect()
}

/// Verify a partition against from-scratch metrics (sanity for benches).
pub fn verify_result(hg: &Hypergraph, parts: &[BlockId], k: usize, reported: i64) -> bool {
    metrics::km1(hg, parts, k) == reported
}

/// Quick Markdown-ish table printer shared by the bench binaries.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n## {title}");
    println!("| {} |", header.join(" | "));
    println!("|{}|", header.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
    for row in rows {
        println!("| {} |", row.join(" | "));
    }
}
