//! Benchmark suites — scaled synthetic stand-ins for the paper's
//! M_HG / L_HG / M_G / L_G sets (substitution rationale in DESIGN.md §2).
//! Sizes scale with `MTK_BENCH_SCALE` (default 1; the paper-shape claims
//! are already visible at scale 1 on this 1-vCPU testbed).

use crate::generators::{self, PlantedParams, SatRepresentation};
use crate::graph::Graph;
use crate::hypergraph::Hypergraph;
use std::sync::Arc;

pub struct HgInstance {
    pub name: String,
    pub hg: Arc<Hypergraph>,
}

pub struct GraphInstance {
    pub name: String,
    pub g: Arc<Graph>,
}

fn scale() -> usize {
    std::env::var("MTK_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(1)
}

/// Medium hypergraph suite (M_HG archetypes: ISPD98 VLSI, SPM, SAT
/// PRIMAL/DUAL/LITERAL).
pub fn suite_mhg() -> Vec<HgInstance> {
    let s = scale();
    let mut out = Vec::new();
    for seed in 0..2u64 {
        out.push(HgInstance {
            name: format!("vlsi_{seed}"),
            hg: Arc::new(generators::vlsi_hypergraph(1500 * s, 2200 * s, seed)),
        });
        out.push(HgInstance {
            name: format!("spm_{seed}"),
            hg: Arc::new(generators::spm_hypergraph(1200 * s, 1200 * s, 6, seed)),
        });
        out.push(HgInstance {
            name: format!("sat_primal_{seed}"),
            hg: Arc::new(generators::sat_hypergraph(600 * s, 2400 * s, SatRepresentation::Primal, seed)),
        });
        out.push(HgInstance {
            name: format!("sat_dual_{seed}"),
            hg: Arc::new(generators::sat_hypergraph(600 * s, 2400 * s, SatRepresentation::Dual, seed)),
        });
        out.push(HgInstance {
            name: format!("planted_{seed}"),
            hg: Arc::new(generators::planted_hypergraph(
                &PlantedParams { n: 2000 * s, m: 3600 * s, blocks: 8, ..Default::default() },
                seed,
            )),
        });
    }
    out
}

/// Large hypergraph suite (L_HG: bigger SAT + SPM instances).
pub fn suite_lhg() -> Vec<HgInstance> {
    let s = scale();
    let mut out = Vec::new();
    for seed in 0..2u64 {
        out.push(HgInstance {
            name: format!("L_spm_{seed}"),
            hg: Arc::new(generators::spm_hypergraph(6000 * s, 6000 * s, 8, seed)),
        });
        out.push(HgInstance {
            name: format!("L_sat_literal_{seed}"),
            hg: Arc::new(generators::sat_hypergraph(
                2500 * s,
                9000 * s,
                SatRepresentation::Literal,
                seed,
            )),
        });
        out.push(HgInstance {
            name: format!("L_planted_{seed}"),
            hg: Arc::new(generators::planted_hypergraph(
                &PlantedParams { n: 8000 * s, m: 14000 * s, blocks: 16, ..Default::default() },
                seed,
            )),
        });
    }
    out
}

/// Medium graph suite (M_G: DIMACS meshes + social networks).
pub fn suite_mg() -> Vec<GraphInstance> {
    let s = scale();
    let mut out = Vec::new();
    out.push(GraphInstance {
        name: "mesh_40x40".into(),
        g: Arc::new(generators::mesh_graph(40 * s, 40 * s)),
    });
    out.push(GraphInstance {
        name: "mesh_64x25".into(),
        g: Arc::new(generators::mesh_graph(64 * s, 25 * s)),
    });
    for seed in 0..2u64 {
        out.push(GraphInstance {
            name: format!("social_rmat_{seed}"),
            g: Arc::new(generators::rmat_graph(11, 8, seed)),
        });
    }
    out
}

/// Large graph suite (L_G).
pub fn suite_lg() -> Vec<GraphInstance> {
    let mut out = Vec::new();
    out.push(GraphInstance {
        name: "L_mesh_90x90".into(),
        g: Arc::new(generators::mesh_graph(90, 90)),
    });
    for seed in 0..2u64 {
        out.push(GraphInstance {
            name: format!("L_social_rmat_{seed}"),
            g: Arc::new(generators::rmat_graph(13, 10, seed)),
        });
    }
    out
}

/// Fig. 8 analogue: print per-instance structure statistics.
pub fn print_suite_stats(instances: &[HgInstance]) {
    println!("\n## Benchmark-set statistics (paper Fig. 8 analogue)");
    println!("| instance | n | m | pins | med |e| | max |e| | med d(v) | max d(v) |");
    println!("|---|---|---|---|---|---|---|---|");
    for inst in instances {
        let hg = &inst.hg;
        let mut sizes: Vec<usize> = hg.nets().map(|e| hg.net_size(e)).collect();
        sizes.sort_unstable();
        let mut degs: Vec<usize> = hg.nodes().map(|u| hg.degree(u)).collect();
        degs.sort_unstable();
        let med = |v: &[usize]| if v.is_empty() { 0 } else { v[v.len() / 2] };
        println!(
            "| {} | {} | {} | {} | {} | {} | {} | {} |",
            inst.name,
            hg.num_nodes(),
            hg.num_nets(),
            hg.num_pins(),
            med(&sizes),
            sizes.last().copied().unwrap_or(0),
            med(&degs),
            degs.last().copied().unwrap_or(0),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suites_are_valid() {
        for inst in suite_mhg() {
            inst.hg.validate().unwrap();
        }
        for inst in suite_mg() {
            inst.g.validate().unwrap();
        }
    }
}
