//! Performance profiles and effectiveness tests (paper §12).

use super::RunResult;
use crate::util::Rng;
use std::collections::BTreeMap;

/// Performance-profile point: fraction of instances with
/// `q_A(I) ≤ τ · Best(I)`.
#[derive(Clone, Debug)]
pub struct ProfileLine {
    pub algorithm: String,
    /// (τ, fraction) samples
    pub points: Vec<(f64, f64)>,
    /// fraction of instances where this algorithm was (tied-)best (τ=1)
    pub best_fraction: f64,
    /// fraction of instances with infeasible results
    pub infeasible_fraction: f64,
}

/// Build performance profiles over per-instance aggregated results.
pub fn performance_profiles(results: &[RunResult], taus: &[f64]) -> Vec<ProfileLine> {
    // best feasible quality per instance
    let mut best: BTreeMap<(String, usize), f64> = BTreeMap::new();
    for r in results {
        let key = (r.instance.clone(), r.k);
        let q = effective_quality(r);
        best.entry(key).and_modify(|b| *b = b.min(q)).or_insert(q);
    }
    let mut algos: Vec<String> = results.iter().map(|r| r.algorithm.clone()).collect();
    algos.sort();
    algos.dedup();

    algos
        .into_iter()
        .map(|algo| {
            let mine: Vec<&RunResult> = results.iter().filter(|r| r.algorithm == algo).collect();
            let n = mine.len().max(1) as f64;
            let points: Vec<(f64, f64)> = taus
                .iter()
                .map(|&tau| {
                    let hits = mine
                        .iter()
                        .filter(|r| {
                            r.feasible
                                && effective_quality(r)
                                    <= tau * best[&(r.instance.clone(), r.k)] + 1e-9
                        })
                        .count();
                    (tau, hits as f64 / n)
                })
                .collect();
            let best_fraction = points.first().map(|&(_, f)| f).unwrap_or(0.0);
            let infeasible_fraction =
                mine.iter().filter(|r| !r.feasible).count() as f64 / n;
            ProfileLine { algorithm: algo, points, best_fraction, infeasible_fraction }
        })
        .collect()
}

fn effective_quality(r: &RunResult) -> f64 {
    // +1 smoothing keeps zero-cut instances comparable under ratios
    r.quality as f64 + 1.0
}

/// Default τ grid used in the bench binaries (paper plots use 1..2 plus
/// an overflow bucket).
pub fn default_taus() -> Vec<f64> {
    vec![1.0, 1.01, 1.05, 1.1, 1.2, 1.5, 2.0, 10.0]
}

/// Effectiveness tests (paper §12): build virtual instances giving the
/// faster algorithm extra repetitions until the time budget of the slower
/// one is used; quality = min over the sampled runs.
///
/// `runs_a`/`runs_b` are the per-seed (not aggregated) results of the two
/// algorithms on one instance. Returns `num_virtual` virtual (qualityA,
/// qualityB) pairs.
pub fn effectiveness_pairs(
    runs_a: &[&RunResult],
    runs_b: &[&RunResult],
    num_virtual: usize,
    seed: u64,
) -> Vec<(i64, i64)> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(num_virtual);
    for _ in 0..num_virtual {
        let ra = runs_a[rng.next_below(runs_a.len())];
        let rb = runs_b[rng.next_below(runs_b.len())];
        // the faster algorithm samples additional runs within the budget
        let (fast_runs, slow_run, fast_is_a) = if ra.seconds <= rb.seconds {
            (runs_a, rb, true)
        } else {
            (runs_b, ra, false)
        };
        let budget = slow_run.seconds;
        let mut used = if fast_is_a { ra.seconds } else { rb.seconds };
        let mut best_fast = if fast_is_a { ra.quality } else { rb.quality };
        let mut pool: Vec<usize> = (0..fast_runs.len()).collect();
        rng.shuffle(&mut pool);
        for &idx in &pool {
            if used >= budget {
                break;
            }
            let candidate = fast_runs[idx];
            let p_accept = ((budget - used) / candidate.seconds.max(1e-9)).min(1.0);
            used += candidate.seconds;
            if rng.next_f64() <= p_accept {
                best_fast = best_fast.min(candidate.quality);
            }
        }
        if fast_is_a {
            out.push((best_fast, slow_run.quality));
        } else {
            out.push((slow_run.quality, best_fast));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rr(algo: &str, inst: &str, q: i64, t: f64, feasible: bool) -> RunResult {
        RunResult {
            algorithm: algo.into(),
            instance: inst.into(),
            k: 2,
            quality: q,
            imbalance: 0.0,
            feasible,
            seconds: t,
        }
    }

    #[test]
    fn profile_fractions() {
        let results = vec![
            rr("A", "i1", 100, 1.0, true),
            rr("B", "i1", 110, 1.0, true),
            rr("A", "i2", 200, 1.0, true),
            rr("B", "i2", 200, 1.0, true),
        ];
        let profiles = performance_profiles(&results, &[1.0, 1.2]);
        let a = profiles.iter().find(|p| p.algorithm == "A").unwrap();
        let b = profiles.iter().find(|p| p.algorithm == "B").unwrap();
        assert!((a.best_fraction - 1.0).abs() < 1e-9);
        assert!((b.best_fraction - 0.5).abs() < 1e-9);
        // at τ=1.2 B covers both instances
        assert!((b.points[1].1 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn infeasible_counted() {
        let results = vec![rr("A", "i1", 10, 1.0, false), rr("A", "i2", 10, 1.0, true)];
        let profiles = performance_profiles(&results, &[1.0]);
        assert!((profiles[0].infeasible_fraction - 0.5).abs() < 1e-9);
    }

    #[test]
    fn effectiveness_gives_fast_algo_more_samples() {
        // A is 4× faster and sometimes lucky
        let a_runs: Vec<RunResult> = (0..8)
            .map(|i| rr("A", "i", if i == 0 { 90 } else { 100 }, 1.0, true))
            .collect();
        let b_runs: Vec<RunResult> = (0..8).map(|_| rr("B", "i", 95, 4.0, true)).collect();
        let ar: Vec<&RunResult> = a_runs.iter().collect();
        let br: Vec<&RunResult> = b_runs.iter().collect();
        let pairs = effectiveness_pairs(&ar, &br, 50, 7);
        // A's min over multiple samples should frequently reach 90
        let wins = pairs.iter().filter(|(a, b)| a < b).count();
        assert!(wins > 10, "A should often win: {wins}");
    }
}
