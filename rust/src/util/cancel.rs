//! Cooperative cancellation and the graceful-degradation ladder.
//!
//! A [`CancelToken`] is shared (via `Arc` on the
//! [`Context`](crate::coordinator::context::Context)) between the driver
//! and every component it runs. Drivers *arm* the token with the
//! configured wall-clock budget at entry; components poll it at their
//! natural checkpoints — LP/FM round boundaries, flow wave boundaries,
//! coarsening passes, IP repetitions, n-level batches — and stop cleanly
//! when it reports expiry. Nothing is ever interrupted mid-operation, so
//! the partition stays consistent at every checkpoint.
//!
//! Between "full stack" and "expired" the token exposes a pressure
//! [`DegradationLevel`] derived from the fraction of the budget already
//! spent. The refinement pipeline sheds work in quality order as pressure
//! rises (skip flows → cap FM rounds → LP only → rebalance only), so a
//! run under deadline always ends with a balanced partition rather than
//! a timeout.
//!
//! **Invariance:** an unarmed token (no `time_limit` configured) never
//! reads the clock — `is_expired()` is a pair of relaxed atomic loads and
//! `level()` is constant [`DegradationLevel::Full`]. With no deadline the
//! whole resilience layer is a no-op and results are bit-identical to a
//! build without it (the §11 determinism guarantees are untouched).

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

/// How much work the pipeline may shed under deadline pressure, in
/// quality order. Higher levels shed strictly more.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum DegradationLevel {
    /// no pressure: run the full refiner stack
    #[default]
    Full = 0,
    /// ≥ 50% of the budget spent: skip flow refinement
    SkipFlows = 1,
    /// ≥ 70%: additionally cap FM at one round per level
    CapFm = 2,
    /// ≥ 85%: LP + rebalance only
    LpOnly = 3,
    /// expired (or forced): rebalance only — feasibility, not quality
    RebalanceOnly = 4,
}

impl DegradationLevel {
    fn from_u8(v: u8) -> Self {
        match v {
            0 => DegradationLevel::Full,
            1 => DegradationLevel::SkipFlows,
            2 => DegradationLevel::CapFm,
            3 => DegradationLevel::LpOnly,
            _ => DegradationLevel::RebalanceOnly,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DegradationLevel::Full => "full",
            DegradationLevel::SkipFlows => "skip-flows",
            DegradationLevel::CapFm => "cap-fm",
            DegradationLevel::LpOnly => "lp-only",
            DegradationLevel::RebalanceOnly => "rebalance-only",
        }
    }
}

/// Shared deadline token. All timestamps are nanoseconds relative to the
/// token's creation instant so they fit in atomics; `u64::MAX` means
/// "unarmed". Cheap enough to poll at every round/wave/batch boundary.
pub struct CancelToken {
    origin: Instant,
    /// ns offset at which the current run was armed (`MAX` = unarmed)
    armed_ns: AtomicU64,
    /// ns offset of the deadline (`MAX` = none)
    deadline_ns: AtomicU64,
    /// explicit cancellation / forced expiry (failpoints, callers)
    forced: AtomicBool,
    /// high-water mark of observed degradation levels
    max_level: AtomicU8,
    // ---- shed accounting for the DegradationReport ----
    pub(crate) flows_shed: AtomicUsize,
    pub(crate) fm_capped: AtomicUsize,
    pub(crate) fm_shed: AtomicUsize,
    pub(crate) lp_shed: AtomicUsize,
    pub(crate) early_stops: AtomicUsize,
    pub(crate) panics_recovered: AtomicUsize,
}

impl Default for CancelToken {
    fn default() -> Self {
        Self::new()
    }
}

impl CancelToken {
    pub fn new() -> Self {
        CancelToken {
            origin: Instant::now(),
            armed_ns: AtomicU64::new(u64::MAX),
            deadline_ns: AtomicU64::new(u64::MAX),
            forced: AtomicBool::new(false),
            max_level: AtomicU8::new(0),
            flows_shed: AtomicUsize::new(0),
            fm_capped: AtomicUsize::new(0),
            fm_shed: AtomicUsize::new(0),
            lp_shed: AtomicUsize::new(0),
            early_stops: AtomicUsize::new(0),
            panics_recovered: AtomicUsize::new(0),
        }
    }

    #[inline]
    fn now_ns(&self) -> u64 {
        self.origin.elapsed().as_nanos().min(u64::MAX as u128 - 1) as u64
    }

    /// Arm (or disarm, with `None`) the token for one driver run. Called
    /// by every driver at entry; re-arming restarts the budget clock and
    /// clears a previous run's forced expiry (the shed counters are
    /// cumulative for the token's lifetime).
    pub fn arm(&self, limit: Option<Duration>) {
        self.forced.store(false, Ordering::Relaxed);
        match limit {
            Some(d) => {
                let now = self.now_ns();
                let dl = now.saturating_add(d.as_nanos().min(u64::MAX as u128 - 1) as u64);
                self.armed_ns.store(now, Ordering::Relaxed);
                self.deadline_ns.store(dl, Ordering::Relaxed);
            }
            None => {
                self.armed_ns.store(u64::MAX, Ordering::Relaxed);
                self.deadline_ns.store(u64::MAX, Ordering::Relaxed);
            }
        }
    }

    /// Force immediate expiry (explicit cancellation; also the failpoint
    /// `Expire` action).
    pub fn force_expire(&self) {
        self.forced.store(true, Ordering::Relaxed);
        self.max_level.fetch_max(DegradationLevel::RebalanceOnly as u8, Ordering::Relaxed);
    }

    /// Has the deadline passed (or expiry been forced)? Reads the clock
    /// only when a deadline is armed.
    #[inline]
    pub fn is_expired(&self) -> bool {
        if self.forced.load(Ordering::Relaxed) {
            return true;
        }
        let dl = self.deadline_ns.load(Ordering::Relaxed);
        dl != u64::MAX && self.now_ns() >= dl
    }

    /// Current pressure level. Constant `Full` while unarmed.
    pub fn level(&self) -> DegradationLevel {
        if self.forced.load(Ordering::Relaxed) {
            return DegradationLevel::RebalanceOnly;
        }
        let armed = self.armed_ns.load(Ordering::Relaxed);
        let dl = self.deadline_ns.load(Ordering::Relaxed);
        if armed == u64::MAX || dl == u64::MAX {
            return DegradationLevel::Full;
        }
        let now = self.now_ns();
        let level = if now >= dl {
            DegradationLevel::RebalanceOnly
        } else {
            let spent = (now - armed) as f64 / (dl - armed).max(1) as f64;
            if spent >= 0.85 {
                DegradationLevel::LpOnly
            } else if spent >= 0.70 {
                DegradationLevel::CapFm
            } else if spent >= 0.50 {
                DegradationLevel::SkipFlows
            } else {
                DegradationLevel::Full
            }
        };
        self.max_level.fetch_max(level as u8, Ordering::Relaxed);
        level
    }

    /// Highest pressure level observed so far (for reporting).
    pub fn max_level(&self) -> DegradationLevel {
        DegradationLevel::from_u8(self.max_level.load(Ordering::Relaxed))
    }

    /// Record that a component stopped early at a cancellation checkpoint.
    #[inline]
    pub fn note_early_stop(&self) {
        self.early_stops.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a worker/refiner panic that was isolated and repaired.
    #[inline]
    pub fn note_panic_recovered(&self) {
        self.panics_recovered.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unarmed_token_is_inert() {
        let t = CancelToken::new();
        assert!(!t.is_expired());
        assert_eq!(t.level(), DegradationLevel::Full);
        assert_eq!(t.max_level(), DegradationLevel::Full);
    }

    #[test]
    fn zero_budget_expires_immediately() {
        let t = CancelToken::new();
        t.arm(Some(Duration::ZERO));
        assert!(t.is_expired());
        assert_eq!(t.level(), DegradationLevel::RebalanceOnly);
    }

    #[test]
    fn force_expire_overrides_everything() {
        let t = CancelToken::new();
        t.arm(Some(Duration::from_secs(3600)));
        assert!(!t.is_expired());
        t.force_expire();
        assert!(t.is_expired());
        assert_eq!(t.level(), DegradationLevel::RebalanceOnly);
        assert_eq!(t.max_level(), DegradationLevel::RebalanceOnly);
    }

    #[test]
    fn generous_budget_stays_full() {
        let t = CancelToken::new();
        t.arm(Some(Duration::from_secs(3600)));
        assert!(!t.is_expired());
        assert_eq!(t.level(), DegradationLevel::Full);
    }

    #[test]
    fn disarm_resets_expiry() {
        let t = CancelToken::new();
        t.arm(Some(Duration::ZERO));
        assert!(t.is_expired());
        t.arm(None);
        assert!(!t.is_expired());
        assert_eq!(t.level(), DegradationLevel::Full);
    }

    #[test]
    fn ladder_is_ordered() {
        assert!(DegradationLevel::Full < DegradationLevel::SkipFlows);
        assert!(DegradationLevel::SkipFlows < DegradationLevel::CapFm);
        assert!(DegradationLevel::CapFm < DegradationLevel::LpOnly);
        assert!(DegradationLevel::LpOnly < DegradationLevel::RebalanceOnly);
    }
}
