//! Plain and atomic fixed-size bitsets.
//!
//! The partition data structure (paper §6.1) stores the connectivity set
//! `Λ(e)` of each net as a bitset of size `k`, mutated with atomic XOR and
//! read via snapshot + count-leading-zeros iteration; `λ(e)` is a popcount.

use std::sync::atomic::{AtomicU64, Ordering};

const W: usize = 64;

/// A plain (single-owner) bitset.
#[derive(Clone, Debug, Default)]
pub struct Bitset {
    words: Vec<u64>,
    bits: usize,
}

impl Bitset {
    pub fn new(bits: usize) -> Self {
        Bitset { words: vec![0; (bits + W - 1) / W], bits }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.bits
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.bits == 0
    }

    /// Grow to at least `bits` (new bits are zero); never shrinks.
    pub fn ensure_len(&mut self, bits: usize) {
        if bits > self.bits {
            self.bits = bits;
            self.words.resize((bits + W - 1) / W, 0);
        }
    }

    #[inline]
    pub fn set(&mut self, i: usize) {
        self.words[i / W] |= 1 << (i % W);
    }

    #[inline]
    pub fn clear_bit(&mut self, i: usize) {
        self.words[i / W] &= !(1 << (i % W));
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        (self.words[i / W] >> (i % W)) & 1 == 1
    }

    /// Set bit `i`; returns the previous value.
    #[inline]
    pub fn test_and_set(&mut self, i: usize) -> bool {
        let prev = self.get(i);
        self.set(i);
        prev
    }

    pub fn clear(&mut self) {
        self.words.iter_mut().for_each(|w| *w = 0);
    }

    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Iterate over set bit indices in increasing order.
    pub fn iter_ones(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut w = w;
            std::iter::from_fn(move || {
                if w == 0 {
                    None
                } else {
                    let b = w.trailing_zeros() as usize;
                    w &= w - 1;
                    Some(wi * W + b)
                }
            })
        })
    }
}

/// A concurrently mutable bitset (per-bit atomic set/xor/test-and-set).
#[derive(Debug)]
pub struct AtomicBitset {
    words: Vec<AtomicU64>,
    bits: usize,
}

impl AtomicBitset {
    pub fn new(bits: usize) -> Self {
        AtomicBitset {
            words: (0..(bits + W - 1) / W).map(|_| AtomicU64::new(0)).collect(),
            bits,
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.bits
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.bits == 0
    }

    /// Grow to at least `bits` (new bits are zero); never shrinks.
    /// Takes `&mut self`, so it cannot race with concurrent accessors.
    pub fn ensure_len(&mut self, bits: usize) {
        if bits > self.bits {
            self.bits = bits;
            let need = (bits + W - 1) / W;
            while self.words.len() < need {
                self.words.push(AtomicU64::new(0));
            }
        }
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        (self.words[i / W].load(Ordering::Acquire) >> (i % W)) & 1 == 1
    }

    #[inline]
    pub fn set(&self, i: usize) {
        self.words[i / W].fetch_or(1 << (i % W), Ordering::AcqRel);
    }

    /// Atomically flip bit `i` (the paper's connectivity-set update).
    #[inline]
    pub fn flip(&self, i: usize) {
        self.words[i / W].fetch_xor(1 << (i % W), Ordering::AcqRel);
    }

    /// Atomic test-and-set; returns previous value.
    #[inline]
    pub fn test_and_set(&self, i: usize) -> bool {
        let mask = 1 << (i % W);
        self.words[i / W].fetch_or(mask, Ordering::AcqRel) & mask != 0
    }

    #[inline]
    pub fn clear_bit(&self, i: usize) {
        self.words[i / W].fetch_and(!(1 << (i % W)), Ordering::AcqRel);
    }

    /// Non-atomic-view clear (requires external synchronization).
    pub fn clear(&self) {
        for w in &self.words {
            w.store(0, Ordering::Release);
        }
    }

    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.load(Ordering::Acquire).count_ones() as usize).sum()
    }

    /// Snapshot the words (the paper's "take a snapshot of its bitset").
    pub fn snapshot(&self) -> Bitset {
        Bitset {
            words: self.words.iter().map(|w| w.load(Ordering::Acquire)).collect(),
            bits: self.bits,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_set_get_iter() {
        let mut b = Bitset::new(130);
        for i in [0usize, 1, 63, 64, 65, 129] {
            b.set(i);
        }
        assert_eq!(b.count_ones(), 6);
        assert!(b.get(64) && !b.get(66));
        let ones: Vec<usize> = b.iter_ones().collect();
        assert_eq!(ones, vec![0, 1, 63, 64, 65, 129]);
        b.clear_bit(64);
        assert!(!b.get(64));
        assert!(!b.test_and_set(64));
        assert!(b.test_and_set(64));
    }

    #[test]
    fn atomic_flip_parity() {
        let b = AtomicBitset::new(64);
        b.flip(3);
        assert!(b.get(3));
        b.flip(3);
        assert!(!b.get(3));
        assert!(!b.test_and_set(5));
        assert!(b.test_and_set(5));
    }

    #[test]
    fn atomic_concurrent_sets() {
        let b = std::sync::Arc::new(AtomicBitset::new(1024));
        std::thread::scope(|s| {
            for t in 0..4 {
                let b = b.clone();
                s.spawn(move || {
                    for i in (t..1024).step_by(4) {
                        b.set(i);
                    }
                });
            }
        });
        assert_eq!(b.count_ones(), 1024);
    }

    #[test]
    fn snapshot_matches() {
        let b = AtomicBitset::new(100);
        b.set(10);
        b.set(99);
        let s = b.snapshot();
        assert_eq!(s.iter_ones().collect::<Vec<_>>(), vec![10, 99]);
    }
}
