//! Aggregation statistics used throughout the evaluation (paper §12):
//! geometric/harmonic means, running mean/stddev (for the portfolio's
//! 95%-rule), and the Wilcoxon signed-rank test.

/// Geometric mean of positive values (zeros clamped to `eps`).
pub fn geometric_mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|&x| x.max(1e-12).ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Harmonic mean (paper Fig. 2 aggregation of quality ratios).
pub fn harmonic_mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.len() as f64 / xs.iter().map(|&x| 1.0 / x.max(1e-12)).sum::<f64>()
}

pub fn arithmetic_mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Incremental mean/standard deviation (Welford) — drives the portfolio's
/// "only rerun if µ − 2σ ≤ best" rule (paper §5).
#[derive(Clone, Debug, Default)]
pub struct RunningStats {
    n: u64,
    mean: f64,
    m2: f64,
}

impl RunningStats {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }
}

/// Wilcoxon signed-rank test (normal approximation, as in the paper's
/// §12 "Statistical Significance Tests"). Returns `(z, p_two_sided)`.
///
/// Pairs with zero difference are dropped; ties share average ranks.
pub fn wilcoxon_signed_rank(a: &[f64], b: &[f64]) -> (f64, f64) {
    assert_eq!(a.len(), b.len());
    let mut diffs: Vec<f64> = a
        .iter()
        .zip(b)
        .map(|(&x, &y)| x - y)
        .filter(|d| d.abs() > 1e-12)
        .collect();
    let n = diffs.len();
    if n == 0 {
        return (0.0, 1.0);
    }
    diffs.sort_by(|x, y| x.abs().partial_cmp(&y.abs()).unwrap());
    // average ranks for ties on |d|
    let mut ranks = vec![0.0f64; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && (diffs[j + 1].abs() - diffs[i].abs()).abs() < 1e-12 {
            j += 1;
        }
        let avg = (i + 1 + j + 1) as f64 / 2.0;
        for r in ranks.iter_mut().take(j + 1).skip(i) {
            *r = avg;
        }
        i = j + 1;
    }
    let w_plus: f64 = diffs
        .iter()
        .zip(&ranks)
        .filter(|(d, _)| **d > 0.0)
        .map(|(_, r)| *r)
        .sum();
    let nf = n as f64;
    let mean = nf * (nf + 1.0) / 4.0;
    let sd = (nf * (nf + 1.0) * (2.0 * nf + 1.0) / 24.0).sqrt();
    if sd == 0.0 {
        return (0.0, 1.0);
    }
    let z = (w_plus - mean) / sd;
    let p = 2.0 * (1.0 - phi(z.abs()));
    (z, p)
}

/// Standard normal CDF (Abramowitz–Stegun 7.1.26 erf approximation).
fn phi(x: f64) -> f64 {
    let t = 1.0 / (1.0 + 0.2316419 * x);
    let poly = t
        * (0.319381530 + t * (-0.356563782 + t * (1.781477937 + t * (-1.821255978 + t * 1.330274429))));
    1.0 - (-(x * x) / 2.0).exp() / (2.0 * std::f64::consts::PI).sqrt() * poly
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn means() {
        assert!((geometric_mean(&[1.0, 4.0]) - 2.0).abs() < 1e-9);
        assert!((harmonic_mean(&[1.0, 1.0]) - 1.0).abs() < 1e-9);
        assert!((arithmetic_mean(&[1.0, 3.0]) - 2.0).abs() < 1e-9);
        assert!((median(&[3.0, 1.0, 2.0]) - 2.0).abs() < 1e-9);
        assert!((median(&[4.0, 1.0, 2.0, 3.0]) - 2.5).abs() < 1e-9);
    }

    #[test]
    fn running_stats() {
        let mut s = RunningStats::default();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-9);
        assert!((s.stddev() - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn wilcoxon_identical_is_insignificant() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let (z, p) = wilcoxon_signed_rank(&a, &a);
        assert_eq!(z, 0.0);
        assert!((p - 1.0).abs() < 1e-9);
    }

    #[test]
    fn wilcoxon_detects_shift() {
        let a: Vec<f64> = (0..30).map(|i| i as f64).collect();
        let b: Vec<f64> = (0..30).map(|i| i as f64 + 5.0).collect();
        let (z, p) = wilcoxon_signed_rank(&a, &b);
        assert!(z < -2.576, "z={z}");
        assert!(p < 0.01, "p={p}");
    }
}
