//! Per-phase wall-clock timing (the component breakdown of paper Fig. 11).

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Accumulates named phase durations; cheap enough for coordinator-level
/// phases (not per-move instrumentation).
#[derive(Debug, Default)]
pub struct PhaseTimer {
    acc: Mutex<BTreeMap<&'static str, Duration>>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f` under phase `name` (accumulating).
    pub fn time<T>(&self, name: &'static str, f: impl FnOnce() -> T) -> T {
        let start = Instant::now();
        let out = f();
        self.add(name, start.elapsed());
        out
    }

    pub fn add(&self, name: &'static str, d: Duration) {
        *self.acc.lock().unwrap().entry(name).or_default() += d;
    }

    pub fn get(&self, name: &str) -> Duration {
        self.acc.lock().unwrap().get(name).copied().unwrap_or_default()
    }

    pub fn total(&self) -> Duration {
        self.acc.lock().unwrap().values().sum()
    }

    /// Snapshot of `(phase, seconds)` pairs, sorted by name.
    pub fn snapshot(&self) -> Vec<(&'static str, f64)> {
        self.acc
            .lock()
            .unwrap()
            .iter()
            .map(|(&k, v)| (k, v.as_secs_f64()))
            .collect()
    }

    /// Share of each phase on the total (paper Fig. 11 y-axis).
    pub fn shares(&self) -> Vec<(&'static str, f64)> {
        let total = self.total().as_secs_f64().max(1e-12);
        self.snapshot().into_iter().map(|(k, v)| (k, v / total)).collect()
    }

    pub fn clear(&self) {
        self.acc.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_shares() {
        let t = PhaseTimer::new();
        t.add("a", Duration::from_millis(30));
        t.add("b", Duration::from_millis(10));
        t.add("a", Duration::from_millis(10));
        assert_eq!(t.get("a"), Duration::from_millis(40));
        let shares = t.shares();
        let a = shares.iter().find(|(k, _)| *k == "a").unwrap().1;
        assert!((a - 0.8).abs() < 1e-9);
        let x = t.time("c", || 5);
        assert_eq!(x, 5);
        assert!(t.get("c") > Duration::ZERO);
    }
}
