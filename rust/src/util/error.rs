//! A tiny `anyhow`-shaped error type for the IO and runtime layers.
//!
//! The build targets an offline registry, so instead of depending on
//! `anyhow` this module provides the three pieces those layers actually
//! use: a string-backed [`Error`], a [`Context`] extension trait for
//! `Result`/`Option`, and the [`bail!`] macro.

use std::fmt;

/// String-backed error with an optional context chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { msg: m.to_string() }
    }

    fn wrap(self, ctx: impl fmt::Display) -> Self {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::msg(e)
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Self {
        Error::msg(e)
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Self {
        Error::msg(e)
    }
}

impl From<String> for Error {
    fn from(m: String) -> Self {
        Error { msg: m }
    }
}

impl From<&str> for Error {
    fn from(m: &str) -> Self {
        Error { msg: m.to_string() }
    }
}

/// Result alias defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context`-style extension for attaching messages.
pub trait Context<T> {
    /// Attach a static context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Attach a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Early-return with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        "nope".parse::<u32>().context("parsing the answer")?;
        Ok(0)
    }

    fn bails(x: i32) -> Result<i32> {
        if x < 0 {
            bail!("negative input: {x}");
        }
        Ok(x)
    }

    #[test]
    fn context_chains_messages() {
        let e = fails().unwrap_err();
        assert!(e.to_string().starts_with("parsing the answer: "));
        let n: Option<u32> = None;
        assert_eq!(n.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn bail_formats() {
        assert_eq!(bails(3).unwrap(), 3);
        assert_eq!(bails(-1).unwrap_err().to_string(), "negative input: -1");
    }
}
