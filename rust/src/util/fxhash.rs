//! A minimal FxHash-style hasher (the multiply–xor–rotate scheme used by
//! rustc) so the hot-path hash maps do not pay SipHash costs. Lives here
//! because the build targets an offline registry: no external crates.
//!
//! Not DoS-resistant — only use for internal keys (node ids, net ids),
//! never for attacker-controlled input.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// Fast non-cryptographic hasher for small integer-like keys.
#[derive(Clone, Copy, Debug, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            let mut buf = [0u8; 8];
            buf.copy_from_slice(c);
            self.add_to_hash(u64::from_le_bytes(buf));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add_to_hash(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, i: u8) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u16(&mut self, i: u16) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add_to_hash(i);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add_to_hash(i as u64);
    }
}

/// `BuildHasher` for [`FxHasher`].
pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// Drop-in `HashMap` with the fast hasher.
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;

/// Drop-in `HashSet` with the fast hasher.
pub type FxHashSet<T> = HashSet<T, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_and_set_basic_ops() {
        let mut m: FxHashMap<u64, i64> = FxHashMap::default();
        for i in 0..1000u64 {
            m.insert(i, (i * 3) as i64);
        }
        assert_eq!(m.len(), 1000);
        assert_eq!(m.get(&500), Some(&1500));
        let s: FxHashSet<u32> = (0..100u32).collect();
        assert!(s.contains(&99) && !s.contains(&100));
    }

    #[test]
    fn hash_is_deterministic_and_spreads() {
        let h = |x: u64| {
            let mut hasher = FxHasher::default();
            hasher.write_u64(x);
            hasher.finish()
        };
        assert_eq!(h(42), h(42));
        // consecutive keys should not collide in the low bits
        let lows: FxHashSet<u64> = (0..64).map(|i| h(i) & 0xffff).collect();
        assert!(lows.len() > 48, "low-bit spread too poor: {}", lows.len());
    }
}
