//! Deterministic pseudo-random number generation.
//!
//! The deterministic configuration (paper §11) requires reproducible
//! randomness that is *stable across thread counts*: every parallel loop
//! derives a per-item or per-chunk RNG from `(seed, item)` via SplitMix64
//! instead of consuming a shared stream. The bulk generator is
//! xoshiro256**, seeded through SplitMix64 as recommended by its authors.

/// SplitMix64 step — also usable standalone as a strong mixing function.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Hash two u64s into one (for per-item deterministic sub-seeds).
#[inline]
pub fn hash2(a: u64, b: u64) -> u64 {
    let mut s = a ^ b.rotate_left(32) ^ 0x9E3779B97F4A7C15;
    splitmix64(&mut s)
}

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Deterministic sub-generator for item `i` (stable across threads).
    #[inline]
    pub fn derive(&self, i: u64) -> Rng {
        Rng::new(hash2(self.s[0] ^ self.s[3], i))
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[1].wrapping_mul(5)).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` (Lemire's multiply-shift reduction).
    #[inline]
    pub fn next_below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.next_below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn coin(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `m` distinct indices from `[0, n)` (Floyd's algorithm for
    /// small m, shuffle-prefix otherwise).
    pub fn sample_indices(&mut self, n: usize, m: usize) -> Vec<usize> {
        let m = m.min(n);
        if m * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(m);
            return all;
        }
        let mut chosen = crate::util::fxhash::FxHashSet::default();
        let mut out = Vec::with_capacity(m);
        for j in n - m..n {
            let t = self.next_below(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_distinct_seeds() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let mut c = Rng::new(8);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn bounds_respected() {
        let mut r = Rng::new(123);
        for _ in 0..10_000 {
            let x = r.next_below(17);
            assert!(x < 17);
            let y = r.range(5, 9);
            assert!((5..9).contains(&y));
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(99);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        for &(n, m) in &[(10usize, 3usize), (100, 50), (7, 7), (1000, 10)] {
            let s = r.sample_indices(n, m);
            assert_eq!(s.len(), m.min(n));
            let mut t = s.clone();
            t.sort_unstable();
            t.dedup();
            assert_eq!(t.len(), s.len());
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn derive_stable() {
        let r = Rng::new(42);
        let mut d1 = r.derive(13);
        let mut d2 = r.derive(13);
        assert_eq!(d1.next_u64(), d2.next_u64());
    }

    #[test]
    fn roughly_uniform() {
        let mut r = Rng::new(2024);
        let mut buckets = [0usize; 10];
        for _ in 0..100_000 {
            buckets[r.next_below(10)] += 1;
        }
        for &b in &buckets {
            assert!((8_000..12_000).contains(&b), "bucket {b}");
        }
    }
}
