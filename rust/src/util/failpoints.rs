//! Deterministic fault injection for the resilience tests.
//!
//! A *failpoint* is a named site in the codebase where a configured fault
//! — a panic, a delay, or forced deadline expiry — can be triggered
//! deterministically. The four sites cover the shared-state hot spots the
//! recovery machinery must survive:
//!
//! | site | location |
//! |---|---|
//! | [`GAIN_TABLE_UPDATE`] | FM worker, before publishing a local move sequence |
//! | [`FLOW_WAVE_TAIL`] | flow worker, after refining a block pair (in-flight guard armed) |
//! | [`BATCH_UNCONTRACTION`] | n-level driver, localized refinement after a batch uncontraction |
//! | [`IP_CANDIDATE`] | initial-partitioning portfolio, per candidate attempt |
//! | [`REPARTITION_APPLY`] | repartitioner, localized refinement after a change batch is applied |
//!
//! The whole module compiles to no-ops unless the off-by-default
//! `failpoints` Cargo feature is enabled — `fire()` is then an empty
//! inline function, so production builds carry zero overhead and remain
//! bit-identical. With the feature on, sites stay inert until configured
//! via [`configure`]; tests must serialize configuration (the registry is
//! process-global) and [`clear`] it afterwards.

use crate::util::cancel::CancelToken;
use std::time::Duration;

/// FM worker: before local moves are published and applied globally.
pub const GAIN_TABLE_UPDATE: &str = "gain-table-update";
/// Flow worker: tail of one block-pair refinement, guard still armed.
pub const FLOW_WAVE_TAIL: &str = "flow-wave-tail";
/// n-level driver: localized refinement following a batch uncontraction.
pub const BATCH_UNCONTRACTION: &str = "batch-uncontraction";
/// Initial partitioning: one portfolio candidate attempt.
pub const IP_CANDIDATE: &str = "ip-candidate";
/// Repartitioner: localized refinement after a change batch was applied
/// to the dynamic structure (the partition is already rebound).
pub const REPARTITION_APPLY: &str = "repartition-apply";

/// The fault a configured site injects when hit.
#[derive(Clone, Copy, Debug)]
pub enum Action {
    /// panic with a recognizable message (drives the recovery tests)
    Panic,
    /// sleep, simulating a slow worker under a deadline
    Delay(Duration),
    /// force the run's `CancelToken` to expire
    Expire,
}

/// Trigger the failpoint `site`. No-op unless the `failpoints` feature is
/// enabled *and* the site has been configured with remaining hits.
#[inline(always)]
pub fn fire(site: &str, cancel: &CancelToken) {
    #[cfg(feature = "failpoints")]
    enabled::fire_impl(site, cancel);
    #[cfg(not(feature = "failpoints"))]
    {
        let _ = (site, cancel);
    }
}

/// Arm `site` to inject `action` for the next `times` hits (then it
/// disarms itself; use `usize::MAX` for "every hit").
#[cfg(feature = "failpoints")]
pub fn configure(site: &str, action: Action, times: usize) {
    enabled::configure_impl(site, action, times);
}

/// Disarm every failpoint (test teardown).
#[cfg(feature = "failpoints")]
pub fn clear() {
    enabled::clear_impl();
}

#[cfg(feature = "failpoints")]
mod enabled {
    use super::Action;
    use crate::util::cancel::CancelToken;
    use std::collections::HashMap;
    use std::sync::{Mutex, OnceLock};

    struct Entry {
        action: Action,
        remaining: usize,
    }

    fn registry() -> &'static Mutex<HashMap<String, Entry>> {
        static REGISTRY: OnceLock<Mutex<HashMap<String, Entry>>> = OnceLock::new();
        REGISTRY.get_or_init(|| Mutex::new(HashMap::new()))
    }

    // a panicking failpoint unwinds through arbitrary test threads; never
    // let mutex poisoning turn a configured fault into a cascading one
    fn lock() -> std::sync::MutexGuard<'static, HashMap<String, Entry>> {
        registry().lock().unwrap_or_else(|e| e.into_inner())
    }

    pub(super) fn configure_impl(site: &str, action: Action, times: usize) {
        lock().insert(site.to_string(), Entry { action, remaining: times });
    }

    pub(super) fn clear_impl() {
        lock().clear();
    }

    pub(super) fn fire_impl(site: &str, cancel: &CancelToken) {
        let action = {
            let mut reg = lock();
            let Some(entry) = reg.get_mut(site) else { return };
            if entry.remaining == 0 {
                return;
            }
            entry.remaining -= 1;
            let action = entry.action;
            if entry.remaining == 0 {
                reg.remove(site);
            }
            action
            // guard dropped here — the action must run unlocked so a
            // panic cannot wedge the registry for other threads
        };
        match action {
            Action::Panic => panic!("failpoint '{site}' triggered"),
            Action::Delay(d) => std::thread::sleep(d),
            Action::Expire => cancel.force_expire(),
        }
    }
}

#[cfg(all(test, feature = "failpoints"))]
mod tests {
    use super::*;

    #[test]
    fn countdown_disarms_and_expire_hits_token() {
        let t = CancelToken::new();
        configure("fp-test-site", Action::Expire, 1);
        fire("fp-test-site", &t);
        assert!(t.is_expired(), "Expire action must force the token");
        // the single configured hit is consumed; firing again is inert
        let t2 = CancelToken::new();
        fire("fp-test-site", &t2);
        assert!(!t2.is_expired());
        clear();
    }

    #[test]
    fn unconfigured_site_is_inert() {
        let t = CancelToken::new();
        fire("fp-never-configured", &t);
        assert!(!t.is_expired());
    }
}
