//! Small shared utilities: deterministic RNG, bitsets, timers, statistics,
//! cooperative cancellation and fault injection.

pub mod bitset;
pub mod cancel;
pub mod error;
pub mod failpoints;
pub mod fxhash;
pub mod rng;
pub mod stats;
pub mod timer;

pub use bitset::{AtomicBitset, Bitset};
pub use cancel::{CancelToken, DegradationLevel};
pub use rng::Rng;
pub use timer::PhaseTimer;

/// Round `x` up to the next multiple of `m` (m > 0).
#[inline]
pub fn round_up(x: usize, m: usize) -> usize {
    (x + m - 1) / m * m
}

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}
