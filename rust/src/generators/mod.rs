//! Synthetic instance generators — the stand-in for the paper's benchmark
//! sets (ISPD98/DAC2012 VLSI circuits, SuiteSparse matrices, SAT14
//! formulas, DIMACS/SNAP graphs; see DESIGN.md §2 for the substitution
//! rationale). Every generator is fully determined by its parameters and
//! a seed, and reproduces the *structural archetype* of its source domain:
//! net-size and degree distributions, locality, and (for planted
//! instances) ground-truth cut structure.

use crate::graph::Graph;
use crate::hypergraph::Hypergraph;
use crate::util::Rng;
use crate::NodeId;

/// Parameters for planted-partition hypergraphs.
#[derive(Clone, Debug)]
pub struct PlantedParams {
    /// number of nodes
    pub n: usize,
    /// number of nets
    pub m: usize,
    /// number of planted blocks
    pub blocks: usize,
    /// net size range (inclusive)
    pub net_size: (usize, usize),
    /// probability that a net stays inside one planted block
    pub p_intra: f64,
}

impl Default for PlantedParams {
    fn default() -> Self {
        PlantedParams { n: 2000, m: 3000, blocks: 8, net_size: (2, 6), p_intra: 0.9 }
    }
}

/// Hypergraph with a planted k-way structure: most nets draw all pins from
/// one random block, the rest span two blocks. Partitioners should recover
/// a cut close to the planted one — used by the integration tests.
pub fn planted_hypergraph(p: &PlantedParams, seed: u64) -> Hypergraph {
    let mut rng = Rng::new(seed ^ 0x9d5a_b5c1);
    let nb = p.blocks.max(1);
    // block membership: contiguous ranges for easy verification
    let block_of = |u: usize| u * nb / p.n;
    let nodes_in = |b: usize| -> (usize, usize) {
        let lo = (b * p.n + nb - 1) / nb;
        let hi = ((b + 1) * p.n + nb - 1) / nb;
        (lo, hi.min(p.n))
    };
    let mut nets = Vec::with_capacity(p.m);
    for _ in 0..p.m {
        let sz = rng.range(p.net_size.0, p.net_size.1 + 1).max(2);
        let intra = rng.coin(p.p_intra);
        let b1 = rng.next_below(nb);
        let mut pins: Vec<NodeId> = Vec::with_capacity(sz);
        let (lo1, hi1) = nodes_in(b1);
        if intra || nb == 1 {
            while pins.len() < sz.min(hi1 - lo1) {
                let u = rng.range(lo1, hi1) as NodeId;
                if !pins.contains(&u) {
                    pins.push(u);
                }
            }
        } else {
            let b2 = (b1 + 1 + rng.next_below(nb - 1)) % nb;
            let (lo2, hi2) = nodes_in(b2);
            while pins.len() < sz {
                let from_b1 = pins.len() < sz / 2;
                let (lo, hi) = if from_b1 { (lo1, hi1) } else { (lo2, hi2) };
                let u = rng.range(lo, hi) as NodeId;
                if !pins.contains(&u) {
                    pins.push(u);
                }
            }
        }
        if pins.len() >= 2 {
            nets.push(pins);
        }
    }
    let _ = block_of;
    Hypergraph::from_nets(p.n, &nets, None, None)
}

/// Sparse-matrix hypergraph (row-net model, paper §12 "SPM"): rows become
/// nets over their nonzero columns. Nonzeros cluster near the diagonal
/// with a few long-range entries — the archetype of SuiteSparse matrices.
pub fn spm_hypergraph(n_cols: usize, n_rows: usize, avg_nnz: usize, seed: u64) -> Hypergraph {
    let mut rng = Rng::new(seed ^ 0x51ab_77ee);
    let mut nets = Vec::with_capacity(n_rows);
    for r in 0..n_rows {
        let nnz = (1 + rng.next_below(2 * avg_nnz)).max(2);
        let center = r * n_cols / n_rows.max(1);
        let band = (n_cols / 50).max(4);
        let mut pins: Vec<NodeId> = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            let c = if rng.coin(0.85) {
                // banded entry
                let lo = center.saturating_sub(band);
                let hi = (center + band).min(n_cols - 1);
                rng.range(lo, hi + 1)
            } else {
                rng.next_below(n_cols)
            } as NodeId;
            if !pins.contains(&c) {
                pins.push(c);
            }
        }
        if pins.len() >= 2 {
            nets.push(pins);
        }
    }
    Hypergraph::from_nets(n_cols, &nets, None, None)
}

/// SAT-instance hypergraph representations (paper §12: PRIMAL, DUAL,
/// LITERAL encodings of random 3-ish-CNF formulas).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SatRepresentation {
    /// variables = nodes, clauses = nets
    Primal,
    /// clauses = nodes, variables = nets
    Dual,
    /// literals = nodes, clauses = nets
    Literal,
}

/// Generate a random CNF with community structure and encode it.
pub fn sat_hypergraph(
    num_vars: usize,
    num_clauses: usize,
    rep: SatRepresentation,
    seed: u64,
) -> Hypergraph {
    let mut rng = Rng::new(seed ^ 0xc1a0_53eb);
    let communities = (num_vars / 60).max(1);
    // clauses: mostly 3 literals from one community, sometimes crossing
    let mut clauses: Vec<Vec<(usize, bool)>> = Vec::with_capacity(num_clauses);
    for _ in 0..num_clauses {
        let len = 2 + rng.next_below(3); // 2..4 literals
        let comm = rng.next_below(communities);
        let mut lits = Vec::with_capacity(len);
        while lits.len() < len {
            let v = if rng.coin(0.8) {
                let per = (num_vars + communities - 1) / communities;
                (comm * per + rng.next_below(per)).min(num_vars - 1)
            } else {
                rng.next_below(num_vars)
            };
            if !lits.iter().any(|&(lv, _)| lv == v) {
                lits.push((v, rng.coin(0.5)));
            }
        }
        clauses.push(lits);
    }
    match rep {
        SatRepresentation::Primal => {
            let nets: Vec<Vec<NodeId>> = clauses
                .iter()
                .map(|c| c.iter().map(|&(v, _)| v as NodeId).collect())
                .collect();
            Hypergraph::from_nets(num_vars, &nets, None, None)
        }
        SatRepresentation::Dual => {
            // nets = variables spanning the clauses they appear in
            let mut var_clauses: Vec<Vec<NodeId>> = vec![Vec::new(); num_vars];
            for (ci, c) in clauses.iter().enumerate() {
                for &(v, _) in c {
                    var_clauses[v].push(ci as NodeId);
                }
            }
            let nets: Vec<Vec<NodeId>> =
                var_clauses.into_iter().filter(|l| l.len() >= 2).collect();
            Hypergraph::from_nets(num_clauses, &nets, None, None)
        }
        SatRepresentation::Literal => {
            let nets: Vec<Vec<NodeId>> = clauses
                .iter()
                .map(|c| {
                    c.iter().map(|&(v, pos)| (2 * v + usize::from(pos)) as NodeId).collect()
                })
                .collect();
            Hypergraph::from_nets(2 * num_vars, &nets, None, None)
        }
    }
}

/// VLSI-circuit-like hypergraph (ISPD98/DAC2012 archetype): dominated by
/// 2–4-pin nets with strong locality plus a few high-fanout nets.
pub fn vlsi_hypergraph(n: usize, m: usize, seed: u64) -> Hypergraph {
    let mut rng = Rng::new(seed ^ 0x7e57_c19c);
    let mut nets = Vec::with_capacity(m);
    for _ in 0..m {
        let high_fanout = rng.coin(0.01);
        let sz = if high_fanout { 10 + rng.next_below(40) } else { 2 + rng.next_below(3) };
        let anchor = rng.next_below(n);
        let radius = if high_fanout { n / 4 } else { (n / 100).max(8) };
        let mut pins: Vec<NodeId> = vec![anchor as NodeId];
        let mut guard = 0;
        while pins.len() < sz && guard < 8 * sz {
            guard += 1;
            let off = rng.next_below(2 * radius + 1) as i64 - radius as i64;
            let u = (anchor as i64 + off).rem_euclid(n as i64) as NodeId;
            if !pins.contains(&u) {
                pins.push(u);
            }
        }
        if pins.len() >= 2 {
            nets.push(pins);
        }
    }
    Hypergraph::from_nets(n, &nets, None, None)
}

/// RMAT-style power-law graph (SNAP/social-network archetype).
pub fn rmat_graph(scale: u32, avg_degree: usize, seed: u64) -> Graph {
    let n = 1usize << scale;
    let m = n * avg_degree / 2;
    let mut rng = Rng::new(seed ^ 0x5EED_0F5E_ED01);
    let (a, b, c) = (0.57, 0.19, 0.19);
    let mut edges: Vec<(NodeId, NodeId, i64)> = Vec::with_capacity(m);
    let mut seen = crate::util::fxhash::FxHashSet::default();
    let mut attempts = 0usize;
    while edges.len() < m && attempts < 20 * m {
        attempts += 1;
        let (mut u, mut v) = (0usize, 0usize);
        for _ in 0..scale {
            let r = rng.next_f64();
            let (du, dv) = if r < a {
                (0, 0)
            } else if r < a + b {
                (0, 1)
            } else if r < a + b + c {
                (1, 0)
            } else {
                (1, 1)
            };
            u = (u << 1) | du;
            v = (v << 1) | dv;
        }
        if u != v && seen.insert((u.min(v), u.max(v))) {
            edges.push((u as NodeId, v as NodeId, 1));
        }
    }
    Graph::from_edges(n, &edges, None)
}

/// 2D grid mesh graph (DIMACS mesh archetype): rows × cols 4-neighborhood.
pub fn mesh_graph(rows: usize, cols: usize) -> Graph {
    let id = |r: usize, c: usize| (r * cols + c) as NodeId;
    let mut edges = Vec::with_capacity(2 * rows * cols);
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                edges.push((id(r, c), id(r, c + 1), 1));
            }
            if r + 1 < rows {
                edges.push((id(r, c), id(r + 1, c), 1));
            }
        }
    }
    Graph::from_edges(rows * cols, &edges, None)
}

/// Random k-uniform hypergraph (unstructured control instance).
pub fn random_kuniform(n: usize, m: usize, k: usize, seed: u64) -> Hypergraph {
    let mut rng = Rng::new(seed ^ 0xdead_beef);
    let mut nets = Vec::with_capacity(m);
    for _ in 0..m {
        let pins: Vec<NodeId> =
            rng.sample_indices(n, k).into_iter().map(|u| u as NodeId).collect();
        if pins.len() >= 2 {
            nets.push(pins);
        }
    }
    Hypergraph::from_nets(n, &nets, None, None)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn planted_is_valid_and_deterministic() {
        let p = PlantedParams::default();
        let a = planted_hypergraph(&p, 1);
        let b = planted_hypergraph(&p, 1);
        let c = planted_hypergraph(&p, 2);
        a.validate().unwrap();
        assert_eq!(a.num_pins(), b.num_pins());
        assert_ne!(a.num_pins(), c.num_pins()); // overwhelmingly likely
    }

    #[test]
    fn spm_shapes() {
        let hg = spm_hypergraph(500, 500, 5, 3);
        hg.validate().unwrap();
        assert_eq!(hg.num_nodes(), 500);
        assert!(hg.num_nets() > 400);
    }

    #[test]
    fn sat_representations() {
        for rep in [SatRepresentation::Primal, SatRepresentation::Dual, SatRepresentation::Literal]
        {
            let hg = sat_hypergraph(200, 800, rep, 7);
            hg.validate().unwrap();
            match rep {
                SatRepresentation::Primal => assert_eq!(hg.num_nodes(), 200),
                SatRepresentation::Dual => assert_eq!(hg.num_nodes(), 800),
                SatRepresentation::Literal => assert_eq!(hg.num_nodes(), 400),
            }
        }
    }

    #[test]
    fn vlsi_small_nets_dominate() {
        let hg = vlsi_hypergraph(1000, 1500, 5);
        hg.validate().unwrap();
        let small = hg.nets().filter(|&e| hg.net_size(e) <= 4).count();
        assert!(small * 10 >= hg.num_nets() * 9);
    }

    #[test]
    fn rmat_power_law_ish() {
        let g = rmat_graph(10, 8, 11);
        g.validate().unwrap();
        assert_eq!(g.num_nodes(), 1024);
        let dmax = g.nodes().map(|u| g.degree(u)).max().unwrap();
        let davg = g.num_edges() / g.num_nodes();
        assert!(dmax > 4 * davg, "expected skew: dmax={dmax} davg={davg}");
    }

    #[test]
    fn mesh_structure() {
        let g = mesh_graph(10, 12);
        g.validate().unwrap();
        assert_eq!(g.num_nodes(), 120);
        assert_eq!(g.num_edges(), 2 * (9 * 12 + 10 * 11));
    }

    #[test]
    fn kuniform() {
        let hg = random_kuniform(100, 300, 4, 9);
        hg.validate().unwrap();
        assert!(hg.nets().all(|e| hg.net_size(e) == 4));
    }
}
