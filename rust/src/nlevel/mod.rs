//! The parallel n-level partitioning scheme (paper §9).
//!
//! Coarsening contracts *single nodes*: each pass computes the best
//! contraction partner per node (heavy-edge rating, Algorithm 9.1),
//! builds the contraction forest through the join protocol, and records
//! the resulting sequence of individual contractions `(v, u)`.
//! Uncoarsening reverts the sequence in **batches** of `b_max`
//! contractions (paper's batch uncontractions); after each batch a
//! *highly localized* LP + FM pass runs around the uncontracted nodes,
//! and the finest level finishes with global FM (+ flows for Q-F).
//!
//! ## Adaptation note (documented in DESIGN.md)
//! The paper maintains a dynamic hypergraph data structure so batch
//! uncontractions mutate pin-lists in place (§9 "The Dynamic Hypergraph
//! Data Structure"). Here each batch boundary *materializes* the
//! corresponding static snapshot through the parallel contraction
//! algorithm instead: identical hypergraphs and identical refinement
//! semantics at every batch boundary, at O(p) per batch instead of
//! O(batch) update cost. On this testbed (1 vCPU, medium instances) the
//! constant is acceptable; the trade-off is recorded in EXPERIMENTS.md.

use crate::coarsening::clustering;
use crate::coordinator::context::Context;
use crate::hypergraph::{contraction, Hypergraph};
use crate::initial;
use crate::partition::PartitionedHypergraph;
use crate::preprocessing::{detect_communities, LouvainConfig};
use crate::refinement::RefinementPipeline;
use crate::{BlockId, NodeId};
use std::sync::Arc;

/// One recorded single-node contraction: `v` contracted onto `u`
/// (ids refer to the *input* hypergraph after path compression).
#[derive(Clone, Copy, Debug)]
pub struct SingleContraction {
    pub v: NodeId,
    pub u: NodeId,
}

/// n-level partitioning pipeline (Algorithm 9.1 + batch uncoarsening).
pub fn partition(hg: Arc<Hypergraph>, ctx: &Context) -> PartitionedHypergraph {
    let timer = ctx.timer.clone();
    let n = hg.num_nodes();

    let communities = if ctx.use_community_detection {
        Some(timer.time("preprocessing", || {
            detect_communities(
                &hg,
                &LouvainConfig {
                    threads: ctx.threads,
                    seed: ctx.seed,
                    max_rounds: ctx.louvain_max_rounds,
                    deterministic: ctx.deterministic,
                    ..Default::default()
                },
            )
        }))
    } else {
        None
    };

    // ---- n-level coarsening: record the single-contraction sequence ----
    // rep_input[u]: current representative of input node u
    let mut rep_input: Vec<NodeId> = (0..n as NodeId).collect();
    let mut sequence: Vec<SingleContraction> = Vec::new();
    let limit = ctx.contraction_limit().max(2 * ctx.k);
    let cmax = ctx.max_cluster_weight(hg.total_weight());
    let mut current = hg.clone();
    // mapping input node -> node id of `current`
    let mut input_to_cur: Vec<NodeId> = (0..n as NodeId).collect();
    let mut comms = communities.clone();

    timer.time("coarsening", || {
        while current.num_nodes() > limit {
            let n_before = current.num_nodes();
            // per-node best partner = clustering pass (the paper's rating);
            // each cluster yields |C|−1 single contractions onto its root
            let rep = clustering::cluster(&current, ctx, comms.as_deref(), cmax, limit);
            // record single contractions in input-node ids
            // cur -> representative input witness
            let mut witness: Vec<NodeId> = vec![crate::INVALID_NODE; current.num_nodes()];
            for u in 0..n {
                let c = input_to_cur[u];
                if c != crate::INVALID_NODE
                    && rep_input[u] == u as NodeId
                    && witness[c as usize] == crate::INVALID_NODE
                {
                    witness[c as usize] = u as NodeId;
                }
            }
            let mut pass_seq: Vec<SingleContraction> = Vec::new();
            for v_cur in 0..current.num_nodes() {
                let r_cur = rep[v_cur] as usize;
                if r_cur != v_cur {
                    let v_in = witness[v_cur];
                    let u_in = witness[r_cur];
                    debug_assert_ne!(v_in, crate::INVALID_NODE);
                    pass_seq.push(SingleContraction { v: v_in, u: u_in });
                }
            }
            let c = contraction::contract(&current, &rep, ctx.threads);
            if n_before - c.coarse.num_nodes() <= (ctx.min_shrink * n_before as f64) as usize {
                break; // pass discarded: nothing contracted meaningfully
            }
            for sc in &pass_seq {
                rep_input[sc.v as usize] = sc.u;
            }
            sequence.extend(pass_seq);
            // project community ids and the input mapping
            if let Some(cm) = &comms {
                let mut coarse = vec![0u32; c.coarse.num_nodes()];
                for u in 0..n_before {
                    coarse[c.fine_to_coarse[u] as usize] = cm[u];
                }
                comms = Some(coarse);
            }
            for u in 0..n {
                let cur = input_to_cur[u];
                if cur != crate::INVALID_NODE {
                    input_to_cur[u] = c.fine_to_coarse[cur as usize];
                }
            }
            current = Arc::new(c.coarse);
        }
    });

    // ---- initial partitioning on the coarsest snapshot ----
    let coarse_parts =
        timer.time("initial_partitioning", || initial::initial_partition(current.clone(), ctx));
    // partition of the input induced by the coarsest snapshot
    let mut parts: Vec<BlockId> =
        (0..n).map(|u| coarse_parts[input_to_cur[u] as usize]).collect();

    // ---- batch uncoarsening (§9) ----
    // revert the sequence in reverse order, b_max contractions per batch;
    // at each batch boundary materialize the snapshot and refine locally.
    // One refinement pipeline serves every batch *and* the finest level:
    // the gain table, FM scratch *and* the pooled partition state are
    // sized for the input hypergraph once and rebound/repaired in place
    // per snapshot — batches allocate hypergraph snapshots (the
    // documented adaptation) but no Π/Φ/Λ/lock storage.
    let mut pipeline = RefinementPipeline::new_for(ctx, &hg);
    let mut bound: Option<PartitionedHypergraph> = None;
    let b_max = ctx.nlevel_batch_size.max(1);
    let mut remaining = sequence.len();
    while remaining > 0 {
        let batch_start = remaining.saturating_sub(b_max);
        let batch = &sequence[batch_start..remaining];
        remaining = batch_start;
        // snapshot after `remaining` contractions: union-find over prefix
        let mut rep_prefix: Vec<NodeId> = (0..n as NodeId).collect();
        for c in &sequence[..remaining] {
            rep_prefix[c.v as usize] = c.u;
        }
        // path-compress to roots
        for u in 0..n {
            let mut r = rep_prefix[u] as usize;
            while rep_prefix[r] as usize != r {
                r = rep_prefix[r] as usize;
            }
            rep_prefix[u] = r as NodeId;
        }
        let snap = contraction::contract(&hg, &rep_prefix, ctx.threads);
        let snap_hg = Arc::new(snap.coarse);
        // project the partition onto the snapshot (input-indexed `parts`
        // is constant on every cluster of the *coarser* state, so any
        // member witnesses its block)
        let mut snap_parts: Vec<BlockId> = vec![0; snap_hg.num_nodes()];
        for u in 0..n {
            snap_parts[snap.fine_to_coarse[u] as usize] = parts[u];
        }
        let phg = match bound.take() {
            Some(prev) => pipeline.rebind_with_parts(prev, snap_hg.clone(), &snap_parts, ctx),
            None => pipeline.bind(snap_hg.clone(), &snap_parts, ctx),
        };

        // localized refinement around the uncontracted nodes (§9)
        let touched: Vec<NodeId> = {
            let mut t: Vec<NodeId> = batch
                .iter()
                .flat_map(|c| {
                    [snap.fine_to_coarse[c.v as usize], snap.fine_to_coarse[c.u as usize]]
                })
                .collect();
            t.sort_unstable();
            t.dedup();
            t
        };
        timer.time("localized_lp", || pipeline.lp_localized(&phg, ctx, &touched));
        if ctx.use_fm {
            timer.time("localized_fm", || pipeline.fm_with_seeds(&phg, ctx, Some(&touched)));
        }
        // write back through the snapshot mapping (per-node reads, no
        // assignment snapshot)
        for u in 0..n {
            parts[u] = phg.block_of(snap.fine_to_coarse[u]);
        }
        bound = Some(phg);
    }

    // ---- finest level: global refinement (paper: global FM + flows) ----
    // distance 0: the one level where the Q-F preset's flows always run
    let phg = match bound.take() {
        Some(prev) => pipeline.rebind_with_parts(prev, hg, &parts, ctx),
        None => pipeline.bind(hg, &parts, ctx),
    };
    pipeline.refine_at_distance(&phg, ctx, 0);
    phg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::{Context, Preset};
    use crate::generators::{planted_hypergraph, PlantedParams};

    fn ctx(preset: Preset, k: usize, threads: usize, seed: u64) -> Context {
        let mut c = Context::new(preset, k, 0.03).with_threads(threads).with_seed(seed);
        c.contraction_limit_factor = 24;
        c.ip_min_repetitions = 2;
        c.ip_max_repetitions = 3;
        c.fm_max_rounds = 3;
        c.nlevel_batch_size = 64;
        c
    }

    #[test]
    fn nlevel_end_to_end() {
        let hg = Arc::new(planted_hypergraph(
            &PlantedParams { n: 500, m: 900, blocks: 4, ..Default::default() },
            31,
        ));
        let phg = partition(hg.clone(), &ctx(Preset::Quality, 4, 2, 31));
        assert!(phg.is_balanced(), "imbalance {}", phg.imbalance());
        phg.verify_consistency().unwrap();
        assert!(phg.km1() < hg.num_nets() as i64 / 2);
    }

    #[test]
    fn nlevel_with_flows() {
        let hg = Arc::new(planted_hypergraph(
            &PlantedParams { n: 300, m: 550, blocks: 2, ..Default::default() },
            5,
        ));
        let phg = partition(hg, &ctx(Preset::QualityFlows, 2, 2, 5));
        assert!(phg.is_balanced());
        phg.verify_consistency().unwrap();
    }

    #[test]
    fn nlevel_quality_competitive_with_multilevel() {
        let mut q_total = 0i64;
        let mut d_total = 0i64;
        for seed in 0..3u64 {
            let hg = Arc::new(planted_hypergraph(
                &PlantedParams { n: 400, m: 800, blocks: 4, p_intra: 0.85, ..Default::default() },
                seed,
            ));
            q_total += partition(hg.clone(), &ctx(Preset::Quality, 4, 2, seed)).km1();
            d_total += crate::coordinator::partitioner::partition_arc(
                hg,
                &ctx(Preset::Default, 4, 2, seed),
            )
            .km1();
        }
        // Q should be within ~25% of D (typically better; paper: 1.9%
        // median improvement of Q over D)
        assert!(
            (q_total as f64) <= d_total as f64 * 1.25 + 8.0,
            "n-level {q_total} vs multilevel {d_total}"
        );
    }
}
