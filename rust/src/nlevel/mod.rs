//! The parallel n-level partitioning scheme (paper §9).
//!
//! Coarsening contracts *single nodes* directly on the
//! [`DynamicHypergraph`]: each pass computes the best contraction partner
//! per node (heavy-edge rating, Algorithm 9.1) and applies the resulting
//! `contract(v, u)` operations in place, recording one [`Memento`] each.
//! Node ids are stable across the whole hierarchy (contracted slots go
//! inactive instead of being renumbered), so the per-pass witness scan,
//! community projection and static re-contraction are gone. (A rating
//! pass itself still visits all input slots — inactive ones are skipped
//! as pre-clustered singletons — which is fine: passes number O(log n),
//! while the cost that actually dominated, the O(n + m) snapshot
//! materialization at each of the ~n/b_max *batch boundaries*, is what
//! this structure eliminates.)
//!
//! Uncoarsening reverts the memento sequence in **batches** of `b_max`
//! contractions (the paper's batch uncontractions): the partition state is
//! parked, [`DynamicHypergraph::uncontract_batch`] mutates pin-lists and
//! incident-net prefixes in place at O(batch) cost, the state is re-bound
//! unchanged and `apply_uncontractions` repairs Π/Φ/Λ only around the
//! nets incident to the uncontracted nodes. A *highly localized* LP + FM
//! pass (table-free, O(region)) then runs around the batch, and the finest
//! level finishes with the full static refiner stack (global FM + flows
//! for Q-F) after a value-preserving hand-off to the input hypergraph.
//!
//! ## Deterministic mode
//!
//! With `ctx.deterministic` the whole n-level pipeline is thread-count
//! invariant: coarsening rates with the synchronous
//! [`crate::coarsening::deterministic::cluster`] (generic over the
//! dynamic structure; inactive slots stay fixed points) instead of the
//! racy join protocol, and each batch boundary runs the *seeded
//! deterministic FM* (§11 frozen gains + prefix selection on the batch
//! region) in place of the asynchronous localized LP/FM pair — its move
//! space subsumes the localized LP's positive single-node moves. The
//! final static hand-off then runs the deterministic refiner stack.
//!
//! ## Adaptation note (§9)
//! Earlier revisions materialized a static snapshot per batch boundary
//! (an O(n) union-find prefix rebuild plus a parallel re-contraction);
//! that adaptation is gone. The one remaining static snapshot is the
//! [`DynamicHypergraph::freeze`] of the coarsest state that initial
//! partitioning runs on — after it, uncoarsening performs **zero**
//! snapshot contractions and **zero** full `rebuild_from_parts` value
//! rebuilds (asserted by [`NLevelStats`] counters in the tests). Batch
//! uncontractions are reverted **in parallel within each batch**
//! ([`DynamicHypergraph::uncontract_batch_parallel`]): the batch's event
//! log is grouped by net, each net's pin-list/prefix reverts replay
//! independently across threads, and the per-node LIFO bookkeeping runs
//! as a short sequential epilogue — the result is bit-identical to the
//! sequential revert for every thread count.

use crate::coarsening::clustering;
use crate::coordinator::context::Context;
use crate::hypergraph::dynamic::{DynamicHypergraph, Memento};
use crate::hypergraph::{Hypergraph, HypergraphOps};
use crate::initial;
use crate::partition::PartitionedHypergraph;
use crate::preprocessing::{detect_communities, LouvainConfig};
use crate::refinement::RefinementPipeline;
use crate::{BlockId, NodeId};
use std::sync::Arc;

/// Counters of one n-level run, pinning the incremental-uncoarsening
/// invariants the tests assert.
///
/// "Zero snapshot contractions after initial partitioning" is enforced
/// through these numbers: a materialized snapshot can only reach the
/// pooled partition state through a counted rebind, and loading its
/// assignment requires a counted full value rebuild — so
/// `value_rebuilds == 1` (the post-IP bind) together with
/// `rebinds == batches + 1` (one value-preserving unpark per batch plus
/// the final static hand-off) leaves no slot for a snapshot path.
#[derive(Clone, Copy, Debug, Default)]
pub struct NLevelStats {
    /// single-node contractions recorded during coarsening
    pub contractions: usize,
    /// batch uncontractions performed during uncoarsening
    pub batches: usize,
    /// partition-pool rebinds (must be `batches + 1`)
    pub rebinds: usize,
    /// full Π/Φ/Λ value rebuilds in the partition pool (must be 1: only
    /// the bind right after initial partitioning)
    pub value_rebuilds: usize,
    /// structural partition-buffer allocations (must be 1)
    pub structural_allocs: usize,
}

/// n-level partitioning pipeline (Algorithm 9.1 + batch uncoarsening).
pub fn partition(hg: Arc<Hypergraph>, ctx: &Context) -> PartitionedHypergraph {
    partition_with_stats(hg, ctx).0
}

/// [`partition`] plus the incremental-uncoarsening counters.
pub fn partition_with_stats(
    hg: Arc<Hypergraph>,
    ctx: &Context,
) -> (PartitionedHypergraph, NLevelStats) {
    let timer = ctx.timer.clone();
    // standalone driver: arm the deadline for this run (no-op when unset)
    ctx.cancel.arm(ctx.time_limit);
    let n = hg.num_nodes();
    let mut stats = NLevelStats::default();

    let communities = if ctx.use_community_detection {
        Some(timer.time("preprocessing", || {
            detect_communities(
                &hg,
                &LouvainConfig {
                    threads: ctx.threads,
                    seed: ctx.seed,
                    max_rounds: ctx.louvain_max_rounds,
                    deterministic: ctx.deterministic,
                    ..Default::default()
                },
            )
        }))
    } else {
        None
    };

    // ---- n-level coarsening: contract directly on the dynamic structure ----
    // Node ids never change, so the community labels of the input apply at
    // every pass and the recorded mementos are the uncoarsening plan.
    let limit = ctx.contraction_limit().max(2 * ctx.k);
    let cmax = ctx.max_cluster_weight(hg.total_weight());
    let mut dynhg = DynamicHypergraph::from_hypergraph(&hg);
    dynhg.reserve_events(hg.num_pins());
    let mut mementos: Vec<Memento> = Vec::new();
    // pooled rating-pass buffers: every pass reuses the same six
    // input-slot-sized vectors instead of allocating fresh ones
    let mut cluster_scratch = clustering::ClusterScratch::default();

    timer.time("coarsening", || {
        while dynhg.num_active_nodes() > limit {
            // cancellation checkpoint at the pass boundary (same
            // discipline as the static coarsener): a shorter memento
            // sequence just means fewer batches to uncoarsen
            if ctx.cancel.is_expired() {
                ctx.cancel.note_early_stop();
                break;
            }
            let n_before = dynhg.num_active_nodes();
            // per-node best partner = clustering pass (the paper's rating);
            // each cluster yields |C|−1 single contractions onto its root.
            // Deterministic mode rates synchronously (§11) so the memento
            // sequence is thread-count invariant.
            let det_rep: Vec<NodeId>;
            let rep: &[NodeId] = if ctx.deterministic {
                det_rep = crate::coarsening::deterministic::cluster(
                    &dynhg,
                    ctx,
                    communities.as_deref(),
                    cmax,
                    limit,
                );
                &det_rep
            } else {
                clustering::cluster_with_scratch(
                    &dynhg,
                    ctx,
                    communities.as_deref(),
                    cmax,
                    limit,
                    &mut cluster_scratch,
                )
            };
            let pass_start = mementos.len();
            for v in 0..n as NodeId {
                let u = rep[v as usize];
                if u != v && dynhg.is_active_node(v) {
                    debug_assert!(dynhg.is_active_node(u), "representatives are fixed points");
                    mementos.push(dynhg.contract(v, u));
                }
            }
            let contracted = mementos.len() - pass_start;
            if contracted <= (ctx.min_shrink * n_before as f64) as usize {
                // pass discarded: revert its contractions and stop
                dynhg.uncontract_batch(&mementos[pass_start..]);
                mementos.truncate(pass_start);
                break;
            }
        }
    });
    stats.contractions = mementos.len();

    // ---- initial partitioning on the frozen coarsest snapshot ----
    let snapshot = dynhg.freeze();
    let coarse_parts = timer
        .time("initial_partitioning", || initial::initial_partition(Arc::new(snapshot.hg), ctx));
    // project onto the dynamic slot space; inactive slots get a valid
    // placeholder (they inherit Π(u) the moment they are uncontracted)
    let mut parts: Vec<BlockId> = vec![0; n];
    for (c, &slot) in snapshot.to_dynamic.iter().enumerate() {
        parts[slot as usize] = coarse_parts[c];
    }

    // ---- batch uncoarsening (§9) ----
    // One refinement pipeline serves every batch *and* the finest level:
    // gain table, FM scratch and the pooled partition state are sized for
    // the input once. The bind below is the single full value rebuild of
    // the whole run; every batch boundary afterwards parks the state,
    // reverts the batch in place on the sole-owner dynamic hypergraph,
    // re-binds the identical values and repairs only the batch delta.
    let mut pipeline = RefinementPipeline::new_for(ctx, &hg);
    let mut dyn_arc = Arc::new(dynhg);
    let mut phg = pipeline.bind(dyn_arc.clone(), &parts, ctx);
    drop(parts);

    let b_max = ctx.nlevel_batch_size.max(1);
    let mut remaining = mementos.len();
    let mut touched: Vec<NodeId> = Vec::new();
    let mut noted_expiry = false;
    while remaining > 0 {
        let batch_start = remaining.saturating_sub(b_max);
        let batch = &mementos[batch_start..remaining];
        remaining = batch_start;

        // batch boundary: park Π/Φ/Λ, revert the batch in place (sole
        // owner — the parked partition released its Arc), re-bind, repair
        pipeline.park(phg);
        Arc::get_mut(&mut dyn_arc)
            .expect("the parked partition was the only other owner")
            .uncontract_batch_parallel(batch, ctx.threads);
        phg = pipeline.unpark(dyn_arc.clone(), ctx);
        phg.apply_uncontractions(batch);
        stats.batches += 1;

        // localized refinement around the uncontracted nodes (§9);
        // ids are stable, so the batch pairs are the seeds directly.
        // Deadline: the uncontractions above can never be shed — they
        // restore the input structure — but the refinement around them
        // can, so an expired budget degrades to plain uncoarsening
        if ctx.cancel.is_expired() {
            if !noted_expiry {
                ctx.cancel.note_early_stop();
                noted_expiry = true;
            }
            continue;
        }
        touched.clear();
        touched.extend(batch.iter().flat_map(|m| [m.v, m.u]));
        touched.sort_unstable();
        touched.dedup();
        // panic isolation: the structure mutation already completed, so a
        // batch whose localized refinement unwinds is repaired
        // (revalidate, rebuild from Π if needed, rebalance) and
        // uncoarsening continues with the next batch
        let refined = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            crate::util::failpoints::fire(
                crate::util::failpoints::BATCH_UNCONTRACTION,
                &ctx.cancel,
            );
            if ctx.deterministic {
                // thread-count invariance: the seeded deterministic FM
                // replaces the racy localized LP/FM pair (its wishlist
                // subsumes LP's positive single-node moves, and it expands
                // around kept moves like the localized searches do). It
                // runs regardless of `use_fm` — it doubles as the
                // deterministic localized LP, and skipping it would leave
                // batch boundaries entirely unrefined in LP-only
                // deterministic configurations
                timer.time("localized_fm", || pipeline.fm_with_seeds(&phg, ctx, Some(&touched)));
            } else {
                timer.time("localized_lp", || pipeline.lp_localized(&phg, ctx, &touched));
                if ctx.use_fm {
                    timer.time("localized_fm", || {
                        pipeline.fm_with_seeds(&phg, ctx, Some(&touched));
                    });
                }
            }
        }));
        let worker_panicked = pipeline.workspace_mut().take_worker_panic();
        if refined.is_err() || worker_panicked {
            ctx.cancel.note_panic_recovered();
            let ws = pipeline.workspace_mut();
            ws.reset_owner(ws.owner.len());
            if phg.validate().is_err() {
                phg.rebuild_from_parts(ctx.threads);
            }
            if !phg.is_balanced() {
                crate::refinement::rebalance::rebalance(&phg, ctx);
            }
        }
    }

    // ---- finest level: global refinement (paper: global FM + flows) ----
    // The fully uncontracted dynamic structure has the input's node/net id
    // spaces and pin multisets, so the binding transfers to the static
    // input with every value preserved — no final rebuild either.
    let phg = pipeline.rebind_preserving(phg, hg, ctx);
    pipeline.refine_at_distance(&phg, ctx, 0);

    let pool = pipeline.partition_pool();
    stats.rebinds = pool.rebinds();
    stats.value_rebuilds = pool.value_rebuilds();
    stats.structural_allocs = pool.structural_allocs();
    (phg, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::{Context, Preset};
    use crate::generators::{planted_hypergraph, PlantedParams};

    /// Thread count for the n-level tests, overridable via
    /// `MTKH_TEST_THREADS` (CI runs this suite at 4 threads too).
    fn test_threads(default: usize) -> usize {
        std::env::var("MTKH_TEST_THREADS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
            .max(1)
    }

    fn ctx(preset: Preset, k: usize, threads: usize, seed: u64) -> Context {
        let mut c =
            Context::new(preset, k, 0.03).with_threads(test_threads(threads)).with_seed(seed);
        c.contraction_limit_factor = 24;
        c.ip_min_repetitions = 2;
        c.ip_max_repetitions = 3;
        c.fm_max_rounds = 3;
        c.nlevel_batch_size = 64;
        c
    }

    #[test]
    fn nlevel_end_to_end() {
        let hg = Arc::new(planted_hypergraph(
            &PlantedParams { n: 500, m: 900, blocks: 4, ..Default::default() },
            31,
        ));
        let phg = partition(hg.clone(), &ctx(Preset::Quality, 4, 2, 31));
        assert!(phg.is_balanced(), "imbalance {}", phg.imbalance());
        phg.verify_consistency().unwrap();
        assert!(phg.km1() < hg.num_nets() as i64 / 2);
    }

    #[test]
    fn nlevel_with_flows() {
        let hg = Arc::new(planted_hypergraph(
            &PlantedParams { n: 300, m: 550, blocks: 2, ..Default::default() },
            5,
        ));
        let phg = partition(hg, &ctx(Preset::QualityFlows, 2, 2, 5));
        assert!(phg.is_balanced());
        phg.verify_consistency().unwrap();
    }

    #[test]
    fn nlevel_uncoarsening_is_fully_incremental() {
        // Acceptance invariant of the dynamic-hypergraph scheme: after
        // initial partitioning, the uncoarsening performs zero snapshot
        // contractions and zero full rebuild_from_parts value rebuilds —
        // the only full rebuild is the post-IP bind, on one structural
        // allocation, while many batches run incrementally.
        let hg = Arc::new(planted_hypergraph(
            &PlantedParams { n: 600, m: 1100, blocks: 4, ..Default::default() },
            13,
        ));
        let (phg, stats) = partition_with_stats(hg, &ctx(Preset::Quality, 4, 2, 13));
        assert_eq!(stats.value_rebuilds, 1, "only the post-IP bind may rebuild values");
        assert_eq!(
            stats.rebinds,
            stats.batches + 1,
            "every rebind must be a value-preserving unpark (one per batch) or \
             the final static hand-off — a snapshot path would add counted \
             rebinds and rebuilds here"
        );
        assert_eq!(stats.structural_allocs, 1, "one pooled allocation for the whole run");
        assert!(stats.batches >= 2, "expected a multi-batch uncoarsening");
        assert!(stats.contractions > 0);
        assert!(phg.is_balanced(), "imbalance {}", phg.imbalance());
        phg.verify_consistency().unwrap();
    }

    #[test]
    fn nlevel_sparse_state_uncoarsening_is_fully_incremental() {
        // The SparseKState path must preserve the pooled lifecycle: one
        // structural allocation sized by the dynamic slot ranges (pin
        // capacities are stable across uncontractions), one value rebuild
        // at the post-IP bind, and value-preserving unparks at every
        // batch boundary — same invariants as the dense twin above.
        let hg = Arc::new(planted_hypergraph(
            &PlantedParams { n: 600, m: 1100, blocks: 4, ..Default::default() },
            13,
        ));
        let mut c = ctx(Preset::Quality, 4, 2, 13);
        c.kstate = crate::partition::KStateChoice::Sparse;
        let (phg, stats) = partition_with_stats(hg, &c);
        assert_eq!(stats.value_rebuilds, 1, "only the post-IP bind may rebuild values");
        assert_eq!(stats.rebinds, stats.batches + 1);
        assert_eq!(stats.structural_allocs, 1, "one pooled sparse allocation for the run");
        assert!(stats.batches >= 2, "expected a multi-batch uncoarsening");
        assert!(phg.is_balanced(), "imbalance {}", phg.imbalance());
        phg.verify_consistency().unwrap();
    }

    #[test]
    fn nlevel_deterministic_is_thread_invariant() {
        // deterministic n-level: synchronous rating on the dynamic
        // structure, seeded det-FM at every batch boundary and the
        // deterministic finest-level stack must be bit-identical for any
        // thread count (threads pinned explicitly — the MTKH_TEST_THREADS
        // override must not collapse the comparison)
        let hg = Arc::new(planted_hypergraph(
            &PlantedParams { n: 500, m: 900, blocks: 4, ..Default::default() },
            19,
        ));
        let run = |threads: usize| {
            let mut c =
                Context::new(Preset::Deterministic, 4, 0.03).with_threads(threads).with_seed(19);
            c.nlevel = true;
            c.contraction_limit_factor = 24;
            c.ip_min_repetitions = 2;
            c.ip_max_repetitions = 3;
            c.fm_max_rounds = 3;
            c.nlevel_batch_size = 64;
            let phg = partition(hg.clone(), &c);
            assert!(phg.is_balanced(), "t={threads}: imbalance {}", phg.imbalance());
            phg.verify_consistency().unwrap();
            (phg.km1(), phg.parts())
        };
        let r1 = run(1);
        let r2 = run(2);
        let r4 = run(4);
        assert_eq!(r1, r2, "t=1 vs t=2");
        assert_eq!(r2, r4, "t=2 vs t=4");
    }

    #[test]
    fn nlevel_quality_competitive_with_multilevel() {
        let mut q_total = 0i64;
        let mut d_total = 0i64;
        for seed in 0..3u64 {
            let hg = Arc::new(planted_hypergraph(
                &PlantedParams { n: 400, m: 800, blocks: 4, p_intra: 0.85, ..Default::default() },
                seed,
            ));
            q_total += partition(hg.clone(), &ctx(Preset::Quality, 4, 2, seed)).km1();
            d_total += crate::coordinator::partitioner::partition_arc(
                hg,
                &ctx(Preset::Default, 4, 2, seed),
            )
            .km1();
        }
        // Q should be within ~25% of D (typically better; paper: 1.9%
        // median improvement of Q over D)
        assert!(
            (q_total as f64) <= d_total as f64 * 1.25 + 8.0,
            "n-level {q_total} vs multilevel {d_total}"
        );
    }
}
