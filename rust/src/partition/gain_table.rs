//! The concurrent gain table (paper §6.2), in two layouts.
//!
//! Both store the benefit term `b(u) = ω({e ∈ I(u) | Φ(e, Π[u]) = 1})` and
//! the penalty terms `p(u, V_t) = ω({e ∈ I(u) | Φ(e, V_t) = 0})` separately
//! so a benefit change needs one update instead of k. Updates are atomic
//! fetch-adds driven by the pin-count transitions of the move operation
//! (update rules 1–4); values *trickle in* and may be transiently stale,
//! which the FM algorithm tolerates by recomputing benefits after each
//! round (the paper's "benefit peculiarities").
//!
//! * [`DenseGainTable`] — the flat `(k+1)·n`-word layout: one penalty slot
//!   per (node, block). Exact O(1) lookups, but the memory and the O(n·k)
//!   initialization sweep make it the wrong choice for large k.
//! * [`SparseGainTable`] — the large-k layout. Per node it stores only a
//!   *correction* for blocks in `Λ(I(u))`: `p(u, t) = pbase(u) + corr(u, t)`
//!   where `pbase(u) = Σ_{e ∈ I(u)} penalty_contrib(ω(e), 0, |e|)` depends
//!   on the structure alone (constant per level) and `corr` is non-zero
//!   only for adjacent blocks. Corrections live in a two-level store: four
//!   inline CAS-claimed slots per node (L1), spilling to a sharded hash
//!   map (L2) for high-connectivity nodes. Memory is
//!   O(n + Σ_u |Λ(I(u))|) words and initialization never touches all k
//!   blocks. The identity that makes this exact: for every objective
//!   policy, `penalty_contrib(ω, Φ, |e|) ≠ penalty_contrib(ω, 0, |e|)`
//!   requires Φ > 0, i.e. t ∈ Λ(e) — blocks outside `Λ(I(u))` always read
//!   the base value.
//!
//! The update rules are written once, against the [`GainTable`] enum's
//! `benefit_add`/`penalty_add` primitives, so the two layouts cannot drift
//! semantically: the sparse variant routes the *same* atomic deltas into
//! its correction store. (Every penalty write of rules 1–4/C1–C4 targets a
//! block that is entering, leaving, or inside Λ(e) — exactly the blocks
//! the correction store covers.)

use super::objective::{GainPolicy, Km1Policy};
use super::state::KStateMode;
use super::PartitionedHypergraph;
use crate::hypergraph::HypergraphOps;
use crate::metrics::Objective;
use crate::parallel::par_for_auto;
use crate::util::fxhash::FxHashMap;
use crate::{BlockId, EdgeId, Gain, NodeId};
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU32, Ordering};
use std::sync::Mutex;

/// Inline correction slots per node before spilling to the L2 map.
const L1_SLOTS: usize = 4;
/// Number of L2 spill shards (power of two).
const SPILL_SHARDS: usize = 64;

/// The flat dense layout (paper §6.2 verbatim): `n` benefit words plus an
/// `n × k` penalty matrix.
pub struct DenseGainTable {
    k: usize,
    benefit: Vec<AtomicI64>,
    penalty: Vec<AtomicI64>,
}

impl DenseGainTable {
    pub fn new(n: usize, k: usize) -> Self {
        DenseGainTable {
            k,
            benefit: (0..n).map(|_| AtomicI64::new(0)).collect(),
            penalty: (0..n * k).map(|_| AtomicI64::new(0)).collect(),
        }
    }

    #[inline]
    fn node_capacity(&self) -> usize {
        self.benefit.len()
    }

    fn ensure_node_capacity(&mut self, n: usize) -> bool {
        if n <= self.benefit.len() {
            return false;
        }
        let old = self.benefit.len();
        self.benefit.extend((old..n).map(|_| AtomicI64::new(0)));
        let target = n * self.k;
        let old_p = self.penalty.len();
        self.penalty.extend((old_p..target).map(|_| AtomicI64::new(0)));
        true
    }

    /// Recompute all entries from the partition for policy `P` — the
    /// O(n·k) sweep the sparse layout exists to avoid.
    fn initialize_p<P: GainPolicy, H: HypergraphOps>(
        &self,
        phg: &PartitionedHypergraph<H>,
        threads: usize,
    ) {
        let n = phg.hypergraph().num_nodes();
        par_for_auto(n, threads, |u| {
            let u = u as NodeId;
            let from = phg.block_of(u);
            let mut b: Gain = 0;
            let mut p = vec![0 as Gain; self.k];
            for &e in phg.hypergraph().incident_nets(u) {
                let w = phg.hypergraph().net_weight(e);
                let sz =
                    if P::NEEDS_NET_SIZE { phg.hypergraph().net_size(e) as u32 } else { 0 };
                b += P::benefit_contrib(w, phg.pin_count(e, from), sz);
                for t in 0..self.k {
                    p[t] += P::penalty_contrib(w, phg.pin_count(e, t as BlockId), sz);
                }
            }
            self.benefit[u as usize].store(b, Ordering::Relaxed);
            for (t, &pt) in p.iter().enumerate() {
                self.penalty[u as usize * self.k + t].store(pt, Ordering::Relaxed);
            }
        });
    }

    #[inline]
    fn benefit(&self, u: NodeId) -> Gain {
        self.benefit[u as usize].load(Ordering::Acquire)
    }

    #[inline]
    fn penalty(&self, u: NodeId, t: BlockId) -> Gain {
        self.penalty[u as usize * self.k + t as usize].load(Ordering::Acquire)
    }

    /// Best feasible move for `u` using only table lookups (O(k)).
    fn max_gain_move<H: HypergraphOps>(
        &self,
        phg: &PartitionedHypergraph<H>,
        u: NodeId,
    ) -> Option<(Gain, BlockId)> {
        let from = phg.block_of(u);
        let w = phg.hypergraph().node_weight(u);
        let b = self.benefit(u);
        let mut best: Option<(Gain, BlockId)> = None;
        for t in 0..self.k as BlockId {
            if t == from || phg.block_weight(t) + w > phg.max_block_weight(t) {
                continue;
            }
            let g = b - self.penalty(u, t);
            match best {
                None => best = Some((g, t)),
                Some((bg, bb)) => {
                    if g > bg || (g == bg && phg.block_weight(t) < phg.block_weight(bb)) {
                        best = Some((g, t));
                    }
                }
            }
        }
        best
    }
}

/// The two-level large-k layout: `p(u, t) = pbase(u) + corr(u, t)`.
///
/// Corrections are keyed by `tag = t + 1` (0 = empty slot). L1 slots are
/// claimed by CAS and their tag is then write-once until the next
/// `initialize` (which runs in an exclusive phase), so a non-zero tag is
/// final and concurrent `fetch_add`s on its value never race with a
/// re-keying. Readers sum every slot/spill entry matching the tag; a
/// reader that observes a freshly claimed tag before its first delta
/// lands merely sees a transiently stale correction — the same trickle-in
/// semantics the dense table has.
pub struct SparseGainTable {
    k: usize,
    benefit: Vec<AtomicI64>,
    /// structure-only penalty base `Σ_{e ∈ I(u)} penalty_contrib(ω, 0, |e|)`
    pbase: Vec<AtomicI64>,
    /// L1: `L1_SLOTS` inline tags per node (`block + 1`, 0 = empty)
    l1_tags: Vec<AtomicU32>,
    l1_vals: Vec<AtomicI64>,
    /// fast-path flag: does node `u` have L2 entries?
    spilled: Vec<AtomicBool>,
    /// L2: sharded spill map `u → [(tag, correction)]`
    shards: Vec<Mutex<FxHashMap<NodeId, Vec<(u32, Gain)>>>>,
}

impl SparseGainTable {
    pub fn new(n: usize, k: usize) -> Self {
        SparseGainTable {
            k,
            benefit: (0..n).map(|_| AtomicI64::new(0)).collect(),
            pbase: (0..n).map(|_| AtomicI64::new(0)).collect(),
            l1_tags: (0..n * L1_SLOTS).map(|_| AtomicU32::new(0)).collect(),
            l1_vals: (0..n * L1_SLOTS).map(|_| AtomicI64::new(0)).collect(),
            spilled: (0..n).map(|_| AtomicBool::new(false)).collect(),
            shards: (0..SPILL_SHARDS).map(|_| Mutex::new(FxHashMap::default())).collect(),
        }
    }

    #[inline]
    fn node_capacity(&self) -> usize {
        self.benefit.len()
    }

    fn ensure_node_capacity(&mut self, n: usize) -> bool {
        if n <= self.benefit.len() {
            return false;
        }
        let old = self.benefit.len();
        self.benefit.extend((old..n).map(|_| AtomicI64::new(0)));
        self.pbase.extend((old..n).map(|_| AtomicI64::new(0)));
        self.l1_tags.extend((old * L1_SLOTS..n * L1_SLOTS).map(|_| AtomicU32::new(0)));
        self.l1_vals.extend((old * L1_SLOTS..n * L1_SLOTS).map(|_| AtomicI64::new(0)));
        self.spilled.extend((old..n).map(|_| AtomicBool::new(false)));
        true
    }

    #[inline]
    fn shard_of(u: NodeId) -> usize {
        u as usize & (SPILL_SHARDS - 1)
    }

    /// Add `d` to `corr(u, t)`: match an existing L1 tag, claim an empty
    /// slot by CAS, or spill to L2. Concurrent-safe; see the type docs for
    /// why a lost CAS can still land in the winner's slot.
    fn corr_add(&self, u: NodeId, t: BlockId, d: Gain) {
        debug_assert!((t as usize) < self.k);
        if d == 0 {
            return;
        }
        let tag = t + 1;
        let base = u as usize * L1_SLOTS;
        for s in 0..L1_SLOTS {
            let slot = &self.l1_tags[base + s];
            let cur = slot.load(Ordering::Acquire);
            if cur == tag {
                self.l1_vals[base + s].fetch_add(d, Ordering::AcqRel);
                return;
            }
            if cur == 0 {
                match slot.compare_exchange(0, tag, Ordering::AcqRel, Ordering::Acquire) {
                    Ok(_) => {
                        self.l1_vals[base + s].fetch_add(d, Ordering::AcqRel);
                        return;
                    }
                    Err(actual) if actual == tag => {
                        self.l1_vals[base + s].fetch_add(d, Ordering::AcqRel);
                        return;
                    }
                    Err(_) => {} // claimed by another block — keep scanning
                }
            }
        }
        let mut map = self.shards[Self::shard_of(u)].lock().unwrap();
        let entries = map.entry(u).or_default();
        if let Some(en) = entries.iter_mut().find(|(tg, _)| *tg == tag) {
            en.1 += d;
        } else {
            entries.push((tag, d));
        }
        drop(map);
        self.spilled[u as usize].store(true, Ordering::Release);
    }

    /// Sum every correction recorded for `(u, t)` across both levels.
    fn corr(&self, u: NodeId, t: BlockId) -> Gain {
        let tag = t + 1;
        let base = u as usize * L1_SLOTS;
        let mut sum: Gain = 0;
        for s in 0..L1_SLOTS {
            if self.l1_tags[base + s].load(Ordering::Acquire) == tag {
                sum += self.l1_vals[base + s].load(Ordering::Acquire);
            }
        }
        if self.spilled[u as usize].load(Ordering::Acquire) {
            let map = self.shards[Self::shard_of(u)].lock().unwrap();
            if let Some(entries) = map.get(&u) {
                sum += entries.iter().filter(|(tg, _)| *tg == tag).map(|(_, v)| v).sum::<Gain>();
            }
        }
        sum
    }

    /// Recompute from the partition: `pbase` from the structure, `corr`
    /// only for `t ∈ Λ(e)` per incident net. Work is O(Σ_u Σ_{e ∈ I(u)}
    /// |Λ(e)|) — no factor k. Runs in an exclusive phase (no concurrent
    /// moves), so clearing shards up front then repopulating node-parallel
    /// is race-free: each node's L1 slots and spill entry are touched only
    /// by the thread owning the node.
    fn initialize_p<P: GainPolicy, H: HypergraphOps>(
        &self,
        phg: &PartitionedHypergraph<H>,
        threads: usize,
    ) {
        for shard in &self.shards {
            shard.lock().unwrap().clear();
        }
        let n = phg.hypergraph().num_nodes();
        par_for_auto(n, threads, |u| {
            let u = u as NodeId;
            let base = u as usize * L1_SLOTS;
            for s in 0..L1_SLOTS {
                self.l1_tags[base + s].store(0, Ordering::Relaxed);
                self.l1_vals[base + s].store(0, Ordering::Relaxed);
            }
            self.spilled[u as usize].store(false, Ordering::Relaxed);
            let from = phg.block_of(u);
            let mut b: Gain = 0;
            let mut pb: Gain = 0;
            for &e in phg.hypergraph().incident_nets(u) {
                let w = phg.hypergraph().net_weight(e);
                let sz =
                    if P::NEEDS_NET_SIZE { phg.hypergraph().net_size(e) as u32 } else { 0 };
                b += P::benefit_contrib(w, phg.pin_count(e, from), sz);
                let zero = P::penalty_contrib(w, 0, sz);
                pb += zero;
                for t in phg.connectivity_set(e) {
                    let d = P::penalty_contrib(w, phg.pin_count(e, t), sz) - zero;
                    self.corr_add(u, t, d);
                }
            }
            self.benefit[u as usize].store(b, Ordering::Relaxed);
            self.pbase[u as usize].store(pb, Ordering::Relaxed);
        });
    }

    #[inline]
    fn benefit(&self, u: NodeId) -> Gain {
        self.benefit[u as usize].load(Ordering::Acquire)
    }

    #[inline]
    fn penalty(&self, u: NodeId, t: BlockId) -> Gain {
        self.pbase[u as usize].load(Ordering::Acquire) + self.corr(u, t)
    }

    /// Best feasible move for `u` among the *adjacent* blocks — the blocks
    /// with a recorded correction, a superset of Λ(I(u)) at read time.
    /// O(|Λ(I(u))|) instead of the dense table's O(k). Non-adjacent blocks
    /// are never candidates (their gain is never better under km1 and a
    /// zero-gain escape move is the rebalancer's job, not FM's), which is
    /// the same candidate set the pin-count fallback path uses.
    ///
    /// Tie-break is a total order (gain desc, target weight asc, block id
    /// asc): candidate enumeration order depends on L1 claim order, so the
    /// first-encounter tie-break of the dense scan would be
    /// schedule-dependent here.
    fn max_gain_move<H: HypergraphOps>(
        &self,
        phg: &PartitionedHypergraph<H>,
        u: NodeId,
    ) -> Option<(Gain, BlockId)> {
        let from = phg.block_of(u);
        let w = phg.hypergraph().node_weight(u);
        let b = self.benefit(u);
        let base = u as usize * L1_SLOTS;
        let mut l1 = [0u32; L1_SLOTS];
        let mut nl1 = 0;
        for s in 0..L1_SLOTS {
            let tag = self.l1_tags[base + s].load(Ordering::Acquire);
            if tag != 0 && !l1[..nl1].contains(&tag) {
                l1[nl1] = tag;
                nl1 += 1;
            }
        }
        let spill: Vec<u32> = if self.spilled[u as usize].load(Ordering::Acquire) {
            let map = self.shards[Self::shard_of(u)].lock().unwrap();
            map.get(&u)
                .map(|es| es.iter().map(|&(tg, _)| tg).filter(|tg| !l1[..nl1].contains(tg)).collect())
                .unwrap_or_default()
        } else {
            Vec::new()
        };
        let mut best: Option<(Gain, BlockId)> = None;
        for &tag in l1[..nl1].iter().chain(spill.iter()) {
            let t = tag - 1;
            if t == from || phg.block_weight(t) + w > phg.max_block_weight(t) {
                continue;
            }
            let g = b - self.penalty(u, t);
            match best {
                None => best = Some((g, t)),
                Some((bg, bb)) => {
                    let (wt, wb) = (phg.block_weight(t), phg.block_weight(bb));
                    if g > bg || (g == bg && (wt < wb || (wt == wb && t < bb))) {
                        best = Some((g, t));
                    }
                }
            }
        }
        best
    }
}

/// The gain table behind either layout. `new` keeps the historical default
/// (dense); the pipeline picks the layout from the resolved
/// [`KStateMode`] via [`GainTable::with_mode`].
pub enum GainTable {
    Dense(DenseGainTable),
    Sparse(SparseGainTable),
}

impl GainTable {
    /// Build an empty dense table for `n` nodes and `k` blocks.
    pub fn new(n: usize, k: usize) -> Self {
        GainTable::Dense(DenseGainTable::new(n, k))
    }

    /// Build an empty table in the layout matching a partition-state mode.
    pub fn with_mode(n: usize, k: usize, mode: KStateMode) -> Self {
        match mode {
            KStateMode::Dense => GainTable::Dense(DenseGainTable::new(n, k)),
            KStateMode::Sparse => GainTable::Sparse(SparseGainTable::new(n, k)),
        }
    }

    /// Which layout this table uses.
    pub fn mode(&self) -> KStateMode {
        match self {
            GainTable::Dense(_) => KStateMode::Dense,
            GainTable::Sparse(_) => KStateMode::Sparse,
        }
    }

    /// Number of nodes the table has entries for.
    #[inline]
    pub fn node_capacity(&self) -> usize {
        match self {
            GainTable::Dense(t) => t.node_capacity(),
            GainTable::Sparse(t) => t.node_capacity(),
        }
    }

    /// Grow the table to hold at least `n` nodes (never shrinks). The
    /// refinement pipeline sizes the table once for the finest level and
    /// reuses it across all uncoarsening levels; coarser levels simply use
    /// a prefix of the entries, so this only allocates when a caller
    /// exceeds the initial capacity.
    pub fn ensure_node_capacity(&mut self, n: usize) -> bool {
        match self {
            GainTable::Dense(t) => t.ensure_node_capacity(n),
            GainTable::Sparse(t) => t.ensure_node_capacity(n),
        }
    }

    /// Recompute all entries from the partition (parallel over nodes).
    /// km1 entry point; [`Self::initialize_p`] is the generic form.
    pub fn initialize<H: HypergraphOps>(&self, phg: &PartitionedHypergraph<H>, threads: usize) {
        self.initialize_p::<Km1Policy, H>(phg, threads);
    }

    /// Recompute all entries from the partition for policy `P`
    /// (parallel over nodes).
    pub fn initialize_p<P: GainPolicy, H: HypergraphOps>(
        &self,
        phg: &PartitionedHypergraph<H>,
        threads: usize,
    ) {
        match self {
            GainTable::Dense(t) => t.initialize_p::<P, H>(phg, threads),
            GainTable::Sparse(t) => t.initialize_p::<P, H>(phg, threads),
        }
    }

    #[inline]
    pub fn benefit(&self, u: NodeId) -> Gain {
        match self {
            GainTable::Dense(t) => t.benefit(u),
            GainTable::Sparse(t) => t.benefit(u),
        }
    }

    #[inline]
    pub fn penalty(&self, u: NodeId, t: BlockId) -> Gain {
        match self {
            GainTable::Dense(tb) => tb.penalty(u, t),
            GainTable::Sparse(tb) => tb.penalty(u, t),
        }
    }

    /// Cached gain `g_u(t) = b(u) − p(u, t)`.
    #[inline]
    pub fn gain(&self, u: NodeId, t: BlockId) -> Gain {
        self.benefit(u) - self.penalty(u, t)
    }

    /// Best feasible move for `u` using only table lookups: O(k) on the
    /// dense layout, O(|Λ(I(u))|) on the sparse one.
    pub fn max_gain_move<H: HypergraphOps>(
        &self,
        phg: &PartitionedHypergraph<H>,
        u: NodeId,
    ) -> Option<(Gain, BlockId)> {
        match self {
            GainTable::Dense(t) => t.max_gain_move(phg, u),
            GainTable::Sparse(t) => t.max_gain_move(phg, u),
        }
    }

    /// Atomic `b(v) += d`.
    #[inline]
    fn benefit_add(&self, v: NodeId, d: Gain) {
        match self {
            GainTable::Dense(t) => {
                t.benefit[v as usize].fetch_add(d, Ordering::AcqRel);
            }
            GainTable::Sparse(t) => {
                t.benefit[v as usize].fetch_add(d, Ordering::AcqRel);
            }
        }
    }

    /// Atomic `p(v, t) += d` — a flat fetch-add on the dense layout, a
    /// correction-store add on the sparse one. Every caller (rules 1–4,
    /// C1–C4) targets a block entering, leaving, or inside Λ(e), so the
    /// correction store covers it.
    #[inline]
    fn penalty_add(&self, v: NodeId, t: BlockId, d: Gain) {
        match self {
            GainTable::Dense(tb) => {
                tb.penalty[v as usize * tb.k + t as usize].fetch_add(d, Ordering::AcqRel);
            }
            GainTable::Sparse(tb) => tb.corr_add(v, t, d),
        }
    }

    /// Per-objective trickle-in update, triggered by the move operation
    /// for each incident net with the post-transition pin counts. The
    /// dispatch is a `const` match: `Km1Policy` selects exactly the
    /// pre-refactor rules 1–4 (the naive "generic delta" formulation
    /// would add a mover-benefit update km1 deliberately omits — the
    /// mover's benefit stays stale until [`Self::recompute_benefit_p`],
    /// the paper's "benefit peculiarities").
    pub(crate) fn update_for_pin_change<P: GainPolicy, H: HypergraphOps>(
        &self,
        phg: &PartitionedHypergraph<H>,
        e: EdgeId,
        from: BlockId,
        to: BlockId,
        phi_from_after: u32,
        phi_to_after: u32,
    ) {
        match P::OBJECTIVE {
            Objective::Km1 => {
                self.update_km1(phg, e, from, to, phi_from_after, phi_to_after)
            }
            Objective::Cut => {
                self.update_cut(phg, e, from, to, phi_from_after, phi_to_after)
            }
            Objective::Soed => {
                self.update_km1(phg, e, from, to, phi_from_after, phi_to_after);
                self.update_cut(phg, e, from, to, phi_from_after, phi_to_after);
            }
        }
    }

    /// Update rules 1–4 (paper §6.2) for the connectivity metric.
    fn update_km1<H: HypergraphOps>(
        &self,
        phg: &PartitionedHypergraph<H>,
        e: EdgeId,
        from: BlockId,
        to: BlockId,
        phi_from_after: u32,
        phi_to_after: u32,
    ) {
        let w = phg.hypergraph().net_weight(e);
        let pins = phg.hypergraph().pins(e);
        // (1) Φ(e, V_s) = 0: every pin pays a penalty for moving to V_s
        if phi_from_after == 0 {
            for &v in pins {
                self.penalty_add(v, from, w);
            }
        }
        // (2) Φ(e, V_s) = 1: the last remaining pin in V_s gains benefit
        if phi_from_after == 1 {
            for &v in pins {
                if phg.block_of(v) == from {
                    self.benefit_add(v, w);
                }
            }
        }
        // (3) Φ(e, V_t) = 1: moving into V_t no longer penalized
        if phi_to_after == 1 {
            for &v in pins {
                self.penalty_add(v, to, -w);
            }
        }
        // (4) Φ(e, V_t) = 2: the previously-lone pin in V_t loses benefit
        if phi_to_after == 2 {
            for &v in pins {
                if phg.block_of(v) == to {
                    self.benefit_add(v, -w);
                }
            }
        }
    }

    /// Cut-net trickle-in rules, mirroring the km1 discipline: benefit
    /// b(v) = −ω(e) iff e is internal to v's block (Φ = |e|), penalty
    /// p(v, t) = −ω(e) iff t can absorb e (Φ(e, t) = |e|−1). Only the
    /// two blocks whose Φ changed need repairs; the mover's own benefit
    /// follows the same stale-until-recompute convention as km1.
    fn update_cut<H: HypergraphOps>(
        &self,
        phg: &PartitionedHypergraph<H>,
        e: EdgeId,
        from: BlockId,
        to: BlockId,
        phi_from_after: u32,
        phi_to_after: u32,
    ) {
        let sz = phg.hypergraph().net_size(e) as u32;
        if sz < 2 {
            return; // single-pin nets are never cut
        }
        let w = phg.hypergraph().net_weight(e);
        let pins = phg.hypergraph().pins(e);
        // (C1) Φ(e, V_s) = |e|−1: e was internal to V_s — remaining V_s
        // pins stop carrying the −ω benefit, and V_s becomes absorbable
        // (p(·, V_s) gains the −ω term)
        if phi_from_after + 1 == sz {
            for &v in pins {
                if phg.block_of(v) == from {
                    self.benefit_add(v, w);
                }
                self.penalty_add(v, from, -w);
            }
        }
        // (C2) Φ(e, V_s) = |e|−2: V_s stops being absorbable
        if phi_from_after + 2 == sz {
            for &v in pins {
                self.penalty_add(v, from, w);
            }
        }
        // (C3) Φ(e, V_t) = |e|−1: V_t becomes absorbable
        if phi_to_after + 1 == sz {
            for &v in pins {
                self.penalty_add(v, to, -w);
            }
        }
        // (C4) Φ(e, V_t) = |e|: e became internal to V_t — its pins gain
        // the −ω benefit and V_t stops being absorbable
        if phi_to_after == sz {
            for &v in pins {
                if phg.block_of(v) == to {
                    self.benefit_add(v, -w);
                }
                self.penalty_add(v, to, w);
            }
        }
    }

    /// Recompute `b(u)` from scratch (post-round benefit repair for moved
    /// nodes — the fix for the benefit race described in the paper).
    /// km1 entry point.
    pub fn recompute_benefit<H: HypergraphOps>(&self, phg: &PartitionedHypergraph<H>, u: NodeId) {
        self.recompute_benefit_p::<Km1Policy, H>(phg, u);
    }

    /// Recompute `b(u)` from scratch for policy `P`.
    pub fn recompute_benefit_p<P: GainPolicy, H: HypergraphOps>(
        &self,
        phg: &PartitionedHypergraph<H>,
        u: NodeId,
    ) {
        let from = phg.block_of(u);
        let mut b: Gain = 0;
        for &e in phg.hypergraph().incident_nets(u) {
            let sz = if P::NEEDS_NET_SIZE { phg.hypergraph().net_size(e) as u32 } else { 0 };
            b += P::benefit_contrib(phg.hypergraph().net_weight(e), phg.pin_count(e, from), sz);
        }
        match self {
            GainTable::Dense(t) => t.benefit[u as usize].store(b, Ordering::Release),
            GainTable::Sparse(t) => t.benefit[u as usize].store(b, Ordering::Release),
        }
    }

    /// Exhaustive comparison against from-scratch values (test helper —
    /// Lemma 6.1: after quiescence, penalties are exact for all nodes and
    /// benefits exact for unmoved nodes; pass `moved` to skip those).
    /// km1 entry point.
    pub fn verify_against<H: HypergraphOps>(
        &self,
        phg: &PartitionedHypergraph<H>,
        moved: &dyn Fn(NodeId) -> bool,
    ) -> Result<(), String> {
        self.verify_against_p::<Km1Policy, H>(phg, moved)
    }

    /// Exhaustive comparison against from-scratch values of policy `P` —
    /// all (u, t) pairs, so on the sparse layout this also checks that
    /// blocks outside Λ(I(u)) correctly read the base value.
    pub fn verify_against_p<P: GainPolicy, H: HypergraphOps>(
        &self,
        phg: &PartitionedHypergraph<H>,
        moved: &dyn Fn(NodeId) -> bool,
    ) -> Result<(), String> {
        let k = phg.k();
        for u in phg.hypergraph().nodes() {
            let from = phg.block_of(u);
            let mut b: Gain = 0;
            for &e in phg.hypergraph().incident_nets(u) {
                let sz =
                    if P::NEEDS_NET_SIZE { phg.hypergraph().net_size(e) as u32 } else { 0 };
                b += P::benefit_contrib(
                    phg.hypergraph().net_weight(e),
                    phg.pin_count(e, from),
                    sz,
                );
            }
            if !moved(u) && b != self.benefit(u) {
                return Err(format!("benefit({u}): table {} real {b}", self.benefit(u)));
            }
            for t in 0..k as BlockId {
                let mut p: Gain = 0;
                for &e in phg.hypergraph().incident_nets(u) {
                    let sz =
                        if P::NEEDS_NET_SIZE { phg.hypergraph().net_size(e) as u32 } else { 0 };
                    p += P::penalty_contrib(
                        phg.hypergraph().net_weight(e),
                        phg.pin_count(e, t),
                        sz,
                    );
                }
                if p != self.penalty(u, t) {
                    return Err(format!(
                        "penalty({u},{t}): table {} real {p}",
                        self.penalty(u, t)
                    ));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergraph::Hypergraph;
    use crate::partition::objective::{CutNetPolicy, SoedPolicy};
    use std::sync::Arc;

    fn setup() -> (PartitionedHypergraph, GainTable) {
        let hg = Arc::new(Hypergraph::from_nets(
            7,
            &[vec![0, 2], vec![0, 1, 3, 4], vec![3, 4, 6], vec![2, 5, 6]],
            None,
            None,
        ));
        let mut phg = PartitionedHypergraph::new(hg, 2);
        phg.set_uniform_max_weight(1.0);
        phg.assign_all(&[0, 0, 0, 1, 1, 1, 1], 1);
        let gt = GainTable::new(7, 2);
        gt.initialize(&phg, 1);
        (phg, gt)
    }

    #[test]
    fn initial_values_match_definition() {
        let (phg, gt) = setup();
        gt.verify_against(&phg, &|_| false).unwrap();
        // table gain equals pin-count gain for all (u, t)
        for u in 0..7 {
            for t in 0..2 {
                if phg.block_of(u) != t {
                    assert_eq!(gt.gain(u, t), phg.gain(u, t), "u={u} t={t}");
                }
            }
        }
    }

    #[test]
    fn updates_keep_unmoved_nodes_exact() {
        let (phg, gt) = setup();
        let mut moved = vec![false; 7];
        for (u, to) in [(0u32, 1u32), (5, 0), (3, 0)] {
            phg.try_move(u, to, Some(&gt)).unwrap();
            moved[u as usize] = true;
        }
        gt.verify_against(&phg, &|u| moved[u as usize]).unwrap();
        // after benefit repair, moved nodes are exact too
        for u in 0..7u32 {
            if moved[u as usize] {
                gt.recompute_benefit(&phg, u);
            }
        }
        gt.verify_against(&phg, &|_| false).unwrap();
    }

    #[test]
    fn concurrent_updates_converge_when_each_node_moves_once() {
        let (phg, gt) = setup();
        let moved: Vec<std::sync::atomic::AtomicBool> =
            (0..7).map(|_| std::sync::atomic::AtomicBool::new(false)).collect();
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let phg = &phg;
                let gt = &gt;
                let moved = &moved;
                s.spawn(move || {
                    let mut rng = crate::util::Rng::new(t + 100);
                    for _ in 0..20 {
                        let u = rng.next_below(7);
                        // each node at most once (FM round discipline)
                        if moved[u].swap(true, Ordering::SeqCst) {
                            continue;
                        }
                        let to = 1 - phg.block_of(u as NodeId);
                        phg.try_move(u as NodeId, to, Some(gt));
                    }
                });
            }
        });
        // Lemma 6.1: after quiescence penalties exact everywhere,
        // benefits exact for unmoved nodes
        gt.verify_against(&phg, &|u| moved[u as usize].load(Ordering::SeqCst)).unwrap();
    }

    #[test]
    fn max_gain_move_matches_exhaustive() {
        let (phg, gt) = setup();
        for u in 0..7u32 {
            let a = gt.max_gain_move(&phg, u);
            let b = phg.max_gain_move(u);
            // table sees all k blocks; pin-count version only adjacent ones.
            // when both found a move, gains must agree
            if let (Some((ga, _)), Some((gb, _))) = (a, b) {
                assert!(ga >= gb, "table must not underestimate: {ga} vs {gb}");
            }
        }
    }

    // ---- sparse layout ----

    /// 12 nodes / k = 6 fixture with a high-degree hub (node 0) adjacent
    /// to more blocks than the L1 slots hold, forcing the L2 spill path.
    fn sparse_setup() -> (Vec<Vec<NodeId>>, Vec<BlockId>, usize) {
        let nets = vec![
            vec![0, 1],
            vec![0, 2],
            vec![0, 3],
            vec![0, 4],
            vec![0, 5],
            vec![0, 6, 7],
            vec![1, 2, 8],
            vec![3, 9, 10],
            vec![5, 11],
            vec![6, 8, 10, 11],
        ];
        let parts: Vec<BlockId> = vec![0, 1, 2, 3, 4, 5, 0, 1, 2, 3, 4, 5];
        (nets, parts, 6)
    }

    fn twin_tables<P: GainPolicy>(
    ) -> (PartitionedHypergraph, GainTable, PartitionedHypergraph, GainTable) {
        let (nets, parts, k) = sparse_setup();
        let hg = Arc::new(Hypergraph::from_nets(12, &nets, None, None));
        let mk = |gt_mode: KStateMode| {
            let mut phg = PartitionedHypergraph::new(Arc::clone(&hg), k);
            phg.set_uniform_max_weight(1.5);
            phg.assign_all(&parts, 1);
            let gt = GainTable::with_mode(12, k, gt_mode);
            gt.initialize_p::<P, Hypergraph>(&phg, 1);
            (phg, gt)
        };
        let (dp, dt) = mk(KStateMode::Dense);
        let (sp, st) = mk(KStateMode::Sparse);
        (dp, dt, sp, st)
    }

    fn assert_table_parity<P: GainPolicy>(
        dp: &PartitionedHypergraph,
        dt: &GainTable,
        sp: &PartitionedHypergraph,
        st: &GainTable,
        moved: &dyn Fn(NodeId) -> bool,
    ) {
        let k = dp.k();
        for u in 0..12u32 {
            if !moved(u) {
                assert_eq!(dt.benefit(u), st.benefit(u), "benefit({u})");
            }
            for t in 0..k as BlockId {
                assert_eq!(dt.penalty(u, t), st.penalty(u, t), "penalty({u},{t})");
            }
        }
        dt.verify_against_p::<P, Hypergraph>(dp, moved).unwrap();
        st.verify_against_p::<P, Hypergraph>(sp, moved).unwrap();
    }

    fn sparse_matches_dense_for<P: GainPolicy>() {
        let (dp, dt, sp, st) = twin_tables::<P>();
        assert_table_parity::<P>(&dp, &dt, &sp, &st, &|_| false);
        // randomized move sequence applied to both twins
        let mut rng = crate::util::Rng::new(42);
        let mut moved = vec![false; 12];
        for _ in 0..120 {
            let u = rng.next_below(12) as NodeId;
            let t = rng.next_below(6) as BlockId;
            if dp.block_of(u) == t {
                continue;
            }
            let a = dp.try_move_p::<P>(u, t, Some(&dt));
            let b = sp.try_move_p::<P>(u, t, Some(&st));
            assert_eq!(a.is_some(), b.is_some());
            if a.is_some() {
                moved[u as usize] = true;
            }
        }
        assert_table_parity::<P>(&dp, &dt, &sp, &st, &|u| moved[u as usize]);
        // after benefit repair, everything is exact
        for u in 0..12u32 {
            if moved[u as usize] {
                dt.recompute_benefit_p::<P, Hypergraph>(&dp, u);
                st.recompute_benefit_p::<P, Hypergraph>(&sp, u);
            }
        }
        assert_table_parity::<P>(&dp, &dt, &sp, &st, &|_| false);
    }

    #[test]
    fn sparse_matches_dense_km1() {
        sparse_matches_dense_for::<Km1Policy>();
    }

    #[test]
    fn sparse_matches_dense_cut() {
        sparse_matches_dense_for::<CutNetPolicy>();
    }

    #[test]
    fn sparse_matches_dense_soed() {
        sparse_matches_dense_for::<SoedPolicy>();
    }

    #[test]
    fn hub_node_spills_to_l2_and_stays_exact() {
        let (_, _, sp, st) = twin_tables::<Km1Policy>();
        // node 0 is adjacent to 5 foreign blocks + its own — more than
        // the 4 L1 slots can hold
        if let GainTable::Sparse(t) = &st {
            assert!(
                t.spilled[0].load(Ordering::Relaxed),
                "hub must exercise the spill path"
            );
        } else {
            panic!("expected sparse layout");
        }
        st.verify_against_p::<Km1Policy, Hypergraph>(&sp, &|_| false).unwrap();
    }

    #[test]
    fn sparse_max_gain_move_agrees_with_dense_on_gain() {
        let (dp, dt, sp, st) = twin_tables::<Km1Policy>();
        for u in 0..12u32 {
            let a = dt.max_gain_move(&dp, u);
            let b = st.max_gain_move(&sp, u);
            // the sparse table only proposes adjacent blocks; when both
            // propose, the gains must agree (dense never beats it: under
            // km1 a non-adjacent block maximizes the penalty)
            match (a, b) {
                (Some((ga, _)), Some((gb, _))) => assert_eq!(ga, gb, "u={u}"),
                (None, None) => {}
                (a, b) => panic!("u={u}: dense {a:?} sparse {b:?}"),
            }
        }
    }
}
