//! The concurrent gain table (paper §6.2).
//!
//! Stores the benefit term `b(u) = ω({e ∈ I(u) | Φ(e, Π[u]) = 1})` and the
//! penalty terms `p(u, V_t) = ω({e ∈ I(u) | Φ(e, V_t) = 0})` separately —
//! `(k+1)·n` memory words — so a benefit change needs one update instead of
//! k. Updates are atomic fetch-adds driven by the pin-count transitions of
//! the move operation (update rules 1–4); values *trickle in* and may be
//! transiently stale, which the FM algorithm tolerates by recomputing
//! benefits after each round (the paper's "benefit peculiarities").

use super::objective::{GainPolicy, Km1Policy};
use super::PartitionedHypergraph;
use crate::hypergraph::HypergraphOps;
use crate::metrics::Objective;
use crate::parallel::par_for_auto;
use crate::{BlockId, EdgeId, Gain, NodeId};
use std::sync::atomic::{AtomicI64, Ordering};

pub struct GainTable {
    k: usize,
    benefit: Vec<AtomicI64>,
    penalty: Vec<AtomicI64>,
}

impl GainTable {
    /// Build an empty table for `n` nodes and `k` blocks.
    pub fn new(n: usize, k: usize) -> Self {
        GainTable {
            k,
            benefit: (0..n).map(|_| AtomicI64::new(0)).collect(),
            penalty: (0..n * k).map(|_| AtomicI64::new(0)).collect(),
        }
    }

    /// Number of nodes the table has entries for.
    #[inline]
    pub fn node_capacity(&self) -> usize {
        self.benefit.len()
    }

    /// Grow the table to hold at least `n` nodes (never shrinks). The
    /// refinement pipeline sizes the table once for the finest level and
    /// reuses it across all uncoarsening levels; coarser levels simply use
    /// a prefix of the entries, so this only allocates when a caller
    /// exceeds the initial capacity.
    pub fn ensure_node_capacity(&mut self, n: usize) -> bool {
        if n <= self.benefit.len() {
            return false;
        }
        let old = self.benefit.len();
        self.benefit.extend((old..n).map(|_| AtomicI64::new(0)));
        let target = n * self.k;
        let old_p = self.penalty.len();
        self.penalty.extend((old_p..target).map(|_| AtomicI64::new(0)));
        true
    }

    /// Recompute all entries from the partition (parallel over nodes).
    /// km1 entry point; [`Self::initialize_p`] is the generic form.
    pub fn initialize<H: HypergraphOps>(&self, phg: &PartitionedHypergraph<H>, threads: usize) {
        self.initialize_p::<Km1Policy, H>(phg, threads);
    }

    /// Recompute all entries from the partition for policy `P`
    /// (parallel over nodes).
    pub fn initialize_p<P: GainPolicy, H: HypergraphOps>(
        &self,
        phg: &PartitionedHypergraph<H>,
        threads: usize,
    ) {
        let n = phg.hypergraph().num_nodes();
        par_for_auto(n, threads, |u| {
            let u = u as NodeId;
            let from = phg.block_of(u);
            let mut b: Gain = 0;
            let mut p = vec![0 as Gain; self.k];
            for &e in phg.hypergraph().incident_nets(u) {
                let w = phg.hypergraph().net_weight(e);
                let sz =
                    if P::NEEDS_NET_SIZE { phg.hypergraph().net_size(e) as u32 } else { 0 };
                b += P::benefit_contrib(w, phg.pin_count(e, from), sz);
                for t in 0..self.k {
                    p[t] += P::penalty_contrib(w, phg.pin_count(e, t as BlockId), sz);
                }
            }
            self.benefit[u as usize].store(b, Ordering::Relaxed);
            for (t, &pt) in p.iter().enumerate() {
                self.penalty[u as usize * self.k + t].store(pt, Ordering::Relaxed);
            }
        });
    }

    #[inline]
    pub fn benefit(&self, u: NodeId) -> Gain {
        self.benefit[u as usize].load(Ordering::Acquire)
    }

    #[inline]
    pub fn penalty(&self, u: NodeId, t: BlockId) -> Gain {
        self.penalty[u as usize * self.k + t as usize].load(Ordering::Acquire)
    }

    /// Cached gain `g_u(t) = b(u) − p(u, t)`.
    #[inline]
    pub fn gain(&self, u: NodeId, t: BlockId) -> Gain {
        self.benefit(u) - self.penalty(u, t)
    }

    /// Best feasible move for `u` using only table lookups (O(k)).
    pub fn max_gain_move<H: HypergraphOps>(
        &self,
        phg: &PartitionedHypergraph<H>,
        u: NodeId,
    ) -> Option<(Gain, BlockId)> {
        let from = phg.block_of(u);
        let w = phg.hypergraph().node_weight(u);
        let b = self.benefit(u);
        let mut best: Option<(Gain, BlockId)> = None;
        for t in 0..self.k as BlockId {
            if t == from || phg.block_weight(t) + w > phg.max_block_weight(t) {
                continue;
            }
            let g = b - self.penalty(u, t);
            match best {
                None => best = Some((g, t)),
                Some((bg, bb)) => {
                    if g > bg || (g == bg && phg.block_weight(t) < phg.block_weight(bb)) {
                        best = Some((g, t));
                    }
                }
            }
        }
        best
    }

    /// Per-objective trickle-in update, triggered by the move operation
    /// for each incident net with the post-transition pin counts. The
    /// dispatch is a `const` match: `Km1Policy` selects exactly the
    /// pre-refactor rules 1–4 (the naive "generic delta" formulation
    /// would add a mover-benefit update km1 deliberately omits — the
    /// mover's benefit stays stale until [`Self::recompute_benefit_p`],
    /// the paper's "benefit peculiarities").
    pub(crate) fn update_for_pin_change<P: GainPolicy, H: HypergraphOps>(
        &self,
        phg: &PartitionedHypergraph<H>,
        e: EdgeId,
        from: BlockId,
        to: BlockId,
        phi_from_after: u32,
        phi_to_after: u32,
    ) {
        match P::OBJECTIVE {
            Objective::Km1 => {
                self.update_km1(phg, e, from, to, phi_from_after, phi_to_after)
            }
            Objective::Cut => {
                self.update_cut(phg, e, from, to, phi_from_after, phi_to_after)
            }
            Objective::Soed => {
                self.update_km1(phg, e, from, to, phi_from_after, phi_to_after);
                self.update_cut(phg, e, from, to, phi_from_after, phi_to_after);
            }
        }
    }

    /// Update rules 1–4 (paper §6.2) for the connectivity metric.
    fn update_km1<H: HypergraphOps>(
        &self,
        phg: &PartitionedHypergraph<H>,
        e: EdgeId,
        from: BlockId,
        to: BlockId,
        phi_from_after: u32,
        phi_to_after: u32,
    ) {
        let w = phg.hypergraph().net_weight(e);
        let pins = phg.hypergraph().pins(e);
        // (1) Φ(e, V_s) = 0: every pin pays a penalty for moving to V_s
        if phi_from_after == 0 {
            for &v in pins {
                self.penalty[v as usize * self.k + from as usize]
                    .fetch_add(w, Ordering::AcqRel);
            }
        }
        // (2) Φ(e, V_s) = 1: the last remaining pin in V_s gains benefit
        if phi_from_after == 1 {
            for &v in pins {
                if phg.block_of(v) == from {
                    self.benefit[v as usize].fetch_add(w, Ordering::AcqRel);
                }
            }
        }
        // (3) Φ(e, V_t) = 1: moving into V_t no longer penalized
        if phi_to_after == 1 {
            for &v in pins {
                self.penalty[v as usize * self.k + to as usize]
                    .fetch_sub(w, Ordering::AcqRel);
            }
        }
        // (4) Φ(e, V_t) = 2: the previously-lone pin in V_t loses benefit
        if phi_to_after == 2 {
            for &v in pins {
                if phg.block_of(v) == to {
                    self.benefit[v as usize].fetch_sub(w, Ordering::AcqRel);
                }
            }
        }
    }

    /// Cut-net trickle-in rules, mirroring the km1 discipline: benefit
    /// b(v) = −ω(e) iff e is internal to v's block (Φ = |e|), penalty
    /// p(v, t) = −ω(e) iff t can absorb e (Φ(e, t) = |e|−1). Only the
    /// two blocks whose Φ changed need repairs; the mover's own benefit
    /// follows the same stale-until-recompute convention as km1.
    fn update_cut<H: HypergraphOps>(
        &self,
        phg: &PartitionedHypergraph<H>,
        e: EdgeId,
        from: BlockId,
        to: BlockId,
        phi_from_after: u32,
        phi_to_after: u32,
    ) {
        let sz = phg.hypergraph().net_size(e) as u32;
        if sz < 2 {
            return; // single-pin nets are never cut
        }
        let w = phg.hypergraph().net_weight(e);
        let pins = phg.hypergraph().pins(e);
        // (C1) Φ(e, V_s) = |e|−1: e was internal to V_s — remaining V_s
        // pins stop carrying the −ω benefit, and V_s becomes absorbable
        // (p(·, V_s) gains the −ω term)
        if phi_from_after + 1 == sz {
            for &v in pins {
                if phg.block_of(v) == from {
                    self.benefit[v as usize].fetch_add(w, Ordering::AcqRel);
                }
                self.penalty[v as usize * self.k + from as usize]
                    .fetch_sub(w, Ordering::AcqRel);
            }
        }
        // (C2) Φ(e, V_s) = |e|−2: V_s stops being absorbable
        if phi_from_after + 2 == sz {
            for &v in pins {
                self.penalty[v as usize * self.k + from as usize]
                    .fetch_add(w, Ordering::AcqRel);
            }
        }
        // (C3) Φ(e, V_t) = |e|−1: V_t becomes absorbable
        if phi_to_after + 1 == sz {
            for &v in pins {
                self.penalty[v as usize * self.k + to as usize]
                    .fetch_sub(w, Ordering::AcqRel);
            }
        }
        // (C4) Φ(e, V_t) = |e|: e became internal to V_t — its pins gain
        // the −ω benefit and V_t stops being absorbable
        if phi_to_after == sz {
            for &v in pins {
                if phg.block_of(v) == to {
                    self.benefit[v as usize].fetch_sub(w, Ordering::AcqRel);
                }
                self.penalty[v as usize * self.k + to as usize]
                    .fetch_add(w, Ordering::AcqRel);
            }
        }
    }

    /// Recompute `b(u)` from scratch (post-round benefit repair for moved
    /// nodes — the fix for the benefit race described in the paper).
    /// km1 entry point.
    pub fn recompute_benefit<H: HypergraphOps>(&self, phg: &PartitionedHypergraph<H>, u: NodeId) {
        self.recompute_benefit_p::<Km1Policy, H>(phg, u);
    }

    /// Recompute `b(u)` from scratch for policy `P`.
    pub fn recompute_benefit_p<P: GainPolicy, H: HypergraphOps>(
        &self,
        phg: &PartitionedHypergraph<H>,
        u: NodeId,
    ) {
        let from = phg.block_of(u);
        let mut b: Gain = 0;
        for &e in phg.hypergraph().incident_nets(u) {
            let sz = if P::NEEDS_NET_SIZE { phg.hypergraph().net_size(e) as u32 } else { 0 };
            b += P::benefit_contrib(phg.hypergraph().net_weight(e), phg.pin_count(e, from), sz);
        }
        self.benefit[u as usize].store(b, Ordering::Release);
    }

    /// Exhaustive comparison against from-scratch values (test helper —
    /// Lemma 6.1: after quiescence, penalties are exact for all nodes and
    /// benefits exact for unmoved nodes; pass `moved` to skip those).
    /// km1 entry point.
    pub fn verify_against<H: HypergraphOps>(
        &self,
        phg: &PartitionedHypergraph<H>,
        moved: &dyn Fn(NodeId) -> bool,
    ) -> Result<(), String> {
        self.verify_against_p::<Km1Policy, H>(phg, moved)
    }

    /// Exhaustive comparison against from-scratch values of policy `P`.
    pub fn verify_against_p<P: GainPolicy, H: HypergraphOps>(
        &self,
        phg: &PartitionedHypergraph<H>,
        moved: &dyn Fn(NodeId) -> bool,
    ) -> Result<(), String> {
        for u in phg.hypergraph().nodes() {
            let from = phg.block_of(u);
            let mut b: Gain = 0;
            for &e in phg.hypergraph().incident_nets(u) {
                let sz =
                    if P::NEEDS_NET_SIZE { phg.hypergraph().net_size(e) as u32 } else { 0 };
                b += P::benefit_contrib(
                    phg.hypergraph().net_weight(e),
                    phg.pin_count(e, from),
                    sz,
                );
            }
            if !moved(u) && b != self.benefit(u) {
                return Err(format!("benefit({u}): table {} real {b}", self.benefit(u)));
            }
            for t in 0..self.k as BlockId {
                let mut p: Gain = 0;
                for &e in phg.hypergraph().incident_nets(u) {
                    let sz =
                        if P::NEEDS_NET_SIZE { phg.hypergraph().net_size(e) as u32 } else { 0 };
                    p += P::penalty_contrib(
                        phg.hypergraph().net_weight(e),
                        phg.pin_count(e, t),
                        sz,
                    );
                }
                if p != self.penalty(u, t) {
                    return Err(format!(
                        "penalty({u},{t}): table {} real {p}",
                        self.penalty(u, t)
                    ));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergraph::Hypergraph;
    use std::sync::Arc;

    fn setup() -> (PartitionedHypergraph, GainTable) {
        let hg = Arc::new(Hypergraph::from_nets(
            7,
            &[vec![0, 2], vec![0, 1, 3, 4], vec![3, 4, 6], vec![2, 5, 6]],
            None,
            None,
        ));
        let mut phg = PartitionedHypergraph::new(hg, 2);
        phg.set_uniform_max_weight(1.0);
        phg.assign_all(&[0, 0, 0, 1, 1, 1, 1], 1);
        let gt = GainTable::new(7, 2);
        gt.initialize(&phg, 1);
        (phg, gt)
    }

    #[test]
    fn initial_values_match_definition() {
        let (phg, gt) = setup();
        gt.verify_against(&phg, &|_| false).unwrap();
        // table gain equals pin-count gain for all (u, t)
        for u in 0..7 {
            for t in 0..2 {
                if phg.block_of(u) != t {
                    assert_eq!(gt.gain(u, t), phg.gain(u, t), "u={u} t={t}");
                }
            }
        }
    }

    #[test]
    fn updates_keep_unmoved_nodes_exact() {
        let (phg, gt) = setup();
        let mut moved = vec![false; 7];
        for (u, to) in [(0u32, 1u32), (5, 0), (3, 0)] {
            phg.try_move(u, to, Some(&gt)).unwrap();
            moved[u as usize] = true;
        }
        gt.verify_against(&phg, &|u| moved[u as usize]).unwrap();
        // after benefit repair, moved nodes are exact too
        for u in 0..7u32 {
            if moved[u as usize] {
                gt.recompute_benefit(&phg, u);
            }
        }
        gt.verify_against(&phg, &|_| false).unwrap();
    }

    #[test]
    fn concurrent_updates_converge_when_each_node_moves_once() {
        let (phg, gt) = setup();
        let moved: Vec<std::sync::atomic::AtomicBool> =
            (0..7).map(|_| std::sync::atomic::AtomicBool::new(false)).collect();
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let phg = &phg;
                let gt = &gt;
                let moved = &moved;
                s.spawn(move || {
                    let mut rng = crate::util::Rng::new(t + 100);
                    for _ in 0..20 {
                        let u = rng.next_below(7);
                        // each node at most once (FM round discipline)
                        if moved[u].swap(true, Ordering::SeqCst) {
                            continue;
                        }
                        let to = 1 - phg.block_of(u as NodeId);
                        phg.try_move(u as NodeId, to, Some(gt));
                    }
                });
            }
        });
        // Lemma 6.1: after quiescence penalties exact everywhere,
        // benefits exact for unmoved nodes
        gt.verify_against(&phg, &|u| moved[u as usize].load(Ordering::SeqCst)).unwrap();
    }

    #[test]
    fn max_gain_move_matches_exhaustive() {
        let (phg, gt) = setup();
        for u in 0..7u32 {
            let a = gt.max_gain_move(&phg, u);
            let b = phg.max_gain_move(u);
            // table sees all k blocks; pin-count version only adjacent ones.
            // when both found a move, gains must agree
            if let (Some((ga, _)), Some((gb, _))) = (a, b) {
                assert!(ga >= gb, "table must not underestimate: {ga} vs {gb}");
            }
        }
    }
}
