//! The partition-state pool for zero-copy uncoarsening.
//!
//! Both Mt-KaHyPar papers (arXiv 2303.17679 §6, arXiv 2010.10272)
//! attribute much of their speedup to reusing level-sized memory across
//! the multilevel hierarchy instead of reallocating it. This module
//! applies that discipline to the §6.1 partition structure itself:
//! a [`PartitionPool`] owns one finest-level-sized allocation of the
//! block assignment Π, the block weights, the packed pin counts Φ, the
//! connectivity bitsets Λ and the per-net locks, and *binds* that memory
//! to each level's hypergraph in turn.
//!
//! Ownership protocol: the buffers always live inside the currently
//! bound [`PartitionedHypergraph`] (so the refiners see a perfectly
//! ordinary partition); the pool itself holds only the finest-level
//! reservation, the reused projection scratch and the allocation
//! counters. Each rebind consumes the previous partition and hands its
//! memory directly to the next one: [`PartitionPool::rebind_level`]
//! snapshots the coarse Π prefix into the scratch vector, points the
//! buffers at the finer hypergraph, projects the assignment through
//! `fine_to_coarse` straight into the existing Π atomics and repairs
//! Φ/Λ/weights in place. The final bind simply stays with the partition
//! returned to the caller — the pool never copies level-sized state and
//! never allocates after the first bind (asserted by the
//! `structural_allocs` counter, mirroring the gain-table counters).
//!
//! Three rebind flavors differ in how the *values* are treated:
//! [`PartitionPool::rebind_level`] projects + fully rebuilds (multilevel
//! uncoarsening, counted by `value_rebuilds`);
//! [`PartitionPool::rebind_with_parts`] delta-repairs when the hypergraph
//! is unchanged (V-cycle restores, counted by `delta_repairs`); and the
//! [`PartitionPool::park`]/[`PartitionPool::unpark`]/
//! [`PartitionPool::rebind_preserving`] trio moves the buffers without
//! touching values at all — the n-level batch loop parks the binding,
//! mutates the dynamic hypergraph in place, unparks and repairs only the
//! batch delta via `apply_uncontractions`.

use super::state::{resolve_kstate, HgState, KStateChoice, KStateMode, PartitionState, StateDims};
use super::PartitionedHypergraph;
use crate::hypergraph::HypergraphOps;
use crate::parallel::{par_for_auto, SharedSlice};
use crate::{BlockId, EdgeId, NodeId, NodeWeight};
use std::sync::atomic::{AtomicI64, AtomicU32};
use std::sync::Arc;

/// The §6.1 state a [`PartitionedHypergraph`] is made of, detached from
/// any hypergraph. Only values tied to a specific binding are stale;
/// the memory itself is always valid for any hypergraph that fits. The
/// per-net portion (Φ/Λ/locks for hypergraphs, endpoint-pair words for
/// plain graphs) lives behind the [`PartitionState`] parameter.
pub(crate) struct PartitionBuffers<S: PartitionState = HgState> {
    pub(crate) part: Vec<AtomicU32>,
    pub(crate) block_weight: Vec<AtomicI64>,
    pub(crate) max_block_weight: Vec<NodeWeight>,
    pub(crate) state: S,
}

impl<S: PartitionState> PartitionBuffers<S> {
    /// One structural allocation covering the given dimensions.
    pub(crate) fn alloc(dims: &StateDims) -> Self {
        PartitionBuffers {
            part: (0..dims.num_nodes).map(|_| AtomicU32::new(0)).collect(),
            block_weight: (0..dims.k).map(|_| AtomicI64::new(0)).collect(),
            max_block_weight: vec![NodeWeight::MAX; dims.k],
            state: S::alloc(dims),
        }
    }

    /// Can these buffers host a partition of the given dimensions without
    /// reallocation? The block dimension must match exactly — the packed
    /// pin-count layout and the weight vectors are k-shaped, so buffers
    /// reclaimed from a partition with a different k (e.g. a V-cycle on
    /// an externally built partition) force a counted reallocation
    /// instead of silently reusing wrong-sized state.
    fn fits(&self, dims: &StateDims) -> bool {
        self.block_weight.len() == dims.k
            && self.part.len() >= dims.num_nodes
            && self.state.fits(dims)
    }
}

/// Manager of one finest-level-sized `PartitionBuffers` allocation that
/// always lives inside the [`PartitionedHypergraph`] bound to the current
/// uncoarsening level; the pool carries the reservation, the reused
/// projection scratch and the allocation counters, and moves the memory
/// from one binding to the next.
///
/// Value semantics per operation (memory is always reused): [`Self::bind`]
/// fully rebuilds, [`Self::rebind_level`] projects Π and rebuilds Φ/Λ,
/// [`Self::rebind_with_parts`] delta-repairs on an unchanged hypergraph,
/// and [`Self::park`]/[`Self::unpark`]/[`Self::rebind_preserving`] move
/// the buffers with every value intact. The counters
/// ([`Self::structural_allocs`], [`Self::value_rebuilds`],
/// [`Self::delta_repairs`], [`Self::rebinds`]) exist so tests can pin
/// which path ran — see the lifecycle table in `rust/ARCHITECTURE.md`.
pub struct PartitionPool<S: PartitionState = HgState> {
    k: usize,
    /// state layout new allocations use (resolved once — per-run choice)
    mode: KStateMode,
    reserved_nodes: usize,
    reserved_nets: usize,
    reserved_net_size: usize,
    /// sparse-arena reservation (Σ slot need at the finest level; slot
    /// needs only shrink under contraction, so this covers every level)
    reserved_pin_budget: usize,
    /// coarse-Π snapshot for in-place projection (coarse-level-sized use
    /// of a finest-level-sized vector)
    proj_scratch: Vec<BlockId>,
    /// buffers of a partition temporarily released ([`Self::park`]) while
    /// the caller mutates the hypergraph the values refer to (n-level
    /// batch uncontractions need `&mut` on the sole-owner structure)
    parked: Option<PartitionBuffers<S>>,
    structural_allocs: usize,
    rebinds: usize,
    value_rebuilds: usize,
    delta_repairs: usize,
}

impl<S: PartitionState> PartitionPool<S> {
    /// An empty pool for `k`-way partitions in the automatically resolved
    /// state layout (dense below [`super::state::SPARSE_K_THRESHOLD`],
    /// sparse above, `MTKH_KSTATE` overriding). Call [`Self::reserve`]
    /// with the finest hypergraph before the first bind so the single
    /// allocation covers the whole uncoarsening sequence.
    pub fn new(k: usize) -> Self {
        Self::with_mode(k, resolve_kstate(KStateChoice::Auto, k))
    }

    /// An empty pool with an explicitly chosen state layout.
    pub fn with_mode(k: usize, mode: KStateMode) -> Self {
        PartitionPool {
            k,
            mode,
            reserved_nodes: 0,
            reserved_nets: 0,
            reserved_net_size: 0,
            reserved_pin_budget: 0,
            proj_scratch: Vec::new(),
            parked: None,
            structural_allocs: 0,
            rebinds: 0,
            value_rebuilds: 0,
            delta_repairs: 0,
        }
    }

    /// Record the finest-level dimensions; the first bind sizes the
    /// buffers (and the projection scratch) to cover them.
    pub fn reserve<H: HypergraphOps>(&mut self, hg: &H) {
        self.reserved_nodes = self.reserved_nodes.max(hg.num_nodes());
        self.reserved_nets = self.reserved_nets.max(hg.num_nets());
        self.reserved_net_size = self.reserved_net_size.max(hg.max_net_size());
        if self.mode == KStateMode::Sparse {
            let dims = StateDims::for_hg(hg, self.k, self.mode);
            self.reserved_pin_budget = self.reserved_pin_budget.max(dims.pin_budget);
        }
        if self.proj_scratch.len() < self.reserved_nodes {
            self.proj_scratch.resize(self.reserved_nodes, 0);
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// State layout this pool allocates (buffers reclaimed from an
    /// external partition may temporarily carry the other layout; they
    /// are reused as long as they fit their own layout's dimensions).
    pub fn mode(&self) -> KStateMode {
        self.mode
    }

    /// How often buffer memory was allocated. Stays at 1 across an entire
    /// uncoarsening sequence whose finest level was [`Self::reserve`]d —
    /// the zero-copy invariant the reuse tests assert.
    pub fn structural_allocs(&self) -> usize {
        self.structural_allocs
    }

    /// How often a bound partition was re-pointed at another hypergraph.
    pub fn rebinds(&self) -> usize {
        self.rebinds
    }

    /// How often the partition *values* (Π/Φ/Λ/weights) were rebuilt from
    /// scratch — `assign_all` on a bind or the per-level
    /// `rebuild_from_parts` of a projection rebind. The incremental
    /// n-level path keeps this at 1 (the post-IP bind) across an entire
    /// uncoarsening sequence: batch boundaries go through
    /// [`Self::park`]/[`Self::unpark`] + `apply_uncontractions` instead.
    pub fn value_rebuilds(&self) -> usize {
        self.value_rebuilds
    }

    /// How often [`Self::rebind_with_parts`] could repair the values by a
    /// same-hypergraph delta instead of a full rebuild.
    pub fn delta_repairs(&self) -> usize {
        self.delta_repairs
    }

    /// Produce buffers able to host `hg`: reuse the `reclaimed` memory of
    /// the previous binding when it fits, otherwise perform one (counted)
    /// allocation sized to the maximum of `hg` and the reservation.
    fn buffers_for<H: HypergraphOps<State = S>>(
        &mut self,
        reclaimed: Option<PartitionBuffers<S>>,
        hg: &H,
    ) -> PartitionBuffers<S> {
        match reclaimed {
            // the fit check uses the *buffer's* layout, not the pool's:
            // reclaimed dense buffers that still cover `hg` are fine to
            // keep using (the layouts are semantically interchangeable)
            Some(b) if b.fits(&StateDims::for_hg(hg, self.k, b.state.mode())) => b,
            _ => {
                self.structural_allocs += 1;
                let mut dims = StateDims::for_hg(hg, self.k, self.mode);
                dims.num_nodes = dims.num_nodes.max(self.reserved_nodes);
                dims.num_nets = dims.num_nets.max(self.reserved_nets);
                dims.max_net_size = dims.max_net_size.max(self.reserved_net_size).max(1);
                dims.pin_budget = dims.pin_budget.max(self.reserved_pin_budget);
                PartitionBuffers::alloc(&dims)
            }
        }
    }

    /// Shared bind sequence: buffers → partition → uniform limits → full
    /// assignment (the one place the bind semantics live).
    fn bind_impl<H: HypergraphOps<State = S>>(
        &mut self,
        reclaimed: Option<PartitionBuffers<S>>,
        hg: Arc<H>,
        parts: &[BlockId],
        eps: f64,
        threads: usize,
    ) -> PartitionedHypergraph<H> {
        self.value_rebuilds += 1;
        let bufs = self.buffers_for(reclaimed, &*hg);
        let mut phg = PartitionedHypergraph::from_buffers(hg, self.k, bufs);
        phg.set_uniform_max_weight(eps);
        phg.assign_all(parts, threads);
        phg
    }

    /// Bind the pooled state to `hg` with the given assignment — the
    /// first (coarsest) level of an uncoarsening sequence. Uniform block
    /// weight limits are derived from `eps`.
    pub fn bind<H: HypergraphOps<State = S>>(
        &mut self,
        hg: Arc<H>,
        parts: &[BlockId],
        eps: f64,
        threads: usize,
    ) -> PartitionedHypergraph<H> {
        self.bind_impl(None, hg, parts, eps, threads)
    }

    /// Re-point an existing binding at `hg` with an explicit assignment
    /// (V-cycle restarts and restores). When `hg` **is** the hypergraph
    /// `phg` is already bound to (and the block dimension matches), the
    /// values are repaired by a *delta*: only nodes whose block changes
    /// are moved, touching only their incident nets — the ROADMAP's
    /// "true delta repair" instead of the full value rebuild. Otherwise
    /// the memory is reused and the values rebuilt in full.
    pub fn rebind_with_parts<H: HypergraphOps<State = S>>(
        &mut self,
        mut phg: PartitionedHypergraph<H>,
        hg: Arc<H>,
        parts: &[BlockId],
        eps: f64,
        threads: usize,
    ) -> PartitionedHypergraph<H> {
        self.rebinds += 1;
        if Arc::ptr_eq(&phg.hg, &hg) && phg.k() == self.k {
            self.delta_repairs += 1;
            phg.set_uniform_max_weight(eps);
            phg.apply_parts_delta(parts, threads);
            return phg;
        }
        self.bind_impl(Some(phg.into_buffers()), hg, parts, eps, threads)
    }

    /// Temporarily release a bound partition's buffers back to the pool
    /// **without touching the values**. Used by the n-level batch loop:
    /// the partition must let go of its `Arc` so the driver can obtain
    /// `&mut` on the sole-owner [`DynamicHypergraph`] and revert a batch
    /// in place; [`Self::unpark`] re-binds the identical state afterwards.
    pub fn park<H: HypergraphOps<State = S>>(&mut self, phg: PartitionedHypergraph<H>) {
        // hard assert: silently overwriting a parked partition would drop
        // its values and hand the wrong state to the next unpark
        assert!(self.parked.is_none(), "only one partition can be parked");
        self.parked = Some(phg.into_buffers());
    }

    /// Re-bind the parked buffers to `hg`, preserving every Π/Φ/Λ/weight
    /// value (no rebuild — the caller repairs the batch delta via
    /// `apply_uncontractions`). Panics if the parked buffers cannot host
    /// `hg`: the incremental path must never reallocate, because a fresh
    /// allocation would lose the values it exists to preserve.
    pub fn unpark<H: HypergraphOps<State = S>>(
        &mut self,
        hg: Arc<H>,
        eps: f64,
    ) -> PartitionedHypergraph<H> {
        let bufs = self.parked.take().expect("no parked partition buffers");
        assert!(
            bufs.fits(&StateDims::for_hg(&*hg, self.k, bufs.state.mode())),
            "parked buffers cannot host the hypergraph without losing values"
        );
        self.rebinds += 1;
        let mut phg = PartitionedHypergraph::from_buffers(hg, self.k, bufs);
        phg.set_uniform_max_weight(eps);
        phg
    }

    /// Would [`Self::unpark`] succeed for `hg`? False when nothing is
    /// parked or when the parked buffers are too small (e.g. the caller
    /// appended node/net slots past the reservation while the partition
    /// was parked). The repartitioner uses this to pick between the
    /// value-preserving unpark and the counted growth path of
    /// [`Self::unpark_with_parts`].
    pub fn parked_fits<H: HypergraphOps<State = S>>(&self, hg: &H) -> bool {
        match &self.parked {
            Some(bufs) => bufs.fits(&StateDims::for_hg(hg, self.k, bufs.state.mode())),
            None => false,
        }
    }

    /// Re-bind the parked buffers to `hg` with an explicit assignment and
    /// a full value rebuild. This is the clean recovery from mutations
    /// that outgrew the parked buffers: [`Self::unpark`] would panic
    /// (it must preserve values and cannot), whereas here the caller
    /// supplies the values, so the memory is reused when it fits and
    /// reallocated (counted) when it doesn't.
    pub fn unpark_with_parts<H: HypergraphOps<State = S>>(
        &mut self,
        hg: Arc<H>,
        parts: &[BlockId],
        eps: f64,
        threads: usize,
    ) -> PartitionedHypergraph<H> {
        let bufs = self.parked.take().expect("no parked partition buffers");
        self.rebinds += 1;
        self.bind_impl(Some(bufs), hg, parts, eps, threads)
    }

    /// Widen the reservation beyond any hypergraph seen so far — headroom
    /// for online growth ([`crate::repartition`] sizes the arena for the
    /// expected churn so insertions stay within the first allocation).
    pub fn reserve_headroom(
        &mut self,
        nodes: usize,
        nets: usize,
        net_size: usize,
        pin_budget: usize,
    ) {
        self.reserved_nodes += nodes;
        self.reserved_nets += nets;
        self.reserved_net_size = self.reserved_net_size.max(net_size);
        if self.mode == KStateMode::Sparse {
            self.reserved_pin_budget += pin_budget;
        }
        if self.proj_scratch.len() < self.reserved_nodes {
            self.proj_scratch.resize(self.reserved_nodes, 0);
        }
    }

    /// Move a binding onto a *structurally equivalent* hypergraph of a
    /// different representation, preserving all values (no rebuild). The
    /// n-level driver uses this once, at the finest level: the fully
    /// uncontracted [`DynamicHypergraph`](crate::hypergraph::dynamic::DynamicHypergraph)
    /// has the same node/net id spaces and pin multisets as the static
    /// input, so Π/Φ/Λ/weights carry over verbatim and the flow-capable
    /// static refiner stack runs without one more `rebuild_from_parts`.
    pub fn rebind_preserving<H1, H2>(
        &mut self,
        phg: PartitionedHypergraph<H1>,
        hg: Arc<H2>,
        eps: f64,
    ) -> PartitionedHypergraph<H2>
    where
        H1: HypergraphOps<State = S>,
        H2: HypergraphOps<State = S>,
    {
        debug_assert_eq!(phg.hypergraph().num_nodes(), hg.num_nodes());
        debug_assert_eq!(phg.hypergraph().num_nets(), hg.num_nets());
        debug_assert_eq!(phg.hypergraph().total_weight(), hg.total_weight());
        self.rebinds += 1;
        let mut out = PartitionedHypergraph::from_buffers(hg, self.k, phg.into_buffers());
        out.set_uniform_max_weight(eps);
        out
    }

    /// The uncoarsening step: consume the refined `coarse` partition and
    /// bind its memory to the finer `fine_hg`, projecting the assignment
    /// through `fine_to_coarse` directly into the existing Π array and
    /// repairing Φ/Λ/block weights in place. The only per-level copy is
    /// the coarse-prefix Π snapshot into the pool's reused scratch (the
    /// fine Π cannot be written while the coarse Π still lives in the
    /// same atomics).
    /// When `net_map` is provided (fine net → coarse net, `EdgeId::MAX`
    /// for nets dropped during contraction), Φ/Λ are repaired net-by-net
    /// from the projected Π instead of rebuilt from scratch: dropped
    /// nets became single-cluster, hence uniform under the projection
    /// (O(1) reset), and surviving nets are recounted locally. The delta
    /// path requires reused buffers — a counted structural reallocation
    /// falls back to the full rebuild.
    pub fn rebind_level<H: HypergraphOps<State = S>>(
        &mut self,
        coarse: PartitionedHypergraph<H>,
        fine_hg: Arc<H>,
        fine_to_coarse: &[NodeId],
        net_map: Option<&[EdgeId]>,
        eps: f64,
        threads: usize,
    ) -> PartitionedHypergraph<H> {
        debug_assert_eq!(coarse.k(), self.k);
        debug_assert_eq!(fine_to_coarse.len(), fine_hg.num_nodes());
        self.rebinds += 1;
        let coarse_n = coarse.hypergraph().num_nodes();
        if self.proj_scratch.len() < coarse_n {
            // only reachable when the pool was never reserved for the
            // finest level (coarse_n ≤ fine_n ≤ reserved_nodes otherwise)
            self.proj_scratch.resize(coarse_n, 0);
        }
        {
            let scratch = SharedSlice::new(&mut self.proj_scratch[..coarse_n]);
            let coarse = &coarse;
            par_for_auto(coarse_n, threads, |u| {
                // SAFETY: each index written exactly once by one thread.
                unsafe { scratch.write(u, coarse.block_of(u as NodeId)) };
            });
        }
        let allocs_before = self.structural_allocs;
        let bufs = self.buffers_for(Some(coarse.into_buffers()), &*fine_hg);
        let reused = self.structural_allocs == allocs_before;
        let mut fine = PartitionedHypergraph::from_buffers(fine_hg, self.k, bufs);
        fine.set_uniform_max_weight(eps);
        fine.store_projected(fine_to_coarse, &self.proj_scratch, threads);
        match net_map {
            // block weights need no repair on either path: projection
            // through fine_to_coarse preserves them exactly (a cluster's
            // weight is the sum of its members' weights)
            Some(map) if reused && map.len() == fine.hypergraph().num_nets() => {
                self.delta_repairs += 1;
                fine.repair_level_delta(map, threads);
            }
            _ => {
                self.value_rebuilds += 1;
                fine.rebuild_from_parts(threads);
            }
        }
        fine
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::{Context, Preset};
    use crate::hypergraph::{contraction, Hypergraph};
    use crate::util::Rng;

    fn random_hypergraph(seed: u64, n: usize, m: usize) -> Arc<Hypergraph> {
        let mut rng = Rng::new(seed);
        let mut nets = Vec::new();
        for _ in 0..m {
            let sz = 2 + rng.next_below(5);
            let pins: Vec<NodeId> =
                rng.sample_indices(n, sz).into_iter().map(|x| x as NodeId).collect();
            if pins.len() >= 2 {
                nets.push(pins);
            }
        }
        let weights: Vec<i64> = (0..n).map(|_| 1 + rng.next_below(3) as i64).collect();
        Arc::new(Hypergraph::from_nets(n, &nets, Some(weights), None))
    }

    /// A random 2:1-ish contraction of `hg` plus the fine→coarse mapping.
    fn random_level(hg: &Arc<Hypergraph>, seed: u64) -> (Arc<Hypergraph>, Vec<NodeId>) {
        let n = hg.num_nodes();
        let mut rng = Rng::new(seed ^ 0xabcd);
        let mut rep: Vec<NodeId> = (0..n as NodeId).collect();
        for u in 0..n {
            let t = rng.next_below(n);
            if rep[t] == t as NodeId {
                rep[u] = t as NodeId;
            }
        }
        for u in 0..n {
            let mut r = rep[u] as usize;
            while rep[r] as usize != r {
                r = rep[r] as usize;
            }
            rep[u] = r as NodeId;
        }
        let c = contraction::contract(hg, &rep, 2);
        (Arc::new(c.coarse), c.fine_to_coarse)
    }

    /// Like [`random_level`] but also keeps the fine→coarse net map.
    fn random_level_full(
        hg: &Arc<Hypergraph>,
        seed: u64,
    ) -> (Arc<Hypergraph>, Vec<NodeId>, Vec<EdgeId>) {
        let n = hg.num_nodes();
        let mut rng = Rng::new(seed ^ 0xabcd);
        let mut rep: Vec<NodeId> = (0..n as NodeId).collect();
        for u in 0..n {
            let t = rng.next_below(n);
            if rep[t] == t as NodeId {
                rep[u] = t as NodeId;
            }
        }
        for u in 0..n {
            let mut r = rep[u] as usize;
            while rep[r] as usize != r {
                r = rep[r] as usize;
            }
            rep[u] = r as NodeId;
        }
        let c = contraction::contract(hg, &rep, 2);
        (Arc::new(c.coarse), c.fine_to_coarse, c.net_map)
    }

    /// Pin counts, connectivity sets and block weights after an in-place
    /// rebind must be identical to a freshly constructed partition.
    #[test]
    fn rebind_level_matches_fresh_construction() {
        for seed in 0..12u64 {
            let k = 2 + (seed % 3) as usize;
            let fine_hg = random_hypergraph(seed, 80 + seed as usize * 13, 150);
            let (coarse_hg, fine_to_coarse) = random_level(&fine_hg, seed);
            let mut rng = Rng::new(seed ^ 0x51);
            let coarse_parts: Vec<BlockId> =
                (0..coarse_hg.num_nodes()).map(|_| rng.next_below(k) as BlockId).collect();

            let mut pool = PartitionPool::new(k);
            pool.reserve(&*fine_hg);
            let coarse_phg = pool.bind(coarse_hg.clone(), &coarse_parts, 0.5, 2);
            coarse_phg.verify_consistency().unwrap();
            let fine_phg =
                pool.rebind_level(coarse_phg, fine_hg.clone(), &fine_to_coarse, None, 0.5, 2);
            fine_phg.verify_consistency().unwrap();

            // reference: legacy constructor on the projected assignment
            let ref_parts: Vec<BlockId> =
                fine_to_coarse.iter().map(|&c| coarse_parts[c as usize]).collect();
            let mut fresh = PartitionedHypergraph::new(fine_hg.clone(), k);
            fresh.set_uniform_max_weight(0.5);
            fresh.assign_all(&ref_parts, 1);

            assert_eq!(fine_phg.parts(), fresh.parts(), "seed {seed}: Π mismatch");
            for b in 0..k as BlockId {
                assert_eq!(
                    fine_phg.block_weight(b),
                    fresh.block_weight(b),
                    "seed {seed}: block weight {b}"
                );
                assert_eq!(fine_phg.max_block_weight(b), fresh.max_block_weight(b));
            }
            for e in fine_hg.nets() {
                assert_eq!(
                    fine_phg.connectivity(e),
                    fresh.connectivity(e),
                    "seed {seed}: λ({e})"
                );
                for b in 0..k as BlockId {
                    assert_eq!(
                        fine_phg.pin_count(e, b),
                        fresh.pin_count(e, b),
                        "seed {seed}: Φ({e},{b})"
                    );
                }
            }
            assert_eq!(pool.structural_allocs(), 1);
        }
    }

    /// The cross-level delta repair (net map supplied) yields the exact
    /// partition a full rebuild would, while the `value_rebuilds`
    /// counter stays at the initial bind's single rebuild.
    #[test]
    fn rebind_level_delta_repair_matches_full_rebuild() {
        for mode in [KStateMode::Dense, KStateMode::Sparse] {
            for seed in 0..8u64 {
                let k = 2 + (seed % 4) as usize;
                let fine_hg = random_hypergraph(seed ^ 0x77, 90 + seed as usize * 11, 160);
                let (mid_hg, fine_to_mid, net_map_fine) = random_level_full(&fine_hg, seed);
                let (coarse_hg, mid_to_coarse, net_map_mid) = random_level_full(&mid_hg, seed ^ 9);
                let mut rng = Rng::new(seed ^ 0x52);
                let coarse_parts: Vec<BlockId> =
                    (0..coarse_hg.num_nodes()).map(|_| rng.next_below(k) as BlockId).collect();

                let mut pool = PartitionPool::with_mode(k, mode);
                pool.reserve(&*fine_hg);
                let mut phg = pool.bind(coarse_hg, &coarse_parts, 0.5, 2);
                phg = pool.rebind_level(phg, mid_hg, &mid_to_coarse, Some(&net_map_mid), 0.5, 2);
                phg.verify_consistency().unwrap();
                phg =
                    pool.rebind_level(phg, fine_hg.clone(), &fine_to_mid, Some(&net_map_fine), 0.5, 2);
                phg.verify_consistency().unwrap();

                assert_eq!(pool.structural_allocs(), 1, "seed {seed} ({mode:?})");
                assert_eq!(pool.value_rebuilds(), 1, "seed {seed} ({mode:?}): only the bind rebuilds");
                assert_eq!(pool.delta_repairs(), 2, "seed {seed} ({mode:?})");

                // reference: legacy constructor on the twice-projected Π
                let ref_parts: Vec<BlockId> = fine_to_mid
                    .iter()
                    .map(|&m| coarse_parts[mid_to_coarse[m as usize] as usize])
                    .collect();
                let mut fresh = PartitionedHypergraph::new(fine_hg.clone(), k);
                fresh.set_uniform_max_weight(0.5);
                fresh.assign_all(&ref_parts, 1);

                assert_eq!(phg.parts(), fresh.parts(), "seed {seed} ({mode:?}): Π");
                for b in 0..k as BlockId {
                    assert_eq!(phg.block_weight(b), fresh.block_weight(b), "seed {seed} ({mode:?})");
                }
                for e in fine_hg.nets() {
                    assert_eq!(
                        phg.connectivity(e),
                        fresh.connectivity(e),
                        "seed {seed} ({mode:?}): λ({e})"
                    );
                    for b in 0..k as BlockId {
                        assert_eq!(
                            phg.pin_count(e, b),
                            fresh.pin_count(e, b),
                            "seed {seed} ({mode:?}): Φ({e},{b})"
                        );
                    }
                }
            }
        }
    }

    /// A reserved pool performs exactly one structural allocation across
    /// an entire multi-level rebind sequence.
    #[test]
    fn zero_structural_allocations_across_levels() {
        let k = 4;
        let fine_hg = random_hypergraph(7, 400, 700);
        // build a 3-deep chain of coarser levels
        let (mid_hg, fine_to_mid) = random_level(&fine_hg, 1);
        let (coarse_hg, mid_to_coarse) = random_level(&mid_hg, 2);
        let mut rng = Rng::new(99);
        let coarse_parts: Vec<BlockId> =
            (0..coarse_hg.num_nodes()).map(|_| rng.next_below(k) as BlockId).collect();

        let mut pool = PartitionPool::new(k);
        pool.reserve(&*fine_hg);
        let mut phg = pool.bind(coarse_hg, &coarse_parts, 0.5, 2);
        phg = pool.rebind_level(phg, mid_hg, &mid_to_coarse, None, 0.5, 2);
        phg = pool.rebind_level(phg, fine_hg.clone(), &fine_to_mid, None, 0.5, 2);
        phg.verify_consistency().unwrap();
        assert_eq!(
            pool.structural_allocs(),
            1,
            "uncoarsening must not allocate Π/Φ/Λ/lock storage per level"
        );
        assert_eq!(pool.rebinds(), 2);

        // a V-cycle-style full re-assignment reuses the memory too
        let parts = phg.parts();
        phg = pool.rebind_with_parts(phg, fine_hg, &parts, 0.5, 2);
        phg.verify_consistency().unwrap();
        assert_eq!(pool.structural_allocs(), 1);
        assert_eq!(pool.rebinds(), 3);
    }

    /// Same-hypergraph rebinds are delta repairs: only changed nodes are
    /// moved, and the result is identical to a full rebuild.
    #[test]
    fn rebind_with_parts_delta_matches_full_rebuild() {
        for seed in 0..6u64 {
            let k = 2 + (seed % 3) as usize;
            let hg = random_hypergraph(seed ^ 0x3d, 120, 220);
            let n = hg.num_nodes();
            let mut rng = Rng::new(seed ^ 0x91);
            let parts_a: Vec<BlockId> = (0..n).map(|_| rng.next_below(k) as BlockId).collect();
            let parts_b: Vec<BlockId> = parts_a
                .iter()
                .map(|&b| if rng.coin(0.2) { rng.next_below(k) as BlockId } else { b })
                .collect();
            let mut pool = PartitionPool::new(k);
            pool.reserve(&*hg);
            let phg = pool.bind(hg.clone(), &parts_a, 0.5, 2);
            let phg = pool.rebind_with_parts(phg, hg.clone(), &parts_b, 0.5, 2);
            assert_eq!(pool.delta_repairs(), 1, "same-hg rebind must delta-repair");
            assert_eq!(pool.value_rebuilds(), 1, "only the bind rebuilds values");
            phg.verify_consistency().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            assert_eq!(phg.parts(), parts_b, "seed {seed}");

            let mut fresh = PartitionedHypergraph::new(hg.clone(), k);
            fresh.set_uniform_max_weight(0.5);
            fresh.assign_all(&parts_b, 1);
            assert_eq!(phg.km1(), fresh.km1(), "seed {seed}");
            for b in 0..k as BlockId {
                assert_eq!(phg.block_weight(b), fresh.block_weight(b), "seed {seed}");
            }
            for e in hg.nets() {
                for b in 0..k as BlockId {
                    assert_eq!(
                        phg.pin_count(e, b),
                        fresh.pin_count(e, b),
                        "seed {seed}: Φ({e},{b})"
                    );
                }
            }
        }
    }

    /// park/unpark moves the buffers without touching any value — the
    /// n-level batch-boundary contract.
    #[test]
    fn park_unpark_preserves_values() {
        let k = 3;
        let hg = random_hypergraph(5, 90, 160);
        let mut rng = Rng::new(23);
        let parts: Vec<BlockId> =
            (0..hg.num_nodes()).map(|_| rng.next_below(k) as BlockId).collect();
        let mut pool = PartitionPool::new(k);
        pool.reserve(&*hg);
        let phg = pool.bind(hg.clone(), &parts, 0.5, 2);
        let km1 = phg.km1();
        let snapshot = phg.parts();
        pool.park(phg);
        let phg = pool.unpark(hg.clone(), 0.5);
        assert_eq!(phg.parts(), snapshot);
        assert_eq!(phg.km1(), km1);
        phg.verify_consistency().unwrap();
        assert_eq!(pool.value_rebuilds(), 1, "unpark must not rebuild values");
        assert_eq!(pool.structural_allocs(), 1);
    }

    /// The parked-growth escape hatch: when the hypergraph outgrows the
    /// parked buffers, `parked_fits` says so and `unpark_with_parts`
    /// reallocates (counted) instead of panicking; within the
    /// reservation it reuses the parked memory.
    #[test]
    fn unpark_with_parts_handles_growth() {
        let k = 2;
        let small = random_hypergraph(31, 50, 80);
        let big = random_hypergraph(32, 300, 500);
        let parts_small: Vec<BlockId> =
            (0..small.num_nodes()).map(|u| (u % k) as BlockId).collect();
        let parts_big: Vec<BlockId> = (0..big.num_nodes()).map(|u| (u % k) as BlockId).collect();

        let mut pool = PartitionPool::new(k);
        pool.reserve(&*small);
        let phg = pool.bind(small.clone(), &parts_small, 0.5, 1);
        pool.park(phg);
        assert!(pool.parked_fits(&*small));
        assert!(!pool.parked_fits(&*big), "bigger instance must not claim to fit");
        let phg = pool.unpark_with_parts(big.clone(), &parts_big, 0.5, 1);
        phg.verify_consistency().unwrap();
        assert_eq!(pool.structural_allocs(), 2, "growth must be counted");

        // within the (now bigger) buffers the same path reuses memory
        pool.park(phg);
        assert!(pool.parked_fits(&*small));
        let phg = pool.unpark_with_parts(small, &parts_small, 0.5, 1);
        phg.verify_consistency().unwrap();
        assert_eq!(pool.structural_allocs(), 2, "shrink must reuse the parked memory");
        assert!(!pool.parked_fits(&*big), "nothing parked anymore");
    }

    /// An unreserved pool still works (growth is counted, not silent).
    #[test]
    fn unreserved_pool_grows_and_counts() {
        let k = 2;
        let small = random_hypergraph(3, 40, 60);
        let big = random_hypergraph(4, 200, 400);
        let mut pool = PartitionPool::new(k);
        let parts_small: Vec<BlockId> =
            (0..small.num_nodes()).map(|u| (u % k) as BlockId).collect();
        let phg = pool.bind(small, &parts_small, 0.5, 1);
        assert_eq!(pool.structural_allocs(), 1);
        let parts_big: Vec<BlockId> = (0..big.num_nodes()).map(|u| (u % k) as BlockId).collect();
        let phg = pool.rebind_with_parts(phg, big, &parts_big, 0.5, 1);
        phg.verify_consistency().unwrap();
        assert_eq!(pool.structural_allocs(), 2, "growth must be counted");
    }

    /// Buffers reclaimed from a partition with a different block count
    /// must not be reused (k-shaped layout): the rebind reallocates and
    /// counts it — the V-cycle-on-external-partition case.
    #[test]
    fn rebind_reallocates_on_block_dimension_mismatch() {
        let hg = random_hypergraph(21, 60, 90);
        let ext = PartitionedHypergraph::new(hg.clone(), 2);
        let zeros = vec![0 as BlockId; hg.num_nodes()];
        ext.assign_all(&zeros, 1);
        let mut pool = PartitionPool::new(4);
        pool.reserve(&*hg);
        let parts: Vec<BlockId> = (0..hg.num_nodes()).map(|u| (u % 2) as BlockId).collect();
        let phg = pool.rebind_with_parts(ext, hg.clone(), &parts, 0.5, 1);
        assert_eq!(phg.k(), 4);
        phg.verify_consistency().unwrap();
        assert_eq!(pool.structural_allocs(), 1, "k mismatch must reallocate (counted)");
    }

    /// Pooled rebinds are deterministic: identical results for any thread
    /// count (static merge order, per-net exclusive rebuilds).
    #[test]
    fn rebind_deterministic_across_threads() {
        let k = 3;
        let fine_hg = random_hypergraph(11, 300, 500);
        let (coarse_hg, f2c) = random_level(&fine_hg, 5);
        let mut rng = Rng::new(13);
        let coarse_parts: Vec<BlockId> =
            (0..coarse_hg.num_nodes()).map(|_| rng.next_below(k) as BlockId).collect();
        let run = |threads: usize| {
            let mut pool = PartitionPool::new(k);
            pool.reserve(&*fine_hg);
            let phg = pool.bind(coarse_hg.clone(), &coarse_parts, 0.5, threads);
            let phg = pool.rebind_level(phg, fine_hg.clone(), &f2c, None, 0.5, threads);
            (phg.parts(), (0..k as BlockId).map(|b| phg.block_weight(b)).collect::<Vec<_>>())
        };
        assert_eq!(run(1), run(4));
    }

    #[test]
    fn pool_is_usable_through_context_dimensions() {
        // smoke: k from a Context, as the pipeline wires it
        let ctx = Context::new(Preset::Default, 3, 0.1);
        let pool: PartitionPool = PartitionPool::new(ctx.k);
        assert_eq!(pool.k(), 3);
        assert_eq!(pool.structural_allocs(), 0);
    }
}
