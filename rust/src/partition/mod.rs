//! The concurrent partition data structure (paper §6.1).
//!
//! Maintains the block assignment Π, atomic block weights, packed pin
//! counts Φ(e, V_i) under per-net spin locks, and connectivity sets Λ(e)
//! as atomic bitsets. The **move node operation** (Algorithm 6.1) performs
//! a balance-checked move and produces the move's *attributed gain* from
//! the synchronized pin-count transitions — the mechanism that lets all
//! parallel refiners track the connectivity metric exactly (Lemma 6.1).
//!
//! ## Pooled memory lifecycle (zero-copy uncoarsening)
//!
//! There are two ways to obtain a [`PartitionedHypergraph`]:
//!
//! * [`PartitionedHypergraph::new`] + [`PartitionedHypergraph::assign_all`]
//!   allocate fresh Π/Φ/Λ/lock storage sized exactly for one hypergraph —
//!   the path used by initial partitioning, tests and external callers.
//! * [`pool::PartitionPool`] owns **one finest-level-sized allocation** of
//!   the same state and *binds* it to each level's hypergraph during
//!   uncoarsening. A rebind projects Π through the contraction mapping
//!   directly into the existing atomics and then rebuilds Φ, Λ and the
//!   block weights **in place** (values are recomputed, memory is not
//!   reallocated; coarser levels address a prefix of the buffers). The
//!   final bind hands the buffers to the finest-level partition returned
//!   to the caller, so ownership always lies with exactly one of
//!   {pool, live partition}.
//!
//! Both paths share [`PartitionedHypergraph::rebuild_from_parts`], which
//! accumulates block weights in per-thread buffers merged once instead of
//! issuing one `fetch_add` per node, and rebuilds each net's pin counts
//! lock-free (nets own disjoint words of the packed array).
//!
//! ## Incremental repair (n-level uncontractions & delta rebinds)
//!
//! The structure is generic over [`HypergraphOps`], so the same Π/Φ/Λ
//! state binds to the static [`Hypergraph`] *or* to the n-level
//! [`DynamicHypergraph`].
//! Two repair paths avoid the full value rebuild entirely:
//!
//! * [`PartitionedHypergraph::apply_uncontractions`] — after
//!   `DynamicHypergraph::uncontract_batch` reverted a batch of mementos in
//!   place, each uncontracted node inherits its representative's block
//!   (Π(v) ← Π(u)) and only the nets whose pin list regained `v` get their
//!   pin count Φ(e, Π(u)) incremented. Replaced pins (`u → v` within one
//!   block) and the block weights are invariant, so the repair costs
//!   O(Σ|I(batch)|) — the §9 batch boundary never touches the other
//!   n − O(batch) nodes.
//! * [`PartitionedHypergraph::apply_parts_delta`] — re-assigning a
//!   partition on the *same* hypergraph (V-cycle restarts/restores) moves
//!   only the nodes whose block actually changed, repairing Φ/Λ/weights
//!   through the ordinary synchronized move operation instead of
//!   rebuilding every net.

pub mod connectivity;
pub mod gain_recalculation;
pub mod gain_table;
pub mod objective;
pub mod pin_counts;
pub mod pool;
pub mod sparse_state;
pub mod state;

pub use gain_recalculation::{best_prefix, recalculate_gains, Move};
pub use gain_table::GainTable;
pub use objective::{CutNetPolicy, GainPolicy, Km1Policy, SoedPolicy};
pub use pool::PartitionPool;
pub use sparse_state::SparseKState;
pub use state::{
    resolve_kstate, ConnIter, HgState, KStateChoice, KStateMode, PartitionState, PhiLambdaState,
    StateDims, StateOps, TwoPinState, SPARSE_K_THRESHOLD,
};
use pool::PartitionBuffers;

use crate::hypergraph::dynamic::{DynamicHypergraph, Memento};
use crate::hypergraph::{Hypergraph, HypergraphOps};
use crate::parallel::{par_for_auto, parallel_chunks};
use crate::{BlockId, EdgeId, Gain, NodeId, NodeWeight};
use std::sync::atomic::{AtomicI64, AtomicU32, Ordering};
use std::sync::Arc;

/// A partitioned plain graph: the generic structure bound to a CSR
/// [`Graph`](crate::graph::Graph), whose state is the two-pin
/// specialization [`TwoPinState`] (no pin-count arrays, no connectivity
/// bitsets, no per-net locks — paper §10).
pub type PartitionedGraph = PartitionedHypergraph<crate::graph::Graph>;

/// The reference block weight ⌈c(V)/k⌉ every balance-related computation
/// must share (see [`PartitionedHypergraph::reference_block_weight`]).
#[inline]
pub(crate) fn reference_block_weight(total: NodeWeight, k: usize) -> f64 {
    (total as f64 / k.max(1) as f64).ceil().max(1.0)
}

/// Standard `L_max = (1+ε)·⌈c(V)/k⌉` block weight limit (paper §2).
pub(crate) fn max_weight_for(total: NodeWeight, k: usize, eps: f64) -> NodeWeight {
    (reference_block_weight(total, k) * (1.0 + eps)).floor() as NodeWeight
}

/// A k-way partitioned hypergraph, generic over the hypergraph
/// representation (`Hypergraph` by default; the n-level scheme binds the
/// same pooled state to a `DynamicHypergraph`).
pub struct PartitionedHypergraph<H: HypergraphOps = Hypergraph> {
    hg: Arc<H>,
    k: usize,
    part: Vec<AtomicU32>,
    block_weight: Vec<AtomicI64>,
    max_block_weight: Vec<NodeWeight>,
    state: H::State,
}

/// Outcome of a [`PartitionedHypergraph::try_move`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MoveOutcome {
    /// attributed gain: positive = connectivity metric decreased
    pub attributed_gain: Gain,
}

impl PartitionedHypergraph {
    /// The reference block weight ⌈c(V)/k⌉ every balance-related
    /// computation must share: [`Self::max_weight_for`],
    /// [`Self::imbalance`], `PartitionedGraph::imbalance` and
    /// `metrics::imbalance`. Clamped to ≥ 1 so zero-weight inputs stay
    /// finite. Keeping a single definition is what guarantees
    /// `is_balanced()` and `imbalance() <= ε` can never disagree.
    #[inline]
    pub fn reference_block_weight(total: NodeWeight, k: usize) -> f64 {
        reference_block_weight(total, k)
    }

    /// Standard `L_max = (1+ε)·⌈c(V)/k⌉` block weight limits (paper §2).
    pub fn max_weight_for(total: NodeWeight, k: usize, eps: f64) -> NodeWeight {
        max_weight_for(total, k, eps)
    }
}

impl<H: HypergraphOps> PartitionedHypergraph<H> {
    /// Create an unassigned partition structure (all nodes in block 0
    /// after [`Self::assign_all`]; until then Π is undefined). The state
    /// mode is auto-selected from k (see [`resolve_kstate`]).
    pub fn new(hg: Arc<H>, k: usize) -> Self {
        Self::new_with_mode(hg, k, resolve_kstate(KStateChoice::Auto, k))
    }

    /// Create an unassigned partition structure with an explicit state
    /// mode — the dense/sparse equivalence tests and large-k callers
    /// force the representation here; graph partitions ignore the mode
    /// (their state is always the two-pin specialization).
    pub fn new_with_mode(hg: Arc<H>, k: usize, mode: KStateMode) -> Self {
        let dims = StateDims::for_hg(&*hg, k, mode);
        let bufs = PartitionBuffers::alloc(&dims);
        Self::from_buffers(hg, k, bufs)
    }

    /// Bind pooled buffers to `hg`. The buffers may be larger than the
    /// hypergraph (finest-level capacity); every accessor only addresses
    /// the `num_nodes`/`num_nets` prefix. Π, Φ, Λ and the block weights
    /// are *stale* until [`Self::assign_all`] or
    /// [`Self::rebuild_from_parts`] runs.
    pub(crate) fn from_buffers(hg: Arc<H>, k: usize, bufs: PartitionBuffers<H::State>) -> Self {
        debug_assert!(bufs.part.len() >= hg.num_nodes());
        debug_assert_eq!(bufs.block_weight.len(), k);
        debug_assert!(bufs.state.fits(&StateDims::for_hg(&*hg, k, bufs.state.mode())));
        PartitionedHypergraph {
            part: bufs.part,
            block_weight: bufs.block_weight,
            max_block_weight: bufs.max_block_weight,
            state: bufs.state,
            hg,
            k,
        }
    }

    /// Release the structural buffers back to a pool (consumes the
    /// partition; the hypergraph `Arc` is dropped, the memory survives).
    pub(crate) fn into_buffers(self) -> PartitionBuffers<H::State> {
        PartitionBuffers {
            part: self.part,
            block_weight: self.block_weight,
            max_block_weight: self.max_block_weight,
            state: self.state,
        }
    }

    /// Set uniform maximum block weights from the imbalance ratio ε
    /// (fills the existing limit vector — rebind-safe, no allocation).
    pub fn set_uniform_max_weight(&mut self, eps: f64) {
        let lmax = max_weight_for(self.hg.total_weight(), self.k, eps);
        self.max_block_weight.iter_mut().for_each(|w| *w = lmax);
    }

    /// Set explicit per-block weight limits.
    pub fn set_max_weights(&mut self, w: Vec<NodeWeight>) {
        assert_eq!(w.len(), self.k);
        self.max_block_weight = w;
    }

    /// Bulk-assign all nodes and (re)build block weights, pin counts and
    /// connectivity sets in parallel.
    pub fn assign_all(&self, parts: &[BlockId], threads: usize) {
        let n = self.hg.num_nodes();
        assert_eq!(parts.len(), n);
        par_for_auto(n, threads, |u| {
            debug_assert!((parts[u] as usize) < self.k);
            self.part[u].store(parts[u], Ordering::Relaxed);
        });
        self.rebuild_from_parts(threads);
    }

    /// Write the projected assignment of a coarser level directly into Π:
    /// `Π[u] = coarse_parts[fine_to_coarse[u]]` for every node of this
    /// (finer) hypergraph. The uncoarsening step of the pooled path — no
    /// intermediate fine-level `Vec<BlockId>` is materialized.
    pub(crate) fn store_projected(
        &self,
        fine_to_coarse: &[NodeId],
        coarse_parts: &[BlockId],
        threads: usize,
    ) {
        let n = self.hg.num_nodes();
        debug_assert_eq!(fine_to_coarse.len(), n);
        par_for_auto(n, threads, |u| {
            let b = coarse_parts[fine_to_coarse[u] as usize];
            debug_assert!((b as usize) < self.k);
            self.part[u].store(b, Ordering::Relaxed);
        });
    }

    /// Recompute block weights, pin counts and connectivity sets from the
    /// current Π — values are rebuilt, memory is reused (the per-level
    /// repair of the pooled uncoarsening path).
    ///
    /// Block weights are accumulated in per-thread buffers merged once at
    /// the end of each chunk instead of one shared `fetch_add` per node;
    /// pin counts are rebuilt lock-free because every net owns disjoint
    /// words of the packed array.
    pub fn rebuild_from_parts(&self, threads: usize) {
        let n = self.hg.num_nodes();
        for b in &self.block_weight {
            b.store(0, Ordering::Relaxed);
        }
        parallel_chunks(n, threads, |_, s, e| {
            let mut local = vec![0 as NodeWeight; self.k];
            for u in s..e {
                // inactive dynamic slots carry no weight of their own —
                // their cluster weight lives at the active representative
                if !self.hg.is_active_node(u as NodeId) {
                    continue;
                }
                let b = self.part[u].load(Ordering::Relaxed) as usize;
                debug_assert!(b < self.k);
                local[b] += self.hg.node_weight(u as NodeId);
            }
            for (b, &w) in local.iter().enumerate() {
                if w != 0 {
                    self.block_weight[b].fetch_add(w, Ordering::Relaxed);
                }
            }
        });
        self.state.rebuild(self, threads);
    }

    // ------------------------------------------------------ accessors

    #[inline]
    pub fn hypergraph(&self) -> &H {
        &self.hg
    }

    #[inline]
    pub fn hypergraph_arc(&self) -> Arc<H> {
        self.hg.clone()
    }

    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    #[inline]
    pub fn block_of(&self, u: NodeId) -> BlockId {
        self.part[u as usize].load(Ordering::Acquire)
    }

    /// Relaxed Π read for bulk value rebuilds (the preceding Π stores use
    /// `Relaxed` too; the parallel-for join provides the ordering).
    #[inline]
    pub(crate) fn block_of_relaxed(&self, u: NodeId) -> BlockId {
        self.part[u as usize].load(Ordering::Relaxed)
    }

    #[inline]
    pub fn block_weight(&self, b: BlockId) -> NodeWeight {
        self.block_weight[b as usize].load(Ordering::Acquire)
    }

    #[inline]
    pub fn max_block_weight(&self, b: BlockId) -> NodeWeight {
        self.max_block_weight[b as usize]
    }

    #[inline]
    pub fn pin_count(&self, e: EdgeId, b: BlockId) -> u32 {
        self.state.pin_count(self, e, b)
    }

    #[inline]
    pub fn connectivity(&self, e: EdgeId) -> u32 {
        self.state.connectivity(self, e)
    }

    /// Iterate the connectivity set Λ(e).
    pub fn connectivity_set(&self, e: EdgeId) -> ConnIter<'_> {
        self.state.connectivity_iter(self, e)
    }

    /// Is `u` incident to at least one cut net?
    pub fn is_border(&self, u: NodeId) -> bool {
        self.state.is_border(self, u)
    }

    /// Snapshot of the block assignment (pooled bindings may hold more
    /// atomics than nodes; only the live prefix is returned).
    pub fn parts(&self) -> Vec<BlockId> {
        self.part[..self.hg.num_nodes()].iter().map(|p| p.load(Ordering::Acquire)).collect()
    }

    // ------------------------------------------------------ move op

    /// Algorithm 6.1: balance-checked move with attributed gain.
    ///
    /// Returns `None` if the move would overload the target block; on
    /// success, applies the move and returns the attributed gain (sum over
    /// nets of ω(e) when Φ(e,from) drops to 0 minus ω(e) when Φ(e,to)
    /// rises to 1). `gain_table` (if given) receives the update rules 1–4.
    ///
    /// km1 entry point; [`Self::try_move_p`] is the policy-generic form.
    pub fn try_move(
        &self,
        u: NodeId,
        to: BlockId,
        gain_table: Option<&GainTable>,
    ) -> Option<MoveOutcome> {
        self.try_move_p::<Km1Policy>(u, to, gain_table)
    }

    /// Balance-checked move with the attributed gain (and gain-table
    /// update rules) of policy `P`.
    pub fn try_move_p<P: GainPolicy>(
        &self,
        u: NodeId,
        to: BlockId,
        gain_table: Option<&GainTable>,
    ) -> Option<MoveOutcome> {
        let from = self.block_of(u);
        if from == to {
            return None;
        }
        let w = self.hg.node_weight(u);
        // optimistic balance reservation
        let new_w = self.block_weight[to as usize].fetch_add(w, Ordering::AcqRel) + w;
        if new_w > self.max_block_weight[to as usize] {
            self.block_weight[to as usize].fetch_sub(w, Ordering::AcqRel);
            return None;
        }
        Some(self.apply_move::<P>(u, from, to, w, gain_table))
    }

    /// Move without the balance check (revert paths and rollback).
    pub fn move_unchecked(
        &self,
        u: NodeId,
        to: BlockId,
        gain_table: Option<&GainTable>,
    ) -> MoveOutcome {
        self.move_unchecked_p::<Km1Policy>(u, to, gain_table)
    }

    /// Unchecked move with the attributed gain of policy `P`.
    pub fn move_unchecked_p<P: GainPolicy>(
        &self,
        u: NodeId,
        to: BlockId,
        gain_table: Option<&GainTable>,
    ) -> MoveOutcome {
        let from = self.block_of(u);
        debug_assert_ne!(from, to);
        let w = self.hg.node_weight(u);
        self.block_weight[to as usize].fetch_add(w, Ordering::AcqRel);
        self.apply_move::<P>(u, from, to, w, gain_table)
    }

    fn apply_move<P: GainPolicy>(
        &self,
        u: NodeId,
        from: BlockId,
        to: BlockId,
        w: NodeWeight,
        gain_table: Option<&GainTable>,
    ) -> MoveOutcome {
        self.part[u as usize].store(to, Ordering::Release);
        self.block_weight[from as usize].fetch_sub(w, Ordering::AcqRel);
        // the per-net Φ/Λ transitions (Algorithm 6.1) — or the two-pin
        // endpoint-word transitions on graphs — live in the state
        let gain = self.state.apply_move::<P>(self, u, from, to, gain_table);
        MoveOutcome { attributed_gain: gain }
    }

    // ------------------------------------------------------ gains/metrics

    /// Exact move gain g_u(t) computed from the current pin counts
    /// (benefit minus penalty; paper §6). km1 entry point.
    pub fn gain(&self, u: NodeId, to: BlockId) -> Gain {
        self.gain_p::<Km1Policy>(u, to)
    }

    /// Exact move gain of policy `P` (delegated to the state's kernel:
    /// benefit − penalty over pin counts for hypergraphs, the single
    /// adjacency-array pass for graphs).
    pub fn gain_p<P: GainPolicy>(&self, u: NodeId, to: BlockId) -> Gain {
        self.state.gain::<P>(self, u, to)
    }

    /// Best move for `u` among blocks adjacent via its nets (ties broken
    /// toward the lighter block). Returns `(gain, block)`; `None` if `u`
    /// has no feasible target distinct from its block. km1 entry point.
    pub fn max_gain_move(&self, u: NodeId) -> Option<(Gain, BlockId)> {
        self.max_gain_move_p::<Km1Policy>(u)
    }

    /// Best move for `u` under policy `P` (same candidate enumeration
    /// and lighter-block tie-break as the km1 form; delegated to the
    /// state's kernel).
    pub fn max_gain_move_p<P: GainPolicy>(&self, u: NodeId) -> Option<(Gain, BlockId)> {
        self.state.max_gain_move::<P>(self, u)
    }

    /// Connectivity metric f_{λ−1}(Π).
    pub fn km1(&self) -> i64 {
        self.hg
            .nets()
            .map(|e| (self.connectivity(e).saturating_sub(1)) as i64 * self.hg.net_weight(e))
            .sum()
    }

    /// Cut-net metric f_c(Π).
    pub fn cut(&self) -> i64 {
        self.hg
            .nets()
            .filter(|&e| self.connectivity(e) > 1)
            .map(|e| self.hg.net_weight(e))
            .sum()
    }

    /// Sum-of-external-degrees metric f_s(Π) = km1 + cut.
    pub fn soed(&self) -> i64 {
        self.km1() + self.cut()
    }

    /// From-scratch metric of policy `P` from the connectivity sets.
    pub fn objective_p<P: GainPolicy>(&self) -> i64 {
        self.hg
            .nets()
            .map(|e| P::net_contribution(self.connectivity(e), self.hg.net_weight(e)))
            .sum()
    }

    /// From-scratch value of a runtime-selected objective (driver-level
    /// accept/reject decisions and reporting).
    pub fn objective_value(&self, obj: crate::metrics::Objective) -> i64 {
        match obj {
            crate::metrics::Objective::Km1 => self.km1(),
            crate::metrics::Objective::Cut => self.cut(),
            crate::metrics::Objective::Soed => self.soed(),
        }
    }

    /// Imbalance ε(Π) = max_b c(V_b)/⌈c(V)/k⌉ − 1.
    ///
    /// Uses the same ⌈c(V)/k⌉ reference as [`Self::max_weight_for`], so for
    /// integer block weights `imbalance() <= ε` holds exactly when
    /// [`Self::is_balanced`] does under uniform `L_max = (1+ε)·⌈c(V)/k⌉`
    /// limits — the two predicates cannot disagree on totals not divisible
    /// by k. Robust against empty/zero-weight inputs (denominator clamped
    /// to 1) and blocks of weight 0 (they contribute −1, never NaN).
    pub fn imbalance(&self) -> f64 {
        let per = reference_block_weight(self.hg.total_weight(), self.k);
        (0..self.k as BlockId)
            .map(|b| self.block_weight(b) as f64 / per - 1.0)
            .fold(-1.0, f64::max)
    }

    /// Do all blocks satisfy their weight limit?
    pub fn is_balanced(&self) -> bool {
        (0..self.k as BlockId).all(|b| self.block_weight(b) <= self.max_block_weight(b))
    }

    /// Full consistency check: Φ/Λ/weights derived from Π from scratch
    /// (used by tests and debug assertions — Lemma 6.1's invariant).
    pub fn verify_consistency(&self) -> Result<(), String> {
        let parts = self.parts();
        // block weights (inactive dynamic slots carry no weight)
        let mut bw = vec![0 as NodeWeight; self.k];
        for u in self.hg.nodes() {
            let b = parts[u as usize] as usize;
            if b >= self.k {
                return Err(format!("node {u} has invalid block"));
            }
            if self.hg.is_active_node(u) {
                bw[b] += self.hg.node_weight(u);
            }
        }
        for b in 0..self.k {
            if bw[b] != self.block_weight(b as BlockId) {
                return Err(format!(
                    "block {b} weight mismatch: stored {} real {}",
                    self.block_weight(b as BlockId),
                    bw[b]
                ));
            }
        }
        // structural state (pin counts + connectivity, or endpoint words)
        self.state.verify(self)
    }

    /// Full Π/Φ/Λ/block-weight consistency check as a structured error —
    /// the revalidation contract of the panic-recovery path: after a
    /// worker is isolated, the pipeline calls this and repairs via
    /// [`Self::rebuild_from_parts`] when it fails.
    pub fn validate(&self) -> crate::util::error::Result<()> {
        self.verify_consistency().map_err(crate::util::error::Error::msg)
    }

    // ------------------------------------------------- incremental repair

    /// Re-assign the partition to `parts` by *delta repair*: only nodes
    /// whose block actually changes are moved (through the synchronized
    /// move operation), so Φ/Λ/weights are touched only for nets incident
    /// to changed nodes — O(Σ|I(changed)|) instead of the O(n + m·k) full
    /// value rebuild. The result is identical to
    /// [`Self::assign_all`]`(parts)` on any starting state whose Π/Φ/Λ are
    /// mutually consistent.
    pub fn apply_parts_delta(&self, parts: &[BlockId], threads: usize) {
        let n = self.hg.num_nodes();
        assert_eq!(parts.len(), n);
        par_for_auto(n, threads, |u| {
            let to = parts[u];
            debug_assert!((to as usize) < self.k);
            if self.part[u].load(Ordering::Acquire) == to {
                return;
            }
            if self.hg.is_active_node(u as NodeId) {
                self.move_unchecked(u as NodeId, to, None);
            } else {
                // inactive dynamic slots have no pins and no weight of
                // their own: re-labeling them is a pure Π store
                self.part[u].store(to, Ordering::Release);
            }
        });
    }

    /// Cross-level Φ/Λ delta repair after a projection from the coarser
    /// level (Π must already hold the projected assignment). `net_map` is
    /// the fine → coarse net mapping recorded by `contraction::contract`:
    /// a net mapped to `EdgeId::MAX` was dropped because *all its pins
    /// contracted into one cluster*, so under the projected Π it is
    /// uniform and its values are filled in O(1) plus a row clear;
    /// surviving nets are recounted from their pins. Block weights are
    /// untouched — projection preserves every per-block total exactly
    /// (cluster weights are the sums of their members).
    pub(crate) fn repair_level_delta(&self, net_map: &[EdgeId], threads: usize) {
        let m = self.hg.num_nets();
        debug_assert_eq!(net_map.len(), m);
        // per-level layout first (no-op on fixed-stride states): the
        // sparse arena regions must match *this* hypergraph before any
        // per-net reset touches them
        self.state.begin_level(self);
        par_for_auto(m, threads, |e| {
            let eid = e as EdgeId;
            if net_map[e] == EdgeId::MAX {
                match self.hg.pins(eid).first() {
                    Some(&p0) => {
                        self.state.reset_net_uniform(self, eid, self.block_of_relaxed(p0))
                    }
                    None => self.state.reset_net_recount(self, eid),
                }
            } else {
                self.state.reset_net_recount(self, eid);
            }
        });
    }
}

impl PartitionedHypergraph<DynamicHypergraph> {
    /// Incremental Π/Φ/Λ repair after
    /// [`DynamicHypergraph::uncontract_batch`] reverted `batch` in place
    /// (paper §9): processed in the same reverse order, each uncontracted
    /// node inherits its representative's *current* block (Π(v) ← Π(u)),
    /// and Φ(e, Π(u)) is incremented for exactly the nets whose pin list
    /// regained `v` ([`DynamicHypergraph::reactivated_nets`]). Replaced
    /// pins swap `u → v` inside one block and block weights split within
    /// one block, so nothing else changes — O(Σ|I(batch)|) total, zero
    /// allocations, no `rebuild_from_parts`.
    pub fn apply_uncontractions(&self, batch: &[Memento]) {
        for m in batch.iter().rev() {
            let b = self.block_of(m.u);
            debug_assert!((b as usize) < self.k);
            self.part[m.v as usize].store(b, Ordering::Release);
            for e in self.hg.reactivated_nets(m) {
                let phi = self.state.uncontract_inc(e as usize, b);
                // u itself still holds a pin of e in block b (a *removed*
                // pin implies u was — and, with the batch suffix already
                // reverted, still is — an active pin of e), so the net was
                // already connected to b: Λ cannot change here.
                debug_assert!(phi >= 2, "Φ({e},{b}) must have counted u already");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Arc<Hypergraph> {
        Arc::new(Hypergraph::from_nets(
            7,
            &[vec![0, 2], vec![0, 1, 3, 4], vec![3, 4, 6], vec![2, 5, 6]],
            None,
            None,
        ))
    }

    fn setup(parts: &[BlockId], k: usize) -> PartitionedHypergraph {
        let mut phg = PartitionedHypergraph::new(tiny(), k);
        phg.set_uniform_max_weight(1.0); // generous for unit tests
        phg.assign_all(parts, 2);
        phg
    }

    #[test]
    fn assign_and_metrics() {
        let phg = setup(&[0, 0, 0, 1, 1, 1, 1], 2);
        phg.verify_consistency().unwrap();
        // net1 {0,1,3,4} spans both; net3 {2,5,6} spans both
        assert_eq!(phg.km1(), 2);
        assert_eq!(phg.cut(), 2);
        assert_eq!(phg.soed(), 4);
        assert_eq!(phg.block_weight(0), 3);
        assert_eq!(phg.block_weight(1), 4);
        assert!(phg.is_balanced());
    }

    #[test]
    fn move_updates_everything_and_attributes_gain() {
        let phg = setup(&[0, 0, 0, 1, 1, 1, 1], 2);
        let before = phg.km1();
        // move node 0 (nets {0,2} and {0,1,3,4}) to block 1:
        // net0 {0,2}: Φ(0,0): 2->1 no zero; Φ(0,1): 0->1 -> -1
        // net1: Φ(1,0): 2->1; Φ(1,1): 2->3 — no transitions
        let out = phg.try_move(0, 1, None).unwrap();
        assert_eq!(out.attributed_gain, -1);
        assert_eq!(phg.km1(), before + 1);
        phg.verify_consistency().unwrap();
    }

    #[test]
    fn attributed_gain_matches_km1_delta_random_walk() {
        let phg = setup(&[0, 1, 0, 1, 0, 1, 0], 2);
        let mut rng = crate::util::Rng::new(3);
        let mut km1 = phg.km1();
        for _ in 0..200 {
            let u = rng.next_below(7) as NodeId;
            let to = rng.next_below(2) as BlockId;
            if to == phg.block_of(u) {
                continue;
            }
            let expected = phg.gain(u, to);
            if let Some(out) = phg.try_move(u, to, None) {
                assert_eq!(out.attributed_gain, expected, "sequential attributed == exact");
                km1 -= out.attributed_gain;
                assert_eq!(phg.km1(), km1);
            }
        }
        phg.verify_consistency().unwrap();
    }

    #[test]
    fn balance_rejection() {
        let mut phg = PartitionedHypergraph::new(tiny(), 2);
        phg.set_max_weights(vec![4, 4]);
        phg.assign_all(&[0, 0, 0, 1, 1, 1, 1], 1);
        // block 1 already at 4 = max; moving any node in fails
        assert!(phg.try_move(0, 1, None).is_none());
        assert_eq!(phg.block_weight(1), 4); // reservation reverted
        phg.verify_consistency().unwrap();
        // but moving out is fine
        assert!(phg.try_move(3, 0, None).is_some());
    }

    #[test]
    fn max_gain_move_finds_improvement() {
        // node 6 in block 0 with its nets mostly in block 1
        let phg = setup(&[1, 1, 1, 1, 1, 1, 0], 2);
        let (g, t) = phg.max_gain_move(6).unwrap();
        assert_eq!(t, 1);
        // moving 6 to 1 uncuts nets {3,4,6} and {2,5,6}: gain 2
        assert_eq!(g, 2);
    }

    #[test]
    fn concurrent_moves_preserve_invariants() {
        let phg = setup(&[0, 1, 0, 1, 0, 1, 0], 2);
        let total_attr = std::sync::atomic::AtomicI64::new(0);
        let before = phg.km1();
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let phg = &phg;
                let total_attr = &total_attr;
                s.spawn(move || {
                    let mut rng = crate::util::Rng::new(t);
                    for _ in 0..500 {
                        let u = rng.next_below(7) as NodeId;
                        let to = rng.next_below(2) as BlockId;
                        if to != phg.block_of(u) {
                            if let Some(out) = phg.try_move(u, to, None) {
                                total_attr.fetch_add(out.attributed_gain, Ordering::Relaxed);
                            }
                        }
                    }
                });
            }
        });
        phg.verify_consistency().unwrap();
        // Lemma 6.1 flavor: sum of attributed gains equals the total change.
        assert_eq!(before - total_attr.load(Ordering::Relaxed), phg.km1());
    }

    #[test]
    fn imbalance_and_border() {
        // total weight 7, k = 2: the reference weight is ⌈7/2⌉ = 4 (the
        // same one max_weight_for uses), so the 3/4 split is perfectly
        // balanced rather than 14% over.
        let phg = setup(&[0, 0, 0, 1, 1, 1, 1], 2);
        assert!(phg.imbalance().abs() < 1e-9);
        let phg = setup(&[0, 0, 1, 1, 1, 1, 1], 2);
        assert!((phg.imbalance() - (5.0 / 4.0 - 1.0)).abs() < 1e-9);
        assert!(phg.is_border(0)); // net1 is cut
    }

    #[test]
    fn imbalance_agrees_with_is_balanced_on_indivisible_totals() {
        // total weight 7 is not divisible by k = 2: is_balanced() (integer
        // L_max check) and imbalance() <= ε (ratio check) must agree for
        // every assignment and ε — the historic bug was a c(V)/k vs
        // ⌈c(V)/k⌉ mismatch between the two.
        for eps in [0.0, 0.03, 0.1, 0.25, 0.5] {
            for split in 0..=7usize {
                let parts: Vec<BlockId> = (0..7).map(|u| u32::from(u >= split)).collect();
                let mut phg = PartitionedHypergraph::new(tiny(), 2);
                phg.set_uniform_max_weight(eps);
                phg.assign_all(&parts, 1);
                assert_eq!(
                    phg.is_balanced(),
                    phg.imbalance() <= eps + 1e-9,
                    "eps={eps} split={split}: imbalance {} vs limits {:?}",
                    phg.imbalance(),
                    (phg.block_weight(0), phg.block_weight(1), phg.max_block_weight(0))
                );
            }
        }
    }

    #[test]
    fn imbalance_robust_for_empty_blocks() {
        // k = 4 over 7 unit nodes: at least one block is empty; the empty
        // block contributes −1 and the result stays finite
        let phg = setup(&[0, 0, 0, 0, 1, 1, 2], 4);
        let imb = phg.imbalance();
        assert!(imb.is_finite());
        assert!((imb - (4.0 / 2.0 - 1.0)).abs() < 1e-9); // ⌈7/4⌉ = 2
    }
}
