//! Packed pin-count storage (paper §6.1 "Data Layout").
//!
//! "The size of a pin count value is bounded by the size of the largest
//! hyperedge. To save memory, we use a packed representation with
//! ⌈log(max |e|)⌉ bits per entry." Because entries are sub-word, updates
//! cannot use fetch-add; the partition structure serializes writers with
//! one spin lock per net and this array only guarantees atomicity at the
//! word level (readers may see values mid-move, exactly like the paper).

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Process-wide count of [`PinCountArray`] constructions. The plain-graph
/// specialization must never allocate packed pin counts (Φ(e, ·) over a
/// two-pin net is derived from the two endpoint blocks); the structural
/// bench/test pair snapshots this counter around a graph run to prove it.
static ALLOCATIONS: AtomicUsize = AtomicUsize::new(0);

/// Number of `PinCountArray::new` calls since process start.
pub fn allocation_count() -> usize {
    ALLOCATIONS.load(Ordering::Relaxed)
}

/// Packed `m × k` table of pin counts Φ(e, V_i).
pub struct PinCountArray {
    words: Vec<AtomicU64>,
    bits: u32,
    mask: u64,
    /// entries (= k) per net
    k: usize,
    /// packed entries per 64-bit word
    per_word: usize,
    /// words per net
    words_per_net: usize,
}

// UnsafeCell not needed: AtomicU64 gives interior mutability.
impl PinCountArray {
    /// `max_value` is the largest representable count (max net size).
    pub fn new(num_nets: usize, k: usize, max_value: usize) -> Self {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        let bits = (usize::BITS - max_value.max(1).leading_zeros()).max(1);
        let per_word = (64 / bits) as usize;
        let words_per_net = (k + per_word - 1) / per_word.max(1);
        let words = (0..num_nets * words_per_net).map(|_| AtomicU64::new(0)).collect();
        PinCountArray {
            words,
            bits,
            mask: if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 },
            k,
            per_word,
            words_per_net,
        }
    }

    #[inline]
    fn locate(&self, e: usize, b: usize) -> (usize, u32) {
        debug_assert!(b < self.k);
        let w = e * self.words_per_net + b / self.per_word;
        let shift = (b % self.per_word) as u32 * self.bits;
        (w, shift)
    }

    /// Read Φ(e, b).
    #[inline]
    pub fn get(&self, e: usize, b: usize) -> u32 {
        let (w, s) = self.locate(e, b);
        ((self.words[w].load(Ordering::Acquire) >> s) & self.mask) as u32
    }

    /// Increment Φ(e, b) by 1 and return the *new* value.
    ///
    /// Caller must hold the net's lock (writers are serialized per net);
    /// the store is still atomic so concurrent readers never see torn words.
    #[inline]
    pub fn inc(&self, e: usize, b: usize) -> u32 {
        let (w, s) = self.locate(e, b);
        let old = self.words[w].load(Ordering::Acquire);
        let val = ((old >> s) & self.mask) + 1;
        debug_assert!(val <= self.mask);
        self.words[w].store((old & !(self.mask << s)) | (val << s), Ordering::Release);
        val as u32
    }

    /// Decrement Φ(e, b) by 1 and return the *new* value (same contract).
    #[inline]
    pub fn dec(&self, e: usize, b: usize) -> u32 {
        let (w, s) = self.locate(e, b);
        let old = self.words[w].load(Ordering::Acquire);
        let val = (old >> s) & self.mask;
        debug_assert!(val > 0, "pin count underflow");
        let val = val - 1;
        self.words[w].store((old & !(self.mask << s)) | (val << s), Ordering::Release);
        val as u32
    }

    /// Set Φ(e, b) (initialization only).
    #[inline]
    pub fn set(&self, e: usize, b: usize, v: u32) {
        let (w, s) = self.locate(e, b);
        let old = self.words[w].load(Ordering::Acquire);
        debug_assert!((v as u64) <= self.mask);
        self.words[w].store((old & !(self.mask << s)) | ((v as u64) << s), Ordering::Release);
    }

    /// Bits per entry (exposed for the memory accounting in DESIGN/benches).
    pub fn bits_per_entry(&self) -> u32 {
        self.bits
    }

    /// Number of nets this array has storage for. The pooled uncoarsening
    /// path sizes the array once for the finest level; coarser levels use
    /// the prefix `0..num_nets` of this capacity.
    #[inline]
    pub fn nets_capacity(&self) -> usize {
        self.words.len() / self.words_per_net.max(1)
    }

    /// Blocks per net this array was laid out for.
    #[inline]
    pub fn blocks(&self) -> usize {
        self.k
    }

    /// Can a count of `v` be stored without overflowing the packed entry?
    #[inline]
    pub fn can_represent(&self, v: usize) -> bool {
        v as u64 <= self.mask
    }

    pub fn clear(&self) {
        self.clear_nets(self.nets_capacity());
    }

    /// Zero the packed row of a single net (exclusive-phase per-net
    /// repair on the cross-level delta path).
    pub fn clear_net(&self, e: usize) {
        for w in &self.words[e * self.words_per_net..(e + 1) * self.words_per_net] {
            w.store(0, Ordering::Relaxed);
        }
    }

    /// Zero the entries of the first `num_nets` nets only (per-level
    /// rebuild on a pooled array: stale counts of a previous binding past
    /// the current hypergraph's nets are never read and need no clearing).
    pub fn clear_nets(&self, num_nets: usize) {
        for w in &self.words[..num_nets * self.words_per_net] {
            w.store(0, Ordering::Relaxed);
        }
    }
}

/// Non-packed variant used where word-level fetch-add lock-freedom matters
/// (the paper notes the trade-off; the graph-optimized path uses none).
pub struct WidePinCounts {
    counts: Vec<AtomicU64>,
    k: usize,
}

impl WidePinCounts {
    pub fn new(num_nets: usize, k: usize) -> Self {
        WidePinCounts { counts: (0..num_nets * k).map(|_| AtomicU64::new(0)).collect(), k }
    }

    #[inline]
    pub fn get(&self, e: usize, b: usize) -> u32 {
        self.counts[e * self.k + b].load(Ordering::Acquire) as u32
    }

    #[inline]
    pub fn inc(&self, e: usize, b: usize) -> u32 {
        (self.counts[e * self.k + b].fetch_add(1, Ordering::AcqRel) + 1) as u32
    }

    #[inline]
    pub fn dec(&self, e: usize, b: usize) -> u32 {
        (self.counts[e * self.k + b].fetch_sub(1, Ordering::AcqRel) - 1) as u32
    }

    #[inline]
    pub fn set(&self, e: usize, b: usize, v: u32) {
        self.counts[e * self.k + b].store(v as u64, Ordering::Release);
    }
}

// Silence "unused" until the wide variant is wired into a config knob.
const _: () = {
    fn _assert_send_sync<T: Send + Sync>() {}
    fn _check() {
        _assert_send_sync::<PinCountArray>();
        _assert_send_sync::<WidePinCounts>();
    }
};

#[allow(dead_code)]
fn _unused(_: &UnsafeCell<u8>) {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packing_roundtrip() {
        // max value 5 -> 3 bits -> 21 entries per word
        let pc = PinCountArray::new(3, 40, 5);
        assert_eq!(pc.bits_per_entry(), 3);
        for e in 0..3 {
            for b in 0..40 {
                pc.set(e, b, ((e + b) % 6) as u32);
            }
        }
        for e in 0..3 {
            for b in 0..40 {
                assert_eq!(pc.get(e, b), ((e + b) % 6) as u32);
            }
        }
    }

    #[test]
    fn inc_dec() {
        let pc = PinCountArray::new(1, 8, 100);
        assert_eq!(pc.inc(0, 3), 1);
        assert_eq!(pc.inc(0, 3), 2);
        assert_eq!(pc.dec(0, 3), 1);
        assert_eq!(pc.get(0, 3), 1);
        assert_eq!(pc.get(0, 2), 0);
    }

    #[test]
    fn neighbors_unaffected() {
        let pc = PinCountArray::new(2, 16, 3);
        pc.set(0, 5, 3);
        pc.inc(0, 6);
        pc.dec(0, 5);
        assert_eq!(pc.get(0, 5), 2);
        assert_eq!(pc.get(0, 6), 1);
        assert_eq!(pc.get(1, 5), 0);
    }

    #[test]
    fn wide_variant() {
        let pc = WidePinCounts::new(2, 4);
        assert_eq!(pc.inc(1, 2), 1);
        assert_eq!(pc.get(1, 2), 1);
        assert_eq!(pc.dec(1, 2), 0);
    }
}
