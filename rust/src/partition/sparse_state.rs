//! `SparseKState` — the large-k partition state: per-net (block → count)
//! mini-tables instead of the dense §6.1 `m·k` layout.
//!
//! The dense [`PhiLambdaState`](super::state::PhiLambdaState) packs Φ as
//! an `m·k` array and Λ as `m·⌈k/64⌉` bitset words: perfect while a row
//! of blocks fits a cache line, quadratic waste at the k-in-the-thousands
//! regimes (SpMV/data placement). Mt-KaHyPar's shared-memory line keeps
//! only the blocks *actually present* in a net; this module is that
//! layout:
//!
//! - Per net `e`, an **entry region** of `c(e) = min(cap(e), k)` packed
//!   `(block+1) << 32 | count` words, of which the first λ(e) form a
//!   compact prefix of live entries (λ(e) ≤ min(|e|, k) ≤ c(e), so the
//!   region never overflows: `apply_move` decrements `from` before
//!   incrementing `to`). Λ(e) iteration scans the prefix — O(|Λ(e)|).
//! - Nets with `c(e) >` [`LINEAR_CUTOFF`] also carry an open-addressed
//!   **index region** of `(2·c(e)).next_power_of_two()` slots mapping
//!   `block+1 → entry index` (empty = 0, tombstone = `u64::MAX`), so
//!   Φ(e, b) lookups stay O(1) on huge nets. Writers (serialized by the
//!   per-net spin lock) keep the index exact; lock-free readers verify
//!   the pointed-at entry's tag and fall back to the linear prefix scan
//!   on any mismatch.
//! - `cap(e)` is [`HypergraphOps::net_pin_capacity`] — the *lifetime*
//!   slot capacity, so one layout computed at bind time survives n-level
//!   pin-list growth between value rebuilds (park → uncontract → unpark
//!   never reallocates or relayouts).
//!
//! Total memory: `Σ_e slot_need(min(cap(e), k))` arena words plus O(m)
//! offsets/λ/locks — independent of k for bounded net sizes, and
//! monotone non-increasing under contraction (each coarse net maps
//! injectively to a fine net of no smaller capacity), so the pool's
//! finest-level reservation serves every level.
//!
//! Concurrency contract: identical to the dense state. Writers hold the
//! net's spin lock; readers are lock-free and may observe a mid-move
//! snapshot (a block transiently duplicated or missing during a
//! swap-remove) — the same tolerance class as the dense bitset's
//! non-atomic flip pairs, and invisible in the quiescent phases where
//! verification and the equivalence tests run.

use super::gain_table::GainTable;
use super::objective::GainPolicy;
use super::state::{ConnIter, KStateMode, PartitionState, StateDims, StateOps};
use super::PartitionedHypergraph;
use crate::datastructures::SpinLockVec;
use crate::hypergraph::HypergraphOps;
use crate::parallel::par_for_auto;
use crate::{BlockId, EdgeId, Gain, NodeId};
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};

/// Process-wide count of [`SparseKState`] constructions — the sparse
/// twin of `pin_counts::allocation_count` / `connectivity::allocation_count`,
/// snapshotted by `perf_hotpath` to prove the pooled lifecycle allocates
/// exactly once on the large-k path.
static ALLOCATIONS: AtomicUsize = AtomicUsize::new(0);

/// Number of `SparseKState` allocations since process start.
pub fn allocation_count() -> usize {
    ALLOCATIONS.load(Ordering::Relaxed)
}

/// Entry capacities at or below this get no hash index: a linear scan of
/// ≤ 8 packed words beats a probe sequence.
const LINEAR_CUTOFF: usize = 8;

/// Index-slot tombstone (a deleted block's probe-chain placeholder).
const TOMBSTONE: u64 = u64::MAX;

/// Index slots of a net with entry capacity `c`: power-of-two table at
/// load factor ≤ 1/2, or none below the linear cutoff.
#[inline]
pub(crate) fn index_cap(entry_cap: usize) -> usize {
    if entry_cap > LINEAR_CUTOFF {
        (2 * entry_cap).next_power_of_two()
    } else {
        0
    }
}

/// Arena words a net with entry capacity `c` occupies (entry region plus
/// optional index region) — the unit [`StateDims::pin_budget`] sums.
#[inline]
pub(crate) fn net_slot_need(entry_cap: usize) -> usize {
    entry_cap + index_cap(entry_cap)
}

/// Fibonacci-style mixer for the block → probe-start hash.
#[inline]
fn hash_block(b: BlockId) -> u64 {
    (b as u64).wrapping_add(1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

#[inline]
fn pack_entry(b: BlockId, count: u32) -> u64 {
    ((b as u64 + 1) << 32) | count as u64
}

/// `block + 1` of an entry or index word; 0 = empty.
#[inline]
fn tag_of(word: u64) -> u64 {
    word >> 32
}

#[inline]
fn count_of(word: u64) -> u32 {
    word as u32
}

/// Per-net open-addressed Φ/Λ mini-tables over one pooled arena.
pub struct SparseKState {
    /// Per-net arena start (len ≥ m+1); rewritten by `rebuild`'s layout
    /// pass, which runs in the exclusive bind phase (atomics only for
    /// interior mutability through `&self`).
    offsets: Vec<AtomicU64>,
    /// Per-net entry capacity `c(e) = min(cap(e), k)` (len ≥ m).
    entry_cap: Vec<AtomicU32>,
    /// The mini-table arena: entry region then index region per net.
    slots: Vec<AtomicU64>,
    /// λ(e) — live entries of net e.
    lambda: Vec<AtomicU32>,
    net_locks: SpinLockVec,
    k: usize,
}

impl SparseKState {
    /// `(arena offset, entry capacity, index capacity)` of net `e`.
    #[inline]
    fn net_regions(&self, e: usize) -> (usize, usize, usize) {
        let off = self.offsets[e].load(Ordering::Relaxed) as usize;
        let c = self.entry_cap[e].load(Ordering::Relaxed) as usize;
        (off, c, index_cap(c))
    }

    // ------------------------------------------------- lock-free reads

    /// Φ(e, b) without the net lock: index probe (verified against the
    /// entry tag), falling back to the compact-prefix scan.
    fn phi(&self, e: usize, b: BlockId) -> u32 {
        let (off, c, x) = self.net_regions(e);
        if x > 0 {
            let base = off + c;
            let mask = x - 1;
            let mut i = (hash_block(b) as usize) & mask;
            for _ in 0..x {
                let w = self.slots[base + i].load(Ordering::Acquire);
                if w == 0 {
                    return 0;
                }
                if w != TOMBSTONE && tag_of(w) == b as u64 + 1 {
                    let idx = count_of(w) as usize;
                    if idx < c {
                        let ew = self.slots[off + idx].load(Ordering::Acquire);
                        if tag_of(ew) == b as u64 + 1 {
                            return count_of(ew);
                        }
                    }
                    break; // index raced a swap-remove: rescan linearly
                }
                i = (i + 1) & mask;
            }
        }
        self.phi_linear(off, c, b)
    }

    fn phi_linear(&self, off: usize, c: usize, b: BlockId) -> u32 {
        for i in 0..c {
            let w = self.slots[off + i].load(Ordering::Acquire);
            if w == 0 {
                return 0;
            }
            if tag_of(w) == b as u64 + 1 {
                return count_of(w);
            }
        }
        0
    }

    // ------------------------------------ writer-side index maintenance
    // (net lock held — the index mirrors the entry region exactly)

    fn index_find(&self, off: usize, c: usize, x: usize, b: BlockId) -> Option<usize> {
        let base = off + c;
        let mask = x - 1;
        let mut i = (hash_block(b) as usize) & mask;
        for _ in 0..x {
            let w = self.slots[base + i].load(Ordering::Relaxed);
            if w == 0 {
                return None;
            }
            if w != TOMBSTONE && tag_of(w) == b as u64 + 1 {
                return Some(count_of(w) as usize);
            }
            i = (i + 1) & mask;
        }
        None
    }

    fn index_insert(&self, off: usize, c: usize, x: usize, b: BlockId, entry_idx: usize) {
        let base = off + c;
        let mask = x - 1;
        let mut i = (hash_block(b) as usize) & mask;
        let mut reuse: Option<usize> = None;
        for _ in 0..x {
            let w = self.slots[base + i].load(Ordering::Relaxed);
            if w == 0 {
                let t = reuse.unwrap_or(i);
                self.slots[base + t]
                    .store(((b as u64 + 1) << 32) | entry_idx as u64, Ordering::Release);
                return;
            }
            if w == TOMBSTONE && reuse.is_none() {
                reuse = Some(i);
            }
            i = (i + 1) & mask;
        }
        let t = reuse.expect("open-addressed index keeps load factor ≤ 1/2");
        self.slots[base + t].store(((b as u64 + 1) << 32) | entry_idx as u64, Ordering::Release);
    }

    fn index_update(&self, off: usize, c: usize, x: usize, b: BlockId, entry_idx: usize) {
        let base = off + c;
        let mask = x - 1;
        let mut i = (hash_block(b) as usize) & mask;
        for _ in 0..x {
            let w = self.slots[base + i].load(Ordering::Relaxed);
            if w != 0 && w != TOMBSTONE && tag_of(w) == b as u64 + 1 {
                self.slots[base + i]
                    .store(((b as u64 + 1) << 32) | entry_idx as u64, Ordering::Release);
                return;
            }
            debug_assert!(w != 0, "index_update: live block missing from index");
            i = (i + 1) & mask;
        }
        debug_assert!(false, "index_update: live block missing from index");
    }

    fn index_remove(&self, off: usize, c: usize, x: usize, b: BlockId) {
        let base = off + c;
        let mask = x - 1;
        let mut i = (hash_block(b) as usize) & mask;
        for _ in 0..x {
            let w = self.slots[base + i].load(Ordering::Relaxed);
            if w != 0 && w != TOMBSTONE && tag_of(w) == b as u64 + 1 {
                self.slots[base + i].store(TOMBSTONE, Ordering::Release);
                return;
            }
            debug_assert!(w != 0, "index_remove: live block missing from index");
            i = (i + 1) & mask;
        }
        debug_assert!(false, "index_remove: live block missing from index");
    }

    // --------------------------------------------- serialized mutation
    // (net lock held, or the net owned exclusively during a rebuild)

    /// Entry position of block `b`, via the index when present.
    fn find_pos(&self, off: usize, c: usize, x: usize, b: BlockId) -> Option<usize> {
        if x > 0 {
            self.index_find(off, c, x, b)
        } else {
            (0..c).take_while(|&i| self.slots[off + i].load(Ordering::Relaxed) != 0).find(|&i| {
                tag_of(self.slots[off + i].load(Ordering::Relaxed)) == b as u64 + 1
            })
        }
    }

    /// Φ(e, b) += 1, inserting a live entry at position λ(e) when the
    /// block is new; returns the new count.
    fn add_pin_serialized(&self, e: usize, b: BlockId) -> u32 {
        let (off, c, x) = self.net_regions(e);
        if let Some(i) = self.find_pos(off, c, x, b) {
            let w = self.slots[off + i].load(Ordering::Relaxed);
            let cnt = count_of(w) + 1;
            self.slots[off + i].store(pack_entry(b, cnt), Ordering::Release);
            return cnt;
        }
        let lam = self.lambda[e].load(Ordering::Relaxed) as usize;
        assert!(lam < c, "sparse Φ mini-table overflow: λ(e) exceeds min(cap(e), k)");
        self.slots[off + lam].store(pack_entry(b, 1), Ordering::Release);
        if x > 0 {
            self.index_insert(off, c, x, b, lam);
        }
        self.lambda[e].store(lam as u32 + 1, Ordering::Release);
        1
    }

    /// Φ(e, b) -= 1, swap-removing the entry (and compacting the prefix)
    /// when it reaches zero; returns the new count.
    fn remove_pin_serialized(&self, e: usize, b: BlockId) -> u32 {
        let (off, c, x) = self.net_regions(e);
        let i = self
            .find_pos(off, c, x, b)
            .expect("decrementing Φ(e, b) requires a live entry for b");
        let w = self.slots[off + i].load(Ordering::Relaxed);
        let cnt = count_of(w) - 1;
        if cnt > 0 {
            self.slots[off + i].store(pack_entry(b, cnt), Ordering::Release);
            return cnt;
        }
        let lam = self.lambda[e].load(Ordering::Relaxed) as usize;
        debug_assert!(lam >= 1);
        let last = lam - 1;
        if i != last {
            // fill the hole with the tail entry *before* zeroing the tail,
            // so lock-free prefix scans never stop short of a live block
            let mv = self.slots[off + last].load(Ordering::Relaxed);
            self.slots[off + i].store(mv, Ordering::Release);
            if x > 0 {
                self.index_update(off, c, x, (tag_of(mv) - 1) as BlockId, i);
            }
        }
        self.slots[off + last].store(0, Ordering::Release);
        if x > 0 {
            self.index_remove(off, c, x, b);
        }
        self.lambda[e].store(last as u32, Ordering::Release);
        0
    }

    /// Zero net `e`'s entry/index regions and λ (exclusive phase).
    fn clear_net_serialized(&self, e: usize) {
        let (off, c, x) = self.net_regions(e);
        for i in 0..c + x {
            self.slots[off + i].store(0, Ordering::Relaxed);
        }
        self.lambda[e].store(0, Ordering::Relaxed);
    }

    /// n-level uncontraction repair: a reactivated pin joins block `b`
    /// which is already live in Λ(e); locked count-only increment.
    pub(crate) fn uncontract_inc(&self, e: usize, b: BlockId) -> u32 {
        self.net_locks.lock(e);
        let (off, c, x) = self.net_regions(e);
        let i = self
            .find_pos(off, c, x, b)
            .expect("uncontracted pin's block must already be live in Λ(e)");
        let w = self.slots[off + i].load(Ordering::Relaxed);
        let cnt = count_of(w) + 1;
        self.slots[off + i].store(pack_entry(b, cnt), Ordering::Release);
        self.net_locks.unlock(e);
        cnt
    }
}

impl PartitionState for SparseKState {
    const USE_GAIN_TABLE: bool = true;

    fn alloc(dims: &StateDims) -> Self {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        SparseKState {
            offsets: (0..dims.num_nets + 1).map(|_| AtomicU64::new(0)).collect(),
            entry_cap: (0..dims.num_nets).map(|_| AtomicU32::new(0)).collect(),
            slots: (0..dims.pin_budget).map(|_| AtomicU64::new(0)).collect(),
            lambda: (0..dims.num_nets).map(|_| AtomicU32::new(0)).collect(),
            net_locks: SpinLockVec::new(dims.num_nets),
            k: dims.k,
        }
    }

    fn fits(&self, dims: &StateDims) -> bool {
        self.k == dims.k
            && self.offsets.len() > dims.num_nets
            && self.entry_cap.len() >= dims.num_nets
            && self.lambda.len() >= dims.num_nets
            && self.net_locks.len() >= dims.num_nets
            && self.slots.len() >= dims.pin_budget
    }

    fn mode(&self) -> KStateMode {
        KStateMode::Sparse
    }
}

impl<H: HypergraphOps> StateOps<H> for SparseKState {
    fn rebuild(&self, phg: &PartitionedHypergraph<H>, threads: usize) {
        let hg = phg.hypergraph();
        let m = hg.num_nets();
        StateOps::<H>::begin_level(self, phg);
        // Parallel per-net recount — each net owns disjoint arena words.
        par_for_auto(m, threads, |e| {
            self.clear_net_serialized(e);
            for &p in hg.pins(e as EdgeId) {
                self.add_pin_serialized(e, phg.block_of_relaxed(p));
            }
        });
    }

    /// Sequential layout pass: per-net regions from lifetime pin
    /// capacities (O(m) stores, no allocation — the pooled arena is
    /// sized for the finest level and capacities only shrink upward).
    fn begin_level(&self, phg: &PartitionedHypergraph<H>) {
        let hg = phg.hypergraph();
        let m = hg.num_nets();
        let mut off = 0u64;
        for e in 0..m {
            self.offsets[e].store(off, Ordering::Relaxed);
            let c = hg.net_pin_capacity(e as EdgeId).min(self.k);
            self.entry_cap[e].store(c as u32, Ordering::Relaxed);
            off += net_slot_need(c) as u64;
        }
        self.offsets[m].store(off, Ordering::Relaxed);
        assert!(
            off as usize <= self.slots.len(),
            "sparse state arena too small for this level (pool fits() must gate binds)"
        );
    }

    #[inline]
    fn pin_count(&self, _phg: &PartitionedHypergraph<H>, e: EdgeId, b: BlockId) -> u32 {
        self.phi(e as usize, b)
    }

    #[inline]
    fn connectivity(&self, _phg: &PartitionedHypergraph<H>, e: EdgeId) -> u32 {
        self.lambda[e as usize].load(Ordering::Acquire)
    }

    #[inline]
    fn connectivity_iter<'a>(
        &'a self,
        _phg: &'a PartitionedHypergraph<H>,
        e: EdgeId,
    ) -> ConnIter<'a> {
        let (off, c, _x) = self.net_regions(e as usize);
        ConnIter::Sparse(SparseConnIter { slots: &self.slots[off..off + c], i: 0 })
    }

    fn apply_move<P: GainPolicy>(
        &self,
        phg: &PartitionedHypergraph<H>,
        u: NodeId,
        from: BlockId,
        to: BlockId,
        gain_table: Option<&GainTable>,
    ) -> Gain {
        let hg = phg.hypergraph();
        let mut gain: Gain = 0;
        for &e in hg.incident_nets(u) {
            let ei = e as usize;
            let we = hg.net_weight(e);
            self.net_locks.lock(ei);
            // dec before inc keeps λ(e) ≤ min(|e|, k) throughout, so the
            // entry region cannot overflow mid-transition
            let phi_from = self.remove_pin_serialized(ei, from);
            let phi_to = self.add_pin_serialized(ei, to);
            let lambda_after =
                if P::NEEDS_CONNECTIVITY { self.lambda[ei].load(Ordering::Relaxed) } else { 0 };
            self.net_locks.unlock(ei);
            gain += P::attributed_delta(we, phi_from, phi_to, lambda_after);
            if let Some(gt) = gain_table {
                gt.update_for_pin_change::<P, H>(phg, e, from, to, phi_from, phi_to);
            }
        }
        gain
    }

    fn gain<P: GainPolicy>(
        &self,
        phg: &PartitionedHypergraph<H>,
        u: NodeId,
        to: BlockId,
    ) -> Gain {
        let from = phg.block_of(u);
        if from == to {
            return 0;
        }
        let hg = phg.hypergraph();
        let mut g = 0;
        for &e in hg.incident_nets(u) {
            let w = hg.net_weight(e);
            let sz = if P::NEEDS_NET_SIZE { hg.net_size(e) as u32 } else { 0 };
            g += P::benefit_contrib(w, self.phi(e as usize, from), sz);
            g -= P::penalty_contrib(w, self.phi(e as usize, to), sz);
        }
        g
    }

    fn max_gain_move<P: GainPolicy>(
        &self,
        phg: &PartitionedHypergraph<H>,
        u: NodeId,
    ) -> Option<(Gain, BlockId)> {
        let from = phg.block_of(u);
        let hg = phg.hypergraph();
        let w = hg.node_weight(u);
        let mut benefit: Gain = 0;
        let mut candidates: Vec<BlockId> = Vec::new();
        for &e in hg.incident_nets(u) {
            let sz = if P::NEEDS_NET_SIZE { hg.net_size(e) as u32 } else { 0 };
            benefit += P::benefit_contrib(hg.net_weight(e), self.phi(e as usize, from), sz);
            for b in StateOps::<H>::connectivity_iter(self, phg, e) {
                if b != from && !candidates.contains(&b) {
                    candidates.push(b);
                }
            }
        }
        // Candidate *placement* in the entry prefixes depends on move
        // history, so unlike the dense bitset walk the enumeration order
        // here is not canonical — break ties by a total order (gain desc,
        // block weight asc, block id asc) to stay order-independent.
        let mut best: Option<(Gain, BlockId)> = None;
        for t in candidates {
            if phg.block_weight(t) + w > phg.max_block_weight(t) {
                continue;
            }
            let mut penalty: Gain = 0;
            for &e in hg.incident_nets(u) {
                let sz = if P::NEEDS_NET_SIZE { hg.net_size(e) as u32 } else { 0 };
                penalty += P::penalty_contrib(hg.net_weight(e), self.phi(e as usize, t), sz);
            }
            let g = benefit - penalty;
            match best {
                None => best = Some((g, t)),
                Some((bg, bb)) => {
                    let (wt, wb) = (phg.block_weight(t), phg.block_weight(bb));
                    if g > bg || (g == bg && (wt < wb || (wt == wb && t < bb))) {
                        best = Some((g, t));
                    }
                }
            }
        }
        best
    }

    #[inline]
    fn is_border(&self, phg: &PartitionedHypergraph<H>, u: NodeId) -> bool {
        phg.hypergraph()
            .incident_nets(u)
            .iter()
            .any(|&e| self.lambda[e as usize].load(Ordering::Acquire) > 1)
    }

    fn reset_net_uniform(&self, phg: &PartitionedHypergraph<H>, e: EdgeId, b: BlockId) {
        let ei = e as usize;
        self.clear_net_serialized(ei);
        let sz = phg.hypergraph().net_size(e) as u32;
        if sz > 0 {
            let (off, c, x) = self.net_regions(ei);
            debug_assert!(c >= 1);
            self.slots[off].store(pack_entry(b, sz), Ordering::Release);
            if x > 0 {
                self.index_insert(off, c, x, b, 0);
            }
            self.lambda[ei].store(1, Ordering::Release);
        }
    }

    fn reset_net_recount(&self, phg: &PartitionedHypergraph<H>, e: EdgeId) {
        let ei = e as usize;
        self.clear_net_serialized(ei);
        for &p in phg.hypergraph().pins(e) {
            self.add_pin_serialized(ei, phg.block_of_relaxed(p));
        }
    }

    fn verify(&self, phg: &PartitionedHypergraph<H>) -> Result<(), String> {
        let hg = phg.hypergraph();
        let parts = phg.parts();
        for e in hg.nets() {
            let ei = e as usize;
            let mut expect: Vec<(BlockId, u32)> = Vec::new();
            for &p in hg.pins(e) {
                let b = parts[p as usize];
                match expect.iter_mut().find(|(eb, _)| *eb == b) {
                    Some((_, c)) => *c += 1,
                    None => expect.push((b, 1)),
                }
            }
            let (off, c, _x) = self.net_regions(ei);
            let lam = self.lambda[ei].load(Ordering::Acquire) as usize;
            if lam != expect.len() {
                return Err(format!("λ({e}) = {lam}, expected {}", expect.len()));
            }
            let mut seen: Vec<BlockId> = Vec::new();
            for i in 0..lam {
                let w = self.slots[off + i].load(Ordering::Acquire);
                if w == 0 {
                    return Err(format!("net {e}: hole at live entry {i} (prefix not compact)"));
                }
                let b = (tag_of(w) - 1) as BlockId;
                if seen.contains(&b) {
                    return Err(format!("net {e}: duplicate entry for block {b}"));
                }
                seen.push(b);
                match expect.iter().find(|(eb, _)| *eb == b) {
                    Some((_, cnt)) if *cnt == count_of(w) => {}
                    Some((_, cnt)) => {
                        return Err(format!(
                            "Φ({e},{b}) = {}, expected {cnt}",
                            count_of(w)
                        ))
                    }
                    None => return Err(format!("net {e}: stale entry for block {b}")),
                }
                if self.phi(ei, b) != count_of(w) {
                    return Err(format!("net {e}: index lookup for block {b} diverges"));
                }
            }
            for i in lam..c {
                if self.slots[off + i].load(Ordering::Acquire) != 0 {
                    return Err(format!("net {e}: live word past λ at entry {i}"));
                }
            }
        }
        Ok(())
    }
}

/// Snapshot iterator over a net's live entry prefix — O(|Λ(e)|).
pub struct SparseConnIter<'a> {
    slots: &'a [AtomicU64],
    i: usize,
}

impl Iterator for SparseConnIter<'_> {
    type Item = BlockId;

    #[inline]
    fn next(&mut self) -> Option<BlockId> {
        while self.i < self.slots.len() {
            let w = self.slots[self.i].load(Ordering::Acquire);
            self.i += 1;
            if w == 0 {
                return None;
            }
            return Some((tag_of(w) - 1) as BlockId);
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use crate::hypergraph::Hypergraph;
    use crate::partition::objective::{CutNetPolicy, GainPolicy, Km1Policy, SoedPolicy};
    use crate::partition::state::KStateMode;
    use crate::partition::PartitionedHypergraph;
    use crate::{BlockId, NodeId};
    use std::sync::atomic::{AtomicBool, AtomicI64, Ordering};
    use std::sync::Arc;

    fn random_hypergraph(n: usize, m: usize, max_size: usize, seed: u64) -> Hypergraph {
        let mut rng = crate::util::Rng::new(seed);
        let mut nets = Vec::with_capacity(m);
        for _ in 0..m {
            let sz = 2 + rng.next_below((max_size - 1) as u64) as usize;
            let mut pins: Vec<NodeId> = Vec::with_capacity(sz);
            while pins.len() < sz {
                let p = rng.next_below(n as u64) as NodeId;
                if !pins.contains(&p) {
                    pins.push(p);
                }
            }
            nets.push(pins);
        }
        Hypergraph::from_nets(n, &nets, None, None)
    }

    fn twin_partitions(
        hg: &Arc<Hypergraph>,
        k: usize,
        parts: &[BlockId],
    ) -> (PartitionedHypergraph, PartitionedHypergraph) {
        let mut dense = PartitionedHypergraph::new_with_mode(hg.clone(), k, KStateMode::Dense);
        dense.set_uniform_max_weight(1.0);
        dense.assign_all(parts, 2);
        let mut sparse = PartitionedHypergraph::new_with_mode(hg.clone(), k, KStateMode::Sparse);
        sparse.set_uniform_max_weight(1.0);
        sparse.assign_all(parts, 2);
        (dense, sparse)
    }

    fn assert_state_parity(dense: &PartitionedHypergraph, sparse: &PartitionedHypergraph) {
        let hg = dense.hypergraph();
        let k = dense.k();
        assert_eq!(dense.km1(), sparse.km1());
        assert_eq!(dense.cut(), sparse.cut());
        assert_eq!(dense.soed(), sparse.soed());
        for e in hg.nets() {
            assert_eq!(
                dense.connectivity(e),
                sparse.connectivity(e),
                "λ({e}) diverges between states"
            );
            for b in 0..k as BlockId {
                assert_eq!(
                    dense.pin_count(e, b),
                    sparse.pin_count(e, b),
                    "Φ({e},{b}) diverges between states"
                );
            }
            let mut dl: Vec<BlockId> = dense.connectivity_set(e).collect();
            let mut sl: Vec<BlockId> = sparse.connectivity_set(e).collect();
            dl.sort_unstable();
            sl.sort_unstable();
            assert_eq!(dl, sl, "Λ({e}) diverges between states");
        }
        for u in hg.nodes() {
            assert_eq!(dense.is_border(u), sparse.is_border(u));
            for t in 0..k as BlockId {
                assert_eq!(dense.gain_p::<Km1Policy>(u, t), sparse.gain_p::<Km1Policy>(u, t));
                assert_eq!(
                    dense.gain_p::<CutNetPolicy>(u, t),
                    sparse.gain_p::<CutNetPolicy>(u, t)
                );
                assert_eq!(dense.gain_p::<SoedPolicy>(u, t), sparse.gain_p::<SoedPolicy>(u, t));
            }
        }
    }

    fn randomized_parity_for<P: GainPolicy>(k: usize, seed: u64) {
        let n = 60;
        let hg = Arc::new(random_hypergraph(n, 40, 10, seed));
        let parts: Vec<BlockId> = (0..n).map(|u| (u % k) as BlockId).collect();
        let (dense, sparse) = twin_partitions(&hg, k, &parts);
        dense.verify_consistency().unwrap();
        sparse.verify_consistency().unwrap();
        let mut rng = crate::util::Rng::new(seed ^ 0xABCD);
        for _ in 0..200 {
            let u = rng.next_below(n as u64) as NodeId;
            let to = rng.next_below(k as u64) as BlockId;
            if to == dense.block_of(u) {
                continue;
            }
            let gd = dense.try_move_p::<P>(u, to, None);
            let gs = sparse.try_move_p::<P>(u, to, None);
            match (gd, gs) {
                (Some(d), Some(s)) => {
                    assert_eq!(d.attributed_gain, s.attributed_gain, "attributed gain diverges")
                }
                (None, None) => {}
                _ => panic!("balance outcome diverges between states"),
            }
        }
        dense.verify_consistency().unwrap();
        sparse.verify_consistency().unwrap();
        assert_state_parity(&dense, &sparse);
    }

    #[test]
    fn randomized_moves_keep_dense_and_sparse_identical_km1() {
        randomized_parity_for::<Km1Policy>(5, 11);
        randomized_parity_for::<Km1Policy>(17, 12);
    }

    #[test]
    fn randomized_moves_keep_dense_and_sparse_identical_cut() {
        randomized_parity_for::<CutNetPolicy>(5, 21);
        randomized_parity_for::<CutNetPolicy>(17, 22);
    }

    #[test]
    fn randomized_moves_keep_dense_and_sparse_identical_soed() {
        randomized_parity_for::<SoedPolicy>(5, 31);
        randomized_parity_for::<SoedPolicy>(17, 32);
    }

    #[test]
    fn large_k_exercises_the_index_region() {
        // one huge net over 200 nodes spread across 128 blocks: entry
        // capacity min(200, 128) = 128 > LINEAR_CUTOFF forces the
        // open-addressed index path for every lookup
        let n = 200usize;
        let k = 128usize;
        let mut nets: Vec<Vec<NodeId>> = vec![(0..n as NodeId).collect()];
        for u in 0..(n as NodeId) - 1 {
            nets.push(vec![u, u + 1]);
        }
        let hg = Arc::new(Hypergraph::from_nets(n, &nets, None, None));
        let parts: Vec<BlockId> = (0..n).map(|u| (u % k) as BlockId).collect();
        let (dense, sparse) = twin_partitions(&hg, k, &parts);
        assert_state_parity(&dense, &sparse);
        let mut rng = crate::util::Rng::new(99);
        for _ in 0..500 {
            let u = rng.next_below(n as u64) as NodeId;
            let to = rng.next_below(k as u64) as BlockId;
            if to == dense.block_of(u) {
                continue;
            }
            let gd = dense.try_move_p::<Km1Policy>(u, to, None);
            let gs = sparse.try_move_p::<Km1Policy>(u, to, None);
            assert_eq!(gd.map(|o| o.attributed_gain), gs.map(|o| o.attributed_gain));
        }
        sparse.verify_consistency().unwrap();
        assert_state_parity(&dense, &sparse);
    }

    #[test]
    fn concurrent_moves_once_per_node_sum_exactly_on_sparse() {
        for trial in 0..6u64 {
            let n = 48usize;
            let k = 6usize;
            let hg = Arc::new(random_hypergraph(n, 30, 8, 1000 + trial));
            let parts: Vec<BlockId> = (0..n).map(|u| (u % k) as BlockId).collect();
            let mut phg =
                PartitionedHypergraph::new_with_mode(hg.clone(), k, KStateMode::Sparse);
            phg.set_uniform_max_weight(1.0);
            phg.assign_all(&parts, 2);
            let before = phg.km1();
            let total = AtomicI64::new(0);
            let claimed: Vec<AtomicBool> = (0..n).map(|_| AtomicBool::new(false)).collect();
            std::thread::scope(|s| {
                for t in 0..4u64 {
                    let phg = &phg;
                    let total = &total;
                    let claimed = &claimed;
                    s.spawn(move || {
                        let mut rng = crate::util::Rng::new(trial * 37 + t);
                        for _ in 0..24 {
                            let u = rng.next_below(n as u64) as NodeId;
                            if claimed[u as usize].swap(true, Ordering::AcqRel) {
                                continue;
                            }
                            let to = rng.next_below(k as u64) as BlockId;
                            if to == phg.block_of(u) {
                                continue;
                            }
                            if let Some(out) = phg.try_move(u, to, None) {
                                total.fetch_add(out.attributed_gain, Ordering::Relaxed);
                            }
                        }
                    });
                }
            });
            phg.verify_consistency().unwrap();
            assert_eq!(
                before - total.load(Ordering::Relaxed),
                phg.km1(),
                "attributed gains sum exactly (trial {trial})"
            );
        }
    }

    #[test]
    fn sparse_max_gain_move_reports_exact_gains() {
        let n = 40usize;
        let k = 8usize;
        let hg = Arc::new(random_hypergraph(n, 25, 6, 77));
        let parts: Vec<BlockId> = (0..n).map(|u| (u % k) as BlockId).collect();
        let mut phg = PartitionedHypergraph::new_with_mode(hg, k, KStateMode::Sparse);
        phg.set_uniform_max_weight(1.0);
        phg.assign_all(&parts, 2);
        for u in 0..n as NodeId {
            if let Some((g, t)) = phg.max_gain_move(u) {
                assert_eq!(g, phg.gain(u, t), "reported gain is the exact gain");
                assert_ne!(t, phg.block_of(u));
            }
        }
    }
}
