//! The partition-state abstraction: what a [`PartitionedHypergraph`]
//! stores *besides* Π and the block weights (paper §6.1 vs §10).
//!
//! The generic hypergraph state [`PhiLambdaState`] is the paper's packed
//! pin-count array Φ(e, V_i) under per-net spin locks plus connectivity
//! bitsets Λ(e). The plain-graph state [`TwoPinState`] exploits that every
//! net of a [`Graph`] has exactly two pins: Φ(e, ·) and Λ(e) ∈ {1, 2} are
//! *derived* from the two endpoint blocks, so the graph path allocates no
//! pin-count array, no bitsets and no per-net locks — one packed
//! `AtomicU64` per undirected edge replaces all three (§10's "single
//! adjacency array + on-the-fly gains" optimization).
//!
//! ## Exact attributed gains on the two-pin state
//!
//! [`TwoPinState`] keeps, per undirected edge e = (x, y) with x < y, one
//! word holding `Π(x) << 32 | Π(y)`. A mover at endpoint u CAS-updates its
//! *own* half to the target block; the word returned by the atomic
//! read-modify-write carries the other endpoint's block **at the
//! linearization point**, from which the post-move pin counts
//! Φ(e, from) ∈ {0, 1}, Φ(e, to) ∈ {1, 2} and λ(e) ∈ {1, 2} are
//! synthesized and fed to the same [`GainPolicy::attributed_delta`] the
//! hypergraph move loop uses. Per word the transitions telescope, so
//! summed attributed gains are exact under any interleaving — the graph
//! analogue of Lemma 6.1, with no locks and no per-round resets.

use super::connectivity::{ConnSetIter, ConnectivitySets};
use super::gain_table::GainTable;
use super::objective::GainPolicy;
use super::pin_counts::PinCountArray;
use super::sparse_state::{net_slot_need, SparseConnIter, SparseKState};
use super::PartitionedHypergraph;
use crate::datastructures::SpinLockVec;
use crate::graph::Graph;
use crate::hypergraph::HypergraphOps;
use crate::metrics::Objective;
use crate::parallel::par_for_auto;
use crate::{BlockId, EdgeId, Gain, NodeId};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

// ===================================================================
// State-mode selection (dense §6.1 layout vs the large-k sparse layout)
// ===================================================================

/// Which Φ/Λ + gain-cache representation a hypergraph run uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KStateMode {
    /// Packed `m·k` pin counts + `m·⌈k/64⌉` connectivity bitsets + the
    /// dense `n·k` gain table (paper §6.1/§6.2) — the right trade while
    /// a row of blocks is about a cache line.
    Dense,
    /// Per-net (block → count) mini-tables sized by `min(|e|, k)` and a
    /// two-level per-node gain cache over Λ(I(u)) — memory and
    /// initialization independent of k.
    Sparse,
}

/// User-facing selection knob (`--kstate`, `Context::kstate`): `Auto`
/// picks [`KStateMode::Sparse`] above [`SPARSE_K_THRESHOLD`] blocks.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum KStateChoice {
    #[default]
    Auto,
    Dense,
    Sparse,
}

/// Above this k, `Auto` switches to the sparse state: beyond a cache
/// line of blocks per row, the dense layout's `O(m·k)` packed entries
/// and `O(n·k)` gain-table initialization start to dominate the run.
pub const SPARSE_K_THRESHOLD: usize = 64;

/// Process-wide override, read once: `MTKH_KSTATE=dense|sparse` forces
/// the mode for every run (the CI large-k lane uses this to push the
/// whole integration suite through the sparse path).
fn env_kstate() -> Option<KStateMode> {
    static FORCED: OnceLock<Option<KStateMode>> = OnceLock::new();
    *FORCED.get_or_init(|| match std::env::var("MTKH_KSTATE").ok().as_deref() {
        Some("dense") => Some(KStateMode::Dense),
        Some("sparse") => Some(KStateMode::Sparse),
        _ => None,
    })
}

/// Resolve the effective state mode for a run with `k` blocks: the
/// `MTKH_KSTATE` environment override wins, then an explicit choice,
/// then `Auto` selects by k.
pub fn resolve_kstate(choice: KStateChoice, k: usize) -> KStateMode {
    if let Some(forced) = env_kstate() {
        return forced;
    }
    match choice {
        KStateChoice::Dense => KStateMode::Dense,
        KStateChoice::Sparse => KStateMode::Sparse,
        KStateChoice::Auto => {
            if k > SPARSE_K_THRESHOLD {
                KStateMode::Sparse
            } else {
                KStateMode::Dense
            }
        }
    }
}

/// The allocation-relevant dimensions of a partitioned structure — what
/// [`PartitionState::alloc`] sizes against and [`PartitionState::fits`]
/// checks a pooled buffer against.
#[derive(Clone, Copy, Debug)]
pub struct StateDims {
    pub num_nodes: usize,
    pub num_nets: usize,
    /// Largest Φ value any net can reach (≥ 1).
    pub max_net_size: usize,
    /// Sparse mini-table arena words, `Σ_e slot_need(min(cap(e), k))`;
    /// 0 under a dense mode (not computed — dense sizing ignores it).
    pub pin_budget: usize,
    pub k: usize,
    pub mode: KStateMode,
}

impl StateDims {
    /// Measure `hg` for `k` blocks under `mode`. The sparse pin budget
    /// derives from [`HypergraphOps::net_pin_capacity`] (lifetime slot
    /// capacities), so a layout computed from these dims survives
    /// n-level pin-list growth between value rebuilds.
    pub fn for_hg<H: HypergraphOps>(hg: &H, k: usize, mode: KStateMode) -> Self {
        let pin_budget = match mode {
            KStateMode::Dense => 0,
            KStateMode::Sparse => (0..hg.num_nets())
                .map(|e| net_slot_need(hg.net_pin_capacity(e as EdgeId).min(k)))
                .sum(),
        };
        StateDims {
            num_nodes: hg.num_nodes(),
            num_nets: hg.num_nets(),
            max_net_size: hg.max_net_size().max(1),
            pin_budget,
            k,
            mode,
        }
    }
}

/// Structural storage of a partition, independent of the bound
/// (hyper)graph: how it is allocated, whether pooled buffers fit a level,
/// and whether the §6.2 gain table applies.
///
/// The [`pool::PartitionPool`](super::pool::PartitionPool) and
/// [`Workspace`](crate::refinement::pipeline::Workspace) are generic over
/// this trait so one pooled allocation drives the whole uncoarsening
/// hierarchy for either representation.
pub trait PartitionState: Send + Sync + Sized {
    /// Does the two-level gain table (§6.2) apply to this state? The
    /// two-pin state computes a node's best move in O(deg) from the
    /// adjacency array, so the table would only add maintenance cost —
    /// the FM drivers skip building it when this is `false`.
    const USE_GAIN_TABLE: bool;

    /// Allocate state sized for `dims`.
    fn alloc(dims: &StateDims) -> Self;

    /// Can this (possibly pooled, larger) allocation serve a structure
    /// with the given dims?
    fn fits(&self, dims: &StateDims) -> bool;

    /// The mode this allocation answers to — lets callers rebuild
    /// matching [`StateDims`] for a buffer of unknown provenance.
    fn mode(&self) -> KStateMode {
        KStateMode::Dense
    }
}

/// The per-representation operations a [`PartitionedHypergraph`] delegates
/// to its state: value rebuilds, Φ/Λ queries, the synchronized move with
/// attributed gain, and the gain kernels.
///
/// Methods receive the owning partition (`phg`) because every state
/// derives its answers from Π and the bound structure; `phg.state` is
/// `self` (same allocation), the double reference is just the shape
/// delegation takes.
pub trait StateOps<H: HypergraphOps>: PartitionState {
    /// Recompute the state's values from Π for the `num_nets` prefix
    /// (memory reused, the pooled per-level repair).
    fn rebuild(&self, phg: &PartitionedHypergraph<H>, threads: usize);

    /// Φ(e, b).
    fn pin_count(&self, phg: &PartitionedHypergraph<H>, e: EdgeId, b: BlockId) -> u32;

    /// λ(e).
    fn connectivity(&self, phg: &PartitionedHypergraph<H>, e: EdgeId) -> u32;

    /// Iterate Λ(e).
    fn connectivity_iter<'a>(
        &'a self,
        phg: &'a PartitionedHypergraph<H>,
        e: EdgeId,
    ) -> ConnIter<'a>;

    /// Apply the state updates of moving `u` from `from` to `to` and
    /// return the attributed gain. Π and the block weights have already
    /// been updated by the caller ([`PartitionedHypergraph`] keeps the
    /// balance reservation protocol); this performs the per-net Φ/Λ
    /// transitions of Algorithm 6.1 (or the two-pin equivalent) and the
    /// gain-table update rules when a table is supplied.
    fn apply_move<P: GainPolicy>(
        &self,
        phg: &PartitionedHypergraph<H>,
        u: NodeId,
        from: BlockId,
        to: BlockId,
        gain_table: Option<&GainTable>,
    ) -> Gain;

    /// Exact gain of moving `u` to `to` under policy `P`.
    fn gain<P: GainPolicy>(&self, phg: &PartitionedHypergraph<H>, u: NodeId, to: BlockId)
        -> Gain;

    /// Best feasible move for `u` under policy `P` (ties broken toward
    /// the lighter block, candidates in first-encounter order).
    fn max_gain_move<P: GainPolicy>(
        &self,
        phg: &PartitionedHypergraph<H>,
        u: NodeId,
    ) -> Option<(Gain, BlockId)>;

    /// Is `u` incident to a cut net?
    fn is_border(&self, phg: &PartitionedHypergraph<H>, u: NodeId) -> bool;

    /// Prepare per-level internal layout for the currently bound
    /// hypergraph *without touching values* — a no-op for fixed-stride
    /// states; the sparse state recomputes its per-net arena regions
    /// here. `rebuild` implies it; callers that skip `rebuild` (the
    /// cross-level delta repair) must invoke it before any
    /// `reset_net_*` call.
    fn begin_level(&self, _phg: &PartitionedHypergraph<H>) {}

    /// Exclusive-phase repair: overwrite net `e`'s values as if all its
    /// pins sat in block `b` — the dropped-net fast path of the
    /// cross-level delta repair (`e` must be uniform under Π).
    fn reset_net_uniform(&self, phg: &PartitionedHypergraph<H>, e: EdgeId, b: BlockId);

    /// Exclusive-phase repair: overwrite net `e`'s values by recounting
    /// its pins from Π.
    fn reset_net_recount(&self, phg: &PartitionedHypergraph<H>, e: EdgeId);

    /// Check the state against a from-scratch recomputation from Π.
    fn verify(&self, phg: &PartitionedHypergraph<H>) -> Result<(), String>;
}

/// Iterator over a connectivity set Λ(e) — dense bitset walk for the
/// §6.1 hypergraph state, a compact entry-prefix scan for the sparse
/// state, at most two derived blocks for the two-pin state.
pub enum ConnIter<'a> {
    Dense(ConnSetIter<'a>),
    Sparse(SparseConnIter<'a>),
    TwoPin { first: Option<BlockId>, second: Option<BlockId> },
}

impl Iterator for ConnIter<'_> {
    type Item = BlockId;

    #[inline]
    fn next(&mut self) -> Option<BlockId> {
        match self {
            ConnIter::Dense(it) => it.next().map(|b| b as BlockId),
            ConnIter::Sparse(it) => it.next(),
            ConnIter::TwoPin { first, second } => first.take().or_else(|| second.take()),
        }
    }
}

// ===================================================================
// PhiLambdaState — the paper's §6.1 hypergraph machinery
// ===================================================================

/// Packed pin counts Φ under per-net spin locks + connectivity bitsets Λ:
/// the general hypergraph partition state (paper §6.1).
pub struct PhiLambdaState {
    pub(crate) pin_counts: PinCountArray,
    pub(crate) conn: ConnectivitySets,
    pub(crate) net_locks: SpinLockVec,
}

impl PartitionState for PhiLambdaState {
    const USE_GAIN_TABLE: bool = true;

    fn alloc(dims: &StateDims) -> Self {
        PhiLambdaState {
            pin_counts: PinCountArray::new(dims.num_nets, dims.k, dims.max_net_size.max(1)),
            conn: ConnectivitySets::new(dims.num_nets, dims.k),
            net_locks: SpinLockVec::new(dims.num_nets),
        }
    }

    fn fits(&self, dims: &StateDims) -> bool {
        self.pin_counts.blocks() == dims.k
            && self.conn.blocks() == dims.k
            && self.pin_counts.nets_capacity() >= dims.num_nets
            && self.pin_counts.can_represent(dims.max_net_size)
            && self.conn.nets_capacity() >= dims.num_nets
            && self.net_locks.len() >= dims.num_nets
    }
}

impl<H: HypergraphOps> StateOps<H> for PhiLambdaState {
    fn rebuild(&self, phg: &PartitionedHypergraph<H>, threads: usize) {
        let m = phg.hypergraph().num_nets();
        self.pin_counts.clear_nets(m);
        self.conn.clear_nets(m);
        // lock-free: every net owns disjoint words of the packed array
        par_for_auto(m, threads, |e| {
            for &p in phg.hypergraph().pins(e as EdgeId) {
                let b = phg.block_of_relaxed(p) as usize;
                if self.pin_counts.inc(e, b) == 1 {
                    self.conn.flip(e, b);
                }
            }
        });
    }

    #[inline]
    fn pin_count(&self, _phg: &PartitionedHypergraph<H>, e: EdgeId, b: BlockId) -> u32 {
        self.pin_counts.get(e as usize, b as usize)
    }

    #[inline]
    fn connectivity(&self, _phg: &PartitionedHypergraph<H>, e: EdgeId) -> u32 {
        self.conn.connectivity(e as usize)
    }

    #[inline]
    fn connectivity_iter<'a>(
        &'a self,
        _phg: &'a PartitionedHypergraph<H>,
        e: EdgeId,
    ) -> ConnIter<'a> {
        ConnIter::Dense(self.conn.iter(e as usize))
    }

    fn apply_move<P: GainPolicy>(
        &self,
        phg: &PartitionedHypergraph<H>,
        u: NodeId,
        from: BlockId,
        to: BlockId,
        gain_table: Option<&GainTable>,
    ) -> Gain {
        let hg = phg.hypergraph();
        let mut gain: Gain = 0;
        for &e in hg.incident_nets(u) {
            let ei = e as usize;
            let we = hg.net_weight(e);
            self.net_locks.lock(ei);
            let phi_from = self.pin_counts.dec(ei, from as usize);
            if phi_from == 0 {
                self.conn.flip(ei, from as usize);
            }
            let phi_to = self.pin_counts.inc(ei, to as usize);
            if phi_to == 1 {
                self.conn.flip(ei, to as usize);
            }
            // cut-style objectives attribute gains to λ 1↔2 transitions:
            // λ after the move must be read under the same lock that
            // serialized the pin-count update (compiled out for km1)
            let lambda_after =
                if P::NEEDS_CONNECTIVITY { self.conn.connectivity(ei) } else { 0 };
            self.net_locks.unlock(ei);
            // attributed gain (paper: decrease attributed to the move that
            // zeroes Φ(e, V_s); increase to the one that makes Φ(e, V_t)=1
            // — generalized per objective by the policy)
            gain += P::attributed_delta(we, phi_from, phi_to, lambda_after);
            if let Some(gt) = gain_table {
                gt.update_for_pin_change::<P, H>(phg, e, from, to, phi_from, phi_to);
            }
        }
        gain
    }

    fn gain<P: GainPolicy>(
        &self,
        phg: &PartitionedHypergraph<H>,
        u: NodeId,
        to: BlockId,
    ) -> Gain {
        let from = phg.block_of(u);
        if from == to {
            return 0;
        }
        let hg = phg.hypergraph();
        let mut g = 0;
        for &e in hg.incident_nets(u) {
            let w = hg.net_weight(e);
            let sz = if P::NEEDS_NET_SIZE { hg.net_size(e) as u32 } else { 0 };
            g += P::benefit_contrib(w, self.pin_counts.get(e as usize, from as usize), sz);
            g -= P::penalty_contrib(w, self.pin_counts.get(e as usize, to as usize), sz);
        }
        g
    }

    fn max_gain_move<P: GainPolicy>(
        &self,
        phg: &PartitionedHypergraph<H>,
        u: NodeId,
    ) -> Option<(Gain, BlockId)> {
        let from = phg.block_of(u);
        let hg = phg.hypergraph();
        let w = hg.node_weight(u);
        let mut benefit: Gain = 0;
        let mut candidates: Vec<BlockId> = Vec::new();
        for &e in hg.incident_nets(u) {
            let sz = if P::NEEDS_NET_SIZE { hg.net_size(e) as u32 } else { 0 };
            benefit += P::benefit_contrib(
                hg.net_weight(e),
                self.pin_counts.get(e as usize, from as usize),
                sz,
            );
            for b in self.conn.iter(e as usize) {
                let b = b as BlockId;
                if b != from && !candidates.contains(&b) {
                    candidates.push(b);
                }
            }
        }
        let mut best: Option<(Gain, BlockId)> = None;
        for t in candidates {
            if phg.block_weight(t) + w > phg.max_block_weight(t) {
                continue;
            }
            let mut penalty: Gain = 0;
            for &e in hg.incident_nets(u) {
                let sz = if P::NEEDS_NET_SIZE { hg.net_size(e) as u32 } else { 0 };
                penalty += P::penalty_contrib(
                    hg.net_weight(e),
                    self.pin_counts.get(e as usize, t as usize),
                    sz,
                );
            }
            let g = benefit - penalty;
            match best {
                None => best = Some((g, t)),
                Some((bg, bb)) => {
                    if g > bg || (g == bg && phg.block_weight(t) < phg.block_weight(bb)) {
                        best = Some((g, t));
                    }
                }
            }
        }
        best
    }

    #[inline]
    fn is_border(&self, phg: &PartitionedHypergraph<H>, u: NodeId) -> bool {
        phg.hypergraph()
            .incident_nets(u)
            .iter()
            .any(|&e| self.conn.connectivity(e as usize) > 1)
    }

    fn reset_net_uniform(&self, phg: &PartitionedHypergraph<H>, e: EdgeId, b: BlockId) {
        let ei = e as usize;
        self.pin_counts.clear_net(ei);
        self.conn.clear_net(ei);
        let sz = phg.hypergraph().net_size(e) as u32;
        if sz > 0 {
            self.pin_counts.set(ei, b as usize, sz);
            self.conn.flip(ei, b as usize);
        }
    }

    fn reset_net_recount(&self, phg: &PartitionedHypergraph<H>, e: EdgeId) {
        let ei = e as usize;
        self.pin_counts.clear_net(ei);
        self.conn.clear_net(ei);
        for &p in phg.hypergraph().pins(e) {
            let b = phg.block_of_relaxed(p) as usize;
            if self.pin_counts.inc(ei, b) == 1 {
                self.conn.flip(ei, b);
            }
        }
    }

    fn verify(&self, phg: &PartitionedHypergraph<H>) -> Result<(), String> {
        let hg = phg.hypergraph();
        let parts = phg.parts();
        let k = phg.k();
        for e in hg.nets() {
            let mut phi = vec![0u32; k];
            for &p in hg.pins(e) {
                phi[parts[p as usize] as usize] += 1;
            }
            for (b, &cnt) in phi.iter().enumerate() {
                if self.pin_counts.get(e as usize, b) != cnt {
                    return Err(format!("Φ({e},{b}) mismatch"));
                }
                let in_lambda = self.conn.contains(e as usize, b);
                if in_lambda != (cnt > 0) {
                    return Err(format!("Λ({e}) bit {b} mismatch"));
                }
            }
        }
        Ok(())
    }
}

// ===================================================================
// TwoPinState — the §10 plain-graph specialization
// ===================================================================

/// Partition state of a plain graph: one packed endpoint-block word per
/// undirected edge, nothing else. Φ(e, ·), Λ(e) ∈ {1, 2}, border status
/// and all gains are derived from endpoint blocks (see the module docs).
pub struct TwoPinState {
    /// `Π(x) << 32 | Π(y)` per undirected edge e = (x, y), x < y.
    words: Vec<AtomicU64>,
}

impl TwoPinState {
    /// The policy-collapse factor on graphs: km1 and cut-net per-edge
    /// gains are algebraically identical on two-pin nets, and soed is
    /// exactly twice that (each cut edge contributes λ−1 = 1 to km1 and
    /// ω(e) to cut). One scaled kernel serves the whole portfolio.
    #[inline]
    fn scale<P: GainPolicy>() -> Gain {
        if matches!(P::OBJECTIVE, Objective::Soed) {
            2
        } else {
            1
        }
    }

    #[inline]
    fn endpoints(word: u64) -> (BlockId, BlockId) {
        ((word >> 32) as BlockId, word as BlockId)
    }
}

impl PartitionState for TwoPinState {
    const USE_GAIN_TABLE: bool = false;

    fn alloc(dims: &StateDims) -> Self {
        TwoPinState { words: (0..dims.num_nets).map(|_| AtomicU64::new(0)).collect() }
    }

    fn fits(&self, dims: &StateDims) -> bool {
        self.words.len() >= dims.num_nets
    }
}

impl StateOps<Graph> for TwoPinState {
    fn rebuild(&self, phg: &PartitionedHypergraph<Graph>, threads: usize) {
        let m = phg.hypergraph().num_nets();
        par_for_auto(m, threads, |e| {
            let ps = phg.hypergraph().pins(e as EdgeId);
            let bx = phg.block_of_relaxed(ps[0]) as u64;
            let by = phg.block_of_relaxed(ps[1]) as u64;
            self.words[e].store((bx << 32) | by, Ordering::Relaxed);
        });
    }

    #[inline]
    fn pin_count(&self, _phg: &PartitionedHypergraph<Graph>, e: EdgeId, b: BlockId) -> u32 {
        let (bx, by) = Self::endpoints(self.words[e as usize].load(Ordering::Acquire));
        u32::from(bx == b) + u32::from(by == b)
    }

    #[inline]
    fn connectivity(&self, _phg: &PartitionedHypergraph<Graph>, e: EdgeId) -> u32 {
        let (bx, by) = Self::endpoints(self.words[e as usize].load(Ordering::Acquire));
        if bx == by {
            1
        } else {
            2
        }
    }

    #[inline]
    fn connectivity_iter<'a>(
        &'a self,
        _phg: &'a PartitionedHypergraph<Graph>,
        e: EdgeId,
    ) -> ConnIter<'a> {
        let (bx, by) = Self::endpoints(self.words[e as usize].load(Ordering::Acquire));
        ConnIter::TwoPin { first: Some(bx), second: if by != bx { Some(by) } else { None } }
    }

    fn apply_move<P: GainPolicy>(
        &self,
        phg: &PartitionedHypergraph<Graph>,
        u: NodeId,
        from: BlockId,
        to: BlockId,
        gain_table: Option<&GainTable>,
    ) -> Gain {
        debug_assert!(gain_table.is_none(), "no gain table on the two-pin state");
        let g = phg.hypergraph();
        let lo = g.offsets[u as usize] as usize;
        let hi = g.offsets[u as usize + 1] as usize;
        let mut gain: Gain = 0;
        for slot in lo..hi {
            let v = g.targets[slot];
            let e = g.uedge[slot] as usize;
            let w = g.edge_weight[slot];
            // own half: high 32 bits iff u is the smaller (canonical x)
            let shift = if u < v { 32 } else { 0 };
            let mask = 0xffff_ffffu64 << shift;
            let prev = self.words[e]
                .fetch_update(Ordering::AcqRel, Ordering::Acquire, |cur| {
                    Some((cur & !mask) | ((to as u64) << shift))
                })
                .unwrap();
            debug_assert_eq!((prev >> shift) as u32, from, "moves of one node are serialized");
            // the other endpoint's block at the linearization point of
            // this edge's transition — synthesize the post-move Φ/λ
            let other = (prev >> (32 - shift)) as BlockId;
            let phi_from_after = u32::from(other == from);
            let phi_to_after = 1 + u32::from(other == to);
            let lambda_after = if other == to { 1 } else { 2 };
            gain += P::attributed_delta(w, phi_from_after, phi_to_after, lambda_after);
        }
        gain
    }

    fn gain<P: GainPolicy>(
        &self,
        phg: &PartitionedHypergraph<Graph>,
        u: NodeId,
        to: BlockId,
    ) -> Gain {
        let from = phg.block_of(u);
        if from == to {
            return 0;
        }
        let g = phg.hypergraph();
        let mut w_from: Gain = 0;
        let mut w_to: Gain = 0;
        for (v, w) in g.neighbors(u) {
            let b = phg.block_of(v);
            if b == from {
                w_from += w;
            } else if b == to {
                w_to += w;
            }
        }
        Self::scale::<P>() * (w_to - w_from)
    }

    fn max_gain_move<P: GainPolicy>(
        &self,
        phg: &PartitionedHypergraph<Graph>,
        u: NodeId,
    ) -> Option<(Gain, BlockId)> {
        let from = phg.block_of(u);
        let g = phg.hypergraph();
        let wu = g.node_weight(u);
        // single adjacency pass: weight toward the own block plus the
        // aggregated weight toward each adjacent foreign block
        let mut w_from: Gain = 0;
        let mut cand: Vec<(BlockId, Gain)> = Vec::new();
        for (v, w) in g.neighbors(u) {
            let b = phg.block_of(v);
            if b == from {
                w_from += w;
                continue;
            }
            match cand.iter_mut().find(|(cb, _)| *cb == b) {
                Some((_, acc)) => *acc += w,
                None => cand.push((b, w)),
            }
        }
        let scale = Self::scale::<P>();
        let mut best: Option<(Gain, BlockId)> = None;
        for (t, wt) in cand {
            if phg.block_weight(t) + wu > phg.max_block_weight(t) {
                continue;
            }
            let gn = scale * (wt - w_from);
            match best {
                None => best = Some((gn, t)),
                Some((bg, bb)) => {
                    if gn > bg || (gn == bg && phg.block_weight(t) < phg.block_weight(bb)) {
                        best = Some((gn, t));
                    }
                }
            }
        }
        best
    }

    #[inline]
    fn is_border(&self, phg: &PartitionedHypergraph<Graph>, u: NodeId) -> bool {
        let from = phg.block_of(u);
        phg.hypergraph().neighbors(u).any(|(v, _)| phg.block_of(v) != from)
    }

    fn reset_net_uniform(&self, _phg: &PartitionedHypergraph<Graph>, e: EdgeId, b: BlockId) {
        let w = ((b as u64) << 32) | b as u64;
        self.words[e as usize].store(w, Ordering::Relaxed);
    }

    fn reset_net_recount(&self, phg: &PartitionedHypergraph<Graph>, e: EdgeId) {
        let ps = phg.hypergraph().pins(e);
        let bx = phg.block_of_relaxed(ps[0]) as u64;
        let by = phg.block_of_relaxed(ps[1]) as u64;
        self.words[e as usize].store((bx << 32) | by, Ordering::Relaxed);
    }

    fn verify(&self, phg: &PartitionedHypergraph<Graph>) -> Result<(), String> {
        let g = phg.hypergraph();
        let parts = phg.parts();
        for e in 0..g.num_nets() {
            let ps = g.pins(e as EdgeId);
            let (bx, by) = Self::endpoints(self.words[e].load(Ordering::Acquire));
            if bx != parts[ps[0] as usize] || by != parts[ps[1] as usize] {
                return Err(format!(
                    "edge {e} word ({bx},{by}) vs Π ({},{})",
                    parts[ps[0] as usize], parts[ps[1] as usize]
                ));
            }
        }
        Ok(())
    }
}

// ===================================================================
// HgState — the k-selected hypergraph state (dense or sparse)
// ===================================================================

/// The hypergraph partition state, selected per run from k and the
/// `--kstate` / `MTKH_KSTATE` knobs: the dense §6.1 [`PhiLambdaState`]
/// while `k·m` words are cheap, the [`SparseKState`] mini-table layout
/// above [`SPARSE_K_THRESHOLD`]. Both variants implement every
/// [`StateOps`] method with identical Φ/Λ/gain semantics, so refinement
/// code never branches on the representation.
pub enum HgState {
    Dense(PhiLambdaState),
    Sparse(SparseKState),
}

macro_rules! hg_delegate {
    ($self:ident, $s:ident => $body:expr) => {
        match $self {
            HgState::Dense($s) => $body,
            HgState::Sparse($s) => $body,
        }
    };
}

impl HgState {
    /// n-level uncontraction repair: net `e` regained a pin whose block
    /// `b` is already in Λ(e) — a locked count-only increment (Λ never
    /// changes). Returns Φ(e, b) after.
    pub(crate) fn uncontract_inc(&self, e: usize, b: BlockId) -> u32 {
        match self {
            HgState::Dense(s) => {
                s.net_locks.lock(e);
                let phi = s.pin_counts.inc(e, b as usize);
                s.net_locks.unlock(e);
                phi
            }
            HgState::Sparse(s) => s.uncontract_inc(e, b),
        }
    }
}

impl PartitionState for HgState {
    const USE_GAIN_TABLE: bool = true;

    fn alloc(dims: &StateDims) -> Self {
        match dims.mode {
            KStateMode::Dense => HgState::Dense(PhiLambdaState::alloc(dims)),
            KStateMode::Sparse => HgState::Sparse(SparseKState::alloc(dims)),
        }
    }

    fn fits(&self, dims: &StateDims) -> bool {
        match (self, dims.mode) {
            (HgState::Dense(s), KStateMode::Dense) => s.fits(dims),
            (HgState::Sparse(s), KStateMode::Sparse) => s.fits(dims),
            _ => false,
        }
    }

    fn mode(&self) -> KStateMode {
        match self {
            HgState::Dense(_) => KStateMode::Dense,
            HgState::Sparse(_) => KStateMode::Sparse,
        }
    }
}

impl<H: HypergraphOps> StateOps<H> for HgState {
    fn rebuild(&self, phg: &PartitionedHypergraph<H>, threads: usize) {
        hg_delegate!(self, s => StateOps::<H>::rebuild(s, phg, threads))
    }

    #[inline]
    fn pin_count(&self, phg: &PartitionedHypergraph<H>, e: EdgeId, b: BlockId) -> u32 {
        hg_delegate!(self, s => StateOps::<H>::pin_count(s, phg, e, b))
    }

    #[inline]
    fn connectivity(&self, phg: &PartitionedHypergraph<H>, e: EdgeId) -> u32 {
        hg_delegate!(self, s => StateOps::<H>::connectivity(s, phg, e))
    }

    #[inline]
    fn connectivity_iter<'a>(
        &'a self,
        phg: &'a PartitionedHypergraph<H>,
        e: EdgeId,
    ) -> ConnIter<'a> {
        hg_delegate!(self, s => StateOps::<H>::connectivity_iter(s, phg, e))
    }

    fn apply_move<P: GainPolicy>(
        &self,
        phg: &PartitionedHypergraph<H>,
        u: NodeId,
        from: BlockId,
        to: BlockId,
        gain_table: Option<&GainTable>,
    ) -> Gain {
        hg_delegate!(self, s => s.apply_move::<P>(phg, u, from, to, gain_table))
    }

    fn gain<P: GainPolicy>(
        &self,
        phg: &PartitionedHypergraph<H>,
        u: NodeId,
        to: BlockId,
    ) -> Gain {
        hg_delegate!(self, s => s.gain::<P>(phg, u, to))
    }

    fn max_gain_move<P: GainPolicy>(
        &self,
        phg: &PartitionedHypergraph<H>,
        u: NodeId,
    ) -> Option<(Gain, BlockId)> {
        hg_delegate!(self, s => s.max_gain_move::<P>(phg, u))
    }

    #[inline]
    fn is_border(&self, phg: &PartitionedHypergraph<H>, u: NodeId) -> bool {
        hg_delegate!(self, s => StateOps::<H>::is_border(s, phg, u))
    }

    fn begin_level(&self, phg: &PartitionedHypergraph<H>) {
        hg_delegate!(self, s => StateOps::<H>::begin_level(s, phg))
    }

    fn reset_net_uniform(&self, phg: &PartitionedHypergraph<H>, e: EdgeId, b: BlockId) {
        hg_delegate!(self, s => StateOps::<H>::reset_net_uniform(s, phg, e, b))
    }

    fn reset_net_recount(&self, phg: &PartitionedHypergraph<H>, e: EdgeId) {
        hg_delegate!(self, s => StateOps::<H>::reset_net_recount(s, phg, e))
    }

    fn verify(&self, phg: &PartitionedHypergraph<H>) -> Result<(), String> {
        hg_delegate!(self, s => StateOps::<H>::verify(s, phg))
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::Graph;
    use crate::partition::PartitionedGraph;
    use crate::{BlockId, Gain, NodeId};
    use std::sync::atomic::{AtomicBool, AtomicI64, Ordering};
    use std::sync::Arc;

    fn ring(n: usize) -> Graph {
        let edges: Vec<(NodeId, NodeId, i64)> =
            (0..n).map(|u| (u as NodeId, ((u + 1) % n) as NodeId, 1)).collect();
        Graph::from_edges(n, &edges, None)
    }

    fn setup(parts: &[BlockId], k: usize) -> PartitionedGraph {
        let g = Arc::new(ring(parts.len()));
        let mut pg = PartitionedGraph::new(g, k);
        pg.set_uniform_max_weight(1.0);
        pg.assign_all(parts, 2);
        pg
    }

    #[test]
    fn cut_and_gain() {
        // ring of 8 split in halves: exactly 2 cut edges
        let pg = setup(&[0, 0, 0, 0, 1, 1, 1, 1], 2);
        assert_eq!(pg.cut(), 2);
        assert_eq!(pg.km1(), 2, "km1 == cut on graphs");
        assert_eq!(pg.soed(), 4);
        // node 3 sits at a boundary: one neighbor per side
        assert_eq!(pg.gain(3, 1), 0);
        assert!(pg.is_border(3));
        assert!(!pg.is_border(1));
        pg.verify_consistency().unwrap();
    }

    #[test]
    fn attributed_gain_matches_cut_delta_sequential() {
        let pg = setup(&[0, 1, 0, 1, 0, 1, 0, 1, 0, 1], 2);
        let mut cut = pg.cut();
        let mut rng = crate::util::Rng::new(7);
        for _ in 0..20 {
            let u = rng.next_below(10) as NodeId;
            let to = 1 - pg.block_of(u);
            let expected = pg.gain(u, to);
            if let Some(out) = pg.try_move(u, to, None) {
                assert_eq!(out.attributed_gain, expected, "sequential attributed == exact");
                cut -= out.attributed_gain;
                assert_eq!(pg.cut(), cut);
            }
        }
        pg.verify_consistency().unwrap();
    }

    #[test]
    fn concurrent_moves_once_per_node_sum_exactly() {
        for trial in 0..10u64 {
            let pg = setup(&[0, 1, 0, 1, 0, 1, 0, 1, 0, 1, 0, 1], 2);
            let before = pg.cut();
            let total = AtomicI64::new(0);
            let claimed: Vec<AtomicBool> = (0..12).map(|_| AtomicBool::new(false)).collect();
            std::thread::scope(|s| {
                for t in 0..4u64 {
                    let pg = &pg;
                    let total = &total;
                    let claimed = &claimed;
                    s.spawn(move || {
                        let mut rng = crate::util::Rng::new(trial * 31 + t);
                        for _ in 0..6 {
                            let u = rng.next_below(12) as NodeId;
                            if claimed[u as usize].swap(true, Ordering::AcqRel) {
                                continue; // each node moves at most once
                            }
                            let to = 1 - pg.block_of(u);
                            if let Some(out) = pg.try_move(u, to, None) {
                                total.fetch_add(out.attributed_gain, Ordering::Relaxed);
                            }
                        }
                    });
                }
            });
            pg.verify_consistency().unwrap();
            assert_eq!(
                before - total.load(Ordering::Relaxed),
                pg.cut(),
                "attributed gains sum exactly (trial {trial})"
            );
        }
    }

    #[test]
    fn balance_rejection() {
        let g = Arc::new(ring(4));
        let mut pg = PartitionedGraph::new(g, 2);
        pg.set_max_weights(vec![2, 2]);
        pg.assign_all(&[0, 0, 1, 1], 1);
        assert!(pg.try_move(0, 1, None).is_none(), "target block at its limit");
        assert_eq!(pg.block_weight(1), 2, "reservation reverted");
        pg.verify_consistency().unwrap();
    }

    #[test]
    fn policy_gains_collapse_on_graphs() {
        use crate::partition::objective::{CutNetPolicy, Km1Policy, SoedPolicy};
        let pg = setup(&[0, 0, 1, 1, 2, 2, 1, 0], 3);
        for u in 0..8 as NodeId {
            for t in 0..3 as BlockId {
                let km1 = pg.gain_p::<Km1Policy>(u, t);
                let cut = pg.gain_p::<CutNetPolicy>(u, t);
                let soed = pg.gain_p::<SoedPolicy>(u, t);
                assert_eq!(km1, cut, "km1 == cut gain on two-pin nets");
                assert_eq!(soed, 2 * km1, "soed == 2 · km1 on two-pin nets");
            }
        }
    }

    #[test]
    fn two_pin_state_matches_hypergraph_view() {
        // same assignment on the CSR graph and its 2-pin-net hypergraph
        // view: every metric and every Φ/Λ query must agree
        let g = ring(9);
        let parts: Vec<BlockId> = (0..9).map(|u| (u % 3) as BlockId).collect();
        let pg = {
            let mut pg = PartitionedGraph::new(Arc::new(g.clone()), 3);
            pg.set_uniform_max_weight(1.0);
            pg.assign_all(&parts, 2);
            pg
        };
        let ph = {
            let mut ph =
                crate::partition::PartitionedHypergraph::new(Arc::new(g.to_hypergraph()), 3);
            ph.set_uniform_max_weight(1.0);
            ph.assign_all(&parts, 2);
            ph
        };
        assert_eq!(pg.km1(), ph.km1());
        assert_eq!(pg.cut(), ph.cut());
        assert_eq!(pg.soed(), ph.soed());
        assert_eq!(pg.cut(), crate::metrics::graph_cut(&g, &parts));
        for u in 0..9 as NodeId {
            assert_eq!(pg.is_border(u), ph.is_border(u));
            for t in 0..3 as BlockId {
                assert_eq!(pg.gain(u, t), ph.gain(u, t), "gain({u},{t})");
            }
        }
    }

    #[test]
    fn max_gain_move_single_pass_matches_generic_shape() {
        let pg = setup(&[0, 0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2], 3);
        for u in 0..12 as NodeId {
            if let Some((g, t)) = pg.max_gain_move(u) {
                assert_eq!(g, pg.gain(u, t), "reported gain is the exact gain");
                assert!(t != pg.block_of(u));
            }
        }
    }
}
