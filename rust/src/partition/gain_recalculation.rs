//! Parallel gain recalculation (paper §6.3, Algorithm 6.2).
//!
//! Given a *sequence* of node moves `M = ⟨m_1 … m_l⟩` (each node moved at
//! most once) and the partition state *after* applying all of them,
//! recompute the exact gain each move would have had if the sequence were
//! executed in order. Used by parallel FM to find the best prefix of the
//! global move sequence (§7) without any sequential replay.
//!
//! Per hyperedge: the move that *last* leaves a block whose pins are all
//! moved out (before anyone moves in) reduces connectivity; the move that
//! *first* enters a block emptied that way increases it. Both are decided
//! from `first_in` / `last_out` move indices and the non-moved pin counts.

use super::objective::{GainPolicy, Km1Policy};
use super::PartitionedHypergraph;
use crate::hypergraph::HypergraphOps;
use crate::metrics::Objective;
use crate::parallel::par_for_auto;
use crate::util::AtomicBitset;
use crate::{BlockId, EdgeId, Gain, NodeId};
use std::sync::atomic::{AtomicI64, Ordering};

/// One entry of a move sequence.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Move {
    pub node: NodeId,
    pub from: BlockId,
    pub to: BlockId,
}

/// Reusable node/net-sized scratch of [`recalculate_gains_with_scratch`].
///
/// The two level-sized structures of Algorithm 6.2 — the per-node move
/// index and the processed-net bitset — are kept allocated across calls
/// and reset *sparsely* (only the entries the move sequence touched), so
/// a seeded n-level FM invocation costs O(Σ|I(moves)|) instead of
/// O(n + m) per batch. The invariant between calls: every `move_idx`
/// entry is `u32::MAX` and every `processed` bit is clear.
pub struct RecalcScratch {
    move_idx: Vec<u32>,
    processed: AtomicBitset,
}

impl Default for RecalcScratch {
    fn default() -> Self {
        RecalcScratch { move_idx: Vec::new(), processed: AtomicBitset::new(0) }
    }
}

impl RecalcScratch {
    /// Grow to cover `n` nodes and `m` nets (new entries enter in the
    /// reset state; never shrinks).
    pub fn ensure(&mut self, n: usize, m: usize) {
        if self.move_idx.len() < n {
            self.move_idx.resize(n, u32::MAX);
        }
        self.processed.ensure_len(m);
    }
}

/// Recalculate the exact in-order gains of `moves` (Algorithm 6.2),
/// parallel over the hyperedges touched by moved nodes. Convenience
/// wrapper allocating throwaway scratch — the FM workspace goes through
/// [`recalculate_gains_with_scratch`].
///
/// `phg` must reflect the state *after* all moves were applied.
pub fn recalculate_gains<H: HypergraphOps>(
    phg: &PartitionedHypergraph<H>,
    moves: &[Move],
    threads: usize,
) -> Vec<Gain> {
    recalculate_gains_p::<Km1Policy, H>(phg, moves, threads)
}

/// [`recalculate_gains`] for an arbitrary [`GainPolicy`].
pub fn recalculate_gains_p<P: GainPolicy, H: HypergraphOps>(
    phg: &PartitionedHypergraph<H>,
    moves: &[Move],
    threads: usize,
) -> Vec<Gain> {
    let mut scratch = RecalcScratch::default();
    recalculate_gains_with_scratch_p::<P, H>(phg, moves, threads, &mut scratch)
}

/// Algorithm 6.2 on reusable scratch (see [`RecalcScratch`]).
pub fn recalculate_gains_with_scratch<H: HypergraphOps>(
    phg: &PartitionedHypergraph<H>,
    moves: &[Move],
    threads: usize,
    scratch: &mut RecalcScratch,
) -> Vec<Gain> {
    recalculate_gains_with_scratch_p::<Km1Policy, H>(phg, moves, threads, scratch)
}

/// [`recalculate_gains_with_scratch`] for an arbitrary [`GainPolicy`].
///
/// The km1 instantiation uses the closed-form per-net rule below
/// (`process_net`); connectivity-transition objectives (cut, soed) go
/// through a restricted per-net replay (`process_net_replay`) that rewinds
/// the net's pin counts to the pre-move state and re-applies its moved
/// pins in move order, so `P::attributed_delta` sees the exact λ after
/// each move — the same state the synchronized online update observes.
pub fn recalculate_gains_with_scratch_p<P: GainPolicy, H: HypergraphOps>(
    phg: &PartitionedHypergraph<H>,
    moves: &[Move],
    threads: usize,
    scratch: &mut RecalcScratch,
) -> Vec<Gain> {
    let hg = phg.hypergraph();
    let l = moves.len();
    scratch.ensure(hg.num_nodes(), hg.num_nets());
    let move_idx = &mut scratch.move_idx;
    for (i, m) in moves.iter().enumerate() {
        debug_assert_eq!(move_idx[m.node as usize], u32::MAX, "node moved twice");
        move_idx[m.node as usize] = i as u32;
    }
    let gains: Vec<AtomicI64> = (0..l).map(|_| AtomicI64::new(0)).collect();
    let processed = &scratch.processed;
    let move_idx = &*move_idx;

    par_for_auto(l, threads, |mi| {
        let u = moves[mi].node;
        for &e in hg.incident_nets(u) {
            if processed.test_and_set(e as usize) {
                continue; // another thread handles this net
            }
            if P::OBJECTIVE == Objective::Km1 {
                process_net(phg, e, moves, move_idx, &gains);
            } else {
                process_net_replay::<P, H>(phg, e, moves, move_idx, &gains);
            }
        }
    });

    // sparse reset: exactly the touched entries go back to the between-
    // calls invariant (all-MAX / all-clear)
    par_for_auto(l, threads, |mi| {
        for &e in hg.incident_nets(moves[mi].node) {
            processed.clear_bit(e as usize);
        }
    });
    for m in moves {
        scratch.move_idx[m.node as usize] = u32::MAX;
    }
    gains.into_iter().map(|g| g.into_inner()).collect()
}

/// Per-block bookkeeping of [`process_net`] — one entry per block the
/// net's pins touch, so a net costs O(|e|·λ'(e)) instead of O(k).
#[derive(Clone, Copy)]
struct NetBlock {
    block: BlockId,
    first_in: u32,
    last_out: i64,
    non_moved: u32,
}

fn net_block(blocks: &mut Vec<NetBlock>, b: BlockId) -> &mut NetBlock {
    match blocks.iter().position(|x| x.block == b) {
        Some(i) => &mut blocks[i],
        None => {
            blocks.push(NetBlock { block: b, first_in: u32::MAX, last_out: i64::MIN, non_moved: 0 });
            blocks.last_mut().unwrap()
        }
    }
}

/// Algorithm 6.2 for a single hyperedge. Touches only the blocks the
/// net's pins occupy or move between — no k-sized scratch, so large-k
/// runs pay per-net work proportional to the net, not to k.
fn process_net<H: HypergraphOps>(
    phg: &PartitionedHypergraph<H>,
    e: EdgeId,
    moves: &[Move],
    move_idx: &[u32],
    gains: &[AtomicI64],
) {
    let hg = phg.hypergraph();
    let w = hg.net_weight(e);
    let pins = hg.pins(e);
    let mut blocks: Vec<NetBlock> = Vec::with_capacity(pins.len().min(16));

    for &u in pins {
        let i = move_idx[u as usize];
        if i != u32::MAX {
            let m = moves[i as usize];
            let s = net_block(&mut blocks, m.from);
            s.last_out = s.last_out.max(i as i64);
            let t = net_block(&mut blocks, m.to);
            t.first_in = t.first_in.min(i);
        } else {
            net_block(&mut blocks, phg.block_of(u)).non_moved += 1;
        }
    }

    for &u in pins {
        let i = move_idx[u as usize];
        if i == u32::MAX {
            continue;
        }
        let m = moves[i as usize];
        let s = *net_block(&mut blocks, m.from);
        // connectivity decrease: u last out of V_s, emptied, before any in
        if s.last_out == i as i64 && (i as u64) < s.first_in as u64 && s.non_moved == 0 {
            gains[i as usize].fetch_add(w, Ordering::Relaxed);
        }
        let t = *net_block(&mut blocks, m.to);
        // connectivity increase: u first into V_t after everyone left
        if t.first_in == i && i as i64 > t.last_out && t.non_moved == 0 {
            gains[i as usize].fetch_sub(w, Ordering::Relaxed);
        }
    }
}

/// Restricted replay for connectivity-transition objectives (cut, soed).
///
/// The km1 closed form above only needs to know *which* move empties or
/// first re-occupies a block; cut-net gains additionally depend on the
/// connectivity λ right after each move (the 2→1 / 1→2 Φ-transitions).
/// So per net: rewind Φ from the post-state by undoing its moved pins,
/// then replay those pins sorted by move index, maintaining λ
/// incrementally and attributing each move via `P::attributed_delta` —
/// O(|e| + t_e log t_e) per net where t_e is the net's moved-pin count.
fn process_net_replay<P: GainPolicy, H: HypergraphOps>(
    phg: &PartitionedHypergraph<H>,
    e: EdgeId,
    moves: &[Move],
    move_idx: &[u32],
    gains: &[AtomicI64],
) {
    let hg = phg.hypergraph();
    let w = hg.net_weight(e);
    // sparse Φ over the ≤ |Λ(e)| + t_e blocks this net can see during
    // the replay (post-state connectivity plus rewound from-blocks) — no
    // k-sized scratch
    let mut phi: Vec<(BlockId, i64)> = Vec::new();
    for b in phg.connectivity_set(e) {
        phi.push((b, phg.pin_count(e, b) as i64));
    }
    fn phi_slot(phi: &mut Vec<(BlockId, i64)>, b: BlockId) -> &mut i64 {
        match phi.iter().position(|&(pb, _)| pb == b) {
            Some(i) => &mut phi[i].1,
            None => {
                phi.push((b, 0));
                &mut phi.last_mut().unwrap().1
            }
        }
    }
    let mut touched: Vec<u32> = Vec::new();
    for &u in hg.pins(e) {
        let i = move_idx[u as usize];
        if i != u32::MAX {
            let m = moves[i as usize];
            *phi_slot(&mut phi, m.to) -= 1;
            *phi_slot(&mut phi, m.from) += 1;
            touched.push(i);
        }
    }
    if touched.is_empty() {
        return;
    }
    touched.sort_unstable();
    let mut lambda = phi.iter().filter(|&&(_, c)| c > 0).count() as u32;
    for &i in &touched {
        let m = moves[i as usize];
        let phi_s = {
            let s = phi_slot(&mut phi, m.from);
            *s -= 1;
            if *s == 0 {
                lambda -= 1;
            }
            *s
        };
        let phi_t = {
            let t = phi_slot(&mut phi, m.to);
            if *t == 0 {
                lambda += 1;
            }
            *t += 1;
            *t
        };
        let d = P::attributed_delta(w, phi_s as u32, phi_t as u32, lambda);
        if d != 0 {
            gains[i as usize].fetch_add(d, Ordering::Relaxed);
        }
    }
}

/// Find the prefix of `gains` with the largest cumulative sum.
/// Returns `(prefix_len, prefix_gain)` — `(0, 0)` if every prefix is
/// non-positive. Ties pick the *longest* prefix achieving the maximum
/// (more moves at equal quality help subsequent rounds escape plateaus).
pub fn best_prefix(gains: &[Gain]) -> (usize, Gain) {
    let mut best_len = 0;
    let mut best_sum: Gain = 0;
    let mut acc: Gain = 0;
    for (i, &g) in gains.iter().enumerate() {
        acc += g;
        if acc >= best_sum && acc > 0 || (acc == best_sum && best_sum > 0) {
            best_sum = acc;
            best_len = i + 1;
        }
    }
    (best_len, best_sum)
}

/// Revert the moves after the best prefix (in reverse order) and return
/// `(prefix_len, prefix_gain)`. The partition afterwards reflects exactly
/// `moves[..prefix_len]`.
pub fn revert_to_best_prefix<H: HypergraphOps>(
    phg: &PartitionedHypergraph<H>,
    moves: &[Move],
    gains: &[Gain],
    gain_table: Option<&super::GainTable>,
) -> (usize, Gain) {
    revert_to_best_prefix_p::<Km1Policy, H>(phg, moves, gains, gain_table)
}

/// [`revert_to_best_prefix`] for an arbitrary [`GainPolicy`] — the
/// reverting moves maintain the gain table under the same policy.
pub fn revert_to_best_prefix_p<P: GainPolicy, H: HypergraphOps>(
    phg: &PartitionedHypergraph<H>,
    moves: &[Move],
    gains: &[Gain],
    gain_table: Option<&super::GainTable>,
) -> (usize, Gain) {
    let (len, total) = best_prefix(gains);
    for m in moves[len..].iter().rev() {
        phg.move_unchecked_p::<P>(m.node, m.from, gain_table);
    }
    (len, total)
}

/// Reference implementation: sequential replay of the move sequence from
/// the pre-move state. Used by tests to validate Algorithm 6.2.
pub fn replay_gains_reference<H: HypergraphOps>(
    phg_pre: &PartitionedHypergraph<H>,
    moves: &[Move],
) -> Vec<Gain> {
    replay_gains_reference_p::<Km1Policy, H>(phg_pre, moves)
}

/// [`replay_gains_reference`] for an arbitrary [`GainPolicy`].
pub fn replay_gains_reference_p<P: GainPolicy, H: HypergraphOps>(
    phg_pre: &PartitionedHypergraph<H>,
    moves: &[Move],
) -> Vec<Gain> {
    moves
        .iter()
        .map(|m| {
            let g = phg_pre.gain_p::<P>(m.node, m.to);
            phg_pre.move_unchecked_p::<P>(m.node, m.to, None);
            g
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergraph::Hypergraph;
    use crate::util::Rng;
    use std::sync::Arc;

    fn random_instance(seed: u64) -> (Arc<Hypergraph>, Vec<BlockId>, usize) {
        let mut rng = Rng::new(seed);
        let n = 30;
        let k = 3;
        let m = 40;
        let mut nets = Vec::new();
        for _ in 0..m {
            let sz = 2 + rng.next_below(4);
            let pins: Vec<NodeId> =
                rng.sample_indices(n, sz).into_iter().map(|x| x as NodeId).collect();
            nets.push(pins);
        }
        let hg = Arc::new(Hypergraph::from_nets(n, &nets, None, None));
        let parts: Vec<BlockId> = (0..n).map(|_| rng.next_below(k) as BlockId).collect();
        (hg, parts, k)
    }

    #[test]
    fn matches_sequential_replay() {
        for seed in 0..20 {
            let (hg, parts, k) = random_instance(seed);
            let mut rng = Rng::new(seed ^ 0xabc);
            // random move sequence, each node at most once
            let mut moves = Vec::new();
            let order = rng.sample_indices(hg.num_nodes(), 15);
            for u in order {
                let from = parts[u];
                let to = ((from as usize + 1 + rng.next_below(k - 1)) % k) as BlockId;
                moves.push(Move { node: u as NodeId, from, to });
            }
            // reference: replay from pre-state
            let pre = PartitionedHypergraph::new(hg.clone(), k);
            pre.assign_all(&parts, 1);
            let expected = replay_gains_reference(&pre, &moves);
            // Algorithm 6.2 on the post-state (pre is now post-replay)
            for threads in [1, 4] {
                let got = recalculate_gains(&pre, &moves, threads);
                assert_eq!(got, expected, "seed {seed} threads {threads}");
            }
        }
    }

    #[test]
    fn matches_sequential_replay_cut_and_soed() {
        use crate::partition::{CutNetPolicy, SoedPolicy};
        for seed in 0..12 {
            let (hg, parts, k) = random_instance(seed ^ 0x9e);
            let mut rng = Rng::new(seed ^ 0xdef);
            let mut moves = Vec::new();
            for u in rng.sample_indices(hg.num_nodes(), 15) {
                let from = parts[u];
                let to = ((from as usize + 1 + rng.next_below(k - 1)) % k) as BlockId;
                moves.push(Move { node: u as NodeId, from, to });
            }
            // cut: reference replay from pre-state, then Alg. 6.2 on post
            let pre = PartitionedHypergraph::new(hg.clone(), k);
            pre.assign_all(&parts, 1);
            let expected = replay_gains_reference_p::<CutNetPolicy, _>(&pre, &moves);
            for threads in [1, 4] {
                let got = recalculate_gains_p::<CutNetPolicy, _>(&pre, &moves, threads);
                assert_eq!(got, expected, "cut seed {seed} threads {threads}");
            }
            // soed on a fresh pre-state
            let pre = PartitionedHypergraph::new(hg.clone(), k);
            pre.assign_all(&parts, 1);
            let expected = replay_gains_reference_p::<SoedPolicy, _>(&pre, &moves);
            for threads in [1, 4] {
                let got = recalculate_gains_p::<SoedPolicy, _>(&pre, &moves, threads);
                assert_eq!(got, expected, "soed seed {seed} threads {threads}");
            }
        }
    }

    #[test]
    fn scratch_reuse_is_bit_identical() {
        // the pooled scratch must behave exactly like throwaway scratch,
        // including when reused across instances of different sizes (the
        // sparse reset restores the between-calls invariant)
        let mut scratch = RecalcScratch::default();
        for seed in 0..10 {
            let (hg, parts, k) = random_instance(seed ^ 0x55);
            let mut rng = Rng::new(seed ^ 0x77);
            let mut moves = Vec::new();
            for u in rng.sample_indices(hg.num_nodes(), 12) {
                let from = parts[u];
                let to = ((from as usize + 1 + rng.next_below(k - 1)) % k) as BlockId;
                moves.push(Move { node: u as NodeId, from, to });
            }
            let pre = PartitionedHypergraph::new(hg.clone(), k);
            pre.assign_all(&parts, 1);
            let expected = replay_gains_reference(&pre, &moves);
            let fresh = recalculate_gains(&pre, &moves, 2);
            let pooled = recalculate_gains_with_scratch(&pre, &moves, 2, &mut scratch);
            assert_eq!(fresh, expected, "seed {seed}");
            assert_eq!(pooled, expected, "seed {seed}: pooled scratch differs");
            // run twice on the same scratch: the sparse reset must hold
            let again = recalculate_gains_with_scratch(&pre, &moves, 2, &mut scratch);
            assert_eq!(again, expected, "seed {seed}: second pooled run differs");
        }
    }

    #[test]
    fn best_prefix_examples() {
        assert_eq!(best_prefix(&[]), (0, 0));
        assert_eq!(best_prefix(&[-1, -2]), (0, 0));
        assert_eq!(best_prefix(&[2, -1, 3, -10]), (3, 4));
        assert_eq!(best_prefix(&[-1, 5]), (2, 4));
        // longest prefix at equal max: [1, 0] -> len 2
        assert_eq!(best_prefix(&[1, 0]), (2, 1));
    }

    #[test]
    fn revert_restores_prefix_state() {
        let (hg, parts, k) = random_instance(99);
        let phg = PartitionedHypergraph::new(hg.clone(), k);
        phg.assign_all(&parts, 1);
        let km1_start = phg.km1();
        let mut rng = Rng::new(1234);
        let mut moves = Vec::new();
        for u in rng.sample_indices(hg.num_nodes(), 12) {
            let from = phg.block_of(u as NodeId);
            let to = ((from as usize + 1) % k) as BlockId;
            phg.move_unchecked(u as NodeId, to, None);
            moves.push(Move { node: u as NodeId, from, to });
        }
        let gains = recalculate_gains(&phg, &moves, 2);
        let (len, total) = revert_to_best_prefix(&phg, &moves, &gains, None);
        phg.verify_consistency().unwrap();
        assert_eq!(phg.km1(), km1_start - total, "prefix gain accounts exactly");
        assert!(len <= moves.len());
        // prefix moves are still applied
        for m in &moves[..len] {
            assert_eq!(phg.block_of(m.node), m.to);
        }
        for m in &moves[len..] {
            assert_eq!(phg.block_of(m.node), m.from);
        }
    }
}
