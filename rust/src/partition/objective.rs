//! The objective layer: per-objective gain semantics behind one trait.
//!
//! The paper optimizes the connectivity metric f_{λ−1}; upstream
//! Mt-KaHyPar ships a *portfolio* of objectives (cut-net, sum of external
//! degrees) behind a single attributed-gain abstraction. [`GainPolicy`]
//! is that abstraction here: a compile-time tag type providing the pure
//! per-net math every refiner needs —
//!
//! * the **attributed delta** of one synchronized pin-count transition
//!   (Algorithm 6.1's gain attribution, generalized per objective),
//! * the **benefit/penalty contributions** of the §6.2 two-level gain
//!   table (and of every from-scratch gain computation, which all take
//!   the shape `gain(u→t) = Σ benefit(e, Φ(e, Π(u))) − Σ penalty(e,
//!   Φ(e, t))`),
//! * the **net contribution** to the from-scratch metric given λ(e),
//! * the **bridging-edge capacity** of the §8.2 Lawler flow network.
//!
//! Everything is a `const`/`#[inline]` pure function of `(ω(e), Φ, λ,
//! |e|)`, so monomorphizing a refiner over [`Km1Policy`] constant-folds
//! to exactly the pre-refactor km1 code: `NEEDS_CONNECTIVITY = false`
//! removes the λ read from the move loop, `NEEDS_NET_SIZE = false`
//! removes the |e| lookup from the gain loops, and the contribution
//! functions inline to the familiar `Φ(e, from) == 1` / `Φ(e, to) == 0`
//! tests.
//!
//! ## Φ-transition rules per objective
//!
//! **km1** (connectivity, λ−1): a move decreases the metric by ω(e) iff
//! it zeroes Φ(e, V_from) and increases it by ω(e) iff it makes
//! Φ(e, V_to) = 1 — pure pin-count transitions, λ is never needed
//! (Lemma 6.1).
//!
//! **cut-net**: ω(e) leaves the cut only on a λ: 2→1 transition and
//! enters it only on a 1→2 transition. Both are detectable from the same
//! synchronized state: the move changes λ(e) by
//! `[Φ(e,to)=1 after] − [Φ(e,from)=0 after] ∈ {−1, 0, +1}`, and λ(e)
//! *after* the move is read under the same per-net lock that serialized
//! the pin-count update. Per net, the signed 1↔2 boundary crossings
//! telescope over any concurrent move sequence to
//! `ω(e)·([λ_start ≥ 2] − [λ_end ≥ 2])`, so summed attributed cut gains
//! are exact exactly like km1's (the cut analogue of Lemma 6.1).
//!
//! **soed** (sum of external degrees) = km1 + cut, composed term-wise in
//! every rule.
//!
//! ## Benefit/penalty shapes
//!
//! km1 keeps the textbook non-negative contributions (benefit ω(e) iff
//! Φ(e, own) = 1; penalty ω(e) iff Φ(e, t) = 0). The cut-net metric fits
//! the same `b − p` decomposition with *signed* contributions: the
//! benefit of leaving the own block is −ω(e) iff the net is internal
//! (Φ(e, own) = |e|), the penalty of entering t is −ω(e) iff t can
//! absorb the net (Φ(e, t) = |e|−1) — so `b − p` is the exact cut delta.
//! All cut contributions carry a |e| ≥ 2 guard: single-pin nets (which
//! the dynamic n-level structure can expose) are never cut.

use crate::metrics::Objective;
use crate::Gain;

/// Per-objective gain semantics (see the module docs). Implementors are
/// zero-sized tag types; every refiner that makes objective-improvement
/// decisions is generic over this trait and monomorphized per objective.
pub trait GainPolicy: Copy + Send + Sync + 'static {
    /// The runtime objective this policy implements.
    const OBJECTIVE: Objective;
    /// Does [`Self::attributed_delta`] need λ(e) after the move? When
    /// `false` the move loop skips the connectivity read entirely.
    const NEEDS_CONNECTIVITY: bool;
    /// Do the contribution functions need |e|? When `false` the gain
    /// loops skip the net-size lookup.
    const NEEDS_NET_SIZE: bool;

    /// Attributed objective delta of one move on one net, from the
    /// synchronized pin-count transition (`phi_*_after` are the values
    /// *after* the move, as returned by the locked dec/inc) and — for
    /// connectivity-transition objectives — λ(e) after the move, read
    /// under the same lock. Positive = the objective decreased.
    fn attributed_delta(w: i64, phi_from_after: u32, phi_to_after: u32, lambda_after: u32)
        -> Gain;

    /// Benefit contribution of net `e` (weight `w`, |e| = `size`) to
    /// moving a pin out of a block holding `phi_own` of its pins.
    fn benefit_contrib(w: i64, phi_own: u32, size: u32) -> Gain;

    /// Penalty contribution of net `e` (weight `w`, |e| = `size`) to
    /// moving a pin into a block holding `phi_target` of its pins.
    fn penalty_contrib(w: i64, phi_target: u32, size: u32) -> Gain;

    /// Contribution of a net with connectivity `lambda` and weight `w`
    /// to the from-scratch metric.
    fn net_contribution(lambda: u32, w: i64) -> i64;

    /// Capacity of the Lawler bridging edge `e_in → e_out` (paper §8.2)
    /// for a net of weight `w`; `external` is true when the net has pins
    /// in blocks other than the refined pair (for cut-style objectives
    /// such a net stays cut no matter how the pair is split, so cutting
    /// it inside the flow network is free).
    fn bridging_capacity(w: i64, external: bool) -> i64;
}

/// Connectivity metric f_{λ−1} — the paper's objective; monomorphizing
/// over this policy reproduces the pre-refactor code paths exactly.
#[derive(Clone, Copy, Debug, Default)]
pub struct Km1Policy;

impl GainPolicy for Km1Policy {
    const OBJECTIVE: Objective = Objective::Km1;
    const NEEDS_CONNECTIVITY: bool = false;
    const NEEDS_NET_SIZE: bool = false;

    #[inline(always)]
    fn attributed_delta(w: i64, phi_from_after: u32, phi_to_after: u32, _lambda_after: u32) -> Gain {
        let mut g = 0;
        if phi_from_after == 0 {
            g += w;
        }
        if phi_to_after == 1 {
            g -= w;
        }
        g
    }

    #[inline(always)]
    fn benefit_contrib(w: i64, phi_own: u32, _size: u32) -> Gain {
        if phi_own == 1 {
            w
        } else {
            0
        }
    }

    #[inline(always)]
    fn penalty_contrib(w: i64, phi_target: u32, _size: u32) -> Gain {
        if phi_target == 0 {
            w
        } else {
            0
        }
    }

    #[inline(always)]
    fn net_contribution(lambda: u32, w: i64) -> i64 {
        lambda.saturating_sub(1) as i64 * w
    }

    #[inline(always)]
    fn bridging_capacity(w: i64, _external: bool) -> i64 {
        w
    }
}

/// Cut-net metric f_c: ω(e) counts iff λ(e) ≥ 2. Attributed gains fire
/// only on λ 2→1 / 1→2 transitions (see the module docs).
#[derive(Clone, Copy, Debug, Default)]
pub struct CutNetPolicy;

impl GainPolicy for CutNetPolicy {
    const OBJECTIVE: Objective = Objective::Cut;
    const NEEDS_CONNECTIVITY: bool = true;
    const NEEDS_NET_SIZE: bool = true;

    #[inline(always)]
    fn attributed_delta(w: i64, phi_from_after: u32, phi_to_after: u32, lambda_after: u32) -> Gain {
        // λ delta of this move: +1 iff the target block is new, −1 iff
        // the source block emptied (both can happen; then λ is unchanged)
        let entered = i32::from(phi_to_after == 1);
        let left = i32::from(phi_from_after == 0);
        match entered - left {
            -1 if lambda_after == 1 => w,  // 2→1: net left the cut
            1 if lambda_after == 2 => -w,  // 1→2: net entered the cut
            _ => 0,
        }
    }

    #[inline(always)]
    fn benefit_contrib(w: i64, phi_own: u32, size: u32) -> Gain {
        // leaving the own block cuts a currently internal net
        if size >= 2 && phi_own == size {
            -w
        } else {
            0
        }
    }

    #[inline(always)]
    fn penalty_contrib(w: i64, phi_target: u32, size: u32) -> Gain {
        // entering t uncuts the net iff t holds all other pins
        if size >= 2 && phi_target + 1 == size {
            -w
        } else {
            0
        }
    }

    #[inline(always)]
    fn net_contribution(lambda: u32, w: i64) -> i64 {
        if lambda >= 2 {
            w
        } else {
            0
        }
    }

    #[inline(always)]
    fn bridging_capacity(w: i64, external: bool) -> i64 {
        if external {
            0
        } else {
            w
        }
    }
}

/// Sum of external degrees f_s = f_{λ−1} + f_c, composed term-wise.
#[derive(Clone, Copy, Debug, Default)]
pub struct SoedPolicy;

impl GainPolicy for SoedPolicy {
    const OBJECTIVE: Objective = Objective::Soed;
    const NEEDS_CONNECTIVITY: bool = true;
    const NEEDS_NET_SIZE: bool = true;

    #[inline(always)]
    fn attributed_delta(w: i64, phi_from_after: u32, phi_to_after: u32, lambda_after: u32) -> Gain {
        Km1Policy::attributed_delta(w, phi_from_after, phi_to_after, lambda_after)
            + CutNetPolicy::attributed_delta(w, phi_from_after, phi_to_after, lambda_after)
    }

    #[inline(always)]
    fn benefit_contrib(w: i64, phi_own: u32, size: u32) -> Gain {
        Km1Policy::benefit_contrib(w, phi_own, size)
            + CutNetPolicy::benefit_contrib(w, phi_own, size)
    }

    #[inline(always)]
    fn penalty_contrib(w: i64, phi_target: u32, size: u32) -> Gain {
        Km1Policy::penalty_contrib(w, phi_target, size)
            + CutNetPolicy::penalty_contrib(w, phi_target, size)
    }

    #[inline(always)]
    fn net_contribution(lambda: u32, w: i64) -> i64 {
        Km1Policy::net_contribution(lambda, w) + CutNetPolicy::net_contribution(lambda, w)
    }

    #[inline(always)]
    fn bridging_capacity(w: i64, external: bool) -> i64 {
        Km1Policy::bridging_capacity(w, external) + CutNetPolicy::bridging_capacity(w, external)
    }
}

/// Monomorphize `$body` over the policy matching a runtime
/// [`Objective`]: inside each arm `$P` is a type alias for the selected
/// policy, so `$body` can call `some_generic_fn::<$P>(…)`. This is the
/// single dispatch point between `ctx.objective` and the generic refiner
/// stack — `Objective::Km1` selects exactly the pre-refactor code.
macro_rules! with_policy {
    ($obj:expr, $P:ident => $body:expr) => {
        match $obj {
            $crate::metrics::Objective::Km1 => {
                type $P = $crate::partition::objective::Km1Policy;
                $body
            }
            $crate::metrics::Objective::Cut => {
                type $P = $crate::partition::objective::CutNetPolicy;
                $body
            }
            $crate::metrics::Objective::Soed => {
                type $P = $crate::partition::objective::SoedPolicy;
                $body
            }
        }
    };
}
pub(crate) use with_policy;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn km1_transitions() {
        // zeroing the source block gains w; first pin in the target costs w
        assert_eq!(Km1Policy::attributed_delta(3, 0, 2, 0), 3);
        assert_eq!(Km1Policy::attributed_delta(3, 1, 1, 0), -3);
        assert_eq!(Km1Policy::attributed_delta(3, 0, 1, 0), 0); // both: λ shifts blocks
        assert_eq!(Km1Policy::attributed_delta(3, 2, 3, 0), 0);
    }

    #[test]
    fn cut_fires_only_on_boundary_transitions() {
        // λ 2→1 (source emptied, λ_after = 1): net leaves the cut
        assert_eq!(CutNetPolicy::attributed_delta(5, 0, 4, 1), 5);
        // λ 1→2 (target entered, λ_after = 2): net enters the cut
        assert_eq!(CutNetPolicy::attributed_delta(5, 2, 1, 2), -5);
        // λ 3→2: still cut, no attributed change
        assert_eq!(CutNetPolicy::attributed_delta(5, 0, 4, 2), 0);
        // λ 2→3: was already cut
        assert_eq!(CutNetPolicy::attributed_delta(5, 2, 1, 3), 0);
        // sole-pin shuffle: source emptied AND target entered, λ stays 1
        assert_eq!(CutNetPolicy::attributed_delta(5, 0, 1, 1), 0);
    }

    #[test]
    fn cut_contributions_guard_single_pin_nets() {
        assert_eq!(CutNetPolicy::benefit_contrib(5, 1, 1), 0);
        assert_eq!(CutNetPolicy::penalty_contrib(5, 0, 1), 0);
        // internal net: leaving cuts it (benefit −w)
        assert_eq!(CutNetPolicy::benefit_contrib(5, 4, 4), -5);
        assert_eq!(CutNetPolicy::benefit_contrib(5, 3, 4), 0);
        // absorbing target: entering uncuts it (penalty −w)
        assert_eq!(CutNetPolicy::penalty_contrib(5, 3, 4), -5);
        assert_eq!(CutNetPolicy::penalty_contrib(5, 2, 4), 0);
    }

    #[test]
    fn soed_composes() {
        for lambda in 1..5u32 {
            assert_eq!(
                SoedPolicy::net_contribution(lambda, 7),
                Km1Policy::net_contribution(lambda, 7) + CutNetPolicy::net_contribution(lambda, 7)
            );
        }
        assert_eq!(SoedPolicy::net_contribution(1, 7), 0);
        assert_eq!(SoedPolicy::net_contribution(2, 7), 14);
    }

    #[test]
    fn bridging_capacities() {
        assert_eq!(Km1Policy::bridging_capacity(4, true), 4);
        assert_eq!(CutNetPolicy::bridging_capacity(4, true), 0);
        assert_eq!(CutNetPolicy::bridging_capacity(4, false), 4);
        assert_eq!(SoedPolicy::bridging_capacity(4, true), 4);
        assert_eq!(SoedPolicy::bridging_capacity(4, false), 8);
    }
}
