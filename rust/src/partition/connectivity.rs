//! Connectivity sets Λ(e) as per-net k-bit bitsets (paper §6.1).
//!
//! "We use a bitset of size k to store the connectivity set Λ(e). …
//! To add or remove a block from the connectivity set, we flip the
//! corresponding bit using an atomic xor operation"; λ(e) is a popcount
//! over a snapshot.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Process-wide count of [`ConnectivitySets`] constructions. The
/// plain-graph specialization must never allocate connectivity bitsets
/// (Λ(e) ∈ {1,2} is derived from the two endpoint blocks); the structural
/// bench/test pair snapshots this counter around a graph run to prove it.
static ALLOCATIONS: AtomicUsize = AtomicUsize::new(0);

/// Number of `ConnectivitySets::new` calls since process start.
pub fn allocation_count() -> usize {
    ALLOCATIONS.load(Ordering::Relaxed)
}

/// Flat `m × ⌈k/64⌉` array of connectivity bitsets.
pub struct ConnectivitySets {
    words: Vec<AtomicU64>,
    words_per_net: usize,
    k: usize,
}

impl ConnectivitySets {
    pub fn new(num_nets: usize, k: usize) -> Self {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        let words_per_net = (k + 63) / 64;
        ConnectivitySets {
            words: (0..num_nets * words_per_net).map(|_| AtomicU64::new(0)).collect(),
            words_per_net,
            k,
        }
    }

    #[inline]
    fn base(&self, e: usize) -> usize {
        e * self.words_per_net
    }

    /// Atomically toggle block `b` in Λ(e).
    #[inline]
    pub fn flip(&self, e: usize, b: usize) {
        debug_assert!(b < self.k);
        self.words[self.base(e) + b / 64].fetch_xor(1 << (b % 64), Ordering::AcqRel);
    }

    /// Is block `b` in Λ(e)?
    #[inline]
    pub fn contains(&self, e: usize, b: usize) -> bool {
        (self.words[self.base(e) + b / 64].load(Ordering::Acquire) >> (b % 64)) & 1 == 1
    }

    /// λ(e) — popcount over a snapshot.
    #[inline]
    pub fn connectivity(&self, e: usize) -> u32 {
        let base = self.base(e);
        (0..self.words_per_net)
            .map(|i| self.words[base + i].load(Ordering::Acquire).count_ones())
            .sum()
    }

    /// Iterate the blocks of Λ(e) from a snapshot (count-trailing-zeros walk).
    ///
    /// Returns the concrete [`ConnSetIter`] so state abstractions can name
    /// the type (the `ConnIter` enum of `partition::state` wraps it).
    pub fn iter(&self, e: usize) -> ConnSetIter<'_> {
        let base = self.base(e);
        ConnSetIter { words: &self.words[base..base + self.words_per_net], wi: 0, cur: 0 }
    }

    /// Number of nets this array has storage for (pooled reuse: coarser
    /// levels address the prefix of a finest-level-sized allocation).
    #[inline]
    pub fn nets_capacity(&self) -> usize {
        self.words.len() / self.words_per_net.max(1)
    }

    /// Blocks per net this array was laid out for.
    #[inline]
    pub fn blocks(&self) -> usize {
        self.k
    }

    pub fn clear(&self) {
        self.clear_nets(self.nets_capacity());
    }

    /// Zero the bitset of a single net (exclusive-phase per-net repair
    /// on the cross-level delta path).
    pub fn clear_net(&self, e: usize) {
        let base = self.base(e);
        for w in &self.words[base..base + self.words_per_net] {
            w.store(0, Ordering::Relaxed);
        }
    }

    /// Zero the bitsets of the first `num_nets` nets only (per-level
    /// rebuild on a pooled array).
    pub fn clear_nets(&self, num_nets: usize) {
        for w in &self.words[..num_nets * self.words_per_net] {
            w.store(0, Ordering::Relaxed);
        }
    }
}

/// Snapshot iterator over one net's connectivity bitset: loads each word
/// once (`Acquire`) and walks its set bits via count-trailing-zeros.
pub struct ConnSetIter<'a> {
    /// the net's `words_per_net` words
    words: &'a [AtomicU64],
    /// index of the *next* word to load (the word `cur` came from is `wi - 1`)
    wi: usize,
    /// remaining bits of the current word's snapshot
    cur: u64,
}

impl<'a> Iterator for ConnSetIter<'a> {
    type Item = usize;

    #[inline]
    fn next(&mut self) -> Option<usize> {
        loop {
            if self.cur != 0 {
                let b = self.cur.trailing_zeros() as usize;
                self.cur &= self.cur - 1;
                return Some((self.wi - 1) * 64 + b);
            }
            if self.wi >= self.words.len() {
                return None;
            }
            self.cur = self.words[self.wi].load(Ordering::Acquire);
            self.wi += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flip_iter_count() {
        let cs = ConnectivitySets::new(2, 130);
        cs.flip(0, 0);
        cs.flip(0, 64);
        cs.flip(0, 129);
        cs.flip(1, 5);
        assert_eq!(cs.connectivity(0), 3);
        assert_eq!(cs.connectivity(1), 1);
        assert_eq!(cs.iter(0).collect::<Vec<_>>(), vec![0, 64, 129]);
        assert!(cs.contains(0, 64));
        cs.flip(0, 64);
        assert!(!cs.contains(0, 64));
        assert_eq!(cs.connectivity(0), 2);
    }

    #[test]
    fn concurrent_flips_distinct_bits() {
        let cs = ConnectivitySets::new(1, 64);
        std::thread::scope(|s| {
            for t in 0..4 {
                let cs = &cs;
                s.spawn(move || {
                    for b in (t..64).step_by(4) {
                        cs.flip(0, b);
                    }
                });
            }
        });
        assert_eq!(cs.connectivity(0), 64);
    }
}
