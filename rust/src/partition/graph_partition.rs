//! Graph-specialized partition data structure (paper §10.2).
//!
//! For plain graphs the pin counts and connectivity sets disappear: gains
//! are computed on the fly from neighbor blocks (`g_u(t) = ω(u,t) −
//! ω(u,Π[u])`), and attributed gains are synchronized per edge through a
//! CAS array `B` of size m — the first endpoint to move wins the CAS and
//! both endpoints account the edge consistently against `B[e]`.

use crate::graph::Graph;
use crate::parallel::par_for_auto;
use crate::{BlockId, Gain, NodeId, NodeWeight};
use std::sync::atomic::{AtomicI64, AtomicU32, Ordering};
use std::sync::Arc;

const UNSET: u32 = u32::MAX;

/// A k-way partitioned plain graph.
pub struct PartitionedGraph {
    g: Arc<Graph>,
    k: usize,
    part: Vec<AtomicU32>,
    block_weight: Vec<AtomicI64>,
    max_block_weight: Vec<NodeWeight>,
    /// undirected edge id per directed CSR slot
    uedge: Vec<u32>,
    num_uedges: usize,
    /// `B` array (paper §10.2): target block of the first endpoint moved
    edge_target: Vec<AtomicU32>,
}

impl PartitionedGraph {
    pub fn new(g: Arc<Graph>, k: usize) -> Self {
        let (uedge, num_uedges) = assign_undirected_ids(&g);
        PartitionedGraph {
            part: (0..g.num_nodes()).map(|_| AtomicU32::new(0)).collect(),
            block_weight: (0..k).map(|_| AtomicI64::new(0)).collect(),
            max_block_weight: vec![NodeWeight::MAX; k],
            edge_target: (0..num_uedges).map(|_| AtomicU32::new(UNSET)).collect(),
            uedge,
            num_uedges,
            g,
            k,
        }
    }

    pub fn set_uniform_max_weight(&mut self, eps: f64) {
        let lmax = super::PartitionedHypergraph::max_weight_for(
            self.g.total_weight(),
            self.k,
            eps,
        );
        self.max_block_weight = vec![lmax; self.k];
    }

    pub fn set_max_weights(&mut self, w: Vec<NodeWeight>) {
        assert_eq!(w.len(), self.k);
        self.max_block_weight = w;
    }

    pub fn assign_all(&self, parts: &[BlockId], threads: usize) {
        assert_eq!(parts.len(), self.g.num_nodes());
        for b in &self.block_weight {
            b.store(0, Ordering::Relaxed);
        }
        par_for_auto(self.g.num_nodes(), threads, |u| {
            self.part[u].store(parts[u], Ordering::Relaxed);
            self.block_weight[parts[u] as usize]
                .fetch_add(self.g.node_weight(u as NodeId), Ordering::Relaxed);
        });
        self.reset_edge_sync();
    }

    /// Reset the per-edge CAS array (start of each refinement round).
    pub fn reset_edge_sync(&self) {
        for t in &self.edge_target {
            t.store(UNSET, Ordering::Relaxed);
        }
    }

    #[inline]
    pub fn graph(&self) -> &Graph {
        &self.g
    }

    #[inline]
    pub fn graph_arc(&self) -> Arc<Graph> {
        self.g.clone()
    }

    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    #[inline]
    pub fn block_of(&self, u: NodeId) -> BlockId {
        self.part[u as usize].load(Ordering::Acquire)
    }

    #[inline]
    pub fn block_weight(&self, b: BlockId) -> NodeWeight {
        self.block_weight[b as usize].load(Ordering::Acquire)
    }

    #[inline]
    pub fn max_block_weight(&self, b: BlockId) -> NodeWeight {
        self.max_block_weight[b as usize]
    }

    pub fn parts(&self) -> Vec<BlockId> {
        self.part.iter().map(|p| p.load(Ordering::Acquire)).collect()
    }

    /// Edge-cut gain `g_u(t) = ω(u, V_t) − ω(u, Π[u])` computed on the fly.
    pub fn gain(&self, u: NodeId, to: BlockId) -> Gain {
        let from = self.block_of(u);
        if from == to {
            return 0;
        }
        let mut internal: Gain = 0;
        let mut external: Gain = 0;
        for (v, w) in self.g.neighbors(u) {
            let b = self.block_of(v);
            if b == from {
                internal += w;
            } else if b == to {
                external += w;
            }
        }
        external - internal
    }

    /// Best feasible move among neighbor blocks.
    pub fn max_gain_move(&self, u: NodeId) -> Option<(Gain, BlockId)> {
        let from = self.block_of(u);
        let w = self.g.node_weight(u);
        let mut conn: Vec<(BlockId, Gain)> = Vec::new();
        let mut internal: Gain = 0;
        for (v, ew) in self.g.neighbors(u) {
            let b = self.block_of(v);
            if b == from {
                internal += ew;
            } else if let Some(c) = conn.iter_mut().find(|(cb, _)| *cb == b) {
                c.1 += ew;
            } else {
                conn.push((b, ew));
            }
        }
        let mut best: Option<(Gain, BlockId)> = None;
        for (t, wt) in conn {
            if self.block_weight(t) + w > self.max_block_weight(t) {
                continue;
            }
            let g = wt - internal;
            match best {
                None => best = Some((g, t)),
                Some((bg, bb)) => {
                    if g > bg || (g == bg && self.block_weight(t) < self.block_weight(bb)) {
                        best = Some((g, t));
                    }
                }
            }
        }
        best
    }

    /// Balance-checked move with CAS-synchronized attributed gain
    /// (paper §10.2). Each node may move at most once per round
    /// ([`Self::reset_edge_sync`] starts a new round).
    pub fn try_move(&self, u: NodeId, to: BlockId) -> Option<Gain> {
        let from = self.block_of(u);
        if from == to {
            return None;
        }
        let w = self.g.node_weight(u);
        let new_w = self.block_weight[to as usize].fetch_add(w, Ordering::AcqRel) + w;
        if new_w > self.max_block_weight[to as usize] {
            self.block_weight[to as usize].fetch_sub(w, Ordering::AcqRel);
            return None;
        }
        Some(self.apply_move(u, from, to, w))
    }

    /// Unchecked move (revert paths).
    pub fn move_unchecked(&self, u: NodeId, to: BlockId) -> Gain {
        let from = self.block_of(u);
        debug_assert_ne!(from, to);
        let w = self.g.node_weight(u);
        self.block_weight[to as usize].fetch_add(w, Ordering::AcqRel);
        self.apply_move(u, from, to, w)
    }

    fn apply_move(&self, u: NodeId, from: BlockId, to: BlockId, w: NodeWeight) -> Gain {
        let mut gain: Gain = 0;
        let base = self.g.offsets[u as usize] as usize;
        for (i, (v, ew)) in self.g.neighbors(u).enumerate() {
            let e = self.uedge[base + i] as usize;
            let prev = self.edge_target[e].compare_exchange(
                UNSET,
                to,
                Ordering::AcqRel,
                Ordering::Acquire,
            );
            // the block the other endpoint is (or will be) in
            let other = match prev {
                Ok(_) => self.block_of(v), // we won: neighbor not moving yet
                Err(t) => t,               // neighbor (first mover) targets t
            };
            // attributed delta for this edge relative to our own move
            if other == to && other != from {
                gain += ew; // edge becomes internal
            } else if other == from && other != to {
                gain -= ew; // edge becomes cut
            }
        }
        // paper: block id updated after gain attribution
        self.part[u as usize].store(to, Ordering::Release);
        self.block_weight[from as usize].fetch_sub(w, Ordering::AcqRel);
        gain
    }

    /// Edge-cut metric.
    pub fn cut(&self) -> i64 {
        let mut cut = 0;
        for u in self.g.nodes() {
            let bu = self.block_of(u);
            for (v, w) in self.g.neighbors(u) {
                if u < v && self.block_of(v) != bu {
                    cut += w;
                }
            }
        }
        cut
    }

    /// Imbalance against the same ⌈c(V)/k⌉ reference the `L_max` limits
    /// use (mirrors `PartitionedHypergraph::imbalance`).
    pub fn imbalance(&self) -> f64 {
        let per =
            super::PartitionedHypergraph::reference_block_weight(self.g.total_weight(), self.k);
        (0..self.k as BlockId)
            .map(|b| self.block_weight(b) as f64 / per - 1.0)
            .fold(-1.0, f64::max)
    }

    pub fn is_balanced(&self) -> bool {
        (0..self.k as BlockId).all(|b| self.block_weight(b) <= self.max_block_weight(b))
    }

    pub fn is_border(&self, u: NodeId) -> bool {
        let b = self.block_of(u);
        self.g.neighbors(u).any(|(v, _)| self.block_of(v) != b)
    }

    pub fn verify_consistency(&self) -> Result<(), String> {
        let mut bw = vec![0 as NodeWeight; self.k];
        for u in self.g.nodes() {
            let b = self.block_of(u) as usize;
            if b >= self.k {
                return Err(format!("invalid block for node {u}"));
            }
            bw[b] += self.g.node_weight(u);
        }
        for b in 0..self.k {
            if bw[b] != self.block_weight(b as BlockId) {
                return Err(format!("block {b} weight mismatch"));
            }
        }
        Ok(())
    }

    /// Number of undirected edges (size of the `B` array).
    pub fn num_undirected_edges(&self) -> usize {
        self.num_uedges
    }
}

/// Pair up the two directed slots of every undirected edge.
fn assign_undirected_ids(g: &Graph) -> (Vec<u32>, usize) {
    // (min, max, slot) sorted → identical (min,max) pairs adjacent.
    // Parallel edges (same endpoints) pair arbitrarily among themselves,
    // which is fine: each still gets a unique undirected id.
    let mut keyed: Vec<(NodeId, NodeId, u32)> = Vec::with_capacity(g.num_edges());
    for u in g.nodes() {
        let base = g.offsets[u as usize] as usize;
        for (i, (v, _)) in g.neighbors(u).enumerate() {
            keyed.push((u.min(v), u.max(v), (base + i) as u32));
        }
    }
    keyed.sort_unstable();
    let mut uedge = vec![0u32; g.num_edges()];
    let mut next = 0u32;
    let mut i = 0;
    while i < keyed.len() {
        debug_assert!(i + 1 < keyed.len(), "unpaired directed edge");
        uedge[keyed[i].2 as usize] = next;
        uedge[keyed[i + 1].2 as usize] = next;
        next += 1;
        i += 2;
    }
    (uedge, next as usize)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ring(n: usize) -> Arc<Graph> {
        let edges: Vec<(NodeId, NodeId, i64)> =
            (0..n).map(|i| (i as NodeId, ((i + 1) % n) as NodeId, 1)).collect();
        Arc::new(Graph::from_edges(n, &edges, None))
    }

    fn setup(parts: &[BlockId], k: usize) -> PartitionedGraph {
        let mut pg = PartitionedGraph::new(ring(parts.len()), k);
        pg.set_uniform_max_weight(1.0);
        pg.assign_all(parts, 1);
        pg
    }

    #[test]
    fn uedge_ids_pair_up() {
        let g = ring(6);
        let (uedge, n) = assign_undirected_ids(&g);
        assert_eq!(n, 6);
        let mut counts = vec![0; n];
        for &e in &uedge {
            counts[e as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 2));
    }

    #[test]
    fn cut_and_gain() {
        // ring of 8, split in contiguous halves: cut = 2
        let pg = setup(&[0, 0, 0, 0, 1, 1, 1, 1], 2);
        assert_eq!(pg.cut(), 2);
        // node 3 borders block 1; moving it: edge (3,4) internal, (2,3) cut
        assert_eq!(pg.gain(3, 1), 0);
        assert!(pg.is_border(3));
        assert!(!pg.is_border(1));
        pg.verify_consistency().unwrap();
    }

    #[test]
    fn attributed_gain_matches_cut_delta_sequential() {
        let pg = setup(&[0, 1, 0, 1, 0, 1, 0, 1], 2);
        let mut cut = pg.cut();
        let mut rng = crate::util::Rng::new(8);
        let mut moved = vec![false; 8];
        for _ in 0..20 {
            let u = rng.next_below(8) as NodeId;
            if moved[u as usize] {
                continue;
            }
            let to = 1 - pg.block_of(u);
            let expected = pg.gain(u, to);
            if let Some(g) = pg.try_move(u, to) {
                moved[u as usize] = true;
                assert_eq!(g, expected);
                cut -= g;
                assert_eq!(pg.cut(), cut);
            }
        }
        pg.verify_consistency().unwrap();
    }

    #[test]
    fn concurrent_moves_once_per_node_sum_exactly() {
        // each node moved at most once; attributed gains must sum to the
        // total cut change (the CAS array makes both endpoints agree)
        for trial in 0..10u64 {
            let pg = setup(&[0, 1, 0, 1, 0, 1, 0, 1], 2);
            let before = pg.cut();
            let total = AtomicI64::new(0);
            let claimed: Vec<std::sync::atomic::AtomicBool> =
                (0..8).map(|_| std::sync::atomic::AtomicBool::new(false)).collect();
            std::thread::scope(|s| {
                for t in 0..4u64 {
                    let pg = &pg;
                    let total = &total;
                    let claimed = &claimed;
                    s.spawn(move || {
                        let mut rng = crate::util::Rng::new(trial * 31 + t);
                        for _ in 0..6 {
                            let u = rng.next_below(8);
                            if claimed[u].swap(true, Ordering::SeqCst) {
                                continue;
                            }
                            let to = 1 - pg.block_of(u as NodeId);
                            if let Some(g) = pg.try_move(u as NodeId, to) {
                                total.fetch_add(g, Ordering::Relaxed);
                            }
                        }
                    });
                }
            });
            assert_eq!(before - total.load(Ordering::Relaxed), pg.cut(), "trial {trial}");
            pg.verify_consistency().unwrap();
        }
    }

    #[test]
    fn balance_rejection() {
        let mut pg = PartitionedGraph::new(ring(4), 2);
        pg.set_max_weights(vec![2, 2]);
        pg.assign_all(&[0, 0, 1, 1], 1);
        assert!(pg.try_move(0, 1).is_none());
        assert_eq!(pg.block_weight(1), 2);
        pg.verify_consistency().unwrap();
    }
}
