//! Addressable max-priority queue for localized FM (paper §7).
//!
//! Stores at most one entry per node, keyed by the node's current best
//! move gain; supports `insert`, `pop_max`, `adjust` (increase or decrease
//! key) and `contains` in O(log n) via a binary heap with a position index.

use crate::util::fxhash::FxHashMap;
use crate::{Gain, NodeId};

/// Max-heap keyed by `(gain, tiebreak)` with per-node addressability.
#[derive(Default)]
pub struct AddressablePQ {
    heap: Vec<(Gain, NodeId)>,
    pos: FxHashMap<NodeId, usize>,
}

impl AddressablePQ {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    #[inline]
    pub fn contains(&self, u: NodeId) -> bool {
        self.pos.contains_key(&u)
    }

    #[inline]
    pub fn key_of(&self, u: NodeId) -> Option<Gain> {
        self.pos.get(&u).map(|&i| self.heap[i].0)
    }

    /// Insert `u` with key `g`; if present, adjusts instead.
    pub fn insert(&mut self, u: NodeId, g: Gain) {
        if let Some(&i) = self.pos.get(&u) {
            let old = self.heap[i].0;
            self.heap[i].0 = g;
            if g > old {
                self.sift_up(i);
            } else {
                self.sift_down(i);
            }
            return;
        }
        self.heap.push((g, u));
        let i = self.heap.len() - 1;
        self.pos.insert(u, i);
        self.sift_up(i);
    }

    /// Change the key of an existing entry (no-op if absent).
    pub fn adjust(&mut self, u: NodeId, g: Gain) {
        if self.contains(u) {
            self.insert(u, g);
        }
    }

    /// Remove and return the max-gain entry.
    pub fn pop_max(&mut self) -> Option<(NodeId, Gain)> {
        if self.heap.is_empty() {
            return None;
        }
        let (g, u) = self.heap[0];
        self.remove_at(0);
        Some((u, g))
    }

    /// Peek at the max entry.
    pub fn peek(&self) -> Option<(NodeId, Gain)> {
        self.heap.first().map(|&(g, u)| (u, g))
    }

    /// Remove a specific node.
    pub fn remove(&mut self, u: NodeId) {
        if let Some(&i) = self.pos.get(&u) {
            self.remove_at(i);
        }
    }

    pub fn clear(&mut self) {
        self.heap.clear();
        self.pos.clear();
    }

    fn remove_at(&mut self, i: usize) {
        let last = self.heap.len() - 1;
        self.pos.remove(&self.heap[i].1);
        if i != last {
            self.heap.swap(i, last);
            self.pos.insert(self.heap[i].1, i);
            self.heap.pop();
            // restore heap order at i
            if i > 0 && self.heap[i].0 > self.heap[(i - 1) / 2].0 {
                self.sift_up(i);
            } else {
                self.sift_down(i);
            }
        } else {
            self.heap.pop();
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let p = (i - 1) / 2;
            if self.heap[i].0 <= self.heap[p].0 {
                break;
            }
            self.swap(i, p);
            i = p;
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut m = i;
            if l < self.heap.len() && self.heap[l].0 > self.heap[m].0 {
                m = l;
            }
            if r < self.heap.len() && self.heap[r].0 > self.heap[m].0 {
                m = r;
            }
            if m == i {
                break;
            }
            self.swap(i, m);
            i = m;
        }
    }

    #[inline]
    fn swap(&mut self, a: usize, b: usize) {
        self.heap.swap(a, b);
        self.pos.insert(self.heap[a].1, a);
        self.pos.insert(self.heap[b].1, b);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn pops_in_decreasing_order() {
        let mut pq = AddressablePQ::new();
        let mut rng = Rng::new(4);
        for u in 0..200u32 {
            pq.insert(u, rng.next_below(1000) as Gain - 500);
        }
        let mut prev = Gain::MAX;
        while let Some((_, g)) = pq.pop_max() {
            assert!(g <= prev);
            prev = g;
        }
    }

    #[test]
    fn adjust_moves_entries() {
        let mut pq = AddressablePQ::new();
        pq.insert(1, 10);
        pq.insert(2, 20);
        pq.insert(3, 30);
        pq.adjust(1, 100);
        assert_eq!(pq.pop_max(), Some((1, 100)));
        pq.adjust(2, -5);
        assert_eq!(pq.pop_max(), Some((3, 30)));
        assert_eq!(pq.pop_max(), Some((2, -5)));
        assert!(pq.pop_max().is_none());
    }

    #[test]
    fn remove_keeps_heap_valid() {
        let mut pq = AddressablePQ::new();
        for u in 0..50u32 {
            pq.insert(u, (u * 7 % 13) as Gain);
        }
        for u in (0..50u32).step_by(3) {
            pq.remove(u);
        }
        assert!(!pq.contains(3));
        let mut prev = Gain::MAX;
        let mut count = 0;
        while let Some((_, g)) = pq.pop_max() {
            assert!(g <= prev);
            prev = g;
            count += 1;
        }
        assert_eq!(count, 50 - 17);
    }

    #[test]
    fn randomized_against_reference() {
        let mut rng = Rng::new(77);
        let mut pq = AddressablePQ::new();
        let mut reference: FxHashMap<NodeId, Gain> = FxHashMap::default();
        for _ in 0..2000 {
            match rng.next_below(4) {
                0 => {
                    let u = rng.next_below(100) as NodeId;
                    let g = rng.next_below(50) as Gain;
                    pq.insert(u, g);
                    reference.insert(u, g);
                }
                1 => {
                    if let Some((u, g)) = pq.pop_max() {
                        let max = reference.values().max().copied().unwrap();
                        assert_eq!(g, max);
                        assert_eq!(reference.remove(&u), Some(g));
                    } else {
                        assert!(reference.is_empty());
                    }
                }
                2 => {
                    let u = rng.next_below(100) as NodeId;
                    let g = rng.next_below(50) as Gain;
                    pq.adjust(u, g);
                    if let std::collections::hash_map::Entry::Occupied(mut e) = reference.entry(u)
                    {
                        e.insert(g);
                    }
                }
                _ => {
                    let u = rng.next_below(100) as NodeId;
                    pq.remove(u);
                    reference.remove(&u);
                }
            }
            assert_eq!(pq.len(), reference.len());
        }
    }
}
