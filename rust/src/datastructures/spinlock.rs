//! Arrays of tiny spin locks.
//!
//! The paper (§6.1) protects the packed pin-count values of each net with
//! a per-net spin lock; the n-level dynamic hypergraph (§9) uses per-net
//! and per-node locks for pin-list edits and contraction-forest updates.

use std::sync::atomic::{AtomicBool, Ordering};

/// `n` independent spin locks addressable by index.
#[derive(Debug)]
pub struct SpinLockVec {
    flags: Vec<AtomicBool>,
}

impl SpinLockVec {
    pub fn new(n: usize) -> Self {
        SpinLockVec { flags: (0..n).map(|_| AtomicBool::new(false)).collect() }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.flags.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.flags.is_empty()
    }

    /// Acquire lock `i` (test-and-test-and-set with spin hint).
    #[inline]
    pub fn lock(&self, i: usize) {
        let f = &self.flags[i];
        loop {
            if !f.swap(true, Ordering::Acquire) {
                return;
            }
            while f.load(Ordering::Relaxed) {
                std::hint::spin_loop();
            }
        }
    }

    /// Try to acquire lock `i`; true on success.
    #[inline]
    pub fn try_lock(&self, i: usize) -> bool {
        !self.flags[i].swap(true, Ordering::Acquire)
    }

    #[inline]
    pub fn unlock(&self, i: usize) {
        self.flags[i].store(false, Ordering::Release);
    }

    /// Run `f` while holding lock `i`.
    #[inline]
    pub fn with<T>(&self, i: usize, f: impl FnOnce() -> T) -> T {
        self.lock(i);
        let out = f();
        self.unlock(i);
        out
    }

    /// Lock two indices in canonical order (deadlock-free pairwise lock).
    #[inline]
    pub fn lock_pair(&self, a: usize, b: usize) {
        if a == b {
            self.lock(a);
        } else if a < b {
            self.lock(a);
            self.lock(b);
        } else {
            self.lock(b);
            self.lock(a);
        }
    }

    #[inline]
    pub fn unlock_pair(&self, a: usize, b: usize) {
        if a == b {
            self.unlock(a);
        } else {
            self.unlock(a);
            self.unlock(b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mutual_exclusion() {
        let locks = SpinLockVec::new(4);
        let mut counters = vec![0u64; 4];
        {
            let c = crate::parallel::SharedSlice::new(&mut counters);
            std::thread::scope(|s| {
                for _ in 0..4 {
                    let locks = &locks;
                    let c = &c;
                    s.spawn(move || {
                        for i in 0..4000 {
                            let idx = i % 4;
                            locks.with(idx, || unsafe {
                                let v = *c.read(idx);
                                *c.get_mut(idx) = v + 1;
                            });
                        }
                    });
                }
            });
        }
        assert_eq!(counters, vec![4000; 4]);
    }

    #[test]
    fn try_lock_contended() {
        let locks = SpinLockVec::new(1);
        assert!(locks.try_lock(0));
        assert!(!locks.try_lock(0));
        locks.unlock(0);
        assert!(locks.try_lock(0));
        locks.unlock(0);
    }

    #[test]
    fn pairwise_order_independent() {
        let locks = SpinLockVec::new(8);
        locks.lock_pair(5, 2);
        locks.unlock_pair(5, 2);
        locks.lock_pair(3, 3);
        locks.unlock_pair(3, 3);
    }
}
