//! Fixed-capacity linear-probing rating table (paper §4.1).
//!
//! "To aggregate ratings, we use fixed-capacity linear probing hash tables
//! with 2^15 entries and resort to a larger hash table if the fill ratio
//! exceeds 1/3 of the capacity." Clearing is O(#used) via a dirty list, so
//! a thread-local table can be reused across millions of nodes.

use crate::util::rng::hash2;

const EMPTY: u64 = u64::MAX;

/// Open-addressing map from `u64` keys to an `f64` accumulator plus an
/// auxiliary `u64` payload, with power-of-two capacity.
pub struct RatingMap {
    keys: Vec<u64>,
    vals: Vec<f64>,
    aux: Vec<u64>,
    dirty: Vec<usize>,
    mask: usize,
}

impl RatingMap {
    /// Paper default: 2^15 entries.
    pub const DEFAULT_CAPACITY: usize = 1 << 15;

    pub fn new(capacity: usize) -> Self {
        let cap = capacity.next_power_of_two().max(16);
        RatingMap {
            keys: vec![EMPTY; cap],
            vals: vec![0.0; cap],
            aux: vec![0; cap],
            dirty: Vec::new(),
            mask: cap - 1,
        }
    }

    pub fn with_default_capacity() -> Self {
        Self::new(Self::DEFAULT_CAPACITY)
    }

    #[inline]
    pub fn capacity(&self) -> usize {
        self.keys.len()
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.dirty.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.dirty.is_empty()
    }

    /// True once the fill ratio exceeds 1/3 — caller should migrate to a
    /// table of twice the size (paper's growth rule).
    #[inline]
    pub fn should_grow(&self) -> bool {
        self.dirty.len() * 3 > self.capacity()
    }

    #[inline]
    fn slot(&self, key: u64) -> usize {
        let mut i = (hash2(key, 0x9E37_79B9) as usize) & self.mask;
        loop {
            let k = self.keys[i];
            if k == key || k == EMPTY {
                return i;
            }
            i = (i + 1) & self.mask;
        }
    }

    /// Add `delta` to the rating of `key`.
    #[inline]
    pub fn add(&mut self, key: u64, delta: f64) {
        let i = self.slot(key);
        if self.keys[i] == EMPTY {
            self.keys[i] = key;
            self.vals[i] = 0.0;
            self.aux[i] = 0;
            self.dirty.push(i);
        }
        self.vals[i] += delta;
    }

    /// Add `delta` to rating and `a` to the auxiliary accumulator.
    #[inline]
    pub fn add_with_aux(&mut self, key: u64, delta: f64, a: u64) {
        let i = self.slot(key);
        if self.keys[i] == EMPTY {
            self.keys[i] = key;
            self.vals[i] = 0.0;
            self.aux[i] = 0;
            self.dirty.push(i);
        }
        self.vals[i] += delta;
        self.aux[i] += a;
    }

    #[inline]
    pub fn get(&self, key: u64) -> Option<f64> {
        let i = self.slot(key);
        if self.keys[i] == EMPTY {
            None
        } else {
            Some(self.vals[i])
        }
    }

    /// Iterate over `(key, rating, aux)` of all used entries
    /// (insertion order).
    pub fn iter(&self) -> impl Iterator<Item = (u64, f64, u64)> + '_ {
        self.dirty.iter().map(move |&i| (self.keys[i], self.vals[i], self.aux[i]))
    }

    /// O(#used) clear.
    pub fn clear(&mut self) {
        for &i in &self.dirty {
            self.keys[i] = EMPTY;
        }
        self.dirty.clear();
    }

    /// Grow to twice the capacity, preserving entries.
    pub fn grow(&mut self) {
        let entries: Vec<(u64, f64, u64)> = self.iter().collect();
        *self = RatingMap::new(self.capacity() * 2);
        for (k, v, a) in entries {
            self.add_with_aux(k, v, a);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use crate::util::fxhash::FxHashMap;

    #[test]
    fn accumulates_like_hashmap() {
        let mut rm = RatingMap::new(64);
        let mut reference: FxHashMap<u64, f64> = FxHashMap::default();
        let mut rng = Rng::new(11);
        for _ in 0..500 {
            if rm.should_grow() {
                rm.grow();
            }
            let k = rng.next_below(40) as u64;
            let d = rng.next_f64();
            rm.add(k, d);
            *reference.entry(k).or_default() += d;
        }
        assert_eq!(rm.len(), reference.len());
        for (k, v) in &reference {
            assert!((rm.get(*k).unwrap() - v).abs() < 1e-9);
        }
    }

    #[test]
    fn clear_is_complete() {
        let mut rm = RatingMap::new(16);
        rm.add(1, 1.0);
        rm.add(2, 2.0);
        rm.clear();
        assert!(rm.is_empty());
        assert!(rm.get(1).is_none());
        rm.add(1, 3.0);
        assert_eq!(rm.get(1), Some(3.0));
    }

    #[test]
    fn aux_accumulates() {
        let mut rm = RatingMap::new(16);
        rm.add_with_aux(7, 0.5, 2);
        rm.add_with_aux(7, 0.25, 3);
        let all: Vec<_> = rm.iter().collect();
        assert_eq!(all.len(), 1);
        assert_eq!(all[0].0, 7);
        assert!((all[0].1 - 0.75).abs() < 1e-12);
        assert_eq!(all[0].2, 5);
    }

    #[test]
    fn grow_preserves() {
        let mut rm = RatingMap::new(16);
        for k in 0..10u64 {
            rm.add(k, k as f64);
        }
        rm.grow();
        assert_eq!(rm.capacity(), 32);
        for k in 0..10u64 {
            assert_eq!(rm.get(k), Some(k as f64));
        }
    }
}
