//! Specialized containers backing the hot paths:
//!
//! * [`RatingMap`] — the fixed-capacity linear-probing hash table used to
//!   aggregate heavy-edge ratings (paper §4.1: 2¹⁵ entries, grow at ⅓ fill),
//! * [`SpinLockVec`] — one spin lock per net for packed pin-count updates
//!   (paper §6.1 data layout),
//! * [`AddressablePQ`] — the per-search priority queue of localized FM
//!   (max-gain with decrease/increase-key),
//! * [`ConcurrentQueue`] — the FIFO used by FM's seed task queue and the
//!   active-block scheduler of flow refinement.

pub mod pq;
pub mod queue;
pub mod rating_map;
pub mod spinlock;

pub use pq::AddressablePQ;
pub use queue::ConcurrentQueue;
pub use rating_map::RatingMap;
pub use spinlock::SpinLockVec;
