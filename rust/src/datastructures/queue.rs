//! A simple multi-producer multi-consumer FIFO.
//!
//! Backs the FM seed task queue ("poll 25 seed nodes", paper §7).
//! Contention is at task granularity, so a mutexed ring is the right
//! complexity/perf spot. (The flow scheduler of §8.1 keeps its own wave
//! queue inside the refinement workspace — see `refinement::flow`.)

use std::collections::VecDeque;
use std::sync::Mutex;

#[derive(Debug, Default)]
pub struct ConcurrentQueue<T> {
    inner: Mutex<VecDeque<T>>,
}

impl<T> ConcurrentQueue<T> {
    pub fn new() -> Self {
        ConcurrentQueue { inner: Mutex::new(VecDeque::new()) }
    }

    pub fn from_iter(items: impl IntoIterator<Item = T>) -> Self {
        ConcurrentQueue { inner: Mutex::new(items.into_iter().collect()) }
    }

    pub fn push(&self, item: T) {
        self.inner.lock().unwrap().push_back(item);
    }

    pub fn pop(&self) -> Option<T> {
        self.inner.lock().unwrap().pop_front()
    }

    /// Pop up to `n` items in one lock acquisition (FM's batched seed poll).
    pub fn pop_many(&self, n: usize) -> Vec<T> {
        let mut q = self.inner.lock().unwrap();
        let take = n.min(q.len());
        q.drain(..take).collect()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let q = ConcurrentQueue::new();
        q.push(1);
        q.push(2);
        q.push(3);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop_many(2), vec![2, 3]);
        assert!(q.pop().is_none());
    }

    #[test]
    fn concurrent_drain_is_complete() {
        let q = ConcurrentQueue::from_iter(0..10_000);
        let seen = std::sync::Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let q = &q;
                let seen = &seen;
                s.spawn(move || {
                    let mut local = Vec::new();
                    while let Some(x) = q.pop() {
                        local.push(x);
                    }
                    seen.lock().unwrap().extend(local);
                });
            }
        });
        let mut all = seen.into_inner().unwrap();
        all.sort_unstable();
        assert_eq!(all, (0..10_000).collect::<Vec<_>>());
    }
}
