//! The uncoarsening refinement pipeline (tentpole of the refinement
//! refactor).
//!
//! The multilevel driver used to rebuild an O(n·k) gain table plus
//! per-round owner bits, boundary buffers and per-thread search scratch
//! *from scratch on every level and every FM invocation* — the dominant
//! allocation cost of the uncoarsening phase (paper §6/§7; see the
//! `perf_hotpath` bench entries "gain table per level: …"). This module
//! turns that state into a long-lived [`Workspace`] allocated **once per
//! `partition_arc` call** and carried across all uncoarsening levels:
//! after `project_partition`, the gain table is re-initialized in place
//! for the projected assignment — values are recomputed, memory is not
//! reallocated (coarser levels use a prefix of the finest-level entries).
//!
//! The refinement algorithms plug into the pipeline through the small
//! [`Refiner`] trait; the stack built from a [`Context`] is
//! `rebalance → LP → FM → flows → rebalance`, with the rebalancer acting
//! as the balance-repair fallback on both ends (repair infeasible
//! projected partitions before quality work, guarantee feasibility after).
//! Under `ctx.deterministic` the same stack positions select the
//! synchronous §11 siblings — deterministic LP, deterministic FM
//! ([`fm::deterministic`]) and the single-worker flow schedule — so the
//! `Deterministic` preset runs `rebalance → det-LP → det-FM → rebalance`
//! (plus det-flows when enabled) instead of silently dropping stages.
//!
//! ## Refiner contract
//!
//! A [`Refiner`] is called with a *consistent, bound* partition and the
//! shared [`Workspace`]; it must leave the partition consistent (Π/Φ/Λ
//! in sync, Lemma 6.1) and account its returned gain exactly against
//! `km1`. Scratch ownership: a refiner may use any workspace buffer
//! during its `refine` call but must not assume state survives from a
//! previous call — the gain table is only valid if the refiner
//! (re-)initializes it, ownership bits must be left all-clear, and the
//! shared `DetScratch`/`LpScratch`/flow buffers are reset by their users.
//! Level gating: [`RefinementPipeline::refine_at_distance`] records the
//! current level's distance from the finest in `Workspace::level_distance`
//! *before* running the stack; level-aware refiners (flows, §8.1 cost
//! model) read it and return 0 without touching their state when gated.
//!
//! ## Pooled partition lifecycle
//!
//! Beyond the gain table, the workspace owns a [`PartitionPool`]: one
//! finest-level-sized allocation of the §6.1 partition state (Π atomics,
//! block weights, packed pin counts, connectivity bitsets, net locks).
//! Drivers built with [`RefinementPipeline::new_for`] reserve that
//! capacity up front, [`RefinementPipeline::bind`] the coarsest level,
//! then [`RefinementPipeline::project_to_level`] per uncoarsening step —
//! which moves the *same memory* to the finer hypergraph, projects Π
//! through the contraction mapping in place and repairs Φ/Λ per net from
//! the contraction's fine→coarse net map (dropped nets reset in O(1),
//! survivors recounted locally; a full parallel value rebuild remains
//! the fallback when no net map is available). Memory ownership
//! alternates between the pool (between levels) and the bound
//! `PartitionedHypergraph` (during refinement); the finest binding is
//! simply returned to the caller. Memory is allocated once.
//!
//! The n-level driver uses the value-preserving half of the pool API
//! instead: [`RefinementPipeline::park`] releases the bound buffers so
//! the driver can mutate the sole-owner `DynamicHypergraph` in place,
//! [`RefinementPipeline::unpark`] re-binds the identical values, and the
//! batch delta is repaired incrementally via `apply_uncontractions` — no
//! value rebuild at any batch boundary (see
//! [`PartitionPool::value_rebuilds`]). The final
//! [`RefinementPipeline::rebind_preserving`] hands the finished values to
//! the static input representation for the flow-capable finest-level
//! stack.
//!
//! ## Flow-scratch lifecycle
//!
//! Flow refinement (paper §8) runs on the workspace's
//! [`FlowWorkspace`](crate::refinement::flow::FlowWorkspace): one
//! [`FlowScratch`](crate::refinement::flow::FlowScratch) slot per flow
//! worker (τ·k-capped, §8.1) holding the Lawler flow network, the
//! push-relabel/FlowCutter working state and the generation-stamped
//! region buffers, plus the incremental quotient graph and the
//! active-pair wave buffers. Slots are created lazily on the first
//! `flow_refine` call and sized to the level's node/net counts; because
//! coarser levels address a prefix of the finest level's dimensions, a
//! whole uncoarsening sequence sizes each slot at most once — every
//! later call reuses the memory (`FlowWorkspace::structural_allocs` stays
//! constant, asserted in tests and the `perf_hotpath` "flow refinement"
//! bench pair). The quotient graph is rebuilt from the connectivity sets
//! once per call and then maintained incrementally from applied moves;
//! [`RefinementPipeline::refine_at_distance`] records each level's
//! distance from the finest so flows run only on the
//! `ctx.flow_finest_levels` finest levels (§8.1's cost model).

use crate::coarsening::Level;
use crate::coordinator::context::Context;
use crate::datastructures::AddressablePQ;
use crate::graph::Graph;
use crate::hypergraph::{Hypergraph, HypergraphOps};
use crate::partition::{
    resolve_kstate, GainTable, HgState, KStateChoice, KStateMode, Move, PartitionPool,
    PartitionState, PartitionedHypergraph,
};
use crate::refinement::fm::{DeltaPartition, FmStats};
use crate::refinement::{flow, fm, lp, rebalance};
use crate::util::{Bitset, DegradationLevel};
use crate::{BlockId, Gain, NodeId};
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Per-thread localized-FM search scratch, reused across seed batches,
/// rounds *and* uncoarsening levels (hash tables and vectors keep their
/// capacity between uses).
pub struct SearchScratch {
    pub(crate) delta: DeltaPartition,
    pub(crate) pq: AddressablePQ,
    /// membership bitset over `moved_list` — replaces the former
    /// O(moves²) `Vec::contains` scan in the ownership-release path
    pub(crate) moved_bits: Bitset,
    pub(crate) acquired: Vec<NodeId>,
    pub(crate) moved_list: Vec<NodeId>,
    pub(crate) local_moves: Vec<Move>,
}

impl SearchScratch {
    fn new(k: usize, node_capacity: usize) -> Self {
        SearchScratch {
            delta: DeltaPartition::new(k),
            pq: AddressablePQ::new(),
            moved_bits: Bitset::new(node_capacity),
            acquired: Vec::new(),
            moved_list: Vec::new(),
            local_moves: Vec::new(),
        }
    }
}

/// The long-lived refinement state: one allocation per `partition_arc`
/// call, shared by every level and every refiner of the pipeline.
///
/// Generic over the [`PartitionState`] of the structures it refines:
/// the hypergraph drivers use the default `Workspace<HgState>` (gain
/// table + Φ/Λ pool, dense or sparse layout per
/// [`resolve_kstate`]), the plain-graph driver uses
/// `Workspace<TwoPinState>` — same scratch, same pool discipline, but
/// the §6.2 gain table stays empty (`USE_GAIN_TABLE = false`: two-pin
/// gains are a single adjacency scan, a table would only add
/// maintenance cost).
pub struct Workspace<S: PartitionState = HgState> {
    pub(crate) k: usize,
    pub(crate) gain_table: GainTable,
    /// FM node-ownership bits (one per node of the finest level)
    pub(crate) owner: Vec<AtomicBool>,
    pub(crate) scratch: Vec<SearchScratch>,
    /// reusable boundary-seed buffer
    pub(crate) boundary: Vec<NodeId>,
    /// reusable label-propagation scratch (visit order + frontier churn)
    pub(crate) lp: lp::LpScratch,
    /// shared scratch of the synchronous deterministic refiners (§11):
    /// sub-round membership, move wishlist, det-FM move log and the
    /// per-pair prefix-selection buffers
    pub(crate) det: crate::refinement::DetScratch,
    /// reusable Algorithm-6.2 scratch (per-node move index + processed-net
    /// bitset, reset sparsely) so seeded n-level FM rounds stay O(region)
    pub(crate) recalc: crate::partition::gain_recalculation::RecalcScratch,
    /// pooled §6.1 partition state rebound across uncoarsening levels
    pub(crate) pool: PartitionPool<S>,
    /// pooled flow-refinement state (per-worker scratch slots, incremental
    /// quotient graph, scheduler wave buffers)
    pub(crate) flow: flow::FlowWorkspace,
    /// distance of the currently refined level from the finest (0 =
    /// finest); set by [`RefinementPipeline::refine_at_distance`] so the
    /// flow refiner can honor the §8.1 cost model (flows only on the
    /// finest levels)
    pub(crate) level_distance: usize,
    /// set by FM/flow invocations whose scoped worker threads panicked
    /// (the worker itself is isolated by `catch_unwind`); the pipeline
    /// consumes it to poison the refiner and trigger the repair path
    pub(crate) worker_panic: bool,
    gain_table_inits: usize,
    gain_table_allocs: usize,
}

impl<S: PartitionState> Workspace<S> {
    /// Allocate a workspace for partitions with `k` blocks, up to
    /// `node_capacity` nodes and `threads` worker threads, in the
    /// auto-selected state/gain-table layout for `k`.
    pub fn new(k: usize, threads: usize, node_capacity: usize) -> Self {
        Self::with_mode(k, threads, node_capacity, resolve_kstate(KStateChoice::Auto, k))
    }

    /// [`Self::new`] with an explicit dense/sparse layout choice — the
    /// pooled partition state and the §6.2 gain table use matching
    /// layouts (`--kstate`).
    pub fn with_mode(k: usize, threads: usize, node_capacity: usize, mode: KStateMode) -> Self {
        let threads = threads.max(1);
        // states that never consult the §6.2 table (two-pin graphs) get a
        // zero-row table; the growth path below is gated the same way
        let table_capacity = if S::USE_GAIN_TABLE { node_capacity } else { 0 };
        Workspace {
            k,
            gain_table: GainTable::with_mode(table_capacity, k, mode),
            owner: (0..node_capacity).map(|_| AtomicBool::new(false)).collect(),
            scratch: (0..threads).map(|_| SearchScratch::new(k, node_capacity)).collect(),
            boundary: Vec::new(),
            lp: lp::LpScratch::default(),
            det: crate::refinement::DetScratch::default(),
            recalc: crate::partition::gain_recalculation::RecalcScratch::default(),
            pool: PartitionPool::with_mode(k, mode),
            flow: flow::FlowWorkspace::new(k),
            level_distance: 0,
            worker_panic: false,
            gain_table_inits: 0,
            gain_table_allocs: 1,
        }
    }

    /// Read and reset the worker-panic flag (one pipeline stage's verdict).
    pub(crate) fn take_worker_panic(&mut self) -> bool {
        std::mem::take(&mut self.worker_panic)
    }

    /// Reserve the partition pool for the finest-level hypergraph so the
    /// whole uncoarsening sequence runs on one structural allocation.
    pub fn reserve_partition<H: HypergraphOps>(&mut self, hg: &H) {
        self.pool.reserve(hg);
    }

    /// Grow node-indexed state to `n` entries (no-op when the finest-level
    /// capacity already covers it — the common case in uncoarsening).
    pub fn ensure_node_capacity(&mut self, n: usize) {
        if S::USE_GAIN_TABLE && self.gain_table.ensure_node_capacity(n) {
            self.gain_table_allocs += 1;
        }
        if n > self.owner.len() {
            let old = self.owner.len();
            self.owner.extend((old..n).map(|_| AtomicBool::new(false)));
        }
        for sc in &mut self.scratch {
            sc.moved_bits.ensure_len(n);
        }
    }

    /// Make sure at least `threads` scratch slots exist.
    pub fn ensure_threads(&mut self, threads: usize) {
        let cap = self.owner.len();
        while self.scratch.len() < threads.max(1) {
            self.scratch.push(SearchScratch::new(self.k, cap));
        }
    }

    /// Recompute the gain table in place for the current assignment of
    /// `phg` (per-level repair after projection: values change, memory
    /// does not).
    pub fn prepare_gain_table<H: HypergraphOps<State = S>>(
        &mut self,
        phg: &PartitionedHypergraph<H>,
        threads: usize,
    ) {
        self.prepare_gain_table_p::<crate::partition::Km1Policy, H>(phg, threads);
    }

    /// [`Self::prepare_gain_table`] for an arbitrary
    /// [`GainPolicy`](crate::partition::GainPolicy): the table's
    /// benefit/penalty terms are filled with the policy's contribution
    /// rules.
    pub fn prepare_gain_table_p<P: crate::partition::GainPolicy, H: HypergraphOps<State = S>>(
        &mut self,
        phg: &PartitionedHypergraph<H>,
        threads: usize,
    ) {
        debug_assert_eq!(phg.k(), self.k);
        if !S::USE_GAIN_TABLE {
            return;
        }
        self.ensure_node_capacity(phg.hypergraph().num_nodes());
        self.gain_table.initialize_p::<P, H>(phg, threads);
        self.gain_table_inits += 1;
    }

    /// Clear the first `n` ownership bits (start of an FM round).
    pub(crate) fn reset_owner(&self, n: usize) {
        for b in &self.owner[..n] {
            b.store(false, Ordering::Relaxed);
        }
    }

    /// The shared gain table (exposed for tests and benches).
    pub fn gain_table(&self) -> &GainTable {
        &self.gain_table
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// How often the gain table was (re-)initialized in place.
    pub fn gain_table_inits(&self) -> usize {
        self.gain_table_inits
    }

    /// How often gain-table memory was allocated (1 = the initial
    /// allocation; stays 1 across an entire uncoarsening sequence).
    pub fn gain_table_allocs(&self) -> usize {
        self.gain_table_allocs
    }

    /// The pooled flow-refinement state (alloc/build counters for tests
    /// and benches).
    pub fn flow_workspace(&self) -> &flow::FlowWorkspace {
        &self.flow
    }
}

/// A refinement algorithm that runs inside the pipeline on the shared
/// [`Workspace`]. Returns the attributed improvement (km1 decrease).
///
/// Contract (see the module-level "Refiner contract" section): the input
/// partition is consistent and stays consistent; the returned gain
/// accounts exactly against `km1`; workspace buffers may be used freely
/// during the call but carry no inter-call guarantees (re-prepare what
/// you need, leave ownership bits all-clear); level-gated refiners read
/// the distance recorded by [`RefinementPipeline::refine_at_distance`]
/// and must return 0 without touching their state when gated.
pub trait Refiner<R: HypergraphOps = Hypergraph>: Send {
    /// Phase-timer name of this refiner.
    fn name(&self) -> &'static str;
    /// Refine `phg` in place using the shared workspace.
    fn refine(
        &mut self,
        phg: &PartitionedHypergraph<R>,
        ws: &mut Workspace<R::State>,
        ctx: &Context,
    ) -> Gain;
    /// Where the degradation ladder sheds this refiner under deadline
    /// pressure. `Never` (the default) marks feasibility stages that must
    /// always run.
    fn shed_class(&self) -> ShedClass {
        ShedClass::Never
    }
}

/// Degradation-ladder classification of a pipeline stage: at which
/// [`DegradationLevel`] the stage is skipped (quality order — flows go
/// first, the rebalancer never goes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedClass {
    /// feasibility stage, runs at every pressure level
    Never,
    /// shed at [`DegradationLevel::SkipFlows`]
    Flows,
    /// capped at [`DegradationLevel::CapFm`], shed at
    /// [`DegradationLevel::LpOnly`]
    Fm,
    /// shed at [`DegradationLevel::RebalanceOnly`]
    Lp,
}

/// Label propagation (parallel or deterministic-synchronous, paper §6.1/§11).
pub struct LpRefiner;

impl<R: HypergraphOps> Refiner<R> for LpRefiner {
    fn name(&self) -> &'static str {
        "label_propagation"
    }

    fn refine(
        &mut self,
        phg: &PartitionedHypergraph<R>,
        ws: &mut Workspace<R::State>,
        ctx: &Context,
    ) -> Gain {
        if ctx.deterministic {
            lp::lp_refine_deterministic_with_scratch(phg, ctx, &mut ws.det)
        } else {
            lp::lp_refine_with_scratch(phg, ctx, &mut ws.lp)
        }
    }

    fn shed_class(&self) -> ShedClass {
        ShedClass::Lp
    }
}

/// Localized FM (paper §7) on the shared gain table, ownership bits and
/// per-thread search scratch — or, under `ctx.deterministic`, the
/// synchronous deterministic FM (§11 frozen gains + prefix selection) on
/// the shared gain table and `DetScratch`.
#[derive(Default)]
pub struct FmRefiner;

impl<R: HypergraphOps> Refiner<R> for FmRefiner {
    fn name(&self) -> &'static str {
        "fm"
    }

    fn refine(
        &mut self,
        phg: &PartitionedHypergraph<R>,
        ws: &mut Workspace<R::State>,
        ctx: &Context,
    ) -> Gain {
        let stats = if ctx.deterministic {
            fm::deterministic::fm_refine_deterministic_with_workspace(phg, ctx, None, ws)
        } else {
            fm::fm_refine_with_workspace(phg, ctx, None, ws)
        };
        stats.improvement
    }

    fn shed_class(&self) -> ShedClass {
        ShedClass::Fm
    }
}

/// Parallel flow-based refinement (paper §8) on the workspace's pooled
/// flow state. Runs only within `ctx.flow_finest_levels` of the finest
/// level (§8.1's cost model: flow problems on coarse levels are small and
/// rarely pay for themselves; the big wins come from the finest levels).
pub struct FlowRefiner;

impl Refiner<Hypergraph> for FlowRefiner {
    fn name(&self) -> &'static str {
        "flows"
    }

    fn refine(
        &mut self,
        phg: &PartitionedHypergraph,
        ws: &mut Workspace,
        ctx: &Context,
    ) -> Gain {
        if ws.level_distance >= ctx.flow_finest_levels.max(1) {
            return 0;
        }
        let gain = flow::flow_refine_with_workspace(phg, ctx, &mut ws.flow);
        if ws.flow.take_worker_panic() {
            ws.worker_panic = true;
        }
        gain
    }

    fn shed_class(&self) -> ShedClass {
        ShedClass::Flows
    }
}

/// Balance repair (the fallback the coordinator historically never
/// invoked): a no-op on balanced partitions, otherwise relocates boundary
/// nodes out of overloaded blocks at minimum connectivity cost. Returns
/// the (usually negative) attributed km1 change.
pub struct RebalanceRefiner;

impl<R: HypergraphOps> Refiner<R> for RebalanceRefiner {
    fn name(&self) -> &'static str {
        "rebalance"
    }

    fn refine(
        &mut self,
        phg: &PartitionedHypergraph<R>,
        _ws: &mut Workspace<R::State>,
        ctx: &Context,
    ) -> Gain {
        if phg.is_balanced() {
            return 0;
        }
        let before = phg.objective_value(ctx.objective);
        rebalance::rebalance(phg, ctx);
        before - phg.objective_value(ctx.objective)
    }
}

/// The per-`partition_arc` refinement pipeline: a [`Workspace`] plus the
/// refiner stack derived from the context's preset. Generic over the
/// refined representation: `RefinementPipeline` (default) drives
/// hypergraph uncoarsening with the full
/// `rebalance → LP → FM → flows → rebalance` stack;
/// [`RefinementPipeline::<Graph>::new_for_graph`] builds the same
/// pipeline over the CSR two-pin state (no flow stage — flows are
/// Λ-set/quotient-graph machinery with no graph counterpart yet).
pub struct RefinementPipeline<R: HypergraphOps = Hypergraph> {
    ws: Workspace<R::State>,
    stack: Vec<Box<dyn Refiner<R>>>,
    /// per-stack-slot poison marks: a refiner whose worker panicked is
    /// taken out of rotation for the rest of the run (the repair path
    /// restores partition consistency; the refiner's own state is suspect)
    poisoned: Vec<bool>,
}

impl RefinementPipeline {
    /// Build the pipeline for `ctx` with capacity for `node_capacity`
    /// nodes (the finest level). Allocates the gain table exactly once.
    pub fn new(ctx: &Context, node_capacity: usize) -> Self {
        let mut stack: Vec<Box<dyn Refiner>> = Vec::new();
        // repair infeasible projected/initial assignments first so the
        // quality refiners start from a feasible partition …
        stack.push(Box::new(RebalanceRefiner));
        stack.push(Box::new(LpRefiner));
        if ctx.use_fm {
            stack.push(Box::new(FmRefiner));
        }
        if ctx.use_flows {
            stack.push(Box::new(FlowRefiner));
        }
        // … and guarantee feasibility on exit (flows/FM preserve balance,
        // but tight ε inputs may still need the fallback)
        stack.push(Box::new(RebalanceRefiner));
        let poisoned = vec![false; stack.len()];
        RefinementPipeline {
            ws: Workspace::with_mode(
                ctx.k,
                ctx.threads,
                node_capacity,
                resolve_kstate(ctx.kstate, ctx.k),
            ),
            stack,
            poisoned,
        }
    }

    /// Build the pipeline for an uncoarsening sequence whose finest level
    /// is `hg`: sizes the gain table, reserves the partition pool *and*
    /// (for flow presets) the flow workspace so every level of the
    /// hierarchy rebinds the same memory.
    pub fn new_for(ctx: &Context, hg: &Hypergraph) -> Self {
        let mut pipeline = Self::new(ctx, hg.num_nodes());
        pipeline.ws.reserve_partition(hg);
        if ctx.use_flows {
            pipeline.ws.flow.reserve(
                flow::flow_workers(ctx, ctx.k),
                hg.num_nodes(),
                hg.num_nets(),
            );
        }
        pipeline
    }

    /// Run the full zero-copy uncoarsening sequence over `levels`
    /// (coarsest → finest): per level, rebind the pooled partition onto
    /// the finer hypergraph (`input_hg` below level 0 — the convention of
    /// [`crate::coarsening::Hierarchy`]) and run the refiner stack.
    /// `phg` must be bound to `levels.last()` (or to `input_hg` when
    /// `levels` is empty) and already refined.
    pub fn uncoarsen(
        &mut self,
        levels: &[Level],
        input_hg: &Arc<Hypergraph>,
        mut phg: PartitionedHypergraph,
        ctx: &Context,
    ) -> PartitionedHypergraph {
        for i in (0..levels.len()).rev() {
            let finer =
                if i == 0 { input_hg.clone() } else { levels[i - 1].coarse.clone() };
            phg = self.project_to_level(
                phg,
                finer,
                &levels[i].fine_to_coarse,
                Some(&levels[i].net_map),
                ctx,
            );
            // after projecting over levels[i] the partition lives on
            // levels[i-1].coarse, i.e. at distance i from the finest level
            self.refine_at_distance(&phg, ctx, i);
        }
        phg
    }
}

impl RefinementPipeline<Graph> {
    /// Build the pipeline for a plain-graph uncoarsening sequence whose
    /// finest level is `g`: the same stack positions as the hypergraph
    /// pipeline minus the flow stage
    /// (`rebalance → LP → (det-)FM → rebalance`), on a
    /// `Workspace<TwoPinState>` whose gain table stays empty and whose
    /// pooled partition buffers hold one endpoint-pair word per
    /// undirected edge instead of packed pin counts + connectivity sets.
    /// Under `ctx.deterministic` the LP/FM slots select the synchronous
    /// §11 siblings exactly as on hypergraphs.
    pub fn new_for_graph(ctx: &Context, g: &Graph) -> Self {
        let mut stack: Vec<Box<dyn Refiner<Graph>>> = Vec::new();
        stack.push(Box::new(RebalanceRefiner));
        stack.push(Box::new(LpRefiner));
        if ctx.use_fm {
            stack.push(Box::new(FmRefiner));
        }
        // no flow stage: flows are Λ-set/quotient-graph machinery with no
        // two-pin specialization yet (see rust/ARCHITECTURE.md)
        stack.push(Box::new(RebalanceRefiner));
        let poisoned = vec![false; stack.len()];
        let mut pipeline = RefinementPipeline {
            ws: Workspace::with_mode(
                ctx.k,
                ctx.threads,
                g.num_nodes(),
                resolve_kstate(ctx.kstate, ctx.k),
            ),
            stack,
            poisoned,
        };
        pipeline.ws.reserve_partition(g);
        pipeline
    }
}

impl<R: HypergraphOps> RefinementPipeline<R> {
    /// Bind the pooled partition state to the coarsest level (static or
    /// dynamic representation).
    pub fn bind<H: HypergraphOps<State = R::State>>(
        &mut self,
        hg: Arc<H>,
        parts: &[BlockId],
        ctx: &Context,
    ) -> PartitionedHypergraph<H> {
        self.ws.pool.bind(hg, parts, ctx.epsilon, ctx.threads)
    }

    /// Re-point the pooled state at `hg` with an explicit assignment
    /// (V-cycle restarts; delta-repaired when `hg` is unchanged).
    pub fn rebind_with_parts<H: HypergraphOps<State = R::State>>(
        &mut self,
        phg: PartitionedHypergraph<H>,
        hg: Arc<H>,
        parts: &[BlockId],
        ctx: &Context,
    ) -> PartitionedHypergraph<H> {
        self.ws.pool.rebind_with_parts(phg, hg, parts, ctx.epsilon, ctx.threads)
    }

    /// Release the bound partition's buffers without touching the values
    /// (n-level batch boundary; see [`crate::partition::PartitionPool::park`]).
    pub fn park<H: HypergraphOps<State = R::State>>(&mut self, phg: PartitionedHypergraph<H>) {
        self.ws.pool.park(phg);
    }

    /// Re-bind the parked buffers to `hg`, values preserved; the caller
    /// repairs the batch delta via `apply_uncontractions`.
    pub fn unpark<H: HypergraphOps<State = R::State>>(
        &mut self,
        hg: Arc<H>,
        ctx: &Context,
    ) -> PartitionedHypergraph<H> {
        self.ws.pool.unpark(hg, ctx.epsilon)
    }

    /// Would [`Self::unpark`] succeed for `hg`? See
    /// [`crate::partition::PartitionPool::parked_fits`].
    pub fn parked_fits<H: HypergraphOps<State = R::State>>(&self, hg: &H) -> bool {
        self.ws.pool.parked_fits(hg)
    }

    /// Reserve pool headroom beyond the bound instance so a stream of
    /// online insertions stays within the first allocation (see
    /// [`crate::partition::PartitionPool::reserve_headroom`]).
    pub fn reserve_headroom(
        &mut self,
        nodes: usize,
        nets: usize,
        net_size: usize,
        pin_budget: usize,
    ) {
        self.ws.pool.reserve_headroom(nodes, nets, net_size, pin_budget);
    }

    /// Re-bind the parked buffers to `hg` with an explicit assignment and
    /// a full value rebuild — the growth-tolerant unpark the
    /// repartitioner falls back to when online mutations outgrew the
    /// parked buffers (see
    /// [`crate::partition::PartitionPool::unpark_with_parts`]).
    pub fn unpark_with_parts<H: HypergraphOps<State = R::State>>(
        &mut self,
        hg: Arc<H>,
        parts: &[BlockId],
        ctx: &Context,
    ) -> PartitionedHypergraph<H> {
        self.ws.pool.unpark_with_parts(hg, parts, ctx.epsilon, ctx.threads)
    }

    /// Move a binding onto a structurally equivalent hypergraph of a
    /// different representation, preserving all values (the n-level
    /// finest-level hand-off from the dynamic structure to the static
    /// input, which the flow-capable refiner stack runs on).
    pub fn rebind_preserving<H1, H2>(
        &mut self,
        phg: PartitionedHypergraph<H1>,
        hg: Arc<H2>,
        ctx: &Context,
    ) -> PartitionedHypergraph<H2>
    where
        H1: HypergraphOps<State = R::State>,
        H2: HypergraphOps<State = R::State>,
    {
        self.ws.pool.rebind_preserving(phg, hg, ctx.epsilon)
    }

    /// One zero-copy uncoarsening step: move the refined coarse partition
    /// onto the finer hypergraph, projecting Π through `fine_to_coarse`
    /// in place (no snapshot, no intermediate assignment vector). A
    /// contraction net map turns the per-level Φ/Λ value rebuild into a
    /// per-net delta repair (see [`PartitionPool::rebind_level`]).
    pub fn project_to_level(
        &mut self,
        coarse: PartitionedHypergraph<R>,
        fine_hg: Arc<R>,
        fine_to_coarse: &[NodeId],
        net_map: Option<&[crate::EdgeId]>,
        ctx: &Context,
    ) -> PartitionedHypergraph<R> {
        self.ws.pool.rebind_level(
            coarse,
            fine_hg,
            fine_to_coarse,
            net_map,
            ctx.epsilon,
            ctx.threads,
        )
    }

    /// Localized label propagation on the shared workspace scratch
    /// (n-level batch refinement, paper §9).
    pub fn lp_localized<H: HypergraphOps<State = R::State>>(
        &mut self,
        phg: &PartitionedHypergraph<H>,
        ctx: &Context,
        nodes: &[NodeId],
    ) -> Gain {
        lp::lp_refine_localized_with_scratch(phg, ctx, nodes, &mut self.ws.lp)
    }

    /// Run the full refiner stack on the finest level's partition
    /// (standalone refinement; equivalent to distance 0).
    pub fn refine(&mut self, phg: &PartitionedHypergraph<R>, ctx: &Context) -> Gain {
        self.refine_at_distance(phg, ctx, 0)
    }

    /// Run the full refiner stack on one level's partition, telling the
    /// level-aware refiners how far from the finest level it sits
    /// (`distance` 0 = finest). Called once per uncoarsening level;
    /// reuses all workspace state.
    pub fn refine_at_distance(
        &mut self,
        phg: &PartitionedHypergraph<R>,
        ctx: &Context,
        distance: usize,
    ) -> Gain {
        debug_assert_eq!(phg.k(), self.ws.k);
        self.ws.ensure_node_capacity(phg.hypergraph().num_nodes());
        self.ws.ensure_threads(ctx.threads);
        self.ws.level_distance = distance;
        let timer = ctx.timer.clone();
        let mut total: Gain = 0;
        for (slot, r) in self.stack.iter_mut().enumerate() {
            if self.poisoned[slot] {
                continue;
            }
            // graceful degradation: shed quality stages as the budget runs
            // out, in quality order; the rebalancer (ShedClass::Never)
            // always runs so the result stays feasible. With no deadline
            // armed `level()` is constant Full and nothing here triggers.
            let level = ctx.cancel.level();
            let class = r.shed_class();
            let shed = match class {
                ShedClass::Never => false,
                ShedClass::Flows => level >= DegradationLevel::SkipFlows,
                ShedClass::Fm => level >= DegradationLevel::LpOnly,
                ShedClass::Lp => level >= DegradationLevel::RebalanceOnly,
            };
            if shed {
                match class {
                    ShedClass::Flows => &ctx.cancel.flows_shed,
                    ShedClass::Fm => &ctx.cancel.fm_shed,
                    _ => &ctx.cancel.lp_shed,
                }
                .fetch_add(1, Ordering::Relaxed);
                continue;
            }
            let capped;
            let rctx = if class == ShedClass::Fm
                && level >= DegradationLevel::CapFm
                && ctx.fm_max_rounds > 1
            {
                ctx.cancel.fm_capped.fetch_add(1, Ordering::Relaxed);
                let mut c = ctx.clone();
                c.fm_max_rounds = 1;
                capped = c;
                &capped
            } else {
                ctx
            };
            // panic isolation: a refiner that unwinds (or whose scoped
            // workers did — see Workspace::worker_panic) is poisoned and
            // the shared partition state is revalidated and repaired
            // before the stack continues with the remaining refiners
            let ws = &mut self.ws;
            let outcome = std::panic::catch_unwind(AssertUnwindSafe(|| {
                timer.time(r.name(), || r.refine(phg, ws, rctx))
            }));
            let worker_panicked = self.ws.take_worker_panic();
            match outcome {
                Ok(gain) if !worker_panicked => total += gain,
                _ => {
                    self.poisoned[slot] = true;
                    Self::repair_after_panic(&mut self.ws, phg, ctx);
                }
            }
        }
        total
    }

    /// Post-panic recovery: clear FM ownership bits a dead worker may
    /// have leaked, revalidate the shared Π/Φ/Λ state and rebuild it from
    /// Π if the isolated worker left it inconsistent, then restore
    /// balance — the partition is fully usable by the remaining refiners
    /// afterwards.
    fn repair_after_panic(ws: &mut Workspace<R::State>, phg: &PartitionedHypergraph<R>, ctx: &Context) {
        ctx.cancel.note_panic_recovered();
        ws.reset_owner(ws.owner.len());
        if phg.validate().is_err() {
            phg.rebuild_from_parts(ctx.threads);
        }
        if !phg.is_balanced() {
            rebalance::rebalance(phg, ctx);
        }
    }

    /// Names of refiners poisoned by an isolated panic (diagnostics).
    pub fn poisoned_refiners(&self) -> Vec<&'static str> {
        self.stack
            .iter()
            .zip(&self.poisoned)
            .filter(|(_, &p)| p)
            .map(|(r, _)| r.name())
            .collect()
    }

    /// Localized FM restricted to `seeds` (n-level batch refinement,
    /// paper §9), on the shared workspace. Seeded invocations bypass the
    /// global gain table (see [`fm::fm_refine_with_workspace`]), so a
    /// batch costs O(Σ|I(region)|), not O(n·k). Under `ctx.deterministic`
    /// this dispatches to the seeded synchronous deterministic FM, which
    /// keeps the same table-free cost bound while staying thread-count
    /// invariant.
    pub fn fm_with_seeds<H: HypergraphOps<State = R::State>>(
        &mut self,
        phg: &PartitionedHypergraph<H>,
        ctx: &Context,
        seeds: Option<&[NodeId]>,
    ) -> FmStats {
        if ctx.deterministic {
            fm::deterministic::fm_refine_deterministic_with_workspace(
                phg,
                ctx,
                seeds,
                &mut self.ws,
            )
        } else {
            fm::fm_refine_with_workspace(phg, ctx, seeds, &mut self.ws)
        }
    }

    /// The pooled partition state (alloc/rebind counters for tests and
    /// benches).
    pub fn partition_pool(&self) -> &PartitionPool<R::State> {
        &self.ws.pool
    }

    /// The shared workspace (gain-table and allocation-stat access).
    pub fn workspace(&self) -> &Workspace<R::State> {
        &self.ws
    }

    pub fn workspace_mut(&mut self) -> &mut Workspace<R::State> {
        &mut self.ws
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::Preset;
    use crate::generators::{planted_hypergraph, PlantedParams};
    use crate::util::Rng;
    use crate::BlockId;
    use std::sync::Arc;

    fn ctx(preset: Preset, k: usize, threads: usize, seed: u64) -> Context {
        let mut c = Context::new(preset, k, 0.03).with_threads(threads).with_seed(seed);
        c.fm_max_rounds = 3;
        c
    }

    fn perturbed(seed: u64, k: usize, eps: f64) -> PartitionedHypergraph {
        let p = PlantedParams { n: 300, m: 550, blocks: k, ..Default::default() };
        let hg = Arc::new(planted_hypergraph(&p, seed));
        let n = hg.num_nodes();
        let mut rng = Rng::new(seed ^ 0x9e37);
        let mut parts: Vec<BlockId> = (0..n).map(|u| (u * k / n) as BlockId).collect();
        for _ in 0..n / 6 {
            parts[rng.next_below(n)] = rng.next_below(k) as BlockId;
        }
        let mut phg = PartitionedHypergraph::new(hg, k);
        phg.set_uniform_max_weight(eps);
        phg.assign_all(&parts, 1);
        phg
    }

    #[test]
    fn pipeline_improves_and_accounts_exactly() {
        let c = ctx(Preset::Default, 3, 2, 5);
        let phg = perturbed(5, 3, 0.3);
        let before = phg.km1();
        let mut pipe = RefinementPipeline::new(&c, phg.hypergraph().num_nodes());
        let gain = pipe.refine(&phg, &c);
        assert!(gain > 0, "pipeline should improve a perturbed partition");
        assert_eq!(phg.km1(), before - gain, "refiner gains account exactly");
        assert!(phg.is_balanced());
        phg.verify_consistency().unwrap();
    }

    #[test]
    fn one_gain_table_allocation_across_levels() {
        // simulate a 5-level uncoarsening: one pipeline, shrinking levels
        let c = ctx(Preset::Default, 2, 2, 7);
        let sizes = [300usize, 220, 150, 90, 40];
        let mut pipe = RefinementPipeline::new(&c, sizes[0]);
        for (i, &n_level) in sizes.iter().enumerate().rev() {
            let p = PlantedParams {
                n: n_level,
                m: 2 * n_level,
                blocks: 2,
                ..Default::default()
            };
            let hg = Arc::new(planted_hypergraph(&p, i as u64));
            let parts: Vec<BlockId> =
                (0..n_level).map(|u| (u * 2 / n_level) as BlockId).collect();
            let mut phg = PartitionedHypergraph::new(hg, 2);
            phg.set_uniform_max_weight(0.3);
            phg.assign_all(&parts, 1);
            pipe.refine(&phg, &c);
            phg.verify_consistency().unwrap();
        }
        assert_eq!(
            pipe.workspace().gain_table_allocs(),
            1,
            "the gain table must be allocated once and reused across levels"
        );
        assert!(pipe.workspace().gain_table_inits() >= sizes.len());
    }

    #[test]
    fn rebalance_fallback_repairs_infeasible_input() {
        // everything in block 0 with tight ε: the pipeline must hand back
        // a balanced partition (the rebalance stage repairs before LP/FM)
        let c = ctx(Preset::Default, 2, 2, 3);
        let p = PlantedParams { n: 200, m: 380, blocks: 2, ..Default::default() };
        let hg = Arc::new(planted_hypergraph(&p, 3));
        let n = hg.num_nodes();
        let mut phg = PartitionedHypergraph::new(hg, 2);
        phg.set_uniform_max_weight(0.03);
        phg.assign_all(&vec![0 as BlockId; n], 1);
        assert!(!phg.is_balanced());
        let mut pipe = RefinementPipeline::new(&c, n);
        pipe.refine(&phg, &c);
        assert!(phg.is_balanced(), "imbalance {}", phg.imbalance());
        phg.verify_consistency().unwrap();
    }

    #[test]
    fn flows_run_only_on_finest_levels() {
        // the flow refiner is level-gated (§8.1 cost model): at distances
        // ≥ flow_finest_levels it must not even build the quotient graph
        let mut c = ctx(Preset::DefaultFlows, 2, 2, 11);
        c.flow_finest_levels = 2;
        let phg = perturbed(11, 2, 0.3);
        let mut pipe = RefinementPipeline::new(&c, phg.hypergraph().num_nodes());
        pipe.refine_at_distance(&phg, &c, 5); // deep coarse level: skipped
        assert_eq!(pipe.workspace().flow_workspace().quotient_builds(), 0);
        pipe.refine_at_distance(&phg, &c, 2); // still outside the window
        assert_eq!(pipe.workspace().flow_workspace().quotient_builds(), 0);
        pipe.refine_at_distance(&phg, &c, 1); // finest-but-one: flows run
        assert_eq!(pipe.workspace().flow_workspace().quotient_builds(), 1);
        pipe.refine(&phg, &c); // finest level (distance 0)
        assert_eq!(pipe.workspace().flow_workspace().quotient_builds(), 2);
        assert!(phg.is_balanced());
        phg.verify_consistency().unwrap();
    }

    #[test]
    fn flow_workspace_is_reused_across_pipeline_levels() {
        // per-level flow calls on one pipeline must stop allocating after
        // the reserved first pass — the flow analogue of the gain-table
        // and partition-pool invariants (threads = 1: identical passes,
        // so the steady state is exact)
        let mut c = ctx(Preset::DefaultFlows, 2, 1, 13);
        c.flow_finest_levels = usize::MAX; // flows on every level
        let sizes = [300usize, 220, 150, 90];
        let hgs: Vec<_> = sizes
            .iter()
            .map(|&n_level| {
                let p = PlantedParams {
                    n: n_level,
                    m: 2 * n_level,
                    blocks: 2,
                    ..Default::default()
                };
                Arc::new(planted_hypergraph(&p, n_level as u64))
            })
            .collect();
        let mut pipe = RefinementPipeline::new_for(&c, &hgs[0]);
        let mut run_levels = |pipe: &mut RefinementPipeline| {
            for hg in hgs.iter().rev() {
                let n_level = hg.num_nodes();
                let parts: Vec<BlockId> =
                    (0..n_level).map(|u| (u * 2 / n_level) as BlockId).collect();
                let mut phg = PartitionedHypergraph::new(hg.clone(), 2);
                phg.set_uniform_max_weight(0.3);
                phg.assign_all(&parts, 1);
                pipe.refine(&phg, &c);
                phg.verify_consistency().unwrap();
            }
        };
        // first uncoarsening pass reaches the steady state (the flow
        // network's edge lists grow to the largest region encountered) …
        run_levels(&mut pipe);
        let allocs = pipe.workspace().flow_workspace().structural_allocs();
        // … after which a whole further uncoarsening sequence on the same
        // workspace performs zero structural allocations
        run_levels(&mut pipe);
        assert_eq!(
            pipe.workspace().flow_workspace().structural_allocs(),
            allocs,
            "flow state must be reused across uncoarsening sequences"
        );
        assert_eq!(
            pipe.workspace().flow_workspace().quotient_builds(),
            2 * sizes.len(),
            "one Λ enumeration per flow call"
        );
    }

    #[test]
    fn deterministic_stack_runs_fm_and_is_thread_invariant() {
        // the Deterministic preset keeps use_fm — FM no longer silently
        // drops out; the det-FM stage runs — and the whole stack
        // (rebalance → det-LP → det-FM → rebalance) is bit-identical
        // across thread counts
        let run = |threads: usize| {
            let c = ctx(Preset::Deterministic, 3, threads, 9);
            assert!(c.use_fm, "the Deterministic preset must run det-FM");
            let phg = perturbed(9, 3, 0.3);
            let mut pipe = RefinementPipeline::new(&c, phg.hypergraph().num_nodes());
            let gain = pipe.refine(&phg, &c);
            phg.verify_consistency().unwrap();
            assert!(phg.is_balanced());
            (gain, phg.km1(), phg.parts())
        };
        let r1 = run(1);
        let r2 = run(2);
        let r4 = run(4);
        assert!(r1.0 > 0, "deterministic stack should improve the perturbed partition");
        assert_eq!(r1, r2, "t=1 vs t=2");
        assert_eq!(r2, r4, "t=2 vs t=4");
    }

    #[test]
    fn capacity_growth_is_tracked() {
        let c = ctx(Preset::Default, 2, 1, 1);
        let mut ws: Workspace = Workspace::new(2, 1, 64);
        assert_eq!(ws.gain_table_allocs(), 1);
        ws.ensure_node_capacity(32); // prefix use: no growth
        assert_eq!(ws.gain_table_allocs(), 1);
        ws.ensure_node_capacity(128); // explicit growth is counted
        assert_eq!(ws.gain_table_allocs(), 2);
        assert!(ws.gain_table().node_capacity() >= 128);
        let _ = c;
    }
}
