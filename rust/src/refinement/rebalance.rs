//! Balance repair: move nodes out of overloaded blocks at minimum
//! connectivity cost.
//!
//! The paper's pipeline keeps partitions balanced by construction
//! (ε′-adapted recursive bipartitioning + balance-checked moves), but a
//! production solver needs a repair path for tight ε, weighted inputs or
//! infeasible starts (paper §12 "Limitations" discusses ε ≈ 0). This
//! rebalancer processes overloaded blocks in decreasing overload order
//! and relocates their cheapest nodes: candidates are popped from a
//! max-gain PQ (node weight is not part of the key), each node goes to
//! its best feasible target block with ties between targets broken
//! toward the *lighter* block, and stale PQ keys are lazily re-inserted
//! with their fresh gain rather than acted on or dropped.

use crate::coordinator::context::Context;
use crate::datastructures::AddressablePQ;
use crate::hypergraph::HypergraphOps;
use crate::partition::objective::{with_policy, GainPolicy};
use crate::partition::PartitionedHypergraph;
use crate::{BlockId, Gain, NodeId};

/// Repair balance; returns the number of moves performed. The partition
/// may remain imbalanced if no feasible relocation exists (caller checks
/// `is_balanced`). Eviction cost is measured under `ctx.objective`.
pub fn rebalance<H: HypergraphOps>(phg: &PartitionedHypergraph<H>, ctx: &Context) -> usize {
    with_policy!(ctx.objective, P => rebalance_p::<P, H>(phg, ctx))
}

fn rebalance_p<P: GainPolicy, H: HypergraphOps>(
    phg: &PartitionedHypergraph<H>,
    ctx: &Context,
) -> usize {
    let k = phg.k();
    let mut moves = 0usize;
    // repeat until no overloaded block makes progress
    for _round in 0..k * 4 {
        // most overloaded block first
        let mut over: Vec<(i64, BlockId)> = (0..k as BlockId)
            .map(|b| (phg.block_weight(b) - phg.max_block_weight(b), b))
            .filter(|&(o, _)| o > 0)
            .collect();
        if over.is_empty() {
            return moves;
        }
        over.sort_unstable_by_key(|&(o, _)| std::cmp::Reverse(o));
        let (_, heavy) = over[0];

        // candidate nodes of the overloaded block, by relocation gain.
        // Nodes without any feasible target are not inserted at all:
        // target blocks only gain weight during this round, so an
        // infeasible node cannot become feasible before the next rebuild
        // (the former `Gain::MIN/2` sentinels just churned the heap).
        let mut pq = AddressablePQ::new();
        for u in phg.hypergraph().nodes() {
            if phg.block_of(u) == heavy {
                if let Some((g, _)) = best_target::<P, H>(phg, u, heavy) {
                    pq.insert(u, g);
                }
            }
        }
        let mut progressed = false;
        while phg.block_weight(heavy) > phg.max_block_weight(heavy) {
            let Some((u, key)) = pq.pop_max() else { break };
            // lazy PQ discipline: earlier evictions change pin counts and
            // fill targets, so the popped key may be stale. Re-evaluate;
            // if the node got *worse*, reinsert with the fresh gain
            // instead of silently dropping it (the historic bug lost
            // evictable nodes here and reported an unrepairable block).
            match best_target::<P, H>(phg, u, heavy) {
                None => continue, // no feasible target anymore this round
                Some((g, t)) => {
                    if g < key {
                        pq.insert(u, g);
                        continue;
                    }
                    if phg.try_move_p::<P>(u, t, None).is_some() {
                        moves += 1;
                        progressed = true;
                    }
                }
            }
        }
        if !progressed {
            return moves;
        }
        let _ = ctx;
    }
    moves
}

/// Cheapest feasible target block for evicting `u` from `heavy`.
fn best_target<P: GainPolicy, H: HypergraphOps>(
    phg: &PartitionedHypergraph<H>,
    u: NodeId,
    heavy: BlockId,
) -> Option<(Gain, BlockId)> {
    let w = phg.hypergraph().node_weight(u);
    let mut best: Option<(Gain, BlockId)> = None;
    for t in 0..phg.k() as BlockId {
        if t == heavy || phg.block_weight(t) + w > phg.max_block_weight(t) {
            continue;
        }
        let g = phg.gain_p::<P>(u, t);
        match best {
            None => best = Some((g, t)),
            Some((bg, bb)) => {
                if g > bg || (g == bg && phg.block_weight(t) < phg.block_weight(bb)) {
                    best = Some((g, t));
                }
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::{Context, Preset};
    use crate::generators::{planted_hypergraph, PlantedParams};
    use std::sync::Arc;

    #[test]
    fn repairs_overloaded_block() {
        let hg = Arc::new(planted_hypergraph(
            &PlantedParams { n: 200, m: 380, blocks: 2, ..Default::default() },
            3,
        ));
        let n = hg.num_nodes();
        // 75% of the weight in block 0, limits at (1+0.03)·n/2
        let parts: Vec<BlockId> = (0..n).map(|u| u32::from(u * 4 / n >= 3)).collect();
        let mut phg = PartitionedHypergraph::new(hg, 2);
        phg.set_uniform_max_weight(0.03);
        phg.assign_all(&parts, 1);
        assert!(!phg.is_balanced());
        let ctx = Context::new(Preset::Default, 2, 0.03);
        let moves = rebalance(&phg, &ctx);
        assert!(moves > 0);
        assert!(phg.is_balanced(), "imbalance {}", phg.imbalance());
        phg.verify_consistency().unwrap();
    }

    #[test]
    fn noop_on_balanced_partition() {
        let hg = Arc::new(planted_hypergraph(
            &PlantedParams { n: 100, m: 200, blocks: 2, ..Default::default() },
            5,
        ));
        let n = hg.num_nodes();
        let parts: Vec<BlockId> = (0..n).map(|u| (u * 2 / n) as BlockId).collect();
        let mut phg = PartitionedHypergraph::new(hg, 2);
        phg.set_uniform_max_weight(0.1);
        phg.assign_all(&parts, 1);
        let km1 = phg.km1();
        assert_eq!(rebalance(&phg, &Context::new(Preset::Default, 2, 0.1)), 0);
        assert_eq!(phg.km1(), km1);
    }

    #[test]
    fn stale_priorities_are_reevaluated_not_dropped() {
        // block 0 is overloaded by four node weights; block 1 — the best
        // target of every candidate — can absorb exactly one node, so all
        // remaining priorities go stale after the first eviction and the
        // repair must re-target block 2 with freshly computed gains
        // instead of acting on (or dropping) outdated entries.
        let hg = Arc::new(crate::hypergraph::Hypergraph::from_nets(
            8,
            &[vec![0, 6], vec![1, 6], vec![2, 6], vec![3, 6]],
            None,
            None,
        ));
        let mut phg = PartitionedHypergraph::new(hg, 3);
        phg.set_max_weights(vec![2, 2, 8]);
        phg.assign_all(&[0, 0, 0, 0, 0, 0, 1, 2], 1);
        let ctx = Context::new(Preset::Default, 3, 0.03);
        let moves = rebalance(&phg, &ctx);
        assert_eq!(moves, 4, "exactly the overload must be evicted");
        assert!(phg.is_balanced(), "imbalance {}", phg.imbalance());
        phg.verify_consistency().unwrap();
        assert_eq!(phg.block_weight(0), 2);
        assert_eq!(phg.block_weight(1), 2, "block 1 absorbed exactly one node");
        assert_eq!(phg.block_weight(2), 4);
    }

    #[test]
    fn picks_low_cost_evictions() {
        // block 0 overloaded; nodes with no incident nets are free to move
        let hg = Arc::new(crate::hypergraph::Hypergraph::from_nets(
            6,
            &[vec![0, 1], vec![1, 2]],
            None,
            None,
        ));
        let mut phg = PartitionedHypergraph::new(hg, 2);
        phg.set_max_weights(vec![4, 4]);
        phg.assign_all(&[0, 0, 0, 0, 0, 1], 1);
        let ctx = Context::new(Preset::Default, 2, 0.03);
        rebalance(&phg, &ctx);
        assert!(phg.is_balanced());
        // isolated nodes 3, 4 (no nets) should have been moved, keeping km1 = 0
        assert_eq!(phg.km1(), 0, "eviction should be free");
    }
}
