//! Per-worker pooled state for flow-based refinement.
//!
//! A [`FlowScratch`] holds everything one flow worker needs to process a
//! block pair — the Lawler flow network, the push-relabel working state,
//! the region/frontier buffers and generation-stamped node/net marks — so
//! repeated `flow_refine` calls on one refinement workspace perform zero
//! structural allocations after the first (the `structural_allocs`
//! counter mirrors `PartitionPool::structural_allocs` and is asserted by
//! tests and the `perf_hotpath` "flow refinement" bench pair).
//!
//! Generation-stamped marks replace the former `vec![false; n]` per-pair
//! visited/seen arrays: a node (net) is marked in the current generation
//! iff its stamp equals the generation counter, so clearing is a counter
//! bump instead of an O(n) write — and there is no per-pair allocation.

use super::maxflow::{FlowNetwork, PreflowScratch};
use crate::{BlockId, EdgeId, NodeId, NodeWeight};
use std::collections::VecDeque;

/// A generation-stamped mark array: entry `i` is marked in the current
/// generation iff `marks[i] == gen`, so "clear all marks" is a counter
/// bump instead of an O(n) write. Wrap-around zeroes the storage once
/// every `u32::MAX` generations. Shared by the flow scratch's node/net
/// marks and the quotient graph's net dedup stamps.
#[derive(Default)]
pub(crate) struct StampMarks {
    marks: Vec<u32>,
    gen: u32,
}

impl StampMarks {
    /// Grow to `n` entries; returns `true` when storage actually grew
    /// (the callers count that as a structural allocation).
    pub(crate) fn ensure(&mut self, n: usize) -> bool {
        if self.marks.len() < n {
            self.marks.resize(n, 0);
            true
        } else {
            false
        }
    }

    /// Start a fresh generation (wrap-safe) and return its id.
    pub(crate) fn next_gen(&mut self) -> u32 {
        if self.gen == u32::MAX {
            self.marks.fill(0);
            self.gen = 0;
        }
        self.gen += 1;
        self.gen
    }

    #[inline]
    pub(crate) fn mark(&mut self, i: usize, gen: u32) {
        self.marks[i] = gen;
    }

    #[inline]
    pub(crate) fn is_marked(&self, i: usize, gen: u32) -> bool {
        self.marks[i] == gen
    }

    /// Mark entry `i`; returns `true` on its first visit this generation.
    #[inline]
    pub(crate) fn mark_first(&mut self, i: usize, gen: u32) -> bool {
        let first = self.marks[i] != gen;
        self.marks[i] = gen;
        first
    }
}

/// Reusable working state of one flow worker.
#[derive(Default)]
pub struct FlowScratch {
    /// pooled Lawler network (edge-list capacity survives across pairs)
    pub(crate) net: FlowNetwork,
    /// pooled push-relabel state for the incremental max-flow calls
    pub(crate) preflow: PreflowScratch,

    // ---- region of the current pair (aligned vectors) ----
    /// region hypernodes (parent ids); flow-node id = 2 + index
    pub(crate) region: Vec<NodeId>,
    /// BFS distance of each region node from the cut (piercing heuristic)
    pub(crate) distance: Vec<u32>,
    /// original side of each region node (true = block b1)
    pub(crate) side: Vec<bool>,
    /// node weights aligned with `region`
    pub(crate) weight: Vec<NodeWeight>,
    /// nets of the Lawler expansion
    pub(crate) nets: Vec<EdgeId>,

    // ---- generation-stamped marks ----
    node_marks: StampMarks,
    net_marks: StampMarks,
    /// flow-node id per hypernode; valid where the node carries the
    /// region generation mark
    pub(crate) flow_node: Vec<u32>,

    // ---- BFS / frontier churn ----
    pub(crate) frontier1: Vec<NodeId>,
    pub(crate) frontier2: Vec<NodeId>,
    pub(crate) bfs: VecDeque<(NodeId, u32)>,

    // ---- FlowCutter state ----
    pub(crate) source: Vec<bool>,
    pub(crate) sink: Vec<bool>,
    pub(crate) s_side: Vec<bool>,
    pub(crate) t_side: Vec<bool>,
    pub(crate) cands: Vec<usize>,
    /// final per-region-node source-side assignment of a cutter run
    pub(crate) assignment: Vec<bool>,

    // ---- scheduler interaction ----
    /// cut-net candidates of the pair being processed (copied out of the
    /// quotient graph under the scheduler lock)
    pub(crate) pair_nets: Vec<EdgeId>,
    /// proposed moves `(node, target block)` of the current pair
    pub(crate) moves: Vec<(NodeId, BlockId)>,
    /// applied moves `(node, source block)` kept by the last pair
    pub(crate) applied: Vec<(NodeId, BlockId)>,

    structural_allocs: usize,
}

impl FlowScratch {
    /// Size the node-/net-indexed mark arrays for a hypergraph with `n`
    /// nodes and `m` nets. Growth is a counted structural allocation;
    /// re-use at or below capacity is free.
    pub fn ensure(&mut self, n: usize, m: usize) {
        if self.node_marks.ensure(n) {
            self.flow_node.resize(n, 0);
            self.structural_allocs += 1;
        }
        if self.net_marks.ensure(m) {
            self.structural_allocs += 1;
        }
    }

    /// Start a fresh node-mark generation (wrap-safe).
    pub(crate) fn next_node_gen(&mut self) -> u32 {
        self.node_marks.next_gen()
    }

    /// Start a fresh net-mark generation (wrap-safe).
    pub(crate) fn next_net_gen(&mut self) -> u32 {
        self.net_marks.next_gen()
    }

    #[inline]
    pub(crate) fn mark_node(&mut self, u: NodeId, gen: u32) {
        self.node_marks.mark(u as usize, gen);
    }

    #[inline]
    pub(crate) fn node_marked(&self, u: NodeId, gen: u32) -> bool {
        self.node_marks.is_marked(u as usize, gen)
    }

    /// Mark net `e`; returns `true` on its first visit this generation.
    #[inline]
    pub(crate) fn mark_net(&mut self, e: EdgeId, gen: u32) -> bool {
        self.net_marks.mark_first(e as usize, gen)
    }

    /// Re-point the pooled flow network at `n` flow nodes; growth of the
    /// adjacency array is a counted structural allocation.
    pub(crate) fn reset_network(&mut self, n: usize) {
        if self.net.reset(n) {
            self.structural_allocs += 1;
        }
    }

    /// How often a node-/net-indexed buffer or the flow-network adjacency
    /// had to grow. Constant across repeated `flow_refine` calls on one
    /// workspace — the zero-allocation invariant of the flow scratch pool.
    pub fn structural_allocs(&self) -> usize {
        self.structural_allocs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn marks_reset_by_generation_bump() {
        let mut sc = FlowScratch::default();
        sc.ensure(8, 4);
        let allocs = sc.structural_allocs();
        let g1 = sc.next_node_gen();
        sc.mark_node(3, g1);
        assert!(sc.node_marked(3, g1));
        let g2 = sc.next_node_gen();
        assert!(!sc.node_marked(3, g2), "new generation clears all marks");
        let ge = sc.next_net_gen();
        assert!(sc.mark_net(2, ge), "first visit in a generation");
        assert!(!sc.mark_net(2, ge), "second visit is a duplicate");
        // re-ensure at or below capacity is free
        sc.ensure(8, 4);
        sc.ensure(2, 1);
        assert_eq!(sc.structural_allocs(), allocs);
        sc.ensure(16, 4);
        assert_eq!(sc.structural_allocs(), allocs + 1, "growth is counted");
    }

    #[test]
    fn network_reset_growth_is_counted() {
        let mut sc = FlowScratch::default();
        sc.reset_network(10);
        let base = sc.structural_allocs();
        sc.reset_network(6);
        sc.reset_network(10);
        assert_eq!(sc.structural_allocs(), base, "within capacity: no alloc");
        sc.reset_network(24);
        assert_eq!(sc.structural_allocs(), base + 1);
    }
}
