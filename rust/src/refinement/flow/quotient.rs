//! The quotient graph over the blocks of a partition (paper §8.1).
//!
//! Flow refinement schedules block *pairs*; the pairs worth scheduling
//! are the edges of the quotient graph — pairs connected by at least one
//! cut net. The former implementation rediscovered adjacency with an
//! O(m) net scan per pair per round (O(k²·m) per round). This structure
//! instead enumerates every net's connectivity set Λ(e) **once** per
//! `flow_refine` call (O(Σ_e |Λ(e)|²), with |Λ(e)| ≪ k in practice) and
//! keeps, per pair, the list of cut-net candidates; afterwards the lists
//! are maintained *incrementally* from the moves flow refinement applies,
//! so no further net scans happen for the rest of the call.
//!
//! Candidate lists are conservative: a net stays listed after moves made
//! it uncut for its pair, and incremental additions may duplicate build
//! entries. Both are cleaned up by [`QuotientGraph::compact_pair`], which
//! the scheduler runs when it hands a pair to a worker — each net is
//! re-checked against the current pin counts there, so stale entries cost
//! O(1) and never affect correctness.

use super::scratch::StampMarks;
use crate::partition::PartitionedHypergraph;
use crate::{BlockId, EdgeId, NodeId};

/// Λ-derived block-pair adjacency with per-pair cut-net candidate lists.
pub struct QuotientGraph {
    k: usize,
    /// upper-triangle pair → cut-net candidates (possibly stale/duplicated)
    cut_nets: Vec<Vec<EdgeId>>,
    /// decoded blocks per pair index
    pairs: Vec<(BlockId, BlockId)>,
    /// generation-stamped per-net dedup marks
    net_marks: StampMarks,
    /// Λ(e) enumeration buffer
    block_buf: Vec<BlockId>,
    builds: usize,
    structural_allocs: usize,
}

impl QuotientGraph {
    pub fn new(k: usize) -> Self {
        let mut pairs = Vec::with_capacity(k * k.saturating_sub(1) / 2);
        for b1 in 0..k as BlockId {
            for b2 in b1 + 1..k as BlockId {
                pairs.push((b1, b2));
            }
        }
        QuotientGraph {
            k,
            cut_nets: pairs.iter().map(|_| Vec::new()).collect(),
            pairs,
            net_marks: StampMarks::default(),
            block_buf: Vec::new(),
            builds: 0,
            structural_allocs: 0,
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn num_pairs(&self) -> usize {
        self.pairs.len()
    }

    /// Upper-triangle index of the pair `(b1, b2)`, `b1 < b2`.
    #[inline]
    pub fn pair_index(k: usize, b1: BlockId, b2: BlockId) -> usize {
        debug_assert!(b1 < b2 && (b2 as usize) < k);
        let (b1, b2) = (b1 as usize, b2 as usize);
        b1 * k - b1 * (b1 + 1) / 2 + (b2 - b1 - 1)
    }

    /// The blocks of pair index `p`.
    #[inline]
    pub fn pair_blocks(&self, p: usize) -> (BlockId, BlockId) {
        self.pairs[p]
    }

    /// Size the per-net stamp array (counted growth, free re-use).
    pub fn ensure_nets(&mut self, m: usize) {
        if self.net_marks.ensure(m) {
            self.structural_allocs += 1;
        }
    }

    /// Rebuild all candidate lists from the connectivity sets: one pass
    /// over the nets, enumerating Λ(e) per net — **no per-pair scans**.
    pub fn build(&mut self, phg: &PartitionedHypergraph) {
        debug_assert_eq!(phg.k(), self.k);
        let hg = phg.hypergraph();
        self.ensure_nets(hg.num_nets());
        for list in &mut self.cut_nets {
            list.clear();
        }
        for e in hg.nets() {
            if phg.connectivity(e) <= 1 {
                continue;
            }
            self.block_buf.clear();
            self.block_buf.extend(phg.connectivity_set(e));
            // Λ iteration is ascending, so (buf[i], buf[j]) is ordered
            for i in 0..self.block_buf.len() {
                for j in i + 1..self.block_buf.len() {
                    let p =
                        Self::pair_index(self.k, self.block_buf[i], self.block_buf[j]);
                    self.cut_nets[p].push(e);
                }
            }
        }
        self.builds += 1;
    }

    /// Is the pair adjacent according to the candidate lists? Exact right
    /// after [`Self::build`]; afterwards a cheap over-approximation
    /// (stale candidates are filtered by [`Self::compact_pair`]).
    pub fn is_adjacent(&self, b1: BlockId, b2: BlockId) -> bool {
        let (a, b) = if b1 < b2 { (b1, b2) } else { (b2, b1) };
        !self.cut_nets[Self::pair_index(self.k, a, b)].is_empty()
    }

    /// Current candidate list of a pair (tests/diagnostics).
    pub fn cut_net_candidates(&self, b1: BlockId, b2: BlockId) -> &[EdgeId] {
        let (a, b) = if b1 < b2 { (b1, b2) } else { (b2, b1) };
        &self.cut_nets[Self::pair_index(self.k, a, b)]
    }

    /// Incremental maintenance after flow refinement applied `moves`
    /// between `b1` and `b2`: every net incident to a moved node may now
    /// connect `b1`/`b2` with further blocks, so it is (re-)listed for all
    /// pairs {b1, b2} × Λ(e). Only candidate *additions* are needed —
    /// removals stay lazy — and only pairs involving the two touched
    /// blocks can change, so the update is O(applied · degree · |Λ|).
    pub fn note_moves(
        &mut self,
        phg: &PartitionedHypergraph,
        b1: BlockId,
        b2: BlockId,
        applied: &[(NodeId, BlockId)],
    ) {
        let hg = phg.hypergraph();
        let stamp = self.net_marks.next_gen();
        for &(u, _) in applied {
            for &e in hg.incident_nets(u) {
                if !self.net_marks.mark_first(e as usize, stamp) {
                    continue; // net already handled for this move set
                }
                if phg.connectivity(e) <= 1 {
                    continue;
                }
                self.block_buf.clear();
                self.block_buf.extend(phg.connectivity_set(e));
                let has1 = self.block_buf.contains(&b1);
                let has2 = self.block_buf.contains(&b2);
                let (k, block_buf, cut_nets) = (self.k, &self.block_buf, &mut self.cut_nets);
                for &b in block_buf {
                    if has1 && b != b1 {
                        let (x, y) = if b < b1 { (b, b1) } else { (b1, b) };
                        cut_nets[Self::pair_index(k, x, y)].push(e);
                    }
                    // `b == b1` would re-add the (b1, b2) pair the first
                    // branch already covers via `b == b2`
                    if has2 && b != b2 && b != b1 {
                        let (x, y) = if b < b2 { (b, b2) } else { (b2, b) };
                        cut_nets[Self::pair_index(k, x, y)].push(e);
                    }
                }
            }
        }
    }

    /// Deduplicate pair `p`'s candidates, drop nets no longer cut between
    /// the pair, and copy the compacted list into `out`. Returns the
    /// number of live cut nets.
    pub fn compact_pair(
        &mut self,
        phg: &PartitionedHypergraph,
        p: usize,
        out: &mut Vec<EdgeId>,
    ) -> usize {
        let (b1, b2) = self.pairs[p];
        let stamp = self.net_marks.next_gen();
        let net_marks = &mut self.net_marks;
        self.cut_nets[p].retain(|&e| {
            if !net_marks.mark_first(e as usize, stamp) {
                return false; // duplicate candidate
            }
            phg.pin_count(e, b1) > 0 && phg.pin_count(e, b2) > 0
        });
        out.clear();
        out.extend_from_slice(&self.cut_nets[p]);
        out.len()
    }

    /// How often the candidate lists were rebuilt from a full Λ
    /// enumeration (exactly once per `flow_refine` call).
    pub fn builds(&self) -> usize {
        self.builds
    }

    pub fn structural_allocs(&self) -> usize {
        self.structural_allocs
    }
}

/// Brute-force adjacency oracle (the pre-quotient-graph O(m) scan): do
/// blocks `b1` and `b2` share a cut net? Kept for tests and diagnostics —
/// the refinement hot path must never call this per pair.
pub fn blocks_adjacent(phg: &PartitionedHypergraph, b1: BlockId, b2: BlockId) -> bool {
    phg.hypergraph()
        .nets()
        .any(|e| phg.pin_count(e, b1) > 0 && phg.pin_count(e, b2) > 0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators::{planted_hypergraph, PlantedParams};
    use crate::util::Rng;
    use std::sync::Arc;

    #[test]
    fn pair_index_is_a_bijection() {
        for k in [2usize, 3, 5, 9] {
            let qg = QuotientGraph::new(k);
            let mut seen = vec![false; qg.num_pairs()];
            for b1 in 0..k as BlockId {
                for b2 in b1 + 1..k as BlockId {
                    let p = QuotientGraph::pair_index(k, b1, b2);
                    assert!(!seen[p], "k={k}: index {p} reused");
                    seen[p] = true;
                    assert_eq!(qg.pair_blocks(p), (b1, b2));
                }
            }
            assert!(seen.iter().all(|&s| s));
        }
    }

    #[test]
    fn build_matches_brute_force_oracle() {
        for seed in 0..8u64 {
            let k = 2 + (seed % 4) as usize;
            let p = PlantedParams { n: 120, m: 240, blocks: k, ..Default::default() };
            let hg = Arc::new(planted_hypergraph(&p, seed));
            let n = hg.num_nodes();
            let mut rng = Rng::new(seed ^ 0x77);
            let parts: Vec<BlockId> =
                (0..n).map(|_| rng.next_below(k) as BlockId).collect();
            let phg = crate::partition::PartitionedHypergraph::new(hg, k);
            phg.assign_all(&parts, 1);
            let mut qg = QuotientGraph::new(k);
            qg.build(&phg);
            for b1 in 0..k as BlockId {
                for b2 in b1 + 1..k as BlockId {
                    assert_eq!(
                        qg.is_adjacent(b1, b2),
                        blocks_adjacent(&phg, b1, b2),
                        "seed {seed}: pair ({b1},{b2})"
                    );
                }
            }
            assert_eq!(qg.builds(), 1);
        }
    }

    #[test]
    fn candidates_are_exactly_the_cut_nets_after_build() {
        let hg = Arc::new(crate::hypergraph::Hypergraph::from_nets(
            6,
            &[vec![0, 1, 2], vec![2, 3], vec![3, 4, 5], vec![0, 5]],
            None,
            None,
        ));
        let phg = crate::partition::PartitionedHypergraph::new(hg, 3);
        phg.assign_all(&[0, 0, 1, 1, 2, 2], 1);
        let mut qg = QuotientGraph::new(3);
        qg.build(&phg);
        assert_eq!(qg.cut_net_candidates(0, 1), &[0]); // net {0,1,2}
        assert_eq!(qg.cut_net_candidates(1, 2), &[2]); // net {3,4,5}
        assert_eq!(qg.cut_net_candidates(0, 2), &[3]); // net {0,5}
    }

    #[test]
    fn note_moves_relists_and_compact_filters() {
        let hg = Arc::new(crate::hypergraph::Hypergraph::from_nets(
            4,
            &[vec![0, 1], vec![1, 2], vec![2, 3]],
            None,
            None,
        ));
        let mut phg = crate::partition::PartitionedHypergraph::new(hg, 3);
        phg.set_uniform_max_weight(2.0);
        phg.assign_all(&[0, 0, 1, 2], 1);
        let mut qg = QuotientGraph::new(3);
        qg.build(&phg);
        assert!(qg.is_adjacent(0, 1) && qg.is_adjacent(1, 2));
        assert!(!qg.is_adjacent(0, 2));
        // move node 2 from block 1 to block 0 (as a (0,1)-pair refinement
        // would): net {2,3} now connects blocks 0 and 2
        phg.move_unchecked(2, 0, None);
        qg.note_moves(&phg, 0, 1, &[(2, 1)]);
        assert!(qg.is_adjacent(0, 2), "new adjacency must be discovered");
        // pair (1,2) still lists net {2,3}, but compaction drops it
        let mut out = Vec::new();
        let live = qg.compact_pair(&phg, QuotientGraph::pair_index(3, 1, 2), &mut out);
        assert_eq!(live, 0, "stale candidate must be filtered");
        // pair (0,2)'s list is live and deduplicated
        let live = qg.compact_pair(&phg, QuotientGraph::pair_index(3, 0, 2), &mut out);
        assert_eq!(live, 1);
        assert_eq!(out, vec![2]);
    }
}
