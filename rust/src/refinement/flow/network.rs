//! Flow network construction (paper §8.2): grow a size-constrained region
//! `B = B₁ ∪ B₂` around the cut nets of a block pair via two BFSs, then
//! build the Lawler expansion with all nodes outside `B` contracted into
//! the source / sink.
//!
//! All level-sized state (visited marks, the region vectors, the Lawler
//! network) lives in the caller's [`FlowScratch`]; the cut nets of the
//! pair come from the scheduler's quotient graph via `scratch.pair_nets`
//! instead of an O(m) scan over all nets.

use super::scratch::FlowScratch;
use crate::partition::objective::{GainPolicy, Km1Policy};
use crate::partition::PartitionedHypergraph;
use crate::{BlockId, EdgeId, NodeId, NodeWeight};

/// The scalar outcome of a region construction; the region itself
/// (nodes, distances, sides, weights, Lawler network) stays in the
/// [`FlowScratch`] the problem was built on.
pub struct FlowProblem {
    /// total weight contracted into the source (block `b1` outside B)
    pub source_weight: NodeWeight,
    /// total weight contracted into the sink (block `b2` outside B)
    pub sink_weight: NodeWeight,
    /// weight of region nets currently cut between b1 and b2
    pub initial_cut: i64,
}

/// Region growth parameters. `max_w1`/`max_w2` are the blocks' *actual*
/// weight limits — non-uniform limits installed via `set_max_weights`
/// (the V-cycle explicit-limit path) shape the region exactly like the
/// balance check that later accepts the moves, instead of a bound
/// re-derived from the global ε.
pub struct RegionConfig {
    /// region scaling factor α (§8.2)
    pub alpha: f64,
    /// max BFS hop distance from the cut δ (§8.2)
    pub max_distance: usize,
    pub max_w1: NodeWeight,
    pub max_w2: NodeWeight,
}

impl RegionConfig {
    /// The configuration flow refinement uses for one block pair.
    pub fn for_pair(
        phg: &PartitionedHypergraph,
        alpha: f64,
        max_distance: usize,
        b1: BlockId,
        b2: BlockId,
    ) -> Self {
        RegionConfig {
            alpha,
            max_distance,
            max_w1: phg.max_block_weight(b1),
            max_w2: phg.max_block_weight(b2),
        }
    }

    /// Region-scale autotuning: adapt the configured `(α, δ)` to instance
    /// statistics, computed once per `flow_refine` call.
    ///
    /// * `avg_net_size` governs how much weight one BFS hop absorbs.
    ///   Near-graph instances (avg |e| ≤ 3, e.g. the two-pin nets of a
    ///   plain graph) collect regions slowly, so the hop horizon widens
    ///   by one; heavy-tailed instances (avg |e| ≥ 16) blow past the
    ///   weight bound in a single hop, so it contracts by one.
    /// * `density` (adjacent block pairs / all pairs of the quotient
    ///   graph) measures how many regions compete for the same blocks at
    ///   once. With many blocks (k ≥ 8) and a dense quotient graph the
    ///   per-pair scale α shrinks — `α / (1 + density·k/8)` — so the
    ///   concurrent regions stay near-disjoint; for small k or sparse
    ///   quotient graphs α is left at the configured value (the §8.2
    ///   default already saturates the weight bound there).
    ///
    /// The mid band (3 < avg |e| < 16, k < 8) reproduces the configured
    /// values exactly, so typical hypergraph runs are unchanged. α never
    /// drops below 1 and δ never below 1.
    pub fn autotune(
        base_alpha: f64,
        base_distance: usize,
        avg_net_size: f64,
        density: f64,
        k: usize,
    ) -> (f64, usize) {
        let distance = if avg_net_size <= 3.0 {
            base_distance + 1
        } else if avg_net_size >= 16.0 {
            base_distance.saturating_sub(1).max(1)
        } else {
            base_distance.max(1)
        };
        let alpha = if k >= 8 {
            (base_alpha / (1.0 + density * k as f64 / 8.0)).max(1.0)
        } else {
            base_alpha
        };
        (alpha, distance)
    }
}

pub const SOURCE: u32 = 0;
pub const SINK: u32 = 1;

/// Cut nets between a block pair by brute force (tests and standalone
/// callers; the scheduler hands workers the quotient graph's incremental
/// candidate lists instead).
pub fn cut_nets_between(
    phg: &PartitionedHypergraph,
    b1: BlockId,
    b2: BlockId,
) -> Vec<EdgeId> {
    phg.hypergraph()
        .nets()
        .filter(|&e| phg.pin_count(e, b1) > 0 && phg.pin_count(e, b2) > 0)
        .collect()
}

/// Grow the region for blocks `(b1, b2)` (paper §8.2) from the cut-net
/// candidates in `scratch.pair_nets`: BFS from the boundary nodes of each
/// block, bounded by `⌈c(V₁∪V₂)/2⌉ + α·(L_max(b) − ⌈c(V₁∪V₂)/2⌉) −
/// c(other block)` — the paper's `(1+αε)`-scaled bound generalized to the
/// blocks' actual weight limits — and by hop distance δ. Stale or
/// duplicated candidates are skipped (each net is re-checked against the
/// current pin counts).
// indexed loops: the bodies call `&mut self` mark methods on the scratch
// that owns the iterated vectors, so iterator-style borrows cannot work
#[allow(clippy::needless_range_loop)]
pub fn construct_region(
    phg: &PartitionedHypergraph,
    b1: BlockId,
    b2: BlockId,
    cfg: &RegionConfig,
    sc: &mut FlowScratch,
) -> Option<FlowProblem> {
    construct_region_p::<Km1Policy>(phg, b1, b2, cfg, sc)
}

/// [`construct_region`] for an arbitrary [`GainPolicy`]: the bridging
/// edge of each net carries `P::bridging_capacity(ω, external)` — for
/// cut-net, a net with pins in a third block stays cut no matter how the
/// pair separates, so its bridging capacity drops to 0 (cutting it is
/// free), while km1 always pays ω for the extra λ. The external-pin scan
/// is gated on `P::NEEDS_CONNECTIVITY`, so the km1 instantiation builds
/// the exact pre-refactor network, edge order included.
#[allow(clippy::needless_range_loop)]
pub fn construct_region_p<P: GainPolicy>(
    phg: &PartitionedHypergraph,
    b1: BlockId,
    b2: BlockId,
    cfg: &RegionConfig,
    sc: &mut FlowScratch,
) -> Option<FlowProblem> {
    let hg = phg.hypergraph();
    sc.ensure(hg.num_nodes(), hg.num_nets());
    sc.region.clear();
    sc.distance.clear();
    sc.side.clear();
    sc.weight.clear();
    sc.nets.clear();
    sc.frontier1.clear();
    sc.frontier2.clear();

    // cut nets between the pair and their boundary pins
    let seed_gen = sc.next_node_gen();
    let cand_gen = sc.next_net_gen();
    let mut initial_cut = 0i64;
    for i in 0..sc.pair_nets.len() {
        let e = sc.pair_nets[i];
        if !sc.mark_net(e, cand_gen) {
            continue; // duplicate candidate
        }
        if phg.pin_count(e, b1) == 0 || phg.pin_count(e, b2) == 0 {
            continue; // stale candidate: no longer cut between the pair
        }
        initial_cut += hg.net_weight(e);
        for &p in hg.pins(e) {
            if sc.node_marked(p, seed_gen) {
                continue;
            }
            let bp = phg.block_of(p);
            if bp == b1 {
                sc.mark_node(p, seed_gen);
                sc.frontier1.push(p);
            } else if bp == b2 {
                sc.mark_node(p, seed_gen);
                sc.frontier2.push(p);
            }
        }
    }
    if initial_cut == 0 {
        return None;
    }

    let pair_weight = phg.block_weight(b1) + phg.block_weight(b2);
    let half = (pair_weight as f64 / 2.0).ceil() as NodeWeight;
    // α-scaled slack from each block's actual limit (ε-free §8.2 bound).
    // The b1-side region is the weight that could move *into* b2, so its
    // cap relaxes b2's limit — and vice versa: growing B₁ until
    // c(V₂) + c(B₁) ≤ ⌈pair/2⌉ + α·(L_max(b2) − ⌈pair/2⌉) generalizes the
    // paper's (1+αε)·⌈pair/2⌉ bound to explicit per-block limits.
    let slack1 = (cfg.alpha * (cfg.max_w2 - half).max(0) as f64) as NodeWeight;
    let slack2 = (cfg.alpha * (cfg.max_w1 - half).max(0) as f64) as NodeWeight;
    let cap1 = half + slack1 - phg.block_weight(b2);
    let cap2 = half + slack2 - phg.block_weight(b1);

    let w1 = grow_side(phg, sc, true, b1, cap1.max(0), cfg.max_distance);
    let w2 = grow_side(phg, sc, false, b2, cap2.max(0), cfg.max_distance);
    if sc.region.is_empty() {
        return None;
    }

    // Lawler expansion over the region's nets
    let region_gen = sc.next_node_gen();
    for i in 0..sc.region.len() {
        let u = sc.region[i];
        sc.mark_node(u, region_gen);
        sc.flow_node[u as usize] = 2 + i as u32;
    }
    // collect nets incident to the region with ≥1 pin in {b1, b2}
    let net_gen = sc.next_net_gen();
    for i in 0..sc.region.len() {
        let u = sc.region[i];
        for &e in hg.incident_nets(u) {
            if sc.mark_net(e, net_gen)
                && (phg.pin_count(e, b1) > 0 || phg.pin_count(e, b2) > 0)
            {
                sc.nets.push(e);
            }
        }
    }

    let num_flow_nodes = 2 + sc.region.len() + 2 * sc.nets.len();
    sc.reset_network(num_flow_nodes);
    let e_in_base = (2 + sc.region.len()) as u32;
    for j in 0..sc.nets.len() {
        let e = sc.nets[j];
        let w = hg.net_weight(e);
        let e_in = e_in_base + 2 * j as u32;
        let e_out = e_in + 1;
        // compiled out for km1 (NEEDS_CONNECTIVITY = false)
        let external = P::NEEDS_CONNECTIVITY
            && hg.pins(e).iter().any(|&p| {
                let bp = phg.block_of(p);
                bp != b1 && bp != b2
            });
        sc.net.add_edge(e_in, e_out, P::bridging_capacity(w, external)); // bridging edge
        let mut touches_source = false;
        let mut touches_sink = false;
        for &p in hg.pins(e) {
            if sc.node_marked(p, region_gen) {
                // bounded pin edges (paper's ω(e) optimization)
                let fid = sc.flow_node[p as usize];
                sc.net.add_edge(fid, e_in, w);
                sc.net.add_edge(e_out, fid, w);
            } else {
                let bp = phg.block_of(p);
                if bp == b1 {
                    touches_source = true;
                } else if bp == b2 {
                    touches_sink = true;
                }
                // pins in other blocks do not participate in this pair
            }
        }
        if touches_source {
            sc.net.add_edge(SOURCE, e_in, w);
            sc.net.add_edge(e_out, SOURCE, w);
        }
        if touches_sink {
            sc.net.add_edge(SINK, e_in, w);
            sc.net.add_edge(e_out, SINK, w);
        }
    }

    Some(FlowProblem {
        source_weight: phg.block_weight(b1) - w1,
        sink_weight: phg.block_weight(b2) - w2,
        initial_cut,
    })
}

/// One side's bounded BFS (from `frontier1` when `first_side`, else
/// `frontier2`); appends to the region vectors, returns the grown weight.
#[allow(clippy::needless_range_loop)] // body calls `&mut sc` mark methods
fn grow_side(
    phg: &PartitionedHypergraph,
    sc: &mut FlowScratch,
    first_side: bool,
    block: BlockId,
    cap: NodeWeight,
    max_distance: usize,
) -> NodeWeight {
    let hg = phg.hypergraph();
    let gen = sc.next_node_gen();
    sc.bfs.clear();
    let frontier_len = if first_side { sc.frontier1.len() } else { sc.frontier2.len() };
    for i in 0..frontier_len {
        let u = if first_side { sc.frontier1[i] } else { sc.frontier2[i] };
        sc.mark_node(u, gen);
        sc.bfs.push_back((u, 0));
    }
    let mut w_acc: NodeWeight = 0;
    while let Some((u, dist)) = sc.bfs.pop_front() {
        let w = hg.node_weight(u);
        if w_acc + w > cap {
            continue;
        }
        w_acc += w;
        sc.region.push(u);
        sc.distance.push(dist);
        sc.side.push(first_side);
        sc.weight.push(w);
        if dist as usize >= max_distance {
            continue;
        }
        for &e in hg.incident_nets(u) {
            for &v in hg.pins(e) {
                if !sc.node_marked(v, gen) && phg.block_of(v) == block {
                    sc.mark_node(v, gen);
                    sc.bfs.push_back((v, dist + 1));
                }
            }
        }
    }
    w_acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergraph::Hypergraph;
    use std::sync::Arc;

    fn setup() -> PartitionedHypergraph {
        // chain of nets across the cut
        let hg = Arc::new(Hypergraph::from_nets(
            8,
            &[vec![0, 1], vec![1, 2], vec![2, 3], vec![3, 4], vec![4, 5], vec![5, 6], vec![6, 7]],
            None,
            None,
        ));
        let mut phg = PartitionedHypergraph::new(hg, 2);
        phg.set_uniform_max_weight(0.5);
        phg.assign_all(&[0, 0, 0, 0, 1, 1, 1, 1], 1);
        phg
    }

    fn build(
        phg: &PartitionedHypergraph,
        sc: &mut FlowScratch,
        alpha: f64,
        dist: usize,
    ) -> Option<FlowProblem> {
        sc.pair_nets = cut_nets_between(phg, 0, 1);
        let cfg = RegionConfig::for_pair(phg, alpha, dist, 0, 1);
        construct_region(phg, 0, 1, &cfg, sc)
    }

    #[test]
    fn region_grows_around_cut() {
        let phg = setup();
        let mut sc = FlowScratch::default();
        let fp = build(&phg, &mut sc, 16.0, 2).unwrap();
        assert_eq!(fp.initial_cut, 1); // net {3,4}
        // boundary nodes 3 (block 0) and 4 (block 1) plus ≤2 hops
        assert!(sc.region.contains(&3) && sc.region.contains(&4));
        assert!(sc.distance.iter().all(|&d| d <= 2));
        // weights accounted: region + contracted = blocks
        let region_w: i64 = sc.weight.iter().sum();
        assert_eq!(
            region_w + fp.source_weight + fp.sink_weight,
            phg.block_weight(0) + phg.block_weight(1)
        );
    }

    #[test]
    fn min_cut_on_network_equals_hyperedge_cut() {
        let phg = setup();
        let mut sc = FlowScratch::default();
        build(&phg, &mut sc, 16.0, 2).unwrap();
        let n = sc.net.num_nodes();
        let mut src = vec![false; n];
        let mut snk = vec![false; n];
        src[SOURCE as usize] = true;
        snk[SINK as usize] = true;
        let f = sc.net.max_preflow(&src, &snk);
        assert_eq!(f, 1, "chain min cut is one net");
    }

    #[test]
    fn region_autotune_scales_with_instance_statistics() {
        // mid-band statistics reproduce the configured defaults exactly
        assert_eq!(RegionConfig::autotune(16.0, 2, 4.5, 1.0, 4), (16.0, 2));
        // near-graph instances (two-pin nets) widen the hop horizon
        assert_eq!(RegionConfig::autotune(16.0, 2, 2.0, 0.3, 4), (16.0, 3));
        // heavy-tailed net sizes contract it, never below one hop
        assert_eq!(RegionConfig::autotune(16.0, 2, 40.0, 0.3, 4), (16.0, 1));
        assert_eq!(RegionConfig::autotune(16.0, 1, 40.0, 0.3, 4), (16.0, 1));
        // dense quotient graphs with many blocks shrink α ...
        let (dense_a, dense_d) = RegionConfig::autotune(16.0, 2, 4.5, 1.0, 16);
        assert!(dense_a < 16.0 && dense_a >= 1.0, "α = {dense_a}");
        assert_eq!(dense_d, 2);
        // ... monotonically in the density
        let (sparse_a, _) = RegionConfig::autotune(16.0, 2, 4.5, 0.1, 16);
        assert!(sparse_a > dense_a);
        // α is floored at 1 even under extreme pressure
        let (floor_a, _) = RegionConfig::autotune(1.0, 2, 4.5, 1.0, 64);
        assert_eq!(floor_a, 1.0);
    }

    #[test]
    fn no_region_for_uncut_pair() {
        let hg = Arc::new(Hypergraph::from_nets(4, &[vec![0, 1], vec![2, 3]], None, None));
        let mut phg = PartitionedHypergraph::new(hg, 2);
        phg.set_uniform_max_weight(0.5);
        phg.assign_all(&[0, 0, 1, 1], 1);
        let mut sc = FlowScratch::default();
        assert!(build(&phg, &mut sc, 16.0, 2).is_none());
    }

    #[test]
    fn stale_and_duplicate_candidates_are_ignored() {
        let phg = setup();
        let mut sc = FlowScratch::default();
        // candidate list with a duplicate and a non-cut net (net 0 = {0,1})
        sc.pair_nets = vec![3, 3, 0];
        let cfg = RegionConfig::for_pair(&phg, 16.0, 2, 0, 1);
        let fp = construct_region(&phg, 0, 1, &cfg, &mut sc).unwrap();
        assert_eq!(fp.initial_cut, 1, "net 3 counted once, net 0 skipped");
    }

    #[test]
    fn repeated_construction_reuses_all_structures() {
        let phg = setup();
        let mut sc = FlowScratch::default();
        build(&phg, &mut sc, 16.0, 2).unwrap();
        let allocs = sc.structural_allocs();
        for _ in 0..5 {
            build(&phg, &mut sc, 16.0, 2).unwrap();
        }
        assert_eq!(
            sc.structural_allocs(),
            allocs,
            "repeated regions on one scratch must not allocate"
        );
    }

    #[test]
    fn explicit_limits_shape_the_region_caps() {
        // a pair with wildly asymmetric explicit limits: no region may
        // grow toward the tight block, while the side movable into the
        // loose block keeps growing — the ε-free bound tracks the actual
        // limits rather than a global ε
        let phg = setup();
        let mut sc = FlowScratch::default();
        sc.pair_nets = cut_nets_between(&phg, 0, 1);
        let cfg = RegionConfig { alpha: 1.0, max_distance: 3, max_w1: 4, max_w2: 8 };
        let fp = construct_region(&phg, 0, 1, &cfg, &mut sc).unwrap();
        // cap2 = 4 + 1·(max_w1−4) − c(V₁) = 4 + 0 − 4 = 0: block 1's side
        // (the weight that could move into the tight block 0) stays empty
        assert!(sc.side.iter().all(|&s| s), "only the b1 side may grow");
        assert_eq!(fp.sink_weight, phg.block_weight(1));
        // cap1 = 4 + 1·(max_w2−4) − c(V₂) = 4 → block 0's side grows
        assert_eq!(sc.weight.iter().sum::<i64>(), 4);
    }
}
