//! Flow network construction (paper §8.2): grow a size-constrained region
//! `B = B₁ ∪ B₂` around the cut nets of a block pair via two BFSs, then
//! build the Lawler expansion with all nodes outside `B` contracted into
//! the source / sink.

use super::maxflow::FlowNetwork;
use crate::partition::PartitionedHypergraph;
use crate::{BlockId, NodeId, NodeWeight};
use std::collections::VecDeque;

/// The extracted flow problem for one block pair.
pub struct FlowProblem {
    pub net: FlowNetwork,
    /// region hypernodes (parent ids); flow-node id = 2 + index
    pub region: Vec<NodeId>,
    /// BFS distance of each region node from the cut (piercing heuristic)
    pub distance: Vec<u32>,
    /// original side of each region node (true = block b1)
    pub side: Vec<bool>,
    /// node weights aligned with `region`
    pub weight: Vec<NodeWeight>,
    /// total weight contracted into the source (block `b1` outside B)
    pub source_weight: NodeWeight,
    /// total weight contracted into the sink (block `b2` outside B)
    pub sink_weight: NodeWeight,
    /// weight of region nets currently cut between b1 and b2
    pub initial_cut: i64,
    pub b1: BlockId,
    pub b2: BlockId,
}

pub const SOURCE: u32 = 0;
pub const SINK: u32 = 1;

/// Grow the region for blocks `(b1, b2)` (paper §8.2): BFS from the
/// boundary nodes of each block, bounded by `(1+αε)·⌈c(V₁∪V₂)/2⌉ −
/// c(other block)` and by hop distance δ.
pub fn construct_region(
    phg: &PartitionedHypergraph,
    b1: BlockId,
    b2: BlockId,
    alpha: f64,
    eps: f64,
    max_distance: usize,
) -> Option<FlowProblem> {
    let hg = phg.hypergraph();
    // cut nets between the pair and their boundary pins
    let mut frontier1: Vec<NodeId> = Vec::new();
    let mut frontier2: Vec<NodeId> = Vec::new();
    let mut initial_cut = 0i64;
    let mut seen_node = vec![false; hg.num_nodes()];
    for e in hg.nets() {
        if phg.pin_count(e, b1) > 0 && phg.pin_count(e, b2) > 0 {
            initial_cut += hg.net_weight(e);
            for &p in hg.pins(e) {
                if seen_node[p as usize] {
                    continue;
                }
                let bp = phg.block_of(p);
                if bp == b1 {
                    seen_node[p as usize] = true;
                    frontier1.push(p);
                } else if bp == b2 {
                    seen_node[p as usize] = true;
                    frontier2.push(p);
                }
            }
        }
    }
    if initial_cut == 0 {
        return None;
    }

    let pair_weight = phg.block_weight(b1) + phg.block_weight(b2);
    let half = (pair_weight as f64 / 2.0).ceil();
    let cap1 = ((1.0 + alpha * eps) * half) as NodeWeight - phg.block_weight(b2);
    let cap2 = ((1.0 + alpha * eps) * half) as NodeWeight - phg.block_weight(b1);

    // BFS per side, bounded by weight capacity and hop distance
    let mut region: Vec<NodeId> = Vec::new();
    let mut distance: Vec<u32> = Vec::new();
    let mut side: Vec<bool> = Vec::new();
    let mut grow = |frontier: &[NodeId], block: BlockId, cap: NodeWeight| {
        let mut w_acc: NodeWeight = 0;
        let mut q: VecDeque<(NodeId, u32)> = VecDeque::new();
        let mut visited = vec![false; hg.num_nodes()];
        for &u in frontier {
            visited[u as usize] = true;
            q.push_back((u, 0));
        }
        while let Some((u, dist)) = q.pop_front() {
            if w_acc + hg.node_weight(u) > cap {
                continue;
            }
            w_acc += hg.node_weight(u);
            region.push(u);
            distance.push(dist);
            side.push(block == b1);
            if dist as usize >= max_distance {
                continue;
            }
            for &e in hg.incident_nets(u) {
                for &v in hg.pins(e) {
                    if !visited[v as usize] && phg.block_of(v) == block {
                        visited[v as usize] = true;
                        q.push_back((v, dist + 1));
                    }
                }
            }
        }
        w_acc
    };
    let w1 = grow(&frontier1, b1, cap1.max(0));
    let w2 = grow(&frontier2, b2, cap2.max(0));
    if region.is_empty() {
        return None;
    }

    // Lawler expansion over the region's nets
    let mut flow_id = vec![u32::MAX; hg.num_nodes()];
    for (i, &u) in region.iter().enumerate() {
        flow_id[u as usize] = 2 + i as u32;
    }
    // collect nets incident to the region with ≥1 pin in {b1, b2}
    let mut net_seen = vec![false; hg.num_nets()];
    let mut nets: Vec<crate::EdgeId> = Vec::new();
    for &u in &region {
        for &e in hg.incident_nets(u) {
            if !net_seen[e as usize] {
                net_seen[e as usize] = true;
                // only nets relevant to the pair
                if phg.pin_count(e, b1) > 0 || phg.pin_count(e, b2) > 0 {
                    nets.push(e);
                }
            }
        }
    }

    let num_flow_nodes = 2 + region.len() + 2 * nets.len();
    let mut net_flow = FlowNetwork::new(num_flow_nodes);
    let e_in_base = (2 + region.len()) as u32;
    for (j, &e) in nets.iter().enumerate() {
        let w = hg.net_weight(e);
        let e_in = e_in_base + 2 * j as u32;
        let e_out = e_in + 1;
        net_flow.add_edge(e_in, e_out, w); // bridging edge
        let mut touches_source = false;
        let mut touches_sink = false;
        for &p in hg.pins(e) {
            let fid = flow_id[p as usize];
            if fid != u32::MAX {
                // bounded pin edges (paper's ω(e) optimization)
                net_flow.add_edge(fid, e_in, w);
                net_flow.add_edge(e_out, fid, w);
            } else {
                let bp = phg.block_of(p);
                if bp == b1 {
                    touches_source = true;
                } else if bp == b2 {
                    touches_sink = true;
                }
                // pins in other blocks do not participate in this pair
            }
        }
        if touches_source {
            net_flow.add_edge(SOURCE, e_in, w);
            net_flow.add_edge(e_out, SOURCE, w);
        }
        if touches_sink {
            net_flow.add_edge(SINK, e_in, w);
            net_flow.add_edge(e_out, SINK, w);
        }
    }

    let weight: Vec<NodeWeight> = region.iter().map(|&u| hg.node_weight(u)).collect();
    Some(FlowProblem {
        net: net_flow,
        source_weight: phg.block_weight(b1) - w1,
        sink_weight: phg.block_weight(b2) - w2,
        region,
        distance,
        side,
        weight,
        initial_cut,
        b1,
        b2,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypergraph::Hypergraph;
    use std::sync::Arc;

    fn setup() -> PartitionedHypergraph {
        // chain of nets across the cut
        let hg = Arc::new(Hypergraph::from_nets(
            8,
            &[vec![0, 1], vec![1, 2], vec![2, 3], vec![3, 4], vec![4, 5], vec![5, 6], vec![6, 7]],
            None,
            None,
        ));
        let mut phg = PartitionedHypergraph::new(hg, 2);
        phg.set_uniform_max_weight(0.5);
        phg.assign_all(&[0, 0, 0, 0, 1, 1, 1, 1], 1);
        phg
    }

    #[test]
    fn region_grows_around_cut() {
        let phg = setup();
        let fp = construct_region(&phg, 0, 1, 16.0, 0.03, 2).unwrap();
        assert_eq!(fp.initial_cut, 1); // net {3,4}
        // boundary nodes 3 (block 0) and 4 (block 1) plus ≤2 hops
        assert!(fp.region.contains(&3) && fp.region.contains(&4));
        assert!(fp.distance.iter().all(|&d| d <= 2));
        // weights accounted: region + contracted = blocks
        let region_w: i64 = fp.weight.iter().sum();
        assert_eq!(
            region_w + fp.source_weight + fp.sink_weight,
            phg.block_weight(0) + phg.block_weight(1)
        );
    }

    #[test]
    fn min_cut_on_network_equals_hyperedge_cut() {
        let phg = setup();
        let mut fp = construct_region(&phg, 0, 1, 16.0, 0.03, 2).unwrap();
        let n = fp.net.num_nodes();
        let mut src = vec![false; n];
        let mut snk = vec![false; n];
        src[SOURCE as usize] = true;
        snk[SINK as usize] = true;
        let f = fp.net.max_preflow(&src, &snk);
        assert_eq!(f, 1, "chain min cut is one net");
    }

    #[test]
    fn no_region_for_uncut_pair() {
        let hg = Arc::new(Hypergraph::from_nets(4, &[vec![0, 1], vec![2, 3]], None, None));
        let mut phg = PartitionedHypergraph::new(hg, 2);
        phg.set_uniform_max_weight(0.5);
        phg.assign_all(&[0, 0, 1, 1], 1);
        assert!(construct_region(&phg, 0, 1, 16.0, 0.03, 2).is_none());
    }
}
