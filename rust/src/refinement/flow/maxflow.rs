//! Push-relabel maximum flow with global relabeling (paper §8.4).
//!
//! FIFO discharge over a residual adjacency structure with explicit
//! reverse edges, supporting *terminal sets* (FlowCutter grows the source
//! and sink sets by piercing) and warm starts from an existing preflow:
//! after piercing, the previous flow stays feasible and only the new
//! terminals' edges are saturated.
//!
//! The paper parallelizes discharge rounds (Baumstark et al.); on this
//! testbed (1 vCPU) the synchronous round structure is kept but executed
//! sequentially — the scheduler-level parallelism of §8.1 is where the
//! thread-level parallelism lives (see DESIGN.md §2).

use std::collections::VecDeque;

/// One directed edge of the flow network.
#[derive(Clone, Debug)]
pub struct FlowEdge {
    pub to: u32,
    /// index of the reverse edge in `edges[to]`
    pub rev: u32,
    pub cap: i64,
    pub flow: i64,
}

/// Reusable push-relabel working state (excess, distance labels, FIFO of
/// active nodes). Owned by the per-worker `FlowScratch` so the repeated
/// max-preflow calls of one FlowCutter run — and of every subsequent block
/// pair handled by the same worker — stop allocating these vectors.
#[derive(Debug, Default)]
pub struct PreflowScratch {
    excess: Vec<i64>,
    dist: Vec<u32>,
    active: VecDeque<usize>,
    in_queue: Vec<bool>,
}

impl PreflowScratch {
    fn prepare(&mut self, n: usize) {
        self.excess.clear();
        self.excess.resize(n, 0);
        self.dist.clear();
        self.dist.resize(n, u32::MAX);
        self.active.clear();
        self.in_queue.clear();
        self.in_queue.resize(n, false);
    }
}

/// Residual flow network over `n` nodes.
///
/// The adjacency storage may hold capacity for more nodes than are live
/// (`reset` keeps the outer vector and every per-node edge list alive
/// across block pairs); only the first `n` entries are addressed.
#[derive(Clone, Debug, Default)]
pub struct FlowNetwork {
    pub edges: Vec<Vec<FlowEdge>>,
    n: usize,
}

impl FlowNetwork {
    pub fn new(n: usize) -> Self {
        FlowNetwork { edges: vec![Vec::new(); n], n }
    }

    pub fn num_nodes(&self) -> usize {
        self.n
    }

    /// Re-point the network at `n` nodes, keeping all edge-list capacity.
    /// Returns `true` when the outer adjacency vector had to grow (the
    /// event the flow workspace counts as a structural allocation).
    pub fn reset(&mut self, n: usize) -> bool {
        let grew = n > self.edges.len();
        if grew {
            self.edges.resize_with(n, Vec::new);
        }
        for list in &mut self.edges[..n] {
            list.clear();
        }
        self.n = n;
        grew
    }

    /// Add a directed edge `u → v` with capacity `cap` (reverse gets 0).
    pub fn add_edge(&mut self, u: u32, v: u32, cap: i64) {
        let ru = self.edges[u as usize].len() as u32;
        let rv = self.edges[v as usize].len() as u32;
        self.edges[u as usize].push(FlowEdge { to: v, rev: rv, cap, flow: 0 });
        self.edges[v as usize].push(FlowEdge { to: u, rev: ru, cap: 0, flow: 0 });
    }

    #[inline]
    fn residual(&self, u: usize, i: usize) -> i64 {
        let e = &self.edges[u][i];
        e.cap - e.flow
    }

    #[inline]
    fn push_on(&mut self, u: usize, i: usize, delta: i64) {
        let (to, rev);
        {
            let e = &mut self.edges[u][i];
            e.flow += delta;
            to = e.to as usize;
            rev = e.rev as usize;
        }
        self.edges[to][rev].flow -= delta;
    }

    /// Value of the current preflow = net inflow into the sink set (the
    /// paper's maximum-preflow value; excess trapped at interior nodes is
    /// not part of it).
    pub fn flow_value(&self, sinks: &[bool]) -> i64 {
        let mut v = 0;
        for (u, is_sink) in sinks.iter().enumerate() {
            if *is_sink {
                // Σ e.flow over u's list = outflow − inflow; edges between
                // two sink nodes cancel in the overall sum
                v -= self.edges[u].iter().map(|e| e.flow).sum::<i64>();
            }
        }
        v
    }

    /// Augment the current flow to a maximum preflow w.r.t. the terminal
    /// sets (paper: a maximum preflow already induces the min sink-side
    /// cut). Returns the flow value. Convenience wrapper over
    /// [`Self::max_preflow_with`] allocating throwaway scratch.
    pub fn max_preflow(&mut self, source: &[bool], sink: &[bool]) -> i64 {
        self.max_preflow_with(source, sink, &mut PreflowScratch::default())
    }

    /// Maximum preflow on caller-provided working state (zero allocations
    /// once the scratch reached the network's size).
    pub fn max_preflow_with(
        &mut self,
        source: &[bool],
        sink: &[bool],
        scratch: &mut PreflowScratch,
    ) -> i64 {
        let n = self.num_nodes();
        debug_assert_eq!(source.len(), n);
        scratch.prepare(n);
        let excess = &mut scratch.excess;
        // saturate all edges leaving sources (their excess is implicit)
        for u in 0..n {
            if source[u] {
                for i in 0..self.edges[u].len() {
                    let r = self.residual(u, i);
                    let to = self.edges[u][i].to as usize;
                    if r > 0 && !source[to] {
                        self.push_on(u, i, r);
                        excess[to] += r;
                    }
                }
            }
        }
        // recompute interior excess from flow conservation (warm start:
        // piercing may have turned an excess node into a source/sink)
        for u in 0..n {
            if source[u] || sink[u] {
                continue;
            }
            let mut bal = 0i64;
            for e in &self.edges[u] {
                bal -= e.flow; // outflow negative, inflow shows on reverse
            }
            // inflow − outflow = −Σ flow(u,·)
            excess[u] = bal;
            debug_assert!(excess[u] >= 0, "flow must stay a preflow");
        }

        // exact distance labels from the sink set (global relabel)
        let d = &mut scratch.dist;
        self.global_relabel(d, source, sink);

        let active = &mut scratch.active;
        let in_queue = &mut scratch.in_queue;
        for u in 0..n {
            if !source[u] && !sink[u] && excess[u] > 0 && d[u] != u32::MAX {
                active.push_back(u);
                in_queue[u] = true;
            }
        }
        let nmax = n as u32;
        let mut work = 0u64;
        // budget over the LIVE prefix only: the pooled adjacency may hold
        // stale edge lists beyond `n` from a larger earlier problem, and
        // counting them would inflate the budget until the periodic
        // global relabel never fires for small pairs
        let relabel_budget =
            6 * n as u64 + self.edges[..n].iter().map(Vec::len).sum::<usize>() as u64;

        while let Some(u) = active.pop_front() {
            in_queue[u] = false;
            if source[u] || sink[u] {
                continue;
            }
            // discharge u
            loop {
                if d[u] >= nmax {
                    break; // unreachable from sink: excess stays (preflow)
                }
                let mut min_label = u32::MAX;
                let mut pushed = false;
                for i in 0..self.edges[u].len() {
                    if excess[u] == 0 {
                        break;
                    }
                    let r = self.residual(u, i);
                    if r <= 0 {
                        continue;
                    }
                    let v = self.edges[u][i].to as usize;
                    if d[v] == u32::MAX {
                        continue;
                    }
                    if d[u] == d[v] + 1 {
                        let delta = excess[u].min(r);
                        self.push_on(u, i, delta);
                        excess[u] -= delta;
                        if !source[v] && !sink[v] {
                            excess[v] += delta;
                            if !in_queue[v] {
                                active.push_back(v);
                                in_queue[v] = true;
                            }
                        }
                        pushed = true;
                    } else {
                        min_label = min_label.min(d[v]);
                    }
                    work += 1;
                }
                if excess[u] == 0 {
                    break;
                }
                if !pushed || excess[u] > 0 {
                    // relabel
                    if min_label == u32::MAX {
                        d[u] = nmax; // disconnected from sink
                        break;
                    }
                    let nl = min_label + 1;
                    if nl >= nmax {
                        d[u] = nmax;
                        break;
                    }
                    d[u] = nl;
                    work += self.edges[u].len() as u64;
                }
                // periodic global relabeling
                if work > relabel_budget {
                    work = 0;
                    self.global_relabel(d, source, sink);
                    if d[u] == u32::MAX {
                        d[u] = nmax;
                        break;
                    }
                }
            }
        }
        self.flow_value(sink)
    }

    /// Reverse residual BFS from the sink set → exact distance labels.
    fn global_relabel(&self, d: &mut [u32], source: &[bool], sink: &[bool]) {
        let n = self.num_nodes();
        for (u, du) in d.iter_mut().enumerate() {
            *du = if sink[u] { 0 } else { u32::MAX };
        }
        let mut q: VecDeque<usize> = (0..n).filter(|&u| sink[u]).collect();
        while let Some(u) = q.pop_front() {
            for e in &self.edges[u] {
                let v = e.to as usize;
                // residual edge v → u exists iff reverse has capacity left
                let rev = &self.edges[v][e.rev as usize];
                if rev.cap - rev.flow > 0 && d[v] == u32::MAX && !sink[v] && !source[v] {
                    d[v] = d[u] + 1;
                    q.push_back(v);
                }
            }
        }
        let _ = source;
    }

    /// Source-side cut: nodes reachable from the source set via residual
    /// edges, seeded additionally with all excess nodes (paper §8.4:
    /// the forward BFS from active excess nodes finds the reverse paths
    /// carrying flow from the source — flow decomposition avoided).
    pub fn source_side(&self, source: &[bool], sink: &[bool]) -> Vec<bool> {
        let mut side = Vec::new();
        self.source_side_into(source, sink, &mut side);
        side
    }

    /// [`Self::source_side`] writing into a reusable buffer.
    pub fn source_side_into(&self, source: &[bool], sink: &[bool], side: &mut Vec<bool>) {
        let n = self.num_nodes();
        side.clear();
        side.resize(n, false);
        let mut q: VecDeque<usize> = VecDeque::new();
        // seeds: sources and non-sink nodes with positive excess
        for u in 0..n {
            let mut seed = source[u];
            if !seed && !sink[u] {
                let bal: i64 = self.edges[u].iter().map(|e| -e.flow).sum();
                if bal > 0 {
                    seed = true;
                }
            }
            if seed {
                side[u] = true;
                q.push_back(u);
            }
        }
        while let Some(u) = q.pop_front() {
            for e in &self.edges[u] {
                let v = e.to as usize;
                if !side[v] && e.cap - e.flow > 0 && !sink[v] {
                    side[v] = true;
                    q.push_back(v);
                }
            }
        }
    }

    /// Sink-side cut: nodes that reach the sink set via residual edges
    /// (reverse residual BFS).
    pub fn sink_side(&self, source: &[bool], sink: &[bool]) -> Vec<bool> {
        let mut side = Vec::new();
        self.sink_side_into(source, sink, &mut side);
        side
    }

    /// [`Self::sink_side`] writing into a reusable buffer.
    pub fn sink_side_into(&self, source: &[bool], sink: &[bool], side: &mut Vec<bool>) {
        let n = self.num_nodes();
        side.clear();
        side.resize(n, false);
        let mut q: VecDeque<usize> = VecDeque::new();
        for u in 0..n {
            if sink[u] {
                side[u] = true;
                q.push_back(u);
            }
        }
        while let Some(u) = q.pop_front() {
            for e in &self.edges[u] {
                let v = e.to as usize;
                let rev = &self.edges[v][e.rev as usize];
                if !side[v] && rev.cap - rev.flow > 0 && !source[v] {
                    side[v] = true;
                    q.push_back(v);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn terminals(n: usize, s: &[u32], t: &[u32]) -> (Vec<bool>, Vec<bool>) {
        let mut src = vec![false; n];
        let mut snk = vec![false; n];
        for &u in s {
            src[u as usize] = true;
        }
        for &u in t {
            snk[u as usize] = true;
        }
        (src, snk)
    }

    #[test]
    fn classic_diamond() {
        // s → a,b → t with capacities forcing max flow 3
        let mut net = FlowNetwork::new(4);
        net.add_edge(0, 1, 2);
        net.add_edge(0, 2, 2);
        net.add_edge(1, 3, 1);
        net.add_edge(2, 3, 2);
        let (s, t) = terminals(4, &[0], &[3]);
        assert_eq!(net.max_preflow(&s, &t), 3);
    }

    #[test]
    fn max_flow_min_cut_duality() {
        // random-ish layered network: flow value == weight of a cut
        let mut net = FlowNetwork::new(6);
        net.add_edge(0, 1, 10);
        net.add_edge(0, 2, 10);
        net.add_edge(1, 2, 2);
        net.add_edge(1, 3, 4);
        net.add_edge(1, 4, 8);
        net.add_edge(2, 4, 9);
        net.add_edge(3, 5, 10);
        net.add_edge(4, 3, 6);
        net.add_edge(4, 5, 10);
        let (s, t) = terminals(6, &[0], &[5]);
        let f = net.max_preflow(&s, &t);
        assert_eq!(f, 19); // classic example (CLRS-style)
        // source side via residual reachability gives a cut of equal weight
        let side = net.source_side(&s, &t);
        let mut cut = 0;
        for u in 0..6 {
            if side[u] {
                for e in &net.edges[u] {
                    if !side[e.to as usize] && e.cap > 0 {
                        cut += e.cap;
                    }
                }
            }
        }
        assert_eq!(cut, f, "max-flow = min-cut");
    }

    #[test]
    fn multi_terminal_sets() {
        // two sources, two sinks
        let mut net = FlowNetwork::new(6);
        net.add_edge(0, 2, 3);
        net.add_edge(1, 2, 3);
        net.add_edge(2, 3, 4);
        net.add_edge(3, 4, 3);
        net.add_edge(3, 5, 3);
        let (s, t) = terminals(6, &[0, 1], &[4, 5]);
        assert_eq!(net.max_preflow(&s, &t), 4);
    }

    #[test]
    fn warm_start_after_piercing() {
        // path s - a - b - t; after max flow, make a an additional source
        let mut net = FlowNetwork::new(4);
        net.add_edge(0, 1, 1);
        net.add_edge(1, 2, 5);
        net.add_edge(2, 3, 2);
        let (mut s, t) = terminals(4, &[0], &[3]);
        assert_eq!(net.max_preflow(&s, &t), 1);
        s[1] = true; // pierce: node a becomes a source
        let f = net.max_preflow(&s, &t);
        assert_eq!(f, 2, "additional source unlocks the second unit");
    }

    #[test]
    fn source_and_sink_sides_disjoint() {
        let mut net = FlowNetwork::new(5);
        net.add_edge(0, 1, 1);
        net.add_edge(1, 2, 1);
        net.add_edge(2, 3, 1);
        net.add_edge(3, 4, 1);
        let (s, t) = terminals(5, &[0], &[4]);
        net.max_preflow(&s, &t);
        let src_side = net.source_side(&s, &t);
        let snk_side = net.sink_side(&s, &t);
        for u in 0..5 {
            assert!(!(src_side[u] && snk_side[u]), "node {u} on both sides");
        }
        assert!(src_side[0] && snk_side[4]);
    }

    #[test]
    fn reset_reuses_capacity_and_recomputes() {
        let mut net = FlowNetwork::new(4);
        net.add_edge(0, 1, 2);
        net.add_edge(1, 3, 2);
        let (s, t) = terminals(4, &[0], &[3]);
        assert_eq!(net.max_preflow(&s, &t), 2);
        // re-point at a smaller problem: no growth, clean state
        assert!(!net.reset(3));
        assert_eq!(net.num_nodes(), 3);
        net.add_edge(0, 1, 1);
        net.add_edge(1, 2, 5);
        let (s, t) = terminals(3, &[0], &[2]);
        assert_eq!(net.max_preflow(&s, &t), 1);
        // growth past the allocated capacity is reported
        assert!(net.reset(8));
        assert!(!net.reset(4), "shrinking within capacity must not grow");
    }

    #[test]
    fn preflow_scratch_reuse_matches_fresh() {
        let mut scratch = PreflowScratch::default();
        for seed in 0..4u64 {
            let mut a = FlowNetwork::new(5);
            let caps = [1 + seed as i64, 2, 3, 1 + (seed % 2) as i64];
            a.add_edge(0, 1, caps[0]);
            a.add_edge(1, 4, caps[1]);
            a.add_edge(0, 2, caps[2]);
            a.add_edge(2, 4, caps[3]);
            let mut b = a.clone();
            let (s, t) = terminals(5, &[0], &[4]);
            let fresh = a.max_preflow(&s, &t);
            let pooled = b.max_preflow_with(&s, &t, &mut scratch);
            assert_eq!(fresh, pooled, "seed {seed}");
        }
    }

    #[test]
    fn disconnected_sink() {
        let mut net = FlowNetwork::new(3);
        net.add_edge(0, 1, 5);
        let (s, t) = terminals(3, &[0], &[2]);
        assert_eq!(net.max_preflow(&s, &t), 0);
    }
}
