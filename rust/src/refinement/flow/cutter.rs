//! The FlowCutter algorithm with bulk piercing (paper §8.3).
//!
//! Solves a sequence of incremental max-flow problems: augment, derive
//! source-/sink-side cuts, and — if neither induced bipartition is
//! balanced — convert the smaller side to terminals and *pierce* one (or,
//! in bulk mode, several) additional nodes, preferring nodes that avoid
//! augmenting paths and lie far from the original cut.
//!
//! All working state (terminal sets, cut sides, candidate ranking, the
//! push-relabel scratch) lives in the worker's [`FlowScratch`], so the
//! incremental max-flow sequence performs no per-iteration allocations.

use super::maxflow::FlowNetwork;
use super::network::{FlowProblem, SINK, SOURCE};
use super::scratch::FlowScratch;
use crate::NodeWeight;

/// Outcome of a FlowCutter run on one block pair. The per-region-node
/// source-side assignment is left in `scratch.assignment`.
pub struct CutterResult {
    /// weight of the minimum cut found
    pub cut_value: i64,
    /// expected connectivity reduction Δ_exp = initial_cut − cut_value
    pub delta_exp: i64,
}

/// Run FlowCutter until a balanced bipartition of the region is found.
///
/// `max_b1` / `max_b2` are the block weight limits; returns `None` when no
/// improving balanced cut exists (flow ≥ initial cut, or piercing ran out
/// of candidates).
pub fn flow_cutter(
    sc: &mut FlowScratch,
    fp: &FlowProblem,
    max_b1: NodeWeight,
    max_b2: NodeWeight,
) -> Option<CutterResult> {
    let n = sc.net.num_nodes();
    let rn = sc.region.len();
    sc.source.clear();
    sc.source.resize(n, false);
    sc.sink.clear();
    sc.sink.resize(n, false);
    sc.source[SOURCE as usize] = true;
    sc.sink[SINK as usize] = true;
    let region_weight_total: NodeWeight = sc.weight.iter().sum();
    let pair_weight: NodeWeight = fp.source_weight + fp.sink_weight + region_weight_total;
    let half = (pair_weight as f64 / 2.0).ceil() as NodeWeight;

    // bulk piercing state per side (paper §8.3)
    let mut pierce_round = [0usize; 2];
    let initial_terminal_weight = [fp.source_weight, fp.sink_weight];
    let avg_node_weight = (region_weight_total as f64 / rn.max(1) as f64).max(1.0);

    let max_iterations = 4 * rn + 16;
    for _ in 0..max_iterations {
        let flow = {
            let (net, preflow) = (&mut sc.net, &mut sc.preflow);
            net.max_preflow_with(&sc.source, &sc.sink, preflow)
        };
        if flow >= fp.initial_cut {
            return None; // cannot improve this pair
        }
        sc.net.source_side_into(&sc.source, &sc.sink, &mut sc.s_side);
        sc.net.sink_side_into(&sc.source, &sc.sink, &mut sc.t_side);

        let w_s: NodeWeight =
            fp.source_weight + region_weight(&sc.weight, &sc.s_side);
        let w_t: NodeWeight = fp.sink_weight + region_weight(&sc.weight, &sc.t_side);

        // bipartition (S_r, V∖S_r)
        if w_s <= max_b1 && pair_weight - w_s <= max_b2 {
            sc.assignment.clear();
            let s_side = &sc.s_side;
            sc.assignment.extend((0..rn).map(|i| s_side[2 + i]));
            return Some(CutterResult { cut_value: flow, delta_exp: fp.initial_cut - flow });
        }
        // bipartition (V∖T_r, T_r)
        if w_t <= max_b2 && pair_weight - w_t <= max_b1 {
            sc.assignment.clear();
            let t_side = &sc.t_side;
            sc.assignment.extend((0..rn).map(|i| !t_side[2 + i]));
            return Some(CutterResult { cut_value: flow, delta_exp: fp.initial_cut - flow });
        }

        // pierce the smaller side
        let pierce_source = w_s <= w_t;
        let side_idx = usize::from(!pierce_source);
        pierce_round[side_idx] += 1;
        let r = pierce_round[side_idx];
        // transform the reachable side into terminals
        if pierce_source {
            for u in 0..n {
                if sc.s_side[u] {
                    sc.source[u] = true;
                }
            }
        } else {
            for u in 0..n {
                if sc.t_side[u] {
                    sc.sink[u] = true;
                }
            }
        }
        // candidates: region nodes not yet terminal on either side
        {
            let (cands, source, sink) = (&mut sc.cands, &sc.source, &sc.sink);
            cands.clear();
            cands.extend((0..rn).filter(|&i| !source[2 + i] && !sink[2 + i]));
        }
        if sc.cands.is_empty() {
            return None;
        }
        // piercing heuristics: (1) avoid augmenting paths — prefer nodes
        // outside both residual sides; (2) stay on the pierced side's
        // original block (reconstructs parts of the original cut);
        // (3) larger distance from the cut
        {
            let (cands, s_side, t_side, side, distance) =
                (&mut sc.cands, &sc.s_side, &sc.t_side, &sc.side, &sc.distance);
            cands.sort_by_key(|&i| {
                let avoids = !(s_side[2 + i] || t_side[2 + i]);
                let same_side = side[i] == pierce_source;
                (
                    std::cmp::Reverse(avoids),
                    std::cmp::Reverse(same_side),
                    std::cmp::Reverse(distance[i]),
                    i,
                )
            });
        }

        // bulk piercing: weight goal (½ⁿ schedule) after warm-up rounds
        let count = if r <= 3 {
            1
        } else {
            let cur = if pierce_source { w_s } else { w_t };
            let init = initial_terminal_weight[side_idx];
            let goal_frac: f64 = (1..=r).map(|i| 0.5f64.powi(i as i32)).sum();
            let goal = init as f64 + ((half - init) as f64) * goal_frac;
            (((goal - cur as f64) / avg_node_weight).ceil() as usize).clamp(1, sc.cands.len())
        };
        {
            let (cands, source, sink) = (&sc.cands, &mut sc.source, &mut sc.sink);
            for &i in cands.iter().take(count) {
                if pierce_source {
                    source[2 + i] = true;
                } else {
                    sink[2 + i] = true;
                }
            }
        }
    }
    None
}

fn region_weight(weights: &[NodeWeight], flow_side: &[bool]) -> NodeWeight {
    weights
        .iter()
        .enumerate()
        .filter(|&(i, _)| flow_side[2 + i])
        .map(|(_, &w)| w)
        .sum()
}

/// Convenience for tests: total weight of a cut in the network, given the
/// final source-side assignment over all flow nodes.
#[allow(dead_code)]
pub fn cut_weight(net: &FlowNetwork, side: &[bool]) -> i64 {
    let mut w = 0;
    for u in 0..net.num_nodes() {
        if side[u] {
            for e in &net.edges[u] {
                if !side[e.to as usize] && e.cap > 0 {
                    w += e.cap;
                }
            }
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::PartitionedHypergraph;
    use crate::refinement::flow::network::{construct_region, cut_nets_between, RegionConfig};
    use std::sync::Arc;

    /// Chain instance where the initial cut (2 nets at a bad position) can
    /// be improved to 1 net by shifting the boundary.
    fn improvable() -> PartitionedHypergraph {
        // nets: {0,1},{1,2},{2,3},{3,4},{4,5}; bottleneck at {2,3}
        // plus parallel nets {0,1} and {4,5} doubling side connectivity
        let hg = Arc::new(crate::hypergraph::Hypergraph::from_nets(
            6,
            &[
                vec![0, 1],
                vec![0, 1],
                vec![1, 2],
                vec![1, 2],
                vec![2, 3],
                vec![3, 4],
                vec![3, 4],
                vec![4, 5],
                vec![4, 5],
            ],
            None,
            None,
        ));
        let mut phg = PartitionedHypergraph::new(hg, 2);
        phg.set_uniform_max_weight(0.4);
        // bad split between 1 and 2 (cut weight 2); optimum between 2 and 3
        phg.assign_all(&[0, 0, 1, 1, 1, 1], 1);
        phg
    }

    fn build(
        phg: &PartitionedHypergraph,
        sc: &mut FlowScratch,
        alpha: f64,
        dist: usize,
    ) -> Option<FlowProblem> {
        sc.pair_nets = cut_nets_between(phg, 0, 1);
        let cfg = RegionConfig::for_pair(phg, alpha, dist, 0, 1);
        construct_region(phg, 0, 1, &cfg, sc)
    }

    #[test]
    fn finds_the_better_cut() {
        let phg = improvable();
        assert_eq!(phg.km1(), 2);
        let mut sc = FlowScratch::default();
        let fp = build(&phg, &mut sc, 16.0, 3).unwrap();
        assert_eq!(fp.initial_cut, 2);
        let res = flow_cutter(&mut sc, &fp, phg.max_block_weight(0), phg.max_block_weight(1))
            .expect("improvement exists");
        assert_eq!(res.cut_value, 1, "min cut is the single net {{2,3}}");
        assert_eq!(res.delta_exp, 1);
        // assignment: node 2 should be on the source side now
        let idx2 = sc.region.iter().position(|&u| u == 2).unwrap();
        assert!(sc.assignment[idx2]);
    }

    #[test]
    fn gives_up_when_no_improvement() {
        // perfectly cut instance: min cut == current cut
        let hg = Arc::new(crate::hypergraph::Hypergraph::from_nets(
            4,
            &[vec![0, 1], vec![1, 2], vec![2, 3]],
            None,
            None,
        ));
        let mut phg = PartitionedHypergraph::new(hg, 2);
        phg.set_uniform_max_weight(1.0);
        phg.assign_all(&[0, 0, 1, 1], 1);
        let mut sc = FlowScratch::default();
        let fp = build(&phg, &mut sc, 16.0, 2).unwrap();
        let res =
            flow_cutter(&mut sc, &fp, phg.max_block_weight(0), phg.max_block_weight(1));
        // either None, or a cut of the same weight (flow == initial cut
        // aborts, so None is expected)
        assert!(res.is_none());
    }

    #[test]
    fn respects_balance_limits() {
        let phg = improvable();
        let mut sc = FlowScratch::default();
        let fp = build(&phg, &mut sc, 16.0, 3).unwrap();
        if let Some(_res) =
            flow_cutter(&mut sc, &fp, phg.max_block_weight(0), phg.max_block_weight(1))
        {
            let w_src: i64 = sc
                .weight
                .iter()
                .zip(&sc.assignment)
                .filter(|&(_, &s)| s)
                .map(|(&w, _)| w)
                .sum::<i64>()
                + fp.source_weight;
            let total = phg.block_weight(0) + phg.block_weight(1);
            assert!(w_src <= phg.max_block_weight(0));
            assert!(total - w_src <= phg.max_block_weight(1));
        }
    }

    #[test]
    fn scratch_reuse_across_cutter_runs_is_allocation_free() {
        let phg = improvable();
        let mut sc = FlowScratch::default();
        let fp = build(&phg, &mut sc, 16.0, 3).unwrap();
        flow_cutter(&mut sc, &fp, phg.max_block_weight(0), phg.max_block_weight(1))
            .expect("improvement exists");
        let allocs = sc.structural_allocs();
        for _ in 0..4 {
            let fp = build(&phg, &mut sc, 16.0, 3).unwrap();
            flow_cutter(&mut sc, &fp, phg.max_block_weight(0), phg.max_block_weight(1))
                .expect("improvement exists");
        }
        assert_eq!(sc.structural_allocs(), allocs);
    }
}
