//! Parallel flow-based refinement (paper §8, Algorithm 8.1).
//!
//! Derives the quotient graph from the connectivity sets Λ (one
//! enumeration per call — no per-pair net scans), schedules **active**
//! block pairs in waves (§8.1: after a pair improves, only pairs incident
//! to the touched blocks are re-enqueued), constructs a flow problem per
//! pair (§8.2) on the worker's pooled [`FlowScratch`], improves it with
//! FlowCutter (§8.3/8.4), and applies the resulting move set to the
//! global partition under a lock with attributed-gain verification.
//!
//! All level-sized state lives in the [`FlowWorkspace`] owned by the
//! refinement pipeline's `Workspace`: one [`FlowScratch`] per flow worker
//! (flow network, FlowCutter state, region buffers) plus the incremental
//! [`QuotientGraph`] and the scheduler's wave buffers — repeated
//! `flow_refine` calls on one workspace perform zero structural
//! allocations after the first (`structural_allocs`, asserted in tests
//! and the `perf_hotpath` "flow refinement" bench pair).

pub mod cutter;
pub mod maxflow;
pub mod network;
pub mod quotient;
pub mod scratch;

pub use quotient::{blocks_adjacent, QuotientGraph};
pub use scratch::FlowScratch;

use crate::coordinator::context::Context;
use crate::partition::objective::{with_policy, GainPolicy};
use crate::partition::PartitionedHypergraph;
use crate::{BlockId, Gain, NodeId};
use network::RegionConfig;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::{Condvar, Mutex};

/// The pooled state of flow refinement, owned by the refinement
/// pipeline's `Workspace` and reused across calls and uncoarsening
/// levels: per-worker scratch slots, the incremental quotient graph and
/// the active-pair wave buffers.
pub struct FlowWorkspace {
    k: usize,
    pub(crate) scratch: Vec<FlowScratch>,
    pub(crate) quotient: QuotientGraph,
    sched_current: VecDeque<u32>,
    sched_next: Vec<u32>,
    sched_queued: Vec<bool>,
    /// set when a flow worker panicked during the last call (the worker
    /// itself is isolated; the pipeline consumes this via
    /// [`FlowWorkspace::take_worker_panic`] to poison + repair)
    worker_panicked: bool,
}

impl FlowWorkspace {
    pub fn new(k: usize) -> Self {
        FlowWorkspace {
            k,
            scratch: Vec::new(),
            quotient: QuotientGraph::new(k),
            sched_current: VecDeque::new(),
            sched_next: Vec::new(),
            sched_queued: Vec::new(),
            worker_panicked: false,
        }
    }

    /// Read and reset the worker-panic verdict of the last flow call.
    pub fn take_worker_panic(&mut self) -> bool {
        std::mem::take(&mut self.worker_panicked)
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Make sure at least `workers` scratch slots exist.
    pub fn ensure_workers(&mut self, workers: usize) {
        while self.scratch.len() < workers.max(1) {
            self.scratch.push(FlowScratch::default());
        }
    }

    /// Size every pooled structure for the finest-level dimensions up
    /// front so an entire uncoarsening sequence (whose coarser levels
    /// address a prefix of these dimensions) never grows flow state.
    pub fn reserve(&mut self, workers: usize, num_nodes: usize, num_nets: usize) {
        self.ensure_workers(workers);
        for sc in &mut self.scratch {
            sc.ensure(num_nodes, num_nets);
        }
        self.quotient.ensure_nets(num_nets);
    }

    /// Total structural allocations across all pooled flow state (worker
    /// scratch + quotient graph). Constant across repeated `flow_refine`
    /// calls on one workspace after the first.
    pub fn structural_allocs(&self) -> usize {
        self.scratch.iter().map(FlowScratch::structural_allocs).sum::<usize>()
            + self.quotient.structural_allocs()
    }

    /// How often the quotient graph was rebuilt from a full Λ enumeration
    /// (exactly once per `flow_refine` call; all further adjacency comes
    /// from incremental maintenance).
    pub fn quotient_builds(&self) -> usize {
        self.quotient.builds()
    }

    pub fn quotient(&self) -> &QuotientGraph {
        &self.quotient
    }
}

/// Number of flow workers the scheduler runs: the thread count capped by
/// τ·k (§8.1 — more workers than meaningful block pairs only contend).
pub fn flow_workers(ctx: &Context, k: usize) -> usize {
    ctx.threads.min(((ctx.flow_tau * k as f64).ceil() as usize).max(1)).max(1)
}

/// Parallel active-block-pair scheduling + flow refinement. Convenience
/// wrapper allocating a throwaway [`FlowWorkspace`] — pipeline callers go
/// through [`flow_refine_with_workspace`].
pub fn flow_refine(phg: &PartitionedHypergraph, ctx: &Context) -> Gain {
    let mut fw = FlowWorkspace::new(phg.k());
    flow_refine_with_workspace(phg, ctx, &mut fw)
}

/// Flow refinement on a caller-provided workspace. Returns the total
/// verified improvement.
pub fn flow_refine_with_workspace(
    phg: &PartitionedHypergraph,
    ctx: &Context,
    fw: &mut FlowWorkspace,
) -> Gain {
    let k = phg.k();
    if k < 2 {
        return 0;
    }
    assert_eq!(fw.k, k, "flow workspace was built for a different k");
    let hg = phg.hypergraph();
    // §8.1 relative-improvement gating measures the *configured* objective
    let objective_before = phg.objective_value(ctx.objective).max(1);
    // Deterministic mode (§11, SDet with flows): one worker draining the
    // waves in a fixed (round, pair-id) order. With a single worker every
    // construct/apply step sees the exact same partition state for any
    // machine or requested thread count, so the result is reproducible;
    // the wave promotion below additionally sorts re-activated pairs by
    // pair id so the order is the *documented* one, not an artifact of
    // report() interleaving.
    let deterministic = ctx.deterministic;

    // one Λ enumeration builds the quotient graph; afterwards adjacency
    // is maintained incrementally from applied moves — zero net scans
    fw.quotient.build(phg);
    fw.sched_queued.clear();
    fw.sched_queued.resize(fw.quotient.num_pairs(), false);
    fw.sched_current.clear();
    fw.sched_next.clear();
    for p in 0..fw.quotient.num_pairs() {
        let (b1, b2) = fw.quotient.pair_blocks(p);
        if fw.quotient.is_adjacent(b1, b2) {
            fw.sched_queued[p] = true;
            fw.sched_current.push_back(p as u32);
        }
    }
    if fw.sched_current.is_empty() {
        return 0;
    }

    // region-scale autotuning (§8.2 leftover): derive the per-pair
    // region parameters once per call from the average net size and the
    // quotient-graph density — pure function of instance statistics, so
    // deterministic mode stays thread-count invariant
    let density = fw.sched_current.len() as f64 / fw.quotient.num_pairs().max(1) as f64;
    let avg_net_size = hg.num_pins() as f64 / hg.num_nets().max(1) as f64;
    let (alpha, distance) =
        RegionConfig::autotune(ctx.flow_alpha, ctx.flow_distance, avg_net_size, density, k);

    // τ·k parallelism cap (§8.1); deterministic mode serializes
    let workers = if deterministic { 1 } else { flow_workers(ctx, k) };
    fw.ensure_workers(workers);
    for sc in fw.scratch.iter_mut().take(workers) {
        sc.ensure(hg.num_nodes(), hg.num_nets());
    }

    let total_gain = AtomicI64::new(0);
    let apply_lock = Mutex::new(());
    let worker_panic = std::sync::atomic::AtomicBool::new(false);
    let sched = SchedulerSync {
        state: Mutex::new(Scheduler {
            quotient: &mut fw.quotient,
            current: &mut fw.sched_current,
            next: &mut fw.sched_next,
            queued: &mut fw.sched_queued,
            in_flight: 0,
            round_gain: 0,
            // a wave must earn ≥ 0.1% relative improvement to launch the next
            min_round_gain: ctx.flow_min_relative_improvement * objective_before as f64,
            deterministic,
        }),
        idle: Condvar::new(),
        cancel: &ctx.cancel,
    };
    std::thread::scope(|s| {
        for sc in fw.scratch.iter_mut().take(workers) {
            let (sched, apply_lock, total_gain) = (&sched, &apply_lock, &total_gain);
            let worker_panic = &worker_panic;
            s.spawn(move || {
                // panic isolation: a dying pair refinement must not abort
                // the process; the guard below releases the in-flight slot
                // during the unwind so peers blocked in claim() finish,
                // and the flag routes the failure into the pipeline's
                // poison/repair path
                let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| loop {
                    match sched.claim(phg, &mut sc.pair_nets) {
                        Claim::Done => break,
                        Claim::Pair(b1, b2) => {
                            let mut guard = InFlightGuard { sched, armed: true };
                            let delta = with_policy!(ctx.objective, P => {
                                refine_pair::<P>(phg, alpha, distance, b1, b2, sc, apply_lock)
                            });
                            // wave-tail injection site: the guard is still
                            // armed, so an injected panic exercises the
                            // in-flight release path
                            crate::util::failpoints::fire(
                                crate::util::failpoints::FLOW_WAVE_TAIL,
                                &ctx.cancel,
                            );
                            if delta > 0 {
                                total_gain.fetch_add(delta, Ordering::Relaxed);
                            }
                            guard.armed = false;
                            sched.report(phg, b1, b2, &sc.applied, delta);
                        }
                    }
                }));
                if caught.is_err() {
                    worker_panic.store(true, Ordering::Relaxed);
                }
            });
        }
    });
    if worker_panic.load(Ordering::Relaxed) {
        fw.worker_panicked = true;
    }
    if ctx.cancel.is_expired() {
        ctx.cancel.note_early_stop();
    }
    total_gain.load(Ordering::Relaxed)
}

/// What the scheduler hands a worker asking for work.
enum Claim {
    /// process this block pair (its cut-net candidates were copied into
    /// the worker's `pair_nets`)
    Pair(BlockId, BlockId),
    /// no further work: all waves exhausted or below the improvement bar
    Done,
}

/// Active-pair wave scheduler state (§8.1). Pairs activated by an
/// improvement go to the *next* wave; the next wave launches only when
/// the finished wave improved the objective by the relative threshold.
struct Scheduler<'a> {
    quotient: &'a mut QuotientGraph,
    current: &'a mut VecDeque<u32>,
    next: &'a mut Vec<u32>,
    queued: &'a mut Vec<bool>,
    in_flight: usize,
    round_gain: i64,
    min_round_gain: f64,
    /// fixed (round, pair-id) wave order (SDet): each promoted wave is
    /// sorted by pair id instead of keeping report() arrival order
    deterministic: bool,
}

/// The shared scheduler: state behind a mutex plus a condvar workers
/// sleep on when the wave is drained but peers are still in flight (an
/// in-flight pair may re-activate work, so sleepers cannot exit yet).
struct SchedulerSync<'a> {
    state: Mutex<Scheduler<'a>>,
    idle: Condvar,
    /// deadline token polled at wave/claim boundaries
    cancel: &'a crate::util::CancelToken,
}

// the scheduler state is consistent at every lock release, even when the
// releasing worker is mid-unwind (the failure is handled by the pipeline's
// repair path) — never let mutex poisoning cascade into further panics
fn relock<T>(r: Result<T, std::sync::PoisonError<T>>) -> T {
    r.unwrap_or_else(|e| e.into_inner())
}

impl SchedulerSync<'_> {
    fn claim(&self, phg: &PartitionedHypergraph, out: &mut Vec<crate::EdgeId>) -> Claim {
        let mut g = relock(self.state.lock());
        loop {
            // cancellation checkpoint: stop handing out pairs on expiry;
            // in-flight peers finish their pair and report normally
            if self.cancel.is_expired() {
                if g.in_flight == 0 {
                    self.idle.notify_all();
                    return Claim::Done;
                }
                g = relock(self.idle.wait(g));
                continue;
            }
            if let Some(p) = g.current.pop_front() {
                let p = p as usize;
                g.queued[p] = false;
                // compaction drops stale candidates; skip dead pairs
                if g.quotient.compact_pair(phg, p, out) == 0 {
                    continue;
                }
                g.in_flight += 1;
                let (b1, b2) = g.quotient.pair_blocks(p);
                return Claim::Pair(b1, b2);
            }
            if g.in_flight == 0 {
                // wave boundary: promote the next wave if it earned its keep
                if g.next.is_empty() || (g.round_gain as f64) < g.min_round_gain {
                    // wake sleepers so they observe the same verdict
                    self.idle.notify_all();
                    return Claim::Done;
                }
                let state = &mut *g;
                state.round_gain = 0;
                if state.deterministic {
                    state.next.sort_unstable();
                }
                state.current.extend(state.next.drain(..));
                continue;
            }
            g = relock(self.idle.wait(g));
        }
    }

    fn report(
        &self,
        phg: &PartitionedHypergraph,
        b1: BlockId,
        b2: BlockId,
        applied: &[(NodeId, BlockId)],
        delta: Gain,
    ) {
        {
            let mut g = relock(self.state.lock());
            let state = &mut *g;
            state.in_flight -= 1;
            if delta > 0 && !applied.is_empty() {
                state.round_gain += delta;
                // incremental quotient maintenance: nets incident to the
                // applied moves may now connect b1/b2 with further blocks
                state.quotient.note_moves(phg, b1, b2, applied);
                // §8.1 active pair scheduling: re-activate only pairs
                // incident to the two improved blocks (other pairs' cut
                // state is unchanged)
                let k = state.quotient.k();
                for t in [b1, b2] {
                    for other in 0..k as BlockId {
                        if other == t {
                            continue;
                        }
                        let (x, y) = if other < t { (other, t) } else { (t, other) };
                        let p = QuotientGraph::pair_index(k, x, y);
                        if !state.queued[p] && state.quotient.is_adjacent(x, y) {
                            state.queued[p] = true;
                            state.next.push(p as u32);
                        }
                    }
                }
            }
        }
        self.idle.notify_all();
    }
}

/// Releases a claimed in-flight slot if the worker unwinds before
/// reporting (a panicked pair must not leave peers asleep forever).
struct InFlightGuard<'s, 'a> {
    sched: &'s SchedulerSync<'a>,
    armed: bool,
}

impl Drop for InFlightGuard<'_, '_> {
    fn drop(&mut self) {
        if self.armed {
            relock(self.sched.state.lock()).in_flight -= 1;
            self.sched.idle.notify_all();
        }
    }
}

/// One flow refinement step on a block pair (Algorithm 8.1 lines 3–9).
/// Candidate cut nets are expected in `sc.pair_nets`; applied moves are
/// left in `sc.applied` (empty when nothing was kept). Moves are kept
/// only when their attributed gain is strictly positive.
fn refine_pair<P: GainPolicy>(
    phg: &PartitionedHypergraph,
    alpha: f64,
    max_distance: usize,
    b1: BlockId,
    b2: BlockId,
    sc: &mut FlowScratch,
    apply_lock: &Mutex<()>,
) -> Gain {
    sc.applied.clear();
    let cfg = RegionConfig::for_pair(phg, alpha, max_distance, b1, b2);
    let Some(fp) = network::construct_region_p::<P>(phg, b1, b2, &cfg, sc) else {
        return 0;
    };
    let Some(res) = cutter::flow_cutter(sc, &fp, cfg.max_w1, cfg.max_w2) else {
        return 0;
    };
    if res.delta_exp <= 0 {
        return 0;
    }
    // moves: region nodes whose side differs from their current block
    sc.moves.clear();
    for (&u, &src_side) in sc.region.iter().zip(&sc.assignment) {
        let target = if src_side { b1 } else { b2 };
        if phg.block_of(u) != target {
            sc.moves.push((u, target));
        }
    }
    if sc.moves.is_empty() {
        return 0;
    }

    // apply under the global lock (§8.1 "Apply Moves"): filter nodes no
    // longer in their expected block, check balance, verify with
    // attributed gains, revert on non-improvement
    let _guard = apply_lock.lock().unwrap();
    let hg = phg.hypergraph();
    let mut delta_w = [0i64; 2]; // (b1, b2)
    for &(u, to) in sc.moves.iter() {
        let from = phg.block_of(u);
        if (from != b1 && from != b2) || from == to {
            continue;
        }
        let w = hg.node_weight(u);
        if from == b1 {
            delta_w[0] -= w;
            delta_w[1] += w;
        } else {
            delta_w[0] += w;
            delta_w[1] -= w;
        }
        sc.applied.push((u, from));
    }
    if sc.applied.is_empty() {
        return 0;
    }
    // balance as if all moves were applied
    if phg.block_weight(b1) + delta_w[0] > phg.max_block_weight(b1)
        || phg.block_weight(b2) + delta_w[1] > phg.max_block_weight(b2)
    {
        sc.applied.clear();
        return 0;
    }
    let mut delta: Gain = 0;
    for &(u, from) in sc.applied.iter() {
        let to = if from == b1 { b2 } else { b1 };
        delta += phg.move_unchecked_p::<P>(u, to, None).attributed_gain;
    }
    if delta <= 0 {
        for &(u, from) in sc.applied.iter().rev() {
            phg.move_unchecked_p::<P>(u, from, None);
        }
        sc.applied.clear();
        return 0;
    }
    delta
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::{Context, Preset};
    use crate::generators::{planted_hypergraph, PlantedParams};
    use crate::util::Rng;
    use std::sync::Arc;

    fn ctx(k: usize, threads: usize, seed: u64) -> Context {
        Context::new(Preset::DefaultFlows, k, 0.1).with_threads(threads).with_seed(seed)
    }

    #[test]
    fn improves_chain_instance() {
        let hg = Arc::new(crate::hypergraph::Hypergraph::from_nets(
            6,
            &[
                vec![0, 1],
                vec![0, 1],
                vec![1, 2],
                vec![1, 2],
                vec![2, 3],
                vec![3, 4],
                vec![3, 4],
                vec![4, 5],
                vec![4, 5],
            ],
            None,
            None,
        ));
        let mut phg = PartitionedHypergraph::new(hg, 2);
        phg.set_uniform_max_weight(0.4);
        phg.assign_all(&[0, 0, 1, 1, 1, 1], 1);
        let before = phg.km1();
        let g = flow_refine(&phg, &ctx(2, 2, 1));
        assert!(g > 0, "flows must fix the misplaced boundary");
        assert_eq!(phg.km1(), before - g);
        assert!(phg.is_balanced());
        phg.verify_consistency().unwrap();
    }

    #[test]
    fn improves_perturbed_planted_kway() {
        let p = PlantedParams { n: 200, m: 400, blocks: 4, ..Default::default() };
        let hg = Arc::new(planted_hypergraph(&p, 3));
        let n = hg.num_nodes();
        let mut rng = Rng::new(99);
        let mut parts: Vec<BlockId> = (0..n).map(|u| (u * 4 / n) as BlockId).collect();
        for _ in 0..25 {
            parts[rng.next_below(n)] = rng.next_below(4) as BlockId;
        }
        let mut phg = PartitionedHypergraph::new(hg, 4);
        phg.set_uniform_max_weight(0.25);
        phg.assign_all(&parts, 1);
        let before = phg.km1();
        let g = flow_refine(&phg, &ctx(4, 4, 3));
        assert!(g >= 0);
        assert_eq!(phg.km1(), before - g);
        assert!(phg.is_balanced());
        phg.verify_consistency().unwrap();
    }

    #[test]
    fn never_applies_regressions() {
        for seed in 0..4u64 {
            let p = PlantedParams { n: 120, m: 260, blocks: 3, ..Default::default() };
            let hg = Arc::new(planted_hypergraph(&p, seed));
            let n = hg.num_nodes();
            let parts: Vec<BlockId> = (0..n).map(|u| (u * 3 / n) as BlockId).collect();
            let mut phg = PartitionedHypergraph::new(hg, 3);
            phg.set_uniform_max_weight(0.15);
            phg.assign_all(&parts, 1);
            let before = phg.km1();
            let g = flow_refine(&phg, &ctx(3, 2, seed));
            assert!(g >= 0, "seed {seed}");
            assert!(phg.km1() <= before, "seed {seed}");
            assert!(phg.is_balanced());
        }
    }

    #[test]
    fn workspace_reuse_is_allocation_free_and_scan_free() {
        let p = PlantedParams { n: 180, m: 360, blocks: 4, ..Default::default() };
        let hg = Arc::new(planted_hypergraph(&p, 17));
        let n = hg.num_nodes();
        // single worker: identical runs, so the steady state after the
        // first call is exact (multi-threaded reuse is covered by the
        // pipeline-level test; allocation-freeness is per-slot anyway)
        let c = ctx(4, 1, 17);
        let mut fw = FlowWorkspace::new(4);
        let mut rng = Rng::new(5);
        let mut parts: Vec<BlockId> = (0..n).map(|u| (u * 4 / n) as BlockId).collect();
        for _ in 0..20 {
            parts[rng.next_below(n)] = rng.next_below(4) as BlockId;
        }
        let run = |fw: &mut FlowWorkspace| {
            let mut phg = PartitionedHypergraph::new(hg.clone(), 4);
            phg.set_uniform_max_weight(0.25);
            phg.assign_all(&parts, 1);
            let before = phg.km1();
            let g = flow_refine_with_workspace(&phg, &c, fw);
            assert_eq!(phg.km1(), before - g);
            phg.verify_consistency().unwrap();
        };
        run(&mut fw);
        let allocs = fw.structural_allocs();
        assert!(allocs > 0, "the first call sizes the pooled state");
        for _ in 0..4 {
            run(&mut fw);
        }
        assert_eq!(
            fw.structural_allocs(),
            allocs,
            "repeated flow calls on one workspace must not allocate"
        );
        // one Λ enumeration per call — never a per-pair net scan
        assert_eq!(fw.quotient_builds(), 5);
    }

    #[test]
    fn deterministic_mode_is_thread_invariant() {
        // under ctx.deterministic the scheduler serializes onto one worker
        // and promotes waves in a fixed (round, pair-id) order, so the
        // SDet preset can enable flows reproducibly: the result must be
        // bit-identical for any requested thread count
        let p = PlantedParams { n: 200, m: 400, blocks: 4, ..Default::default() };
        let hg = Arc::new(planted_hypergraph(&p, 29));
        let n = hg.num_nodes();
        let mut rng = Rng::new(7);
        let mut parts: Vec<BlockId> = (0..n).map(|u| (u * 4 / n) as BlockId).collect();
        for _ in 0..30 {
            parts[rng.next_below(n)] = rng.next_below(4) as BlockId;
        }
        let run = |threads: usize| {
            let mut c = ctx(4, threads, 29);
            c.deterministic = true;
            let mut phg = PartitionedHypergraph::new(hg.clone(), 4);
            phg.set_uniform_max_weight(0.25);
            phg.assign_all(&parts, 1);
            let before = phg.km1();
            let g = flow_refine(&phg, &c);
            assert_eq!(phg.km1(), before - g);
            phg.verify_consistency().unwrap();
            (g, phg.parts())
        };
        let (g1, p1) = run(1);
        let (g4, p4) = run(4);
        assert_eq!(g1, g4, "same improvement for any thread count");
        assert_eq!(p1, p4, "deterministic flows must be bit-identical");
        assert!(g1 >= 0);
    }

    #[test]
    fn balances_stay_with_non_uniform_limits() {
        // explicit per-block limits (the set_max_weights path): flows must
        // respect each block's own limit in region construction and apply
        for seed in 0..4u64 {
            let p = PlantedParams { n: 150, m: 300, blocks: 3, ..Default::default() };
            let hg = Arc::new(planted_hypergraph(&p, seed ^ 0xbeef));
            let n = hg.num_nodes();
            let parts: Vec<BlockId> = (0..n).map(|u| (u * 3 / n) as BlockId).collect();
            let mut phg = PartitionedHypergraph::new(hg, 3);
            // asymmetric limits, all satisfied by the initial assignment
            let w: Vec<i64> = (0..3u32)
                .map(|b| {
                    let bw: i64 = (0..n)
                        .filter(|&u| parts[u] == b)
                        .map(|u| phg.hypergraph().node_weight(u as NodeId))
                        .sum();
                    bw + 1 + 7 * b as i64
                })
                .collect();
            phg.set_max_weights(w);
            phg.assign_all(&parts, 1);
            assert!(phg.is_balanced());
            let before = phg.km1();
            let g = flow_refine(&phg, &ctx(3, 2, seed));
            assert_eq!(phg.km1(), before - g, "seed {seed}");
            assert!(phg.is_balanced(), "seed {seed}: explicit limits violated");
            phg.verify_consistency().unwrap();
        }
    }
}
