//! Parallel flow-based refinement (paper §8, Algorithm 8.1).
//!
//! Builds the quotient graph, schedules active block pairs from a shared
//! FIFO (§8.1), constructs a flow problem per pair (§8.2), improves it
//! with FlowCutter (§8.3/8.4), and applies the resulting move set to the
//! global partition under a lock with attributed-gain verification.

pub mod cutter;
pub mod maxflow;
pub mod network;

use crate::coordinator::context::Context;
use crate::datastructures::ConcurrentQueue;
use crate::partition::PartitionedHypergraph;
use crate::{BlockId, Gain};
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::Mutex;

/// Parallel active-block-pair scheduling + flow refinement.
/// Returns the total verified improvement.
pub fn flow_refine(phg: &PartitionedHypergraph, ctx: &Context) -> Gain {
    let k = phg.k();
    if k < 2 {
        return 0;
    }
    let total_gain = AtomicI64::new(0);
    let apply_lock = Mutex::new(());
    let objective_before = phg.km1().max(1);

    // several rounds; stop when relative improvement < 0.1% (§8.1)
    for _round in 0..8 {
        // all currently adjacent block pairs
        let mut pairs: Vec<(BlockId, BlockId)> = Vec::new();
        for b1 in 0..k as BlockId {
            for b2 in b1 + 1..k as BlockId {
                if blocks_adjacent(phg, b1, b2) {
                    pairs.push((b1, b2));
                }
            }
        }
        if pairs.is_empty() {
            break;
        }
        let queue = ConcurrentQueue::from_iter(pairs);
        let round_gain = AtomicI64::new(0);
        // τ·k parallelism cap (§8.1)
        let workers = ctx
            .threads
            .min(((ctx.flow_tau * k as f64).ceil() as usize).max(1))
            .max(1);
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| {
                    while let Some((b1, b2)) = queue.pop() {
                        let g = refine_pair(phg, ctx, b1, b2, &apply_lock);
                        if g > 0 {
                            round_gain.fetch_add(g, Ordering::Relaxed);
                        }
                    }
                });
            }
        });
        let rg = round_gain.load(Ordering::Relaxed);
        total_gain.fetch_add(rg, Ordering::Relaxed);
        if (rg as f64) < ctx.flow_min_relative_improvement * objective_before as f64 {
            break;
        }
    }
    total_gain.load(Ordering::Relaxed)
}

fn blocks_adjacent(phg: &PartitionedHypergraph, b1: BlockId, b2: BlockId) -> bool {
    phg.hypergraph()
        .nets()
        .any(|e| phg.pin_count(e, b1) > 0 && phg.pin_count(e, b2) > 0)
}

/// One flow refinement step on a block pair (Algorithm 8.1 lines 3–9).
fn refine_pair(
    phg: &PartitionedHypergraph,
    ctx: &Context,
    b1: BlockId,
    b2: BlockId,
    apply_lock: &Mutex<()>,
) -> Gain {
    let Some(mut fp) =
        network::construct_region(phg, b1, b2, ctx.flow_alpha, ctx.epsilon, ctx.flow_distance)
    else {
        return 0;
    };
    let Some(res) =
        cutter::flow_cutter(&mut fp, phg.max_block_weight(b1), phg.max_block_weight(b2))
    else {
        return 0;
    };
    if res.delta_exp < 0 {
        return 0;
    }
    // moves: region nodes whose side differs from their current block
    let moves: Vec<(crate::NodeId, BlockId)> = fp
        .region
        .iter()
        .zip(&res.source_assignment)
        .filter_map(|(&u, &src_side)| {
            let target = if src_side { b1 } else { b2 };
            (phg.block_of(u) != target).then_some((u, target))
        })
        .collect();
    if moves.is_empty() {
        return 0;
    }

    // apply under the global lock (§8.1 "Apply Moves"): filter nodes no
    // longer in their expected block, check balance, verify with
    // attributed gains, revert on regression
    let _guard = apply_lock.lock().unwrap();
    let hg = phg.hypergraph();
    let valid: Vec<(crate::NodeId, BlockId, BlockId)> = moves
        .iter()
        .filter_map(|&(u, to)| {
            let from = phg.block_of(u);
            ((from == b1 || from == b2) && from != to).then_some((u, from, to))
        })
        .collect();
    // balance as if all moves were applied
    let mut delta_w = [0i64; 2];
    for &(u, from, _) in &valid {
        let w = hg.node_weight(u);
        if from == b1 {
            delta_w[0] -= w;
            delta_w[1] += w;
        } else {
            delta_w[0] += w;
            delta_w[1] -= w;
        }
    }
    if phg.block_weight(b1) + delta_w[0] > phg.max_block_weight(b1)
        || phg.block_weight(b2) + delta_w[1] > phg.max_block_weight(b2)
    {
        return 0;
    }
    let mut applied: Vec<(crate::NodeId, BlockId)> = Vec::with_capacity(valid.len());
    let mut delta: Gain = 0;
    for &(u, from, to) in &valid {
        let out = phg.move_unchecked(u, to, None);
        delta += out.attributed_gain;
        applied.push((u, from));
    }
    if delta < 0 {
        for &(u, from) in applied.iter().rev() {
            phg.move_unchecked(u, from, None);
        }
        return 0;
    }
    delta
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::{Context, Preset};
    use crate::generators::{planted_hypergraph, PlantedParams};
    use crate::util::Rng;
    use std::sync::Arc;

    fn ctx(k: usize, threads: usize, seed: u64) -> Context {
        Context::new(Preset::DefaultFlows, k, 0.1).with_threads(threads).with_seed(seed)
    }

    #[test]
    fn improves_chain_instance() {
        let hg = Arc::new(crate::hypergraph::Hypergraph::from_nets(
            6,
            &[
                vec![0, 1],
                vec![0, 1],
                vec![1, 2],
                vec![1, 2],
                vec![2, 3],
                vec![3, 4],
                vec![3, 4],
                vec![4, 5],
                vec![4, 5],
            ],
            None,
            None,
        ));
        let mut phg = PartitionedHypergraph::new(hg, 2);
        phg.set_uniform_max_weight(0.4);
        phg.assign_all(&[0, 0, 1, 1, 1, 1], 1);
        let before = phg.km1();
        let g = flow_refine(&phg, &ctx(2, 2, 1));
        assert!(g > 0, "flows must fix the misplaced boundary");
        assert_eq!(phg.km1(), before - g);
        assert!(phg.is_balanced());
        phg.verify_consistency().unwrap();
    }

    #[test]
    fn improves_perturbed_planted_kway() {
        let p = PlantedParams { n: 200, m: 400, blocks: 4, ..Default::default() };
        let hg = Arc::new(planted_hypergraph(&p, 3));
        let n = hg.num_nodes();
        let mut rng = Rng::new(99);
        let mut parts: Vec<BlockId> = (0..n).map(|u| (u * 4 / n) as BlockId).collect();
        for _ in 0..25 {
            parts[rng.next_below(n)] = rng.next_below(4) as BlockId;
        }
        let mut phg = PartitionedHypergraph::new(hg, 4);
        phg.set_uniform_max_weight(0.25);
        phg.assign_all(&parts, 1);
        let before = phg.km1();
        let g = flow_refine(&phg, &ctx(4, 4, 3));
        assert!(g >= 0);
        assert_eq!(phg.km1(), before - g);
        assert!(phg.is_balanced());
        phg.verify_consistency().unwrap();
    }

    #[test]
    fn never_applies_regressions() {
        for seed in 0..4u64 {
            let p = PlantedParams { n: 120, m: 260, blocks: 3, ..Default::default() };
            let hg = Arc::new(planted_hypergraph(&p, seed));
            let n = hg.num_nodes();
            let parts: Vec<BlockId> = (0..n).map(|u| (u * 3 / n) as BlockId).collect();
            let mut phg = PartitionedHypergraph::new(hg, 3);
            phg.set_uniform_max_weight(0.15);
            phg.assign_all(&parts, 1);
            let before = phg.km1();
            let g = flow_refine(&phg, &ctx(3, 2, seed));
            assert!(g >= 0, "seed {seed}");
            assert!(phg.km1() <= before, "seed {seed}");
            assert!(phg.is_balanced());
        }
    }
}
