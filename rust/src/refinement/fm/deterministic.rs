//! Synchronous deterministic FM refinement (the ROADMAP "Deterministic
//! FM" item; paper §11 discipline, see also *Deterministic Parallel
//! Hypergraph Partitioning*, arXiv:2112.12704).
//!
//! ## §11 adaptation note
//!
//! The paper's deterministic configuration (SDet) makes preprocessing,
//! coarsening and label propagation synchronous but leaves FM out
//! entirely — its localized searches own nodes via atomics and publish
//! moves in poll order, which no fixed schedule can reproduce. This
//! module adapts the §11 *frozen gains + prefix selection* discipline to
//! an FM-strength refiner instead of dropping FM from the deterministic
//! preset:
//!
//! 1. **Frozen gains.** Each round computes every candidate's best move
//!    against the round-start partition snapshot — from the workspace
//!    [`GainTable`] in global mode (O(k) per lookup, §6.2), or from the
//!    exact pin counts in seeded mode, where the table is never
//!    initialized (the n-level batch-boundary cost argument of
//!    [`super::fm_refine_with_workspace`]). Nothing is applied while
//!    gains are computed, so the parallel phase only reads.
//! 2. **Prefix selection per block pair.** Candidate moves (frozen gain
//!    ≥ 0 — zero-gain plateau moves are admitted, unlike deterministic
//!    LP's strictly positive filter) are grouped by block pair, each
//!    pair's two directions sorted by `(gain desc, node id)`, and the
//!    longest balance-feasible prefix pair is selected by the §11
//!    two-pointer prefix-sum over move weights ([`select_prefixes`]).
//!    Pairs are processed in a fixed ascending `(s, t)` order, so
//!    opposite-direction conflicts resolve identically for every thread
//!    count; application is sequential — no atomics race on Π.
//! 3. **Balance-admissible best-prefix revert.** Each pair's selected
//!    moves are applied merged across the two directions in
//!    `(gain desc, node)` order, logging the exact attributed gain *and*
//!    whether the pair's two blocks are within their limits right after
//!    the move (its *admissibility* as a cut point — other blocks are
//!    untouched since their own pair finished, and the §11 prefix-sum
//!    selection proves every pair boundary feasible). The round then
//!    reverts to the best admissible prefix of the move log (§6.3
//!    flavor, ties toward the longest prefix so kept zero-gain plateau
//!    moves survive). This is the FM ingredient: frozen gains go stale
//!    as earlier moves apply — the mirror move of an already-uncut net
//!    realizes −ω(e) instead of its frozen +ω(e) — and the revert keeps
//!    the profitable prefix and undoes the rest, so a round can never
//!    end worse than it started, which plain deterministic LP does not
//!    guarantee.
//!
//! **Divergence from the paper:** §11 splits every round into
//! `det_sub_rounds` hash-partitioned sub-rounds to keep the frozen state
//! fresh for LP's cheap moves. Det-FM intentionally runs *synchronous
//! full rounds* instead: the unit revert already repairs stale-gain
//! damage exactly, and full rounds give the prefix selection the complete
//! wishlist to trade off per pair. Seeded (n-level §9) invocations expand
//! the candidate set around the nodes kept by the previous round — the
//! synchronous analogue of localized FM's neighborhood expansion.
//!
//! Everything runs on the pipeline [`Workspace`]: the gain table for
//! frozen gains, the shared [`DetScratch`](crate::refinement::DetScratch)
//! (membership, wishlist, move log, weight-prefix buffers) and nothing
//! per-invocation — repeated
//! calls across uncoarsening levels allocate nothing new. The refiner is
//! generic over [`HypergraphOps`], so the same code serves the static
//! multilevel/V-cycle/baseline drivers and the n-level
//! `DynamicHypergraph` path.

use crate::coordinator::context::Context;
use crate::hypergraph::HypergraphOps;
use crate::parallel::parallel_chunks;
use crate::partition::objective::{with_policy, GainPolicy};
use crate::partition::{GainTable, Move, PartitionState, PartitionedHypergraph};
use crate::refinement::fm::{FmStats, EXPANSION_NET_SIZE_LIMIT};
use crate::refinement::lp::select_prefixes;
use crate::refinement::pipeline::Workspace;
use crate::{BlockId, Gain, NodeId};
use std::sync::Mutex;

/// Synchronous deterministic FM; returns round/improvement statistics.
///
/// Standalone entry point allocating a transient [`Workspace`] — pipeline
/// callers go through
/// [`RefinementPipeline::fm_with_seeds`](crate::refinement::RefinementPipeline::fm_with_seeds)
/// or the refiner stack, which carry the workspace across levels.
pub fn fm_refine_deterministic<H: HypergraphOps>(
    phg: &PartitionedHypergraph<H>,
    ctx: &Context,
) -> FmStats {
    let mut ws = Workspace::new(phg.k(), ctx.threads, phg.hypergraph().num_nodes());
    fm_refine_deterministic_with_workspace(phg, ctx, None, &mut ws)
}

/// The deterministic FM algorithm proper, on a caller-provided
/// [`Workspace`]. Global rounds (no seed set) compute frozen gains from
/// the workspace gain table (initialized once per invocation, maintained
/// through the move update rules); seeded rounds skip the table and use
/// exact pin-count gains, staying O(region) per n-level batch boundary.
///
/// Thread-count invariant by construction: the parallel phase only reads
/// the frozen partition, its merged wishlist is totally ordered by
/// `(gain, node)` before use, and all moves are applied — and reverted —
/// sequentially in a fixed pair order.
pub fn fm_refine_deterministic_with_workspace<H: HypergraphOps>(
    phg: &PartitionedHypergraph<H>,
    ctx: &Context,
    seed_set: Option<&[NodeId]>,
    ws: &mut Workspace<H::State>,
) -> FmStats {
    with_policy!(ctx.objective, P => {
        fm_refine_deterministic_with_workspace_p::<P, H>(phg, ctx, seed_set, ws)
    })
}

fn fm_refine_deterministic_with_workspace_p<P: GainPolicy, H: HypergraphOps>(
    phg: &PartitionedHypergraph<H>,
    ctx: &Context,
    seed_set: Option<&[NodeId]>,
    ws: &mut Workspace<H::State>,
) -> FmStats {
    assert_eq!(phg.k(), ws.k(), "workspace was built for a different k");
    let n = phg.hypergraph().num_nodes();
    let threads = ctx.threads.max(1);
    ws.ensure_node_capacity(n);
    // two-pin states skip the table in global mode too: frozen best moves
    // come straight from max_gain_move_p's single adjacency scan
    let use_table = seed_set.is_none() && <H::State as PartitionState>::USE_GAIN_TABLE;
    if use_table {
        ws.prepare_gain_table_p::<P, H>(phg, threads);
    }
    // field-disjoint borrows: the det scratch is mutated, the gain table
    // is read (and updated through interior mutability by the move ops)
    let ws = &mut *ws;
    let det = &mut ws.det;
    let table: Option<&GainTable> = if use_table { Some(&ws.gain_table) } else { None };

    if let Some(seeds) = seed_set {
        det.candidates.clear();
        det.candidates.extend_from_slice(seeds);
        det.candidates.sort_unstable();
        det.candidates.dedup();
    }

    let mut stats = FmStats::default();
    for round in 0..ctx.fm_max_rounds {
        // cancellation checkpoint at the synchronous round boundary: only
        // whole rounds are ever observable, so stopping here keeps the
        // partition at a consistent §11 state
        if ctx.cancel.is_expired() {
            ctx.cancel.note_early_stop();
            break;
        }
        // ---- candidates of this round (frozen-state border nodes) ----
        det.members.clear();
        match seed_set {
            Some(_) => det.members.extend_from_slice(&det.candidates),
            None => det.members.extend(0..n as NodeId),
        }

        // ---- phase 1: frozen best moves, computed in parallel ----
        // Reads only; the merged wishlist is totally ordered below, so
        // the nondeterministic per-thread collection order cannot show.
        det.desired.clear();
        {
            let members = &det.members[..];
            let desired = Mutex::new(&mut det.desired);
            parallel_chunks(members.len(), threads, |_, lo, hi| {
                let mut local: Vec<(Gain, NodeId, BlockId, BlockId)> = Vec::new();
                for &u in &members[lo..hi] {
                    if !phg.is_border(u) {
                        continue;
                    }
                    let best = match table {
                        Some(gt) => gt.max_gain_move(phg, u),
                        None => phg.max_gain_move_p::<P>(u),
                    };
                    if let Some((g, t)) = best {
                        // zero-gain plateau moves are admitted (see the
                        // module doc); negative ones are not — the
                        // best-prefix revert could only drop them again
                        if g >= 0 {
                            local.push((g, u, phg.block_of(u), t));
                        }
                    }
                }
                desired.lock().unwrap().extend(local);
            });
        }
        if det.desired.is_empty() {
            break;
        }
        // total order: block pair asc, direction, gain desc, node asc
        det.desired.sort_unstable_by(|a, b| {
            pair_dir(a).cmp(&pair_dir(b)).then(b.0.cmp(&a.0)).then(a.1.cmp(&b.1))
        });

        // ---- phase 2: sequential per-pair prefix application ----
        det.moves.clear();
        det.gains.clear();
        det.admissible.clear();
        let desired = &det.desired[..];
        let mut i = 0;
        while i < desired.len() {
            let (pmin, pmax, _) = pair_dir(&desired[i]);
            let mut j = i;
            while j < desired.len() {
                let (a, b, _) = pair_dir(&desired[j]);
                if (a, b) != (pmin, pmax) {
                    break;
                }
                j += 1;
            }
            // the sort puts the s→t direction (from == pmin) first
            let mut mid = i;
            while mid < j && desired[mid].2 == pmin {
                mid += 1;
            }
            let st = &desired[i..mid];
            let ts = &desired[mid..j];
            i = j;

            det.w_st.clear();
            det.w_st.extend(st.iter().map(|m| phg.hypergraph().node_weight(m.1)));
            det.w_ts.clear();
            det.w_ts.extend(ts.iter().map(|m| phg.hypergraph().node_weight(m.1)));
            let feasible_before = phg.block_weight(pmin) <= phg.max_block_weight(pmin)
                && phg.block_weight(pmax) <= phg.max_block_weight(pmax);
            let (len_st, len_ts) = select_prefixes(
                &det.w_st,
                &det.w_ts,
                phg.block_weight(pmin),
                phg.block_weight(pmax),
                phg.max_block_weight(pmin),
                phg.max_block_weight(pmax),
            );
            if len_st + len_ts == 0 {
                continue;
            }
            // apply the two selected prefixes merged by (gain desc, node)
            // — high-gain moves first, so a stale mirror move cannot drag
            // an earlier genuine improvement past the revert cut
            let (mut si, mut ti) = (0usize, 0usize);
            while si < len_st || ti < len_ts {
                let take_st = if si < len_st && ti < len_ts {
                    let (x, y) = (&st[si], &ts[ti]);
                    x.0 > y.0 || (x.0 == y.0 && x.1 < y.1)
                } else {
                    si < len_st
                };
                let m = if take_st {
                    si += 1;
                    &st[si - 1]
                } else {
                    ti += 1;
                    &ts[ti - 1]
                };
                let out = phg.move_unchecked_p::<P>(m.1, m.3, table);
                det.moves.push(Move { node: m.1, from: m.2, to: m.3 });
                det.gains.push(out.attributed_gain);
                // admissible cut point: the pair's blocks are inside their
                // limits right now (no other block moved since its own
                // pair finished, so this is a globally balanced state)
                det.admissible.push(
                    phg.block_weight(pmin) <= phg.max_block_weight(pmin)
                        && phg.block_weight(pmax) <= phg.max_block_weight(pmax),
                );
            }
            // the §11 prefix-sum selection proves the pair boundary
            // feasible whenever the pair started feasible
            debug_assert!(
                !feasible_before || det.admissible.last().copied().unwrap_or(true),
                "prefix selection violated a block weight limit"
            );
        }
        if det.moves.is_empty() {
            break;
        }

        // ---- balance-admissible best-prefix revert (§6.3 discipline) ----
        // ties pick the longest admissible prefix, so zero-gain plateau
        // moves behind a positive prefix survive the round
        let mut cut = 0usize;
        let mut total: Gain = 0;
        let mut acc: Gain = 0;
        for (p, &g) in det.gains.iter().enumerate() {
            acc += g;
            if det.admissible[p] && acc > 0 && acc >= total {
                total = acc;
                cut = p + 1;
            }
        }
        for m in det.moves[cut..].iter().rev() {
            phg.move_unchecked_p::<P>(m.node, m.from, table);
        }
        if let Some(gt) = table {
            // movers' own benefits are the one thing the update rules
            // leave stale (§6.2); repair them — applied and reverted alike
            for m in &det.moves {
                gt.recompute_benefit_p::<P, H>(phg, m.node);
            }
        }
        stats.rounds = round + 1;
        stats.improvement += total;
        stats.moves_applied += cut;
        if total <= 0 {
            break;
        }

        // ---- seeded mode: expand around the kept moves (§9) ----
        if seed_set.is_some() {
            let hg = phg.hypergraph();
            for m in &det.moves[..cut] {
                for &e in hg.incident_nets(m.node) {
                    if hg.net_size(e) <= EXPANSION_NET_SIZE_LIMIT {
                        det.candidates.extend_from_slice(hg.pins(e));
                    }
                }
            }
            det.candidates.sort_unstable();
            det.candidates.dedup();
        }
    }
    stats
}

/// Sort/group key of a desired move: `(min block, max block, direction)`
/// with direction 0 for `min → max` moves.
#[inline]
fn pair_dir(m: &(Gain, NodeId, BlockId, BlockId)) -> (BlockId, BlockId, u8) {
    if m.2 < m.3 {
        (m.2, m.3, 0)
    } else {
        (m.3, m.2, 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::{Context, Preset};
    use crate::generators::{planted_hypergraph, PlantedParams};
    use crate::hypergraph::dynamic::DynamicHypergraph;
    use crate::hypergraph::Hypergraph;
    use crate::refinement::lp;
    use crate::util::Rng;
    use std::sync::Arc;

    fn ctx(k: usize, threads: usize, seed: u64) -> Context {
        Context::new(Preset::Deterministic, k, 0.03).with_threads(threads).with_seed(seed)
    }

    fn perturbed(seed: u64, k: usize, flips: usize) -> PartitionedHypergraph {
        let p = PlantedParams { n: 300, m: 600, blocks: k, ..Default::default() };
        let hg = Arc::new(planted_hypergraph(&p, seed));
        let n = hg.num_nodes();
        let mut rng = Rng::new(seed ^ 0x123);
        let mut parts: Vec<BlockId> = (0..n).map(|u| (u * k / n) as BlockId).collect();
        for _ in 0..flips {
            parts[rng.next_below(n)] = rng.next_below(k) as BlockId;
        }
        let mut phg = PartitionedHypergraph::new(hg, k);
        phg.set_uniform_max_weight(0.3);
        phg.assign_all(&parts, 1);
        phg
    }

    #[test]
    fn improves_and_accounts_exactly() {
        for threads in [1, 4] {
            let phg = perturbed(2, 2, 60);
            let before = phg.km1();
            let stats = fm_refine_deterministic(&phg, &ctx(2, threads, 2));
            assert!(stats.improvement > 0, "t={threads}: no improvement");
            assert_eq!(phg.km1(), before - stats.improvement, "t={threads}");
            assert!(phg.is_balanced());
            phg.verify_consistency().unwrap();
        }
    }

    #[test]
    fn thread_count_invariant() {
        // the §11 contract: bit-identical partitions and improvements for
        // 1, 2 and 4 threads, global and seeded mode alike
        for seed in [3u64, 11, 29] {
            let reference: Vec<(i64, Vec<BlockId>)> = [1usize, 2, 4]
                .iter()
                .map(|&t| {
                    let phg = perturbed(seed, 3, 70);
                    let stats = fm_refine_deterministic(&phg, &ctx(3, t, seed));
                    phg.verify_consistency().unwrap();
                    (stats.improvement, phg.parts())
                })
                .collect();
            assert_eq!(reference[0], reference[1], "seed {seed}: t=1 vs t=2");
            assert_eq!(reference[1], reference[2], "seed {seed}: t=2 vs t=4");
            let seeded: Vec<Vec<BlockId>> = [1usize, 4]
                .iter()
                .map(|&t| {
                    let phg = perturbed(seed, 3, 70);
                    let seeds: Vec<NodeId> =
                        (0..phg.hypergraph().num_nodes() as NodeId).step_by(3).collect();
                    let mut ws = Workspace::new(3, t, phg.hypergraph().num_nodes());
                    fm_refine_deterministic_with_workspace(
                        &phg,
                        &ctx(3, t, seed),
                        Some(&seeds),
                        &mut ws,
                    );
                    phg.parts()
                })
                .collect();
            assert_eq!(seeded[0], seeded[1], "seed {seed}: seeded mode");
        }
    }

    #[test]
    fn never_worsens() {
        // the pair-unit best-prefix revert bounds every round at ≥ 0
        for seed in 0..6u64 {
            let phg = perturbed(seed, 3, 40);
            let before = phg.km1();
            let stats = fm_refine_deterministic(&phg, &ctx(3, 2, seed));
            assert!(stats.improvement >= 0, "seed {seed}");
            assert!(phg.km1() <= before, "seed {seed}");
            phg.verify_consistency().unwrap();
        }
    }

    #[test]
    fn escapes_det_lp_mirror_oscillation() {
        // nodes p=0 q=1 a=2 c=3 z=4, parts [0,1,0,0,1]; nets N0={p,q},
        // N1={a,c}, N2={a,z}. Initially N0 and N2 are cut (km1 = 2) and
        // every positive frozen move has a mirror: det-LP (one sub-round,
        // no revert) applies p→1 together with the mirror q→0 and stalls
        // at km1 = 1. Det-FM applies the same wishlist high-gain-first,
        // and its admissible best-prefix revert keeps the profitable
        // prefix (p, q, z in round 1; p in round 2) while undoing the
        // realized mirror losses — two rounds reach the optimum km1 = 0.
        let hg = Arc::new(Hypergraph::from_nets(
            5,
            &[vec![0, 1], vec![2, 3], vec![2, 4]],
            None,
            None,
        ));
        let build = || {
            let mut phg = PartitionedHypergraph::new(hg.clone(), 2);
            phg.set_max_weights(vec![5, 5]);
            phg.assign_all(&[0, 1, 0, 0, 1], 1);
            phg
        };
        let mut c = ctx(2, 2, 7);
        c.det_sub_rounds = 1; // one synchronous wishlist per round
        let lp_phg = build();
        assert_eq!(lp_phg.km1(), 2);
        lp::lp_refine_deterministic(&lp_phg, &c);
        assert_eq!(lp_phg.km1(), 1, "det-LP keeps the mirror losses and stalls");

        let fm_phg = build();
        let stats = fm_refine_deterministic(&fm_phg, &c);
        assert_eq!(fm_phg.km1(), 0, "det-FM reverts the mirror losses");
        assert_eq!(stats.improvement, 2);
        fm_phg.verify_consistency().unwrap();
    }

    #[test]
    fn prefix_selection_respects_non_uniform_limits() {
        // the required satellite property: under per-block set_max_weights
        // (non-uniform, some blocks tight), no applied prefix may ever
        // leave a block over its limit — across seeds and thread counts
        for seed in 0..5u64 {
            for threads in [1usize, 4] {
                let p = PlantedParams { n: 200, m: 400, blocks: 3, ..Default::default() };
                let hg = Arc::new(planted_hypergraph(&p, seed));
                let n = hg.num_nodes();
                let mut rng = Rng::new(seed ^ 0x77);
                let mut parts: Vec<BlockId> =
                    (0..n).map(|u| (u * 3 / n) as BlockId).collect();
                for _ in 0..n / 6 {
                    parts[rng.next_below(n)] = rng.next_below(3) as BlockId;
                }
                let mut phg = PartitionedHypergraph::new(hg, 3);
                phg.assign_all(&parts, 1);
                // non-uniform limits: one roomy block, two tight ones
                // (slack 2 and 5 above the current weight)
                let w0 = phg.block_weight(0);
                let w1 = phg.block_weight(1);
                phg.set_max_weights(vec![w0 + 2, w1 + 5, 2 * n as i64]);
                assert!(phg.is_balanced());
                let before = phg.km1();
                let stats = fm_refine_deterministic(&phg, &ctx(3, threads, seed));
                assert!(
                    phg.is_balanced(),
                    "seed {seed} t={threads}: weights {:?} limits {:?}",
                    (0..3).map(|b| phg.block_weight(b)).collect::<Vec<_>>(),
                    (0..3).map(|b| phg.max_block_weight(b)).collect::<Vec<_>>()
                );
                assert_eq!(phg.km1(), before - stats.improvement);
                phg.verify_consistency().unwrap();
            }
        }
    }

    #[test]
    fn runs_on_the_dynamic_hypergraph() {
        // the HypergraphOps requirement: the same refiner on the n-level
        // representation, global and seeded, matching the static result
        let p = PlantedParams { n: 250, m: 450, blocks: 2, ..Default::default() };
        let static_hg = Arc::new(planted_hypergraph(&p, 9));
        let dyn_hg = Arc::new(DynamicHypergraph::from_hypergraph(&static_hg));
        let n = static_hg.num_nodes();
        let mut rng = Rng::new(0x5eed);
        let mut parts: Vec<BlockId> = (0..n).map(|u| (u * 2 / n) as BlockId).collect();
        for _ in 0..n / 5 {
            parts[rng.next_below(n)] = rng.next_below(2) as BlockId;
        }
        let run_static = || {
            let mut phg = PartitionedHypergraph::new(static_hg.clone(), 2);
            phg.set_uniform_max_weight(0.3);
            phg.assign_all(&parts, 1);
            fm_refine_deterministic(&phg, &ctx(2, 2, 5));
            phg.parts()
        };
        let run_dynamic = |seeds: Option<Vec<NodeId>>| {
            let mut phg = PartitionedHypergraph::new(dyn_hg.clone(), 2);
            phg.set_uniform_max_weight(0.3);
            phg.assign_all(&parts, 1);
            let mut ws = Workspace::new(2, 2, n);
            fm_refine_deterministic_with_workspace(
                &phg,
                &ctx(2, 2, 5),
                seeds.as_deref(),
                &mut ws,
            );
            phg.verify_consistency().unwrap();
            phg.parts()
        };
        assert_eq!(run_static(), run_dynamic(None), "static vs dynamic global mode");
        let all: Vec<NodeId> = (0..n as NodeId).collect();
        let seeded = run_dynamic(Some(all));
        assert_eq!(seeded.len(), n, "seeded mode runs on the dynamic structure");
    }

    #[test]
    fn workspace_reuse_is_bit_identical() {
        // a dirty reused workspace must behave like a fresh one
        let c = ctx(2, 2, 21);
        let phg_a = perturbed(21, 2, 60);
        let phg_b = perturbed(21, 2, 60);
        let sa = fm_refine_deterministic(&phg_a, &c);
        let mut ws = Workspace::new(2, 2, phg_b.hypergraph().num_nodes());
        let other = perturbed(22, 2, 30);
        fm_refine_deterministic_with_workspace(&other, &c, None, &mut ws);
        let sb = fm_refine_deterministic_with_workspace(&phg_b, &c, None, &mut ws);
        assert_eq!(sa.improvement, sb.improvement);
        assert_eq!(phg_a.parts(), phg_b.parts());
    }
}
