//! The adaptive stopping rule of Osipov & Sanders (paper §7).
//!
//! Models the gains observed since the last improvement as i.i.d. normal
//! and terminates a localized search when further improvement has become
//! unlikely: stop once `s·µ² > α·σ² + β`, where `µ` (< 0 in the
//! interesting case) and `σ²` are the mean/variance of the last `s` gains
//! and `β = ln(n)` grows slowly with the instance.

/// Streaming mean/variance over the gains since the last improvement.
pub struct AdaptiveStoppingRule {
    alpha: f64,
    beta: f64,
    s: u64,
    mean: f64,
    m2: f64,
}

impl AdaptiveStoppingRule {
    pub fn new(alpha: f64, n: usize) -> Self {
        AdaptiveStoppingRule {
            alpha,
            beta: (n.max(2) as f64).ln(),
            s: 0,
            mean: 0.0,
            m2: 0.0,
        }
    }

    /// Record the gain of a performed move.
    pub fn push(&mut self, gain: i64) {
        self.s += 1;
        let x = gain as f64;
        let d = x - self.mean;
        self.mean += d / self.s as f64;
        self.m2 += d * (x - self.mean);
    }

    /// Reset when a new best solution was found.
    pub fn improvement_found(&mut self) {
        self.s = 0;
        self.mean = 0.0;
        self.m2 = 0.0;
    }

    /// Should the search stop?
    pub fn should_stop(&self) -> bool {
        if self.s < 2 {
            return false;
        }
        let var = self.m2 / (self.s - 1) as f64;
        // positive drift: keep going
        if self.mean > 0.0 {
            return false;
        }
        self.s as f64 * self.mean * self.mean > self.alpha * var + self.beta
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_going_on_positive_gains() {
        let mut r = AdaptiveStoppingRule::new(1.0, 1000);
        for _ in 0..100 {
            r.push(2);
        }
        assert!(!r.should_stop());
    }

    #[test]
    fn stops_on_long_negative_plateau() {
        let mut r = AdaptiveStoppingRule::new(1.0, 1000);
        let mut stopped = false;
        for _ in 0..200 {
            r.push(-1);
            if r.should_stop() {
                stopped = true;
                break;
            }
        }
        assert!(stopped, "persistent losses must trigger the rule");
    }

    #[test]
    fn reset_on_improvement() {
        let mut r = AdaptiveStoppingRule::new(1.0, 1000);
        for _ in 0..50 {
            r.push(-1);
        }
        r.improvement_found();
        assert!(!r.should_stop());
        r.push(-1);
        assert!(!r.should_stop(), "needs evidence again after reset");
    }

    #[test]
    fn high_variance_delays_stop() {
        let mut low_var = AdaptiveStoppingRule::new(1.0, 100);
        let mut high_var = AdaptiveStoppingRule::new(1.0, 100);
        let mut stop_low = None;
        let mut stop_high = None;
        for i in 0..500 {
            low_var.push(-1);
            high_var.push(if i % 2 == 0 { -30 } else { 28 });
            if stop_low.is_none() && low_var.should_stop() {
                stop_low = Some(i);
            }
            if stop_high.is_none() && high_var.should_stop() {
                stop_high = Some(i);
            }
        }
        assert!(stop_low.is_some());
        assert!(
            stop_high.unwrap_or(usize::MAX) > stop_low.unwrap(),
            "noisy searches run longer"
        );
    }
}
