//! The parallel localized k-way FM algorithm (paper §7, Algorithm 7.1).
//!
//! Rounds: all boundary nodes enter a shared task queue; threads poll
//! batches of seed nodes and run *localized* FM searches that expand to
//! neighbors of moved nodes. Searches own their nodes exclusively, move
//! them on a thread-local [`DeltaPartition`] first, and publish the
//! pending moves to the global partition as soon as the local gain is
//! positive. After the queue drains, the exact gains of the global move
//! sequence are recomputed in parallel (§6.3) and the sequence is
//! reverted to its best prefix.

pub mod delta;
pub mod stop;

pub use delta::DeltaPartition;
pub use stop::AdaptiveStoppingRule;

use crate::coordinator::context::Context;
use crate::datastructures::{AddressablePQ, ConcurrentQueue};
use crate::partition::{
    gain_recalculation::{recalculate_gains, revert_to_best_prefix},
    GainTable, Move, PartitionedHypergraph,
};
use crate::util::rng::hash2;
use crate::util::Rng;
use crate::{Gain, NodeId};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// Summary of an FM invocation.
#[derive(Clone, Copy, Debug, Default)]
pub struct FmStats {
    pub rounds: usize,
    pub improvement: Gain,
    pub moves_applied: usize,
}

/// Cap on net size during search expansion: gain updates on huge nets are
/// prohibitively expensive and rarely change decisions (the paper notes
/// FM outliers on instances with many large nets).
const EXPANSION_NET_SIZE_LIMIT: usize = 512;

/// Parallel k-way FM refinement; returns round/improvement statistics.
pub fn fm_refine(phg: &PartitionedHypergraph, ctx: &Context) -> FmStats {
    fm_refine_with_seeds(phg, ctx, None)
}

/// FM restricted to the given seed nodes (the highly-localized variant
/// run after each n-level batch uncontraction, paper §9). `None` seeds
/// all boundary nodes.
pub fn fm_refine_with_seeds(
    phg: &PartitionedHypergraph,
    ctx: &Context,
    seed_set: Option<&[NodeId]>,
) -> FmStats {
    let n = phg.hypergraph().num_nodes();
    let gt = GainTable::new(n, phg.k());
    gt.initialize(phg, ctx.threads);
    let mut stats = FmStats::default();

    for round in 0..ctx.fm_max_rounds {
        // --- seed queue: boundary nodes (of the seed set), random order ---
        let mut boundary: Vec<NodeId> = match seed_set {
            Some(set) => set.iter().copied().filter(|&u| phg.is_border(u)).collect(),
            None => (0..n as NodeId).filter(|&u| phg.is_border(u)).collect(),
        };
        Rng::new(hash2(ctx.seed ^ 0xf3, round as u64)).shuffle(&mut boundary);
        if boundary.is_empty() {
            break;
        }
        let queue = ConcurrentQueue::from_iter(boundary);
        let owner: Vec<AtomicBool> = (0..n).map(|_| AtomicBool::new(false)).collect();
        let global_moves: Mutex<Vec<Move>> = Mutex::new(Vec::new());

        std::thread::scope(|s| {
            for _ in 0..ctx.threads.max(1) {
                s.spawn(|| {
                    let mut search = LocalSearch::new(phg, &gt, ctx);
                    loop {
                        let seeds = queue.pop_many(ctx.fm_seeds_per_poll.max(1));
                        if seeds.is_empty() {
                            break;
                        }
                        search.run(&seeds, &owner, &global_moves);
                    }
                });
            }
        });

        // --- global recalculation + best-prefix revert (§6.3) ---
        let moves = global_moves.into_inner().unwrap();
        if moves.is_empty() {
            break;
        }
        let gains = recalculate_gains(phg, &moves, ctx.threads);
        let (len, total) = revert_to_best_prefix(phg, &moves, &gains, Some(&gt));
        // repair benefits of all touched nodes (paper: recompute after the
        // round instead of immediately after each move)
        for m in &moves {
            gt.recompute_benefit(phg, m.node);
        }
        stats.rounds = round + 1;
        stats.improvement += total;
        stats.moves_applied += len;
        if total <= 0 {
            break;
        }
    }
    stats
}

/// One thread's localized FM search state (reused across seed batches).
struct LocalSearch<'a> {
    phg: &'a PartitionedHypergraph,
    gt: &'a GainTable,
    ctx: &'a Context,
    delta: DeltaPartition<'a>,
    pq: AddressablePQ,
}

impl<'a> LocalSearch<'a> {
    fn new(phg: &'a PartitionedHypergraph, gt: &'a GainTable, ctx: &'a Context) -> Self {
        LocalSearch { phg, gt, ctx, delta: DeltaPartition::new(phg), pq: AddressablePQ::new() }
    }

    /// Algorithm 7.1's `LocalizedFMRefinement`.
    fn run(
        &mut self,
        seeds: &[NodeId],
        owner: &[AtomicBool],
        global_moves: &Mutex<Vec<Move>>,
    ) {
        self.pq.clear();
        self.delta.clear();
        let mut acquired: Vec<NodeId> = Vec::new();
        for &u in seeds {
            if try_acquire(owner, u) {
                acquired.push(u);
                if let Some((g, _)) = self.gt.max_gain_move(self.phg, u) {
                    self.pq.insert(u, g);
                }
            }
        }
        let mut local_moves: Vec<Move> = Vec::new();
        let mut dtotal: Gain = 0;
        let mut moved_globally: Vec<NodeId> = Vec::new();
        let mut stop =
            AdaptiveStoppingRule::new(self.ctx.fm_adaptive_alpha, self.phg.hypergraph().num_nodes());

        while let Some((u, g)) = self.pq.pop_max() {
            // lazy PQ: recompute the exact (delta-aware) best move
            let Some((g2, t2)) = self.delta.max_gain_move(u) else { continue };
            if g2 < g {
                self.pq.insert(u, g2);
                continue;
            }
            let from = self.delta.block_of(u);
            let Some(gain) = self.delta.try_move(u, t2) else { continue };
            debug_assert_eq!(gain, g2);
            dtotal += gain;
            local_moves.push(Move { node: u, from, to: t2 });
            stop.push(gain);

            // improvement (or perfect-balance tie): publish to global
            if dtotal > 0 {
                if self.apply_globally(&mut local_moves, global_moves, &mut moved_globally) {
                    dtotal = 0;
                    stop.improvement_found();
                } else {
                    break; // global balance conflict: abort this search
                }
            }

            // expand to neighbors of the moved node
            self.expand(u, owner, &mut acquired);

            if stop.should_stop() {
                break;
            }
        }
        // drop unpublished local moves (ΔΠ discarded implicitly)
        self.delta.clear();
        // release ownership of nodes that were not globally moved
        for &u in &acquired {
            if !moved_globally.contains(&u) {
                owner[u as usize].store(false, Ordering::Release);
            }
        }
    }

    /// Apply the pending local moves to the global partition (Alg. 7.1
    /// line 18). Returns false if a balance conflict forced a rollback.
    fn apply_globally(
        &mut self,
        local_moves: &mut Vec<Move>,
        global_moves: &Mutex<Vec<Move>>,
        moved_globally: &mut Vec<NodeId>,
    ) -> bool {
        let mut applied: Vec<Move> = Vec::with_capacity(local_moves.len());
        for m in local_moves.iter() {
            if self.phg.try_move(m.node, m.to, Some(self.gt)).is_some() {
                applied.push(*m);
            } else {
                // rollback: another thread consumed the balance slack
                for a in applied.iter().rev() {
                    self.phg.move_unchecked(a.node, a.from, Some(self.gt));
                }
                local_moves.clear();
                self.delta.clear();
                return false;
            }
        }
        moved_globally.extend(applied.iter().map(|m| m.node));
        global_moves.lock().unwrap().extend(applied);
        local_moves.clear();
        self.delta.clear();
        true
    }

    /// Claim the neighbors of a moved node and (re)insert them in the PQ.
    ///
    /// PQ keys come from the *global gain table* (O(k) per node — the
    /// paper's "use the gain table … combining global gain table and ΔΠ
    /// data"); the exact delta-aware gain is recomputed lazily at pop
    /// time, so temporarily stale keys only cost a reinsertion.
    fn expand(&mut self, u: NodeId, owner: &[AtomicBool], acquired: &mut Vec<NodeId>) {
        let hg = self.phg.hypergraph();
        for &e in hg.incident_nets(u) {
            if hg.net_size(e) > EXPANSION_NET_SIZE_LIMIT {
                continue;
            }
            for &v in hg.pins(e) {
                if v == u {
                    continue;
                }
                if self.pq.contains(v) {
                    if let Some((g, _)) = self.gt.max_gain_move(self.phg, v) {
                        self.pq.adjust(v, g);
                    }
                } else if !owner[v as usize].load(Ordering::Relaxed) && try_acquire(owner, v) {
                    acquired.push(v);
                    if let Some((g, _)) = self.gt.max_gain_move(self.phg, v) {
                        self.pq.insert(v, g);
                    }
                }
            }
        }
    }
}

#[inline]
fn try_acquire(owner: &[AtomicBool], u: NodeId) -> bool {
    !owner[u as usize].swap(true, Ordering::AcqRel)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::{Context, Preset};
    use crate::generators::{planted_hypergraph, PlantedParams};
    use crate::BlockId;
    use std::sync::Arc;

    fn ctx(k: usize, threads: usize, seed: u64) -> Context {
        Context::new(Preset::Default, k, 0.03).with_threads(threads).with_seed(seed)
    }

    fn perturbed(seed: u64, k: usize, flips: usize) -> PartitionedHypergraph {
        let p = PlantedParams { n: 300, m: 600, blocks: k, ..Default::default() };
        let hg = Arc::new(planted_hypergraph(&p, seed));
        let n = hg.num_nodes();
        let mut rng = Rng::new(seed ^ 0x123);
        let mut parts: Vec<BlockId> = (0..n).map(|u| (u * k / n) as BlockId).collect();
        for _ in 0..flips {
            parts[rng.next_below(n)] = rng.next_below(k) as BlockId;
        }
        let mut phg = PartitionedHypergraph::new(hg, k);
        phg.set_uniform_max_weight(0.3);
        phg.assign_all(&parts, 1);
        phg
    }

    #[test]
    fn fm_improves_and_accounts_exactly() {
        for threads in [1, 4] {
            let phg = perturbed(2, 2, 60);
            let before = phg.km1();
            let stats = fm_refine(&phg, &ctx(2, threads, 2));
            assert!(stats.improvement > 0, "t={threads}: no improvement");
            assert_eq!(phg.km1(), before - stats.improvement, "t={threads}");
            assert!(phg.is_balanced());
            phg.verify_consistency().unwrap();
        }
    }

    #[test]
    fn fm_beats_lp_on_non_trivial_instances() {
        // FM escapes local optima LP cannot (negative-gain move sets)
        let phg_lp = perturbed(7, 4, 90);
        let phg_fm = perturbed(7, 4, 90);
        assert_eq!(phg_lp.km1(), phg_fm.km1());
        crate::refinement::lp::lp_refine(&phg_lp, &ctx(4, 2, 7));
        fm_refine(&phg_fm, &ctx(4, 2, 7));
        crate::refinement::lp::lp_refine(&phg_fm, &ctx(4, 2, 7));
        assert!(
            phg_fm.km1() <= phg_lp.km1(),
            "FM({}) should be at least as good as LP({})",
            phg_fm.km1(),
            phg_lp.km1()
        );
    }

    #[test]
    fn fm_never_worsens() {
        for seed in 0..5u64 {
            let phg = perturbed(seed, 3, 40);
            let before = phg.km1();
            let stats = fm_refine(&phg, &ctx(3, 2, seed));
            assert!(stats.improvement >= 0, "best-prefix revert forbids regressions");
            assert!(phg.km1() <= before);
            phg.verify_consistency().unwrap();
        }
    }

    #[test]
    fn fm_respects_balance() {
        let phg = perturbed(11, 2, 50);
        fm_refine(&phg, &ctx(2, 4, 11));
        assert!(phg.is_balanced());
        assert!(phg.imbalance() <= 0.03 + 1e-9);
    }

    #[test]
    fn sequential_twoway_fm_for_bipartitions() {
        // the IP portfolio uses fm_refine with 1 thread on k=2
        let phg = perturbed(13, 2, 80);
        let before = phg.km1();
        let mut c = ctx(2, 1, 13);
        c.fm_max_rounds = 5;
        let stats = fm_refine(&phg, &c);
        assert!(stats.improvement > 0);
        assert_eq!(phg.km1(), before - stats.improvement);
    }
}
